"""Benchmark harness — one function per paper table/figure plus the
TPU-analogue and fabric-runtime benches.  Prints ``name,us_per_call,derived``
CSV rows; ``--json`` additionally writes one ``BENCH_<mode>.json`` per bench
mode at the repo root (schema: mode, config, wall_clock_s, rows, details) so
the perf trajectory is tracked across PRs — CI uploads them as artifacts
from the nightly job.

  PYTHONPATH=src python -m benchmarks.run                    # everything
  PYTHONPATH=src python -m benchmarks.run fig8 fig9          # subset
  PYTHONPATH=src python -m benchmarks.run --json fabric_tail dse
"""

from __future__ import annotations

import pathlib
import sys
import time

import numpy as np

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

_JSON_ROWS: list[dict] = []
_JSON_DETAILS: list[list] = []


def write_bench_json(mode: str, payload: dict) -> pathlib.Path:
    """Serialize one bench mode's payload to ``BENCH_<mode>.json`` at the
    repo root — the single write path every mode shares (schema: mode,
    config, wall_clock_s, rows, details).  ``benchmarks/check_drift.py``
    and the nightly CI artifact upload both consume exactly this layout."""
    import json

    path = REPO_ROOT / f"BENCH_{mode}.json"
    with open(path, "w") as f:
        json.dump({"mode": mode, **payload}, f, indent=2)
    print(f"# wrote {path}", file=sys.stderr)
    return path


def _bench_config() -> dict:
    import platform

    cfg = {
        "python": platform.python_version(),
        "platform": platform.platform(),
        "numpy": np.__version__,
        "argv": sys.argv[1:],
    }
    try:
        import jax

        cfg["jax"] = jax.__version__
    except Exception:
        cfg["jax"] = None
    return cfg


class _Timing(float):
    """Steady-state us-per-call that also carries the first-call time (which
    pays jit compile / tracing / cache warmup) — the compile-vs-run split."""

    first_us: float | None = None


def _timeit(fn, repeats=3):
    t0 = time.perf_counter()
    fn()  # warm — the first call pays compile/trace/cache fill
    first = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    out = _Timing((time.perf_counter() - t0) / repeats * 1e6)
    out.first_us = first
    return out


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
    row = {"name": name, "us_per_call": round(us, 1), "derived": derived}
    first = getattr(us, "first_us", None)
    if first is not None:  # compile-vs-run breakdown from _timeit
        row["first_call_us"] = round(first, 1)
    _JSON_ROWS.append(row)


def _detail(*fields):
    print("#" + ",".join(str(f) for f in fields))
    _JSON_DETAILS.append(list(fields))


# --------------------------------------------------------------------- paper
_PROFILES = {}


def _profile(netname):
    if netname not in _PROFILES:
        from repro.core.cim import profile_network, resnet18_imagenet, vgg11_cifar10

        spec = resnet18_imagenet() if netname == "resnet18" else vgg11_cifar10()
        _PROFILES[netname] = (spec, profile_network(spec, n_images=2))
    return _PROFILES[netname]


def fig4():
    """Cycles per array vs '1'-bit density (ResNet18 layers) — paper Fig 4."""
    from repro.core.cim import expected_cycles_from_density

    spec, prof = _profile("resnet18")
    dens = np.array([lp.density for lp in prof.layers])
    cyc = np.array([lp.mean_cycles.mean() for lp in prof.layers])
    # linearity: correlation between density and measured mean cycles
    r = np.corrcoef(dens, cyc)[0, 1]
    us = _timeit(lambda: expected_cycles_from_density(dens, 128))
    _row("fig4_cycles_vs_density", us, f"pearson_r={r:.3f}")
    for lp in prof.layers:
        _detail("fig4", lp.name, f"{lp.density:.4f}", f"{lp.mean_cycles.mean():.1f}")


def fig6():
    """Per-block cycle skew for ResNet18 layers 10 and 15 — paper Fig 6."""
    spec, prof = _profile("resnet18")
    rows = []
    for idx, label in ((6, "layer10"), (13, "layer15")):
        lp = prof.layers[idx]
        spread = lp.mean_cycles.max() / lp.mean_cycles.min() - 1
        rows.append((label, lp.mean_cycles, spread))
        for b, (d, c) in enumerate(zip(lp.block_density, lp.mean_cycles)):
            _detail("fig6", label, f"block{b}", f"{d:.4f}", f"{c:.1f}")
    _row(
        "fig6_block_skew",
        0.0,
        ";".join(f"{l}_spread={s*100:.0f}%" for l, _, s in rows),
    )


def fig8():
    """Throughput vs design size, 4 policies x 2 networks — paper Fig 8."""
    from repro.core.cim import run_policy

    for netname in ("resnet18", "vgg11"):
        spec, prof = _profile(netname)
        base_pes = spec.min_pes()
        # the paper's sweep: half-powers of 2 up to ~5.7x the minimum design
        sizes = [
            base_pes,
            int(base_pes * 1.41),
            base_pes * 2,
            int(base_pes * 2.83),
            base_pes * 4,
            int(base_pes * 5.66),
        ]
        results = {}
        t0 = time.perf_counter()
        for pol in ("baseline", "weight_based", "perf_layerwise", "blockwise"):
            results[pol] = [run_policy(spec, prof, pol, n).images_per_sec for n in sizes]
        us = (time.perf_counter() - t0) * 1e6
        bw, wb = results["blockwise"][-1], results["weight_based"][-1]
        bl, pl = results["baseline"][-1], results["perf_layerwise"][-1]
        _row(
            f"fig8_{netname}",
            us,
            f"blockwise_vs_weight={bw/wb:.2f}x;vs_baseline={bw/bl:.2f}x;vs_perf_layerwise={bw/pl:.2f}x",
        )
        for pol, vals in results.items():
            for n, v in zip(sizes, vals):
                _detail("fig8", netname, pol, n, f"{v:.1f}")


def ablation():
    """Separate the paper's two contributions: block-wise DATAFLOW alone
    (weight-based allocation) vs allocation+dataflow together."""
    from repro.core.cim import run_policy

    spec, prof = _profile("resnet18")
    pes = spec.min_pes() * 4
    import time as _t

    t0 = _t.perf_counter()
    wb = run_policy(spec, prof, "weight_based", pes).images_per_sec
    flow = run_policy(spec, prof, "weight_blockflow", pes).images_per_sec
    full = run_policy(spec, prof, "blockwise", pes).images_per_sec
    us = (_t.perf_counter() - t0) * 1e6
    _row(
        "ablation_dataflow_vs_allocation",
        us,
        f"dataflow_only={flow/wb:.2f}x;dataflow+alloc={full/wb:.2f}x "
        f"(of the {full/wb:.2f}x total, {flow/wb:.2f}x comes from the dataflow alone)",
    )


def fig9():
    """Array utilization per layer, ResNet18 — paper Fig 9."""
    from repro.core.cim import run_policy

    spec, prof = _profile("resnet18")
    pes = spec.min_pes() * 2
    t0 = time.perf_counter()
    utils = {
        pol: run_policy(spec, prof, pol, pes).layer_utilization
        for pol in ("weight_based", "perf_layerwise", "blockwise")
    }
    us = (time.perf_counter() - t0) * 1e6
    _row(
        "fig9_utilization",
        us,
        ";".join(f"{p}={u.mean():.3f}" for p, u in utils.items()),
    )
    for pol, u in utils.items():
        for i, v in enumerate(u):
            _detail("fig9", pol, f"layer{i}", f"{v:.3f}")


# ------------------------------------------------------------- TPU analogues
def expert_replication():
    """Paper technique at the MoE level: max-load + drop-rate relief."""
    from repro.core.alloc.expert import (
        drop_rate,
        expected_max_load,
        plan_replication,
    )

    rng = np.random.default_rng(0)
    hist = rng.pareto(1.1, size=160) + 0.05
    hist = hist / hist.sum()
    t0 = time.perf_counter()
    plan = plan_replication(hist, slot_budget=256, pad_to=256)
    us = (time.perf_counter() - t0) * 1e6
    base_max = expected_max_load(hist, n_tokens=65536, top_k=6)
    repl_max = expected_max_load(plan, n_tokens=65536, top_k=6)
    base_drop = drop_rate(hist, 65536, 6, 1.25)
    repl_drop = drop_rate(plan, 65536, 6, 1.25)
    _row(
        "expert_replication_160to256",
        us,
        f"max_load {base_max:.0f}->{repl_max:.0f} ({base_max/repl_max:.2f}x);"
        f"drop {base_drop*100:.1f}%->{repl_drop*100:.2f}%;balance={plan.balance:.3f}",
    )


def stage_balance():
    """Perf-based pipeline partitioning vs equal-count (paper Sec III-A)."""
    from repro.core.alloc.pipeline_stages import bottleneck, partition_stages

    rng = np.random.default_rng(1)
    costs = np.exp(rng.normal(0, 0.8, size=64))  # skewed per-layer costs
    P = 8
    t0 = time.perf_counter()
    smart = partition_stages(costs, P)
    us = (time.perf_counter() - t0) * 1e6
    step = -(-64 // P)
    naive = [(i * step, min((i + 1) * step, 64)) for i in range(P)]
    _row(
        "stage_balance_64L_8P",
        us,
        f"bottleneck {bottleneck(costs, naive):.2f}->{bottleneck(costs, smart):.2f} "
        f"({bottleneck(costs, naive)/bottleneck(costs, smart):.2f}x)",
    )


def kernels():
    """Pallas kernel interpret-mode sanity timings vs jnp references."""
    import jax
    from repro.kernels import ops, ref

    import jax.numpy as jnp

    key = jax.random.PRNGKey(0)
    # structured activation sparsity: half the tiles all-zero (the paper's
    # zero-skipping input regime at tile granularity)
    a = jax.nn.relu(jax.random.normal(key, (256, 256)))
    keep = jnp.kron(jnp.array([[1, 0], [0, 1]], jnp.float32), jnp.ones((128, 128)))
    a = a * keep
    b = jax.random.normal(key, (256, 256))
    us = _timeit(lambda: jax.block_until_ready(ops.zskip_matmul_op(a, b)))
    nz = float((ref.block_mask_ref(a, 128, 128) == 0).mean())
    _row("kernel_zskip_matmul_256", us, f"zero_tile_frac={nz:.2f}")

    q = jax.random.normal(key, (2, 128, 4, 64))
    us = _timeit(lambda: jax.block_until_ready(ops.flash_attention_op(q, q, q)))
    _row("kernel_flash_attention_128", us, "interpret=True")


def continuous_batching():
    """The paper's block-wise dataflow at the request level: static vs
    continuous batching under a log-normal generation-length workload."""
    from repro.serve.scheduler import (
        WorkloadConfig,
        sample_lengths,
        simulate_continuous,
        simulate_static,
    )
    import time as _t

    lens = sample_lengths(WorkloadConfig(n_requests=1024, mean_len=128, sigma=1.0))
    t0 = _t.perf_counter()
    st = simulate_static(lens, n_slots=32)
    ct = simulate_continuous(lens, n_slots=32)
    us = (_t.perf_counter() - t0) * 1e6
    _row(
        "continuous_batching_1024req_32slots",
        us,
        f"util {st.utilization:.2f}->{ct.utilization:.2f};"
        f"steps {st.total_steps}->{ct.total_steps} ({st.total_steps/ct.total_steps:.2f}x);"
        f"mean_latency {st.mean_latency:.0f}->{ct.mean_latency:.0f}",
    )


def roofline_table():
    """Re-emit the dry-run roofline table from results/ (no recompiles)."""
    import glob
    import json

    recs = []
    for f in sorted(glob.glob("results/dr_*.json")):
        recs.extend(json.load(open(f)))
    n_ok = sum(r["status"] == "ok" for r in recs)
    _row("roofline_table", 0.0, f"cells_ok={n_ok};cells_total={len(recs)}")
    for r in recs:
        if r["status"] != "ok":
            _detail("roofline", r["arch"], r["shape"], f"mp={int(r['multi_pod'])}", r["status"])
            continue
        ro = r["roofline"]
        _detail(
            "roofline", r["arch"], r["shape"], f"mp={int(r['multi_pod'])}",
            f"{ro['compute_s']:.3f}", f"{ro['memory_s']:.3f}",
            f"{ro['collective_s']:.3f}", ro["bottleneck"],
            f"{ro['roofline_fraction']:.4f}",
        )


# ------------------------------------------------------------ fabric runtime
def fabric_tail():
    """Tail latency across a (policy x load) grid on one fabric design:
    the scalar event engine vs ONE batched virtual-time evaluation of all
    (allocation, arrival-trace) pairs — the engine behind latency-aware
    provisioning.  Asserts bit-identical per-request completion times and
    reports the batch speedup (acceptance: >= 20x)."""
    from repro.core.cim import allocate, simulate
    from repro.core.cim.simulate import CLOCK_HZ
    from repro.fabric import (
        FabricSim,
        PoissonOpen,
        VirtualTimeFabric,
        provision_latency_aware,
    )

    spec, prof = _profile("vgg11")
    pes = spec.min_pes() * 2
    wb = allocate(spec, prof, "weight_based", pes)
    bw = allocate(spec, prof, "blockwise", pes)
    cap = simulate(spec, prof, bw, n_images=64).images_per_sec
    loads = (0.3, 0.5, 0.6, 0.7, 0.85)
    n_req = 400
    allocs, procs, labels = [], [], []
    vt_prov = VirtualTimeFabric(spec, prof, lane_quantum=8)  # shared warm cache
    for f in loads:
        la = provision_latency_aware(
            spec, prof, pes, offered_ips=f * cap, calib_requests=150, grants=0,
            vt=vt_prov,
        )
        proc = PoissonOpen(n_requests=n_req, rate_per_cycle=f * cap / CLOCK_HZ, seed=5)
        for pol, a in (("weight_based", wb), ("blockwise", bw), ("latency_aware", la)):
            allocs.append(a)
            procs.append(proc)
            labels.append((pol, f))

    t0 = time.perf_counter()
    scalar = [
        FabricSim(spec, prof, a, seed=3).run(p) for a, p in zip(allocs, procs)
    ]
    t_scalar = time.perf_counter() - t0

    vt = VirtualTimeFabric(spec, prof)
    t0 = time.perf_counter()
    vt.run_batch(allocs, procs, seed=3)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = vt.run_batch(allocs, procs, seed=3)
    t_warm = time.perf_counter() - t0

    bitident = all(
        np.array_equal(res.completions[i], r.completions)
        and np.array_equal(res.arrivals[i], r.arrivals)
        for i, r in enumerate(scalar)
    )
    # hard acceptance: the batched kernel must BE the event engine
    assert bitident, "virtual-time batch diverged from the scalar event engine"
    ms = 1e3 / CLOCK_HZ
    p99 = {lab: res.latency(i).p99 * ms for i, lab in enumerate(labels)}
    f0 = 0.7
    _row(
        f"fabric_tail_vgg11_{len(allocs)}cfg",
        t_warm * 1e6,
        f"speedup={t_scalar / t_warm:.1f}x;scalar_s={t_scalar:.2f};"
        f"batch_cold_s={t_cold:.2f};bitident={bitident};"
        f"p99@70% wb={p99[('weight_based', f0)]:.3f}ms "
        f"bw={p99[('blockwise', f0)]:.3f}ms "
        f"la={p99[('latency_aware', f0)]:.3f}ms",
    )
    for i, (pol, f) in enumerate(labels):
        st = res.latency(i)
        _detail(
            "fabric_tail", pol, f, f"{st.p50 * ms:.4f}", f"{st.p95 * ms:.4f}",
            f"{st.p99 * ms:.4f}", f"{st.mean * ms:.4f}",
        )


def fabric_drift():
    """Distribution shift mid-serve: stale allocation vs EWMA-triggered
    online re-allocation (warm-started greedy) vs clairvoyant oracle."""
    from repro.core.cim import allocate
    from repro.core.cim.simulate import ARRAYS_PER_PE
    from repro.fabric import (
        ClosedLoop,
        DriftConfig,
        FabricSim,
        OnlineReallocator,
        shift_profile,
    )

    spec, prof = _profile("vgg11")
    pes = spec.min_pes() * 2
    free = pes * ARRAYS_PER_PE - spec.n_arrays
    reserve = 0.4
    alloc0 = allocate(spec, prof, "blockwise", pes, free_budget=free * (1 - reserve))
    shifted = shift_profile(prof, {4: 1.8, 5: 1.8, 6: 1.8})
    cl = ClosedLoop(n_requests=120, concurrency=24)
    t0 = time.perf_counter()
    stale = FabricSim(spec, prof, alloc0, seed=2, live_prof=shifted).run(cl)
    rl = OnlineReallocator(spec, prof, reserve_arrays=free * reserve, cfg=DriftConfig())
    online = FabricSim(spec, prof, alloc0, seed=2, live_prof=shifted, reallocator=rl).run(cl)
    oracle = FabricSim(spec, shifted, allocate(spec, shifted, "blockwise", pes), seed=2).run(cl)
    us = (time.perf_counter() - t0) * 1e6
    ts, to, torc = stale.images_per_sec, online.images_per_sec, oracle.images_per_sec
    rec = (to - ts) / (torc - ts)
    if online.reallocations:
        ev = online.reallocations[0]
        realloc = f"stall={ev.stall_cycles:.0f}cyc;arrays_added={ev.arrays_added}"
    else:
        realloc = "realloc=never_tripped"
    _row(
        "fabric_drift_vgg11_shift1.8x",
        us,
        f"stale={ts:.0f};online={to:.0f};oracle={torc:.0f};recovery={rec:.2f};{realloc}",
    )
    _detail("fabric_drift", "stale", f"{ts:.1f}")
    _detail("fabric_drift", "online", f"{to:.1f}")
    _detail("fabric_drift", "oracle", f"{torc:.1f}")


def fabric_multitenant():
    """ResNet18 + VGG11 sharing one fabric, weighted-fair allocation."""
    from repro.core.cim.simulate import ARRAYS_PER_PE
    from repro.fabric import ClosedLoop, Tenant, allocate_shared, fairness_report, run_tenants

    rspec, rprof = _profile("resnet18")
    vspec, vprof = _profile("vgg11")
    tenants = [
        Tenant("resnet18", rspec, rprof, weight=2.0),
        Tenant("vgg11", vspec, vprof, weight=1.0),
    ]
    base = rspec.n_arrays + vspec.n_arrays
    n_pes = -(-base // ARRAYS_PER_PE) * 2
    t0 = time.perf_counter()
    shared = allocate_shared(tenants, n_pes=n_pes)
    results = run_tenants(shared, [ClosedLoop(60, 40), ClosedLoop(60, 16)], seed=0)
    us = (time.perf_counter() - t0) * 1e6
    rep = fairness_report(shared, results)
    _row(
        "fabric_multitenant_r18+vgg11",
        us,
        ";".join(
            f"{n}:ips={d['images_per_sec']:.0f},p99={d['latency_ms_p99']:.2f}ms,arrays={d['arrays']}"
            for n, d in rep["tenants"].items()
        )
        + f";balance={rep['weighted_rate_balance']:.2f}",
    )
    for n, d in rep["tenants"].items():
        _detail(
            "fabric_multitenant", n, d["weight"], d["arrays"],
            f"{d['images_per_sec']:.1f}", f"{d['latency_ms_p99']:.3f}",
            f"{d['mean_utilization']:.3f}",
        )


# ----------------------------------------------------------------- profile
class _LegacyProfiler:
    """The pre-batched-engine scalar profiler, kept verbatim as the bench
    baseline: per-layer host round-trips (``float(jnp.max)`` sync, numpy
    matmul), and a full ``np.unpackbits`` + python block loop per layer —
    re-run from scratch for EVERY array geometry."""

    def __init__(self, spec, key, sample_patches, array):
        import jax
        from repro.core.cim.profile import _kaiming

        self.spec = spec
        self.array = array
        self.sample = sample_patches
        self.records = {}
        keys = jax.random.split(key, len(spec.layers))
        self.weights = {
            i: _kaiming(keys[i], l.rows, l.cout) for i, l in enumerate(spec.layers)
        }
        self.rng = np.random.default_rng(0)

    def conv(self, idx, x):
        import jax
        import jax.numpy as jnp
        from repro.core.cim.profile import _im2col

        layer = self.spec.layers[idx]
        pat = _im2col(x, layer)
        relu = jax.nn.relu(pat)
        scale = float(jnp.max(relu)) / 255.0 + 1e-12  # host sync per layer
        q = np.asarray(jnp.clip(jnp.round(relu / scale), 0, 255), dtype=np.uint8)
        self._record(idx, layer, q)
        y = (q.astype(np.float32) * scale) @ np.asarray(self.weights[idx])
        n = x.shape[0]
        return jnp.asarray(y).reshape(n, layer.out_hw, layer.out_hw, layer.cout)

    def _record(self, idx, layer, q):
        from repro.core.cim.cost import baseline_cycles, zskip_cycles
        from repro.core.cim.profile import LayerProfile

        P = q.shape[0]
        take = min(self.sample, P)
        sel = self.rng.choice(P, size=take, replace=False)
        qs = q[sel]
        dens, cyc_cols, base = [], [], []
        bits_full = np.unpackbits(q[..., None], axis=-1)  # (P, rows, 8)
        for sl in layer.block_row_slices():
            rows_here = sl.stop - sl.start
            dens.append(bits_full[:, sl, :].mean())
            cyc_cols.append(zskip_cycles(qs[:, sl], self.array))
            base.append(baseline_cycles(rows_here, self.array))
        cyc = np.stack(cyc_cols, axis=-1)
        self.records[idx] = LayerProfile(
            name=layer.name,
            block_density=np.asarray(dens),
            mean_cycles=cyc.mean(axis=0),
            cycles_sample=cyc,
            baseline_block_cycles=np.asarray(base, dtype=np.int64),
            patches_per_image=layer.patches_per_image,
        )


def _legacy_profile_network(spec, n_images, sample_patches):
    import jax
    from repro.core.cim.profile import (
        NetworkProfile,
        _forward_resnet18,
        _forward_vgg11,
        _resolve_array,
        synthetic_images,
    )

    key = jax.random.PRNGKey(0)
    kimg, kw = jax.random.split(key)
    hw = 224 if spec.name == "resnet18" else 32
    x = synthetic_images(n_images, hw, kimg)
    p = _LegacyProfiler(spec, kw, sample_patches, array=_resolve_array(spec, None))
    (_forward_resnet18 if spec.name == "resnet18" else _forward_vgg11)(p, x)
    return NetworkProfile(
        spec.name, tuple(p.records[i] for i in range(len(spec.layers)))
    )


def profile():
    """The batched bit-plane profiling engine vs the pre-PR scalar profiler
    on a geometry x ADC sweep (ResNet18, the paper's workload).  The scalar
    path re-runs the quantized forward + full unpackbits per geometry; the
    engine captures activations ONCE (jit forward, in-graph popcount) and
    derives every geometry as a cheap bit-plane view.  Cold times include
    each path's own compile/warmup.  Acceptance: >=10x cold on the
    12-geometry sweep, engines bit-identical."""
    from repro.core.cim import DEFAULT_ARRAY, resnet18_imagenet
    from repro.core.cim.network import with_array
    from repro.core.cim.profile import capture_activations, derive_profile

    n_img, s_patches = 16, 128
    spec = resnet18_imagenet()
    geos = [
        DEFAULT_ARRAY.variant(rows=r, cols=r, adc_bits=a)
        for r in (64, 128, 256)
        for a in (2, 3, 4, 5)
    ]

    legacy_t = []
    legacy_first = None
    for g in geos:
        t0 = time.perf_counter()
        lp = _legacy_profile_network(with_array(spec, g), n_img, s_patches)
        legacy_t.append(time.perf_counter() - t0)
        legacy_first = legacy_first or lp

    t0 = time.perf_counter()
    cap = capture_activations(spec, n_images=n_img, sample_patches=s_patches)
    views = [derive_profile(cap, with_array(spec, g), array=g) for g in geos]
    t_cold = time.perf_counter() - t0
    t_cap0 = time.perf_counter()
    cap2 = capture_activations(spec, n_images=n_img, sample_patches=s_patches)
    t_cap_warm = time.perf_counter() - t_cap0
    t_derive = []
    for g in geos:
        t0 = time.perf_counter()
        derive_profile(cap2, with_array(spec, g), array=g)
        t_derive.append(time.perf_counter() - t0)
    t_warm = t_cap_warm + sum(t_derive)

    # the engine IS the scalar derivation, bit for bit (the golden suite
    # pins this per engine; re-checked here on the bench capture)
    ref = derive_profile(cap, with_array(spec, geos[0]), array=geos[0], engine="reference")
    bitident = all(
        np.array_equal(a.cycles_sample, b.cycles_sample)
        and np.array_equal(a.block_density, b.block_density)
        for a, b in zip(ref.layers, views[0].layers)
    )
    assert bitident, "profile engines diverged"
    # the legacy baseline measures the same statistics: geometry-derived
    # baselines bit-equal, densities within the XLA-vs-BLAS forward drift
    for a, b in zip(legacy_first.layers, views[0].layers):
        assert np.array_equal(a.baseline_block_cycles, b.baseline_block_cycles)
        assert a.cycles_sample.shape == b.cycles_sample.shape
        assert np.allclose(a.block_density, b.block_density, atol=0.05)

    # derives are pure numpy (no compile), so a K-geometry cold time is the
    # measured 12-geometry cold run minus the warm derive cost of the rest
    sp_1 = legacy_t[0] / (t_cold - sum(t_derive[1:]))
    sp_8 = sum(legacy_t[:8]) / (t_cold - sum(t_derive[8:]))
    sp_12 = sum(legacy_t) / t_cold
    _row(
        f"profile_resnet18_{len(geos)}geo_{n_img}img",
        t_cold * 1e6,
        f"speedup_12geo={sp_12:.1f}x;speedup_8geo={sp_8:.1f}x;"
        f"speedup_1geo={sp_1:.1f}x;legacy_12geo_s={sum(legacy_t):.1f};"
        f"engine_cold_s={t_cold:.2f};engine_warm_s={t_warm:.2f};"
        f"bitident={bitident}",
    )
    for g, lt, dt in zip(geos, legacy_t, t_derive):
        _detail(
            "profile", f"{g.rows}x{g.cols}", f"adc{g.adc_bits}",
            f"legacy_s={lt:.2f}", f"derive_s={dt:.4f}",
        )


# ------------------------------------------------------------------- dse
def dse():
    """Vectorized design-space sweep vs the scalar loop: >=1000 (policy,
    PE-count, array-geometry) configs, element-wise equivalence + speedup."""
    import numpy as np

    from repro.core.cim import DEFAULT_ARRAY
    from repro.dse import design_grid, pareto_frontier, run_sweep

    arrays = (
        DEFAULT_ARRAY,
        DEFAULT_ARRAY.variant(adc_bits=2),
        DEFAULT_ARRAY.variant(rows=256, cols=256),
    )
    points = design_grid(
        networks=("vgg11",),
        pe_multipliers=tuple(np.linspace(1.0, 6.0, 67)),
        arrays=arrays,
    )
    kw = dict(profile_images=1, sample_patches=64)
    cold = run_sweep(points, **kw)  # includes jit compile
    warm = run_sweep(points, **kw)
    scalar = run_sweep(points, engine="scalar", **kw)
    err = max(
        np.abs((warm.total_cycles - scalar.total_cycles) / scalar.total_cycles).max(),
        np.abs((warm.images_per_sec - scalar.images_per_sec) / scalar.images_per_sec).max(),
        np.abs(
            (warm.mean_utilization - scalar.mean_utilization) / scalar.mean_utilization
        ).max(),
    )
    alloc_equal = bool((warm.arrays_used == scalar.arrays_used).all())
    frontier = pareto_frontier(warm)
    _row(
        f"dse_sweep_vgg11_{len(points)}cfg",
        warm.elapsed_s * 1e6,
        f"speedup={scalar.elapsed_s / warm.elapsed_s:.1f}x;"
        f"scalar_s={scalar.elapsed_s:.2f};batch_cold_s={cold.elapsed_s:.2f};"
        f"max_rel_err={err:.1e};alloc_equal={alloc_equal};"
        f"pareto_points={len(frontier)}",
    )
    for i in frontier[:: max(1, len(frontier) // 20)]:
        p = warm.points[i]
        _detail(
            "dse_pareto", p.network, p.policy, p.n_pes,
            f"{p.array.rows}x{p.array.cols}", f"adc{p.array.adc_bits}",
            int(warm.arrays_total[i]), f"{warm.images_per_sec[i]:.1f}",
            f"{warm.mean_utilization[i]:.3f}",
        )


def fabric_multichip():
    """Equal-silicon scale-out: one fabric budget tiled over 1..8 chips at
    several link bandwidths, placed by the communication-aware allocator and
    measured on the batched virtual-time engine WITH inter-chip transfer
    delays.  The headline is the chip-scaling curve: throughput retention
    and p99 inflation vs the single-chip design at each link speed."""
    from repro.dse import (
        MULTICHIP_OBJECTIVES,
        chip_grid,
        pareto_frontier,
        run_multichip_sweep,
    )

    chips = (1, 2, 4, 8)
    links = (16.0, 64.0, 256.0)
    pts = chip_grid(
        networks=("vgg11",), chips=chips, link_gbps=links, pe_multiplier=2.0
    )
    t0 = time.perf_counter()
    res = run_multichip_sweep(
        pts, n_requests=200, closed_requests=60, concurrency=24,
        sample_patches=64, seed=0,
    )
    us = (time.perf_counter() - t0) * 1e6
    rows = {(p.n_chips, p.link_gbps): i for i, p in enumerate(res.points)}
    ret = {
        g: res.images_per_sec[rows[(8, g)]] / res.images_per_sec[rows[(1, g)]]
        for g in links
    }
    p99x = {
        g: res.p99_cycles[rows[(8, g)]] / res.p99_cycles[rows[(1, g)]]
        for g in links
    }
    frontier = pareto_frontier(res, MULTICHIP_OBJECTIVES)
    _row(
        f"fabric_multichip_vgg11_{len(pts)}cfg",
        us,
        ";".join(f"retention8chip@{g:.0f}gbps={ret[g]:.2f}x" for g in links)
        + ";"
        + ";".join(f"p99_8chip@{g:.0f}gbps={p99x[g]:.2f}x" for g in links)
        + f";pareto_points={len(frontier)}",
    )
    for r in res.rows():
        _detail(
            "fabric_multichip", r["network"], r["n_chips"],
            f"{r['link_gbps']:.0f}", f"{r['images_per_sec']:.1f}",
            f"{r['p50_ms']:.4f}", f"{r['p95_ms']:.4f}", f"{r['p99_ms']:.4f}",
            f"{r['max_stage_transfer_cycles']:.0f}", r["n_crossings"],
        )


def dse_fused():
    """The one-jit fused DSE pipeline (shared per-ADC bank stacks, event-
    schedule allocation replay, chunk-streamed scatter+eval dispatches) vs
    the staged path (host profile derive per (geometry, ADC) +
    allocate_batch + BatchSimulator per group), plus the lifted
    placement x load axis vs running the staged multichip sweep once per
    load.  The headline grid is 10^6 analytic configs streamed through the
    chunked driver; a density sub-table re-times the VGG11 analytic grid at
    several budgets-per-variant densities (the regime axis where the
    pre-shared-bank fused path used to LOSE — 0.69x at 6,400 pv).  Both
    paths share one warm activation capture; each analytic pass is timed on
    its second (compile-warm) invocation, with the staged pass re-paying
    the host profile derivation every run (that derivation is part of what
    the fusion moved in-graph).  Per-stage wall times and peak RSS land in
    the BENCH json as telemetry gauges.  Acceptance: every integer-cycle
    analytic column bit-equal (utilization at ULP tolerance), the 0.7-load
    chip column bit-equal, and the committed headlines
    ``end_to_end_speedup`` AND ``analytic_speedup`` present
    (benchmarks/check_drift.py errors out if either goes missing)."""
    import resource

    from repro.core.cim import DEFAULT_ARRAY
    from repro.dse import (
        chip_grid,
        design_grid,
        run_fused_multichip_sweep,
        run_fused_sweep,
        run_sweep,
    )
    from repro.dse.sweep import _PROFILE_CACHE, get_captured, run_multichip_sweep
    from repro.fabric.telemetry import get_telemetry

    arrays = tuple(
        DEFAULT_ARRAY.variant(rows=r, cols=r, adc_bits=a)
        for r in (128, 256)
        for a in (1, 2, 3, 4, 5, 6, 7, 8)
    )
    pols = ("baseline", "weight_based", "perf_layerwise", "blockwise")

    def vgg_grid(n_budgets):
        return design_grid(
            networks=("vgg11",), policies=pols,
            pe_multipliers=tuple(np.linspace(1.0, 6.0, n_budgets)),
            arrays=arrays,
        )

    # 64 (geometry, ADC, policy) variants x 11,250 + 4,400 budgets = the
    # 10^6-config headline grid the chunked fused driver streams through
    pts = vgg_grid(11250) + design_grid(
        networks=("resnet18",), policies=pols,
        pe_multipliers=tuple(np.linspace(1.0, 2.5, 4400)), arrays=arrays,
    )
    for net in ("vgg11", "resnet18"):
        get_captured(net)  # shared capture, warmed outside both timings

    def staged_pass(p):
        _PROFILE_CACHE.clear()  # staged honestly re-pays per-variant derive
        return run_sweep(p, engine="batch")

    staged_pass(pts)  # warm compiles (BatchSimulator per geometry)
    t0 = time.perf_counter()
    staged = staged_pass(pts)
    t_staged = time.perf_counter() - t0

    t0 = time.perf_counter()
    run_fused_sweep(pts)
    t_fused_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    fused = run_fused_sweep(pts)
    t_fused = time.perf_counter() - t0

    # discrete columns exactly equal; float columns at ULP tolerance —
    # staged and fused are different XLA programs and cross-compilation
    # op-fusion wobbles the last ULP (contract documented in dse/fused.py)
    equiv = np.array_equal(staged.arrays_used, fused.arrays_used) and all(
        np.allclose(getattr(staged, c), getattr(fused, c), rtol=1e-12, atol=0)
        for c in ("total_cycles", "images_per_sec", "mean_utilization")
    )
    assert equiv, "fused sweep diverged from the staged path"
    del staged, fused  # the 10^6-row columns: release before the density runs

    # density-vs-speedup table: same VGG11 variant set, budgets-per-variant
    # swept across the regimes EXPERIMENTS.md discusses (80 pv is the
    # variant-dense regime, 6,400 pv the config-dense one that measured
    # 0.69x before the shared-bank + event-schedule rework)
    density_keys = []
    for pv in (80, 400, 1200, 6400):
        dpts = vgg_grid(pv)
        staged_pass(dpts)  # warm this C's program shapes
        run_fused_sweep(dpts)
        t0 = time.perf_counter()
        staged_pass(dpts)
        ts = time.perf_counter() - t0
        t0 = time.perf_counter()
        run_fused_sweep(dpts)
        tf = time.perf_counter() - t0
        density_keys.append(f"analytic_speedup_{pv}pv={ts / tf:.2f}x")
        _detail(
            "dse_fused", "density", pv, len(dpts), f"{ts:.3f}", f"{tf:.3f}"
        )

    # placement x load surface: staged = one full multichip sweep PER load
    # (closed-loop re-measured and kernels re-built each time); fused = one
    # closed-loop call + one batched open-loop call over the whole surface
    cpts = chip_grid(networks=("vgg11",), chips=(1, 2, 4), link_gbps=(16.0, 64.0))
    loads = (0.3, 0.4, 0.5, 0.6, 0.7, 0.8)
    ckw = dict(n_requests=120, closed_requests=40, concurrency=24, seed=0)
    t0 = time.perf_counter()
    staged_chip = {
        lf: run_multichip_sweep(cpts, load_frac=lf, **ckw) for lf in loads
    }
    t_chip_staged = time.perf_counter() - t0
    t0 = time.perf_counter()
    fused_chip = run_fused_multichip_sweep(cpts, load_fracs=loads, **ckw)
    t_chip_fused = time.perf_counter() - t0
    s07 = staged_chip[0.7]
    k07 = loads.index(0.7)
    chip_equiv = np.allclose(
        np.stack([s07.p50_cycles, s07.p95_cycles, s07.p99_cycles], axis=1),
        fused_chip.pcts[:, k07, :], rtol=1e-12, atol=0,
    ) and np.allclose(
        s07.images_per_sec, fused_chip.images_per_sec, rtol=1e-12, atol=0
    )
    assert chip_equiv, "fused multichip surface diverged at load 0.7"

    n_cfg = len(pts) + fused_chip.n_evaluations
    e2e = (t_staged + t_chip_staged) / (t_fused + t_chip_fused)
    # stable row name + a configs= field: check_drift compares speedups
    # like-for-like and skips (with a WARN) when the grid size changes
    _row(
        "dse_fused",
        t_fused * 1e6,
        f"end_to_end_speedup={e2e:.2f}x;"
        f"analytic_speedup={t_staged / t_fused:.2f}x;"
        f"load_surface_ratio={t_chip_staged / t_chip_fused:.2f}x;"
        f"staged_s={t_staged + t_chip_staged:.2f};"
        f"fused_s={t_fused + t_chip_fused:.2f};"
        f"fused_cold_s={t_fused_cold:.2f};configs={n_cfg};"
        f"equiv={equiv and chip_equiv}",
    )
    # the density keys are self-labeled (fixed pv each), so they live on a
    # configs=-free row and stay drift-comparable across headline resizes
    _row("dse_fused_density", 0.0, ";".join(density_keys))
    # per-stage wall time + peak RSS ride the telemetry session into the
    # BENCH json (nightly uploads it with the artifact)
    tel = get_telemetry()
    tel.gauge("dse.fused.bench.analytic_staged_s", round(t_staged, 3))
    tel.gauge("dse.fused.bench.analytic_fused_s", round(t_fused, 3))
    tel.gauge("dse.fused.bench.analytic_fused_cold_s", round(t_fused_cold, 3))
    tel.gauge("dse.fused.bench.chip_staged_s", round(t_chip_staged, 3))
    tel.gauge("dse.fused.bench.chip_fused_s", round(t_chip_fused, 3))
    tel.gauge(
        "dse.fused.bench.peak_rss_mb",
        round(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0, 1),
    )
    _detail("dse_fused", "analytic_configs", len(pts), f"{t_staged:.2f}", f"{t_fused:.2f}")
    _detail(
        "dse_fused", "chip_surface", fused_chip.n_evaluations,
        f"{t_chip_staged:.2f}", f"{t_chip_fused:.2f}",
    )
    for r in fused_chip.rows():
        if r["load_frac"] in (0.3, 0.7):
            _detail(
                "dse_fused", r["n_chips"], f"{r['link_gbps']:.0f}",
                r["load_frac"], f"{r['images_per_sec']:.1f}", f"{r['p99_ms']:.4f}",
            )


# ----------------------------------------------------------- fleet replay
def fabric_fleet():
    """Fleet-scale trace replay: a >= 10^6-request diurnal trace against a
    C=2 allocation batch, segmented at two control boundaries with
    warm-start re-allocation.

    baseline = the W=1 materializing path (exact per-request latencies,
    O(C x N) memory — what replaying a day of traffic used to cost);
    fleet    = blocked scan (window=8) + in-carry latency sketch + macro-job
    coarsening (tail_lanes=2) + segmented warm-start replay.

    Acceptance: replay_speedup >= 3x at bounded memory (peak-RSS gauges in
    the JSON), sketch percentiles within SketchConfig.rel_error of the
    baseline's exact ones (same hashed service draws), zero growth rejected
    nowhere — plus a W-sweep detail table isolating the blocked-scan term.
    """
    import os
    import resource

    from repro.core.cim import allocate, simulate
    from repro.core.cim.simulate import CLOCK_HZ
    from repro.fabric import (
        CoarsenConfig,
        SinusoidalPoisson,
        TraceReplay,
        VirtualTimeFabric,
        arrival_times,
        get_telemetry,
        run_stream,
        run_trace_segments,
        segment_growth_plan,
    )

    tel = get_telemetry()
    rss_mb = lambda: resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0

    spec, prof = _profile("vgg11")
    bw = allocate(spec, prof, "blockwise", spec.min_pes() * 2)
    cap = simulate(spec, prof, bw, n_images=64).images_per_sec
    vt = VirtualTimeFabric(spec, prof)
    plan = segment_growth_plan(spec, prof, bw, budgets=[64, 128])

    # overridable for smoke runs; the committed BENCH json uses the default
    n = int(os.environ.get("FLEET_BENCH_REQUESTS", 1_000_000))
    rate = 0.6 * cap / CLOCK_HZ
    # two diurnal cycles across the trace span
    trace = SinusoidalPoisson(
        n, base_rate=rate, period=n / rate / 2.0, amplitude=0.5, seed=0
    )
    times = arrival_times(trace)
    # C=2 candidates: hold the starting allocation vs grow at each boundary
    segs = [[bw, plan[0]], [bw, plan[1]], [bw, plan[2]]]
    bounds = [float(times[n // 3]), float(times[2 * n // 3])]
    coarsen = CoarsenConfig(tail_lanes=2)

    # ---- W-sweep (exact kernel, small slice): the blocked-scan term alone
    n_sweep = min(20_000, n)
    tr_sweep = TraceReplay(times[:n_sweep])
    for w in (1, 2, 4, 8, 16):
        run_stream(vt, [bw, plan[0]], tr_sweep, seed=7, window=w)  # warm
        t0 = time.perf_counter()
        run_stream(vt, [bw, plan[0]], tr_sweep, seed=7, window=w)
        _detail(
            "fabric_fleet_wsweep", w,
            f"{(time.perf_counter() - t0) / n_sweep * 1e6:.1f}",
        )

    # ---- baseline: W=1, materialized (C, N) latencies, exact percentiles
    t0 = time.perf_counter()
    base = run_stream(
        vt, [bw, plan[0]], TraceReplay(times), seed=7, window=1,
        materialize=True,
    )
    t_base = time.perf_counter() - t0
    tel.gauge("fabric.fleet.bench.baseline_s", round(t_base, 1))
    tel.gauge_max("fabric.fleet.bench.baseline_peak_rss_mb", round(rss_mb(), 1))
    exact = base.exact_percentiles  # (2, 3) exact np.percentile reference
    sk_err = float(
        np.max(np.abs(base.percentiles - exact) / exact)
    )  # same run, same draws: pure bucketization error
    bound = base.sketches[0].config.rel_error
    assert sk_err <= bound, f"sketch error {sk_err:.4f} exceeds bound {bound}"

    # ---- fleet: blocked scan + sketch + coarsening + segmented warm-start
    # (compile cost stays inside t_fleet, mirroring the baseline's own
    # first-run compile — both sides pay their cold start once)
    t0 = time.perf_counter()
    fleet = run_trace_segments(
        vt, segs, times, bounds, seed=7, window=8, coarsen=coarsen,
    )
    t_fleet = time.perf_counter() - t0
    tel.gauge("fabric.fleet.bench.fleet_s", round(t_fleet, 1))
    tel.gauge_max("fabric.fleet.bench.peak_rss_mb", round(rss_mb(), 1))
    speedup = t_base / t_fleet
    stall = fleet.total_stall_cycles
    rps = fleet.n_requests / float(fleet.makespan.max()) * CLOCK_HZ

    _row(
        "fabric_fleet",
        t_fleet * 1e6,
        f"replay_speedup={speedup:.2f}x;configs=2;requests={n};"
        f"baseline_s={t_base:.1f};fleet_s={t_fleet:.1f};"
        f"sketch_rel_err={sk_err:.4f};sketch_bound={bound:.4f};"
        f"requests_per_sec={rps:.1f}",
    )
    ms = 1e3 / CLOCK_HZ
    for k, name in enumerate(("hold", "grow")):
        p = fleet.percentiles[k]
        _detail(
            "fabric_fleet", name, f"{p[0] * ms:.3f}", f"{p[1] * ms:.3f}",
            f"{p[2] * ms:.3f}", f"{stall[k]:.0f}",
        )
    for s in fleet.segments:
        _detail(
            "fabric_fleet_segment", f"{s.start:.0f}", s.n_requests,
            f"{s.arrays_added[1]:.0f}", f"{s.stall_cycles[1]:.0f}",
        )


def fabric_faults():
    """Fault-tolerant fabric: spare-fraction x failure-rate sweep on VGG11.

    Every point holds back part of its free-array budget as hot spares,
    generates one seeded failure trace (per-array exponential hazards),
    compiles it to a ``DegradePlan`` (spares re-place lost replicas,
    reprogramming charges drift stalls), and replays Poisson traffic on the
    segmented vtime engine.  Headline: ``availability`` (serviceable-
    capacity fraction, REQUIRED by check_drift) at the stress corner —
    max spare fraction under the max failure rate — plus the full
    (spare, rate) -> (availability, p99) table in the details.

    A second table ablates the event-engine ``RetryPolicy`` on a
    zero-survivor outage (one block dead for a third of the trace):
    infinite patience stalls requests until the repair seam, finite
    timeouts shed them — served/shed counts and the served-p99 quantify
    the trade.
    """
    import os

    from repro.core.cim import allocate, simulate
    from repro.core.cim.simulate import CLOCK_HZ, split_block_dups
    from repro.dse import FAULT_OBJECTIVES, fault_grid, pareto_frontier, run_fault_sweep
    from repro.fabric import (
        FabricSim,
        RetryPolicy,
        TraceReplay,
        degrade_plan_from_allocs,
        get_telemetry,
    )
    from repro.fabric.dispatch import Allocation

    tel = get_telemetry()
    # overridable for smoke runs; the committed BENCH json uses the default
    n_req = int(os.environ.get("FAULT_BENCH_REQUESTS", 600))

    spares = (0.0, 0.1, 0.25)
    rates = (1e-9, 1e-8)
    points = fault_grid(
        networks=("vgg11",), spare_fractions=spares, rates=rates
    )
    t0 = time.perf_counter()
    res = run_fault_sweep(points, n_requests=n_req, seed=0)
    t_sweep = time.perf_counter() - t0
    tel.gauge("fabric.faults.bench.sweep_s", round(t_sweep, 1))

    # headlines = the two stress corners at max failure rate: full spares
    # (the availability the spares buy — the acceptance claim) and zero
    # spares (the undefended floor, the more regression-sensitive number);
    # both keys contain "availability" so check_drift guards both
    stress = max(
        range(len(points)),
        key=lambda i: (points[i].spare_fraction, points[i].rate_per_array),
    )
    floor = max(
        range(len(points)),
        key=lambda i: (-points[i].spare_fraction, points[i].rate_per_array),
    )
    frontier = pareto_frontier(res, FAULT_OBJECTIVES)
    _row(
        "fabric_faults",
        t_sweep * 1e6,
        f"availability={res.availability[stress]:.4f}x;"
        f"availability_nospare={res.availability[floor]:.4f}x;"
        f"configs={len(points)};requests={n_req};"
        f"p99_under_failure_ms={res.p99_cycles[stress] / CLOCK_HZ * 1e3:.3f};"
        f"frontier_points={len(frontier)}",
    )
    for r in res.rows():
        _detail(
            "fabric_faults", f"{r['spare_fraction']:.2f}",
            f"{r['rate_per_array']:.0e}", r["spare_arrays"],
            f"{r['availability']:.4f}", f"{r['p50_ms']:.3f}",
            f"{r['p99_ms']:.3f}", r["n_killed"],
            f"{r['total_stall_cycles']:.0f}",
        )

    # ---- RetryPolicy ablation: one block loses ALL replicas for the middle
    # third of the trace (zero survivors), then revives at the repair seam
    spec, prof = _profile("vgg11")
    bw = allocate(spec, prof, "blockwise", spec.min_pes() * 2)
    cap = simulate(spec, prof, bw, n_images=64).images_per_sec
    times = np.cumsum(
        np.random.default_rng(0).exponential(1.0, size=n_req)
    ) / (0.6 * cap / CLOCK_HZ)
    flat = np.concatenate(bw.block_dups)
    dead = flat.copy()
    dead[0] = 0  # first block of the first layer: total outage
    dead_alloc = Allocation(
        bw.policy, None, split_block_dups(spec, dead),
        bw.arrays_used, bw.arrays_total,
    )
    bounds = [float(times[n_req // 3]), float(times[2 * n_req // 3])]
    plan = degrade_plan_from_allocs(
        spec, [bw, dead_alloc, bw], bounds, horizon=float(times[-1])
    )
    for name, policy in (
        ("stall_forever", RetryPolicy()),
        ("timeout_median", RetryPolicy(timeout_cycles=(bounds[1] - bounds[0]) / 2)),
        ("timeout_zero", RetryPolicy(timeout_cycles=0.0)),
    ):
        sim = FabricSim(spec, prof, bw, seed=0, failures=plan, retry=policy)
        out = sim.run(TraceReplay(times))
        comp = np.asarray(out.completions)
        served = comp[~np.isnan(comp)]
        lat = served - times[~np.isnan(comp)]
        _detail(
            "fabric_faults_retry", name, int(served.size),
            int(comp.size - served.size),
            f"{np.percentile(lat, 99) / CLOCK_HZ * 1e3:.3f}",
        )
        tel.count(f"fabric.faults.bench.shed_{name}", comp.size - served.size)


# ------------------------------------------------------------- telemetry
def telemetry():
    """Recorder overhead on the fabric_tail workload: the event engine and
    the jit virtual-time kernel run with stats ON vs OFF on the same
    (allocation, trace) pairs.  OFF is the compiled-out configuration — the
    instrumented branches never execute, so its cost must be the baseline's
    (~0% overhead, measured as the ratio of two OFF runs); ON must stay
    within 5% (acceptance).  Both modes are asserted bit-identical, and the
    vtime accumulators are asserted to reconcile with the event engine's
    counters at rtol 1e-9."""
    from repro.core.cim import allocate, simulate
    from repro.core.cim.simulate import CLOCK_HZ
    from repro.fabric import FabricSim, PoissonOpen, VirtualTimeFabric

    spec, prof = _profile("vgg11")
    pes = spec.min_pes() * 2
    wb = allocate(spec, prof, "weight_based", pes)
    bw = allocate(spec, prof, "blockwise", pes)
    cap = simulate(spec, prof, bw, n_images=64).images_per_sec
    n_req = 400
    allocs, procs = [], []
    for f in (0.5, 0.7):
        proc = PoissonOpen(n_requests=n_req, rate_per_cycle=f * cap / CLOCK_HZ, seed=5)
        for a in (wb, bw):
            allocs.append(a)
            procs.append(proc)

    def run_event(stats):
        return [
            FabricSim(spec, prof, a, seed=3, stats=stats).run(p)
            for a, p in zip(allocs, procs)
        ]

    # Overhead ratios use CPU time (process_time) and per-config minima over
    # 8 interleaved rounds: CPU time rejects wall-clock stalls from co-tenant
    # load, and taking the min per (config, mode) at sub-pass granularity
    # gives every sample many chances to land in a quiet window — the summed
    # minima then estimate the true quiet-machine times for each mode.
    run_event(False)  # warm numpy/python caches
    ev = {False: [1e30] * len(allocs), True: [1e30] * len(allocs)}
    ev2 = {False: [1e30] * len(allocs), True: [1e30] * len(allocs)}
    off, on = [None] * len(allocs), [None] * len(allocs)
    import gc

    gc.disable()  # GC pauses would land on whichever mode triggers them
    try:
        for _ in range(8):
            for i, (a, p) in enumerate(zip(allocs, procs)):
                for st in (False, True):
                    t0 = time.process_time()
                    res = FabricSim(spec, prof, a, seed=3, stats=st).run(p)
                    dt = time.process_time() - t0
                    if dt < ev[st][i]:
                        ev2[st][i] = ev[st][i]
                        ev[st][i] = dt
                    elif dt < ev2[st][i]:
                        ev2[st][i] = dt
                    (on if st else off)[i] = res
            gc.collect()
    finally:
        gc.enable()
    assert all(
        np.array_equal(a.completions, b.completions) for a, b in zip(off, on)
    ), "event engine stats=True changed completion times"
    t_on, ev_base = sum(ev[True]), sum(ev[False])
    ev_over = t_on / ev_base
    # spread between best and second-best UNinstrumented samples = the noise
    # floor the "on" overhead must be read against ("~0% compiled out")
    ev_noise = sum(ev2[False]) / ev_base

    vt = VirtualTimeFabric(spec, prof)
    vt.run_batch(allocs, procs, seed=3)  # compile both kernel variants
    vt.run_batch(allocs, procs, seed=3, collect_stats=True)
    vtm = {False: [], True: []}
    voff = von = None
    for _ in range(8):
        for st in (False, True):
            t0 = time.process_time()
            for _rep in range(3):  # ~1s samples: single batches are too short
                res = vt.run_batch(allocs, procs, seed=3, collect_stats=st)
            vtm[st].append(time.process_time() - t0)
            von, voff = (res, voff) if st else (von, res)
    assert np.array_equal(
        voff.completions, von.completions
    ), "vtime collect_stats=True changed completion times"
    tv_on, vt_base = min(vtm[True]) / 3, min(vtm[False]) / 3
    vt_over = tv_on / vt_base
    vt_noise = sorted(vtm[False])[1] / min(vtm[False])

    # event counters and in-kernel accumulators describe the same cycles
    recon = 0.0
    for i, r in enumerate(on):
        recon = max(
            recon,
            float(
                np.abs(r.stats.layer_service - von.layer_busy[i]).max()
                / max(von.layer_busy[i].max(), 1.0)
            ),
        )
    assert recon < 1e-9, f"event/vtime busy-cycle reconciliation off by {recon}"

    _row(
        f"telemetry_vgg11_{len(allocs)}cfg",
        t_on * 1e6,
        f"overhead_event_on={ev_over:.2f}x;"
        f"overhead_event_off={ev_noise:.2f}x;"
        f"overhead_vtime_on={vt_over:.2f}x;"
        f"overhead_vtime_off={vt_noise:.2f}x;"
        f"recon_rel_err={recon:.1e};bitident=True",
    )
    _detail("telemetry", "event_off_s", f"{ev_base:.3f}")
    _detail("telemetry", "event_on_s", f"{t_on:.3f}")
    _detail("telemetry", "vtime_off_s", f"{vt_base:.3f}")
    _detail("telemetry", "vtime_on_s", f"{tv_on:.3f}")


ALL = {
    "fig4": fig4,
    "fig6": fig6,
    "fig8": fig8,
    "fig9": fig9,
    "ablation": ablation,
    "expert_replication": expert_replication,
    "stage_balance": stage_balance,
    "continuous_batching": continuous_batching,
    "kernels": kernels,
    "roofline_table": roofline_table,
    "fabric_tail": fabric_tail,
    "fabric_drift": fabric_drift,
    "fabric_multitenant": fabric_multitenant,
    "fabric_multichip": fabric_multichip,
    "profile": profile,
    "dse": dse,
    "dse_fused": dse_fused,
    "fabric_fleet": fabric_fleet,
    "fabric_faults": fabric_faults,
    "telemetry": telemetry,
}


def main() -> None:
    args = sys.argv[1:]
    write_json = "--json" in args
    if write_json:
        args = [a for a in args if a != "--json"]
    names = args or list(ALL)
    unknown = [n for n in names if n not in ALL]
    if unknown:
        raise SystemExit(f"unknown bench(es) {unknown}; choose from {list(ALL)}")
    print("name,us_per_call,derived")
    config = _bench_config()
    from repro.fabric.telemetry import telemetry_session

    for n in names:
        r0, d0 = len(_JSON_ROWS), len(_JSON_DETAILS)
        t0 = time.perf_counter()
        # a scoped recorder per bench: anything instrumented underneath (DSE
        # cache hit/miss counters, profile timers) lands in this bench's JSON
        with telemetry_session() as tel:
            ALL[n]()
            snap = tel.snapshot()
        wall = time.perf_counter() - t0
        if write_json:
            payload = {
                "config": config,
                "wall_clock_s": round(wall, 3),
                "rows": _JSON_ROWS[r0:],
                "details": _JSON_DETAILS[d0:],
            }
            if snap["counters"] or snap["gauges"] or snap["histograms"]:
                payload["telemetry"] = {
                    "counters": snap["counters"],
                    "gauges": snap["gauges"],
                    "histograms": snap["histograms"],
                }
            write_bench_json(n, payload)


if __name__ == "__main__":
    main()
