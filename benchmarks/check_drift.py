"""Bench-drift guard: fail if a freshly-run BENCH_*.json regresses vs the
committed baseline.

Compares every ``BENCH_<mode>.json`` in the working tree (the nightly job
regenerates them with ``benchmarks.run --json``) against the version at a
git ref (default ``HEAD`` — i.e. the previous commit's numbers, since the
fresh run overwrote the checkout's files).  Two headline metric families
are extracted from each mode's ``rows``:

  * ``us_per_call`` (lower is better) — skipped when the baseline is 0
    (modes that report a pure derived metric).
  * ``speedup=<x>x`` / ``speedup_<n>geo=<x>x`` parsed from ``derived``
    (higher is better) — the batch-vs-scalar acceptance numbers
    (fabric_tail, dse, and the profiling engine's multi-geometry
    ``profile`` headline).

A metric FAILS when it is worse than baseline by more than ``--tolerance``
(default 10%).  Shared-runner wall-clock is noisy, so the default checks
only the speedup ratios (self-normalizing); pass ``--strict-timing`` to
also enforce the raw ``us_per_call`` timings.

Some headline metrics are REQUIRED (``_REQUIRED``): the fused-DSE bench
must always report its ``end_to_end_speedup`` AND ``analytic_speedup``
ratios, the fleet bench its ``replay_speedup``, and the fault bench its
``availability`` ratio — a bench that silently stops reporting an
acceptance number is a broken guard, so absence is a hard error (exit 2),
not a skipped comparison.

Rows may carry a ``configs=<n>`` field in their derived string recording
the grid size the speedups were measured at.  When baseline and fresh
disagree on a row's config count, that row's ratio comparisons are not
like-for-like (speedups are density-dependent), so they are skipped with
a WARN instead of failing or silently passing.

  PYTHONPATH=src python benchmarks/check_drift.py             # vs HEAD
  python benchmarks/check_drift.py --base HEAD~1 --tolerance 0.15
"""

from __future__ import annotations

import argparse
import json
import pathlib
import re
import subprocess
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
# metric keys may contain '@' and '.' (retention8chip@64gbps=1.00x); value
# must end in 'x' so latency/ms fields never match
_SPEEDUP = re.compile(r"([\w.@]+)=([0-9.]+)x")
# grid size stamp: speedup ratios are only comparable at equal grid sizes
_CONFIGS = re.compile(r"\bconfigs=(\d+)\b")
# headline keys that must exist whenever the file is checked; the file
# itself is mandatory in default-glob (nightly) runs
_REQUIRED = {
    "BENCH_dse_fused.json": ("end_to_end_speedup", "analytic_speedup"),
    "BENCH_fabric_faults.json": ("availability",),
    "BENCH_fabric_fleet.json": ("replay_speedup",),
}


def _baseline(ref: str, name: str) -> dict | None:
    try:
        out = subprocess.run(
            ["git", "show", f"{ref}:{name}"],
            cwd=REPO_ROOT,
            capture_output=True,
            text=True,
            check=True,
        ).stdout
    except subprocess.CalledProcessError:
        return None  # new bench mode: nothing to drift from
    try:
        return json.loads(out)
    except json.JSONDecodeError as e:
        print(f"error: baseline {ref}:{name} is not valid JSON: {e}", file=sys.stderr)
        raise SystemExit(2)


def _metrics(
    doc: dict, timing: bool
) -> tuple[dict[str, tuple[float, bool]], dict[str, int]]:
    """({metric: (value, higher_is_better)}, {metric: configs=}) for one
    bench document.  The second map carries each metric's row-level
    ``configs=<n>`` grid-size stamp (absent when the row has none)."""
    out: dict[str, tuple[float, bool]] = {}
    sizes: dict[str, int] = {}
    for row in doc.get("rows", []):
        name = row.get("name", "?")
        derived = str(row.get("derived", ""))
        cfg = _CONFIGS.search(derived)
        keys = []
        if timing and row.get("us_per_call", 0) > 0:
            keys.append("us_per_call")
            out[f"{name}.us_per_call"] = (float(row["us_per_call"]), False)
        for key, val in _SPEEDUP.findall(derived):
            # availability (fault bench) is a [0, 1] serviceable-capacity
            # ratio — like retention, higher is better and drift guards it
            if "speedup" in key or "retention" in key or "availability" in key:
                keys.append(key)
                out[f"{name}.{key}"] = (float(val), True)
        if cfg:
            for key in keys:
                sizes[f"{name}.{key}"] = int(cfg.group(1))
    return out, sizes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--base", default="HEAD", help="git ref holding the baseline")
    ap.add_argument("--tolerance", type=float, default=0.10)
    ap.add_argument(
        "--strict-timing",
        action="store_true",
        help="also enforce raw us_per_call timings (noisy on shared runners)",
    )
    ap.add_argument(
        "--root",
        type=pathlib.Path,
        default=REPO_ROOT,
        help="directory holding the fresh BENCH_*.json files",
    )
    ap.add_argument(
        "modes",
        nargs="*",
        help="bench modes to check (default: every BENCH_*.json under --root)",
    )
    args = ap.parse_args(argv)

    if args.modes:
        paths = [args.root / f"BENCH_{m}.json" for m in sorted(args.modes)]
        for p in paths:
            if not p.is_file():
                print(
                    f"error: {p.name} not found under {args.root} "
                    f"(run: python -m benchmarks.run --json {p.stem[6:]})",
                    file=sys.stderr,
                )
                return 2
    else:
        paths = sorted(args.root.glob("BENCH_*.json"))
        for fname in sorted(_REQUIRED):
            if not (args.root / fname).is_file():
                print(
                    f"error: required {fname} missing under {args.root} "
                    f"(run: python -m benchmarks.run --json {fname[6:-5]})",
                    file=sys.stderr,
                )
                return 2

    failures, checked = [], 0
    for path in paths:
        try:
            cur = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"error: cannot read {path.name}: {e}", file=sys.stderr)
            return 2
        fresh, fresh_sizes = _metrics(cur, args.strict_timing)
        for req in _REQUIRED.get(path.name, ()):
            if not any(k.endswith(f".{req}") for k in fresh):
                print(
                    f"error: {path.name} lacks required headline metric "
                    f"{req!r} in its derived strings",
                    file=sys.stderr,
                )
                return 2
        base = _baseline(args.base, path.name)
        if base is None:
            print(f"{path.name}: no baseline at {args.base}, skipping")
            continue
        cm = fresh
        bm, base_sizes = _metrics(base, args.strict_timing)
        # a baseline key absent from the fresh run (renamed bench row,
        # changed grid size in the name) silently disables its guard — say
        # so loudly in the nightly log rather than skipping in silence
        for key in sorted(set(bm) - set(cm)):
            print(f"WARN {path.name}:{key} in baseline but not in fresh run")
        for key, (bv, hib) in bm.items():
            if key not in cm or bv <= 0:
                continue
            if (
                key in base_sizes
                and key in fresh_sizes
                and base_sizes[key] != fresh_sizes[key]
            ):
                # speedup ratios are density-dependent: a resized grid is
                # not like-for-like, so skip loudly instead of judging it
                print(
                    f"WARN {path.name}:{key} config count changed "
                    f"({base_sizes[key]} -> {fresh_sizes[key]}); "
                    f"skipping comparison"
                )
                continue
            cv = cm[key][0]
            checked += 1
            ratio = cv / bv
            bad = ratio < 1.0 - args.tolerance if hib else ratio > 1.0 + args.tolerance
            mark = "FAIL" if bad else "ok"
            if bad or ratio != 1.0:
                print(
                    f"{mark:4s} {path.name}:{key} {bv:.3g} -> {cv:.3g} "
                    f"({'+' if ratio >= 1 else ''}{(ratio - 1) * 100:.1f}%)"
                )
            if bad:
                failures.append(key)
    print(f"checked {checked} metrics, {len(failures)} regressed")
    if failures:
        print("regressions:", ", ".join(failures), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
