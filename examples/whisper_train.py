"""Enc-dec (Whisper-family) training example: stub audio frontend, synthetic
paired (frames -> tokens) data, a few fault-tolerant steps on CPU.

  PYTHONPATH=src python examples/whisper_train.py --steps 10
"""

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.distrib.context import set_mesh
from repro.models import encdec
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.runtime.fault import RunnerConfig, TrainRunner
from repro.train.step import make_encdec_train_step


def synth_batch(cfg, step, batch=2, seq=24):
    """Frames carry a per-example bias; targets encode that bias — a
    learnable audio->token mapping."""
    rng = np.random.default_rng(step)
    cls = rng.integers(0, 8, size=(batch,))
    frames = rng.normal(0, 1, size=(batch, cfg.encoder_seq, cfg.d_model)) * 0.1
    frames += cls[:, None, None] * 0.3
    toks = np.stack([np.full((seq + 1,), 5 + c, dtype=np.int32) for c in cls])
    return {
        "frames": jnp.asarray(frames, jnp.float32),
        "tokens": jnp.asarray(toks[:, :-1]),
        "targets": jnp.asarray(toks[:, 1:]),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--ckpt", default="/tmp/repro_whisper")
    args = ap.parse_args()

    cfg = get_config("whisper-medium", smoke=True)
    set_mesh(None)
    params = encdec.init_encdec_params(cfg, jax.random.PRNGKey(0))
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=args.steps)
    opt_state = adamw_init(params)
    step_fn = jax.jit(make_encdec_train_step(cfg, opt))
    runner = TrainRunner(
        RunnerConfig(ckpt_dir=args.ckpt, ckpt_every=5),
        step_fn,
        lambda s: synth_batch(cfg, s),
        fingerprint="whisper-smoke",
    )
    params, opt_state = runner.run(params, opt_state, args.steps)
    losses = [h.metrics["loss"] for h in runner.history]
    print(json.dumps({"first": round(losses[0], 3), "last": round(losses[-1], 3)}))
    assert losses[-1] < losses[0], "enc-dec did not learn the synthetic mapping"


if __name__ == "__main__":
    main()
