"""Design-space exploration over the CIM fabric (repro.dse).

Sweeps (array geometry x ADC precision x PE budget x policy) for one
network through the batched float64 allocate/simulate kernels, checks the
batch against the scalar simulator, and prints the
arrays-vs-throughput-vs-utilization Pareto frontier.

  PYTHONPATH=src python examples/design_space.py [network]
"""

from __future__ import annotations

import sys

import numpy as np

from repro.core.cim import DEFAULT_ARRAY
from repro.dse import design_grid, pareto_frontier, run_sweep


def main() -> None:
    network = sys.argv[1] if len(sys.argv) > 1 else "vgg11"
    arrays = (
        DEFAULT_ARRAY,  # 128x128, 3-bit ADC (the paper's PE)
        DEFAULT_ARRAY.variant(adc_bits=2),  # cheaper ADC: more reads/plane
        DEFAULT_ARRAY.variant(adc_bits=4),  # 16 rows summed per read
        DEFAULT_ARRAY.variant(rows=256, cols=256),  # bigger crossbars
    )
    points = design_grid(
        networks=(network,),
        pe_multipliers=tuple(np.linspace(1.0, 6.0, 25)),
        arrays=arrays,
    )
    print(f"sweeping {len(points)} design points on {network} ...")
    res = run_sweep(points, profile_images=1, sample_patches=64)
    res = run_sweep(points, profile_images=1, sample_patches=64)  # warm kernel
    scalar = run_sweep(points, profile_images=1, sample_patches=64, engine="scalar")
    err = np.abs((res.total_cycles - scalar.total_cycles) / scalar.total_cycles).max()
    print(
        f"batch {res.elapsed_s * 1e3:.1f} ms vs scalar {scalar.elapsed_s * 1e3:.1f} ms "
        f"({scalar.elapsed_s / res.elapsed_s:.1f}x), max rel err {err:.2e}"
    )

    idx = pareto_frontier(res)
    print(f"\nPareto frontier ({len(idx)} of {len(points)} points):")
    print(f"{'arrays':>8} {'PEs':>5} {'adc':>4} {'geom':>9} {'policy':>16} {'img/s':>10} {'util':>6}")
    for i in idx:
        p = res.points[i]
        print(
            f"{res.arrays_total[i]:>8} {p.n_pes:>5} {p.array.adc_bits:>4} "
            f"{p.array.rows}x{p.array.cols:<4} {p.policy:>16} "
            f"{res.images_per_sec[i]:>10.1f} {res.mean_utilization[i]:>6.3f}"
        )


if __name__ == "__main__":
    main()
