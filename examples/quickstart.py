"""Quickstart: the paper's allocation algorithm end-to-end in 30 lines.

Profiles VGG11 activation statistics, allocates crossbar arrays under all
four policies, and prints the throughput/utilization table (paper Fig 8/9).

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.cim import profile_network, run_policy, vgg11_cifar10


def main():
    spec = vgg11_cifar10()
    print(f"{spec.name}: {spec.n_arrays} arrays in {spec.n_blocks} blocks, "
          f"min design = {spec.min_pes()} PEs")
    prof = profile_network(spec, n_images=2)
    print(f"{'policy':16s} {'images/s':>10s} {'utilization':>12s}")
    pes = spec.min_pes() * 2
    for policy in ("baseline", "weight_based", "perf_layerwise", "blockwise"):
        r = run_policy(spec, prof, policy, n_pes=pes)
        print(f"{policy:16s} {r.images_per_sec:10.0f} {r.mean_utilization:12.2f}")
    bw = run_policy(spec, prof, "blockwise", pes).images_per_sec
    wb = run_policy(spec, prof, "weight_based", pes).images_per_sec
    print(f"\nblock-wise allocation speedup over naive: {bw/wb:.2f}x "
          f"(paper reports 3.50x for VGG11, 7.47x for ResNet18)")


if __name__ == "__main__":
    main()
