"""End-to-end driver: train a ~130M-param GLM4-family model on the synthetic
pipeline with checkpointing + fault-tolerant runner.

  PYTHONPATH=src python examples/train_lm.py --steps 200

This is deliberately the same code path as the production launcher
(repro.launch.train), just with an explicit ~100M config.
"""

import argparse
import json
import time

import jax

from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distrib.context import set_mesh
from repro.launch.mesh import make_cpu_mesh
from repro.models import lm
from repro.models.config import AttnConfig, ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.runtime.fault import RunnerConfig, TrainRunner
from repro.train.step import make_train_step

CFG_100M = ModelConfig(
    name="glm4-130m",
    family="dense",
    n_layers=12,
    d_model=768,
    d_ff=2048,
    vocab=32_000,
    attn=AttnConfig(kind="gqa", n_heads=12, n_kv_heads=4, head_dim=64),
    activation="silu_glu",
    remat="none",
    dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m")
    args = ap.parse_args()

    print(f"params: {CFG_100M.param_count()/1e6:.0f}M")
    set_mesh(None)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(CFG_100M, key)
    opt = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    opt_state = adamw_init(params)
    step_fn = jax.jit(make_train_step(CFG_100M, opt))
    data = SyntheticLM(
        DataConfig(vocab=CFG_100M.vocab, seq_len=args.seq, global_batch=args.batch)
    )
    runner = TrainRunner(
        RunnerConfig(ckpt_dir=args.ckpt, ckpt_every=50),
        step_fn,
        lambda s: data.batch(s),
        fingerprint="glm4-130m",
    )
    t0 = time.time()
    params, opt_state = runner.run(params, opt_state, args.steps)
    losses = [h.metrics["loss"] for h in runner.history]
    print(
        json.dumps(
            {
                "steps": len(losses),
                "loss_first10": round(sum(losses[:10]) / 10, 4),
                "loss_last10": round(sum(losses[-10:]) / 10, 4),
                "tokens_per_s": round(
                    args.batch * args.seq * len(losses) / (time.time() - t0)
                ),
            }
        )
    )


if __name__ == "__main__":
    main()
