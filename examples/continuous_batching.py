"""Continuous batching demo — the paper's block-wise dataflow for serving.

Drives the REAL slot engine (per-slot KV positions) on a smoke model:
finished requests hand their slot to the next queued request immediately,
while static batching waits for the slowest request in the batch (the
synchronization barrier the paper breaks).

  PYTHONPATH=src python examples/continuous_batching.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.distrib.context import set_mesh
from repro.models import init_params
from repro.serve.engine import init_slot_state, reset_slots, slot_decode_step
from repro.serve.scheduler import (
    WorkloadConfig,
    sample_lengths,
    simulate_continuous,
    simulate_static,
)


def run_engine(cfg, params, lengths, n_slots, max_seq):
    """Greedy-decode every request with continuous slot refill."""
    queue = list(range(len(lengths)))[::-1]  # FIFO (matches the analytic sim)
    remaining = {i: int(l) for i, l in enumerate(lengths)}
    slot_req = [-1] * n_slots
    state = init_slot_state(cfg, n_slots, max_seq, dtype=jnp.float32)
    tok = jnp.zeros((n_slots,), jnp.int32)
    done, steps = 0, 0
    while done < len(lengths):
        refill = jnp.asarray(
            [
                slot_req[s] == -1
                or (slot_req[s] >= 0 and remaining[slot_req[s]] == 0)
                for s in range(n_slots)
            ]
        )
        if bool(refill.any()):
            state = reset_slots(state, refill)
            for s in range(n_slots):
                if bool(refill[s]):
                    if slot_req[s] != -1:
                        pass
                    slot_req[s] = queue.pop() if queue else -2
        logits, state = slot_decode_step(params, cfg, state, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        steps += 1
        for s in range(n_slots):
            r = slot_req[s]
            if r >= 0:
                remaining[r] -= 1
                if remaining[r] == 0:
                    done += 1
                    slot_req[s] = -1
        if steps > 10_000:
            raise RuntimeError("runaway")
    return steps


def main():
    set_mesh(None)
    cfg = get_config("glm4-9b", smoke=True).with_(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    lengths = sample_lengths(WorkloadConfig(n_requests=24, mean_len=12, sigma=1.0, seed=3))
    lengths = np.minimum(lengths, 30)
    n_slots = 4

    st = simulate_static(lengths, n_slots)
    ct = simulate_continuous(lengths, n_slots)
    print(f"analytic: static util={st.utilization:.2f} steps={st.total_steps}  "
          f"continuous util={ct.utilization:.2f} steps={ct.total_steps} "
          f"({st.total_steps/ct.total_steps:.2f}x)")

    t0 = time.time()
    steps = run_engine(cfg, params, lengths, n_slots, max_seq=32)
    print(f"engine:   continuous completed {len(lengths)} requests in {steps} "
          f"decode steps ({time.time()-t0:.1f}s wall) — analytic predicted {ct.total_steps}")
    assert abs(steps - ct.total_steps) <= n_slots, (steps, ct.total_steps)


if __name__ == "__main__":
    main()
