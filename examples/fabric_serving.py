"""Serving a CIM fabric with the discrete-event runtime.

Walks the serving questions the analytic model cannot answer:

  1. tail latency under open-loop Poisson traffic (blockwise vs layer-wise),
  2. latency-aware provisioning: the batched virtual-time engine sweeps a
     whole (policy x load) grid per jit call, and `provision_latency_aware`
     uses it to pick replicas by measured p99 at the offered load,
  3. input-distribution drift + online re-allocation from a reserve,
  4. two networks sharing one fabric with weighted-fair allocation,
  5. the same silicon tiled over several chips: communication-aware
     placement (chip -> PE -> array tree) vs naively serialized placement,
     with inter-chip transfer delays on the request path.

Run:  PYTHONPATH=src python examples/fabric_serving.py
      PYTHONPATH=src python examples/fabric_serving.py --chips 4 --link-gbps 32
"""

import argparse

import numpy as np

from repro.core.cim import (
    FabricTopology,
    allocate,
    allocate_placed,
    place_allocation,
    profile_network,
    simulate,
    vgg11_cifar10,
)
from repro.core.cim.simulate import ARRAYS_PER_PE, CLOCK_HZ
from repro.fabric import (
    ClosedLoop,
    DriftConfig,
    FabricSim,
    OnlineReallocator,
    PoissonOpen,
    Tenant,
    VirtualTimeFabric,
    allocate_shared,
    fairness_report,
    provision_latency_aware,
    run_tenants,
    shift_profile,
)


def fmt(st):
    return f"p50={st.p50:7.3f}ms  p95={st.p95:7.3f}ms  p99={st.p99:7.3f}ms"


def parse_args():
    ap = argparse.ArgumentParser(description="CIM fabric serving walkthrough")
    ap.add_argument(
        "--chips", type=int, default=4,
        help="chips the fixed array budget is tiled over in the multi-chip "
        "section (1 = the flat single-chip fabric, zero transfer cost)",
    )
    ap.add_argument(
        "--link-gbps", type=float, default=32.0,
        help="inter-chip link bandwidth (Gbit/s) for the multi-chip section",
    )
    ap.add_argument(
        "--trace-out", type=str, default=None, metavar="PATH",
        help="write a Perfetto trace (trace_event JSON) of one instrumented "
        "open-loop run to PATH and print its utilization report "
        "(open at https://ui.perfetto.dev)",
    )
    return ap.parse_args()


def trace_section(args, spec, prof, pes, cap):
    """--trace-out: one instrumented open-loop run -> Perfetto + report."""
    from repro.obs import build_trace, utilization_report, validate_trace, write_trace

    print(f"\n== instrumented run -> {args.trace_out} ==")
    alloc = allocate(spec, prof, "blockwise", pes)
    sim = FabricSim(
        spec, prof, alloc, seed=1, record_timeline=True, stats=True
    )
    res = sim.run(PoissonOpen(120, 0.6 * cap / CLOCK_HZ, seed=5))
    trace = build_trace(sim, res, merge_gap=64.0)
    write_trace(trace, args.trace_out)
    print(f"  {validate_trace(trace)} spans written; "
          f"open the file at https://ui.perfetto.dev")
    print(utilization_report(res).format())


def main():
    args = parse_args()
    spec = vgg11_cifar10()
    print(f"profiling {spec.name} ({spec.n_arrays} arrays, {spec.n_blocks} blocks)...")
    prof = profile_network(spec, n_images=2)
    pes = spec.min_pes() * 2

    # ---- 1. the event engine reproduces the analytic steady state, then
    #         shows what the closed form can't: the latency distribution
    print("\n== closed loop: event-driven vs analytic steady state ==")
    for pol in ("weight_based", "blockwise"):
        alloc = allocate(spec, prof, pol, pes)
        ana = simulate(spec, prof, alloc, n_images=64).images_per_sec
        res = FabricSim(spec, prof, alloc, seed=0).run(ClosedLoop(60, 16))
        print(
            f"  {pol:13s} analytic={ana:8.0f} img/s  event={res.images_per_sec:8.0f} img/s"
            f"  ({res.images_per_sec / ana * 100:.1f}%)   {fmt(res.latency_ms())}"
        )

    print("\n== open-loop Poisson at 70% of weight_based capacity ==")
    wb = allocate(spec, prof, "weight_based", pes)
    bw = allocate(spec, prof, "blockwise", pes)
    cap = simulate(spec, prof, wb, n_images=64).images_per_sec
    proc = PoissonOpen(n_requests=400, rate_per_cycle=0.7 * cap / CLOCK_HZ, seed=5)
    for pol, alloc in (("weight_based", wb), ("blockwise", bw)):
        res = FabricSim(spec, prof, alloc, seed=1).run(proc)
        print(f"  {pol:13s} {fmt(res.latency_ms())}")

    # ---- 2. latency-aware provisioning on the batched virtual-time engine
    print("\n== latency-aware provisioning (batched virtual-time engine) ==")
    cap = simulate(spec, prof, bw, n_images=64).images_per_sec
    vt = VirtualTimeFabric(spec, prof, lane_quantum=8)
    for frac in (0.3, 0.7):
        offered = frac * cap
        la = provision_latency_aware(
            spec, prof, pes, offered_ips=offered, calib_requests=150, grants=0
        )
        ev = PoissonOpen(400, offered / CLOCK_HZ, seed=9)
        res = vt.run_batch([bw, la], ev, seed=4)  # one call, both allocations
        ms = 1e3 / CLOCK_HZ
        p_bw, p_la = res.p99 * ms
        note = "reshaped for latency" if p_la < p_bw else "kept the throughput shape"
        print(
            f"  load {frac:.0%} of peak: blockwise p99={p_bw:7.3f}ms  "
            f"latency_aware p99={p_la:7.3f}ms  ({note})"
        )

    # ---- 3. drift: the profile goes stale mid-serve
    print("\n== input drift: deep layers turn 1.8x denser mid-serve ==")
    free = pes * ARRAYS_PER_PE - spec.n_arrays
    reserve = 0.4
    alloc0 = allocate(spec, prof, "blockwise", pes, free_budget=free * (1 - reserve))
    shifted = shift_profile(prof, {4: 1.8, 5: 1.8, 6: 1.8})
    cl = ClosedLoop(120, 24)
    stale = FabricSim(spec, prof, alloc0, seed=2, live_prof=shifted).run(cl)
    rl = OnlineReallocator(spec, prof, reserve_arrays=free * reserve, cfg=DriftConfig())
    online = FabricSim(spec, prof, alloc0, seed=2, live_prof=shifted, reallocator=rl).run(cl)
    oracle = FabricSim(spec, shifted, allocate(spec, shifted, "blockwise", pes), seed=2).run(cl)
    ts, to, torc = stale.images_per_sec, online.images_per_sec, oracle.images_per_sec
    print(f"  stale profile : {ts:8.0f} img/s")
    print(f"  online realloc: {to:8.0f} img/s   (oracle {torc:8.0f} img/s, "
          f"recovered {(to - ts) / (torc - ts) * 100:.0f}% of the gap)")
    for e in online.reallocations:
        print(f"    realloc @ {e.time / CLOCK_HZ * 1e3:6.2f}ms: +{e.arrays_added} arrays, "
              f"stall {e.stall_cycles / CLOCK_HZ * 1e6:.0f}us, divergence {e.divergence:.2f}")

    # ---- 4. two tenants on one fabric
    print("\n== two tenants (weights 3:1) sharing one fabric ==")
    tenants = [
        Tenant("prio", spec, prof, weight=3.0),
        Tenant("batch", spec, prof, weight=1.0),
    ]
    shared = allocate_shared(tenants, n_pes=-(-2 * spec.n_arrays // ARRAYS_PER_PE) * 2)
    results = run_tenants(shared, [ClosedLoop(40, 12), ClosedLoop(40, 12)], seed=3)
    rep = fairness_report(shared, results)
    for name, d in rep["tenants"].items():
        print(f"  {name:6s} w={d['weight']:.0f}  arrays={d['arrays']:5d}  "
              f"ips={d['images_per_sec']:8.0f}  p99={d['latency_ms_p99']:.3f}ms")
    print(f"  weighted rate balance: {rep['weighted_rate_balance']:.2f} "
          f"(1.0 = perfectly weight-proportional)")

    # ---- 5. the same silicon tiled over several chips
    n_chips = max(1, args.chips)
    pes_total = pes + (-pes) % n_chips  # divisible equal-silicon split
    print(f"\n== multi-chip: {pes_total} PEs over {n_chips} chip(s), "
          f"{args.link_gbps:.0f} Gbps links ==")
    topo = FabricTopology.split(
        n_chips, pes_total, link_gbps=args.link_gbps
    )
    flat = allocate(spec, prof, "blockwise", pes_total)
    placed = allocate_placed(spec, prof, "blockwise", topo)
    alloc_blind, alloc_aware = flat, placed.allocation
    pl_aware = placed.placement
    try:
        striped = place_allocation(spec, flat, topo, strategy="stripe")
    except ValueError as e:
        # a fully-spent flat budget can be unplaceable under blind striping
        # (capacity fragments across chips) — itself an argument for
        # placement-aware allocation.  Re-run the comparison at a slack
        # budget with IDENTICAL counts on both sides so the printed gap is
        # purely the placement's.
        print(f"  [striping fragmented the tree: {e}; comparing at 70% budget]")
        free = topo.total_arrays - spec.n_arrays
        flat = allocate(
            spec, prof, "blockwise", pes_total, free_budget=int(free * 0.7)
        )
        alloc_blind = alloc_aware = flat
        striped = place_allocation(spec, flat, topo, strategy="stripe")
        pl_aware = place_allocation(spec, flat, topo, strategy="locality")
    proc = PoissonOpen(300, 0.5 * cap / CLOCK_HZ, seed=13)
    res = vt.run_batch(
        [alloc_blind, alloc_aware],
        proc,
        seed=6,
        placements=[striped, pl_aware],
    )
    ms = 1e3 / CLOCK_HZ
    for name, pl, i in (
        ("striped placement (blind)", striped, 0),
        ("comm-aware placement", pl_aware, 1),
    ):
        st = res.latency(i)
        print(f"  {name:26s} {fmt(st.scaled(ms))}  "
              f"worst stage transfer={pl.max_stage_transfer:8.0f} cyc  "
              f"off-source replicas={pl.n_crossings}")
    if n_chips == 1:
        flat_res = vt.run_batch([flat], proc, seed=6)
        same = np.array_equal(flat_res.completions[0], res.completions[0])
        print(f"  single chip: transfers all zero; bit-identical to the flat "
              f"fabric engine: {same}")

    # ---- 6. optional: export a Perfetto timeline of an instrumented run
    if args.trace_out:
        trace_section(args, spec, prof, pes, cap)


if __name__ == "__main__":
    main()
