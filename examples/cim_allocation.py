"""Walkthrough of the paper's core contribution on ResNet18.

Shows: (1) profiling '1'-bit densities, (2) the block-level skew that causes
synchronization stalls (Fig 6), (3) the greedy block-wise allocation, and
(4) the resulting speedup and utilization (Fig 8/9).

  PYTHONPATH=src python examples/cim_allocation.py
"""

import numpy as np

from repro.core.cim import (
    allocate,
    profile_network,
    resnet18_imagenet,
    run_policy,
)


def main():
    spec = resnet18_imagenet()
    print(f"ResNet18 -> {spec.n_arrays} arrays, {spec.n_blocks} blocks "
          f"(paper: 5472 arrays, 247 blocks)")

    prof = profile_network(spec, n_images=2)
    print("\nper-layer '1' density (paper Fig 4 x-axis):")
    print("  " + " ".join(f"{lp.density:.2f}" for lp in prof.layers))

    l15 = prof.layers[13]
    spread = l15.mean_cycles.max() / l15.mean_cycles.min() - 1
    print(f"\nblock skew inside layer3.1.conv1 (paper Fig 6 'layer 15'): "
          f"{spread*100:.0f}% cycle spread across {len(l15.mean_cycles)} blocks")

    pes = spec.min_pes() * 2
    alloc = allocate(spec, prof, "blockwise", pes)
    dups = np.concatenate(alloc.block_dups)
    print(f"\nblock-wise allocation at {pes} PEs: replicas min={dups.min()} "
          f"max={dups.max()} (hot blocks get more arrays)")

    for policy in ("baseline", "weight_based", "perf_layerwise", "blockwise"):
        r = run_policy(spec, prof, policy, pes)
        print(f"  {policy:16s} {r.images_per_sec:8.0f} img/s  "
              f"util={r.mean_utilization:.2f}")


if __name__ == "__main__":
    main()
