"""Batched serving example: prefill + cached decode for any architecture.

  PYTHONPATH=src python examples/serve_lm.py --arch deepseek-v2-236b
  PYTHONPATH=src python examples/serve_lm.py --arch mamba2-370m --gen 64

Uses the smoke config on CPU; production shapes go through
repro.launch.dryrun / repro.launch.serve.
"""

import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--smoke", "--gen", str(args.gen)])


if __name__ == "__main__":
    main()
