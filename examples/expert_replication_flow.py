"""The paper's full workflow, end-to-end on a REAL MoE:

  1. train a small DeepSeek-family MoE until the router develops preferences,
  2. PROFILE the routing distribution (the paper's 'profile the distribution
     of ones ... from a large set of examples' — here: expert-selection
     histograms captured from eager forward passes),
  3. run the paper's greedy allocator to PLAN hot-expert replication under a
     physical-slot budget,
  4. REDEPLOY with the replication baked in and measure the barrier relief
     (expected max slot load / token drop rate).

  PYTHONPATH=src python examples/expert_replication_flow.py
"""

import dataclasses
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.core.alloc.expert import (
    drop_rate,
    expected_max_load,
    plan_replication,
)
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distrib.context import set_mesh
from repro.models import forward, init_params, loss_fn
from repro.models.layers import capture_routing
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.train.step import make_train_step


def main():
    set_mesh(None)
    cfg = get_config("deepseek-v2-236b", smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=60)
    opt_state = adamw_init(params)
    step = jax.jit(make_train_step(cfg, opt))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8))

    # 1. train — routers drift away from uniform
    for s in range(40):
        params, opt_state, m = step(params, opt_state, data.batch(s))
    print(f"trained 40 steps, loss={float(m['loss']):.3f}")

    # 2. profile routing on held-out batches.  jax.lax.scan traces its body
    # (capture needs concrete values), so the profiler walks the layer stack
    # in a python loop — profiling is offline and CPU-cheap by design.
    import jax.numpy as jnp
    from repro.models.lm import _block_fwd

    with capture_routing() as records:
        for s in range(100, 104):
            toks = data.batch(s)["tokens"]
            x = params["embed"].astype(jnp.dtype(cfg.dtype))[toks]
            pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])
            for i in range(cfg.n_layers):
                p_l = jax.tree.map(lambda a, i=i: a[i], params["layers"])
                x, _ = _block_fwd(p_l, cfg, x, pos, None)
    eids = np.concatenate([r.reshape(-1) for r in records])
    hist = np.bincount(eids, minlength=cfg.moe.n_experts).astype(np.float64)
    hist /= hist.sum()
    print(f"profiled {eids.size} routings across {len(records)} MoE calls; "
          f"hottest expert carries {hist.max()*100:.1f}% (uniform would be "
          f"{100/cfg.moe.n_experts:.1f}%)")

    # 3. plan replication: pad 8 experts to 12 physical slots
    plan = plan_replication(hist, slot_budget=12)
    print(f"replication plan: {plan.replication} -> {plan.n_physical} slots, "
          f"balance {plan.balance:.2f}")

    # 4. barrier relief, measured against the profiled distribution
    n_tok, k = 4096, cfg.moe.top_k
    base_max = expected_max_load(hist, n_tok, k)
    repl_max = expected_max_load(plan, n_tok, k)
    base_drop = drop_rate(hist, n_tok, k, cfg.moe.capacity_factor)
    repl_drop = drop_rate(plan, n_tok, k, cfg.moe.capacity_factor)
    print(json.dumps({
        "max_slot_load": {"base": round(base_max), "replicated": round(repl_max),
                          "relief": f"{base_max/repl_max:.2f}x"},
        "drop_rate": {"base": f"{base_drop*100:.2f}%",
                      "replicated": f"{repl_drop*100:.2f}%"},
    }, indent=1))

    # 5. redeploy: the plan bakes into the config; the distributed dispatch
    # (moe_fwd) routes round-robin over replicas of each logical expert.
    cfg_repl = cfg.with_(moe=dataclasses.replace(cfg.moe, replication=plan.replication))
    logits, _ = forward(params_with_replicas(params, cfg, plan), cfg_repl,
                        data.batch(200)["tokens"])
    assert bool(jax.numpy.isfinite(logits.astype(jax.numpy.float32)).all())
    print("redeployed with replicated experts: forward OK")


def params_with_replicas(params, cfg, plan):
    """Expand the physical expert bank according to the plan (replicas are
    exact copies — the paper's weight duplication)."""
    import jax.numpy as jnp

    idx = np.concatenate(
        [np.full(r, e) for e, r in enumerate(plan.replication)]
    )

    def expand(leaf_path, leaf):
        return leaf

    new = jax.tree_util.tree_map_with_path(
        lambda path, leaf: (
            leaf[:, jnp.asarray(idx)]
            if any(getattr(p, "key", "") == "experts" for p in path)
            else leaf
        ),
        params,
    )
    return new


if __name__ == "__main__":
    main()
