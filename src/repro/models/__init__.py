"""Model zoo: flexible decoder-only LM + enc-dec assemblers."""

from .config import AttnConfig, ModelConfig, MoEConfig, SSMConfig
from .lm import forward, init_cache, init_params, loss_fn
from .encdec import (
    decode,
    encode,
    encdec_loss_fn,
    init_decoder_cache,
    init_encdec_params,
)

__all__ = [
    "AttnConfig",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
    "decode",
    "encode",
    "encdec_loss_fn",
    "init_decoder_cache",
    "init_encdec_params",
]
