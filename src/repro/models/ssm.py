"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD algorithm in pure JAX (`ssd_chunked`) used for training/prefill,
O(1)-state `ssd_step` for decode (this is what makes the 500k-token
long-context shape feasible), and a depthwise conv frontend with a rolling
cache.  The per-chunk compute hot-spot also exists as a Pallas TPU kernel in
``repro.kernels.ssd_scan`` validated against this reference.

Projections are SEPARATE matrices (wz/wx/wB/wC/wdt rather than one fused
in_proj) so tensor-parallel sharding boundaries align with the logical
splits: heads shard over the `model` mesh axis, the SSD recurrence is
embarrassingly parallel across heads.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import _dense, init_rmsnorm, rmsnorm

__all__ = [
    "init_mamba2",
    "mamba2_fwd",
    "mamba2_step",
    "init_mamba2_cache",
    "ssd_chunked",
    "ssd_step",
]


# ------------------------------------------------------------------ SSD core


def ssd_chunked(
    x: jax.Array,  # (b, s, h, p)   inputs (already conv'd / activated)
    dt: jax.Array,  # (b, s, h)      softplus'd step sizes
    A: jax.Array,  # (h,)           negative decay rates
    B: jax.Array,  # (b, s, n)      input projection (n_groups=1, shared)
    C: jax.Array,  # (b, s, n)      output projection
    chunk: int = 128,
    init_state: jax.Array | None = None,  # (b, h, n, p)
) -> tuple[jax.Array, jax.Array]:
    """Chunked state-space-duality scan.  Returns (y (b,s,h,p), final_state)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    dA = dtc * A  # (b, nc, Q, h) log-decay, negative
    cum = jnp.cumsum(dA, axis=2)
    xdt = xc * dtc[..., None]

    # --- intra-chunk (quadratic within the chunk) ---
    # L[i, j, h] = exp(cum_i - cum_j) for i >= j.  Computed in HEAD BLOCKS of
    # `head_group` so the (Q, Q, h) decay tensor never lives all at once —
    # at (b=16, nc=32, Q=128, h=32) the full tensor is >1 GB/layer and was
    # the dominant HBM term of the hybrid/ssm train cells (§Perf).  The
    # Pallas kernel (kernels/ssd_scan.py) keeps it in VMEM entirely.
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)  # (b,nc,Q,Q)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    head_group = min(8, h)

    def _intra(args):
        cum_g, xdt_g = args  # (b,nc,Q,hb), (b,nc,Q,hb,p)
        diff = cum_g[:, :, :, None, :] - cum_g[:, :, None, :, :]
        # mask BEFORE exp: exp of the (discarded) upper triangle overflows
        # and poisons gradients through jnp.where otherwise.
        L = jnp.exp(jnp.where(tri, diff, -jnp.inf)).astype(x.dtype)
        return jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", scores, L, xdt_g)

    if h > head_group and h % head_group == 0:
        hg = h // head_group
        cum_s = jnp.moveaxis(
            cum.reshape(b, nc, chunk, hg, head_group), 3, 0
        )  # (hg, b, nc, Q, hb)
        xdt_s = jnp.moveaxis(xdt.reshape(b, nc, chunk, hg, head_group, p), 3, 0)
        y_blocks = jax.lax.map(jax.checkpoint(_intra), (cum_s, xdt_s))
        y_intra = jnp.moveaxis(y_blocks, 0, 3).reshape(b, nc, chunk, h, p)
    else:
        y_intra = _intra((cum, xdt))

    # --- chunk summary states ---
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (b,nc,Q,h)
    S_chunk = jnp.einsum("bckh,bckn,bckhp->bchnp", decay_to_end, Bc, xdt)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (b,nc,h)

    # --- inter-chunk recurrence (scan over chunks) ---
    S0 = (
        jnp.zeros((b, h, n, p), x.dtype)
        if init_state is None
        else init_state.astype(x.dtype)
    )

    def step(S, inp):
        S_c, dec = inp  # (b,h,n,p), (b,h)
        S_prev = S
        S_new = dec[:, :, None, None] * S + S_c
        return S_new, S_prev

    S_final, S_prevs = jax.lax.scan(
        step,
        S0,
        (jnp.moveaxis(S_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)  # (b, nc, h, n, p)

    y_inter = jnp.einsum(
        "bcqh,bcqn,bchnp->bcqhp", jnp.exp(cum).astype(x.dtype), Cc, S_prevs
    )
    y = (y_intra + y_inter).reshape(b, nc * chunk, h, p)
    return y[:, :s], S_final


def ssd_step(
    state: jax.Array,  # (b, h, n, p)
    x: jax.Array,  # (b, h, p)
    dt: jax.Array,  # (b, h)
    A: jax.Array,  # (h,)
    B: jax.Array,  # (b, n)
    C: jax.Array,  # (b, n)
) -> tuple[jax.Array, jax.Array]:
    """Single-token recurrence: S <- exp(dt A) S + dt B (x);  y = C S."""
    dA = jnp.exp(dt * A)  # (b, h)
    upd = jnp.einsum("bn,bhp->bhnp", B, x * dt[..., None])
    S = dA[:, :, None, None] * state + upd
    y = jnp.einsum("bn,bhnp->bhp", C, S)
    return y, S


# ------------------------------------------------------------------- block


def init_mamba2(key: jax.Array, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    gn = s.n_groups * s.d_state
    ks = jax.random.split(key, 6)
    return {
        "wz": _dense(ks[0], (d, di)),
        "wx": _dense(ks[1], (d, di)),
        "wB": _dense(ks[2], (d, gn)),
        "wC": _dense(ks[3], (d, gn)),
        "wdt": _dense(ks[4], (d, nh)),
        "conv_x_w": _dense(ks[5], (s.d_conv, di)) * 0.1,
        "conv_x_b": jnp.zeros((di,), jnp.float32),
        "conv_B_w": jnp.zeros((s.d_conv, gn), jnp.float32).at[-1].set(1.0),
        "conv_B_b": jnp.zeros((gn,), jnp.float32),
        "conv_C_w": jnp.zeros((s.d_conv, gn), jnp.float32).at[-1].set(1.0),
        "conv_C_b": jnp.zeros((gn,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "gate_norm": init_rmsnorm(di),
        "out_proj": _dense(ks[0], (di, d)),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d over (b, s, ch) + SiLU."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(k))
    return jax.nn.silu(out + b.astype(x.dtype))


def _project(p: dict, cfg: ModelConfig, x: jax.Array):
    z = x @ p["wz"].astype(x.dtype)
    xi = x @ p["wx"].astype(x.dtype)
    B = x @ p["wB"].astype(x.dtype)
    C = x @ p["wC"].astype(x.dtype)
    dt = x @ p["wdt"].astype(x.dtype)
    return z, xi, B, C, dt


def mamba2_fwd(
    p: dict, cfg: ModelConfig, x: jax.Array, init_state=None
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence Mamba2 block: (b, s, d) -> (b, s, d), final SSM state."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    di = s_cfg.d_inner(d)
    nh = s_cfg.n_heads(d)
    z, xin, B, C, dt = _project(p, cfg, x)
    xin = _causal_conv(xin, p["conv_x_w"], p["conv_x_b"])
    B = _causal_conv(B, p["conv_B_w"], p["conv_B_b"])
    C = _causal_conv(C, p["conv_C_w"], p["conv_C_b"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"]).astype(x.dtype)
    A = -jnp.exp(p["A_log"]).astype(x.dtype)
    xh = xin.reshape(b, s, nh, s_cfg.head_dim)
    y, S = ssd_chunked(xh, dt, A, B, C, chunk=s_cfg.chunk, init_state=init_state)
    y = y + p["D"].astype(x.dtype)[None, None, :, None] * xh
    y = y.reshape(b, s, di)
    y = rmsnorm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["out_proj"].astype(x.dtype), S


def init_mamba2_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    gn = s.n_groups * s.d_state
    return {
        "conv_x": jnp.zeros((batch, s.d_conv - 1, di), dtype),
        "conv_B": jnp.zeros((batch, s.d_conv - 1, gn), dtype),
        "conv_C": jnp.zeros((batch, s.d_conv - 1, gn), dtype),
        "ssm": jnp.zeros((batch, nh, s.d_state, s.head_dim), dtype),
    }


def _conv_step(window: jax.Array, new: jax.Array, w, b):
    """window: (b, k-1, ch) rolling cache; new: (b, ch)."""
    full = jnp.concatenate([window, new[:, None]], axis=1)  # (b, k, ch)
    out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", full, w.astype(new.dtype)) + b.astype(new.dtype)
    )
    return out, full[:, 1:]


def mamba2_step(
    p: dict, cfg: ModelConfig, x: jax.Array, cache: dict
) -> tuple[jax.Array, dict]:
    """Single-token decode: (b, 1, d) -> (b, 1, d) with O(1) state."""
    s_cfg = cfg.ssm
    b = x.shape[0]
    di = s_cfg.d_inner(cfg.d_model)
    nh = s_cfg.n_heads(cfg.d_model)
    z, xin, B, C, dt = _project(p, cfg, x[:, 0])
    xin, conv_x = _conv_step(cache["conv_x"], xin, p["conv_x_w"], p["conv_x_b"])
    B, conv_B = _conv_step(cache["conv_B"], B, p["conv_B_w"], p["conv_B_b"])
    C, conv_C = _conv_step(cache["conv_C"], C, p["conv_C_w"], p["conv_C_b"])
    dt1 = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"]).astype(x.dtype)
    A = -jnp.exp(p["A_log"]).astype(x.dtype)
    xh = xin.reshape(b, nh, s_cfg.head_dim)
    y, S = ssd_step(cache["ssm"].astype(x.dtype), xh, dt1, A, B, C)
    y = y + p["D"].astype(x.dtype)[None, :, None] * xh
    y = y.reshape(b, 1, di)
    y = rmsnorm(p["gate_norm"], y * jax.nn.silu(z[:, None, :]), cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, {"conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C, "ssm": S}
