"""Encoder-decoder (Whisper-style) backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (b, encoder_seq, d_model).  Encoder =
bidirectional self-attention stack; decoder = causal self-attention +
cross-attention stack with a token embedding and LM head.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    _dense,
    _sdpa,
    apply_rope,
    init_gqa,
    init_gqa_cache,
    init_mlp,
    init_rmsnorm,
    gqa_fwd,
    mlp_fwd,
    rmsnorm,
)

__all__ = [
    "init_encdec_params",
    "encode",
    "decode",
    "init_decoder_cache",
    "encdec_loss_fn",
]


def _init_cross(key, cfg: ModelConfig) -> dict:
    nh, nkv, hd = cfg.attn_dims()
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense(ks[0], (d, nh * hd)),
        "wk": _dense(ks[1], (d, nkv * hd)),
        "wv": _dense(ks[2], (d, nkv * hd)),
        "wo": _dense(ks[3], (nh * hd, d)),
    }


def _cross_fwd(p, cfg: ModelConfig, x, enc_kv):
    """Cross attention against precomputed encoder K/V."""
    nh, nkv, hd = cfg.attn_dims()
    b, s, d = x.shape
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, nh, hd)
    out = _sdpa(q, enc_kv["k"], enc_kv["v"], causal=False)
    return out.reshape(b, s, nh * hd) @ p["wo"].astype(x.dtype)


def _init_enc_layer(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": init_rmsnorm(cfg.d_model),
        "attn": init_gqa(k1, cfg),
        "mlp_norm": init_rmsnorm(cfg.d_model),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.activation),
    }


def _init_dec_layer(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn_norm": init_rmsnorm(cfg.d_model),
        "attn": init_gqa(k1, cfg),
        "cross_norm": init_rmsnorm(cfg.d_model),
        "cross": _init_cross(k2, cfg),
        "mlp_norm": init_rmsnorm(cfg.d_model),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.activation),
    }


def init_encdec_params(cfg: ModelConfig, key: jax.Array) -> dict:
    ke, kd, kt, ko = jax.random.split(key, 4)
    enc_keys = jax.random.split(ke, cfg.n_encoder_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_norm": init_rmsnorm(cfg.d_model),
        "embed": _dense(kt, (cfg.vocab, cfg.d_model)),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "final_norm": init_rmsnorm(cfg.d_model),
        "lm_head": _dense(ko, (cfg.d_model, cfg.vocab)),
    }


def encode(params: dict, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (b, enc_seq, d_model) precomputed frontend embeddings."""
    b, s, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    noncausal = cfg.with_(attn=dataclasses.replace(cfg.attn, causal=False))

    def body(x, p_l):
        h, _ = gqa_fwd(p_l["attn"], noncausal, rmsnorm(p_l["attn_norm"], x, cfg.norm_eps), positions, None)
        x = x + h
        x = x + mlp_fwd(p_l["mlp"], rmsnorm(p_l["mlp_norm"], x, cfg.norm_eps), cfg.activation)
        return x, None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, frames.astype(jnp.dtype(cfg.dtype)), params["enc_layers"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _enc_kv(params_dec_layer: dict, cfg: ModelConfig, enc_out: jax.Array) -> dict:
    nh, nkv, hd = cfg.attn_dims()
    b, s, _ = enc_out.shape
    p = params_dec_layer["cross"]
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(b, s, nkv, hd)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(b, s, nkv, hd)
    return {"k": k, "v": v}


def decode(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,  # (b, s)
    enc_out: jax.Array,  # (b, enc_seq, d)
    cache: dict | None = None,
) -> tuple[jax.Array, dict | None]:
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]
    b, s, _ = x.shape
    base = cache["layers"]["len"][0] if cache is not None else 0
    positions = jnp.broadcast_to(base + jnp.arange(s)[None], (b, s))

    def body(x, inp):
        p_l, c_l = inp
        h, c_new = gqa_fwd(p_l["attn"], cfg, rmsnorm(p_l["attn_norm"], x, cfg.norm_eps), positions, c_l)
        x = x + h
        kv = _enc_kv(p_l, cfg, enc_out)
        x = x + _cross_fwd(p_l["cross"], cfg, rmsnorm(p_l["cross_norm"], x, cfg.norm_eps), kv)
        x = x + mlp_fwd(p_l["mlp"], rmsnorm(p_l["mlp_norm"], x, cfg.norm_eps), cfg.activation)
        return x, c_new

    if cache is None:
        nocache_body = lambda xx, pl: body(xx, (pl, None))
        if cfg.remat != "none":
            nocache_body = jax.checkpoint(nocache_body)
        x, _ = jax.lax.scan(nocache_body, x, params["dec_layers"])
        new_cache = None
    else:
        x, new_layers = jax.lax.scan(body, x, (params["dec_layers"], cache["layers"]))
        new_cache = {"layers": new_layers}
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x @ params["lm_head"].astype(x.dtype), new_cache


def init_decoder_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    layers = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[init_gqa_cache(cfg, batch, max_seq, dtype) for _ in range(cfg.n_layers)],
    )
    return {"layers": layers}


def encdec_loss_fn(params, cfg: ModelConfig, frames, tokens, targets) -> jax.Array:
    enc_out = encode(params, cfg, frames)
    logits, _ = decode(params, cfg, tokens, enc_out)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, cfg.vocab, dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    return (lse - gold).mean()
