"""Core transformer layers: norms, RoPE/M-RoPE, GQA + MLA attention, MLPs,
and capacity-bucketed MoE with the paper's expert-replication technique.

Everything is functional: ``init_*`` builds a param pytree (dict of jnp
arrays), ``*_fwd`` applies it.  Layer stacks are scanned, so all ``init_*``
are vmapped over the layer axis by the model assemblers.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .config import AttnConfig, ModelConfig, MoEConfig

# --------------------------------------------------------------------- norms


def init_rmsnorm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"]).astype(dt)


# ---------------------------------------------------------------------- RoPE


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(
    x: jax.Array,  # (b, s, h, hd)
    positions: jax.Array,  # (b, s) or (sections, b, s) for M-RoPE
    theta: float,
    mrope_sections: tuple[int, ...] = (),
) -> jax.Array:
    """Standard rotary embedding; with `mrope_sections` the frequency bands
    are split across (t, h, w) position streams (Qwen2-VL M-RoPE)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), dtype=jnp.float32)  # (hd/2,)
    if mrope_sections:
        assert sum(mrope_sections) == hd // 2, (mrope_sections, hd)
        if positions.ndim == 2:  # text-only: all streams share positions
            positions = jnp.broadcast_to(
                positions[None], (len(mrope_sections),) + positions.shape
            )
        parts = []
        start = 0
        for i, sec in enumerate(mrope_sections):
            parts.append(positions[i][..., None] * freqs[start : start + sec])
            start += sec
        ang = jnp.concatenate(parts, axis=-1)  # (b, s, hd/2)
    else:
        ang = positions[..., None] * freqs  # (b, s, hd/2)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ----------------------------------------------------------------- attention


def _dense(key, shape, scale_axis=0):
    fan_in = shape[scale_axis]
    return (jax.random.normal(key, shape, jnp.float32) / np.sqrt(fan_in)).astype(
        jnp.float32
    )


def init_gqa(key: jax.Array, cfg: ModelConfig) -> dict:
    a = cfg.attn
    nh, nkv, hd = cfg.attn_dims()
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense(ks[0], (d, nh * hd)),
        "wk": _dense(ks[1], (d, nkv * hd)),
        "wv": _dense(ks[2], (d, nkv * hd)),
        "wo": _dense(ks[3], (nh * hd, d)),
    }
    if a.qkv_bias:
        p["bq"] = jnp.zeros((nh * hd,), jnp.float32)
        p["bk"] = jnp.zeros((nkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((nkv * hd,), jnp.float32)
    return p


_Q_CHUNK = 1024


def _constrain_heads(t: jax.Array) -> jax.Array:
    """with_sharding_constraint: (b, s, h, hd) -> heads over 'model', batch
    over DP axes.  Without this, sharding propagated from neighboring ops
    (e.g. the MoE EP path's sequence split) can pull attention into a
    sequence-sharded layout whose masked-softmax needs cross-shard traffic
    (§Perf deepseek iteration 3)."""
    from jax.sharding import PartitionSpec as P

    from ..distrib.context import get_mesh

    mesh = get_mesh()
    if mesh is None or "model" not in mesh.axis_names or t.ndim != 4:
        return t
    b, s, h, hd = t.shape
    tp = mesh.shape["model"]
    if h % tp != 0:
        return t
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_n = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    bspec = dp if (dp and b % dp_n == 0) else None
    return jax.lax.with_sharding_constraint(t, P(bspec, None, "model", None))


def _sdpa_block(q, k, v, causal, q_offset, kv_len):
    """One dense attention block (q fits in memory against full kv)."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    qg = q.reshape(b, sq, kv, rep, hd)
    scores = jnp.einsum("bqkrh,bskh->bkrqs", qg, k) / np.sqrt(hd)
    sk = k.shape[1]
    mask = None
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(sk)
        mask = qpos[:, None] >= kpos[None, :]
    if kv_len is not None:
        valid = jnp.arange(sk)[None, :] < kv_len
        mask = valid if mask is None else (mask & valid)
    if mask is not None:
        scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkrqs,bskh->bqkrh", probs, v)
    return out.reshape(b, sq, h, v.shape[-1])  # v head dim may differ (MLA)


def _sdpa(
    q: jax.Array,  # (b, sq, h, hd)
    k: jax.Array,  # (b, sk, kv, hd)
    v: jax.Array,  # (b, sk, kv, hd)
    causal: bool,
    q_offset: jax.Array | int = 0,
    kv_len: jax.Array | None = None,
    q_chunk: int = _Q_CHUNK,
) -> jax.Array:
    """Grouped scaled-dot-product attention, numerically-stable softmax.

    Long query sequences are processed in q-chunks (lax.scan) so the live
    score tensor is (b, h, q_chunk, sk) instead of (b, h, sq, sk) — the
    memory-bounded formulation the dry-run lowers.  The Pallas flash kernel
    (kernels/flash_attention.py) is the TPU-native replacement with
    O(s * d) HBM traffic; see EXPERIMENTS.md §Perf.

    q_offset: absolute position of q[0] (decode: cache length).
    kv_len: number of valid kv entries (decode with preallocated cache).
    """
    b, sq, h, hd = q.shape
    if sq <= 2 * q_chunk or sq % q_chunk != 0:
        return _sdpa_block(q, k, v, causal, q_offset, kv_len)
    nq = sq // q_chunk
    qs = jnp.moveaxis(q.reshape(b, nq, q_chunk, h, hd), 1, 0)
    offs = q_offset + jnp.arange(nq) * q_chunk

    @jax.checkpoint  # don't save per-chunk probs (s^2 fp32) for backward
    def body(_, inp):
        qc, off = inp
        return 0.0, _sdpa_block(qc, k, v, causal, off, kv_len)

    _, out = jax.lax.scan(body, 0.0, (qs, offs))
    return jnp.moveaxis(out, 0, 1).reshape(b, sq, h, v.shape[-1])


def _decode_attn_seq_sharded(
    q: jax.Array,  # (b, 1, h, hd) — replicated over 'model'
    k: jax.Array,  # (b, S, kv, hd) — S sharded over 'model'
    v: jax.Array,
    kv_len: jax.Array,
    mesh,
) -> jax.Array:
    """Distributed flash decode: each 'model' shard computes a partial
    softmax (m, l, acc) over ITS slice of the KV cache; partials combine
    with a pmax + two psums.  Replaces the all-gather of the full cache
    (which dominated big-batch decode memory) with O(b*h*hd) collectives.
    """
    from jax.sharding import PartitionSpec as P

    from ..distrib.compat import shard_map

    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    b = q.shape[0]
    dp_n = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    bspec = dp if (dp and b % dp_n == 0) else None
    s_shard = k.shape[1] // mesh.shape["model"]

    def local(q_l, k_l, v_l, kv_len_l):
        bb, sq, h, hd = q_l.shape
        kv = k_l.shape[2]
        rep = h // kv
        idx = jax.lax.axis_index("model")
        kpos = idx * s_shard + jnp.arange(s_shard)
        valid = kpos[None, :] < kv_len_l  # (1, s_shard)
        qg = q_l.reshape(bb, sq, kv, rep, hd)
        scores = jnp.einsum("bqkrh,bskh->bkrqs", qg, k_l) / np.sqrt(hd)
        scores = jnp.where(valid[None, None, None], scores, -jnp.inf)
        m_l = scores.max(axis=-1, keepdims=True)
        m_g = jax.lax.pmax(m_l, "model")
        m_g = jnp.maximum(m_g, -1e30)  # guard all-masked shards
        p_ = jnp.exp(jnp.maximum(scores, -1e30) - m_g)
        l_g = jax.lax.psum(p_.sum(axis=-1, keepdims=True), "model")
        acc = jnp.einsum("bkrqs,bskh->bkrqh", p_.astype(v_l.dtype), v_l)
        acc_g = jax.lax.psum(acc, "model")
        out = acc_g / jnp.maximum(l_g, 1e-30).astype(acc_g.dtype)
        return jnp.moveaxis(out, 3, 1).reshape(bb, sq, h, hd)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None, None),
            P(bspec, "model", None, None),
            P(bspec, "model", None, None),
            P(),
        ),
        out_specs=P(bspec, None, None, None),
        check_vma=False,
    )
    return fn(q, k, v, kv_len)


def gqa_fwd(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (b, s, d)
    positions: jax.Array,
    cache: dict | None = None,
) -> tuple[jax.Array, dict | None]:
    """GQA attention.  With `cache`, runs a decode step appending s new
    tokens (cache = {'k': (b, max_s, kv, hd), 'v': ..., 'len': int32})."""
    a = cfg.attn
    nh, nkv, hd = cfg.attn_dims()
    b, s, d = x.shape
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if a.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = _constrain_heads(q.reshape(b, s, nh, hd))
    k = _constrain_heads(k.reshape(b, s, nkv, hd))
    v = _constrain_heads(v.reshape(b, s, nkv, hd))
    q_offset = 0 if cache is None else cache["len"]
    q = apply_rope(q, positions, a.rope_theta, a.mrope_sections)
    k = apply_rope(k, positions, a.rope_theta, a.mrope_sections)
    if cache is None:
        out = _sdpa(q, k, v, a.causal)
        new_cache = None
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache["len"], axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache["len"], axis=1)
        new_len = cache["len"] + s

        from ..distrib.context import get_mesh

        mesh = get_mesh()
        tp = mesh.shape["model"] if mesh is not None and "model" in mesh.axis_names else 0
        if (
            tp
            and s == 1
            and a.causal
            and nkv % tp != 0  # heads not shardable -> cache is seq-sharded
            and ck.shape[1] % tp == 0
        ):
            out = _decode_attn_seq_sharded(q, ck, cv, new_len, mesh)
        else:
            out = _sdpa(q, ck, cv, a.causal, q_offset=q_offset, kv_len=new_len)
        new_cache = {"k": ck, "v": cv, "len": new_len}
    y = out.reshape(b, s, nh * hd) @ p["wo"].astype(x.dtype)
    return y, new_cache


def init_gqa_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> dict:
    _, nkv, hd = cfg.attn_dims()
    return {
        "k": jnp.zeros((batch, max_seq, nkv, hd), dtype),
        "v": jnp.zeros((batch, max_seq, nkv, hd), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


# ------------------------------------------------------------------ MLA (DSv2)


def init_mla(key: jax.Array, cfg: ModelConfig) -> dict:
    a = cfg.attn
    d = cfg.d_model
    nh = a.n_heads
    qk = a.qk_nope_dim + a.qk_rope_dim
    ks = jax.random.split(key, 7)
    return {
        "wdq": _dense(ks[0], (d, a.q_lora_rank)),
        "q_norm": init_rmsnorm(a.q_lora_rank),
        "wuq": _dense(ks[1], (a.q_lora_rank, nh * qk)),
        "wdkv": _dense(ks[2], (d, a.kv_lora_rank)),
        "kv_norm": init_rmsnorm(a.kv_lora_rank),
        "wkr": _dense(ks[3], (d, a.qk_rope_dim)),
        "wuk": _dense(ks[4], (a.kv_lora_rank, nh * a.qk_nope_dim)),
        "wuv": _dense(ks[5], (a.kv_lora_rank, nh * a.v_head_dim)),
        "wo": _dense(ks[6], (nh * a.v_head_dim, d)),
    }


def mla_fwd(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    cache: dict | None = None,
) -> tuple[jax.Array, dict | None]:
    """Multi-head Latent Attention.  The decode cache stores only the
    compressed c_kv (kv_lora_rank) + shared rope key — DeepSeek-V2's memory
    saving — and up-projects per step."""
    a = cfg.attn
    nh = a.n_heads
    b, s, d = x.shape
    cq = rmsnorm(p["q_norm"], x @ p["wdq"].astype(x.dtype), cfg.norm_eps)
    q = _constrain_heads(
        (cq @ p["wuq"].astype(x.dtype)).reshape(b, s, nh, a.qk_nope_dim + a.qk_rope_dim)
    )
    q_nope, q_rope = jnp.split(q, [a.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, a.rope_theta)

    ckv = rmsnorm(p["kv_norm"], x @ p["wdkv"].astype(x.dtype), cfg.norm_eps)
    k_rope = apply_rope(
        (x @ p["wkr"].astype(x.dtype))[:, :, None, :], positions, a.rope_theta
    )  # (b, s, 1, rope_dim) — shared across heads

    if cache is not None:
        ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, cache["len"], 1)
        k_rope = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope, cache["len"], 1
        )
        new_len = cache["len"] + s
        new_cache = {"ckv": ckv, "k_rope": k_rope, "len": new_len}
        kv_len, q_offset = new_len, cache["len"]
    else:
        new_cache, kv_len, q_offset = None, None, 0

    k_nope = _constrain_heads(
        (ckv @ p["wuk"].astype(x.dtype)).reshape(-1, ckv.shape[1], nh, a.qk_nope_dim)
    )
    v = _constrain_heads(
        (ckv @ p["wuv"].astype(x.dtype)).reshape(-1, ckv.shape[1], nh, a.v_head_dim)
    )
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:3] + (a.qk_rope_dim,))], axis=-1
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = _sdpa(q_full, k, v, a.causal, q_offset=q_offset, kv_len=kv_len)
    y = out.reshape(b, s, nh * a.v_head_dim) @ p["wo"].astype(x.dtype)
    return y, new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> dict:
    a = cfg.attn
    return {
        "ckv": jnp.zeros((batch, max_seq, a.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, 1, a.qk_rope_dim), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


# ----------------------------------------------------------------------- MLP


def init_mlp(key: jax.Array, d: int, ff: int, activation: str) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_up": _dense(ks[0], (d, ff)), "w_down": _dense(ks[1], (ff, d))}
    if activation.endswith("_glu"):
        p["w_gate"] = _dense(ks[2], (d, ff))
    return p


def mlp_fwd(p: dict, x: jax.Array, activation: str) -> jax.Array:
    up = x @ p["w_up"].astype(x.dtype)
    if activation == "silu_glu":
        h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * up
    elif activation == "gelu_glu":
        h = jax.nn.gelu(x @ p["w_gate"].astype(x.dtype)) * up
    elif activation == "sq_relu":  # Nemotron-4: squared ReLU
        h = jnp.square(jax.nn.relu(up))
    elif activation == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(activation)
    return h @ p["w_down"].astype(x.dtype)


# ----------------------------------------------------------------------- MoE


def expert_replication_table(replication: tuple[int, ...]) -> np.ndarray:
    """Map logical expert -> slice of physical expert slots.

    With replication (r_0 .. r_{E-1}) the physical weight array holds
    sum(r_e) slots; slot order groups replicas of the same expert together.
    Returns (E, 2) int [start, count].
    """
    starts = np.concatenate([[0], np.cumsum(replication)[:-1]])
    return np.stack([starts, np.asarray(replication)], axis=1).astype(np.int32)


def init_moe(key: jax.Array, cfg: ModelConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    repl = m.replication or tuple([1] * m.n_experts)
    n_phys = int(sum(repl))
    ks = jax.random.split(key, 5)

    def expert_bank(key, n):
        kk = jax.random.split(key, 3)
        bank = {
            "w_up": _dense(kk[0], (n, d, m.d_ff_expert), scale_axis=1),
            "w_down": _dense(kk[1], (n, m.d_ff_expert, d), scale_axis=1),
        }
        if cfg.activation.endswith("_glu"):
            bank["w_gate"] = _dense(kk[2], (n, d, m.d_ff_expert), scale_axis=1)
        return bank

    p = {
        "router": _dense(ks[0], (d, m.n_experts)),
        "experts": expert_bank(ks[1], n_phys),
    }
    if m.n_shared:
        p["shared"] = init_mlp(ks[2], d, m.n_shared * m.d_ff_expert, cfg.activation)
    return p


def _expert_ffn(bank: dict, x: jax.Array, activation: str) -> jax.Array:
    """x: (E, C, d) -> (E, C, d), per-expert FFN via batched einsum."""
    up = jnp.einsum("ecd,edf->ecf", x, bank["w_up"].astype(x.dtype))
    if activation.endswith("_glu"):
        gate = jnp.einsum("ecd,edf->ecf", x, bank["w_gate"].astype(x.dtype))
        act = jax.nn.silu(gate) if activation == "silu_glu" else jax.nn.gelu(gate)
        h = act * up
    elif activation == "sq_relu":
        h = jnp.square(jax.nn.relu(up))
    else:
        h = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", h, bank["w_down"].astype(x.dtype))


# Router-statistics capture (the paper's "profile the input distribution"
# step).  When a list is installed via `capture_routing`, every EAGER (non-
# jit) moe_fwd call appends its top-k expert ids — used by the offline
# profile -> plan_replication -> redeploy flow.
_ROUTING_CAPTURE: list | None = None


class capture_routing:
    def __init__(self):
        self.records: list = []

    def __enter__(self):
        global _ROUTING_CAPTURE
        _ROUTING_CAPTURE = self.records
        return self.records

    def __exit__(self, *exc):
        global _ROUTING_CAPTURE
        _ROUTING_CAPTURE = None
        return False


def _route_and_bucket(
    p: dict, cfg: ModelConfig, xt: jax.Array, n_phys: int, capacity: int
):
    """Local (per-shard) top-k routing into capacity-bucketed slot buffers.

    Returns (expert_in (n_phys, C, d), scatter state for the combine).
    All indices are LOCAL — no cross-shard gathers, which is what keeps the
    GSPMD/shard_map lowering communication-minimal.
    """
    m = cfg.moe
    n_tok, d = xt.shape
    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)  # (N, E)
    gates, eids = jax.lax.top_k(logits, m.top_k)  # (N, k)
    gates = jax.nn.softmax(gates, axis=-1)
    if _ROUTING_CAPTURE is not None and not isinstance(eids, jax.core.Tracer):
        _ROUTING_CAPTURE.append(np.asarray(eids))

    repl = m.replication or tuple([1] * m.n_experts)
    table = expert_replication_table(repl)
    starts = jnp.asarray(table[:, 0])
    counts = jnp.asarray(table[:, 1])
    # round-robin replica choice per (token, k): the paper's 'next available
    # duplicate' dispatch, deterministic so it stays SPMD.
    tok_ids = jnp.arange(n_tok, dtype=jnp.int32)[:, None]
    slot = starts[eids] + jnp.where(
        counts[eids] > 1, (tok_ids + jnp.arange(m.top_k)[None]) % counts[eids], 0
    )  # (N, k)

    flat_slot = slot.reshape(-1)
    flat_gate = gates.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(n_tok, dtype=jnp.int32), m.top_k)

    order = jnp.argsort(flat_slot)
    s_slot = flat_slot[order]
    s_tok = flat_tok[order]
    s_gate = flat_gate[order]
    first = jnp.searchsorted(s_slot, jnp.arange(n_phys), side="left")
    rank = jnp.arange(s_slot.size) - first[s_slot]
    keep = rank < capacity
    buf_idx = jnp.where(keep, s_slot * capacity + rank, n_phys * capacity)

    buf = jnp.zeros((n_phys * capacity + 1, d), xt.dtype)
    buf = buf.at[buf_idx].set(xt[s_tok], mode="drop")
    expert_in = buf[:-1].reshape(n_phys, capacity, d)
    return expert_in, (s_tok, s_gate, keep, buf_idx, n_tok)


def _combine(expert_out: jax.Array, state, d: int) -> jax.Array:
    s_tok, s_gate, keep, buf_idx, n_tok = state
    n_slots = expert_out.shape[0] * expert_out.shape[1]
    flat_out = expert_out.reshape(n_slots, d)
    contrib = jnp.where(
        keep[:, None], flat_out[jnp.minimum(buf_idx, n_slots - 1)], 0
    )
    y = jnp.zeros((n_tok, d), expert_out.dtype)
    return y.at[s_tok].add(contrib * s_gate[:, None].astype(expert_out.dtype), mode="drop")


def _moe_capacity(cfg: ModelConfig, n_tok: int, n_phys: int) -> int:
    c = int(np.ceil(n_tok * cfg.moe.top_k / n_phys * cfg.moe.capacity_factor))
    return max(c, 4)


def moe_fwd(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Capacity-bucketed top-k MoE with optional expert replication.

    Three dispatch paths:
      * local (no mesh): everything on one shard — CPU tests.
      * EP (shard_map): physical experts shard over the 'model' axis; tokens
        shard over (dp..., 'model'); per-shard local routing then all-to-all
        to expert owners and back.  Requires n_phys % tp == 0 — expert
        REPLICATION (the paper's block-wise duplication) can make an
        undivisible expert count divisible (e.g. Grok's 8 experts x2 on a
        16-way axis), turning the allocation trick into a sharding enabler.
      * TP (shard_map): expert count not divisible -> every expert's ff dim
        shards over 'model'; routing is replicated per data shard and the
        down-projection psums over 'model'.
    """
    from ..distrib.context import get_mesh

    m = cfg.moe
    b, s, d = x.shape
    repl = m.replication or tuple([1] * m.n_experts)
    n_phys = int(sum(repl))
    mesh = get_mesh()

    def shared_out(xt):
        return mlp_fwd(p["shared"], xt, cfg.activation) if m.n_shared else 0.0

    if mesh is None or "model" not in mesh.axis_names:
        xt = x.reshape(b * s, d)
        cap = _moe_capacity(cfg, b * s, n_phys)
        expert_in, state = _route_and_bucket(p, cfg, xt, n_phys, cap)
        expert_out = _expert_ffn(p["experts"], expert_in, cfg.activation)
        y = _combine(expert_out, state, d) + shared_out(xt)
        return y.reshape(b, s, d)

    from jax.sharding import PartitionSpec as P

    from ..distrib.compat import shard_map

    from ..distrib.sharding import moe_ep_axes

    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    tp = mesh.shape["model"]
    dp_n = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    batch_ok = b % dp_n == 0
    bspec = dp if batch_ok else None

    ep = moe_ep_axes(cfg, mesh, seq_len=s)
    if ep:
        # ---- EP over `ep` axes: tokens split over (dp, model); physical
        # expert slots over ep (possibly ('data','model') = full 2D EP when
        # replication pads n_phys to the full group — the paper's block
        # duplication enabling maximal expert sharding).
        ep_n = int(np.prod([mesh.shape[a] for a in ep]))
        seq_split = tp if s % tp == 0 else 1
        n_local = (b // dp_n if batch_ok else b) * (s // seq_split)
        cap = _moe_capacity(cfg, n_local, n_phys)

        def ep_local(xl, router, experts, shared):
            bl, sl, _ = xl.shape
            xt = xl.reshape(bl * sl, d)
            pl_ = {"router": router, "experts": experts}
            expert_in, state = _route_and_bucket(pl_, cfg, xt, n_phys, cap)
            # send each expert's bucket to its owner shard
            expert_in = jax.lax.all_to_all(
                expert_in, ep, split_axis=0, concat_axis=1, tiled=True
            )  # (n_phys/ep_n, cap*ep_n, d)
            expert_out = _expert_ffn(experts, expert_in, cfg.activation)
            expert_out = jax.lax.all_to_all(
                expert_out, ep, split_axis=1, concat_axis=0, tiled=True
            )  # (n_phys, cap, d)
            y = _combine(expert_out, state, d)
            if m.n_shared:
                y = y + mlp_fwd(shared, xt, cfg.activation)
            return y.reshape(bl, sl, d)

        ep_spec = ep if len(ep) > 1 else ep[0]
        in_specs = (
            P(bspec, "model" if seq_split > 1 else None, None),
            P(None, None),
            jax.tree.map(lambda _: P(ep_spec, None, None), p["experts"]),
            jax.tree.map(lambda _: P(None, None), p.get("shared", {})),
        )
        fn = shard_map(
            ep_local,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=P(bspec, "model" if seq_split > 1 else None, None),
            check_vma=False,
        )
        return fn(x, p["router"], p["experts"], p.get("shared", {}))

    # ---- TP: routing replicated across model; expert ff dim sharded.
    # With serve_ff_2d the ff dim shards over ('data','model') — 2D
    # weight-stationary slicing for huge experts — and tokens replicate
    # (decode batches are tiny; the psum spans both axes).
    ff_2d = (
        m.serve_ff_2d
        and "data" in mesh.axis_names
        and m.d_ff_expert % (mesh.shape["data"] * tp) == 0
    )
    ff_axes = ("data", "model") if ff_2d else ("model",)
    x_spec = P(None, None, None) if ff_2d else P(bspec, None, None)
    n_local = b * s if ff_2d else (b // dp_n if batch_ok else b) * s
    cap = _moe_capacity(cfg, n_local, n_phys)

    def tp_local(xl, router, experts, shared):
        bl, sl, _ = xl.shape
        xt = xl.reshape(bl * sl, d)
        pl_ = {"router": router, "experts": experts}
        expert_in, state = _route_and_bucket(pl_, cfg, xt, n_phys, cap)
        expert_out = _expert_ffn(experts, expert_in, cfg.activation)
        expert_out = jax.lax.psum(expert_out, ff_axes)
        y = _combine(expert_out, state, d)
        if m.n_shared:
            y = y + mlp_fwd(shared, xt, cfg.activation)  # replicated weights
        return y.reshape(bl, sl, d)

    ffs = ff_axes if len(ff_axes) > 1 else ff_axes[0]
    expert_specs = jax.tree.map(
        lambda a: P(None, None, ffs) if a.shape[-1] == m.d_ff_expert else P(None, ffs, None),
        p["experts"],
    )
    shared_specs = jax.tree.map(lambda _: P(None, None), p.get("shared", {}))
    fn = shard_map(
        tp_local,
        mesh=mesh,
        in_specs=(x_spec, P(None, None), expert_specs, shared_specs),
        out_specs=x_spec,
        check_vma=False,
    )
    return fn(x, p["router"], p["experts"], p.get("shared", {}))
