"""Decoder-only LM assembler for dense / MoE / SSM / hybrid families.

* ``init_params``     — parameter pytree; homogeneous layer stacks are
                        vmap-initialized with a leading layer axis and scanned
                        at apply time (flat HLO, depth-independent compile).
* ``forward``         — training/prefill forward; with a cache pytree it
                        appends to preallocated KV/SSM state (prefill s>1 or
                        decode s=1 use the same path).
* ``init_cache``      — preallocated decode state for a (batch, max_seq).
* ``loss_fn``         — causal LM cross-entropy (fp32 logsumexp, z-loss).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import (
    _dense,
    gqa_fwd,
    init_gqa,
    init_gqa_cache,
    init_mla,
    init_mla_cache,
    init_mlp,
    init_moe,
    init_rmsnorm,
    mla_fwd,
    mlp_fwd,
    moe_fwd,
    rmsnorm,
)
from .ssm import (
    init_mamba2,
    init_mamba2_cache,
    mamba2_fwd,
    mamba2_step,
)

__all__ = ["init_params", "forward", "init_cache", "loss_fn"]


# ------------------------------------------------------------------ init


def _init_attn(key, cfg: ModelConfig) -> dict:
    return init_mla(key, cfg) if cfg.attn.kind == "mla" else init_gqa(key, cfg)


def _init_block(key, cfg: ModelConfig) -> dict:
    """One transformer block (attention + mlp/moe) with pre-norms."""
    k1, k2 = jax.random.split(key)
    p = {
        "attn_norm": init_rmsnorm(cfg.d_model),
        "attn": _init_attn(k1, cfg),
        "mlp_norm": init_rmsnorm(cfg.d_model),
    }
    if cfg.family == "moe":
        p["moe"] = init_moe(k2, cfg)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.activation)
    return p


def _init_ssm_block(key, cfg: ModelConfig) -> dict:
    return {"norm": init_rmsnorm(cfg.d_model), "mamba": init_mamba2(key, cfg)}


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    ke, kl, kh, ko = jax.random.split(key, 4)
    p: dict = {"embed": _dense(ke, (cfg.vocab, cfg.d_model))}
    L = cfg.n_layers
    layer_keys = jax.random.split(kl, L)
    if cfg.family in ("dense", "moe"):
        p["layers"] = jax.vmap(lambda k: _init_block(k, cfg))(layer_keys)
    elif cfg.family == "ssm":
        p["layers"] = jax.vmap(lambda k: _init_ssm_block(k, cfg))(layer_keys)
    elif cfg.family == "hybrid":
        p["layers"] = jax.vmap(lambda k: _init_ssm_block(k, cfg))(layer_keys)
        p["shared_block"] = _init_block(kh, cfg)
    else:
        raise ValueError(f"init_params: unsupported family {cfg.family}")
    p["final_norm"] = init_rmsnorm(cfg.d_model)
    if not cfg.tie_embeddings:
        p["lm_head"] = _dense(ko, (cfg.d_model, cfg.vocab))
    return p


# ------------------------------------------------------------------ cache


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    L = cfg.n_layers

    def stack(make):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[make() for _ in range(L)])

    if cfg.family in ("dense", "moe"):
        if cfg.attn.kind == "mla":
            layers = stack(lambda: init_mla_cache(cfg, batch, max_seq, dtype))
        else:
            layers = stack(lambda: init_gqa_cache(cfg, batch, max_seq, dtype))
        return {"layers": layers}
    if cfg.family == "ssm":
        return {"layers": stack(lambda: init_mamba2_cache(cfg, batch, dtype))}
    if cfg.family == "hybrid":
        n_sites = cfg.n_layers // cfg.shared_every
        sites = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[init_gqa_cache(cfg, batch, max_seq, dtype) for _ in range(n_sites)],
        )
        return {
            "layers": stack(lambda: init_mamba2_cache(cfg, batch, dtype)),
            "shared_sites": sites,
        }
    raise ValueError(cfg.family)


# ------------------------------------------------------------------ blocks


def _block_fwd(p, cfg: ModelConfig, x, positions, cache):
    attn_fn = mla_fwd if cfg.attn.kind == "mla" else gqa_fwd
    h, new_cache = attn_fn(p["attn"], cfg, rmsnorm(p["attn_norm"], x, cfg.norm_eps), positions, cache)
    x = x + h
    z = rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
    if cfg.family == "moe":
        x = x + moe_fwd(p["moe"], cfg, z)
    else:
        x = x + mlp_fwd(p["mlp"], z, cfg.activation)
    return x, new_cache


def _ssm_block_fwd(p, cfg: ModelConfig, x, cache):
    z = rmsnorm(p["norm"], x, cfg.norm_eps)
    if cache is None:
        h, _ = mamba2_fwd(p["mamba"], cfg, z)
        return x + h, None
    if x.shape[1] == 1:
        h, new_cache = mamba2_step(p["mamba"], cfg, z, cache)
        return x + h, new_cache
    # prefill with state carry-out: run full scan, update ssm state; the conv
    # rolling caches keep their (d_conv - 1) windows (prefill fills them via
    # the in-sequence conv; a production prefill would also refresh them —
    # exactness is covered by the s=1 step path).
    h, S = mamba2_fwd(p["mamba"], cfg, z, init_state=cache["ssm"].astype(z.dtype))
    new_cache = dict(cache, ssm=S)
    return x + h, new_cache


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _block_size(L: int) -> int:
    """Divisor of L nearest sqrt(L): sqrt-depth nested remat block size."""
    best, target = 1, L**0.5
    for k in range(1, L + 1):
        if L % k == 0 and abs(k - target) < abs(best - target):
            best = k
    return best


def _scan_layers(body, x, stacked, cfg: ModelConfig):
    """Scan a homogeneous layer stack with sqrt(L) two-level remat.

    Peak saved activations drop from O(L) layer inputs to
    O(L/k + k) block/layer inputs (k ~ sqrt(L)) at ~1 extra forward of
    recompute — the standard memory/compute trade for deep stacks.
    """
    L = cfg.n_layers
    k = _block_size(L) if cfg.remat != "none" else 1
    if k <= 1 or k == L:
        wrapped = _maybe_remat(body, cfg)
        x, _ = jax.lax.scan(wrapped, x, stacked)
        return x

    inner = _maybe_remat(body, cfg)
    blocked = jax.tree.map(lambda a: a.reshape((L // k, k) + a.shape[1:]), stacked)

    def block_body(xx, p_blk):
        xx, _ = jax.lax.scan(inner, xx, p_blk)
        return xx, None

    x, _ = jax.lax.scan(jax.checkpoint(block_body), x, blocked)
    return x


# ------------------------------------------------------------------ forward


def forward(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,  # (b, s) int32
    cache: dict | None = None,
    positions: jax.Array | None = None,
) -> tuple[jax.Array, dict | None]:
    """Returns (logits (b, s, vocab), new_cache)."""
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]
    b, s, _ = x.shape
    if positions is None:
        if cache is not None and cfg.family in ("dense", "moe"):
            base = cache["layers"]["len"][0]  # lens stacked (L,), all equal
        elif cache is not None and cfg.family == "hybrid":
            base = cache["shared_sites"]["len"][0]
        else:
            base = 0
        positions = jnp.broadcast_to(base + jnp.arange(s)[None, :], (b, s))

    if cfg.family in ("dense", "moe"):

        def body(x, inp):
            p_l, c_l = inp
            x, c_new = _block_fwd(p_l, cfg, x, positions, c_l)
            return x, c_new

        layer_cache = cache["layers"] if cache is not None else None
        if layer_cache is None:
            x = _scan_layers(
                lambda xx, pl: (body(xx, (pl, None))[0], None), x, params["layers"], cfg
            )
            new_cache = None
        else:
            x, new_layer_cache = jax.lax.scan(body, x, (params["layers"], layer_cache))
            new_cache = {"layers": new_layer_cache}

    elif cfg.family == "ssm":

        def body(x, inp):
            p_l, c_l = inp
            return _ssm_block_fwd(p_l, cfg, x, c_l)

        if cache is None:
            x = _scan_layers(
                lambda xx, pl: (_ssm_block_fwd(pl, cfg, xx, None)[0], None),
                x,
                params["layers"],
                cfg,
            )
            new_cache = None
        else:
            x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
            new_cache = {"layers": new_layers}

    elif cfg.family == "hybrid":
        if cache is None and cfg.shared_every and cfg.n_layers >= cfg.shared_every:
            # Train/prefill-without-cache: scan over GROUPS of
            # (shared_every mamba layers + 1 shared attention block).  The
            # shared block's weights are a scan closure constant (weight
            # sharing = the paper's duplication in reverse); group-level
            # remat keeps saved activations to O(n_sites + shared_every).
            n_sites = cfg.n_layers // cfg.shared_every
            main = n_sites * cfg.shared_every
            grouped = jax.tree.map(
                lambda a: a[:main].reshape((n_sites, cfg.shared_every) + a.shape[1:]),
                params["layers"],
            )

            def inner(xx, p_l):
                return _ssm_block_fwd(p_l, cfg, xx, None)[0], None

            inner_w = _maybe_remat(inner, cfg)

            def group(xx, p_grp):
                xx, _ = jax.lax.scan(inner_w, xx, p_grp)
                xx, _ = _block_fwd(params["shared_block"], cfg, xx, positions, None)
                return xx, None

            group_w = jax.checkpoint(group) if cfg.remat != "none" else group
            x, _ = jax.lax.scan(group_w, x, grouped)
            for i in range(main, cfg.n_layers):  # remainder layers
                p_l = jax.tree.map(lambda a, i=i: a[i], params["layers"])
                body = _maybe_remat(
                    lambda xx, pp: _ssm_block_fwd(pp, cfg, xx, None), cfg
                )
                x, _ = body(x, p_l)
            new_cache = None
        else:
            # Decode/prefill-with-cache: python loop (site-specific KV cache
            # breaks scan homogeneity; decode layer cost is tiny).
            new_layers, new_sites = [], []
            site = 0
            for i in range(cfg.n_layers):
                p_l = jax.tree.map(lambda a, i=i: a[i], params["layers"])
                c_l = (
                    jax.tree.map(lambda a, i=i: a[i], cache["layers"]) if cache else None
                )
                x, c_new = _ssm_block_fwd(p_l, cfg, x, c_l)
                if cache is not None:
                    new_layers.append(c_new)
                if cfg.shared_every and (i + 1) % cfg.shared_every == 0:
                    sc = (
                        jax.tree.map(lambda a, s=site: a[s], cache["shared_sites"])
                        if cache
                        else None
                    )
                    x, sc_new = _block_fwd(params["shared_block"], cfg, x, positions, sc)
                    if cache is not None:
                        new_sites.append(sc_new)
                    site += 1
            if cache is not None:
                new_cache = {
                    "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *new_layers),
                    "shared_sites": jax.tree.map(lambda *xs: jnp.stack(xs), *new_sites),
                }
            else:
                new_cache = None
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(x.dtype)
    logits = x @ head
    return logits, new_cache


# ------------------------------------------------------------------ loss


def loss_fn(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,  # (b, s)
    targets: jax.Array,  # (b, s)
    z_loss: float = 1e-4,
) -> jax.Array:
    logits, _ = forward(params, cfg, tokens)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: stays sharded over the
    # vocab axis (a gather would all-gather the full fp32 logits).
    onehot = jax.nn.one_hot(targets, cfg.vocab, dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = lse - gold
    loss = nll.mean()
    if z_loss:
        loss = loss + z_loss * jnp.square(lse).mean()
    return loss
