"""Model configuration for all assigned architectures.

One flexible config covers dense / MoE / SSM / hybrid / enc-dec families so
the distribution layer, launcher and dry-run treat every architecture
uniformly (``--arch <id>``).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Literal

__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "AttnConfig"]

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec"]
Activation = Literal["silu_glu", "gelu_glu", "sq_relu", "gelu"]


@dataclass(frozen=True)
class AttnConfig:
    kind: Literal["gqa", "mla", "none"] = "gqa"
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, ...] = ()  # qwen2-vl M-RoPE (t, h, w) dims
    # MLA (DeepSeek-V2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    causal: bool = True


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # Paper technique: extra replicas of hot experts (block-wise allocation).
    replication: tuple[int, ...] = ()  # replicas per expert; () -> all 1
    # Serving-only: shard each expert's ff dim over ('data', 'model') with
    # replicated tokens — weight-stationary 2D slicing for huge experts
    # (Grok) whose count divides no mesh axis.
    serve_ff_2d: bool = False


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    attn: AttnConfig = field(default_factory=AttnConfig)
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    activation: Activation = "silu_glu"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # hybrid (zamba2): one shared attention block applied every `shared_every`
    # SSM layers (weights shared across applications).
    shared_every: int = 0
    # enc-dec (whisper)
    n_encoder_layers: int = 0
    encoder_seq: int = 1500  # 30 s of audio at 50 Hz after the conv frontend
    # modality frontend stub: inputs arrive as precomputed embeddings
    frontend: Literal["none", "vision_stub", "audio_stub"] = "none"
    # compute dtype for activations (params kept fp32 master in the optimizer)
    dtype: str = "bfloat16"
    # activation remat policy for the scan-over-layers
    remat: Literal["none", "full", "dots"] = "full"

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    # ------------------------------------------------------------- accounting
    @property
    def sub_quadratic(self) -> bool:
        """True if 500k-token decode is feasible (SSM/hybrid state models)."""
        return self.family in ("ssm", "hybrid")

    def attn_dims(self) -> tuple[int, int, int]:
        a = self.attn
        hd = a.head_dim or (self.d_model // max(a.n_heads, 1))
        return a.n_heads, a.n_kv_heads, hd

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS = 6ND)."""
        d = self.d_model
        n = 0
        n += self.vocab * d * (1 if self.tie_embeddings else 2)
        L = self.n_layers

        def attn_params() -> int:
            a = self.attn
            if a.kind == "none":
                return 0
            nh, nkv, hd = self.attn_dims()
            if a.kind == "mla":
                p = d * a.q_lora_rank + a.q_lora_rank * nh * (a.qk_nope_dim + a.qk_rope_dim)
                p += d * (a.kv_lora_rank + a.qk_rope_dim)
                p += a.kv_lora_rank * nh * (a.qk_nope_dim + a.v_head_dim)
                p += nh * a.v_head_dim * d
                return p
            p = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
            if a.qkv_bias:
                p += (nh + 2 * nkv) * hd
            return p

        def ffn_params(ff: int) -> int:
            mats = 3 if self.activation.endswith("_glu") else 2
            return mats * d * ff

        def ssm_params() -> int:
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            p = d * (2 * di + 2 * s.n_groups * s.d_state + nh)  # in_proj
            p += s.d_conv * (di + 2 * s.n_groups * s.d_state)  # conv1d
            p += nh * 2  # A_log, D
            p += di * d  # out_proj
            return p

        if self.family == "dense":
            n += L * (attn_params() + ffn_params(self.d_ff))
        elif self.family == "moe":
            m = self.moe
            per_layer = attn_params()
            per_layer += m.n_experts * ffn_params(m.d_ff_expert)
            per_layer += m.n_shared * ffn_params(m.d_ff_expert)
            per_layer += d * m.n_experts  # router
            n += L * per_layer
        elif self.family == "ssm":
            n += L * ssm_params()
        elif self.family == "hybrid":
            n += L * ssm_params()
            n += attn_params() + ffn_params(self.d_ff)  # one shared block
        elif self.family == "encdec":
            n += self.n_encoder_layers * (attn_params() + ffn_params(self.d_ff))
            # decoder: self-attn + cross-attn + ffn
            n += L * (2 * attn_params() + ffn_params(self.d_ff))
        n += L * 2 * d  # norms (approx)
        return n

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only top-k + shared experts)."""
        if self.family != "moe":
            return self.param_count()
        m = self.moe
        full = self.param_count()
        mats = 3 if self.activation.endswith("_glu") else 2
        expert_p = mats * self.d_model * m.d_ff_expert
        inactive = self.n_layers * (m.n_experts - m.top_k) * expert_p
        return full - inactive
