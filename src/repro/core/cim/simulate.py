"""Allocation policies + pipelined-throughput simulator (Sections III & V).

Four policies, matching the paper's Figure 8:

  * ``baseline``        — zero-skipping OFF, arrays allocated by MACs
                          (deterministic arrays: the pre-zero-skip world).
  * ``weight_based``    — zero-skipping ON, arrays still allocated by MACs,
                          layer-wise dataflow (the naive policy that the
                          paper's 7.47x is measured against).
  * ``perf_layerwise``  — zero-skipping ON, arrays allocated greedily by
                          expected layer latency, layer-wise dataflow.
  * ``blockwise``       — zero-skipping ON, arrays allocated greedily by
                          expected *block* latency, block-wise dataflow
                          (the paper's contribution).

Dataflow model (steady-state pipelined throughput):

  Layer-wise: a duplicate is a full copy of the layer's block grid; all
  blocks of a duplicate synchronize per patch (gather/accumulate barrier), so
  a patch costs max_b cycles[p, b] and layer latency for N images is
      T_l = max( sum_p max_b c[p,b] / d_l ,  max_p max_b c[p,b] ).

  Block-wise: each block is an independent server pool with d_b replicas and
  no intra-layer barrier:
      T_l = max_b max( sum_p c[p,b] / d_b ,  max_p c[p,b] ).

  Layer pipelining makes throughput the bottleneck layer's:  T = max_l T_l.

Per-patch cycles come from the profiled sample (see profile.py); sums over
all patches are scaled from the sample mean.  Utilization = busy array-cycles
/ (arrays alive x T), per layer — the paper's Figure 9.

Array-kernel core
-----------------
The simulator is implemented as a pure array kernel over a *packed* profile
(``pack_profile`` -> ``SimTensors``): per-layer (S, B) cycle samples are
padded to a dense (L, S, Bmax) tensor with validity masks, reduced once to
sufficient statistics, and evaluated by ``_eval_kernel`` — plain array
algebra parameterized on the array module ``xp``.  The scalar ``simulate()``
runs it with ``xp=numpy`` (float64, drop-in API for the fabric runtime);
``BatchSimulator`` runs the same kernel with ``xp=jax.numpy`` under
``vmap``+``jit`` (x64) over a batch of allocations — the engine behind
``repro.dse`` design-space sweeps.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Literal

import numpy as np

from ..alloc.greedy import greedy_allocate, proportional_allocate, queueing_allocate
from .network import NetworkSpec
from .profile import NetworkProfile

__all__ = [
    "Policy",
    "POLICIES",
    "ALL_POLICIES",
    "Allocation",
    "SimResult",
    "SimTensors",
    "BatchSimResult",
    "BatchSimulator",
    "allocate",
    "pack_profile",
    "simulate",
    "run_policy",
    "blockwise_units",
    "split_block_dups",
]

Policy = Literal[
    "baseline",
    "weight_based",
    "perf_layerwise",
    "blockwise",
    # ablation: weight-based ALLOCATION but block-wise DATAFLOW — separates
    # the paper's two contributions (the paper reports them fused)
    "weight_blockflow",
    # serving extension: replicas by marginal queueing-delay reduction at a
    # target offered load (block-wise dataflow; see alloc.greedy
    # .queueing_allocate and fabric.vtime.refine_latency_aware)
    "latency_aware",
]
# the paper's Figure-8 policies — sweeps default to these; "latency_aware"
# additionally needs an offered load, so it joins sweeps explicitly
POLICIES: tuple[Policy, ...] = (
    "baseline",
    "weight_based",
    "perf_layerwise",
    "blockwise",
    "weight_blockflow",
)
ALL_POLICIES: tuple[Policy, ...] = POLICIES + ("latency_aware",)
ARRAYS_PER_PE = 64
CLOCK_HZ = 100e6


@dataclass(frozen=True)
class Allocation:
    policy: Policy
    layer_dups: np.ndarray | None  # (L,) for layer-wise policies
    block_dups: list[np.ndarray] | None  # per-layer (B_l,) for blockwise
    arrays_used: int
    arrays_total: int


@dataclass(frozen=True)
class SimResult:
    policy: Policy
    total_cycles: float
    images_per_sec: float
    layer_cycles: np.ndarray  # (L,) per-layer makespan for the batch
    layer_utilization: np.ndarray  # (L,) busy / (arrays x T)
    arrays_used: int

    @property
    def mean_utilization(self) -> float:
        return float(self.layer_utilization.mean())


def _layer_patch_cycles(prof: NetworkProfile, zskip: bool) -> list[np.ndarray]:
    """Per-layer (S, B) per-patch per-block cycle samples."""
    out = []
    for lp in prof.layers:
        if zskip:
            out.append(lp.cycles_sample.astype(np.float64))
        else:
            s = lp.cycles_sample.shape[0]
            out.append(np.broadcast_to(lp.baseline_block_cycles.astype(np.float64), (s, lp.baseline_block_cycles.size)).copy())
    return out


def blockwise_units(
    spec: NetworkSpec, block_mean_cycles: list[np.ndarray]
) -> tuple[np.ndarray, np.ndarray]:
    """Flattened per-block (base_latency, replica_cost) for greedy allocation.

    ``block_mean_cycles``: per-layer (B_l,) expected cycles per patch — from
    the profile, or from runtime-observed EWMA means (drift re-allocation).
    """
    base_lat, cost = [], []
    for i, layer in enumerate(spec.layers):
        mean_b = np.asarray(block_mean_cycles[i], dtype=np.float64)
        ppi = float(layer.patches_per_image)
        for b in range(layer.n_blocks):
            base_lat.append(mean_b[b] * ppi)
            cost.append(layer.arrays_per_block)
    return np.asarray(base_lat), np.asarray(cost, dtype=np.float64)


def split_block_dups(spec: NetworkSpec, replicas: np.ndarray) -> list[np.ndarray]:
    """Inverse of ``blockwise_units``'s flattening: per-layer (B_l,) replica
    arrays from the flat per-block vector (layers in order, blocks within)."""
    out, k = [], 0
    for layer in spec.layers:
        out.append(np.asarray(replicas[k : k + layer.n_blocks]).copy())
        k += layer.n_blocks
    return out


def allocate(
    spec: NetworkSpec,
    prof: NetworkProfile,
    policy: Policy,
    n_pes: int,
    arrays_per_pe: int = ARRAYS_PER_PE,
    free_budget: float | None = None,
    offered_ips: float | None = None,
    load_frac: float = 0.7,
    audit=None,
) -> Allocation:
    """Pick replica counts.  ``free_budget`` caps the arrays spent on extra
    replicas below the physical ``total - base`` (used to hold back a reserve
    pool for online re-allocation).

    The ``latency_aware`` policy additionally needs a target offered load:
    ``offered_ips`` (images/sec), or — when omitted — ``load_frac`` times
    the analytic throughput of the ``blockwise`` allocation at the same
    budget (the natural "provision for X% of peak" operating point).

    ``audit`` (a ``repro.obs.AllocationAudit``) records the greedy policies'
    per-grant decision log (``perf_layerwise`` / ``blockwise``); other
    policies do not route through the greedy loop and leave it empty."""
    total = n_pes * arrays_per_pe
    base_arrays = spec.n_arrays
    if total < base_arrays:
        raise ValueError(f"{total} arrays < minimum {base_arrays} for {spec.name}")
    free = total - base_arrays
    if free_budget is not None:
        if not 0 <= free_budget <= free:
            raise ValueError(
                f"free_budget {free_budget} outside [0, {free}] free arrays"
            )
        free = float(free_budget)
    L = len(spec.layers)
    layer_arrays = np.array([l.n_arrays for l in spec.layers], dtype=np.float64)
    zskip = policy != "baseline"
    cyc = _layer_patch_cycles(prof, zskip)
    ppi = np.array([l.patches_per_image for l in spec.layers], dtype=np.float64)

    if policy in ("baseline", "weight_based", "weight_blockflow"):
        macs = np.array([l.macs_per_image for l in spec.layers], dtype=np.float64)
        res = proportional_allocate(macs, layer_arrays, free)
        dups = res.replicas
        used = int(base_arrays + (res.replicas - 1) @ layer_arrays)
        if policy == "weight_blockflow":
            # same replica budget per layer, but blocks dispatch independently
            block_dups = [
                np.full(l.n_blocks, dups[i], dtype=np.int64)
                for i, l in enumerate(spec.layers)
            ]
            return Allocation(policy, None, block_dups, used, total)
        return Allocation(policy, dups, None, used, total)

    if policy == "perf_layerwise":
        # expected per-layer latency with one duplicate: patches x E[max_b c]
        exp_lat = np.array([cyc[i].max(axis=1).mean() * ppi[i] for i in range(L)])
        res = greedy_allocate(exp_lat, layer_arrays, free, audit=audit)
        used = int(base_arrays + (res.replicas - 1) @ layer_arrays)
        return Allocation(policy, res.replicas, None, used, total)

    if policy == "blockwise":
        # one unit per block across the whole network
        base_lat, cost = blockwise_units(spec, [cyc[i].mean(axis=0) for i in range(L)])
        res = greedy_allocate(base_lat, cost, free, audit=audit)
        block_dups = split_block_dups(spec, res.replicas)
        used = int(base_arrays + ((res.replicas - 1) * cost).sum())
        return Allocation(policy, None, block_dups, used, total)

    if policy == "latency_aware":
        if offered_ips is None:
            bw = allocate(spec, prof, "blockwise", n_pes, arrays_per_pe, free_budget)
            offered_ips = load_frac * simulate(spec, prof, bw).images_per_sec
        if offered_ips <= 0:
            raise ValueError(f"offered_ips must be positive, got {offered_ips}")
        r_cyc = float(offered_ips) / CLOCK_HZ  # images per fabric cycle
        job_rate, mean, scv, cost, batch, group = _queueing_inputs(spec, cyc, r_cyc)
        res = queueing_allocate(
            job_rate, mean, scv, cost, free, batch_size=batch, group=group
        )
        block_dups = split_block_dups(spec, res.replicas)
        used = int(base_arrays + ((res.replicas - 1) * cost).sum())
        return Allocation(policy, None, block_dups, used, total)

    raise ValueError(policy)


def _queueing_inputs(spec: NetworkSpec, cyc, r_cyc: float):
    """Per-block queueing-model inputs for the ``latency_aware`` policy.

    Per-block FIFO pools: every patch of layer ``l`` brings one job to each
    of its blocks, so the pool's job rate is ``r * patches/image``, arriving
    in request-batches of ``patches_per_image``; a layer (= one pipeline
    stage) is a group — its latency is its slowest pool's.  Shared between
    the flat ``allocate`` and the placed ``topology.allocate_placed`` so
    their scoring inputs cannot drift apart (the single-chip bit-identity
    guarantee hangs on it).  Returns flat (job_rate, mean, scv, cost,
    batch, group) arrays over all blocks.
    """
    mean, scv, job_rate, cost, batch, group = [], [], [], [], [], []
    for i, layer in enumerate(spec.layers):
        m = cyc[i].mean(axis=0)
        v = cyc[i].var(axis=0)
        mean.append(m)
        scv.append(v / np.maximum(m, 1e-300) ** 2)
        job_rate.append(np.full(layer.n_blocks, r_cyc * layer.patches_per_image))
        cost.append(np.full(layer.n_blocks, float(layer.arrays_per_block)))
        batch.append(np.full(layer.n_blocks, float(layer.patches_per_image)))
        group.append(np.full(layer.n_blocks, i, dtype=np.int64))
    return (
        np.concatenate(job_rate),
        np.concatenate(mean),
        np.concatenate(scv),
        np.concatenate(cost),
        np.concatenate(batch),
        np.concatenate(group),
    )


# ------------------------------------------------------- array-kernel core
@dataclass(frozen=True)
class SimTensors:
    """Packed (NetworkSpec, NetworkProfile) pair: padded cycle tensors plus
    the sufficient statistics the dataflow model needs.

    Leading axis 2 on the per-variant arrays selects zero-skipping:
    index 0 = baseline (deterministic cycles), 1 = zero-skipping.
    """

    cycles: np.ndarray  # (2, L, S, B) per-patch per-block cycles, 0-padded
    s_mask: np.ndarray  # (L, S) valid patch samples
    b_mask: np.ndarray  # (L, B) valid blocks
    ppi: np.ndarray  # (L,) patches per image
    width: np.ndarray  # (L,) arrays per block
    layer_arrays: np.ndarray  # (L,) arrays in one copy of the layer
    n_blocks: np.ndarray  # (L,) valid block count
    # derived statistics (2, ...):
    mean_b: np.ndarray  # (2, L, B) E_S[c]
    max_b: np.ndarray  # (2, L, B) max_S c
    pm_mean: np.ndarray  # (2, L) E_S[max_B c]  (layer-wise barrier)
    pm_max: np.ndarray  # (2, L) max_S max_B c
    busy_sum: np.ndarray  # (2, L) sum_B E_S[c]  (busy cycles per patch)

    @property
    def L(self) -> int:
        return self.b_mask.shape[0]

    @property
    def B(self) -> int:
        return self.b_mask.shape[1]


# keyed on object identity (the frozen dataclasses hold numpy arrays, so
# they are not hashable); weakref finalizers evict entries before an id can
# be reused, keeping repeated scalar simulate() calls from re-packing
_PACK_CACHE: dict[tuple[int, int], SimTensors] = {}


def pack_profile(spec: NetworkSpec, prof: NetworkProfile) -> SimTensors:
    """Pad per-layer (S, B) cycle samples into dense tensors + statistics.

    Cached per (spec, profile) object pair — the tensors are pure functions
    of the inputs and every ``simulate()`` call needs them."""
    import weakref

    key = (id(spec), id(prof))
    hit = _PACK_CACHE.get(key)
    if hit is not None:
        return hit
    st = _pack_profile(spec, prof)
    _PACK_CACHE[key] = st
    weakref.finalize(spec, _PACK_CACHE.pop, key, None)
    weakref.finalize(prof, _PACK_CACHE.pop, key, None)
    return st


def _pack_profile(spec: NetworkSpec, prof: NetworkProfile) -> SimTensors:
    L = len(spec.layers)
    variants = [_layer_patch_cycles(prof, False), _layer_patch_cycles(prof, True)]
    S = max(c.shape[0] for c in variants[1])
    B = max(l.n_blocks for l in spec.layers)
    cycles = np.zeros((2, L, S, B))
    s_mask = np.zeros((L, S), dtype=bool)
    b_mask = np.zeros((L, B), dtype=bool)
    for v, cyc in enumerate(variants):
        for i, c in enumerate(cyc):
            s, b = c.shape
            cycles[v, i, :s, :b] = c
            s_mask[i, :s] = True
            b_mask[i, :b] = True
    s_count = s_mask.sum(axis=1)  # (L,)
    mean_b = cycles.sum(axis=2) / s_count[None, :, None]
    max_b = cycles.max(axis=2)  # padded entries are 0 <= any real cycle count
    patch_max = np.where(b_mask[None, :, None, :], cycles, -np.inf).max(axis=3)
    pm_mean = np.where(s_mask, patch_max, 0.0).sum(axis=2) / s_count[None, :]
    pm_max = np.where(s_mask, patch_max, -np.inf).max(axis=2)
    busy_sum = np.where(b_mask, mean_b, 0.0).sum(axis=2)
    return SimTensors(
        cycles=cycles,
        s_mask=s_mask,
        b_mask=b_mask,
        ppi=np.array([l.patches_per_image for l in spec.layers], dtype=np.float64),
        width=np.array([l.arrays_per_block for l in spec.layers], dtype=np.float64),
        layer_arrays=np.array([l.n_arrays for l in spec.layers], dtype=np.float64),
        n_blocks=np.array([l.n_blocks for l in spec.layers], dtype=np.int64),
        mean_b=mean_b,
        max_b=max_b,
        pm_mean=pm_mean,
        pm_max=pm_max,
        busy_sum=busy_sum,
    )


def _eval_kernel(
    xp,
    mean_b,  # (L, B) — zskip variant already selected; (V, L, B) with ``sel``
    max_b,  # (L, B)
    pm_mean,  # (L,)
    pm_max,  # (L,)
    busy_sum,  # (L,)
    b_mask,  # (L, B)
    ppi,  # (L,)
    width,  # (L,)
    layer_arrays,  # (L,)
    dups_lb,  # (L, B) float replicas (layer-wise: broadcast along B)
    layerwise,  # scalar bool: barrier (layer-wise) vs independent blocks
    n_images,
    clock_hz,
    *,
    sel=None,  # scalar variant index into a leading stack axis, or None
):
    """One allocation -> (T, img/s, per-layer makespan, per-layer util).

    Pure array algebra: runs identically with ``xp=numpy`` (scalar float64
    path) and ``xp=jax.numpy`` (vmapped batch path).

    With ``sel`` the five statistic tensors carry a leading variant axis
    (e.g. the fused pipeline's (2A, L, B) baseline+zskip per-ADC stacks)
    and the kernel gathers its variant FIRST, inside the kernel body.
    Under ``vmap`` (banks unbatched, ``sel`` batched) this is a per-config
    scalar-indexed gather that XLA fuses into the eval loop — the bank
    stack stays shared across the whole batch instead of being
    materialized per config (the 0.69x dense-grid regression the shared
    bank layout removes).  Selecting an element is not arithmetic, so
    results are identical to pre-gathered inputs.
    """
    if sel is not None:
        mean_b = mean_b[sel]
        max_b = max_b[sel]
        pm_mean = pm_mean[sel]
        pm_max = pm_max[sel]
        busy_sum = busy_sum[sel]
    P = ppi * n_images  # (L,) patches in the batch
    d_layer = dups_lb[:, 0]
    # layer-wise: patches synchronize on the slowest block (barrier)
    t_lw = xp.maximum(pm_mean * P / d_layer, pm_max)
    # block-wise: every block is an independent replicated server pool
    per_block = xp.maximum(mean_b * P[:, None] / dups_lb, max_b)
    t_bw = xp.where(b_mask, per_block, -xp.inf).max(axis=-1)
    layer_T = xp.where(layerwise, t_lw, t_bw)
    alive = xp.where(
        layerwise,
        layer_arrays * d_layer,
        xp.where(b_mask, dups_lb * width[:, None], 0.0).sum(axis=-1),
    )
    # busy cycles are allocation-independent: every (patch, block) job runs
    # exactly once on `width` arrays.
    busy = busy_sum * P * width
    T = layer_T.max()
    util = busy / (alive * T)
    ips = n_images / (T / clock_hz)
    return T, ips, layer_T, util


def _alloc_to_dups(st: SimTensors, alloc: Allocation) -> tuple[np.ndarray, bool]:
    """Allocation -> dense (L, B) replica matrix + layer-wise dataflow flag."""
    dups = np.ones((st.L, st.B))
    if alloc.layer_dups is not None:
        dups *= np.asarray(alloc.layer_dups, dtype=np.float64)[:, None]
        return dups, True
    for i, d in enumerate(alloc.block_dups):
        dups[i, : len(d)] = np.asarray(d, dtype=np.float64)
    return dups, False


def simulate(
    spec: NetworkSpec,
    prof: NetworkProfile,
    alloc: Allocation,
    n_images: int = 64,
    clock_hz: float = CLOCK_HZ,
) -> SimResult:
    st = pack_profile(spec, prof)
    z = int(alloc.policy != "baseline")
    dups_lb, layerwise = _alloc_to_dups(st, alloc)
    T, ips, layer_T, util = _eval_kernel(
        np,
        st.mean_b[z],
        st.max_b[z],
        st.pm_mean[z],
        st.pm_max[z],
        st.busy_sum[z],
        st.b_mask,
        st.ppi,
        st.width,
        st.layer_arrays,
        dups_lb,
        layerwise,
        n_images,
        clock_hz,
    )
    return SimResult(alloc.policy, float(T), float(ips), layer_T, util, alloc.arrays_used)


# ----------------------------------------------------------- batched engine
@dataclass(frozen=True)
class BatchSimResult:
    """Structure-of-arrays ``SimResult`` for a batch of C allocations."""

    total_cycles: np.ndarray  # (C,)
    images_per_sec: np.ndarray  # (C,)
    layer_cycles: np.ndarray  # (C, L)
    layer_utilization: np.ndarray  # (C, L)

    @property
    def mean_utilization(self) -> np.ndarray:  # (C,)
        return self.layer_utilization.mean(axis=1)

    def __len__(self) -> int:
        return self.total_cycles.shape[0]


class BatchSimulator:
    """jit + vmap of ``_eval_kernel`` over a batch of allocations.

    One instance per (spec, profile); the packed tensors are baked into the
    compiled kernel as constants.  Runs in float64 (``jax.experimental
    .enable_x64``) so batch results match the scalar ``simulate()`` to
    roundoff — the golden-equivalence suite pins this at 1e-9.

    ``shard=True`` shard_maps the vmapped kernel over the host's local
    devices (``repro.distrib.sharding.shard_map_batch``): the batch is split
    device-wise, so sweep throughput scales with the accelerators present.
    Rows are evaluated independently either way — results are identical to
    the unsharded path (the suite asserts it).
    """

    def __init__(self, spec: NetworkSpec, prof: NetworkProfile, *, shard: bool = False):
        self.spec = spec
        self.tensors = pack_profile(spec, prof)
        self.shard = bool(shard)
        self._compiled: dict[tuple, object] = {}

    def _fn(self, n_images: int, clock_hz: float):
        key = (n_images, clock_hz)
        if key not in self._compiled:
            import jax
            import jax.numpy as jnp

            st = self.tensors

            def one(dups_lb, layerwise, zskip):
                pick = lambda a: jnp.where(zskip, a[1], a[0])  # noqa: E731
                return _eval_kernel(
                    jnp,
                    pick(st.mean_b),
                    pick(st.max_b),
                    pick(st.pm_mean),
                    pick(st.pm_max),
                    pick(st.busy_sum),
                    st.b_mask,
                    st.ppi,
                    st.width,
                    st.layer_arrays,
                    dups_lb,
                    layerwise,
                    n_images,
                    clock_hz,
                )

            if self.shard:
                from ...distrib.sharding import shard_map_batch

                self._compiled[key] = shard_map_batch(jax.vmap(one))
            else:
                self._compiled[key] = jax.jit(jax.vmap(one))
        return self._compiled[key]

    def __call__(
        self,
        dups_lb: np.ndarray,  # (C, L, B) float replicas
        layerwise: np.ndarray,  # (C,) bool
        zskip: np.ndarray,  # (C,) bool
        n_images: int = 64,
        clock_hz: float = CLOCK_HZ,
    ) -> BatchSimResult:
        from jax.experimental import enable_x64

        dups_lb = np.asarray(dups_lb, dtype=np.float64)
        if dups_lb.ndim != 3 or dups_lb.shape[1:] != (self.tensors.L, self.tensors.B):
            raise ValueError(
                f"dups_lb {dups_lb.shape} != (C, {self.tensors.L}, {self.tensors.B})"
            )
        with enable_x64():
            T, ips, layer_T, util = self._fn(int(n_images), float(clock_hz))(
                dups_lb, np.asarray(layerwise, bool), np.asarray(zskip, bool)
            )
        return BatchSimResult(
            np.asarray(T), np.asarray(ips), np.asarray(layer_T), np.asarray(util)
        )


def run_policy(
    spec: NetworkSpec,
    prof: NetworkProfile,
    policy: Policy,
    n_pes: int,
    n_images: int = 64,
) -> SimResult:
    return simulate(spec, prof, allocate(spec, prof, policy, n_pes), n_images)
