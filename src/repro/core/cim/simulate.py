"""Allocation policies + pipelined-throughput simulator (Sections III & V).

Four policies, matching the paper's Figure 8:

  * ``baseline``        — zero-skipping OFF, arrays allocated by MACs
                          (deterministic arrays: the pre-zero-skip world).
  * ``weight_based``    — zero-skipping ON, arrays still allocated by MACs,
                          layer-wise dataflow (the naive policy that the
                          paper's 7.47x is measured against).
  * ``perf_layerwise``  — zero-skipping ON, arrays allocated greedily by
                          expected layer latency, layer-wise dataflow.
  * ``blockwise``       — zero-skipping ON, arrays allocated greedily by
                          expected *block* latency, block-wise dataflow
                          (the paper's contribution).

Dataflow model (steady-state pipelined throughput):

  Layer-wise: a duplicate is a full copy of the layer's block grid; all
  blocks of a duplicate synchronize per patch (gather/accumulate barrier), so
  a patch costs max_b cycles[p, b] and layer latency for N images is
      T_l = max( sum_p max_b c[p,b] / d_l ,  max_p max_b c[p,b] ).

  Block-wise: each block is an independent server pool with d_b replicas and
  no intra-layer barrier:
      T_l = max_b max( sum_p c[p,b] / d_b ,  max_p c[p,b] ).

  Layer pipelining makes throughput the bottleneck layer's:  T = max_l T_l.

Per-patch cycles come from the profiled sample (see profile.py); sums over
all patches are scaled from the sample mean.  Utilization = busy array-cycles
/ (arrays alive x T), per layer — the paper's Figure 9.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Literal

import numpy as np

from ..alloc.greedy import greedy_allocate, proportional_allocate
from .network import NetworkSpec
from .profile import NetworkProfile

__all__ = [
    "Policy",
    "Allocation",
    "SimResult",
    "allocate",
    "simulate",
    "run_policy",
    "blockwise_units",
    "split_block_dups",
]

Policy = Literal[
    "baseline",
    "weight_based",
    "perf_layerwise",
    "blockwise",
    # ablation: weight-based ALLOCATION but block-wise DATAFLOW — separates
    # the paper's two contributions (the paper reports them fused)
    "weight_blockflow",
]
ARRAYS_PER_PE = 64
CLOCK_HZ = 100e6


@dataclass(frozen=True)
class Allocation:
    policy: Policy
    layer_dups: np.ndarray | None  # (L,) for layer-wise policies
    block_dups: list[np.ndarray] | None  # per-layer (B_l,) for blockwise
    arrays_used: int
    arrays_total: int


@dataclass(frozen=True)
class SimResult:
    policy: Policy
    total_cycles: float
    images_per_sec: float
    layer_cycles: np.ndarray  # (L,) per-layer makespan for the batch
    layer_utilization: np.ndarray  # (L,) busy / (arrays x T)
    arrays_used: int

    @property
    def mean_utilization(self) -> float:
        return float(self.layer_utilization.mean())


def _layer_patch_cycles(prof: NetworkProfile, zskip: bool) -> list[np.ndarray]:
    """Per-layer (S, B) per-patch per-block cycle samples."""
    out = []
    for lp in prof.layers:
        if zskip:
            out.append(lp.cycles_sample.astype(np.float64))
        else:
            s = lp.cycles_sample.shape[0]
            out.append(np.broadcast_to(lp.baseline_block_cycles.astype(np.float64), (s, lp.baseline_block_cycles.size)).copy())
    return out


def blockwise_units(
    spec: NetworkSpec, block_mean_cycles: list[np.ndarray]
) -> tuple[np.ndarray, np.ndarray]:
    """Flattened per-block (base_latency, replica_cost) for greedy allocation.

    ``block_mean_cycles``: per-layer (B_l,) expected cycles per patch — from
    the profile, or from runtime-observed EWMA means (drift re-allocation).
    """
    base_lat, cost = [], []
    for i, layer in enumerate(spec.layers):
        mean_b = np.asarray(block_mean_cycles[i], dtype=np.float64)
        ppi = float(layer.patches_per_image)
        for b in range(layer.n_blocks):
            base_lat.append(mean_b[b] * ppi)
            cost.append(layer.arrays_per_block)
    return np.asarray(base_lat), np.asarray(cost, dtype=np.float64)


def split_block_dups(spec: NetworkSpec, replicas: np.ndarray) -> list[np.ndarray]:
    """Inverse of ``blockwise_units``'s flattening: per-layer (B_l,) replica
    arrays from the flat per-block vector (layers in order, blocks within)."""
    out, k = [], 0
    for layer in spec.layers:
        out.append(np.asarray(replicas[k : k + layer.n_blocks]).copy())
        k += layer.n_blocks
    return out


def allocate(
    spec: NetworkSpec,
    prof: NetworkProfile,
    policy: Policy,
    n_pes: int,
    arrays_per_pe: int = ARRAYS_PER_PE,
    free_budget: float | None = None,
) -> Allocation:
    """Pick replica counts.  ``free_budget`` caps the arrays spent on extra
    replicas below the physical ``total - base`` (used to hold back a reserve
    pool for online re-allocation)."""
    total = n_pes * arrays_per_pe
    base_arrays = spec.n_arrays
    if total < base_arrays:
        raise ValueError(f"{total} arrays < minimum {base_arrays} for {spec.name}")
    free = total - base_arrays
    if free_budget is not None:
        if not 0 <= free_budget <= free:
            raise ValueError(
                f"free_budget {free_budget} outside [0, {free}] free arrays"
            )
        free = float(free_budget)
    L = len(spec.layers)
    layer_arrays = np.array([l.n_arrays for l in spec.layers], dtype=np.float64)
    zskip = policy != "baseline"
    cyc = _layer_patch_cycles(prof, zskip)
    ppi = np.array([l.patches_per_image for l in spec.layers], dtype=np.float64)

    if policy in ("baseline", "weight_based", "weight_blockflow"):
        macs = np.array([l.macs_per_image for l in spec.layers], dtype=np.float64)
        res = proportional_allocate(macs, layer_arrays, free)
        dups = res.replicas
        used = int(base_arrays + (res.replicas - 1) @ layer_arrays)
        if policy == "weight_blockflow":
            # same replica budget per layer, but blocks dispatch independently
            block_dups = [
                np.full(l.n_blocks, dups[i], dtype=np.int64)
                for i, l in enumerate(spec.layers)
            ]
            return Allocation(policy, None, block_dups, used, total)
        return Allocation(policy, dups, None, used, total)

    if policy == "perf_layerwise":
        # expected per-layer latency with one duplicate: patches x E[max_b c]
        exp_lat = np.array([cyc[i].max(axis=1).mean() * ppi[i] for i in range(L)])
        res = greedy_allocate(exp_lat, layer_arrays, free)
        used = int(base_arrays + (res.replicas - 1) @ layer_arrays)
        return Allocation(policy, res.replicas, None, used, total)

    if policy == "blockwise":
        # one unit per block across the whole network
        base_lat, cost = blockwise_units(spec, [cyc[i].mean(axis=0) for i in range(L)])
        res = greedy_allocate(base_lat, cost, free)
        block_dups = split_block_dups(spec, res.replicas)
        used = int(base_arrays + ((res.replicas - 1) * cost).sum())
        return Allocation(policy, None, block_dups, used, total)

    raise ValueError(policy)


def simulate(
    spec: NetworkSpec,
    prof: NetworkProfile,
    alloc: Allocation,
    n_images: int = 64,
    clock_hz: float = CLOCK_HZ,
) -> SimResult:
    zskip = alloc.policy != "baseline"
    cyc = _layer_patch_cycles(prof, zskip)
    L = len(spec.layers)
    layer_T = np.zeros(L)
    busy = np.zeros(L)  # busy array-cycles
    arrays_alive = np.zeros(L)

    for i, layer in enumerate(spec.layers):
        c = cyc[i]  # (S, B) per-patch-per-block cycles
        P = layer.patches_per_image * n_images
        width = layer.arrays_per_block
        if alloc.layer_dups is not None:
            d = float(alloc.layer_dups[i])
            patch_t = c.max(axis=1)  # barrier: slowest block per patch
            layer_T[i] = max(patch_t.mean() * P / d, patch_t.max())
            arrays_alive[i] = layer.n_arrays * d
        else:
            dups = alloc.block_dups[i].astype(np.float64)  # (B,)
            per_block = np.maximum(c.mean(axis=0) * P / dups, c.max(axis=0))
            layer_T[i] = per_block.max()
            arrays_alive[i] = float((dups * width).sum())
        # busy cycles are allocation-independent: every (patch, block) job
        # runs exactly once on `width` arrays.
        busy[i] = c.mean(axis=0).sum() * P * width

    T = float(layer_T.max())  # pipelined bottleneck
    util = busy / (arrays_alive * T)
    ips = n_images / (T / clock_hz)
    return SimResult(alloc.policy, T, ips, layer_T, util, alloc.arrays_used)


def run_policy(
    spec: NetworkSpec,
    prof: NetworkProfile,
    policy: Policy,
    n_pes: int,
    n_images: int = 64,
) -> SimResult:
    return simulate(spec, prof, allocate(spec, prof, policy, n_pes), n_images)
