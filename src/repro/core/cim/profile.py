"""Input-statistics profiling (Section III-A, "profile the distribution of
'1's in the activations gathered from a large set of examples run on a GPU").

We run the actual quantized network forward in JAX (CPU here), collect the
uint8 im2col patch matrices that would be applied to the crossbar word lines,
and derive per-block '1'-bit densities plus sampled per-(patch, block) cycle
counts for the simulator.

Inputs are synthetic-but-structured images (low-frequency random fields +
noise) — the distributional knobs the paper relies on (ReLU sparsity, per-
layer density spread) emerge from the network itself, not the dataset.  The
measured speedups are reported against our own profile in EXPERIMENTS.md.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .cost import ArrayConfig, DEFAULT_ARRAY, zskip_cycles, baseline_cycles
from .network import NetworkSpec, LayerSpec

__all__ = ["LayerProfile", "NetworkProfile", "profile_network", "synthetic_images"]


@dataclass(frozen=True)
class LayerProfile:
    name: str
    block_density: np.ndarray  # (B,) mean '1'-bit density per block
    mean_cycles: np.ndarray  # (B,) E[zskip cycles] per block per patch
    cycles_sample: np.ndarray  # (S, B) sampled per-patch per-block cycles
    baseline_block_cycles: np.ndarray  # (B,) constant cycles without zskip
    patches_per_image: int

    @property
    def density(self) -> float:
        return float(self.block_density.mean())


@dataclass(frozen=True)
class NetworkProfile:
    network: str
    layers: tuple[LayerProfile, ...]


def synthetic_images(n: int, hw: int, key: jax.Array, channels: int = 3) -> jax.Array:
    """Low-frequency random fields + noise, normalized to [0, 1]."""
    k1, k2 = jax.random.split(key)
    coarse = jax.random.uniform(k1, (n, 8, 8, channels))
    smooth = jax.image.resize(coarse, (n, hw, hw, channels), method="cubic")
    noisy = smooth + 0.08 * jax.random.normal(k2, (n, hw, hw, channels))
    lo = noisy.min(axis=(1, 2, 3), keepdims=True)
    hi = noisy.max(axis=(1, 2, 3), keepdims=True)
    return (noisy - lo) / (hi - lo + 1e-9)


def _quantize_u8(x: jax.Array) -> tuple[np.ndarray, float]:
    """Per-tensor uint8 quantization of a non-negative activation tensor."""
    scale = float(jnp.max(x)) / 255.0 + 1e-12
    q = np.asarray(jnp.clip(jnp.round(x / scale), 0, 255), dtype=np.uint8)
    return q, scale


def _im2col(x: jax.Array, layer: LayerSpec) -> jax.Array:
    """(N,H,W,C) -> (P, rows) patch matrix for this conv layer."""
    pad = "SAME" if layer.kernel > 1 else "VALID"
    patches = jax.lax.conv_general_dilated_patches(
        x,
        (layer.kernel, layer.kernel),
        (layer.stride, layer.stride),
        pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (N, H', W', C*k*k)
    rows = patches.shape[-1]
    assert rows == layer.rows, (rows, layer.rows, layer.name)
    return patches.reshape(-1, rows)


def _kaiming(key: jax.Array, rows: int, cout: int) -> jax.Array:
    return jax.random.normal(key, (rows, cout)) * np.sqrt(2.0 / rows)


def _bn_relu(y: jax.Array) -> jax.Array:
    mu = y.mean(axis=tuple(range(y.ndim - 1)), keepdims=True)
    sd = y.std(axis=tuple(range(y.ndim - 1)), keepdims=True) + 1e-5
    return jax.nn.relu((y - mu) / sd)


class _Profiler:
    """Runs a conv stack layer-by-layer, recording crossbar input stats."""

    def __init__(
        self,
        spec: NetworkSpec,
        key: jax.Array,
        sample_patches: int,
        array: ArrayConfig = DEFAULT_ARRAY,
    ):
        self.spec = spec
        self.array = array
        self.sample = sample_patches
        self.records: dict[int, LayerProfile] = {}
        keys = jax.random.split(key, len(spec.layers))
        self.weights = {
            i: _kaiming(keys[i], l.rows, l.cout) for i, l in enumerate(spec.layers)
        }
        self.rng = np.random.default_rng(0)

    def conv(self, idx: int, x: jax.Array) -> jax.Array:
        """Quantize -> record stats -> matmul -> reshape to (N,H',W',Cout)."""
        layer = self.spec.layers[idx]
        pat = _im2col(x, layer)  # (P, rows) float
        q, scale = _quantize_u8(jax.nn.relu(pat))
        self._record(idx, layer, q)
        y = (q.astype(np.float32) * scale) @ np.asarray(self.weights[idx])
        n = x.shape[0]
        return jnp.asarray(y).reshape(n, layer.out_hw, layer.out_hw, layer.cout)

    def _record(self, idx: int, layer: LayerSpec, q: np.ndarray) -> None:
        P = q.shape[0]
        take = min(self.sample, P)
        sel = self.rng.choice(P, size=take, replace=False)
        qs = q[sel]  # (S, rows)
        slices = layer.block_row_slices()
        dens, cyc_cols, base = [], [], []
        bits_full = np.unpackbits(q[..., None], axis=-1)  # (P, rows, 8)
        for sl in slices:
            rows_here = sl.stop - sl.start
            dens.append(bits_full[:, sl, :].mean())
            cyc_cols.append(zskip_cycles(qs[:, sl], self.array))
            base.append(baseline_cycles(rows_here, self.array))
        cyc = np.stack(cyc_cols, axis=-1)  # (S, B)
        self.records[idx] = LayerProfile(
            name=layer.name,
            block_density=np.asarray(dens),
            mean_cycles=cyc.mean(axis=0),
            cycles_sample=cyc,
            baseline_block_cycles=np.asarray(base, dtype=np.int64),
            patches_per_image=layer.patches_per_image,
        )


def _forward_resnet18(p: _Profiler, x: jax.Array) -> jax.Array:
    """ResNet18 topology over the 20-layer spec (residuals included)."""
    x = _bn_relu(p.conv(0, x))  # conv1
    # maxpool 3x3 s2 -> 56x56
    x = jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME"
    )
    idx = 1

    def basic(x, i, down_idx=None):
        h = _bn_relu(p.conv(i, x))
        h = p.conv(i + 1, h)
        sc = p.conv(down_idx, x) if down_idx is not None else x
        return jax.nn.relu(_bn_relu(h) + sc)

    # layer1: idx 1..4
    x = basic(x, 1)
    x = basic(x, 3)
    # layer2: 5,6 + down 7; then 8,9
    x = basic(x, 5, down_idx=7)
    x = basic(x, 8)
    # layer3: 10,11 + 12; 13,14
    x = basic(x, 10, down_idx=12)
    x = basic(x, 13)
    # layer4: 15,16 + 17; 18,19
    x = basic(x, 15, down_idx=17)
    x = basic(x, 18)
    return x


def _forward_vgg11(p: _Profiler, x: jax.Array) -> jax.Array:
    pool_after = {0, 1, 3, 5, 7}
    for i in range(len(p.spec.layers)):
        x = _bn_relu(p.conv(i, x))
        if i in pool_after:
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
            )
    return x


def profile_network(
    spec: NetworkSpec,
    n_images: int = 2,
    image_hw: int | None = None,
    sample_patches: int = 256,
    seed: int = 0,
    array: ArrayConfig | None = None,
) -> NetworkProfile:
    key = jax.random.PRNGKey(seed)
    kimg, kw = jax.random.split(key)
    if image_hw is None:
        image_hw = 224 if spec.name == "resnet18" else 32
    if array is None:
        # derive from the spec so swept geometries (dse.with_array) profile
        # with the array they will run on, not the default
        configs = {l.array for l in spec.layers}
        if len(configs) != 1:
            raise ValueError(
                f"{spec.name} mixes {len(configs)} array configs; pass array= explicitly"
            )
        (array,) = configs
    x = synthetic_images(n_images, image_hw, kimg)
    prof = _Profiler(spec, kw, sample_patches, array=array)
    if spec.name == "resnet18":
        _forward_resnet18(prof, x)
    elif spec.name == "vgg11":
        _forward_vgg11(prof, x)
    else:
        raise ValueError(f"no forward plan for {spec.name}")
    layers = tuple(prof.records[i] for i in range(len(spec.layers)))
    return NetworkProfile(spec.name, layers)
