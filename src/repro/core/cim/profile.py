"""Input-statistics profiling (Section III-A, "profile the distribution of
'1's in the activations gathered from a large set of examples run on a GPU").

The profiler is split into two phases so that a geometry x ADC design sweep
pays the expensive part exactly once:

  * **capture** — one jit-compiled quantized forward per network
    (``capture_activations``).  The whole conv stack, including the in-graph
    uint8 quantization of every crossbar word-line input, runs as a single
    XLA computation per calibration batch: no per-layer host syncs, no
    geometry dependence.  Per layer we keep two geometry-independent
    sufficient statistics: the total '1'-bit count per lowered-matrix row
    over ALL patches and bit-planes (``rowbits``, drives exact per-block
    densities for any row slicing), and a fixed random sample of quantized
    patch rows (``sampled_q``, drives the per-(patch, block) cycle samples).
    Calibration images stream through in fixed-size batches at constant
    memory; quantization scales and BN statistics are per-batch under
    streaming (identical to the single-tensor path when ``n_images <=
    batch_images``).

  * **derive** — ``derive_profile`` turns one capture into a
    ``NetworkProfile`` for ANY ``ArrayConfig`` (block row-slicing, ADC
    precision, read width) without re-running the network.  Three engines
    produce bit-identical integer statistics: ``"reference"`` (the original
    per-block numpy loop, kept as the pinned-golden source), ``"vectorized"``
    (cumulative bit-plane sums, the CPU default), and ``"pallas"`` (the
    ``kernels.bitplane_profile`` popcount kernel; interpret-mode on CPU).

Inputs are synthetic-but-structured images (low-frequency random fields +
noise) — the distributional knobs the paper relies on (ReLU sparsity, per-
layer density spread) emerge from the network itself, not the dataset.  The
measured speedups are reported against our own profile in EXPERIMENTS.md.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .cost import (
    ArrayConfig,
    DEFAULT_ARRAY,
    baseline_cycles,
    zskip_cycles,
    zskip_cycles_from_ones,
)
from .network import NetworkSpec, LayerSpec, with_array

__all__ = [
    "LayerProfile",
    "NetworkProfile",
    "LayerCapture",
    "ActivationCapture",
    "PROFILE_ENGINES",
    "capture_activations",
    "derive_profile",
    "profile_network",
    "synthetic_images",
]

PROFILE_ENGINES = ("reference", "vectorized", "pallas")
_FORWARD_PLANS = ("resnet18", "vgg11")


@dataclass(frozen=True)
class LayerProfile:
    name: str
    block_density: np.ndarray  # (B,) mean '1'-bit density per block
    mean_cycles: np.ndarray  # (B,) E[zskip cycles] per block per patch
    cycles_sample: np.ndarray  # (S, B) sampled per-patch per-block cycles
    baseline_block_cycles: np.ndarray  # (B,) constant cycles without zskip
    patches_per_image: int

    @property
    def density(self) -> float:
        return float(self.block_density.mean())


@dataclass(frozen=True)
class NetworkProfile:
    network: str
    layers: tuple[LayerProfile, ...]


@dataclass(frozen=True)
class LayerCapture:
    """Geometry-independent word-line input statistics for one layer."""

    name: str
    rowbits: np.ndarray  # (rows,) int64 — '1' bits per matrix row, all patches x planes
    sampled_q: np.ndarray  # (take, rows) uint8 — rng-sampled quantized patches
    n_patches: int  # P: total patches the rowbits cover
    patches_per_image: int


@dataclass(frozen=True)
class ActivationCapture:
    """One quantized forward's worth of profiling state.  Derives a
    ``NetworkProfile`` for any array geometry via ``derive_profile``."""

    network: str
    n_images: int
    sample_patches: int
    seed: int
    layers: tuple[LayerCapture, ...]


def synthetic_images(n: int, hw: int, key: jax.Array, channels: int = 3) -> jax.Array:
    """Low-frequency random fields + noise, normalized to [0, 1]."""
    k1, k2 = jax.random.split(key)
    coarse = jax.random.uniform(k1, (n, 8, 8, channels))
    smooth = jax.image.resize(coarse, (n, hw, hw, channels), method="cubic")
    noisy = smooth + 0.08 * jax.random.normal(k2, (n, hw, hw, channels))
    lo = noisy.min(axis=(1, 2, 3), keepdims=True)
    hi = noisy.max(axis=(1, 2, 3), keepdims=True)
    return (noisy - lo) / (hi - lo + 1e-9)


def _im2col(x: jax.Array, layer: LayerSpec) -> jax.Array:
    """(N,H,W,C) -> (P, rows) patch matrix for this conv layer."""
    pad = "SAME" if layer.kernel > 1 else "VALID"
    patches = jax.lax.conv_general_dilated_patches(
        x,
        (layer.kernel, layer.kernel),
        (layer.stride, layer.stride),
        pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (N, H', W', C*k*k)
    rows = patches.shape[-1]
    assert rows == layer.rows, (rows, layer.rows, layer.name)
    return patches.reshape(-1, rows)


def _kaiming(key: jax.Array, rows: int, cout: int) -> jax.Array:
    return jax.random.normal(key, (rows, cout)) * np.sqrt(2.0 / rows)


def _bn_relu(y: jax.Array) -> jax.Array:
    mu = y.mean(axis=tuple(range(y.ndim - 1)), keepdims=True)
    sd = y.std(axis=tuple(range(y.ndim - 1)), keepdims=True) + 1e-5
    return jax.nn.relu((y - mu) / sd)


class _CaptureTracer:
    """Plays a conv stack inside one jit trace, recording crossbar input
    statistics at every layer.  ``sel`` holds per-layer patch indices (already
    batch-local and clipped) whose quantized rows are gathered for the cycle
    sample."""

    def __init__(
        self,
        spec: NetworkSpec,
        weights: tuple[jax.Array, ...],
        sel: tuple[jax.Array, ...],
    ):
        self.spec = spec
        self.weights = weights
        self.sel = sel
        self.rowbits: list = [None] * len(spec.layers)
        self.sampled: list = [None] * len(spec.layers)

    def conv(self, idx: int, x: jax.Array) -> jax.Array:
        """Quantize in-graph -> record stats -> matmul -> (N,H',W',Cout)."""
        layer = self.spec.layers[idx]
        pat = jax.nn.relu(_im2col(x, layer))  # (P, rows) float32, >= 0
        # per-tensor uint8 quantization: the scale is computed in float64
        # (this traces under enable_x64) and applied in float32 — the same
        # arithmetic the host-side `float(jnp.max(x))` path performed
        scale = jnp.max(pat).astype(jnp.float64) / 255.0 + 1e-12
        s32 = scale.astype(jnp.float32)
        q = jnp.clip(jnp.round(pat / s32), 0, 255).astype(jnp.uint8)
        # per-row popcount over all patches and planes, one plane at a time
        # (a fori_loop keeps the graph small — 8 unrolled reductions per
        # layer dominate XLA compile time — and each (P, rows) bit
        # extraction fuses into its reduction, so the (P, rows, 8) bit
        # tensor never materializes; integer sums are order-independent)
        self.rowbits[idx] = jax.lax.fori_loop(
            0,
            8,
            lambda p, rb: rb + jnp.sum((q >> (7 - p)) & 1, axis=0, dtype=jnp.int64),
            jnp.zeros((layer.rows,), jnp.int64),
        )
        self.sampled[idx] = jnp.take(q, self.sel[idx], axis=0)
        y = (q.astype(jnp.float32) * s32) @ self.weights[idx]
        n = x.shape[0]
        return y.reshape(n, layer.out_hw, layer.out_hw, layer.cout)


def _forward_resnet18(p, x: jax.Array) -> jax.Array:
    """ResNet18 topology over the 20-layer spec (residuals included)."""
    x = _bn_relu(p.conv(0, x))  # conv1
    # maxpool 3x3 s2 -> 56x56
    x = jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME"
    )

    def basic(x, i, down_idx=None):
        h = _bn_relu(p.conv(i, x))
        h = p.conv(i + 1, h)
        sc = p.conv(down_idx, x) if down_idx is not None else x
        return jax.nn.relu(_bn_relu(h) + sc)

    # layer1: idx 1..4
    x = basic(x, 1)
    x = basic(x, 3)
    # layer2: 5,6 + down 7; then 8,9
    x = basic(x, 5, down_idx=7)
    x = basic(x, 8)
    # layer3: 10,11 + 12; 13,14
    x = basic(x, 10, down_idx=12)
    x = basic(x, 13)
    # layer4: 15,16 + 17; 18,19
    x = basic(x, 15, down_idx=17)
    x = basic(x, 18)
    return x


def _forward_vgg11(p, x: jax.Array) -> jax.Array:
    pool_after = {0, 1, 3, 5, 7}
    for i in range(len(p.spec.layers)):
        x = _bn_relu(p.conv(i, x))
        if i in pool_after:
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
            )
    return x


def _run_capture(spec, weights, sel, x):
    tr = _CaptureTracer(spec, weights, sel)
    if spec.name == "resnet18":
        _forward_resnet18(tr, x)
    elif spec.name == "vgg11":
        _forward_vgg11(tr, x)
    else:  # pragma: no cover — capture_activations validates upfront
        raise ValueError(f"no forward plan for {spec.name}")
    return tuple(tr.rowbits), tuple(tr.sampled)


_capture_jit = jax.jit(_run_capture, static_argnums=0)


def capture_activations(
    spec: NetworkSpec,
    n_images: int = 2,
    image_hw: int | None = None,
    sample_patches: int = 256,
    seed: int = 0,
    batch_images: int | None = 8,
) -> ActivationCapture:
    """Run the quantized calibration forward once; keep geometry-independent
    statistics.  ``batch_images`` bounds device memory: images stream through
    the jit forward in fixed-size slices (``None`` = one batch)."""
    if spec.name not in _FORWARD_PLANS:
        raise ValueError(f"no forward plan for {spec.name}")
    # the forward never reads the array geometry (layer rows/strides/channels
    # only), but ``spec`` is the jit static argument — canonicalize it so
    # every ArrayConfig variant of a network shares one compiled forward
    spec = with_array(spec, DEFAULT_ARRAY)
    key = jax.random.PRNGKey(seed)
    kimg, kw = jax.random.split(key)
    if image_hw is None:
        image_hw = 224 if spec.name == "resnet18" else 32
    keys = jax.random.split(kw, len(spec.layers))
    weights = tuple(
        _kaiming(keys[i], l.rows, l.cout) for i, l in enumerate(spec.layers)
    )
    x = synthetic_images(n_images, image_hw, kimg)

    # sample patch indices over the FULL calibration run, one rng stream in
    # layer order (the legacy profiler's exact draw sequence)
    rng = np.random.default_rng(0)
    sel_global, takes = [], []
    for layer in spec.layers:
        P = n_images * layer.patches_per_image
        take = min(sample_patches, P)
        sel_global.append(rng.choice(P, size=take, replace=False))
        takes.append(take)

    L = len(spec.layers)
    rowbits = [np.zeros(l.rows, dtype=np.int64) for l in spec.layers]
    sampled = [
        np.zeros((t, l.rows), dtype=np.uint8) for t, l in zip(takes, spec.layers)
    ]
    batch = n_images if batch_images is None else max(1, min(batch_images, n_images))
    from jax.experimental import enable_x64

    for i0 in range(0, n_images, batch):
        i1 = min(i0 + batch, n_images)
        nb = i1 - i0
        sel_local, owned = [], []
        for layer, sg in zip(spec.layers, sel_global):
            off = i0 * layer.patches_per_image
            pb = nb * layer.patches_per_image
            loc = sg - off
            owned.append((loc >= 0) & (loc < pb))
            sel_local.append(jnp.asarray(np.clip(loc, 0, pb - 1).astype(np.int32)))
        with enable_x64():
            rb, qs = _capture_jit(spec, weights, tuple(sel_local), x[i0:i1])
        for li in range(L):
            rowbits[li] += np.asarray(rb[li])
            m = owned[li]
            if m.any():
                sampled[li][m] = np.asarray(qs[li])[m]

    layers = tuple(
        LayerCapture(
            name=l.name,
            rowbits=rowbits[i],
            sampled_q=sampled[i],
            n_patches=n_images * l.patches_per_image,
            patches_per_image=l.patches_per_image,
        )
        for i, l in enumerate(spec.layers)
    )
    return ActivationCapture(spec.name, n_images, sample_patches, seed, layers)


def _resolve_array(spec: NetworkSpec, array: ArrayConfig | None) -> ArrayConfig:
    if array is not None:
        return array
    # derive from the spec so swept geometries (dse.with_array) profile
    # with the array they will run on, not the default
    configs = {l.array for l in spec.layers}
    if len(configs) != 1:
        raise ValueError(
            f"{spec.name} mixes {len(configs)} array configs; pass array= explicitly"
        )
    (array,) = configs
    return array


def _slice_bounds(layer: LayerSpec) -> tuple[np.ndarray, np.ndarray]:
    slices = layer.block_row_slices()
    starts = np.asarray([sl.start for sl in slices])
    stops = np.asarray([sl.stop for sl in slices])
    return starts, stops


def _block_density(cap: LayerCapture, starts, stops) -> np.ndarray:
    """Exact per-block mean '1'-bit density over ALL captured patches —
    integer bit counts divided by exact float64 counts, so it reproduces
    ``np.unpackbits(...).mean()`` over the full patch matrix bit for bit."""
    rbz = np.concatenate([[0], np.cumsum(cap.rowbits)])
    counts = cap.n_patches * (stops - starts) * 8.0
    return (rbz[stops] - rbz[starts]) / counts


def _derive_layer_reference(
    cap: LayerCapture, layer: LayerSpec, array: ArrayConfig
) -> LayerProfile:
    """The original scalar numpy derivation, one python-loop pass per block
    slice — the math the golden profile fixtures pin."""
    dens, cyc_cols, base = [], [], []
    for sl in layer.block_row_slices():
        rows_here = sl.stop - sl.start
        dens.append(int(cap.rowbits[sl].sum()) / (cap.n_patches * rows_here * 8))
        cyc_cols.append(zskip_cycles(cap.sampled_q[:, sl], array))
        base.append(baseline_cycles(rows_here, array))
    cyc = np.stack(cyc_cols, axis=-1)  # (S, B)
    return LayerProfile(
        name=layer.name,
        block_density=np.asarray(dens),
        mean_cycles=cyc.mean(axis=0),
        cycles_sample=cyc,
        baseline_block_cycles=np.asarray(base, dtype=np.int64),
        patches_per_image=layer.patches_per_image,
    )


def _derive_layer_vectorized(
    cap: LayerCapture, layer: LayerSpec, array: ArrayConfig
) -> LayerProfile:
    """One segmented-reduction pass over the sampled bit-planes; every
    geometry's per-block '1' counts are row-range sums of the same bits.
    ``block_row_slices`` tiles [0, rows) contiguously, so the block starts
    are exactly ``np.add.reduceat`` boundaries."""
    starts, stops = _slice_bounds(layer)
    bits = np.unpackbits(cap.sampled_q[..., None], axis=-1)  # (S, rows, 8)
    ones = np.add.reduceat(bits.astype(np.int32), starts, axis=1)  # (S, B, 8)
    cyc = zskip_cycles_from_ones(ones.astype(np.int64), array)  # (S, B) int64
    return LayerProfile(
        name=layer.name,
        block_density=_block_density(cap, starts, stops),
        mean_cycles=cyc.mean(axis=0),
        cycles_sample=cyc,
        baseline_block_cycles=baseline_cycles(stops - starts, array).astype(np.int64),
        patches_per_image=layer.patches_per_image,
    )


def _derive_layer_pallas(
    cap: LayerCapture, layer: LayerSpec, array: ArrayConfig
) -> LayerProfile:
    """Cycle samples via the Pallas bit-plane popcount kernel
    (``kernels.bitplane_profile``; interpret-mode off-TPU)."""
    from ...kernels.bitplane_profile import bitplane_profile
    from ...kernels.ops import interpret_mode

    starts, stops = _slice_bounds(layer)
    _, cyc = bitplane_profile(
        cap.sampled_q,
        block_rows=layer.array.rows,
        rows_per_read=array.rows_per_read,
        cycles_per_read=array.cycles_per_read,
        interpret=interpret_mode(),
    )
    cyc = np.asarray(cyc).astype(np.int64)
    return LayerProfile(
        name=layer.name,
        block_density=_block_density(cap, starts, stops),
        mean_cycles=cyc.mean(axis=0),
        cycles_sample=cyc,
        baseline_block_cycles=baseline_cycles(stops - starts, array).astype(np.int64),
        patches_per_image=layer.patches_per_image,
    )


_DERIVE = {
    "reference": _derive_layer_reference,
    "vectorized": _derive_layer_vectorized,
    "pallas": _derive_layer_pallas,
}


def derive_profile(
    capture: ActivationCapture,
    spec: NetworkSpec,
    array: ArrayConfig | None = None,
    engine: str = "vectorized",
) -> NetworkProfile:
    """A ``NetworkProfile`` for ``spec``'s geometry from one capture — the
    cheap phase of a geometry x ADC sweep.  All engines are bit-identical."""
    if engine not in PROFILE_ENGINES:
        raise ValueError(f"engine must be one of {PROFILE_ENGINES}, got {engine!r}")
    if spec.name != capture.network:
        raise ValueError(
            f"capture is for {capture.network!r}, spec is {spec.name!r}"
        )
    array = _resolve_array(spec, array)
    derive = _DERIVE[engine]
    layers = tuple(
        derive(cap, layer, array) for cap, layer in zip(capture.layers, spec.layers)
    )
    return NetworkProfile(spec.name, layers)


def profile_network(
    spec: NetworkSpec,
    n_images: int = 2,
    image_hw: int | None = None,
    sample_patches: int = 256,
    seed: int = 0,
    array: ArrayConfig | None = None,
    engine: str = "vectorized",
    batch_images: int | None = 8,
) -> NetworkProfile:
    """One-shot capture + derive.  For many geometries over one network, use
    ``capture_activations`` once and ``derive_profile`` per geometry (what
    ``dse.get_profiled`` does behind its split cache)."""
    array = _resolve_array(spec, array)
    cap = capture_activations(
        spec,
        n_images=n_images,
        image_hw=image_hw,
        sample_patches=sample_patches,
        seed=seed,
        batch_images=batch_images,
    )
    return derive_profile(cap, spec, array=array, engine=engine)
