"""Faithful reproduction of the paper's CIM evaluation stack."""

from .cost import (
    ArrayConfig,
    DEFAULT_ARRAY,
    baseline_cycles,
    bitplane_ones,
    expected_cycles_from_density,
    zskip_cycles,
    zskip_cycles_from_ones,
)
from .network import LayerSpec, NetworkSpec, resnet18_imagenet, vgg11_cifar10, with_array
from .profile import NetworkProfile, LayerProfile, profile_network, synthetic_images
from .simulate import (
    POLICIES,
    Allocation,
    BatchSimResult,
    BatchSimulator,
    SimResult,
    SimTensors,
    allocate,
    blockwise_units,
    pack_profile,
    run_policy,
    simulate,
    split_block_dups,
)

__all__ = [
    "ArrayConfig",
    "DEFAULT_ARRAY",
    "baseline_cycles",
    "bitplane_ones",
    "expected_cycles_from_density",
    "zskip_cycles",
    "zskip_cycles_from_ones",
    "LayerSpec",
    "NetworkSpec",
    "resnet18_imagenet",
    "vgg11_cifar10",
    "with_array",
    "NetworkProfile",
    "LayerProfile",
    "profile_network",
    "synthetic_images",
    "POLICIES",
    "Allocation",
    "BatchSimResult",
    "BatchSimulator",
    "SimResult",
    "SimTensors",
    "allocate",
    "blockwise_units",
    "pack_profile",
    "run_policy",
    "simulate",
    "split_block_dups",
]
