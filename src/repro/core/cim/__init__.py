"""Faithful reproduction of the paper's CIM evaluation stack."""

from .cost import (
    ArrayConfig,
    DEFAULT_ARRAY,
    baseline_cycles,
    bitplane_ones,
    expected_cycles_from_density,
    zskip_cycles,
)
from .network import LayerSpec, NetworkSpec, resnet18_imagenet, vgg11_cifar10
from .profile import NetworkProfile, LayerProfile, profile_network, synthetic_images
from .simulate import (
    Allocation,
    SimResult,
    allocate,
    blockwise_units,
    run_policy,
    simulate,
    split_block_dups,
)

__all__ = [
    "ArrayConfig",
    "DEFAULT_ARRAY",
    "baseline_cycles",
    "bitplane_ones",
    "expected_cycles_from_density",
    "zskip_cycles",
    "LayerSpec",
    "NetworkSpec",
    "resnet18_imagenet",
    "vgg11_cifar10",
    "NetworkProfile",
    "LayerProfile",
    "profile_network",
    "synthetic_images",
    "Allocation",
    "SimResult",
    "allocate",
    "blockwise_units",
    "run_policy",
    "simulate",
    "split_block_dups",
]
