"""Bit-serial crossbar cost model (Section II / IV of the paper).

Hardware model (matching the paper's PE):
  * 128 x 128 binary eNVM cells per array.
  * 8-bit weights -> 8 adjacent cells/columns per logical weight, so one
    array holds a 128 x 16 logical weight tile.
  * 8-bit inputs are shifted in bit-serially, one bit-plane at a time
    (8 planes).
  * 3-bit ADC -> at most 2**3 = 8 rows can be summed per analog read.
  * One ADC per 8 columns, pitch-matched: each read occupies the column ADC
    pipeline for 8 cycles.

Zero-skipping: within a bit-plane only rows whose input bit is '1' must be
read, in groups of <= 8.  A plane with `ones` active rows costs
`max(1, ceil(ones / 8))` reads.  The baseline (no zero-skipping) always
reads all rows in groups of 8: `ceil(rows / 8)` reads per plane.

Total cycles = CYCLES_PER_READ * sum over planes of reads-per-plane, which
for a full 128-row array spans [8 * 8 * 1, 8 * 8 * 16] = [64, 1024] — exactly
the paper's stated range.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

__all__ = [
    "ArrayConfig",
    "bitplane_ones",
    "zskip_cycles",
    "zskip_cycles_from_ones",
    "baseline_cycles",
    "expected_cycles_from_density",
]


@dataclass(frozen=True)
class ArrayConfig:
    rows: int = 128
    cols: int = 128
    cell_bits: int = 1
    weight_bits: int = 8
    input_bits: int = 8
    adc_bits: int = 3
    adc_share: int = 8  # columns per ADC -> cycles per read
    # interconnect characteristics (consumed by core.cim.topology): latency
    # of one NoC hop between neighboring PEs, in fabric cycles, and the NoC
    # flit width in bytes (how many activation bytes move per hop-cycle).
    noc_hop_cycles: int = 2
    noc_flit_bytes: int = 16

    @property
    def rows_per_read(self) -> int:
        return 2**self.adc_bits

    @property
    def cycles_per_read(self) -> int:
        return self.adc_share

    @property
    def logical_cols(self) -> int:
        """8-bit weights per array row of columns."""
        return self.cols * self.cell_bits // self.weight_bits

    @property
    def act_bytes(self) -> int:
        """Bytes one quantized activation (word-line input) occupies on the
        interconnect — what a patch row costs to move between stages."""
        return -(-self.input_bits // 8)

    def min_cycles(self) -> int:
        return self.input_bits * 1 * self.cycles_per_read

    def max_cycles(self) -> int:
        reads = -(-self.rows // self.rows_per_read)
        return self.input_bits * reads * self.cycles_per_read

    def variant(self, **changes) -> "ArrayConfig":
        """A modified copy — the design-space sweep axis (e.g.
        ``DEFAULT_ARRAY.variant(adc_bits=2)`` or ``.variant(rows=256,
        cols=256)``)."""
        return replace(self, **changes)


DEFAULT_ARRAY = ArrayConfig()


def bitplane_ones(patches_u8, xp=np):
    """Count '1' bits per bit-plane for each patch row-slice.

    Args:
      patches_u8: uint8 array (..., rows) of quantized input values that are
        applied to the word lines of one crossbar array.
      xp: array module — ``numpy`` (default) or ``jax.numpy``; the jax path
        is trace-safe so the same code runs inside jit'd profiling kernels.

    Returns:
      int array (..., input_bits) — number of active rows per bit-plane,
      plane 0 = MSB (the ``np.unpackbits`` bit order).
    """
    if patches_u8.dtype != np.uint8:
        raise TypeError(f"expected uint8, got {patches_u8.dtype}")
    if xp is np:
        # unpackbits along a fresh trailing axis: (..., rows, 8); plane 0 = MSB.
        bits = np.unpackbits(patches_u8[..., None], axis=-1)
        return bits.sum(axis=-2, dtype=np.int64)
    # shift-and-mask popcount — jnp has no unpackbits; identical integers
    planes = [
        ((patches_u8 >> (7 - p)) & 1).sum(axis=-1, dtype=xp.int32)
        for p in range(8)
    ]
    return xp.stack(planes, axis=-1)


def zskip_cycles_from_ones(ones, cfg: ArrayConfig = DEFAULT_ARRAY, xp=np):
    """Cycles given per-bit-plane active-row counts (..., input_bits).

    Split out of ``zskip_cycles`` so ADC-precision sweeps can re-cost cached
    bit statistics without re-running the network forward pass.  Pure array
    algebra over ``xp`` — shared verbatim between the numpy profiler
    derivation and jax/Pallas paths.
    """
    reads = xp.maximum(1, -(-xp.asarray(ones) // cfg.rows_per_read))
    return cfg.cycles_per_read * reads.sum(axis=-1)


def zskip_cycles(patches_u8, cfg: ArrayConfig = DEFAULT_ARRAY, xp=np):
    """Cycles for one array to run a dot product against each input patch.

    patches_u8: (..., rows) uint8 — rows <= cfg.rows.
    Returns: (...) int cycles.
    """
    return zskip_cycles_from_ones(bitplane_ones(patches_u8, xp=xp), cfg, xp=xp)


def baseline_cycles(
    rows: int | np.ndarray, cfg: ArrayConfig = DEFAULT_ARRAY
) -> np.ndarray:
    """Cycles without zero-skipping: every row group is read, every plane."""
    reads_per_plane = -(-np.asarray(rows) // cfg.rows_per_read)
    return cfg.cycles_per_read * cfg.input_bits * reads_per_plane


def expected_cycles_from_density(
    density: np.ndarray, rows: int | np.ndarray, cfg: ArrayConfig = DEFAULT_ARRAY
) -> np.ndarray:
    """Analytic E[cycles] given a mean '1'-bit density (the paper's Fig 4 line).

    For density p and r rows, each plane has Binomial(r, p) active rows and
    costs ceil(ones / 8) reads; E[ceil(x/8)] ~= E[x]/8 + (8-1)/(2*8) for a
    smooth remainder distribution.  The result is linear in p above the
    1-read floor, matching the empirical linear relationship the paper
    reports between cycle time and the percentage of '1's.
    """
    density = np.asarray(density, dtype=np.float64)
    r = np.asarray(rows, dtype=np.float64)
    k = cfg.rows_per_read
    ceil_offset = (k - 1) / (2 * k)
    reads = np.maximum(1.0, r * density / k + ceil_offset)
    return cfg.cycles_per_read * cfg.input_bits * reads
