"""Hierarchical chip -> PE -> array resource tree + communication-aware
placement.

The paper's allocator treats the fabric as one flat pool of arrays, but its
own architecture (Fig. 2/6) is hierarchical: arrays group into PEs behind a
NoC, and scaling past one chip strings several such fabrics on inter-chip
links.  Once the fabric is tiled, *where* a replica sits matters: a stage
whose replicas live off the chip that produces its input pays a transfer
delay on every request crossing that dataflow edge (the dominant cost in
tiled CIM fabrics per the co-design literature).

This module defines the tree (``FabricTopology``), the cost model (derived
from ``ArrayConfig``: activation bytes from ``input_bits``, NoC hop latency
from ``noc_hop_cycles``/``noc_flit_bytes``, inter-chip links from
``link_gbps``), and the placement layer over the flat allocators:

  * ``allocate_placed`` — every policy of ``simulate.allocate`` run
    placement-aware: the greedy policies score each grant with the comm
    penalty of the chip it would land on (``greedy_allocate_placed``); the
    queueing policy folds the stage entry transfer into its delay score
    (``queueing_allocate(extra_delay=)``); the proportional policies keep
    their counts (proportional by definition) and place replicas greedily.
  * ``place_allocation`` — place an EXISTING flat ``Allocation`` (tenancy,
    drift re-allocation, externally computed replica vectors).
  * ``Placement.stage_transfer`` — per-request entry delay per stage, the
    single vector the fabric engines need (``FabricSim(placement=)`` /
    ``VirtualTimeFabric.run_batch(placements=)``).

Cost-model conventions (deliberate, and what makes the single-chip case the
zero-cost special case): movement *within* a chip is already paid for in the
profiled per-patch cycles (word-line drivers and the on-chip NoC overlap
with the bit-serial reads), so ``transfer_cycles(c, c, n) == 0`` and a
1-chip fabric reproduces the flat allocator and the flat fabric engines bit
for bit.  Chips sit on a linear chain; a transfer over ``h`` hops costs
``h * (head_latency + bytes / link_bytes_per_cycle)`` where the head
latency is the NoC traversal to reach the link (``noc_hop_cycles *
ceil(sqrt(pes_per_chip))`` hops at one flit per hop-cycle).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace

import numpy as np

from .cost import ArrayConfig, DEFAULT_ARRAY
from .network import LayerSpec, NetworkSpec
from .profile import NetworkProfile
from .simulate import (
    ARRAYS_PER_PE,
    CLOCK_HZ,
    Allocation,
    Policy,
    _layer_patch_cycles,
    _queueing_inputs,
    allocate,
    blockwise_units,
    simulate,
    split_block_dups,
)
from ..alloc.greedy import (
    greedy_allocate_placed,
    place_extras,
    proportional_allocate,
    queueing_allocate,
)

__all__ = [
    "FabricTopology",
    "Placement",
    "PlacedAllocation",
    "allocate_placed",
    "place_allocation",
    "request_bytes",
    "stage_transfer_matrix",
]


@dataclass(frozen=True)
class FabricTopology:
    """chip -> PE -> array resource tree with a link/NoC cost model.

    ``n_chips`` chips on a linear chain, each holding ``pes_per_chip`` PEs of
    ``arrays_per_pe`` crossbar arrays.  ``link_gbps`` is the bandwidth of one
    inter-chip link; per-hop head latency and activation byte counts derive
    from ``array`` (the same ``ArrayConfig`` the compute model uses, so a
    geometry sweep that changes the array automatically re-prices
    communication).  The host interface (input injection) attaches to chip 0.
    """

    pes_per_chip: int
    n_chips: int = 1
    arrays_per_pe: int = ARRAYS_PER_PE
    link_gbps: float = 64.0
    clock_hz: float = CLOCK_HZ
    array: ArrayConfig = DEFAULT_ARRAY

    def __post_init__(self):
        if self.n_chips < 1 or self.pes_per_chip < 1 or self.arrays_per_pe < 1:
            raise ValueError(
                f"degenerate topology: {self.n_chips} chips x "
                f"{self.pes_per_chip} PEs x {self.arrays_per_pe} arrays"
            )
        if self.link_gbps <= 0:
            raise ValueError(f"link_gbps must be positive, got {self.link_gbps}")

    # ------------------------------------------------------------ capacities
    @property
    def arrays_per_chip(self) -> int:
        return self.pes_per_chip * self.arrays_per_pe

    @property
    def total_pes(self) -> int:
        return self.n_chips * self.pes_per_chip

    @property
    def total_arrays(self) -> int:
        return self.n_chips * self.arrays_per_chip

    def spares_per_chip(self, spare_fraction: float) -> int:
        """Arrays to hold back as hot spares on EACH chip for fault
        tolerance: ``floor(arrays_per_chip * spare_fraction)``.  Spares are
        budgeted per chip, not fabric-wide, because a chip-correlated
        failure domain (``fabric.failures`` bursts) takes its own spares
        down with it — cross-chip spares are what survive."""
        if not 0.0 <= spare_fraction <= 1.0:
            raise ValueError(
                f"spare_fraction must be in [0, 1], got {spare_fraction}"
            )
        return int(self.arrays_per_chip * spare_fraction)

    # ------------------------------------------------------------ cost model
    @property
    def link_bytes_per_cycle(self) -> float:
        """Inter-chip link bandwidth in bytes per fabric clock cycle."""
        return self.link_gbps * 1e9 / 8.0 / self.clock_hz

    @property
    def hop_latency_cycles(self) -> float:
        """Head latency of one inter-chip hop: the NoC traversal from the
        producing PEs to the chip-edge link (diameter of a square PE mesh)."""
        return self.array.noc_hop_cycles * math.ceil(math.sqrt(self.pes_per_chip))

    def chip_hops(self, src: int, dst: int) -> int:
        return abs(int(src) - int(dst))

    def transfer_cycles(self, src: int, dst: int, nbytes: float) -> float:
        """Cycles to move ``nbytes`` of activations from chip ``src`` to chip
        ``dst``.  Zero on-chip (folded into the profiled compute cycles);
        store-and-forward per hop off-chip."""
        hops = self.chip_hops(src, dst)
        if hops == 0:
            return 0.0
        return hops * (self.hop_latency_cycles + nbytes / self.link_bytes_per_cycle)

    def transfer_matrix(self, src: int, nbytes: float) -> np.ndarray:
        """(n_chips,) transfer cycles from ``src`` to every chip."""
        return np.asarray(
            [self.transfer_cycles(src, k, nbytes) for k in range(self.n_chips)]
        )

    def variant(self, **changes) -> "FabricTopology":
        """A modified copy — the multi-chip design-space sweep axis (e.g.
        ``topo.variant(n_chips=4)`` or ``.variant(link_gbps=8.0)``)."""
        return replace(self, **changes)

    # --------------------------------------------------------- constructors
    @classmethod
    def single_chip(
        cls,
        n_pes: int,
        arrays_per_pe: int = ARRAYS_PER_PE,
        array: ArrayConfig = DEFAULT_ARRAY,
        clock_hz: float = CLOCK_HZ,
    ) -> "FabricTopology":
        """The degenerate one-chip tree: the flat pool the paper assumes.
        All transfers cost zero, so every placed result reproduces the flat
        allocator / fabric engines bit for bit."""
        return cls(
            pes_per_chip=int(n_pes),
            n_chips=1,
            arrays_per_pe=arrays_per_pe,
            array=array,
            clock_hz=clock_hz,
        )

    @classmethod
    def split(
        cls,
        n_chips: int,
        n_pes_total: int,
        arrays_per_pe: int = ARRAYS_PER_PE,
        link_gbps: float = 64.0,
        array: ArrayConfig = DEFAULT_ARRAY,
        clock_hz: float = CLOCK_HZ,
    ) -> "FabricTopology":
        """Partition a fixed PE budget over ``n_chips`` chips (the equal-
        silicon comparison the multi-chip sweep makes).  Requires the budget
        to divide evenly so every chip count compares the same total."""
        if n_pes_total % n_chips:
            raise ValueError(
                f"{n_pes_total} PEs do not split evenly over {n_chips} chips"
            )
        return cls(
            pes_per_chip=n_pes_total // n_chips,
            n_chips=n_chips,
            arrays_per_pe=arrays_per_pe,
            link_gbps=link_gbps,
            array=array,
            clock_hz=clock_hz,
        )


def request_bytes(layer: LayerSpec, array: ArrayConfig | None = None) -> float:
    """Activation bytes one request (image) carries INTO a layer: every
    patch applies its ``rows`` quantized inputs to the word lines."""
    a = layer.array if array is None else array
    return float(layer.patches_per_image) * layer.rows * a.act_bytes


@dataclass(frozen=True)
class Placement:
    """Replica -> location for one allocation on one topology.

    ``replica_chips``: per layer — block-wise allocations hold a tuple of
    (d_b,) int chip arrays (one per block, entry 0 = mandatory copy);
    layer-wise allocations hold a single (d_l,) array whose entry 0 stands
    for the mandatory grid and entries 1: are full-grid duplicates, each on
    one chip.  A mandatory grid can SPAN chips (first-fit may split it), so
    ``mandatory_chips`` records the true per-block home chips per layer —
    transfer and per-chip load accounting use it, never the single
    representative entry.  ``layer_src`` is the chip each stage's input is
    gathered from (host = chip 0 for stage 0, then the majority chip of the
    previous layer's mandatory arrays).  ``stage_transfer`` is the derived
    per-request entry delay per stage — the only thing the fabric engines
    consume.
    """

    topology: FabricTopology
    layer_src: np.ndarray  # (L,) int
    replica_chips: tuple  # per layer: tuple[np.ndarray, ...] | np.ndarray
    mandatory_chips: tuple  # per layer: (B_l,) int per-block home chips
    stage_transfer: np.ndarray  # (L,) float64 cycles
    chip_arrays: np.ndarray  # (K,) arrays occupied per chip

    @property
    def n_crossings(self) -> int:
        """Replica units parked off their stage's source chip — mandatory
        blocks plus extra replicas (blocks for block-wise, whole-grid
        duplicates for layer-wise); a data-movement footprint for reports."""
        total = 0
        for src, man, rc in zip(
            self.layer_src, self.mandatory_chips, self.replica_chips
        ):
            total += int((man != src).sum())
            extras = [a[1:] for a in rc] if isinstance(rc, tuple) else [rc[1:]]
            total += int(sum((a != src).sum() for a in extras))
        return total

    @property
    def max_stage_transfer(self) -> float:
        return float(self.stage_transfer.max()) if self.stage_transfer.size else 0.0


@dataclass(frozen=True)
class PlacedAllocation:
    """An ``Allocation`` plus where every replica lives."""

    allocation: Allocation
    placement: Placement


def stage_transfer_matrix(placements) -> np.ndarray:
    """Pack P placements' per-stage entry delays into one (P, L) float64
    matrix — the batchable placement axis the fused DSE pipeline feeds to
    the virtual-time kernel (one vmapped fabric call across placements
    instead of a Python loop over topologies)."""
    return np.ascontiguousarray(
        np.stack(
            [np.asarray(p.stage_transfer, dtype=np.float64) for p in placements]
        )
    )


# --------------------------------------------------------------- internals
def _mandatory_placement(
    spec: NetworkSpec, topo: FabricTopology, chip_free: np.ndarray | None = None
) -> tuple[list[np.ndarray], np.ndarray, np.ndarray]:
    """First-fit the mandatory copy of every block, in layer order.

    Returns (per-layer (B_l,) home-chip arrays, (L,) per-layer source chips,
    (K,) free arrays per chip after the mandatory copies).  Walking layers in
    order onto a chain of chips keeps adjacent stages co-located, which is
    what makes the dataflow edges cheap by default.  ``chip_free`` starts
    from partially-occupied chips (multi-tenant fabrics place tenants
    sequentially on one shared tree).
    """
    free = (
        np.full(topo.n_chips, float(topo.arrays_per_chip))
        if chip_free is None
        else np.asarray(chip_free, dtype=np.float64).copy()
    )
    homes: list[np.ndarray] = []
    for layer in spec.layers:
        w = float(layer.arrays_per_block)
        if w > topo.arrays_per_chip:
            raise ValueError(
                f"block of {layer.name} ({int(w)} arrays) exceeds one chip "
                f"({topo.arrays_per_chip} arrays)"
            )
        h = np.empty(layer.n_blocks, dtype=np.int64)
        for b in range(layer.n_blocks):
            fit = np.flatnonzero(free >= w)
            if fit.size == 0:
                raise ValueError(
                    f"topology ({topo.total_arrays} arrays over "
                    f"{topo.n_chips} chips) cannot hold the mandatory copy "
                    f"of {spec.name} ({spec.n_arrays} arrays)"
                )
            k = int(fit[0])
            free[k] -= w
            h[b] = k
        homes.append(h)
    src = np.zeros(len(spec.layers), dtype=np.int64)  # stage 0 feeds from host
    for i, layer in enumerate(spec.layers[:-1]):
        # the next stage's input is gathered where the bulk of this layer's
        # mandatory arrays sit (ties -> lowest chip id)
        src[i + 1] = _majority_chip(homes[i], layer, topo.n_chips)
    return homes, src, free


def _majority_chip(homes_i: np.ndarray, layer: LayerSpec, n_chips: int) -> int:
    """Chip holding the bulk of a layer's mandatory arrays (ties -> lowest
    id).  The ONE definition shared by the per-layer source-chip derivation
    and the layer-duplicate home — they must agree, or penalties would be
    measured from a different chip than replicas are charged to."""
    load = np.bincount(
        homes_i,
        weights=np.full(layer.n_blocks, layer.arrays_per_block),
        minlength=n_chips,
    )
    return int(np.argmax(load))


def _stage_transfer(
    spec: NetworkSpec,
    topo: FabricTopology,
    layer_src: np.ndarray,
    mandatory_chips,
    replica_chips,
) -> np.ndarray:
    """(L,) per-request entry delay: the worst replica's transfer on each
    stage's incoming dataflow edge (all jobs dispatch at stage entry, so the
    farthest replica gates readiness).  The mandatory copy is accounted by
    its TRUE per-block chips (first-fit may have split it across chips) —
    for layer-wise allocations ``replica_chips`` entry 0 is only a
    representative and is replaced by ``mandatory_chips`` here."""
    out = np.zeros(len(spec.layers))
    for i, layer in enumerate(spec.layers):
        nb = request_bytes(layer, topo.array)
        row = topo.transfer_matrix(int(layer_src[i]), nb)
        rc = replica_chips[i]
        worst = float(row[mandatory_chips[i]].max())
        extras = [a[1:] for a in rc] if isinstance(rc, tuple) else [rc[1:]]
        for a in extras:
            if a.size:
                worst = max(worst, float(row[a].max()))
        out[i] = worst
    return out


def _chip_arrays(
    spec: NetworkSpec, topo: FabricTopology, mandatory_chips, replica_chips
) -> np.ndarray:
    """(K,) arrays occupied per chip — mandatory blocks at their true homes
    plus extra replicas where they were placed (block replicas are
    ``arrays_per_block`` wide; layer-wise duplicates are whole grids)."""
    load = np.zeros(topo.n_chips)
    for layer, man, rc in zip(spec.layers, mandatory_chips, replica_chips):
        np.add.at(load, man, float(layer.arrays_per_block))
        if isinstance(rc, tuple):
            for a in rc:
                np.add.at(load, a[1:], float(layer.arrays_per_block))
        else:
            np.add.at(load, rc[1:], float(layer.n_arrays))
    return load


def _free_arrays(spec: NetworkSpec, topo: FabricTopology, free_budget) -> float:
    total = topo.total_arrays
    base = spec.n_arrays
    if total < base:
        raise ValueError(f"{total} arrays < minimum {base} for {spec.name}")
    free = total - base
    if free_budget is not None:
        if not 0 <= free_budget <= free:
            raise ValueError(
                f"free_budget {free_budget} outside [0, {free}] free arrays"
            )
        free = float(free_budget)
    return float(free)


def _layer_home_and_penalty(
    spec: NetworkSpec,
    topo: FabricTopology,
    homes: list[np.ndarray],
    src: np.ndarray,
) -> tuple[np.ndarray, np.ndarray]:
    """Per-LAYER (home chip, (L, K) penalty matrix) for layer-wise policies:
    a layer duplicate's home is the majority chip of its mandatory grid."""
    L = len(spec.layers)
    home = np.empty(L, dtype=np.int64)
    pen = np.zeros((L, topo.n_chips))
    for i, layer in enumerate(spec.layers):
        home[i] = _majority_chip(homes[i], layer, topo.n_chips)
        pen[i] = topo.transfer_matrix(int(src[i]), request_bytes(layer, topo.array))
    return home, pen


def _block_penalty(
    spec: NetworkSpec, topo: FabricTopology, src: np.ndarray
) -> np.ndarray:
    """(n_blocks, K) penalty matrix for the flat block units."""
    rows = []
    for i, layer in enumerate(spec.layers):
        row = topo.transfer_matrix(int(src[i]), request_bytes(layer, topo.array))
        rows.append(np.broadcast_to(row, (layer.n_blocks, topo.n_chips)))
    return np.concatenate(rows, axis=0)


def _stripe_extras(
    replicas: np.ndarray,
    cost: np.ndarray,
    home: np.ndarray,
    chip_free: np.ndarray,
) -> list[np.ndarray]:
    """Round-robin replica striping: the communication-blind baseline.
    Each extra replica goes to the next chip in rotation with space."""
    free = np.asarray(chip_free, dtype=np.float64).copy()
    K = free.size
    out: list[np.ndarray] = []
    ptr = 0
    for i in range(replicas.size):
        chips = [int(home[i])]
        for _ in range(int(replicas[i]) - 1):
            for off in range(K):
                k = (ptr + off) % K
                if free[k] >= cost[i]:
                    break
            else:
                raise ValueError(
                    f"no chip can hold another replica of unit {i} "
                    f"(cost {cost[i]}, free {free})"
                )
            free[k] -= cost[i]
            chips.append(k)
            ptr = (k + 1) % K
        out.append(np.asarray(chips, dtype=np.int64))
    return out


def _split_chips(spec: NetworkSpec, flat: list[np.ndarray]) -> tuple:
    """Flat per-block chip lists -> per-layer tuples (blockwise layout)."""
    out, k = [], 0
    for layer in spec.layers:
        out.append(tuple(flat[k : k + layer.n_blocks]))
        k += layer.n_blocks
    return tuple(out)


def _repack_or_keep(res, cost, *, home, pen, chip_free) -> list[np.ndarray]:
    """Final placement for counts granted by ``greedy_allocate_placed``.

    The dataflow-order re-pack (``place_extras``: chips fill along the chain
    as layers do) dominates grant-order interleaving on chain topologies,
    but it is a DIFFERENT first-fit order, so on a near-full fabric it can
    fail to pack counts the greedy's own grant-time assignment already
    proved placeable — in that case keep the greedy's certified chips.
    """
    try:
        return place_extras(
            res.replicas, cost, home_chip=home, unit_penalty=pen,
            chip_free=chip_free,
        )
    except ValueError:
        return res.replica_chips


# ------------------------------------------------------------------ public
def place_allocation(
    spec: NetworkSpec,
    alloc: Allocation,
    topo: FabricTopology,
    chip_free: np.ndarray | None = None,
    strategy: str = "locality",
) -> Placement:
    """Place an existing flat ``Allocation`` on a topology.

    Mandatory copies first-fit in layer order; extra replicas follow
    ``strategy``:

      * ``"locality"`` (default) — each replica goes to the affordable chip
        with the lowest transfer penalty on its stage's incoming dataflow
        edge (``place_extras``), in dataflow order.
      * ``"stripe"`` — replicas round-robin across chips (the
        communication-blind load/thermal-balancing default a flat-pool
        scheduler would pick); the baseline the locality placement is
        measured against.

    This is the placement path for allocations whose replica counts were
    chosen elsewhere — proportional policies, tenancy slices, drift
    re-allocations — and for evaluating a flat allocation "as if"
    serialized onto a multi-chip fabric.  ``chip_free`` starts from
    partially-occupied chips (sequential tenant placement on one shared
    tree); subtract the returned ``chip_arrays`` to chain the next tenant.
    """
    if strategy not in ("locality", "stripe"):
        raise ValueError(f"strategy must be 'locality' or 'stripe', got {strategy!r}")
    homes, src, free = _mandatory_placement(spec, topo, chip_free)
    if alloc.layer_dups is not None:
        home, pen = _layer_home_and_penalty(spec, topo, homes, src)
        cost = np.array([l.n_arrays for l in spec.layers], dtype=np.float64)
        reps = np.asarray(alloc.layer_dups, dtype=np.int64)
    else:
        table = spec.block_table()
        cost = table[:, 2].astype(np.float64)
        reps = np.concatenate([np.asarray(d) for d in alloc.block_dups]).astype(
            np.int64
        )
        home = np.concatenate(homes)
        pen = _block_penalty(spec, topo, src)
    if strategy == "stripe":
        chips = _stripe_extras(reps, cost, home, free)
    else:
        chips = place_extras(
            reps, cost, home_chip=home, unit_penalty=pen, chip_free=free
        )
    replica_chips = (
        tuple(chips) if alloc.layer_dups is not None else _split_chips(spec, chips)
    )
    return Placement(
        topology=topo,
        layer_src=src,
        replica_chips=replica_chips,
        mandatory_chips=tuple(homes),
        stage_transfer=_stage_transfer(spec, topo, src, homes, replica_chips),
        chip_arrays=_chip_arrays(spec, topo, homes, replica_chips),
    )


def allocate_placed(
    spec: NetworkSpec,
    prof: NetworkProfile,
    policy: Policy,
    topo: FabricTopology,
    free_budget: float | None = None,
    offered_ips: float | None = None,
    load_frac: float = 0.7,
    audit=None,
) -> PlacedAllocation:
    """``simulate.allocate`` lifted from "replica counts in a flat pool" to
    "placement on the resource tree".

    ``audit`` (a ``repro.obs.AllocationAudit``) records the placed greedy's
    per-grant decision log — including the chip each replica landed on —
    for the greedy policies (``perf_layerwise`` / ``blockwise``).

    Policy-for-policy mirror of the flat allocator, with moves scored by a
    communication penalty on the dataflow edges:

      * ``perf_layerwise`` / ``blockwise`` run the comm-aware greedy
        (``greedy_allocate_placed``): the heap ranks units by effective
        latency = drain latency + worst-replica transfer, and each grant
        lands on the chip that least raises that transfer.
      * ``latency_aware`` folds the stage entry transfer into the queueing
        score (``extra_delay``), then places the chosen counts.
      * proportional policies (``baseline`` / ``weight_based`` /
        ``weight_blockflow``) keep their counts — proportional by
        definition — and place replicas penalty-greedily.

    On a 1-chip topology every penalty is zero and each policy reproduces
    the flat ``allocate`` replica-for-replica, bit for bit (pinned against
    the pre-refactor golden fixtures).
    """
    free = _free_arrays(spec, topo, free_budget)
    homes, src, chip_free = _mandatory_placement(spec, topo)
    L = len(spec.layers)
    zskip = policy != "baseline"
    cyc = _layer_patch_cycles(prof, zskip)
    ppi = np.array([l.patches_per_image for l in spec.layers], dtype=np.float64)
    layer_arrays = np.array([l.n_arrays for l in spec.layers], dtype=np.float64)
    base_arrays = spec.n_arrays
    total = topo.total_arrays

    if policy in ("baseline", "weight_based", "weight_blockflow"):
        macs = np.array([l.macs_per_image for l in spec.layers], dtype=np.float64)
        res = proportional_allocate(macs, layer_arrays, free)
        used = int(base_arrays + (res.replicas - 1) @ layer_arrays)
        home, pen = _layer_home_and_penalty(spec, topo, homes, src)
        if policy == "weight_blockflow":
            block_dups = [
                np.full(l.n_blocks, res.replicas[i], dtype=np.int64)
                for i, l in enumerate(spec.layers)
            ]
            table = spec.block_table()
            chips = place_extras(
                np.concatenate(block_dups), table[:, 2].astype(np.float64),
                home_chip=np.concatenate(homes),
                unit_penalty=_block_penalty(spec, topo, src),
                chip_free=chip_free,
            )
            alloc = Allocation(policy, None, block_dups, used, total)
            replica_chips = _split_chips(spec, chips)
        else:
            chips = place_extras(
                res.replicas, layer_arrays,
                home_chip=home, unit_penalty=pen, chip_free=chip_free,
            )
            alloc = Allocation(policy, res.replicas, None, used, total)
            replica_chips = tuple(chips)

    elif policy == "perf_layerwise":
        exp_lat = np.array([cyc[i].max(axis=1).mean() * ppi[i] for i in range(L)])
        home, pen = _layer_home_and_penalty(spec, topo, homes, src)
        res = greedy_allocate_placed(
            exp_lat, layer_arrays, free,
            home_chip=home, unit_penalty=pen, chip_free=chip_free,
            audit=audit,
        )
        used = int(base_arrays + (res.replicas - 1) @ layer_arrays)
        alloc = Allocation(policy, res.replicas, None, used, total)
        replica_chips = tuple(
            _repack_or_keep(
                res, layer_arrays, home=home, pen=pen, chip_free=chip_free
            )
        )

    elif policy == "blockwise":
        base_lat, cost = blockwise_units(spec, [cyc[i].mean(axis=0) for i in range(L)])
        pen_blocks = _block_penalty(spec, topo, src)
        home_flat = np.concatenate(homes)
        res = greedy_allocate_placed(
            base_lat, cost, free,
            home_chip=home_flat, unit_penalty=pen_blocks, chip_free=chip_free,
            audit=audit,
        )
        used = int(base_arrays + ((res.replicas - 1) * cost).sum())
        alloc = Allocation(
            policy, None, split_block_dups(spec, res.replicas), used, total
        )
        replica_chips = _split_chips(
            spec,
            _repack_or_keep(
                res, cost, home=home_flat, pen=pen_blocks, chip_free=chip_free
            ),
        )

    elif policy == "latency_aware":
        if offered_ips is None:
            bw = allocate(
                spec, prof, "blockwise", topo.total_pes, topo.arrays_per_pe,
                free_budget,
            )
            offered_ips = load_frac * simulate(spec, prof, bw).images_per_sec
        if offered_ips <= 0:
            raise ValueError(f"offered_ips must be positive, got {offered_ips}")
        r_cyc = float(offered_ips) / CLOCK_HZ
        pen_blocks = _block_penalty(spec, topo, src)
        home_flat = np.concatenate(homes)
        job_rate, mean, scv, cost, batch, group = _queueing_inputs(
            spec, cyc, r_cyc
        )
        # the stage's unavoidable entry transfer at the mandatory placement;
        # None (not zeros) on a single chip so the flat scoring path is
        # genuinely untouched
        home_pen = pen_blocks[np.arange(home_flat.size), home_flat]
        res = queueing_allocate(
            job_rate, mean, scv, cost, free,
            batch_size=batch, group=group,
            extra_delay=home_pen if np.any(home_pen) else None,
        )
        used = int(base_arrays + ((res.replicas - 1) * cost).sum())
        chips = place_extras(
            res.replicas, cost,
            home_chip=home_flat, unit_penalty=pen_blocks, chip_free=chip_free,
        )
        alloc = Allocation(
            policy, None, split_block_dups(spec, res.replicas), used, total
        )
        replica_chips = _split_chips(spec, chips)

    else:
        raise ValueError(policy)

    placement = Placement(
        topology=topo,
        layer_src=src,
        replica_chips=replica_chips,
        mandatory_chips=tuple(homes),
        stage_transfer=_stage_transfer(spec, topo, src, homes, replica_chips),
        chip_arrays=_chip_arrays(spec, topo, homes, replica_chips),
    )
    return PlacedAllocation(alloc, placement)
