"""DNN -> crossbar mapping (Section III / Figure 5 of the paper).

Every conv/fc layer is lowered to a matrix of shape
  (rows = k*k*Cin, logical_cols = Cout)
and tiled over 128x128 binary arrays: 8 cells per 8-bit weight means an array
holds a 128-row x 16-weight tile.  One tile-row — the arrays that share word
lines and therefore input data — is the paper's *block*, the minimal
deterministic compute unit.

ResNet18 (ImageNet) lowers to 20 conv layers = 5472 arrays in 247 blocks,
matching the counts quoted in the paper (Fig 5 shows layer 10: a
3x3x128x128 filter -> 72 arrays in a 9x8 grid); we assert this in tests.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import numpy as np

from .cost import ArrayConfig, DEFAULT_ARRAY

__all__ = [
    "LayerSpec",
    "NetworkSpec",
    "resnet18_imagenet",
    "vgg11_cifar10",
    "with_array",
]


@dataclass(frozen=True)
class LayerSpec:
    """One conv/fc layer lowered to a crossbar matrix."""

    name: str
    kernel: int
    cin: int
    cout: int
    out_hw: int  # output spatial size (H == W); 1 for fc
    stride: int = 1
    array: ArrayConfig = field(default=DEFAULT_ARRAY)

    @property
    def rows(self) -> int:
        return self.kernel * self.kernel * self.cin

    @property
    def n_blocks(self) -> int:
        """Tile-rows: ceil(rows / array rows)."""
        return -(-self.rows // self.array.rows)

    @property
    def arrays_per_block(self) -> int:
        """Tile width: ceil(cout / logical weights per array)."""
        return -(-self.cout // self.array.logical_cols)

    @property
    def n_arrays(self) -> int:
        return self.n_blocks * self.arrays_per_block

    @property
    def patches_per_image(self) -> int:
        return self.out_hw * self.out_hw

    @property
    def macs_per_image(self) -> int:
        return self.patches_per_image * self.rows * self.cout

    def block_row_slices(self) -> list[slice]:
        """Row ranges of the lowered matrix feeding each block."""
        r = self.array.rows
        return [slice(i * r, min((i + 1) * r, self.rows)) for i in range(self.n_blocks)]


@dataclass(frozen=True)
class NetworkSpec:
    name: str
    layers: tuple[LayerSpec, ...]

    @property
    def n_arrays(self) -> int:
        return sum(l.n_arrays for l in self.layers)

    @property
    def n_blocks(self) -> int:
        return sum(l.n_blocks for l in self.layers)

    def min_pes(self, arrays_per_pe: int = 64) -> int:
        return -(-self.n_arrays // arrays_per_pe)

    def block_table(self) -> "np.ndarray":
        """(n_blocks, 3) int table: [layer_index, block_index_in_layer, width]."""
        out = []
        for li, layer in enumerate(self.layers):
            for bi in range(layer.n_blocks):
                out.append((li, bi, layer.arrays_per_block))
        return np.asarray(out, dtype=np.int64)


def with_array(spec: NetworkSpec, array: ArrayConfig) -> NetworkSpec:
    """Retarget a network onto a different crossbar geometry / ADC config.

    The lowered matrix shapes are unchanged; tiling (blocks, arrays per
    block) re-derives from the new array.  This is the geometry axis of the
    design-space sweep (``repro.dse``).
    """
    return NetworkSpec(spec.name, tuple(replace(l, array=array) for l in spec.layers))


def resnet18_imagenet() -> NetworkSpec:
    """The 20 convolutional layers of ResNet18 at 224x224 (paper's workload).

    The final fc layer is excluded, matching the paper's 5472-array /
    247-block accounting.
    """
    layers: list[LayerSpec] = []

    def conv(name, k, cin, cout, out_hw, stride=1):
        layers.append(LayerSpec(name, k, cin, cout, out_hw, stride))

    conv("conv1", 7, 3, 64, 112, 2)
    # layer1: two basic blocks, 64ch, 56x56
    for b in range(2):
        conv(f"layer1.{b}.conv1", 3, 64, 64, 56)
        conv(f"layer1.{b}.conv2", 3, 64, 64, 56)
    # layer2: 128ch, 28x28, downsample on block 0
    conv("layer2.0.conv1", 3, 64, 128, 28, 2)
    conv("layer2.0.conv2", 3, 128, 128, 28)
    conv("layer2.0.down", 1, 64, 128, 28, 2)
    conv("layer2.1.conv1", 3, 128, 128, 28)
    conv("layer2.1.conv2", 3, 128, 128, 28)
    # layer3: 256ch, 14x14
    conv("layer3.0.conv1", 3, 128, 256, 14, 2)
    conv("layer3.0.conv2", 3, 256, 256, 14)
    conv("layer3.0.down", 1, 128, 256, 14, 2)
    conv("layer3.1.conv1", 3, 256, 256, 14)
    conv("layer3.1.conv2", 3, 256, 256, 14)
    # layer4: 512ch, 7x7
    conv("layer4.0.conv1", 3, 256, 512, 7, 2)
    conv("layer4.0.conv2", 3, 512, 512, 7)
    conv("layer4.0.down", 1, 256, 512, 7, 2)
    conv("layer4.1.conv1", 3, 512, 512, 7)
    conv("layer4.1.conv2", 3, 512, 512, 7)
    return NetworkSpec("resnet18", tuple(layers))


def vgg11_cifar10() -> NetworkSpec:
    """The 8 convolutional layers of VGG11 at 32x32 (paper's second workload)."""
    cfg = [
        # (cin, cout, out_hw) — maxpool after convs 1, 2, 4, 6, 8
        (3, 64, 32),
        (64, 128, 16),
        (128, 256, 8),
        (256, 256, 8),
        (256, 512, 4),
        (512, 512, 4),
        (512, 512, 2),
        (512, 512, 2),
    ]
    layers = tuple(
        LayerSpec(f"conv{i+1}", 3, cin, cout, hw) for i, (cin, cout, hw) in enumerate(cfg)
    )
    return NetworkSpec("vgg11", layers)
