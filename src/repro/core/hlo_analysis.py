"""Static cost analysis over post-SPMD optimized HLO text.

Why not ``compiled.cost_analysis()``?  XLA's HloCostAnalysis visits every
computation ONCE: a ``jax.lax.scan`` over L layers reports the loop body's
FLOPs a single time, so any scanned model undercounts by ~L.  This analyzer
parses the HLO text, builds the computation call graph, extracts while-loop
trip counts from their condition computations, and multiplies.

Per-computation metrics:
  * flops            — 2 * prod(output dims) * prod(contracting dims) per
                       dot; convolutions likewise (2 * out * k * cin).
  * hbm_bytes        — for TOP-LEVEL instructions of non-fusion computations:
                       output bytes + operand bytes (resolved through a
                       per-computation symbol table — scheduled HLO does not
                       inline operand shapes).  Post-optimization HLO is
                       fully fused, so top-level buffers are the HBM-resident
                       ones; fusion-internal elementwise ops never touch HBM.
  * collective_bytes — operand bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute.

These aggregate over the call graph (while bodies x trip count, fusions /
calls / branches x 1) to whole-program totals.  This is the "profile" the
perf loop iterates on: a dry-run-only, hardware-independent static trace.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["HloCost", "analyze_hlo", "parse_computations"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVE_BASES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}

_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id", "replica-id",
    "iota", "copy-start", "copy-done",
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*"
    r"(\([^()]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^{}]*\})?)\s*"
    r"([\w\-]+)\("
)
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'trip_count["=:\s]+(\d+)')
_OPERAND_RE = re.compile(r"%[\w.\-]+")
_PARAM_RE = re.compile(r"([\w.\-]+)\s*:\s*(\([^()]*\)|[a-z][a-z0-9]*\[[0-9,]*\])")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _is_comp_header(line: str) -> str | None:
    """Return computation name if this line opens a computation body."""
    s = line.rstrip()
    if not s.endswith("{"):
        return None
    s2 = s.lstrip()
    if s2.startswith("ENTRY "):
        s2 = s2[len("ENTRY "):]
    if not s2.startswith("%") and not s2[:1].isalpha():
        return None
    if " -> " not in s2:
        return None
    name = re.match(r"(%?[\w.\-]+)", s2)
    if not name:
        return None
    # exclude instruction lines ("%x = ... {" never happens at top level)
    if "=" in s2.split("(")[0]:
        return None
    return name.group(1).lstrip("%")


def _dot_flops(line: str, out_shape: str, symtab: dict[str, str]) -> float:
    out_elems = 1
    m = _SHAPE_RE.search(out_shape)
    if not m:
        return 0.0
    for d in m.group(2).split(","):
        if d:
            out_elems *= int(d)
    paren = line[line.index("(") :]
    ops = _OPERAND_RE.findall(paren.split("), ")[0] + ")")
    lhs_shape = symtab.get(ops[0].lstrip("%"), "") if ops else ""
    sm = _SHAPE_RE.search(lhs_shape)
    lhs_dims = [int(d) for d in sm.group(2).split(",") if d] if sm else []
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    contract = 1
    if cm and lhs_dims:
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    elif lhs_dims:
        contract = lhs_dims[-1]
    return 2.0 * out_elems * contract


@dataclass
class _Comp:
    name: str
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    coll_by_op: dict = field(default_factory=dict)
    coll_count: dict = field(default_factory=dict)
    calls: list = field(default_factory=list)  # (callee, kind, cond, trip)
    max_const: int = 0
    is_fusion: bool = False


def parse_computations(text: str) -> tuple[dict[str, "_Comp"], str]:
    comps: dict[str, _Comp] = {}
    entry_name = ""
    cur: _Comp | None = None
    symtab: dict[str, str] = {}

    for raw in text.splitlines():
        line = raw.rstrip()
        header = _is_comp_header(line)
        if header is not None:
            cur = _Comp(name=header, is_fusion="fused" in header)
            comps[header] = cur
            if line.lstrip().startswith("ENTRY"):
                entry_name = header
            symtab = {}
            # computation parameters carry shapes in the header
            for pname, pshape in _PARAM_RE.findall(line):
                symtab[pname] = pshape
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            for c in _CONST_INT_RE.findall(line):
                cur.max_const = max(cur.max_const, int(c))
            continue
        name, out_shape, opcode = m.groups()
        symtab[name.lstrip("%")] = out_shape
        for c in _CONST_INT_RE.findall(line):
            cur.max_const = max(cur.max_const, int(c))

        paren_all = line[line.index("(") :]
        arg_str = paren_all.split("), ")[0]
        operand_names = [o.lstrip("%") for o in _OPERAND_RE.findall(arg_str)]
        operand_bytes = sum(_shape_bytes(symtab.get(o, "")) for o in operand_names)

        if opcode in ("dot", "convolution"):
            cur.flops += _dot_flops(line, out_shape, symtab)

        base_op = opcode.replace("-start", "")
        if base_op in _COLLECTIVE_BASES and not opcode.endswith("-done"):
            nbytes = operand_bytes or _shape_bytes(out_shape)
            cur.collective_bytes += nbytes
            cur.coll_by_op[base_op] = cur.coll_by_op.get(base_op, 0) + nbytes
            cur.coll_count[base_op] = cur.coll_count.get(base_op, 0) + 1
        elif not cur.is_fusion and opcode not in _SKIP_BYTES:
            cur.hbm_bytes += _shape_bytes(out_shape) + operand_bytes

        if opcode == "while":
            bm = _CALLS_RE.search(line)
            cm = _COND_RE.search(line)
            tm = _TRIP_RE.search(line)
            trip = int(tm.group(1)) if tm else None
            if bm:
                cur.calls.append(
                    (bm.group(1).lstrip("%"), "while", cm.group(1).lstrip("%") if cm else None, trip)
                )
        elif opcode == "conditional":
            bm = _BRANCHES_RE.search(line)
            if bm:
                for b in bm.group(1).split(","):
                    cur.calls.append((b.strip().lstrip("%"), "branch", None, None))
        else:
            for callee in _CALLS_RE.findall(line):
                cur.calls.append((callee.lstrip("%"), "call", None, None))
    return comps, entry_name


@dataclass(frozen=True)
class HloCost:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    coll_by_op: dict
    coll_count: dict
    n_while: int
    trip_counts: tuple


def analyze_hlo(text: str) -> HloCost:
    comps, entry = parse_computations(text)
    memo: dict[str, tuple] = {}
    trips: list[int] = []

    def total(name: str, stack=()) -> tuple:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return (0.0, 0.0, 0.0, {}, {})
        c = comps[name]
        f, h, cb = c.flops, c.hbm_bytes, c.collective_bytes
        cbo = dict(c.coll_by_op)
        cbc = dict(c.coll_count)
        for callee, kind, cond, trip in c.calls:
            cf, ch, ccb, ccbo, ccbc = total(callee, stack + (name,))
            mult = 1
            if kind == "while":
                if trip is None:
                    # heuristic: largest integer constant in the condition
                    # computation (jax scans lower to `i < L` compares)
                    trip = comps[cond].max_const if cond in comps else 1
                mult = max(int(trip), 1)
                trips.append(mult)
            f += cf * mult
            h += ch * mult
            cb += ccb * mult
            for k, v in ccbo.items():
                cbo[k] = cbo.get(k, 0) + v * mult
            for k, v in ccbc.items():
                cbc[k] = cbc.get(k, 0) + v * mult
        memo[name] = (f, h, cb, cbo, cbc)
        return memo[name]

    n_while = sum(
        1 for c in comps.values() for call in c.calls if call[1] == "while"
    )
    f, h, cb, cbo, cbc = total(entry)
    return HloCost(
        flops=f,
        hbm_bytes=h,
        collective_bytes=cb,
        coll_by_op=cbo,
        coll_count=cbc,
        n_while=n_while,
        trip_counts=tuple(sorted(trips, reverse=True)),
    )
