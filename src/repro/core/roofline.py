"""Three-term roofline analysis from a compiled (AOT) executable.

  compute    = HLO_FLOPs        / (chips * PEAK_FLOPS)
  memory     = HLO_bytes        / (chips * HBM_BW)
  collective = collective_bytes / (chips * LINK_BW)

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are NOT
in cost_analysis: we parse the post-SPMD optimized HLO text and sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["HW", "CollectiveStats", "collective_stats", "Roofline", "analyze"]

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / link


@dataclass(frozen=True)
class HW:
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    link_bw: float = LINK_BW


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# one HLO instruction: "%name = <shape(s)> opcode(...operands...)"
_INSTR_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(([^)]*)\)"
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=dict)
    count_by_op: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective in post-SPMD optimized HLO.

    Operand shapes appear inline in full-form HLO; when they don't (short
    form), we fall back to the result shape (exact for all-reduce /
    collective-permute / all-to-all, the shard-side size for all-gather /
    reduce-scatter)."""
    st = CollectiveStats()
    seen_done = set()
    for m in _INSTR_RE.finditer(hlo_text):
        result_shapes, op, operands = m.group(1), m.group(2), m.group(3)
        opname = op
        operand_shapes = _SHAPE_RE.findall(operands)
        if operand_shapes:
            nbytes = sum(_shape_bytes(dt, dims) for dt, dims in operand_shapes)
        else:
            nbytes = sum(
                _shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(result_shapes)
            )
        st.bytes_by_op[opname] = st.bytes_by_op.get(opname, 0) + nbytes
        st.count_by_op[opname] = st.count_by_op.get(opname, 0) + 1
    return st


@dataclass(frozen=True)
class Roofline:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    chips: int
    model_flops: float = 0.0
    hw: HW = field(default_factory=HW)

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * self.hw.peak_flops)

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / (self.chips * self.hw.hbm_bw)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * self.hw.link_bw)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat / padding / redundancy."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak the step achieves if it runs at the roofline:
        useful model FLOPs / (chips * peak * step_time)."""
        t = self.step_time_s
        if not t:
            return 0.0
        return self.model_flops / (self.chips * self.hw.peak_flops * t)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flop_fraction": self.useful_flop_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze(compiled, chips: int, model_flops: float = 0.0, hw: HW = HW()) -> Roofline:
    """Roofline from the compiled artifact.

    FLOPs / HBM bytes / collective bytes come from our own HLO-text analyzer
    (``hlo_analysis``) because XLA's HloCostAnalysis counts while-loop
    (scan) bodies once instead of x trip-count.  ``compiled.cost_analysis``
    is kept as a cross-check in the dry-run record.

    NOTE on units: the analyzer runs on the post-SPMD (per-device) module, so
    flops/bytes are PER-CHIP; the roofline terms therefore divide by 1 chip's
    peak.  ``chips`` is kept for reporting/derived metrics.
    """
    from . import hlo_analysis

    cost = hlo_analysis.analyze_hlo(compiled.as_text())
    return Roofline(
        flops=cost.flops * chips,
        bytes_accessed=cost.hbm_bytes * chips,
        collective_bytes=cost.collective_bytes * chips,
        chips=chips,
        model_flops=model_flops,
        hw=hw,
    )
