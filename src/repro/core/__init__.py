"""Core: the paper's contribution (cim, alloc) + roofline/HLO analysis."""
