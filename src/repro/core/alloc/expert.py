"""Load-aware expert replication — the paper's block-wise allocation applied
to MoE expert parallelism.

CIM mapping (DESIGN.md §3): an expert is a block of immovable weights; the
routed token count per expert is its data-dependent service time; the EP
all-to-all + capacity buffer is the synchronization barrier.  As in the
paper, we (1) profile the input statistics (expert-selection histogram),
(2) run the SAME greedy highest-expected-latency-first allocator to grant
replicas under a physical-slot budget, (3) dispatch each token to the next
replica round-robin.

Quantitative payoffs (asserted in tests + shown in benchmarks):
  * expected max slot load drops toward the mean (barrier relief),
  * token drop rate at fixed capacity_factor falls,
  * a slot count padded to a mesh-divisible number unlocks wider EP
    sharding (e.g. DeepSeek-V2: 160 experts + 96 replicas = 256 slots on a
    (data=16, model=16) mesh — full 2D expert parallelism).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .greedy import greedy_allocate

__all__ = [
    "ReplicationPlan",
    "plan_replication",
    "profile_expert_histogram",
    "expected_max_load",
    "drop_rate",
]


@dataclass(frozen=True)
class ReplicationPlan:
    replication: tuple[int, ...]  # replicas per logical expert
    n_physical: int
    histogram: np.ndarray  # normalized load per logical expert
    slot_load: np.ndarray  # expected load per physical slot

    @property
    def max_slot_load(self) -> float:
        return float(self.slot_load.max())

    @property
    def balance(self) -> float:
        """mean/max slot load: 1.0 = perfectly balanced (full utilization)."""
        return float(self.slot_load.mean() / self.slot_load.max())


def profile_expert_histogram(router_logits: np.ndarray, top_k: int) -> np.ndarray:
    """Selection frequencies from profiled router logits (N, E) — the
    paper's 'profile the distribution of ones ... from a large set of
    examples run on a GPU' step, for experts."""
    n, e = router_logits.shape
    idx = np.argsort(-router_logits, axis=-1)[:, :top_k]
    hist = np.bincount(idx.reshape(-1), minlength=e).astype(np.float64)
    return hist / hist.sum()


def plan_replication(
    histogram: np.ndarray,
    slot_budget: int,
    *,
    pad_to: int | None = None,
) -> ReplicationPlan:
    """Greedy replica grants: expected slot latency = hist_e / replicas_e.

    slot_budget: total physical slots available (>= n_experts).
    pad_to: if set, force the final slot count to exactly this value
      (mesh divisibility); leftover grants keep going to the current
      slowest expert even past the greedy stopping rule.
    """
    hist = np.asarray(histogram, dtype=np.float64)
    e = hist.size
    if slot_budget < e:
        raise ValueError(f"budget {slot_budget} < experts {e}")
    target = pad_to if pad_to is not None else slot_budget
    if target < e:
        raise ValueError(f"pad_to {target} < experts {e}")
    res = greedy_allocate(hist, np.ones(e), budget=target - e)
    repl = res.replicas.copy()
    # pad_to forces an exact count (greedy never stops early here since every
    # unit cost is 1, but guard anyway)
    while repl.sum() < target:
        repl[np.argmax(hist / repl)] += 1
    slot_load = np.concatenate([np.full(r, h / r) for h, r in zip(hist, repl)])
    return ReplicationPlan(tuple(int(r) for r in repl), int(repl.sum()), hist, slot_load)


def expected_max_load(plan_or_hist, n_tokens: int, top_k: int, rng=None, trials: int = 32) -> float:
    """Monte-Carlo E[max slot tokens] for a routing distribution — the
    barrier cost in the paper's terms (everyone waits for the slowest)."""
    if isinstance(plan_or_hist, ReplicationPlan):
        probs = plan_or_hist.slot_load
    else:
        probs = np.asarray(plan_or_hist, dtype=np.float64)
    probs = probs / probs.sum()
    rng = rng or np.random.default_rng(0)
    draws = rng.multinomial(n_tokens * top_k, probs, size=trials)
    return float(draws.max(axis=1).mean())


def drop_rate(plan_or_hist, n_tokens: int, top_k: int, capacity_factor: float, rng=None, trials: int = 32) -> float:
    """Fraction of routed assignments dropped at a given capacity factor."""
    if isinstance(plan_or_hist, ReplicationPlan):
        probs = plan_or_hist.slot_load
    else:
        probs = np.asarray(plan_or_hist, dtype=np.float64)
    probs = probs / probs.sum()
    n_slots = probs.size
    cap = int(np.ceil(n_tokens * top_k / n_slots * capacity_factor))
    rng = rng or np.random.default_rng(0)
    draws = rng.multinomial(n_tokens * top_k, probs, size=trials)
    dropped = np.maximum(draws - cap, 0).sum(axis=1)
    return float(dropped.mean() / (n_tokens * top_k))
