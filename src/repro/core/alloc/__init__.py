"""Generalized allocation algorithms shared by the CIM simulator and the
distributed runtime."""

from .greedy import AllocationResult, greedy_allocate, proportional_allocate

__all__ = ["AllocationResult", "greedy_allocate", "proportional_allocate"]
