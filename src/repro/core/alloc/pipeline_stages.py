"""Cost-based pipeline-stage partitioning — the paper's performance-based
layer-wise allocation applied to pipeline parallelism.

Prior-work analogue ("weight-based"): split L layers into P stages with
equal LAYER COUNTS.  Paper analogue ("performance-based"): split so that
per-stage COST (profiled per-layer step cost — FLOPs from the dry-run, or
measured step times) is balanced, because the pipeline runs at the speed of
the slowest stage.

On a multi-chip fabric every stage boundary is an inter-chip link, so a cut
is not free: the activations crossing it ride the link every microbatch.
``edge_cost[i]`` prices starting a stage at layer ``i`` (the transfer of
layer ``i``'s input across the boundary, in the same units as ``costs``) and
the DP charges it to the receiving stage — balanced cuts migrate off fat
activation edges onto thin ones.  ``edge_cost=None`` is the flat special
case, bit-identical to the classic partition.

`partition_stages` is the classic linear-partition DP (O(L^2 P)), exact."""

from __future__ import annotations

import numpy as np

__all__ = ["partition_stages", "stage_costs", "bottleneck"]


def partition_stages(
    costs: np.ndarray,
    n_stages: int,
    edge_cost: np.ndarray | None = None,
) -> list[tuple[int, int]]:
    """Split layers [0, L) into contiguous stages minimizing max stage cost.

    With ``edge_cost`` (length L; entry ``i`` = cost of cutting BEFORE layer
    ``i``, ``edge_cost[0]`` ignored — the first stage reads from the host),
    a stage [i, j) costs ``sum(costs[i:j]) + edge_cost[i]`` and the DP
    minimizes the communication-inclusive bottleneck.

    Returns [(start, end), ...] half-open ranges, len == n_stages."""
    costs = np.asarray(costs, dtype=np.float64)
    L = costs.size
    if edge_cost is None:
        if n_stages >= L:
            return [(i, i + 1) for i in range(L)] + [(L, L)] * (n_stages - L)
        edge = np.zeros(L)
        P = n_stages
    else:
        edge = np.asarray(edge_cost, dtype=np.float64)
        if edge.shape != (L,):
            raise ValueError(f"edge_cost has shape {edge.shape}, expected ({L},)")
        # with priced cuts, more stages than layers never helps; pad with
        # empty trailing stages instead of forcing degenerate cuts
        P = min(n_stages, L)
    prefix = np.concatenate([[0.0], np.cumsum(costs)])

    def seg(i, j):  # cost of layers [i, j), plus the incoming transfer
        base = prefix[j] - prefix[i]
        return base + edge[i] if i > 0 else base

    # dp[p][j] = minimal bottleneck for first j layers in p stages
    dp = np.full((P + 1, L + 1), np.inf)
    cut = np.zeros((P + 1, L + 1), dtype=np.int64)
    dp[0][0] = 0.0
    for p in range(1, P + 1):
        for j in range(1, L + 1):
            for i in range(p - 1, j):
                val = max(dp[p - 1][i], seg(i, j))
                if val < dp[p][j]:
                    dp[p][j] = val
                    cut[p][j] = i
    # with priced cuts, FEWER nonempty stages can beat the full count (a fat
    # activation edge may cost more than the imbalance it relieves): take
    # the best p <= P and pad with empty trailing stages.  Without edge
    # costs dp[p][L] is non-increasing in p, so best == P and the classic
    # partition is returned unchanged.
    best = int(np.argmin(dp[1 : P + 1, L])) + 1 if edge_cost is not None else P
    # walk back
    bounds = []
    j = L
    for p in range(best, 0, -1):
        i = int(cut[p][j])
        bounds.append((i, j))
        j = i
    out = list(reversed(bounds))
    return out + [(L, L)] * (n_stages - best)


def stage_costs(costs: np.ndarray, stages: list[tuple[int, int]]) -> np.ndarray:
    costs = np.asarray(costs, dtype=np.float64)
    return np.asarray([costs[a:b].sum() for a, b in stages])


def bottleneck(costs: np.ndarray, stages: list[tuple[int, int]]) -> float:
    return float(stage_costs(costs, stages).max())
