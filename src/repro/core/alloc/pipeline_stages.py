"""Cost-based pipeline-stage partitioning — the paper's performance-based
layer-wise allocation applied to pipeline parallelism.

Prior-work analogue ("weight-based"): split L layers into P stages with
equal LAYER COUNTS.  Paper analogue ("performance-based"): split so that
per-stage COST (profiled per-layer step cost — FLOPs from the dry-run, or
measured step times) is balanced, because the pipeline runs at the speed of
the slowest stage.

`partition_stages` is the classic linear-partition DP (O(L^2 P)), exact."""

from __future__ import annotations

import numpy as np

__all__ = ["partition_stages", "stage_costs", "bottleneck"]


def partition_stages(costs: np.ndarray, n_stages: int) -> list[tuple[int, int]]:
    """Split layers [0, L) into contiguous stages minimizing max stage cost.

    Returns [(start, end), ...] half-open ranges, len == n_stages."""
    costs = np.asarray(costs, dtype=np.float64)
    L = costs.size
    if n_stages >= L:
        return [(i, i + 1) for i in range(L)] + [(L, L)] * (n_stages - L)
    prefix = np.concatenate([[0.0], np.cumsum(costs)])

    def seg(i, j):  # cost of layers [i, j)
        return prefix[j] - prefix[i]

    # dp[p][j] = minimal bottleneck for first j layers in p stages
    dp = np.full((n_stages + 1, L + 1), np.inf)
    cut = np.zeros((n_stages + 1, L + 1), dtype=np.int64)
    dp[0][0] = 0.0
    for p in range(1, n_stages + 1):
        for j in range(1, L + 1):
            for i in range(p - 1, j):
                val = max(dp[p - 1][i], seg(i, j))
                if val < dp[p][j]:
                    dp[p][j] = val
                    cut[p][j] = i
    # walk back
    bounds = []
    j = L
    for p in range(n_stages, 0, -1):
        i = int(cut[p][j])
        bounds.append((i, j))
        j = i
    return list(reversed(bounds))


def stage_costs(costs: np.ndarray, stages: list[tuple[int, int]]) -> np.ndarray:
    costs = np.asarray(costs, dtype=np.float64)
    return np.asarray([costs[a:b].sum() for a, b in stages])


def bottleneck(costs: np.ndarray, stages: list[tuple[int, int]]) -> float:
    return float(stage_costs(costs, stages).max())
