"""Greedy latency-proportional replica allocation.

This is the paper's core algorithm (Section III-B), factored out so that it is
shared verbatim between:

  * the CIM simulator (units = blocks of crossbar arrays, cost = arrays), and
  * the distributed runtime (units = MoE experts / pipeline stages, cost =
    HBM bytes or device slots).

The paper describes a linear-time loop: "While we have free (not allocated)
arrays, we loop through and allocate arrays to the block with the highest
expected latency. Once we run out of arrays or the number of arrays left over
is not enough to allocate to the slowest block we have found the optimal
allocation."  We implement it with a max-heap (O(N log N)); the result is
identical to the paper's linear scan.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np

__all__ = [
    "AllocationResult",
    "BatchAllocationResult",
    "greedy_allocate",
    "greedy_allocate_batch",
    "proportional_allocate",
    "proportional_allocate_batch",
]


@dataclass(frozen=True)
class AllocationResult:
    """Replica counts chosen by the allocator.

    Attributes:
      replicas:    int array, replicas granted per unit (>= 1 each).
      latency:     float array, resulting expected latency per unit
                   (base_latency / replicas).
      spent:       total cost consumed.
      leftover:    budget remaining when the loop stopped.
    """

    replicas: np.ndarray
    latency: np.ndarray
    spent: float
    leftover: float

    @property
    def makespan(self) -> float:
        return float(self.latency.max()) if self.latency.size else 0.0


def greedy_allocate(
    base_latency: np.ndarray,
    unit_cost: np.ndarray,
    budget: float,
    *,
    initial_replicas: np.ndarray | None = None,
) -> AllocationResult:
    """Grant replicas to the unit with the highest expected latency.

    Args:
      base_latency: expected latency of each unit with a single replica
        (e.g. expected cycles for a block to process its share of work).
      unit_cost: cost of one additional replica of each unit (e.g. arrays per
        block row, HBM bytes per expert copy).
      budget: total cost available for *additional* replicas (the mandatory
        first copy of each unit is assumed already placed and not billed).
      initial_replicas: optionally start from an existing allocation.

    Stops when the current slowest unit can no longer be afforded, mirroring
    the paper's stopping rule.
    """
    base_latency = np.asarray(base_latency, dtype=np.float64)
    unit_cost = np.asarray(unit_cost, dtype=np.float64)
    if base_latency.shape != unit_cost.shape:
        raise ValueError(
            f"base_latency {base_latency.shape} vs unit_cost {unit_cost.shape}"
        )
    n = base_latency.size
    replicas = (
        np.ones(n, dtype=np.int64)
        if initial_replicas is None
        else np.asarray(initial_replicas, dtype=np.int64).copy()
    )
    if n == 0:
        return AllocationResult(replicas, base_latency.copy(), 0.0, budget)
    if np.any(replicas < 1):
        raise ValueError("every unit needs at least one replica")

    # Max-heap keyed by current expected latency.
    heap = [(-base_latency[i] / replicas[i], i) for i in range(n)]
    heapq.heapify(heap)
    spent = 0.0
    remaining = float(budget)
    while heap:
        neg_lat, i = heapq.heappop(heap)
        if unit_cost[i] > remaining:
            # Paper's stopping rule: if the slowest unit cannot be afforded,
            # the allocation is final (do not skip to cheaper, faster units —
            # they would not reduce the makespan anyway).
            heapq.heappush(heap, (neg_lat, i))
            break
        remaining -= unit_cost[i]
        spent += unit_cost[i]
        replicas[i] += 1
        heapq.heappush(heap, (-base_latency[i] / replicas[i], i))

    latency = base_latency / replicas
    return AllocationResult(replicas, latency, spent, remaining)


@dataclass(frozen=True)
class BatchAllocationResult:
    """Structure-of-arrays ``AllocationResult`` for C independent configs."""

    replicas: np.ndarray  # (C, N) int64
    latency: np.ndarray  # (C, N)
    spent: np.ndarray  # (C,)
    leftover: np.ndarray  # (C,)

    @property
    def makespan(self) -> np.ndarray:  # (C,)
        if self.latency.shape[1] == 0:
            return np.zeros(len(self))
        return self.latency.max(axis=1)

    def __len__(self) -> int:
        return self.replicas.shape[0]


_GREEDY_BATCH_JIT: dict = {}


def _greedy_batch_kernel():
    """Build (once) the jitted lock-step batched greedy kernel.

    Two phases, both exactly replicating the scalar heap loop:

    1.  *Bulk water-fill by bisection.*  The greedy's max-latency is
        non-increasing, so for any makespan target ``lam`` the state
        ``r_i = max(r0_i, ceil(base_i / lam))`` is a state the scalar greedy
        passes through — provided its cost fits the budget (every
        intermediate grant is then affordable, so the scalar stopping rule
        cannot fire early).  We bisect ``lam`` to the tightest affordable
        state, then back off by 1e-9 relative so grants at levels within
        roundoff of the boundary are left to phase 2 (whose tie-breaking is
        exact) rather than resolved by float ceil.
    2.  *Lock-step residual loop.*  Grant the argmax-latency unit of every
        config one replica per iteration; a config freezes the moment its
        argmax is unaffordable (the paper's stopping rule — argmax ties
        resolve to the lowest index, matching the scalar heap order).
    """
    import jax
    import jax.numpy as jnp

    def kernel(base, cost, budget, r0):
        N = base.shape[1]

        def r_of(lam):
            return jnp.maximum(r0, jnp.ceil(base / lam[:, None]))

        def spend_of(r):
            return ((r - r0) * cost).sum(axis=1)

        lat0 = base / r0
        hi = jnp.maximum(lat0.max(axis=1), 1e-300)  # degenerate all-zero rows
        min_cost = cost.min(axis=1)
        # strictly below the final greedy makespan -> provably infeasible
        lo = hi / (2.0 * (2.0 + jnp.maximum(budget, 0.0) / min_cost))

        def bisect(_, lohi):
            lo, hi = lohi
            mid = 0.5 * (lo + hi)
            feasible = spend_of(r_of(mid)) <= budget
            return jnp.where(feasible, lo, mid), jnp.where(feasible, mid, hi)

        lo, hi = jax.lax.fori_loop(0, 80, bisect, (lo, hi))
        r = r_of(hi * (1.0 + 1e-9))
        rem = budget - spend_of(r)

        idx = jnp.arange(N)

        def not_done(state):
            return ~state[2].all()

        def grant(state):
            r, rem, done = state
            lat = base / r
            i = lat.argmax(axis=1)  # first max == scalar heap tie order
            ci = jnp.take_along_axis(cost, i[:, None], axis=1)[:, 0]
            ok = (ci <= rem) & ~done
            r = r + ((idx[None, :] == i[:, None]) & ok[:, None])
            rem = rem - jnp.where(ok, ci, 0.0)
            return r, rem, done | ~ok

        done = jnp.zeros(base.shape[0], dtype=bool)
        r, rem, done = jax.lax.while_loop(not_done, grant, (r, rem, done))
        return r, rem

    return jax.jit(kernel)


def greedy_allocate_batch(
    base_latency: np.ndarray,
    unit_cost: np.ndarray,
    budgets: np.ndarray,
    *,
    initial_replicas: np.ndarray | None = None,
) -> BatchAllocationResult:
    """Vectorized ``greedy_allocate`` over C configs, lock-step in jnp.

    ``base_latency`` / ``unit_cost`` / ``initial_replicas`` broadcast from
    (N,) to (C, N); ``budgets`` is (C,).  Replica counts are element-wise
    identical to looping the scalar allocator (the property suite pins
    this); ``spent`` / ``leftover`` agree to float roundoff.  Runs in
    float64 under ``jax.experimental.enable_x64``.
    """
    budgets = np.atleast_1d(np.asarray(budgets, dtype=np.float64))
    C = budgets.shape[0]
    base = np.atleast_1d(np.asarray(base_latency, dtype=np.float64))
    cost = np.atleast_1d(np.asarray(unit_cost, dtype=np.float64))
    if base.shape[-1] != cost.shape[-1]:
        raise ValueError(f"base_latency {base.shape} vs unit_cost {cost.shape}")
    N = base.shape[-1]
    base = np.ascontiguousarray(np.broadcast_to(base, (C, N)))
    cost = np.ascontiguousarray(np.broadcast_to(cost, (C, N)))
    if np.any(cost <= 0):
        raise ValueError("unit_cost must be strictly positive")
    if initial_replicas is None:
        r0 = np.ones((C, N))
    else:
        r0 = np.ascontiguousarray(
            np.broadcast_to(np.asarray(initial_replicas, dtype=np.float64), (C, N))
        )
        if np.any(r0 < 1):
            raise ValueError("every unit needs at least one replica")
    if N == 0:
        return BatchAllocationResult(
            np.ones((C, 0), dtype=np.int64), base.copy(), np.zeros(C), budgets.copy()
        )

    from jax.experimental import enable_x64

    if "kernel" not in _GREEDY_BATCH_JIT:
        _GREEDY_BATCH_JIT["kernel"] = _greedy_batch_kernel()
    with enable_x64():
        r, rem = _GREEDY_BATCH_JIT["kernel"](base, cost, budgets, r0)
    r = np.asarray(r)
    replicas = r.astype(np.int64)
    spent = ((r - r0) * cost).sum(axis=1)
    return BatchAllocationResult(replicas, base / r, spent, np.asarray(rem))


def proportional_allocate(
    weight: np.ndarray,
    unit_cost: np.ndarray,
    budget: float,
) -> AllocationResult:
    """Allocate replicas proportional to `weight` (the prior-work policy).

    This is "weight-based" allocation when `weight` = MACs per layer and
    "performance-based layer-wise" when `weight` = expected cycles per layer.
    Replica counts are the floor of the proportional share (>= 1), with any
    leftover budget distributed by largest fractional remainder.
    """
    weight = np.asarray(weight, dtype=np.float64)
    unit_cost = np.asarray(unit_cost, dtype=np.float64)
    n = weight.size
    replicas = np.ones(n, dtype=np.int64)
    if n == 0 or budget <= 0:
        return AllocationResult(replicas, weight / replicas, 0.0, float(budget))

    total_w = weight.sum()
    # Ideal fractional share of the budget, in cost units, then converted to
    # whole replicas of each unit.
    share = weight / total_w * float(budget)
    extra = np.floor(share / unit_cost).astype(np.int64)
    replicas = replicas + np.maximum(extra, 0)
    spent = float((extra * unit_cost).sum())
    remaining = float(budget) - spent
    # Largest-remainder top-up.
    frac = share / unit_cost - extra
    for i in np.argsort(-frac):
        if unit_cost[i] <= remaining:
            replicas[i] += 1
            remaining -= unit_cost[i]
            spent += unit_cost[i]
    latency = weight / replicas
    return AllocationResult(replicas, latency, spent, remaining)


def proportional_allocate_batch(
    weight: np.ndarray,
    unit_cost: np.ndarray,
    budgets: np.ndarray,
) -> BatchAllocationResult:
    """``proportional_allocate`` over C budgets, vectorized in numpy.

    Element-wise identical to looping the scalar routine: the share /
    floor arithmetic broadcasts unchanged, and ``np.argsort(-frac, axis=1)``
    applies the same introsort per row as the scalar's per-config call, so
    even unstable tie orders agree.  The largest-remainder top-up walks the
    N sorted positions lock-step across configs.
    """
    budgets = np.atleast_1d(np.asarray(budgets, dtype=np.float64))
    weight = np.atleast_1d(np.asarray(weight, dtype=np.float64))
    cost = np.atleast_1d(np.asarray(unit_cost, dtype=np.float64))
    C = budgets.shape[0]
    N = weight.shape[-1]
    weight = np.broadcast_to(weight, (C, N))
    cost = np.broadcast_to(cost, (C, N))
    replicas = np.ones((C, N), dtype=np.int64)
    if N == 0 or C == 0:
        return BatchAllocationResult(
            replicas, weight / replicas, np.zeros(C), budgets.copy()
        )

    act = budgets > 0  # scalar early-returns all-ones below/at zero budget
    total_w = weight.sum(axis=1)
    share = weight / total_w[:, None] * budgets[:, None]
    extra = np.where(act[:, None], np.floor(share / cost).astype(np.int64), 0)
    replicas = replicas + np.maximum(extra, 0)
    spent = (extra * cost).sum(axis=1)
    remaining = budgets - spent
    # largest-remainder top-up, lock-step over the N sorted positions
    frac = share / cost - extra
    order = np.argsort(-frac, axis=1)
    rows = np.arange(C)
    for k in range(N):
        i = order[:, k]
        ci = cost[rows, i]
        ok = act & (ci <= remaining)
        replicas[rows[ok], i[ok]] += 1
        remaining = np.where(ok, remaining - ci, remaining)
        spent = np.where(ok, spent + ci, spent)
    return BatchAllocationResult(replicas, weight / replicas, spent, remaining)
