"""Greedy latency-proportional replica allocation.

This is the paper's core algorithm (Section III-B), factored out so that it is
shared verbatim between:

  * the CIM simulator (units = blocks of crossbar arrays, cost = arrays), and
  * the distributed runtime (units = MoE experts / pipeline stages, cost =
    HBM bytes or device slots).

The paper describes a linear-time loop: "While we have free (not allocated)
arrays, we loop through and allocate arrays to the block with the highest
expected latency. Once we run out of arrays or the number of arrays left over
is not enough to allocate to the slowest block we have found the optimal
allocation."  We implement it with a max-heap (O(N log N)); the result is
identical to the paper's linear scan.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np

__all__ = ["AllocationResult", "greedy_allocate", "proportional_allocate"]


@dataclass(frozen=True)
class AllocationResult:
    """Replica counts chosen by the allocator.

    Attributes:
      replicas:    int array, replicas granted per unit (>= 1 each).
      latency:     float array, resulting expected latency per unit
                   (base_latency / replicas).
      spent:       total cost consumed.
      leftover:    budget remaining when the loop stopped.
    """

    replicas: np.ndarray
    latency: np.ndarray
    spent: float
    leftover: float

    @property
    def makespan(self) -> float:
        return float(self.latency.max()) if self.latency.size else 0.0


def greedy_allocate(
    base_latency: np.ndarray,
    unit_cost: np.ndarray,
    budget: float,
    *,
    initial_replicas: np.ndarray | None = None,
) -> AllocationResult:
    """Grant replicas to the unit with the highest expected latency.

    Args:
      base_latency: expected latency of each unit with a single replica
        (e.g. expected cycles for a block to process its share of work).
      unit_cost: cost of one additional replica of each unit (e.g. arrays per
        block row, HBM bytes per expert copy).
      budget: total cost available for *additional* replicas (the mandatory
        first copy of each unit is assumed already placed and not billed).
      initial_replicas: optionally start from an existing allocation.

    Stops when the current slowest unit can no longer be afforded, mirroring
    the paper's stopping rule.
    """
    base_latency = np.asarray(base_latency, dtype=np.float64)
    unit_cost = np.asarray(unit_cost, dtype=np.float64)
    if base_latency.shape != unit_cost.shape:
        raise ValueError(
            f"base_latency {base_latency.shape} vs unit_cost {unit_cost.shape}"
        )
    n = base_latency.size
    replicas = (
        np.ones(n, dtype=np.int64)
        if initial_replicas is None
        else np.asarray(initial_replicas, dtype=np.int64).copy()
    )
    if n == 0:
        return AllocationResult(replicas, base_latency.copy(), 0.0, budget)
    if np.any(replicas < 1):
        raise ValueError("every unit needs at least one replica")

    # Max-heap keyed by current expected latency.
    heap = [(-base_latency[i] / replicas[i], i) for i in range(n)]
    heapq.heapify(heap)
    spent = 0.0
    remaining = float(budget)
    while heap:
        neg_lat, i = heapq.heappop(heap)
        if unit_cost[i] > remaining:
            # Paper's stopping rule: if the slowest unit cannot be afforded,
            # the allocation is final (do not skip to cheaper, faster units —
            # they would not reduce the makespan anyway).
            heapq.heappush(heap, (neg_lat, i))
            break
        remaining -= unit_cost[i]
        spent += unit_cost[i]
        replicas[i] += 1
        heapq.heappush(heap, (-base_latency[i] / replicas[i], i))

    latency = base_latency / replicas
    return AllocationResult(replicas, latency, spent, remaining)


def proportional_allocate(
    weight: np.ndarray,
    unit_cost: np.ndarray,
    budget: float,
) -> AllocationResult:
    """Allocate replicas proportional to `weight` (the prior-work policy).

    This is "weight-based" allocation when `weight` = MACs per layer and
    "performance-based layer-wise" when `weight` = expected cycles per layer.
    Replica counts are the floor of the proportional share (>= 1), with any
    leftover budget distributed by largest fractional remainder.
    """
    weight = np.asarray(weight, dtype=np.float64)
    unit_cost = np.asarray(unit_cost, dtype=np.float64)
    n = weight.size
    replicas = np.ones(n, dtype=np.int64)
    if n == 0 or budget <= 0:
        return AllocationResult(replicas, weight / replicas, 0.0, float(budget))

    total_w = weight.sum()
    # Ideal fractional share of the budget, in cost units, then converted to
    # whole replicas of each unit.
    share = weight / total_w * float(budget)
    extra = np.floor(share / unit_cost).astype(np.int64)
    replicas = replicas + np.maximum(extra, 0)
    spent = float((extra * unit_cost).sum())
    remaining = float(budget) - spent
    # Largest-remainder top-up.
    frac = share / unit_cost - extra
    for i in np.argsort(-frac):
        if unit_cost[i] <= remaining:
            replicas[i] += 1
            remaining -= unit_cost[i]
            spent += unit_cost[i]
    latency = weight / replicas
    return AllocationResult(replicas, latency, spent, remaining)
