"""Greedy latency-proportional replica allocation.

This is the paper's core algorithm (Section III-B), factored out so that it is
shared verbatim between:

  * the CIM simulator (units = blocks of crossbar arrays, cost = arrays), and
  * the distributed runtime (units = MoE experts / pipeline stages, cost =
    HBM bytes or device slots).

The paper describes a linear-time loop: "While we have free (not allocated)
arrays, we loop through and allocate arrays to the block with the highest
expected latency. Once we run out of arrays or the number of arrays left over
is not enough to allocate to the slowest block we have found the optimal
allocation."  We implement it with a max-heap (O(N log N)); the result is
identical to the paper's linear scan.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np

__all__ = [
    "AllocationResult",
    "BatchAllocationResult",
    "GreedyEventSchedule",
    "PlacedAllocationResult",
    "erlang_c",
    "greedy_allocate",
    "greedy_allocate_batch",
    "greedy_release",
    "greedy_batch_kernel",
    "greedy_event_schedule",
    "greedy_allocate_placed",
    "place_extras",
    "proportional_allocate",
    "proportional_allocate_batch",
    "queueing_allocate",
    "queueing_delay",
]


@dataclass(frozen=True)
class AllocationResult:
    """Replica counts chosen by the allocator.

    Attributes:
      replicas:    int array, replicas granted per unit (>= 1 each).
      latency:     float array, resulting expected latency per unit
                   (base_latency / replicas).
      spent:       total cost consumed.
      leftover:    budget remaining when the loop stopped.
    """

    replicas: np.ndarray
    latency: np.ndarray
    spent: float
    leftover: float

    @property
    def makespan(self) -> float:
        return float(self.latency.max()) if self.latency.size else 0.0


def greedy_allocate(
    base_latency: np.ndarray,
    unit_cost: np.ndarray,
    budget: float,
    *,
    initial_replicas: np.ndarray | None = None,
    spare_fraction: float = 0.0,
    audit=None,
) -> AllocationResult:
    """Grant replicas to the unit with the highest expected latency.

    Args:
      base_latency: expected latency of each unit with a single replica
        (e.g. expected cycles for a block to process its share of work).
      unit_cost: cost of one additional replica of each unit (e.g. arrays per
        block row, HBM bytes per expert copy).
      budget: total cost available for *additional* replicas (the mandatory
        first copy of each unit is assumed already placed and not billed).
      initial_replicas: optionally start from an existing allocation.
      spare_fraction: fraction of ``budget`` withheld from the loop as a hot
        spare pool (fault tolerance: ``fabric.failures.degrade_plan`` spends
        it re-placing lost replicas).  The reserve is never granted here and
        comes back in ``leftover``.  0.0 (the default) is bit-identical to
        the original allocator.
      audit: optional ``repro.obs.AllocationAudit`` receiving one entry per
        grant (and one for the stopping rule) — the decision log.  ``None``
        leaves the loop untouched.

    Stops when the current slowest unit can no longer be afforded, mirroring
    the paper's stopping rule.
    """
    if not 0.0 <= spare_fraction <= 1.0:
        raise ValueError(f"spare_fraction must be in [0, 1], got {spare_fraction}")
    reserve = float(budget) * spare_fraction
    base_latency = np.asarray(base_latency, dtype=np.float64)
    unit_cost = np.asarray(unit_cost, dtype=np.float64)
    if base_latency.shape != unit_cost.shape:
        raise ValueError(
            f"base_latency {base_latency.shape} vs unit_cost {unit_cost.shape}"
        )
    n = base_latency.size
    replicas = (
        np.ones(n, dtype=np.int64)
        if initial_replicas is None
        else np.asarray(initial_replicas, dtype=np.int64).copy()
    )
    if n == 0:
        return AllocationResult(replicas, base_latency.copy(), 0.0, float(budget))
    if np.any(replicas < 1):
        raise ValueError("every unit needs at least one replica")

    # Max-heap keyed by current expected latency.
    heap = [(-base_latency[i] / replicas[i], i) for i in range(n)]
    heapq.heapify(heap)
    spent = 0.0
    remaining = float(budget) - reserve
    while heap:
        neg_lat, i = heapq.heappop(heap)
        if unit_cost[i] > remaining:
            # Paper's stopping rule: if the slowest unit cannot be afforded,
            # the allocation is final (do not skip to cheaper, faster units —
            # they would not reduce the makespan anyway).
            if audit is not None:
                audit.stop("budget", i, unit_cost[i], remaining)
            heapq.heappush(heap, (neg_lat, i))
            break
        remaining -= unit_cost[i]
        spent += unit_cost[i]
        replicas[i] += 1
        new_lat = base_latency[i] / replicas[i]
        if audit is not None:
            audit.grant(i, unit_cost[i], -neg_lat, new_lat, remaining)
        heapq.heappush(heap, (-new_lat, i))

    latency = base_latency / replicas
    return AllocationResult(replicas, latency, spent, remaining + reserve)


def greedy_release(
    base_latency: np.ndarray,
    unit_cost: np.ndarray,
    release: float,
    *,
    replicas: np.ndarray,
) -> AllocationResult:
    """Reverse greedy: free at least ``release`` cost from ``replicas``.

    The exact inverse of ``greedy_allocate``'s grant rule: repeatedly remove
    one replica from the unit whose latency grows the LEAST by losing it —
    the unit with the smallest ``base_i / (r_i - 1)`` among those with more
    than one replica (ties to the lower index, mirroring the grant heap).
    Used by segmented replay (``fleet.segment_growth_plan``) when a seam's
    budget shrinks — degraded capacity after failures.  Stops once the freed
    cost reaches ``release`` or every unit is down to its mandatory copy.

    Returns an ``AllocationResult`` whose ``spent`` is the (negative) freed
    cost — so warm-started callers can keep one running budget across grow
    and shrink seams; ``leftover`` is the overshoot past ``release`` (>= 0,
    replicas free whole cost units).
    """
    base_latency = np.asarray(base_latency, dtype=np.float64)
    unit_cost = np.asarray(unit_cost, dtype=np.float64)
    if base_latency.shape != unit_cost.shape:
        raise ValueError(
            f"base_latency {base_latency.shape} vs unit_cost {unit_cost.shape}"
        )
    replicas = np.asarray(replicas, dtype=np.int64).copy()
    if replicas.shape != base_latency.shape:
        raise ValueError(
            f"replicas {replicas.shape} vs base_latency {base_latency.shape}"
        )
    if np.any(replicas < 1):
        raise ValueError("every unit needs at least one replica")
    if release < 0:
        raise ValueError(f"release must be >= 0, got {release}")

    # Min-heap keyed by the latency each unit would have after losing one
    # replica; stale entries are detected by re-deriving the key.
    heap = [
        (base_latency[i] / (replicas[i] - 1), i)
        for i in range(base_latency.size)
        if replicas[i] > 1
    ]
    heapq.heapify(heap)
    freed = 0.0
    while heap and freed < release:
        lat, i = heapq.heappop(heap)
        if replicas[i] <= 1 or lat != base_latency[i] / (replicas[i] - 1):
            continue
        replicas[i] -= 1
        freed += unit_cost[i]
        if replicas[i] > 1:
            heapq.heappush(heap, (base_latency[i] / (replicas[i] - 1), i))
    latency = base_latency / replicas
    return AllocationResult(replicas, latency, -freed, max(freed - release, 0.0))


@dataclass(frozen=True)
class PlacedAllocationResult:
    """Replica counts AND locations chosen by the placement-aware greedy.

    Attributes:
      replicas:      int array, replicas granted per unit (>= 1 each).
      latency:       float array, effective expected latency per unit =
                     base_latency / replicas + current comm penalty.
      spent:         total cost consumed.
      leftover:      budget remaining when the loop stopped.
      replica_chips: per unit, int array of the chip each replica sits on
                     (entry 0 is the mandatory copy's home chip).
      penalty:       per-unit comm penalty at the final placement (the max
                     over the unit's replica chips — a stage dispatches all
                     its jobs at entry, so the farthest replica gates it).
    """

    replicas: np.ndarray
    latency: np.ndarray
    spent: float
    leftover: float
    replica_chips: list[np.ndarray]
    penalty: np.ndarray

    @property
    def makespan(self) -> float:
        return float(self.latency.max()) if self.latency.size else 0.0


def greedy_allocate_placed(
    base_latency: np.ndarray,
    unit_cost: np.ndarray,
    budget: float,
    *,
    home_chip: np.ndarray,
    unit_penalty: np.ndarray,
    chip_free: np.ndarray,
    initial_replicas: np.ndarray | None = None,
    audit=None,
) -> PlacedAllocationResult:
    """Communication-aware ``greedy_allocate`` over a chip-partitioned fabric.

    The paper's greedy treats the fabric as one flat pool; here every replica
    must land on a specific chip with finite free capacity, and a replica
    placed off the unit's data source costs its stage a transfer delay on the
    dataflow edge (a stage dispatches all its jobs at request entry, so the
    farthest replica's transfer gates the whole unit).  The penalty scores
    the PLACEMENT side of every move: each grant goes on the affordable chip
    that least raises the unit's max penalty (ties -> lower raw penalty,
    then lower chip id), so grant-order interleaving packs the replicas of
    hot stages onto their source chips before cold stages fragment them —
    measurably fewer crossings than placing the same counts sequentially
    after the fact.

    Ranking (and therefore the replica COUNTS) stays the paper's pure drain
    latency ``base_i / r_i``, deliberately penalty-free, for two reasons.
    Transfers pipeline across requests — they delay each request but consume
    no pool capacity — so the throughput-optimal counts are exactly the flat
    greedy's; and a transfer penalty is a per-request constant replication
    cannot remove, so folding it into the rank pours replicas into taxed
    stages to "compensate" a latency no replica removes while the true
    bottleneck pools saturate (the communication-blind failure mode,
    inverted — we measured p99 blowing up 40x that way).  Load-dependent
    penalty/queueing trade-offs belong to the ``latency_aware`` policy,
    which prices the stage entry transfer into its delay score
    (``queueing_allocate(extra_delay=)``).

    Args:
      home_chip:    (N,) chip of each unit's mandatory first copy (replica 0).
      unit_penalty: (N, K) comm penalty, in latency units, of serving unit
        ``i`` from chip ``k`` — typically ``transfer_cycles(src_i, k, bytes_i)``.
      chip_free:    (K,) free capacity per chip AFTER mandatory copies; the
        caller's array is copied, not consumed.

    With one chip the chip choice is trivial and the loop performs
    bit-for-bit the same float comparisons as ``greedy_allocate`` — the flat
    allocator is recovered exactly as the single-chip special case (pinned
    by the golden-equivalence suite).  Stops, as in the paper, when the
    current slowest unit can no longer be afforded — by budget *or* by chip
    capacity.  Returned ``latency`` is the effective per-unit latency
    (drain + final penalty).
    """
    base_latency = np.asarray(base_latency, dtype=np.float64)
    unit_cost = np.asarray(unit_cost, dtype=np.float64)
    if base_latency.shape != unit_cost.shape:
        raise ValueError(
            f"base_latency {base_latency.shape} vs unit_cost {unit_cost.shape}"
        )
    n = base_latency.size
    home = np.asarray(home_chip, dtype=np.int64)
    pen = np.asarray(unit_penalty, dtype=np.float64)
    free = np.asarray(chip_free, dtype=np.float64).copy()
    K = free.size
    if pen.shape != (n, K):
        raise ValueError(f"unit_penalty {pen.shape} != ({n}, {K})")
    if home.shape != (n,):
        raise ValueError(f"home_chip has shape {home.shape}, expected ({n},)")
    replicas = (
        np.ones(n, dtype=np.int64)
        if initial_replicas is None
        else np.asarray(initial_replicas, dtype=np.int64).copy()
    )
    if n == 0:
        return PlacedAllocationResult(
            replicas, base_latency.copy(), 0.0, float(budget), [], np.zeros(0)
        )
    if np.any(replicas < 1):
        raise ValueError("every unit needs at least one replica")
    # initial replicas (the mandatory copy + any warm start) sit at home —
    # and warm-start extras consume their home chip's capacity (chip_free is
    # defined as free AFTER mandatory copies only)
    chips = [home[i] * np.ones(replicas[i], dtype=np.int64) for i in range(n)]
    np.subtract.at(free, home, (replicas - 1) * unit_cost)
    if np.any(free < 0):
        bad = int(np.flatnonzero(free < 0)[0])
        raise ValueError(
            f"warm-start replicas oversubscribe chip {bad} by {-free[bad]} arrays"
        )
    cur_pen = pen[np.arange(n), home]

    heap = [(-base_latency[i] / replicas[i], i) for i in range(n)]
    heapq.heapify(heap)
    spent = 0.0
    remaining = float(budget)
    chip_ids = np.arange(K)
    while heap:
        neg_lat, i = heapq.heappop(heap)
        ok = free >= unit_cost[i]
        if unit_cost[i] > remaining or not ok.any():
            # the paper's stopping rule, extended: the slowest unit cannot be
            # afforded (budget) or physically placed (capacity) — final.
            if audit is not None:
                reason = "budget" if unit_cost[i] > remaining else "capacity"
                audit.stop(reason, i, unit_cost[i], remaining)
            heapq.heappush(heap, (neg_lat, i))
            break
        # cheapest chip in (new max penalty, raw penalty, id) order
        cand = chip_ids[ok]
        new_max = np.maximum(cur_pen[i], pen[i, cand])
        k = cand[np.lexsort((cand, pen[i, cand], new_max))[0]]
        free[k] -= unit_cost[i]
        remaining -= unit_cost[i]
        spent += unit_cost[i]
        replicas[i] += 1
        chips[i] = np.append(chips[i], k)
        cur_pen[i] = max(cur_pen[i], pen[i, k])
        new_lat = base_latency[i] / replicas[i]
        if audit is not None:
            audit.grant(i, unit_cost[i], -neg_lat, new_lat, remaining, chip=k)
        heapq.heappush(heap, (-new_lat, i))

    latency = base_latency / replicas + cur_pen
    return PlacedAllocationResult(
        replicas, latency, spent, remaining, chips, cur_pen
    )


def place_extras(
    replicas: np.ndarray,
    unit_cost: np.ndarray,
    *,
    home_chip: np.ndarray,
    unit_penalty: np.ndarray,
    chip_free: np.ndarray,
) -> list[np.ndarray]:
    """Assign chips to replica counts chosen WITHOUT placement awareness.

    The proportional policies (and the queueing allocator, whose wavefront
    moves are not per-replica) fix replica counts first; this places each
    unit's extra replicas greedily on the affordable chip with the lowest
    (penalty, id), walking units in index order (deterministic).  Used by
    ``core.cim.topology.allocate_placed`` for every policy that does not go
    through ``greedy_allocate_placed``.  Raises if capacity cannot hold the
    counts (callers budget extras from total free arrays, so this only
    triggers when fragmentation across chips is pathological).
    """
    replicas = np.asarray(replicas, dtype=np.int64)
    cost = np.asarray(unit_cost, dtype=np.float64)
    home = np.asarray(home_chip, dtype=np.int64)
    pen = np.asarray(unit_penalty, dtype=np.float64)
    free = np.asarray(chip_free, dtype=np.float64).copy()
    chip_ids = np.arange(free.size)
    out: list[np.ndarray] = []
    for i in range(replicas.size):
        chips = [int(home[i])]
        for _ in range(int(replicas[i]) - 1):
            ok = free >= cost[i]
            if not ok.any():
                raise ValueError(
                    f"no chip can hold another replica of unit {i} "
                    f"(cost {cost[i]}, free {free})"
                )
            cand = chip_ids[ok]
            k = cand[np.lexsort((cand, pen[i, cand]))[0]]
            free[k] -= cost[i]
            chips.append(int(k))
        out.append(np.asarray(chips, dtype=np.int64))
    return out


@dataclass(frozen=True)
class BatchAllocationResult:
    """Structure-of-arrays ``AllocationResult`` for C independent configs."""

    replicas: np.ndarray  # (C, N) int64
    latency: np.ndarray  # (C, N)
    spent: np.ndarray  # (C,)
    leftover: np.ndarray  # (C,)

    @property
    def makespan(self) -> np.ndarray:  # (C,)
        if self.latency.shape[1] == 0:
            return np.zeros(len(self))
        return self.latency.max(axis=1)

    def __len__(self) -> int:
        return self.replicas.shape[0]


_GREEDY_BATCH_JIT: dict = {}


def greedy_batch_kernel(base, cost, budget, r0):
    """The lock-step batched greedy as a TRACEABLE jax function.

    (C, N) ``base`` latencies / ``cost`` per replica, (C,) ``budget``,
    (C, N) ``r0`` initial replicas -> (replicas (C, N) float, leftover (C,)).
    Plain jax ops end to end, so callers may either jit it standalone
    (``greedy_allocate_batch``) or inline it inside a larger traced program
    — the fused DSE pipeline (``repro.dse.fused``) calls it between the
    in-graph profile derivation and the vmapped throughput kernel, with no
    host round-trip on either side.

    Two phases, both exactly replicating the scalar heap loop:

    1.  *Bulk water-fill by bisection.*  The greedy's max-latency is
        non-increasing, so for any makespan target ``lam`` the state
        ``r_i = max(r0_i, ceil(base_i / lam))`` is a state the scalar greedy
        passes through — provided its cost fits the budget (every
        intermediate grant is then affordable, so the scalar stopping rule
        cannot fire early).  We bisect ``lam`` to the tightest affordable
        state, then back off by 1e-9 relative so grants at levels within
        roundoff of the boundary are left to phase 2 (whose tie-breaking is
        exact) rather than resolved by float ceil.
    2.  *Lock-step residual loop.*  Grant the argmax-latency unit of every
        config one replica per iteration; a config freezes the moment its
        argmax is unaffordable (the paper's stopping rule — argmax ties
        resolve to the lowest index, matching the scalar heap order).
    """
    import jax
    import jax.numpy as jnp

    N = base.shape[1]

    def r_of(lam):
        return jnp.maximum(r0, jnp.ceil(base / lam[:, None]))

    def spend_of(r):
        return ((r - r0) * cost).sum(axis=1)

    lat0 = base / r0
    hi = jnp.maximum(lat0.max(axis=1), 1e-300)  # degenerate all-zero rows
    min_cost = cost.min(axis=1)
    # strictly below the final greedy makespan -> provably infeasible
    lo = hi / (2.0 * (2.0 + jnp.maximum(budget, 0.0) / min_cost))

    def bisect(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        feasible = spend_of(r_of(mid)) <= budget
        return jnp.where(feasible, lo, mid), jnp.where(feasible, mid, hi)

    lo, hi = jax.lax.fori_loop(0, 80, bisect, (lo, hi))
    r = r_of(hi * (1.0 + 1e-9))
    rem = budget - spend_of(r)

    idx = jnp.arange(N)

    def not_done(state):
        return ~state[2].all()

    def grant(state):
        r, rem, done = state
        lat = base / r
        i = lat.argmax(axis=1)  # first max == scalar heap tie order
        ci = jnp.take_along_axis(cost, i[:, None], axis=1)[:, 0]
        ok = (ci <= rem) & ~done
        r = r + ((idx[None, :] == i[:, None]) & ok[:, None])
        rem = rem - jnp.where(ok, ci, 0.0)
        return r, rem, done | ~ok

    done = jnp.zeros(base.shape[0], dtype=bool)
    r, rem, done = jax.lax.while_loop(not_done, grant, (r, rem, done))
    return r, rem


def _greedy_batch_kernel():
    """Build (once) the standalone jitted entry over ``greedy_batch_kernel``."""
    import jax

    return jax.jit(greedy_batch_kernel)


def greedy_allocate_batch(
    base_latency: np.ndarray,
    unit_cost: np.ndarray,
    budgets: np.ndarray,
    *,
    initial_replicas: np.ndarray | None = None,
) -> BatchAllocationResult:
    """Vectorized ``greedy_allocate`` over C configs, lock-step in jnp.

    ``base_latency`` / ``unit_cost`` / ``initial_replicas`` broadcast from
    (N,) to (C, N); ``budgets`` is (C,).  Replica counts are element-wise
    identical to looping the scalar allocator (the property suite pins
    this); ``spent`` / ``leftover`` agree to float roundoff.  Runs in
    float64 under ``jax.experimental.enable_x64``.
    """
    budgets = np.atleast_1d(np.asarray(budgets, dtype=np.float64))
    C = budgets.shape[0]
    base = np.atleast_1d(np.asarray(base_latency, dtype=np.float64))
    cost = np.atleast_1d(np.asarray(unit_cost, dtype=np.float64))
    if base.shape[-1] != cost.shape[-1]:
        raise ValueError(f"base_latency {base.shape} vs unit_cost {cost.shape}")
    N = base.shape[-1]
    base = np.ascontiguousarray(np.broadcast_to(base, (C, N)))
    cost = np.ascontiguousarray(np.broadcast_to(cost, (C, N)))
    if np.any(cost <= 0):
        raise ValueError("unit_cost must be strictly positive")
    if initial_replicas is None:
        r0 = np.ones((C, N))
    else:
        r0 = np.ascontiguousarray(
            np.broadcast_to(np.asarray(initial_replicas, dtype=np.float64), (C, N))
        )
        if np.any(r0 < 1):
            raise ValueError("every unit needs at least one replica")
    if N == 0:
        return BatchAllocationResult(
            np.ones((C, 0), dtype=np.int64), base.copy(), np.zeros(C), budgets.copy()
        )

    from jax.experimental import enable_x64

    if "kernel" not in _GREEDY_BATCH_JIT:
        _GREEDY_BATCH_JIT["kernel"] = _greedy_batch_kernel()
    with enable_x64():
        r, rem = _GREEDY_BATCH_JIT["kernel"](base, cost, budgets, r0)
    r = np.asarray(r)
    replicas = r.astype(np.int64)
    spent = ((r - r0) * cost).sum(axis=1)
    return BatchAllocationResult(replicas, base / r, spent, np.asarray(rem))


@dataclass(frozen=True)
class GreedyEventSchedule:
    """The greedy grant sequence as a static, budget-independent table.

    The scalar heap loop is fully determined before it runs: unit ``i``'s
    grant at replica count ``r`` has priority ``base_i / r`` (the latency
    it relieves), priorities of one unit strictly decrease in ``r``, ties
    across units resolve to the lower index (heapq tuple order ==
    ``argmax`` first-max), and the loop stops at the FIRST grant it cannot
    afford — it never skips ahead to cheaper units.  So the whole run is a
    walk down ONE sorted event list, and the stopping point for budget
    ``W`` is simply the longest prefix whose cumulative cost is <= ``W``.

    Why this is *exactly* the heap loop and not an approximation of it:

      * priorities are the very float64 quotients the heap compares, so
        sorting by ``(-key, unit)`` reproduces every comparison;
      * with integer-valued costs and budgets (arrays are indivisible)
        every partial sum is an exact float64 integer below 2**53, so
        ``cumsum[e] <= W`` is bit-for-bit the heap's
        ``cost_i <= remaining`` test;
      * costs are positive, so the cumulative cost is strictly increasing
        and ``searchsorted(cum, W, side="right")`` IS the stopping rule.

    One schedule therefore answers EVERY budget on the same base latencies
    in O(log E) — this is what lets the fused DSE pipeline replace a
    per-chunk bisection + residual ``while_loop`` over (C, N) tensors with
    a single shared table per ADC variant (``repro.dse.fused``).

    Attributes:
      unit: (E,) int64 — receiving unit of each event, priority order.
      key:  (E,) float64 — event priorities, non-increasing.
      cum_cost: (E,) float64 — cumulative cost through each event.
      r0:   (N,) int64 — warm-start replicas (grants count from here).
      max_budget: largest budget this table is complete for.
    """

    unit: np.ndarray
    key: np.ndarray
    cum_cost: np.ndarray
    r0: np.ndarray
    max_budget: float
    base: np.ndarray  # (N,) float64 — the priorities' numerators

    @property
    def n_units(self) -> int:
        return self.r0.size

    def __len__(self) -> int:
        return self.unit.size

    def replicas_at(self, budgets: np.ndarray) -> BatchAllocationResult:
        """Replica counts for C budgets — element-wise identical to running
        ``greedy_allocate`` (or the lock-step batch kernel) per budget.

        Distinct budgets are answered from one incremental walk over the
        event list: O(E + U*N + C log E) for U distinct stopping points,
        instead of the kernel's O(iters * C * N).
        """
        b = np.atleast_1d(np.asarray(budgets, dtype=np.float64))
        if b.size and b.max() > self.max_budget:
            raise ValueError(
                f"budget {b.max()} exceeds schedule coverage {self.max_budget}"
            )
        if np.any(b != np.floor(b)):
            raise ValueError("exact prefix arithmetic needs integral budgets")
        n = self.n_units
        m = np.searchsorted(self.cum_cost, b, side="right")
        uniq, inv = np.unique(m, return_inverse=True)
        snaps = np.empty((uniq.size, n), dtype=np.int64)
        counts = self.r0.copy()
        prev = 0
        for j, stop in enumerate(uniq):
            if stop > prev:
                counts = counts + np.bincount(
                    self.unit[prev:stop], minlength=n
                )
                prev = int(stop)
            snaps[j] = counts
        replicas = snaps[inv]
        spent = (
            np.where(m > 0, self.cum_cost[np.maximum(m - 1, 0)], 0.0)
            if len(self)
            else np.zeros(b.size)
        )
        return BatchAllocationResult(
            replicas, self.base / replicas, spent, b - spent
        )


def greedy_event_schedule(
    base_latency: np.ndarray,
    unit_cost: np.ndarray,
    max_budget: float,
    *,
    initial_replicas: np.ndarray | None = None,
) -> GreedyEventSchedule:
    """Build the sorted grant-event table covering budgets up to ``max_budget``.

    Events are generated per unit down to an estimated water level (with a
    4x safety margin), sorted by ``(-priority, unit)``, and truncated at
    the first event no ``<= max_budget`` run can afford.  A coverage check
    regenerates with more events per unit whenever the truncation point
    could have been preceded by an ungenerated event — the loop terminates
    because at most ``max_budget / min(cost)`` events are ever affordable.
    """
    base = np.atleast_1d(np.asarray(base_latency, dtype=np.float64))
    cost = np.atleast_1d(np.asarray(unit_cost, dtype=np.float64))
    if base.shape != cost.shape:
        raise ValueError(f"base_latency {base.shape} vs unit_cost {cost.shape}")
    if np.any(cost <= 0):
        raise ValueError("unit_cost must be strictly positive")
    if np.any(cost != np.floor(cost)):
        raise ValueError("exact prefix arithmetic needs integral unit costs")
    n = base.size
    r0 = (
        np.ones(n, dtype=np.int64)
        if initial_replicas is None
        else np.asarray(initial_replicas, dtype=np.int64).copy()
    )
    if np.any(r0 < 1):
        raise ValueError("every unit needs at least one replica")
    W = float(max_budget)
    if W != np.floor(W):
        raise ValueError("exact prefix arithmetic needs an integral max_budget")
    if n == 0 or W < np.min(cost):
        return GreedyEventSchedule(
            np.zeros(0, dtype=np.int64), np.zeros(0), np.zeros(0), r0, W, base
        )
    # at most floor(W / cost_i) grants of unit i fit ANY affordable prefix
    cap = np.floor(W / cost).astype(np.int64) + 1
    # water-level estimate: greedy stops near lam with
    # sum_i cost_i * base_i / lam ~= W; generate 4x past it
    lam = float(np.dot(cost, base / r0)) / max(W, 1.0) / 4.0
    if lam > 0:
        K = np.floor(base / (r0 * lam)).astype(np.int64) + 1
        K = np.clip(K, 1, cap)
    else:
        K = cap
    while True:
        units = np.repeat(np.arange(n, dtype=np.int64), K)
        offs = np.concatenate([[0], np.cumsum(K)[:-1]])
        reps = r0[units] + (np.arange(units.size) - np.repeat(offs, K))
        key = base[units] / reps
        order = np.lexsort((units, -key))
        units, key = units[order], key[order]
        cum = np.cumsum(cost[units])
        stop = int(np.searchsorted(cum, W, side="right"))
        if stop == units.size:
            if np.all(K >= cap):  # every affordable event already generated
                break
            K = np.minimum(K * 2, cap)
            continue
        # complete iff every unit's next UNgenerated event ranks after the
        # first rejected one — i.e. strictly below its priority
        next_key = base / (r0 + K)
        short = (next_key >= key[stop]) & (K < cap)
        if not short.any():
            break
        K = np.minimum(np.where(short, K * 2, K), cap)
    return GreedyEventSchedule(
        units[:stop], key[:stop], cum[:stop], r0, W, base
    )


def erlang_c(replicas: np.ndarray, offered: np.ndarray) -> np.ndarray:
    """Erlang-C wait probability P(wait) for M/M/c units, vectorized.

    ``replicas``: (N,) int servers per unit; ``offered``: (N,) offered load
    in erlangs (a = lambda * mean_service).  Units at or beyond saturation
    (a >= c) return 1.0 (the delay formula turns infinite there anyway).
    Computed through the numerically stable Erlang-B recurrence
    ``B(k) = a B(k-1) / (k + a B(k-1))``, run lock-step across units and
    frozen at each unit's own replica count.
    """
    c = np.asarray(replicas, dtype=np.int64)
    a = np.asarray(offered, dtype=np.float64)
    if np.any(c < 1):
        raise ValueError("every unit needs at least one replica")
    B = np.ones_like(a)
    for k in range(1, int(c.max()) + 1):
        aB = a * B
        B = np.where(k <= c, aB / (k + aB), B)
    rho = a / c
    out = B / np.maximum(1.0 - rho * (1.0 - B), 1e-300)
    return np.where(rho >= 1.0, 1.0, np.minimum(out, 1.0))


def queueing_delay(
    replicas: np.ndarray,
    job_rate: np.ndarray,
    mean_service: np.ndarray,
    service_scv: np.ndarray,
    arrival_scv: np.ndarray | float = 1.0,
) -> np.ndarray:
    """Expected queueing wait per job for G/G/c units (Allen-Cunneen).

    ``Wq = P(wait) / (c/s - lambda) * (Ca^2 + Cs^2) / 2`` with the per-unit
    service squared-CV measured from the profile — the input-distribution
    awareness the paper's throughput allocator does not have.  ``arrival_scv``
    is the arrival-process dispersion: 1 for Poisson jobs, ~the batch size
    for Poisson batch arrivals (requests dumping a whole patch batch at
    once).  Saturated units (rho >= 1) return +inf.  Exact for M/M/c; the
    standard approximation otherwise (M/D/c comes out as the familiar half
    of the M/M/c wait).
    """
    c = np.asarray(replicas, dtype=np.float64)
    lam = np.asarray(job_rate, dtype=np.float64)
    s = np.asarray(mean_service, dtype=np.float64)
    scv = np.asarray(service_scv, dtype=np.float64)
    ca2 = np.asarray(arrival_scv, dtype=np.float64)
    a = lam * s
    slack = c / np.maximum(s, 1e-300) - lam  # (c - a) / s
    pw = erlang_c(np.maximum(np.rint(c), 1).astype(np.int64), a)
    wq = pw / np.maximum(slack, 1e-300) * (ca2 + scv) / 2.0
    return np.where(a >= c, np.inf, wq)


def queueing_allocate(
    job_rate: np.ndarray,
    mean_service: np.ndarray,
    service_scv: np.ndarray,
    unit_cost: np.ndarray,
    budget: float,
    *,
    batch_size: np.ndarray | float = 1.0,
    group: np.ndarray | None = None,
    tail_weight: float = 4.6,
    initial_replicas: np.ndarray | None = None,
    extra_delay: np.ndarray | None = None,
) -> AllocationResult:
    """Greedy replica allocation by tail-weighted request delay at a load.

    Where ``greedy_allocate`` equalizes expected *throughput* latencies (the
    paper's objective — only the bottleneck matters), this allocator targets
    the latency a *request* sees at an offered load.  Each unit is a FIFO
    server pool receiving ``job_rate`` jobs per cycle in request-batches of
    ``batch_size``; with ``c`` replicas its delay score is

        D(c) = Shat + tail_weight * Wq(c),    Shat = s * max(batch / c, 1)

    ``Shat`` is the drain of the request's own batch (nearly deterministic —
    it concentrates over the batch), while ``Wq`` is the wait behind prior
    requests — for batch >= c the pool serves one "super-job" per request
    with no Erlang pooling gain (M/G/1 Pollaczek-Khinchine), below that the
    job-level Erlang-C wait applies.  The queueing term is the *variable*
    part of the delay, so a p99 objective weights it by roughly the tail
    ratio of an exponential-like wait: ``tail_weight ~ -ln(1 - 0.99) = 4.6``.

    The objective is ``sum over groups of max_in_group D`` — with ``group``
    = pipeline stage, a stage's latency is its slowest pool's, and stages
    add along the request path (contrast throughput, where only the global
    bottleneck matters).  At high utilization the Wq guard pins the
    allocation to the paper's utilization-equalizing greedy; at low
    utilization it spends the slack bottleneck headroom on shortening the
    whole request path instead.

    ``extra_delay`` (per-unit, additive) folds a replica-count-independent
    delay into the score — the communication penalty of the unit's placement
    on a multi-chip fabric (the stage's entry transfer on its dataflow
    edge).  A stage parked far from its data source scores slower, so the
    wavefront spends replicas shortening the compute of the stages the
    topology already taxes.  ``None`` leaves the score arithmetic untouched
    (the flat single-chip special case, bit-identical to before the hook).

    Greedy loop with *wavefront* moves: per group, the candidate is one
    extra replica for every member within 5% of the group's max (granting
    only the argmax of a near-tied wide stage would barely move its max, so
    single-unit moves systematically starve wide stages).  Grants go to the
    best positive gain per cost; a stabilization pre-phase first buys every
    pool below saturation.  Stops when the budget is out, nothing gains, or
    the best wavefront cannot be afforded (the paper's stopping rule).
    Returns an ``AllocationResult`` whose ``latency`` is the per-unit score
    ``D`` at the final replica counts.
    """
    lam = np.asarray(job_rate, dtype=np.float64)
    s = np.asarray(mean_service, dtype=np.float64)
    scv = np.asarray(service_scv, dtype=np.float64)
    cost = np.asarray(unit_cost, dtype=np.float64)
    if not (lam.shape == s.shape == scv.shape == cost.shape):
        raise ValueError(
            f"shape mismatch: rate {lam.shape}, service {s.shape}, "
            f"scv {scv.shape}, cost {cost.shape}"
        )
    if np.any(cost <= 0):
        raise ValueError("unit_cost must be strictly positive")
    n = lam.size
    batch = np.broadcast_to(np.asarray(batch_size, dtype=np.float64), (n,))
    grp = np.arange(n) if group is None else np.asarray(group, dtype=np.int64)
    if grp.shape != (n,):
        raise ValueError(f"group has shape {grp.shape}, expected ({n},)")
    replicas = (
        np.ones(n, dtype=np.int64)
        if initial_replicas is None
        else np.asarray(initial_replicas, dtype=np.int64).copy()
    )
    if n == 0:
        return AllocationResult(replicas, s.copy(), 0.0, float(budget))
    if np.any(replicas < 1):
        raise ValueError("every unit needs at least one replica")

    if extra_delay is not None:
        extra_delay = np.asarray(extra_delay, dtype=np.float64)
        if extra_delay.shape != (n,):
            raise ValueError(
                f"extra_delay has shape {extra_delay.shape}, expected ({n},)"
            )

    def score(reps, mem=slice(None)):
        """Delay score for the unit subset ``mem`` at replica counts
        ``reps`` (shaped like the subset) — candidate moves only re-score
        their own wave."""
        reps = np.asarray(reps, dtype=np.float64)
        s_, lam_, scv_, batch_ = s[mem], lam[mem], scv[mem], batch[mem]
        shat = s_ * np.maximum(batch_ / reps, 1.0)
        rho = lam_ * s_ / reps
        cv2 = scv_ / np.maximum(batch_, 1.0)
        wq = rho * shat * (1.0 + cv2) / 2.0 / np.maximum(1.0 - rho, 1e-300)
        sub = batch_ < reps  # more lanes than a whole batch: Erlang pooling
        if sub.any():
            wq_er = queueing_delay(
                np.maximum(np.rint(reps), 1).astype(np.int64), lam_, s_, scv_,
                arrival_scv=batch_,  # jobs still land in request-bursts
            )
            wq = np.where(sub, wq_er, wq)
        d = np.where(rho >= 1.0, np.inf, shat + float(tail_weight) * wq)
        if extra_delay is not None:
            d = d + extra_delay[mem]
        return d

    spent, remaining = 0.0, float(budget)

    # pre-phase: buy stability (rho < 1) for the most overloaded unit first
    while True:
        rho = lam * s / replicas
        i = int(np.argmax(rho))
        if rho[i] < 1.0 or cost[i] > remaining:
            break
        replicas[i] += 1
        remaining -= cost[i]
        spent += cost[i]

    members = [np.flatnonzero(grp == g) for g in np.unique(grp)]
    d = score(replicas)  # updated incrementally: a grant only moves its wave
    while True:
        best_wave, best_gain = None, 0.0
        for mem in members:
            dm = d[mem]
            mx = dm.max()
            if not np.isfinite(mx):
                in_wave = ~np.isfinite(dm)
            else:
                in_wave = dm >= 0.95 * mx
            wave = mem[in_wave]
            cst = float(cost[wave].sum())
            if cst > remaining:
                continue
            rest = dm[~in_wave].max() if (~in_wave).any() else -np.inf
            new_mx = max(float(score(replicas[wave] + 1, wave).max()), rest)
            gain = (mx - new_mx) / cst if np.isfinite(mx) else np.inf
            if gain > best_gain:
                best_gain, best_wave = gain, wave
        if best_wave is None:
            break
        replicas[best_wave] += 1
        cst = float(cost[best_wave].sum())
        remaining -= cst
        spent += cst
        d[best_wave] = score(replicas[best_wave], best_wave)
    return AllocationResult(replicas, score(replicas), spent, remaining)


def proportional_allocate(
    weight: np.ndarray,
    unit_cost: np.ndarray,
    budget: float,
) -> AllocationResult:
    """Allocate replicas proportional to `weight` (the prior-work policy).

    This is "weight-based" allocation when `weight` = MACs per layer and
    "performance-based layer-wise" when `weight` = expected cycles per layer.
    Replica counts are the floor of the proportional share (>= 1), with any
    leftover budget distributed by largest fractional remainder.
    """
    weight = np.asarray(weight, dtype=np.float64)
    unit_cost = np.asarray(unit_cost, dtype=np.float64)
    n = weight.size
    replicas = np.ones(n, dtype=np.int64)
    if n == 0 or budget <= 0:
        return AllocationResult(replicas, weight / replicas, 0.0, float(budget))

    total_w = weight.sum()
    # Ideal fractional share of the budget, in cost units, then converted to
    # whole replicas of each unit.
    share = weight / total_w * float(budget)
    extra = np.floor(share / unit_cost).astype(np.int64)
    replicas = replicas + np.maximum(extra, 0)
    spent = float((extra * unit_cost).sum())
    remaining = float(budget) - spent
    # Largest-remainder top-up.
    frac = share / unit_cost - extra
    for i in np.argsort(-frac):
        if unit_cost[i] <= remaining:
            replicas[i] += 1
            remaining -= unit_cost[i]
            spent += unit_cost[i]
    latency = weight / replicas
    return AllocationResult(replicas, latency, spent, remaining)


def proportional_allocate_batch(
    weight: np.ndarray,
    unit_cost: np.ndarray,
    budgets: np.ndarray,
) -> BatchAllocationResult:
    """``proportional_allocate`` over C budgets, vectorized in numpy.

    Element-wise identical to looping the scalar routine: the share /
    floor arithmetic broadcasts unchanged, and ``np.argsort(-frac, axis=1)``
    applies the same introsort per row as the scalar's per-config call, so
    even unstable tie orders agree.  The largest-remainder top-up walks the
    N sorted positions lock-step across configs.
    """
    budgets = np.atleast_1d(np.asarray(budgets, dtype=np.float64))
    weight = np.atleast_1d(np.asarray(weight, dtype=np.float64))
    cost = np.atleast_1d(np.asarray(unit_cost, dtype=np.float64))
    C = budgets.shape[0]
    N = weight.shape[-1]
    weight = np.broadcast_to(weight, (C, N))
    cost = np.broadcast_to(cost, (C, N))
    replicas = np.ones((C, N), dtype=np.int64)
    if N == 0 or C == 0:
        return BatchAllocationResult(
            replicas, weight / replicas, np.zeros(C), budgets.copy()
        )

    act = budgets > 0  # scalar early-returns all-ones below/at zero budget
    total_w = weight.sum(axis=1)
    share = weight / total_w[:, None] * budgets[:, None]
    extra = np.where(act[:, None], np.floor(share / cost).astype(np.int64), 0)
    replicas = replicas + np.maximum(extra, 0)
    spent = (extra * cost).sum(axis=1)
    remaining = budgets - spent
    # largest-remainder top-up, lock-step over the N sorted positions
    frac = share / cost - extra
    order = np.argsort(-frac, axis=1)
    rows = np.arange(C)
    for k in range(N):
        i = order[:, k]
        ci = cost[rows, i]
        ok = act & (ci <= remaining)
        replicas[rows[ok], i[ok]] += 1
        remaining = np.where(ok, remaining - ci, remaining)
        spent = np.where(ok, spent + ci, spent)
    return BatchAllocationResult(replicas, weight / replicas, spent, remaining)
