"""Discrete-event CIM fabric runtime.

The analytic model (``core/cim/simulate.py``) answers "what is the
steady-state pipelined throughput of this allocation"; this package answers
the serving questions that need explicit time: tail latency under bursty
arrivals, behavior when the live input distribution drifts off the profile
(with online re-allocation from a reserve), and several networks sharing
one fabric.  It executes the same ``NetworkSpec`` / ``NetworkProfile`` /
``Allocation`` objects as the analytic model and agrees with it in the
closed-loop steady state (asserted in tests).

Two equivalent engines: the event calendar (``FabricSim``, scalar, supports
drift re-allocation and timelines) and the packed virtual-time kernel
(``VirtualTimeFabric``, jit+vmap over batches of (allocation, trace) pairs,
bit-identical to the event engine) — the latter powers latency-aware
provisioning (``provision_latency_aware``) and the DSE latency columns.

Fleet-scale replay lives in ``fleet``: a streaming variant of the kernel
(in-kernel hashed service sampling + fixed-size latency sketches, so
memory stays O(lanes) at million-request traces), plus segmented trace
replay that re-allocates at control-interval boundaries with warm-started
``greedy_allocate`` and charges array-reprogramming stalls in-kernel.

Fault tolerance lives in ``failures``: seeded per-array Weibull hazards
with chip-correlated burst domains and optional repair, compiled
(``degrade_plan``) into a segment trajectory BOTH engines replay
bit-identically — ``FabricSim(failures=plan)`` on the event calendar,
``run_trace_failures`` on the segmented vtime kernel — with spare-pool
re-placement, reprogramming stalls, and an availability metric; a
``RetryPolicy`` governs event-engine request shedding on zero-survivor
blocks.
"""

from .arrivals import (
    MMPP2,
    ClosedLoop,
    PoissonOpen,
    SinusoidalPoisson,
    TraceReplay,
    arrival_times,
)
from .dispatch import FabricSim
from .drift import DriftConfig, OnlineReallocator, shift_profile
from .events import EventCalendar, PoolStats, ServerPool
from .failures import (
    DegradePlan,
    FailureEvent,
    FailureTrace,
    RetryPolicy,
    degrade_plan,
    degrade_plan_from_allocs,
    failure_step_schedule,
    generate_failure_events,
    generate_failure_trace,
    lane_chips,
)
from .fleet import (
    FleetResult,
    SegmentedReplayResult,
    SegmentReport,
    run_stream,
    run_trace_failures,
    run_trace_segments,
    segment_growth_plan,
)
from .metrics import (
    FabricResult,
    FabricStats,
    LatencySketch,
    LatencyStats,
    ReallocationEvent,
    SketchConfig,
    latency_stats,
    steady_throughput,
)
from .telemetry import (
    NULL_TELEMETRY,
    Telemetry,
    get_telemetry,
    set_telemetry,
    telemetry_session,
)
from .tenancy import (
    SharedAllocation,
    Tenant,
    allocate_shared,
    fairness_report,
    run_tenants,
)
from .vtime import (
    CoarsenConfig,
    VTResult,
    VirtualTimeFabric,
    hash_service_indices,
    provision_latency_aware,
    refine_latency_aware,
    sample_service_indices,
)

__all__ = [
    "ClosedLoop",
    "MMPP2",
    "PoissonOpen",
    "SinusoidalPoisson",
    "TraceReplay",
    "arrival_times",
    "FleetResult",
    "SegmentReport",
    "SegmentedReplayResult",
    "run_stream",
    "run_trace_failures",
    "run_trace_segments",
    "segment_growth_plan",
    "DegradePlan",
    "FailureEvent",
    "FailureTrace",
    "RetryPolicy",
    "degrade_plan",
    "degrade_plan_from_allocs",
    "failure_step_schedule",
    "generate_failure_events",
    "generate_failure_trace",
    "lane_chips",
    "FabricSim",
    "DriftConfig",
    "OnlineReallocator",
    "shift_profile",
    "EventCalendar",
    "PoolStats",
    "ServerPool",
    "FabricResult",
    "FabricStats",
    "LatencySketch",
    "LatencyStats",
    "SketchConfig",
    "Telemetry",
    "NULL_TELEMETRY",
    "get_telemetry",
    "set_telemetry",
    "telemetry_session",
    "ReallocationEvent",
    "latency_stats",
    "steady_throughput",
    "SharedAllocation",
    "Tenant",
    "allocate_shared",
    "fairness_report",
    "run_tenants",
    "CoarsenConfig",
    "VTResult",
    "VirtualTimeFabric",
    "hash_service_indices",
    "provision_latency_aware",
    "refine_latency_aware",
    "sample_service_indices",
]
