"""Telemetry recorder: counters / gauges / histograms / spans.

The observability spine of the fabric stack.  Producers (the event engine's
pool stats, the DSE caches, the benchmark harness) talk to ONE tiny
interface — ``count`` / ``gauge`` / ``observe`` / ``span`` / ``timed`` — and
consumers read a JSON-friendly ``snapshot()``.

Zero overhead when off, by construction: the process-global recorder
defaults to ``NULL_TELEMETRY``, whose methods are empty single-statement
no-ops, and the hot paths that accumulate per-job statistics (``ServerPool``
stats, the virtual-time scan accumulators) are gated on their own
``stats``/``collect_stats`` flags — with the flag off the instrumented code
is never executed at all, so instrumented builds are bit-identical AND
cycle-identical to uninstrumented ones (pinned by the telemetry bench:
``BENCH_telemetry.json``).

Wall-clock spans use ``time.perf_counter``; simulated-time spans (request
stage residence in fabric cycles) are exported by ``repro.obs.trace`` from
``FabricSim`` stats rather than recorded here — the recorder never injects
host time into simulated time.
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "Span",
    "Telemetry",
    "NULL_TELEMETRY",
    "get_telemetry",
    "set_telemetry",
    "telemetry_session",
]


@dataclass(frozen=True)
class Span:
    """One named interval, in seconds (wall clock) or any caller unit."""

    name: str
    start: float
    end: float
    attrs: dict = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.end - self.start


class Telemetry:
    """Accumulating recorder.  All methods are O(1) appends/adds; nothing
    here is thread-safe (the simulators are single-threaded) and nothing
    samples host state behind the caller's back."""

    enabled = True

    def __init__(self):
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.histograms: dict[str, list] = {}
        self.spans: list[Span] = []

    # ------------------------------------------------------------- recording
    def count(self, name: str, value: float = 1.0) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + value

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def gauge_max(self, name: str, value: float) -> None:
        """Monotone-max gauge: keeps the high-water mark across updates
        (peak RSS, peak in-flight) instead of the last write."""
        cur = self.gauges.get(name)
        v = float(value)
        self.gauges[name] = v if cur is None or v > cur else cur

    def observe(self, name: str, value: float) -> None:
        self.histograms.setdefault(name, []).append(float(value))

    def span(self, name: str, start: float, end: float, **attrs) -> None:
        self.spans.append(Span(name, float(start), float(end), attrs))

    @contextmanager
    def timed(self, name: str, **attrs):
        """Record a wall-clock span (and an ``<name>.s`` histogram sample)."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            t1 = time.perf_counter()
            self.span(name, t0, t1, **attrs)
            self.observe(f"{name}.s", t1 - t0)

    # --------------------------------------------------------------- reading
    def hist_stats(self, name: str) -> dict:
        v = np.asarray(self.histograms.get(name, ()), dtype=np.float64)
        if v.size == 0:
            return {"count": 0}
        return {
            "count": int(v.size),
            "mean": float(v.mean()),
            "min": float(v.min()),
            "p50": float(np.percentile(v, 50.0)),
            "p99": float(np.percentile(v, 99.0)),
            "max": float(v.max()),
        }

    def snapshot(self) -> dict:
        """JSON-serializable view of everything recorded so far."""
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {k: self.hist_stats(k) for k in self.histograms},
            "spans": [
                {"name": s.name, "start": s.start, "end": s.end, **s.attrs}
                for s in self.spans
            ],
        }

    def reset(self) -> None:
        self.counters.clear()
        self.gauges.clear()
        self.histograms.clear()
        self.spans.clear()


class _NullTelemetry(Telemetry):
    """The compiled-out recorder: every method is a no-op, so call sites can
    stay unconditional without paying for dict updates."""

    enabled = False

    def count(self, name, value=1.0):
        pass

    def gauge(self, name, value):
        pass

    def gauge_max(self, name, value):
        pass

    def observe(self, name, value):
        pass

    def span(self, name, start, end, **attrs):
        pass

    @contextmanager
    def timed(self, name, **attrs):
        yield


NULL_TELEMETRY = _NullTelemetry()
_GLOBAL: Telemetry = NULL_TELEMETRY


def get_telemetry() -> Telemetry:
    """The process-global recorder (``NULL_TELEMETRY`` unless a session is
    active).  Library code calls this at use time, never at import time, so
    enabling telemetry mid-process takes effect everywhere."""
    return _GLOBAL


def set_telemetry(t: Telemetry | None) -> Telemetry:
    """Install ``t`` as the global recorder (None -> NULL) and return it."""
    global _GLOBAL
    _GLOBAL = NULL_TELEMETRY if t is None else t
    return _GLOBAL


@contextmanager
def telemetry_session():
    """Scoped recorder: installs a fresh ``Telemetry`` globally, yields it,
    and restores the previous recorder on exit."""
    prev = _GLOBAL
    t = set_telemetry(Telemetry())
    try:
        yield t
    finally:
        set_telemetry(prev)
