"""Request arrival processes for the fabric runtime.

Three shapes cover the serving scenarios we care about:

  * ``ClosedLoop``   — a fixed population of in-flight requests; a completed
                       request is immediately replaced (throughput mode —
                       this is the regime the analytic model's steady-state
                       pipelined throughput describes).
  * ``PoissonOpen``  — open-loop Poisson arrivals at a target rate,
                       independent of completions (tail-latency mode).
  * ``TraceReplay``  — explicit arrival timestamps, e.g. recorded traffic.

Times are in fabric clock cycles throughout; convert at the edges.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["ClosedLoop", "PoissonOpen", "TraceReplay", "arrival_times"]


@dataclass(frozen=True)
class ClosedLoop:
    n_requests: int
    concurrency: int = 8


@dataclass(frozen=True)
class PoissonOpen:
    n_requests: int
    rate_per_cycle: float  # mean arrivals per clock cycle
    seed: int = 0

    @staticmethod
    def from_ips(n_requests: int, ips: float, clock_hz: float, seed: int = 0) -> "PoissonOpen":
        return PoissonOpen(n_requests, ips / clock_hz, seed)


@dataclass(frozen=True)
class TraceReplay:
    times: np.ndarray  # (N,) nondecreasing arrival times in cycles


ArrivalProcess = ClosedLoop | PoissonOpen | TraceReplay


def arrival_times(proc: ArrivalProcess) -> np.ndarray | None:
    """Explicit arrival times for open-loop processes; None for closed-loop
    (closed-loop admissions depend on completions and are resolved by the
    engine).

    Edge cases are part of the contract: an empty trace is a legal zero-
    request workload; duplicate timestamps (simultaneous arrivals, recorded
    bursts) are legal and dispatch in request order; a time running
    *backwards* is a data error and is rejected with the first offending
    position.
    """
    if isinstance(proc, ClosedLoop):
        return None
    if isinstance(proc, PoissonOpen):
        if not proc.rate_per_cycle > 0:
            raise ValueError(
                f"PoissonOpen rate_per_cycle must be positive, got {proc.rate_per_cycle}"
            )
        rng = np.random.default_rng(proc.seed)
        gaps = rng.exponential(1.0 / proc.rate_per_cycle, size=proc.n_requests)
        return np.cumsum(gaps)
    if isinstance(proc, TraceReplay):
        t = np.asarray(proc.times, dtype=np.float64)
        if t.ndim != 1:
            raise ValueError(f"trace times must be 1-D, got shape {t.shape}")
        bad = np.flatnonzero(np.diff(t) < 0)
        if bad.size:
            i = int(bad[0]) + 1
            raise ValueError(
                f"trace times must be nondecreasing: times[{bad[0]}]={t[bad[0]]} "
                f"> times[index {i}]={t[i]}"
            )
        return t
    raise TypeError(f"unknown arrival process {proc!r}")
