"""Request arrival processes for the fabric runtime.

Three shapes cover the serving scenarios we care about:

  * ``ClosedLoop``   — a fixed population of in-flight requests; a completed
                       request is immediately replaced (throughput mode —
                       this is the regime the analytic model's steady-state
                       pipelined throughput describes).
  * ``PoissonOpen``  — open-loop Poisson arrivals at a target rate,
                       independent of completions (tail-latency mode).
  * ``TraceReplay``  — explicit arrival timestamps, e.g. recorded traffic.

Fleet traces add two non-stationary open-loop generators so diurnal /
bursty workloads don't have to be hand-built:

  * ``SinusoidalPoisson`` — inhomogeneous Poisson with a sinusoidal rate
                       (the diurnal load curve), sampled exactly by
                       thinning a homogeneous process at the peak rate.
  * ``MMPP2``        — 2-state Markov-modulated Poisson process (quiet /
                       burst), the standard bursty-traffic model.

Times are in fabric clock cycles throughout; convert at the edges.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = [
    "ClosedLoop",
    "MMPP2",
    "PoissonOpen",
    "SinusoidalPoisson",
    "TraceReplay",
    "arrival_times",
]


@dataclass(frozen=True)
class ClosedLoop:
    n_requests: int
    concurrency: int = 8


@dataclass(frozen=True)
class PoissonOpen:
    n_requests: int
    rate_per_cycle: float  # mean arrivals per clock cycle
    seed: int = 0

    @staticmethod
    def from_ips(n_requests: int, ips: float, clock_hz: float, seed: int = 0) -> "PoissonOpen":
        return PoissonOpen(n_requests, ips / clock_hz, seed)


@dataclass(frozen=True)
class TraceReplay:
    times: np.ndarray  # (N,) nondecreasing arrival times in cycles


@dataclass(frozen=True)
class SinusoidalPoisson:
    """Diurnal traffic: inhomogeneous Poisson with rate
    ``base_rate * (1 + amplitude * sin(2*pi*t/period + phase))``.

    Sampled exactly by thinning a homogeneous Poisson process at the peak
    rate — no discretization, seeded, nondecreasing by construction.
    """

    n_requests: int
    base_rate: float  # mean arrivals per cycle, averaged over a period
    period: float  # cycles per diurnal cycle
    amplitude: float = 0.5  # 0 (flat) .. 1 (rate touches zero at trough)
    phase: float = 0.0
    seed: int = 0


@dataclass(frozen=True)
class MMPP2:
    """Bursty traffic: 2-state Markov-modulated Poisson process.

    The process alternates exponentially-distributed sojourns in a quiet
    state (``rate0``) and a burst state (``rate1``); within each sojourn
    arrivals are Poisson at that state's rate (sampled exactly: Poisson
    count + sorted uniform order statistics per sojourn).
    """

    n_requests: int
    rate0: float  # arrivals per cycle in the quiet state
    rate1: float  # arrivals per cycle in the burst state
    mean_sojourn0: float  # cycles, mean dwell in the quiet state
    mean_sojourn1: float  # cycles, mean dwell in the burst state
    seed: int = 0


ArrivalProcess = ClosedLoop | PoissonOpen | TraceReplay | SinusoidalPoisson | MMPP2


def _sinusoidal_times(p: SinusoidalPoisson) -> np.ndarray:
    if not p.base_rate > 0:
        raise ValueError(f"base_rate must be positive, got {p.base_rate}")
    if not 0.0 <= p.amplitude <= 1.0:
        raise ValueError(f"amplitude must be in [0, 1], got {p.amplitude}")
    if not p.period > 0:
        raise ValueError(f"period must be positive, got {p.period}")
    rng = np.random.default_rng(p.seed)
    n = int(p.n_requests)
    peak = p.base_rate * (1.0 + p.amplitude)
    out = np.empty(n)
    got, t = 0, 0.0
    while got < n:
        m = max(1024, 2 * (n - got))
        cand = t + np.cumsum(rng.exponential(1.0 / peak, size=m))
        rate = p.base_rate * (
            1.0 + p.amplitude * np.sin(2.0 * np.pi * cand / p.period + p.phase)
        )
        keep = cand[rng.random(m) * peak < rate]
        k = min(keep.size, n - got)
        out[got : got + k] = keep[:k]
        got += k
        t = float(cand[-1])
    return out


def _mmpp2_times(p: MMPP2) -> np.ndarray:
    if p.rate0 < 0 or p.rate1 < 0 or (p.rate0 == 0 and p.rate1 == 0):
        raise ValueError(f"MMPP2 needs nonnegative rates, not both zero: {p.rate0}, {p.rate1}")
    if not (p.mean_sojourn0 > 0 and p.mean_sojourn1 > 0):
        raise ValueError("MMPP2 mean sojourns must be positive")
    rng = np.random.default_rng(p.seed)
    n = int(p.n_requests)
    rates = (p.rate0, p.rate1)
    sojourns = (p.mean_sojourn0, p.mean_sojourn1)
    chunks, got, t, state = [], 0, 0.0, 0
    while got < n:
        dur = float(rng.exponential(sojourns[state]))
        lam = rates[state]
        k = int(rng.poisson(lam * dur)) if lam > 0 and dur > 0 else 0
        if k:
            chunks.append(t + np.sort(rng.random(k)) * dur)
            got += k
        t += dur
        state ^= 1
    return np.concatenate(chunks)[:n]


def arrival_times(proc: ArrivalProcess) -> np.ndarray | None:
    """Explicit arrival times for open-loop processes; None for closed-loop
    (closed-loop admissions depend on completions and are resolved by the
    engine).

    Edge cases are part of the contract: an empty trace is a legal zero-
    request workload; duplicate timestamps (simultaneous arrivals, recorded
    bursts) are legal and dispatch in request order; a time running
    *backwards* is a data error and is rejected with the first offending
    position.
    """
    if isinstance(proc, ClosedLoop):
        return None
    if isinstance(proc, PoissonOpen):
        if not proc.rate_per_cycle > 0:
            raise ValueError(
                f"PoissonOpen rate_per_cycle must be positive, got {proc.rate_per_cycle}"
            )
        rng = np.random.default_rng(proc.seed)
        gaps = rng.exponential(1.0 / proc.rate_per_cycle, size=proc.n_requests)
        return np.cumsum(gaps)
    if isinstance(proc, SinusoidalPoisson):
        return _sinusoidal_times(proc)
    if isinstance(proc, MMPP2):
        return _mmpp2_times(proc)
    if isinstance(proc, TraceReplay):
        t = np.asarray(proc.times, dtype=np.float64)
        if t.ndim != 1:
            raise ValueError(f"trace times must be 1-D, got shape {t.shape}")
        bad = np.flatnonzero(np.diff(t) < 0)
        if bad.size:
            i = int(bad[0]) + 1
            raise ValueError(
                f"trace times must be nondecreasing: times[{bad[0]}]={t[bad[0]]} "
                f"> times[index {i}]={t[i]}"
            )
        return t
    raise TypeError(f"unknown arrival process {proc!r}")
