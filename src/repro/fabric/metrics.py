"""Result containers + latency/throughput/utilization accounting."""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "FabricStats",
    "LatencyStats",
    "ReallocationEvent",
    "FabricResult",
    "latency_stats",
    "percentile_kernel",
    "steady_throughput",
]


def percentile_kernel(xp, lat, qs):
    """Latency percentiles as pure array algebra over the module ``xp``.

    The ONE implementation shared by the scalar accounting path
    (``latency_stats``, ``xp=numpy``) and the jitted virtual-time fabric
    kernel (``fabric.vtime.run_fabric_kernel``, ``xp=jax.numpy``), so the
    in-kernel reduction cannot drift from the reference: both evaluate
    ``xp.percentile`` (linear interpolation) on the same float64 latencies.
    ``lat`` may be any shape reduced over its last axis by the caller's
    convention (1-D here); ``qs`` is a sequence of percentile levels.
    Callers guard the empty case (percentiles of zero requests are defined
    as zeros at the result-container level, not here).
    """
    return xp.percentile(lat, xp.asarray(qs))


@dataclass(frozen=True)
class LatencyStats:
    n: int
    mean: float
    p50: float
    p95: float
    p99: float
    max: float

    def scaled(self, k: float) -> "LatencyStats":
        return LatencyStats(self.n, self.mean * k, self.p50 * k, self.p95 * k, self.p99 * k, self.max * k)


def latency_stats(latencies: np.ndarray) -> LatencyStats:
    lat = np.asarray(latencies, dtype=np.float64)
    if lat.size == 0:
        return LatencyStats(0, 0.0, 0.0, 0.0, 0.0, 0.0)
    p50, p95, p99 = percentile_kernel(np, lat, (50.0, 95.0, 99.0))
    return LatencyStats(int(lat.size), float(lat.mean()), float(p50), float(p95), float(p99), float(lat.max()))


def steady_throughput(
    completions: np.ndarray, warmup_frac: float = 0.25, clock_hz: float | None = None
) -> float:
    """Steady-state rate from completion timestamps, discarding the pipeline
    fill: rate over the completions after the ``warmup_frac`` quantile.
    Returns requests/cycle, or requests/sec when ``clock_hz`` is given."""
    c = np.sort(np.asarray(completions, dtype=np.float64))
    if c.size < 2:
        return 0.0
    w = min(int(c.size * warmup_frac), c.size - 2)
    span = c[-1] - c[w]
    if span <= 0:
        return 0.0
    rate = (c.size - 1 - w) / span
    return rate * clock_hz if clock_hz else rate


@dataclass(frozen=True)
class ReallocationEvent:
    time: float  # cycles, when drift tripped
    stall_cycles: float  # fabric frozen for this long (array reprogramming)
    arrays_added: int
    divergence: float  # monitor statistic that tripped the threshold


@dataclass
class FabricStats:
    """Per-layer telemetry from an instrumented event-engine run
    (``FabricSim(stats=True)``) — the barrier/stall attribution the
    end-of-run percentiles cannot show.

    Job-cycle accumulators (``layer_service`` / ``layer_queue_wait``) sum
    over every job the layer's pools dispatched; they reconcile with the
    virtual-time kernel's scan-carry accumulators (``VTResult.layer_busy`` /
    ``layer_wait``) to float64 summation-order tolerance (rtol 1e-9, pinned
    in tests).  ``layer_reprogram`` is in replica-cycles x width =
    array-cycles, directly comparable to ``FabricResult.layer_capacity``.
    ``stage_entry`` / ``stage_exit`` are per-(request, stage) residence
    bounds — the raw material of the Perfetto request tracks.
    """

    layer_service: np.ndarray  # (L,) job-cycles of service dispatched
    layer_queue_wait: np.ndarray  # (L,) job-cycles waiting for a free replica
    layer_xfer: np.ndarray  # (L,) cycles of stage-entry transfer, all requests
    layer_reprogram: np.ndarray  # (L,) array-cycles frozen for reprogramming
    layer_jobs: np.ndarray  # (L,) int64 jobs dispatched
    replica_busy: tuple  # per layer: tuple of per-pool (D,) busy job-cycles
    stage_entry: np.ndarray  # (N, L) request arrival at each stage
    stage_exit: np.ndarray  # (N, L) request completion of each stage
    # (L,) array-cycles the pools' replicas were OCCUPIED (barrier-inclusive:
    # a layer-wise duplicate charges the per-patch barrier max to all its
    # arrays).  occupied - FabricResult.layer_busy = intra-layer barrier waste
    layer_occupied: np.ndarray | None = None

    def replica_imbalance(self) -> np.ndarray:
        """(L,) max/mean busy cycles over the layer's replica lanes — 1.0 is
        perfectly balanced load across replicas."""
        out = np.ones(len(self.replica_busy))
        for i, pools in enumerate(self.replica_busy):
            lanes = np.concatenate(pools)
            m = lanes.mean()
            if m > 0:
                out[i] = float(lanes.max() / m)
        return out


@dataclass
class FabricResult:
    """One fabric run: per-request timings + per-pool utilization."""

    policy: str
    clock_hz: float
    arrivals: np.ndarray  # (N,) cycles
    completions: np.ndarray  # (N,) cycles
    layer_busy: np.ndarray  # (L,) busy array-cycles
    layer_arrays: np.ndarray  # (L,) arrays alive at the end (servers x width)
    # (L,) array-cycles of capacity over the run; differs from
    # layer_arrays * makespan when replicas came online mid-run (drift growth)
    layer_capacity: np.ndarray | None = None
    reallocations: list[ReallocationEvent] = field(default_factory=list)
    tenant: str | None = None
    stats: FabricStats | None = None  # populated by FabricSim(stats=True)

    @property
    def latencies(self) -> np.ndarray:
        return self.completions - self.arrivals

    @property
    def makespan(self) -> float:
        return float(self.completions.max()) if self.completions.size else 0.0

    @property
    def latency(self) -> LatencyStats:
        return latency_stats(self.latencies)

    def latency_ms(self) -> LatencyStats:
        return self.latency.scaled(1e3 / self.clock_hz)

    @property
    def images_per_sec(self) -> float:
        return steady_throughput(self.completions, clock_hz=self.clock_hz)

    @property
    def layer_utilization(self) -> np.ndarray:
        span = self.makespan
        if span <= 0:
            return np.zeros_like(self.layer_busy)
        cap = (
            self.layer_capacity
            if self.layer_capacity is not None
            else self.layer_arrays * span
        )
        return self.layer_busy / cap

    @property
    def mean_utilization(self) -> float:
        u = self.layer_utilization
        return float(u.mean()) if u.size else 0.0
