"""Result containers + latency/throughput/utilization accounting."""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "FabricStats",
    "LatencySketch",
    "LatencyStats",
    "ReallocationEvent",
    "FabricResult",
    "SketchConfig",
    "latency_stats",
    "percentile_kernel",
    "sketch_bucket",
    "sketch_init",
    "sketch_update",
    "steady_throughput",
]


def percentile_kernel(xp, lat, qs):
    """Latency percentiles as pure array algebra over the module ``xp``.

    The ONE implementation shared by the scalar accounting path
    (``latency_stats``, ``xp=numpy``) and the jitted virtual-time fabric
    kernel (``fabric.vtime.run_fabric_kernel``, ``xp=jax.numpy``), so the
    in-kernel reduction cannot drift from the reference: both evaluate
    ``xp.percentile`` (linear interpolation) on the same float64 latencies.
    ``lat`` may be any shape reduced over its last axis by the caller's
    convention (1-D here); ``qs`` is a sequence of percentile levels.
    Callers guard the empty case (percentiles of zero requests are defined
    as zeros at the result-container level, not here).
    """
    return xp.percentile(lat, xp.asarray(qs))


# ---------------------------------------------------------------------------
# Streaming latency sketch
#
# Fleet-scale trace replay cannot materialize a (configs, requests) latency
# matrix — at 10^6 requests the reduction input alone dwarfs the lane state.
# The streaming path keeps a fixed-size sketch in the scan carry instead:
#
#   * a log-spaced bucket histogram (``bins_per_octave`` sub-buckets per
#     power of two), giving quantile estimates with bounded RELATIVE error,
#   * exact running min / max,
#   * exact-order Welford mean / M2 moments.
#
# Bucketing is pure float64 primitive algebra (``frexp`` + multiply + floor)
# so the numpy and jit paths agree bit-for-bit: for ``bins_per_octave`` a
# power of two every intermediate (``2*m``, ``2*m - 1``, ``* F``) is exact in
# float64 (Sterbenz subtraction, exponent-only scaling), hence ``floor`` sees
# the same value under both backends.


@dataclass(frozen=True)
class SketchConfig:
    """Geometry of the log-spaced latency histogram.

    Buckets tile ``[2**min_exp, 2**(min_exp + n_octaves))`` cycles with
    ``bins_per_octave`` equal-width sub-buckets per octave; values outside
    the range clamp into the edge buckets (quantile estimates additionally
    clamp into the exact ``[min, max]``, so degenerate traces stay exact).
    The guaranteed quantile error is RELATIVE: a sub-bucket spans a
    ``1/bins_per_octave`` fraction of its octave, so the midpoint estimate
    of any in-range value is off by at most ``1/(2*bins_per_octave)`` of the
    value; interpolated quantiles (convex combinations of two such order
    statistics) stay within ``rel_error = 1/bins_per_octave`` with slack.
    Defaults: 32 bins/octave (3.1% documented bound) x 44 octaves from 1
    cycle covers every latency the fabric can plausibly produce in 1408
    float64 buckets (~11 KB per config).
    """

    bins_per_octave: int = 32
    min_exp: int = 0
    n_octaves: int = 44

    def __post_init__(self):
        if self.bins_per_octave & (self.bins_per_octave - 1) or self.bins_per_octave < 1:
            raise ValueError(
                f"bins_per_octave must be a power of two for exact float64 "
                f"sub-bucket arithmetic, got {self.bins_per_octave}"
            )
        if self.n_octaves < 1:
            raise ValueError(f"n_octaves must be >= 1, got {self.n_octaves}")

    @property
    def n_bins(self) -> int:
        return self.bins_per_octave * self.n_octaves

    @property
    def rel_error(self) -> float:
        """Documented relative-error bound on quantile estimates."""
        return 1.0 / self.bins_per_octave

    def bucket_lo(self) -> np.ndarray:
        """(n_bins,) lower edge of each bucket, in cycles."""
        F = self.bins_per_octave
        b = np.arange(self.n_bins)
        return 2.0 ** (self.min_exp + b // F) * (1.0 + (b % F) / F)

    def bucket_mid(self) -> np.ndarray:
        """(n_bins,) midpoint representative of each bucket, in cycles."""
        F = self.bins_per_octave
        b = np.arange(self.n_bins)
        return 2.0 ** (self.min_exp + b // F) * (1.0 + (b % F + 0.5) / F)


def sketch_bucket(xp, lat, cfg: SketchConfig):
    """Bucket index of each latency — identical bits under numpy and jit.

    ``frexp`` factors ``v = m * 2**e`` with ``m in [0.5, 1)``; the octave is
    ``e - 1 - min_exp`` and the sub-bucket is ``floor((2m - 1) * F)``, all of
    it exact float64 arithmetic for ``F`` a power of two.
    """
    F = cfg.bins_per_octave
    v = xp.maximum(xp.asarray(lat, dtype=xp.float64), 2.0**cfg.min_exp)
    m, e = xp.frexp(v)
    sub = xp.floor((m * 2.0 - 1.0) * F).astype(xp.int32)
    b = (e.astype(xp.int32) - (cfg.min_exp + 1)) * F + sub
    return xp.clip(b, 0, cfg.n_bins - 1)


def sketch_init(xp, cfg: SketchConfig):
    """Empty in-carry sketch state: (counts, n, min, max, mean, m2)."""
    z = xp.zeros((), dtype=xp.float64)
    return (
        xp.zeros(cfg.n_bins, dtype=xp.float64),
        z,
        xp.asarray(xp.inf, dtype=xp.float64),
        xp.asarray(-xp.inf, dtype=xp.float64),
        z,
        z,
    )


def sketch_update(xp, state, lat, cfg: SketchConfig):
    """Fold one latency into the sketch state (scan-carry friendly).

    The Welford moment updates are sequential with a fixed operation order,
    so numpy and jit replays of the same latency stream agree bit-for-bit.
    """
    counts, n, mn, mx, mean, m2 = state
    b = sketch_bucket(xp, lat, cfg)
    counts = counts + (xp.arange(cfg.n_bins) == b)
    n1 = n + 1.0
    d = lat - mean
    mean = mean + d / n1
    m2 = m2 + d * (lat - mean)
    return (counts, n1, xp.minimum(mn, lat), xp.maximum(mx, lat), mean, m2)


@dataclass(frozen=True)
class LatencySketch:
    """Materialized streaming sketch: quantiles from the histogram (bounded
    relative error), min/max/mean exact by construction."""

    config: SketchConfig
    counts: np.ndarray  # (n_bins,) integer-valued float64
    n: int
    min: float
    max: float
    mean: float
    m2: float

    @classmethod
    def from_state(cls, cfg: SketchConfig, state) -> "LatencySketch":
        counts, n, mn, mx, mean, m2 = (np.asarray(s) for s in state)
        n_int = int(round(float(n)))
        return cls(
            cfg,
            counts,
            n_int,
            float(mn) if n_int else 0.0,
            float(mx) if n_int else 0.0,
            float(mean),
            float(m2),
        )

    @classmethod
    def from_latencies(
        cls, latencies, cfg: SketchConfig = SketchConfig()
    ) -> "LatencySketch":
        """Vectorized numpy reference: bucket counts are EXACTLY what a
        sequential ``sketch_update`` replay produces (same bucket algebra);
        mean/m2 use vectorized reductions, so they match the streaming
        moments only to float64 summation-order tolerance."""
        lat = np.asarray(latencies, dtype=np.float64).ravel()
        if lat.size == 0:
            return cls(cfg, np.zeros(cfg.n_bins), 0, 0.0, 0.0, 0.0, 0.0)
        counts = np.bincount(
            sketch_bucket(np, lat, cfg), minlength=cfg.n_bins
        ).astype(np.float64)
        mean = float(lat.mean())
        return cls(
            cfg,
            counts,
            int(lat.size),
            float(lat.min()),
            float(lat.max()),
            mean,
            float(((lat - mean) ** 2).sum()),
        )

    @property
    def variance(self) -> float:
        return self.m2 / self.n if self.n else 0.0

    @property
    def std(self) -> float:
        return math.sqrt(max(self.variance, 0.0))

    def _order_stat(self, cum: np.ndarray, k: int) -> float:
        """Midpoint estimate of the k-th (0-based) order statistic, clamped
        into the exact [min, max] envelope.  The extreme order statistics
        ARE the tracked min/max, so p0/p100 are exact even for data outside
        the histogram range."""
        if k <= 0:
            return self.min
        if k >= self.n - 1:
            return self.max
        b = int(np.searchsorted(cum, k, side="right"))
        mid = self.config.bucket_mid()[min(b, self.config.n_bins - 1)]
        return float(np.clip(mid, self.min, self.max))

    def quantile(self, q: float) -> float:
        """np.percentile-compatible linear-interpolation quantile estimate.

        Both neighboring order statistics are estimated from the histogram
        and interpolated — a convex combination of two midpoint estimates,
        each within ``rel_error/2`` of its true order statistic, so the
        result is within ``config.rel_error`` of ``np.percentile`` on
        in-range data (exact on constant / single-element streams via the
        [min, max] clamp).
        """
        if self.n == 0:
            return 0.0
        t = q / 100.0 * (self.n - 1)
        lo, hi = math.floor(t), math.ceil(t)
        cum = np.cumsum(self.counts)
        v_lo = self._order_stat(cum, lo)
        v_hi = v_lo if hi == lo else self._order_stat(cum, hi)
        return v_lo + (t - lo) * (v_hi - v_lo)

    def percentiles(self, qs) -> np.ndarray:
        return np.asarray([self.quantile(float(q)) for q in qs])

    @property
    def p50(self) -> float:
        return self.quantile(50.0)

    @property
    def p95(self) -> float:
        return self.quantile(95.0)

    @property
    def p99(self) -> float:
        return self.quantile(99.0)

    def merge(self, other: "LatencySketch") -> "LatencySketch":
        """Combine two segment sketches: counts add exactly; moments merge
        via Chan's parallel update (float64, not bit-exact vs sequential)."""
        if self.config != other.config:
            raise ValueError("cannot merge sketches with different SketchConfig")
        if other.n == 0:
            return self
        if self.n == 0:
            return other
        n = self.n + other.n
        d = other.mean - self.mean
        return LatencySketch(
            self.config,
            self.counts + other.counts,
            n,
            min(self.min, other.min),
            max(self.max, other.max),
            self.mean + d * other.n / n,
            self.m2 + other.m2 + d * d * self.n * other.n / n,
        )

    @property
    def stats(self) -> LatencyStats:
        return LatencyStats(self.n, self.mean, self.p50, self.p95, self.p99, self.max)


@dataclass(frozen=True)
class LatencyStats:
    n: int
    mean: float
    p50: float
    p95: float
    p99: float
    max: float

    def scaled(self, k: float) -> "LatencyStats":
        return LatencyStats(self.n, self.mean * k, self.p50 * k, self.p95 * k, self.p99 * k, self.max * k)


def latency_stats(latencies: np.ndarray) -> LatencyStats:
    lat = np.asarray(latencies, dtype=np.float64)
    if lat.size == 0:
        return LatencyStats(0, 0.0, 0.0, 0.0, 0.0, 0.0)
    p50, p95, p99 = percentile_kernel(np, lat, (50.0, 95.0, 99.0))
    return LatencyStats(int(lat.size), float(lat.mean()), float(p50), float(p95), float(p99), float(lat.max()))


def steady_throughput(
    completions: np.ndarray, warmup_frac: float = 0.25, clock_hz: float | None = None
) -> float:
    """Steady-state rate from completion timestamps, discarding the pipeline
    fill: rate over the completions after the ``warmup_frac`` quantile.
    Returns requests/cycle, or requests/sec when ``clock_hz`` is given."""
    c = np.sort(np.asarray(completions, dtype=np.float64))
    if c.size < 2:
        return 0.0
    w = min(int(c.size * warmup_frac), c.size - 2)
    span = c[-1] - c[w]
    if span <= 0:
        return 0.0
    rate = (c.size - 1 - w) / span
    return rate * clock_hz if clock_hz else rate


@dataclass(frozen=True)
class ReallocationEvent:
    time: float  # cycles, when drift tripped
    stall_cycles: float  # fabric frozen for this long (array reprogramming)
    arrays_added: int
    divergence: float  # monitor statistic that tripped the threshold


@dataclass
class FabricStats:
    """Per-layer telemetry from an instrumented event-engine run
    (``FabricSim(stats=True)``) — the barrier/stall attribution the
    end-of-run percentiles cannot show.

    Job-cycle accumulators (``layer_service`` / ``layer_queue_wait``) sum
    over every job the layer's pools dispatched; they reconcile with the
    virtual-time kernel's scan-carry accumulators (``VTResult.layer_busy`` /
    ``layer_wait``) to float64 summation-order tolerance (rtol 1e-9, pinned
    in tests).  ``layer_reprogram`` is in replica-cycles x width =
    array-cycles, directly comparable to ``FabricResult.layer_capacity``.
    ``stage_entry`` / ``stage_exit`` are per-(request, stage) residence
    bounds — the raw material of the Perfetto request tracks.
    """

    layer_service: np.ndarray  # (L,) job-cycles of service dispatched
    layer_queue_wait: np.ndarray  # (L,) job-cycles waiting for a free replica
    layer_xfer: np.ndarray  # (L,) cycles of stage-entry transfer, all requests
    layer_reprogram: np.ndarray  # (L,) array-cycles frozen for reprogramming
    layer_jobs: np.ndarray  # (L,) int64 jobs dispatched
    replica_busy: tuple  # per layer: tuple of per-pool (D,) busy job-cycles
    stage_entry: np.ndarray  # (N, L) request arrival at each stage
    stage_exit: np.ndarray  # (N, L) request completion of each stage
    # (L,) array-cycles the pools' replicas were OCCUPIED (barrier-inclusive:
    # a layer-wise duplicate charges the per-patch barrier max to all its
    # arrays).  occupied - FabricResult.layer_busy = intra-layer barrier waste
    layer_occupied: np.ndarray | None = None

    def replica_imbalance(self) -> np.ndarray:
        """(L,) max/mean busy cycles over the layer's replica lanes — 1.0 is
        perfectly balanced load across replicas."""
        out = np.ones(len(self.replica_busy))
        for i, pools in enumerate(self.replica_busy):
            lanes = np.concatenate(pools)
            m = lanes.mean()
            if m > 0:
                out[i] = float(lanes.max() / m)
        return out


@dataclass
class FabricResult:
    """One fabric run: per-request timings + per-pool utilization."""

    policy: str
    clock_hz: float
    arrivals: np.ndarray  # (N,) cycles
    completions: np.ndarray  # (N,) cycles
    layer_busy: np.ndarray  # (L,) busy array-cycles
    layer_arrays: np.ndarray  # (L,) arrays alive at the end (servers x width)
    # (L,) array-cycles of capacity over the run; differs from
    # layer_arrays * makespan when replicas came online mid-run (drift growth)
    layer_capacity: np.ndarray | None = None
    reallocations: list[ReallocationEvent] = field(default_factory=list)
    tenant: str | None = None
    stats: FabricStats | None = None  # populated by FabricSim(stats=True)

    @property
    def latencies(self) -> np.ndarray:
        return self.completions - self.arrivals

    def latency_sketch(self, config: SketchConfig = SketchConfig()) -> LatencySketch:
        """Sketch-backed latency view — the same fixed-size summary the
        streaming fleet replay keeps in-carry, built here from the
        materialized latencies (bucket counts identical by construction)."""
        return LatencySketch.from_latencies(self.latencies, config)

    @property
    def makespan(self) -> float:
        return float(self.completions.max()) if self.completions.size else 0.0

    @property
    def latency(self) -> LatencyStats:
        return latency_stats(self.latencies)

    def latency_ms(self) -> LatencyStats:
        return self.latency.scaled(1e3 / self.clock_hz)

    @property
    def images_per_sec(self) -> float:
        return steady_throughput(self.completions, clock_hz=self.clock_hz)

    @property
    def layer_utilization(self) -> np.ndarray:
        span = self.makespan
        if span <= 0:
            return np.zeros_like(self.layer_busy)
        cap = (
            self.layer_capacity
            if self.layer_capacity is not None
            else self.layer_arrays * span
        )
        return self.layer_busy / cap

    @property
    def mean_utilization(self) -> float:
        u = self.layer_utilization
        return float(u.mean()) if u.size else 0.0
