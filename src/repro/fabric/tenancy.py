"""Multi-tenant fabrics: several networks sharing one array budget.

CIMPool's observation — fabric capacity is the scarce resource, and weights
from more than one model contend for it — lands here as a weighted-fair
extension of the paper's greedy allocator.  Every block of every tenant is a
unit; a tenant's blocks enter the shared greedy heap with their expected
latency scaled by the tenant's weight, so the allocator equalizes
*weighted* block latencies across tenants (weighted max-min fairness): a
weight-2 tenant's slowest block looks twice as urgent as a weight-1
tenant's equally-slow block and soaks up replicas until it is half as slow.

Tenants own disjoint arrays after allocation (a block is never shared), so
the event simulations are independent; only the allocation couples them.

On a multi-chip fabric (``allocate_shared(topology=...)``) tenants are
additionally *placed*: each tenant's blocks land on the shared chip->PE->
array tree sequentially (first-fit in layer order, extras penalty-greedy),
so a tenant whose mandatory copy spills across a link pays the transfer on
its own dataflow edges — the per-tenant ``Placement``s feed straight into
``run_tenants``' simulations.  Replica COUNTS stay the flat weighted-fair
greedy's (bit-identical with or without a topology); only locations and the
resulting transfer delays are added.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.alloc.greedy import greedy_allocate
from ..core.cim.network import NetworkSpec
from ..core.cim.profile import NetworkProfile
from ..core.cim.simulate import (
    ARRAYS_PER_PE,
    Allocation,
    CLOCK_HZ,
    _layer_patch_cycles,
    blockwise_units,
    split_block_dups,
)
from .arrivals import ArrivalProcess
from .dispatch import FabricSim
from .metrics import FabricResult

__all__ = ["Tenant", "SharedAllocation", "allocate_shared", "run_tenants", "fairness_report"]


@dataclass(frozen=True)
class Tenant:
    name: str
    spec: NetworkSpec
    prof: NetworkProfile
    weight: float = 1.0


@dataclass(frozen=True)
class SharedAllocation:
    tenants: tuple[Tenant, ...]
    allocations: tuple[Allocation, ...]  # block-wise, one per tenant
    arrays_total: int
    arrays_used: int
    placements: tuple | None = None  # per-tenant Placement (multi-chip only)

    @property
    def leftover(self) -> int:
        return self.arrays_total - self.arrays_used


def allocate_shared(
    tenants: list[Tenant],
    n_pes: int,
    arrays_per_pe: int = ARRAYS_PER_PE,
    topology=None,
) -> SharedAllocation:
    """Weighted-fair block-wise allocation of one fabric across tenants.

    ``topology`` (a ``core.cim.topology.FabricTopology`` spanning the same
    array budget) additionally places every tenant on the chip tree —
    sequentially in tenant order, so earlier (typically heavier-weight)
    tenants pack closest to the host chip — and attaches the per-tenant
    ``Placement``s the simulations consume."""
    if len(tenants) < 1:
        raise ValueError("need at least one tenant")
    if any(t.weight <= 0 for t in tenants):
        raise ValueError("tenant weights must be positive")
    total = n_pes * arrays_per_pe
    if topology is not None and topology.total_arrays != total:
        raise ValueError(
            f"topology holds {topology.total_arrays} arrays but the fabric "
            f"budget is {total} ({n_pes} PEs x {arrays_per_pe})"
        )
    base = sum(t.spec.n_arrays for t in tenants)
    if total < base:
        raise ValueError(
            f"{total} arrays cannot hold the mandatory copy of every tenant "
            f"({base} arrays: {', '.join(t.spec.name for t in tenants)})"
        )
    lat_parts, cost_parts, sizes = [], [], []
    for t in tenants:
        cyc = _layer_patch_cycles(t.prof, zskip=True)
        lat, cost = blockwise_units(t.spec, [c.mean(axis=0) for c in cyc])
        lat_parts.append(lat * t.weight)
        cost_parts.append(cost)
        sizes.append(lat.size)
    res = greedy_allocate(
        np.concatenate(lat_parts), np.concatenate(cost_parts), total - base
    )
    allocs: list[Allocation] = []
    k = 0
    used_total = base
    for t, size, cost in zip(tenants, sizes, cost_parts):
        rep = res.replicas[k : k + size]
        used = int(t.spec.n_arrays + ((rep - 1) * cost).sum())
        used_total += used - t.spec.n_arrays
        allocs.append(
            Allocation("blockwise", None, split_block_dups(t.spec, rep), used, total)
        )
        k += size
    placements = None
    if topology is not None:
        from ..core.cim.topology import place_allocation

        free = np.full(topology.n_chips, float(topology.arrays_per_chip))
        pls = []
        for t, alloc in zip(tenants, allocs):
            pl = place_allocation(t.spec, alloc, topology, chip_free=free)
            free = free - pl.chip_arrays
            pls.append(pl)
        placements = tuple(pls)
    return SharedAllocation(
        tuple(tenants), tuple(allocs), total, int(used_total), placements
    )


def run_tenants(
    shared: SharedAllocation,
    procs: list[ArrivalProcess],
    *,
    seed: int = 0,
    clock_hz: float = CLOCK_HZ,
) -> list[FabricResult]:
    """Run every tenant's arrival process on its slice of the fabric.
    Slices are disjoint, so tenants simulate independently and exactly."""
    if len(procs) != len(shared.tenants):
        raise ValueError("one arrival process per tenant")
    pls = shared.placements or (None,) * len(shared.tenants)
    out = []
    for i, (t, alloc, proc, pl) in enumerate(
        zip(shared.tenants, shared.allocations, procs, pls)
    ):
        sim = FabricSim(
            t.spec, t.prof, alloc, seed=seed + i, clock_hz=clock_hz, placement=pl
        )
        res = sim.run(proc)
        res.tenant = t.name
        out.append(res)
    return out


def fairness_report(shared: SharedAllocation, results: list[FabricResult]) -> dict:
    """Per-tenant accounting + how close the allocator got to weighted
    fairness (ratio of weighted per-image service rates)."""
    per = {}
    shares = []
    pls = shared.placements or (None,) * len(shared.tenants)
    for t, alloc, r, pl in zip(shared.tenants, shared.allocations, results, pls):
        ips = r.images_per_sec
        shares.append(ips / t.weight)
        lat = r.latency_ms()
        per[t.name] = {
            "weight": t.weight,
            "arrays": alloc.arrays_used,
            "images_per_sec": ips,
            "latency_ms_p50": lat.p50,
            "latency_ms_p95": lat.p95,
            "latency_ms_p99": lat.p99,
            "mean_utilization": r.mean_utilization,
        }
        if pl is not None:
            per[t.name]["max_stage_transfer_cycles"] = pl.max_stage_transfer
            per[t.name]["chips"] = np.flatnonzero(pl.chip_arrays > 0).tolist()
    shares = np.asarray(shares)
    return {
        "tenants": per,
        "arrays_total": shared.arrays_total,
        "arrays_used": shared.arrays_used,
        # 1.0 = perfectly weighted-proportional throughput; the min/max ratio
        # of weight-normalized rates (networks differ in per-image work, so
        # this is a fabric-level, not SLA-level, fairness signal)
        "weighted_rate_balance": float(shares.min() / shares.max()) if shares.size else 1.0,
    }
