"""Input-distribution drift: detection + online re-allocation.

The paper's allocation is computed against an offline profile ("Counting
Cards" makes the case that real input statistics move); when live inputs are
denser than profiled, the blocks sized for the old distribution become the
bottleneck.  The monitor keeps an EWMA of observed per-block mean cycles and
compares it to the profiled expectation; when the worst relative divergence
crosses a threshold it re-runs the paper's greedy allocator *warm-started
from the live replica state* (``greedy_allocate(initial_replicas=...)``)
against a held-back reserve of arrays, then charges an explicit stall while
the new replicas are programmed.

Growth-only by design: already-programmed replicas are never torn down
mid-serve (reprogramming eNVM costs far more than leaving a replica hot),
which is exactly the warm-start invariant the allocator's
``initial_replicas`` path provides.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from ..core.alloc.greedy import greedy_allocate
from ..core.cim.network import NetworkSpec
from ..core.cim.profile import LayerProfile, NetworkProfile
from ..core.cim.simulate import blockwise_units
from .metrics import ReallocationEvent

__all__ = ["DriftConfig", "OnlineReallocator", "shift_profile"]


@dataclass(frozen=True)
class DriftConfig:
    alpha: float = 0.25  # EWMA weight for a new per-block observation
    threshold: float = 0.20  # worst relative divergence that trips realloc
    warmup_observations: int = 96  # stage-visits before the EWMA is trusted
    cooldown_observations: int = 48  # stage-visits between reallocations
    program_cycles_per_array: float = 2048.0  # eNVM write time for one array
    parallel_writes: int = 64  # arrays programmed concurrently (per-PE ports)

    def stall(self, arrays_added: int) -> float:
        batches = -(-arrays_added // self.parallel_writes)
        return self.program_cycles_per_array * batches


class OnlineReallocator:
    """Watches one FabricSim's block-wise stages and grows replicas from a
    reserve budget when the observed cycle distribution drifts."""

    def __init__(self, spec: NetworkSpec, prof: NetworkProfile, reserve_arrays: float, cfg: DriftConfig = DriftConfig()):
        self.spec = spec
        self.cfg = cfg
        self.budget = float(reserve_arrays)
        self.expected = [lp.mean_cycles.astype(np.float64).copy() for lp in prof.layers]
        self.ewma = [e.copy() for e in self.expected]
        self.events: list[ReallocationEvent] = []
        self._sim = None
        self._obs = 0
        self._last_realloc_obs = 0
        self._min_cost = min(l.arrays_per_block for l in spec.layers)

    def bind(self, sim) -> None:
        self._sim = sim

    @property
    def divergence(self) -> float:
        worst = 0.0
        for e, w in zip(self.expected, self.ewma):
            d = float(np.max(np.abs(w - e) / np.maximum(e, 1e-9)))
            if d > worst:
                worst = d
        return worst

    def observe(self, layer_idx: int, block_means: np.ndarray, t: float) -> None:
        a = self.cfg.alpha
        self.ewma[layer_idx] = (1 - a) * self.ewma[layer_idx] + a * block_means
        self._obs += 1
        if (
            self._obs >= self.cfg.warmup_observations
            and self._obs - self._last_realloc_obs >= self.cfg.cooldown_observations
            and self.budget >= self._min_cost
            and self.divergence > self.cfg.threshold
        ):
            self._reallocate(t)

    def _reallocate(self, t: float) -> None:
        current = self._sim.current_block_dups()
        base_lat, cost = blockwise_units(self.spec, self.ewma)
        res = greedy_allocate(base_lat, cost, self.budget, initial_replicas=current)
        added = res.replicas - current
        arrays_added = int((added * cost).sum())
        self._last_realloc_obs = self._obs
        if arrays_added == 0:
            # Reserve can't afford the slowest block (greedy's stopping rule),
            # so the same EWMA would add 0 again next cooldown too: absorb the
            # drift into the baseline instead of re-running a futile greedy
            # pass forever.  A *further* shift still re-arms the monitor.
            self.expected = [w.copy() for w in self.ewma]
            return
        self.budget -= res.spent
        stall = self.cfg.stall(arrays_added)
        self._sim.apply_growth(added, t + stall)
        tripped_at = self.divergence
        # re-baseline: the live distribution is the new expectation, so the
        # monitor arms against *further* drift instead of re-tripping
        self.expected = [w.copy() for w in self.ewma]
        self.events.append(ReallocationEvent(t, stall, arrays_added, tripped_at))

    @property
    def stall_cycles(self) -> float:
        return sum(e.stall_cycles for e in self.events)


def shift_profile(prof: NetworkProfile, layer_scale: dict[int, float]) -> NetworkProfile:
    """A drifted copy of ``prof``: per-patch cycles of layer ``i`` scaled by
    ``layer_scale[i]`` (denser inputs -> more '1' bits -> more reads), clipped
    to the physical range [min reads, all-rows-read baseline] per block."""
    layers: list[LayerProfile] = []
    for i, lp in enumerate(prof.layers):
        k = layer_scale.get(i)
        if k is None:
            layers.append(lp)
            continue
        hi = lp.baseline_block_cycles.astype(np.float64)[None, :]
        lo = np.min(lp.cycles_sample, axis=0, keepdims=True).astype(np.float64)
        samp = np.clip(lp.cycles_sample * k, lo, hi)
        layers.append(
            replace(
                lp,
                cycles_sample=samp,
                mean_cycles=samp.mean(axis=0),
                block_density=np.minimum(lp.block_density * k, 1.0),
            )
        )
    return NetworkProfile(prof.network, tuple(layers))
