"""Discrete-event core: FIFO server pools + a global event calendar.

The analytic model in ``core/cim/simulate.py`` collapses time into
steady-state closed forms; this module keeps it explicit.  The fabric is a
set of *server pools* — one pool per block (block-wise dataflow) or one pool
per layer (layer-wise dataflow, where a server is a full layer duplicate and
a "job" is a patch whose service time is the per-patch barrier
``max_b cycles[p, b]``).

Two exact optimizations keep pure-Python simulation tractable at ResNet18
scale (~1.3e5 patch-block jobs per image):

  * Pools are *work-conserving FIFO with no preemption*, so a job's
    completion time is fixed the moment it is enqueued — later arrivals
    cannot affect earlier jobs.  We therefore resolve a whole batch of jobs
    eagerly at dispatch time ("lazy lookahead") instead of scheduling one
    event per job.  The global calendar only carries request x stage events.
  * Dispatches happen in nondecreasing simulated time (the calendar pops in
    time order), so per-pool FIFO order is preserved across requests.

Single-server pools (the common case at small designs) vectorize to a
cumulative sum; multi-server pools scan server free-times with a
deterministic earliest-free / lowest-index rule.  Both are bit-identical to
the packed virtual-time kernel in ``vtime.py`` (asserted in tests), which is
the same logic as dense array algebra under ``jit``+``vmap``.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field

import numpy as np

__all__ = ["PoolStats", "ServerPool", "EventCalendar"]


@dataclass
class PoolStats:
    """Per-pool accumulators for the telemetry layer (``stats=True``).

    Units are JOB-cycles (one job on one replica for one cycle), except
    ``frozen_cycles`` which is replica-cycles lost to reprogramming freezes;
    multiply by the pool's ``width`` for array-cycles.  ``server_busy`` is
    per replica lane, the input to replica-level load-imbalance reporting.
    It is a plain float list — scalar ``+=`` on a list element is an order
    of magnitude cheaper than on an ndarray cell, and the dispatch hot loop
    touches it per job batch; convert with ``np.asarray`` when reporting.
    """

    server_busy: list[float]  # (D,) busy cycles per replica lane
    svc_cycles: float = 0.0  # total service cycles dispatched
    queue_wait: float = 0.0  # cycles jobs spent waiting for a free replica
    frozen_cycles: float = 0.0  # replica-cycles lost to freeze_until stalls
    jobs: int = 0


def _earliest_free(avail: list[float]) -> int:
    """Earliest-free server, ties -> lowest index.

    The deterministic tie-break (rather than heap order) keeps the pool's
    evolution a pure function of the free-time *multiset*, which is what the
    packed virtual-time kernel (``vtime.dispatch_step``, sorted lanes)
    simulates — so the two engines agree bit-for-bit."""
    return min(range(len(avail)), key=avail.__getitem__)


class ServerPool:
    """``n`` identical replicas of one compute unit with a shared FIFO queue.

    ``width`` = crossbar arrays per replica (for utilization accounting).
    Server state is just each replica's next-free time; ``busy`` accumulates
    busy array-cycles.
    """

    __slots__ = (
        "avail",
        "width",
        "busy",
        "jobs",
        "record_starts",
        "starts",
        "durations",
        "servers",
        "stats",
        "_online",
    )

    def __init__(
        self,
        n_servers: int,
        width: int = 1,
        record_starts: bool = False,
        stats: bool = False,
    ):
        if n_servers < 1:
            raise ValueError("a pool needs at least one server")
        self.avail: list[float] = [0.0] * n_servers
        self.width = int(width)
        self.busy = 0.0
        self.jobs = 0
        self.record_starts = record_starts
        self.starts: list[np.ndarray] = []
        self.durations: list[np.ndarray] = []
        self.servers: list[np.ndarray] = []  # lane index per job (record_starts)
        self.stats = PoolStats([0.0] * n_servers) if stats else None
        self._online: list[tuple[float, int]] = [(0.0, n_servers)]

    @property
    def n_servers(self) -> int:
        return len(self.avail)

    def dispatch(self, t_ready: float, services: np.ndarray) -> float:
        """FIFO-dispatch a batch of jobs, all ready at ``t_ready``.

        Returns the completion time of the batch (max over jobs) and
        advances the replica free-times.  Exact: equivalent to running one
        event per job.
        """
        s = np.asarray(services, dtype=np.float64)
        m = s.size
        if m == 0:
            return t_ready
        tot = float(s.sum())
        self.busy += tot * self.width
        self.jobs += m
        observe = self.record_starts or self.stats is not None
        if len(self.avail) == 1:
            start0 = self.avail[0] if self.avail[0] > t_ready else t_ready
            # cumsum over [start0, s...] accumulates left-to-right, the same
            # op order as the per-job recurrence — bit-identical to vtime's
            # step scan (a plain `start0 + cumsum(s)` would round differently)
            ends = np.cumsum(np.concatenate(((start0,), s)))[1:]
            if observe:
                if self.record_starts:
                    self.starts.append(np.concatenate(((start0,), ends[:-1])))
                    self.durations.append(s)
                    self.servers.append(np.zeros(m, dtype=np.int64))
                if self.stats is not None:
                    ps = self.stats
                    ps.jobs += m
                    ps.svc_cycles += tot
                    # sum(starts) - m*t_ready without materializing starts
                    if m == 1:
                        ps.queue_wait += start0 - t_ready
                    else:
                        ps.queue_wait += (
                            start0 + float(ends[:-1].sum()) - m * t_ready
                        )
                    ps.server_busy[0] += tot
            self.avail[0] = float(ends[-1])
            return self.avail[0]
        avail = self.avail
        last = 0.0
        if self.record_starts:
            st_l: list[float] = []
            lane_l: list[int] = []
            put_st = st_l.append
            put_lane = lane_l.append
            for sv in s.tolist():
                i = _earliest_free(avail)
                a = avail[i]
                if a < t_ready:
                    a = t_ready
                put_st(a)
                put_lane(i)
                e = a + sv
                if e > last:
                    last = e
                avail[i] = e
            lane = np.array(lane_l, dtype=np.int64)
            self.starts.append(np.array(st_l))
            self.durations.append(s)
            self.servers.append(lane)
            if self.stats is not None:
                ps = self.stats
                ps.jobs += m
                ps.svc_cycles += tot
                ps.queue_wait += float(sum(st_l)) - m * t_ready
                sb = ps.server_busy
                for i, v in enumerate(
                    np.bincount(lane, weights=s, minlength=len(sb)).tolist()
                ):
                    sb[i] += v
        elif observe:
            # stats-only: one float add per job; per-lane busy falls out of
            # the free-time deltas afterwards.  All jobs in this batch share
            # t_ready, so a lane's idle gap (the clamp) can occur at most
            # once — on its first job — hence busy = final - max(init, t).
            avail0 = list(avail)
            qw = 0.0
            for sv in s.tolist():
                i = _earliest_free(avail)
                a = avail[i]
                if a < t_ready:
                    a = t_ready
                qw += a
                e = a + sv
                if e > last:
                    last = e
                avail[i] = e
            ps = self.stats
            ps.jobs += m
            ps.svc_cycles += tot
            ps.queue_wait += qw - m * t_ready
            sb = ps.server_busy
            for i, a0 in enumerate(avail0):
                b = avail[i] - (a0 if a0 > t_ready else t_ready)
                if b > 0.0:
                    sb[i] += b
        else:
            for sv in s.tolist():
                i = _earliest_free(avail)
                a = avail[i]
                if a < t_ready:
                    a = t_ready
                e = a + sv
                if e > last:
                    last = e
                avail[i] = e
        return last

    def grow(self, extra: int, t_free: float) -> None:
        """Add ``extra`` replicas that come online at ``t_free``."""
        self.avail.extend([float(t_free)] * int(extra))
        self._online.append((float(t_free), int(extra)))
        if self.stats is not None:
            self.stats.server_busy.extend([0.0] * int(extra))

    def kill(self, k: int, t: float) -> int:
        """Remove the ``k`` LATEST-free replicas at time ``t`` (failures).

        Killing the largest free-times is the multiset rule the packed
        virtual-time kernel implements by setting the top sorted lane
        positions to ``+inf`` (``fleet._apply_boundary``) — both engines
        must retire the same lanes for bit-identity to hold.  Jobs already
        dispatched to a killed lane DRAIN (their completion was fixed at
        dispatch; no preemption in either engine) — the return value counts
        how many killed lanes were still busy at ``t``, i.e. carried work a
        live fabric would have had to retry on survivors.  ``kill`` may
        empty the pool; dispatching on an empty pool is the caller's
        responsibility to prevent (``FabricSim`` parks a phantom lane)."""
        k = int(k)
        if k > len(self.avail):
            raise ValueError(f"cannot kill {k} of {len(self.avail)} servers")
        busy = 0
        for _ in range(k):
            i = max(range(len(self.avail)), key=self.avail.__getitem__)
            if self.avail[i] > t:
                busy += 1
            self.avail.pop(i)
            if self.stats is not None:
                self.stats.server_busy.pop(i)
        self._online.append((float(t), -k))
        return busy

    def capacity_cycles(self, horizon: float) -> float:
        """Array-cycles of capacity over [0, horizon], counting replicas
        added mid-run only from the moment they came online."""
        return self.width * sum(
            n * max(0.0, horizon - t) for t, n in self._online
        )

    def freeze_until(self, t: float) -> None:
        """Stall the pool (e.g. while arrays are being reprogrammed)."""
        if self.stats is not None:
            # replica-cycles the freeze takes away: each lane that would have
            # been free before ``t`` cannot serve until ``t``
            self.stats.frozen_cycles += sum(
                t - a for a in self.avail if a < t
            )
        self.avail = [a if a > t else float(t) for a in self.avail]

    def occupancy(self, bucket: float, horizon: float) -> np.ndarray:
        """Mean busy replicas per time bucket (requires record_starts).

        Exact: every job interval is split over the buckets it overlaps, so
        ``occupancy(...) * bucket`` integrates to total busy cycles."""
        n = int(np.ceil(horizon / bucket)) + 1
        out = np.zeros(n)
        if not self.starts:
            return out
        B = float(bucket)
        a = np.concatenate(self.starts)
        d = np.concatenate(self.durations)
        b = a + d
        i0 = np.minimum((a / B).astype(np.int64), n - 1)
        i1 = np.minimum((b / B).astype(np.int64), n - 1)
        same = i0 == i1
        np.add.at(out, i0[same], d[same])
        sp = ~same
        np.add.at(out, i0[sp], (i0[sp] + 1) * B - a[sp])
        np.add.at(out, i1[sp], b[sp] - i1[sp] * B)
        # full buckets strictly between i0 and i1, via a difference array
        diff = np.zeros(n + 1)
        np.add.at(diff, i0[sp] + 1, B)
        np.add.at(diff, i1[sp], -B)
        out += np.cumsum(diff)[:n]
        return out / B

    def timeline(self, bucket: float, horizon: float) -> np.ndarray:
        """Busy array-cycles per time bucket (requires record_starts)."""
        n = int(np.ceil(horizon / bucket)) + 1
        out = np.zeros(n)
        if not self.starts:
            return out
        st = np.concatenate(self.starts)
        du = np.concatenate(self.durations)
        idx = np.minimum((st / bucket).astype(np.int64), n - 1)
        np.add.at(out, idx, du * self.width)
        return out


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    req: int = field(compare=False)
    stage: int = field(compare=False)


class EventCalendar:
    """Time-ordered heap of (request, stage) entry events."""

    __slots__ = ("_heap", "_seq")

    def __init__(self):
        self._heap: list[_Event] = []
        self._seq = 0

    def push(self, time: float, req: int, stage: int) -> None:
        heapq.heappush(self._heap, _Event(float(time), self._seq, req, stage))
        self._seq += 1

    def pop(self) -> tuple[float, int, int]:
        ev = heapq.heappop(self._heap)
        return ev.time, ev.req, ev.stage

    def __len__(self) -> int:
        return len(self._heap)
