"""Packed virtual-time fabric kernel: the event engine as array algebra.

``events.py``/``dispatch.py`` simulate the fabric with an explicit event
calendar; this module evaluates the *same* model as a dense virtual-time
recurrence that runs identically in numpy and under ``jit``+``vmap``.

Why that is exact and not an approximation:

  * Pools are work-conserving FIFO and a request's patch jobs enqueue the
    moment it enters a stage, so a later request's jobs always sit behind an
    earlier request's jobs in every pool — *requests cannot overtake each
    other*.  The calendar's time-ordered pops therefore process each stage's
    dispatches in request-index order, and the whole simulation collapses to
    a scan over requests: request r runs through all L stages against pool
    state left by requests 0..r-1.
  * Closed-loop admission keeps the same shape: completions happen in index
    order, so request k arrives exactly when request ``k - concurrency``
    completes — a ring buffer in the scan carry.

Pool state is packed into dense per-layer ``(B, D)`` free-time tensors kept
sorted ascending (``+inf`` marks servers that do not exist): the sorted
lanes ARE the multiset of server free-times, which is all the FIFO
recurrence can observe, so one FIFO job is "pop lane 0, elementwise
sorted-insert of the end time" — pure array algebra with no reductions or
scatters, shared verbatim between the scalar numpy path and the batched jax
path (``lax.scan`` over jobs and requests, ``vmap`` over (allocation,
arrival-trace) pairs, jitted in float64).  Both paths perform bit-for-bit
the same IEEE operations as the ``ServerPool`` event engine, so per-request
completion times agree exactly (pinned in tests/test_fabric_vtime.py).

Service times are presampled request-major (``sample_service_indices``) from
the profiled per-(patch, block) cycle sample; ``FabricSim`` consumes the
same helper in the same order, which is what makes the three paths
bit-identical rather than merely statistically equivalent.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.cim.network import NetworkSpec
from ..core.cim.profile import NetworkProfile
from ..core.cim.simulate import Allocation, CLOCK_HZ, _layer_patch_cycles
from .arrivals import ArrivalProcess, ClosedLoop, PoissonOpen, arrival_times
from .metrics import LatencyStats, latency_stats, percentile_kernel, steady_throughput

__all__ = [
    "CoarsenConfig",
    "chunk_plan",
    "dispatch_step",
    "hash_service_indices",
    "pool_dispatch",
    "pool_dispatch_stream",
    "sample_service_indices",
    "VTResult",
    "VirtualTimeFabric",
    "provision_latency_aware",
    "refine_latency_aware",
]


# ------------------------------------------------------------ shared kernel
def dispatch_step(xp, free, svc):
    """One FIFO job per pool onto its earliest-free server.

    ``free``: (..., D) server free-times kept SORTED ascending (``+inf`` =
    absent server); ``svc``: (...,) the job's service time.  Because the
    lanes hold the sorted *multiset* of free-times — which is all the FIFO
    recurrence can observe — the earliest-free server is lane 0, and the
    update is an elementwise sorted-insert of the job's end time:

        r_i = min(max(u_{i-1}, v), u_i),   u = remaining lanes (+/-inf edges)

    No reductions, no scatter: the step is pure elementwise algebra, and it
    performs bit-for-bit the same IEEE add (start + svc) as the event
    engine's ``ServerPool``, whose completion times depend only on the same
    multiset.  Returns (free', end).
    """
    end = free[..., 0] + svc
    up = xp.concatenate([free[..., 1:], xp.full_like(free[..., :1], xp.inf)], axis=-1)
    free = xp.minimum(xp.maximum(free, end[..., None]), up)
    return free, end


def pool_dispatch(xp, scan, free, t_ready, svc, b_mask, collect=False):
    """FIFO-dispatch a batch of jobs, all ready at ``t_ready``.

    ``free``: (B, D) per-pool server free-times; ``svc``: (P, B) one job per
    pool per row; ``b_mask``: (B,) valid pools.  Returns (free', done) with
    ``done`` = completion of the batch (max end over valid pools, at least
    ``t_ready``) — exactly ``ServerPool.dispatch`` batched over pools.

    Clamping every server to ``t_ready`` up front is equivalent to the event
    engine's per-job ``max(avail, t)``: dispatch times per pool are
    nondecreasing, so a stored pre-clamp value below ``t_ready`` can never
    matter again, and the sorted multiset of free-times (which is all the
    FIFO recurrence sees) evolves identically.

    ``collect=True`` additionally returns (busy, wait) for this batch: busy
    = total service cycles dispatched, wait = total queue-wait (job start -
    ``t_ready``).  A job's start is read off lane 0 AFTER the clamp and
    BEFORE the sorted-insert — the same quantity the event engine's
    ``max(avail_i, t_ready)`` yields — so the telemetry path performs the
    identical IEEE ops on ``free``/``done`` and cannot perturb results.
    """
    free = xp.maximum(free, t_ready)
    if not collect:

        def job(free, svc_p):
            return dispatch_step(xp, free, svc_p)

        free, ends = scan(job, free, svc)  # (P, B) per-job completion times
        done = xp.maximum(xp.where(b_mask, ends, -xp.inf).max(), t_ready)
        return free, done

    def job(state, svc_p):
        free, acc = state
        start = free[..., 0]  # earliest-free lane = this job's start time
        free, end = dispatch_step(xp, free, svc_p)
        # accumulate queue wait in the carry (a 0-d scalar) rather than
        # emitting a second (B,) scan output: the collect kernel then adds
        # one fused reduction per job instead of doubling the ys traffic
        acc = acc + xp.where(b_mask, start - t_ready, 0.0).sum()
        return (free, acc), end

    (free, wait), ends = scan(job, (free, xp.zeros(())), svc)
    done = xp.maximum(xp.where(b_mask, ends, -xp.inf).max(), t_ready)
    busy = xp.where(b_mask, svc, 0.0).sum()
    return free, done, busy, wait


def pool_dispatch_stream(xp, scan, free, t_ready, svc, b_mask):
    """Carry-max variant of ``pool_dispatch``: accumulate the batch's
    completion as a running max in the scan carry instead of emitting a
    (P, B) per-job end matrix.  Float max is associative and commutative
    (no NaNs here), so folding the ends one job at a time — seeded with
    ``t_ready`` — produces bit-for-bit the same ``done`` as the
    materializing reduction; the lane updates are untouched.  This is what
    lets the fleet streaming kernel keep O(lanes) state per scan step
    regardless of trace length."""
    free = xp.maximum(free, t_ready)

    def job(state, svc_p):
        f, acc = state
        f, end = dispatch_step(xp, f, svc_p)
        acc = xp.maximum(acc, xp.where(b_mask, end, -xp.inf).max())
        return (f, acc), None

    (free, done), _ = scan(job, (free, t_ready), svc)
    return free, done


# ---------------------------------------------------- macro-job coarsening
@dataclass(frozen=True)
class CoarsenConfig:
    """Opt-in approximation: aggregate a stage's bulk patch jobs into
    macro-jobs of K patches (service times summed per pool), keeping the
    last ``tail_lanes * D`` jobs exact per-patch so end-of-stage lane
    balancing — which sets the next stage's start — is preserved.

    The kernel is work-bound at one scan step per job, so chunking the bulk
    is the honest wall-time lever: measured on VGG11 (single core),
    ``granularity=1, tail_lanes=3`` is 2.7x with ~0.3% positive (pessimistic)
    p50/p95/p99 bias and ``tail_lanes=2`` is 3.2x at ~2%.  Default off —
    every exactness-pinned path passes ``coarsen=None``.
    """

    granularity: float = 1.0  # target macro-jobs per lane in the bulk
    tail_lanes: int = 3  # exact per-patch jobs kept at stage end, x lanes
    k_max: int = 32  # macro-job size ceiling


def chunk_plan(n_patches: int, n_lanes: int, cfg: CoarsenConfig | None) -> tuple:
    """Static (K, n_bulk) macro-job plan for one stage; (1, 0) means exact.

    K is chosen so the bulk leaves ~``granularity * n_lanes`` macro-jobs
    (enough to keep every lane fed), capped at ``k_max``; the plan degrades
    to exact whenever the stage is too small to leave >= 2 bulk chunks."""
    if cfg is None:
        return (1, 0)
    target = max(1, int(round(cfg.granularity * n_lanes)))
    k = max(1, min(int(cfg.k_max), int(n_patches) // target))
    tail = min(int(n_patches), int(cfg.tail_lanes) * int(n_lanes))
    nb = max(0, (int(n_patches) - tail) // k)
    if k == 1 or nb < 2:
        return (1, 0)
    return (k, nb)


def _chunk_services(xp, svc, plan):
    """Aggregate (P, B) per-patch services into the planned macro-jobs.

    The K-way sum is an explicit left fold so numpy and jit accumulate in
    the identical order (library ``sum`` reduction trees differ)."""
    k, nb = plan
    if nb == 0:
        return svc
    head = svc[: nb * k].reshape((nb, k) + svc.shape[1:])
    acc = head[:, 0]
    for j in range(1, k):
        acc = acc + head[:, j]
    return xp.concatenate([acc, svc[nb * k :]], axis=0)


def _request_step(xp, job_scan, stages, xfer, concurrency, collect, carry, inp):
    """Run one request through every stage against the carried pool state.

    ``stages``: sequence of (cycles (S, B), b_mask (B,)) per layer;
    ``xfer``: (L,) per-stage entry transfer delay (multi-chip placement), or
    None for the flat fabric — when present, the request's clock advances by
    ``xfer[l]`` before stage ``l`` dispatches, the identical IEEE add the
    event engine performs in ``FabricSim._dispatch_stage``;
    ``carry``: (per-layer free tensors, completion ring buffer);
    ``inp``: (request index, open-loop arrival time, per-layer (P,) sample
    indices).  Closed loop (``concurrency`` not None) reads the arrival from
    the ring: request r enters when request r - concurrency completed (slots
    before the first wrap hold the 0.0 init = the initial admissions).

    ``collect=True`` carries two extra per-layer tuples of 0-d accumulators
    (busy, wait) through the scan — the jit path's utilization/duty-cycle
    telemetry, emitted by the same single jit call as the percentiles.
    """
    if collect:
        frees, ring, busy, wait = carry
    else:
        frees, ring = carry
    r, t_arr, idx = inp
    if concurrency is None:
        t = t_arr
    else:
        pos = r % concurrency
        t = ring[pos]
    t0 = t
    new_frees = []
    for li, ((cycles, b_mask), free, ix) in enumerate(zip(stages, frees, idx)):
        if xfer is not None:
            t = t + xfer[li]
        svc = cycles[ix]  # (P, B) this request's sampled per-block cycles
        if collect:
            free, t, b_l, w_l = pool_dispatch(
                xp, job_scan, free, t, svc, b_mask, collect=True
            )
            busy = busy[:li] + (busy[li] + b_l,) + busy[li + 1 :]
            wait = wait[:li] + (wait[li] + w_l,) + wait[li + 1 :]
        else:
            free, t = pool_dispatch(xp, job_scan, free, t, svc, b_mask)
        new_frees.append(free)
    if concurrency is not None:
        ring = xp.where(xp.arange(ring.shape[0]) == pos, t, ring)
    if collect:
        return (tuple(new_frees), ring, busy, wait), (t0, t)
    return (tuple(new_frees), ring), (t0, t)


def _tree_blocks(xs, nb, w):
    """Reshape each leaf (N, ...) -> (nb, w, ...) over the first nb*w rows."""
    if isinstance(xs, tuple):
        return tuple(_tree_blocks(x, nb, w) for x in xs)
    return xs[: nb * w].reshape((nb, w) + xs.shape[1:])


def _tree_tail(xs, lo):
    if isinstance(xs, tuple):
        return tuple(_tree_tail(x, lo) for x in xs)
    return xs[lo:]


def _scan_windowed(xp, scan, body, carry, xs, n, window):
    """Blocked request scan: ``window`` sequential ``body`` steps per scan
    step, cutting the scan length N -> N/W (+ a W=1 epilogue for the
    remainder).  The block body unrolls the SAME per-request step in the
    same order — only the loop-carried structure changes — so results are
    bit-identical to the W=1 scan for every W (pinned in tests).  Handles
    bodies that emit no ys (the streaming fleet kernel)."""
    w = max(1, min(int(window), n if n else 1))
    nb = n // w if w > 1 else 0
    parts = []
    if nb > 0:

        def block(c, blk):
            ys = []
            for j in range(w):
                c, y = body(c, _tree_index(blk, j))
                ys.append(y)
            if ys[0] is None:
                return c, None
            return c, tuple(
                xp.stack([y[k] for y in ys]) for k in range(len(ys[0]))
            )

        carry, ys = scan(block, carry, _tree_blocks(xs, nb, w))
        if ys is not None:
            # (nb, w, ...) -> (nb * w, ...) restores request-major order
            parts.append(tuple(y.reshape((nb * w,) + y.shape[2:]) for y in ys))
        done = nb * w
    else:
        done = 0
    if done < n:
        carry, ys = scan(body, carry, _tree_tail(xs, done))
        if ys is not None:
            parts.append(ys)
    if not parts:
        return carry, None
    if len(parts) == 1:
        return carry, parts[0]
    return carry, tuple(
        xp.concatenate([p[k] for p in parts]) for k in range(len(parts[0]))
    )


def run_fabric_kernel(
    xp, scan, stages, frees, arrivals, idx, concurrency, percentiles,
    job_scan=None, xfer=None, collect_stats=False, window=1, return_state=False,
):
    """Whole-run recurrence: scan ``_request_step`` over requests, then
    reduce per-request latencies to percentiles — one fused computation in
    the jax path, a plain loop in the numpy path.  ``job_scan`` (defaults to
    ``scan``) drives the inner per-job loop; ``xfer`` is this config's (L,)
    stage transfer vector (or None for the flat fabric).

    ``window`` processes W requests per scan step (``_scan_windowed``),
    exploiting the non-overtaking property to shorten the scan N -> N/W
    bit-identically; the window auto-clamps to the closed-loop concurrency,
    where admission forces request k to wait on request k - concurrency and
    a wider block buys nothing.

    ``collect_stats=True`` returns two extra (L,) vectors — total busy
    (service) cycles and queue-wait cycles per layer, accumulated through
    the scan carry.  They reconcile with the event engine's ``PoolStats``
    counters to float64 summation-order tolerance (scalar ``+=`` there vs.
    ``xp.sum`` here); completions/percentiles are bit-identical either way.

    ``return_state=True`` appends the final (frees, ring) carry to the
    outputs — the hook segmented replay uses to hand lane state across
    control-interval boundaries.
    """
    n = arrivals.shape[0]
    ring = xp.zeros(concurrency if concurrency is not None else 1)
    from functools import partial

    body = partial(
        _request_step, xp, job_scan or scan, stages, xfer, concurrency, collect_stats
    )
    if concurrency is not None:
        window = min(int(window), int(concurrency))
    if collect_stats:
        zeros = tuple(xp.zeros(()) for _ in stages)
        carry0 = (frees, ring, zeros, zeros)
    else:
        carry0 = (frees, ring)
    carry, (t_arr, comp) = _scan_windowed(
        xp, scan, body, carry0, (xp.arange(n), arrivals, idx), n, window
    )
    lat = comp - t_arr
    pct = percentile_kernel(xp, lat, percentiles)
    out = (t_arr, comp, pct)
    if collect_stats:
        out = out + (xp.stack(carry[2]), xp.stack(carry[3]))
    if return_state:
        out = out + (carry[0], carry[1])
    return out


def _tree_index(xs, j):
    if isinstance(xs, tuple):
        return tuple(_tree_index(x, j) for x in xs)
    return xs[j]


def _tree_len(xs):
    while isinstance(xs, tuple):
        xs = xs[0]
    return len(xs)


def _np_scan(f, init, xs):
    """``lax.scan`` semantics for numpy: xs is a (possibly nested) tuple of
    arrays sliced along axis 0; ys stacked (or None)."""
    n = _tree_len(xs)
    carry = init
    ys = []
    for j in range(n):
        carry, y = f(carry, _tree_index(xs, j))
        if y is not None:
            ys.append(y)
    if not ys:
        return carry, None
    if isinstance(ys[0], tuple):
        return carry, tuple(np.stack([y[k] for y in ys]) for k in range(len(ys[0])))
    return carry, np.stack(ys)


# --------------------------------------------------------------- packing
def sample_service_indices(rng: np.random.Generator, dims, n_requests: int):
    """Per-layer (N, ppi) sample-row indices, drawn layer-major.

    ``dims`` = [(S_l, ppi_l)] per stage.  Both ``FabricSim`` and the
    virtual-time paths draw through this helper with the same generator
    state, so all engines see identical service times per (request, patch).
    """
    return [
        rng.integers(0, s, size=(int(n_requests), int(ppi))) for s, ppi in dims
    ]


def _hash_salt(seed: int, layer: int) -> int:
    """Per-(seed, layer) salt for ``hash_service_indices`` — plain python
    int, mixed host-side so the kernel hashes only (request, patch)."""
    return (int(seed) * 0x9E3779B9 + (int(layer) + 1) * 0xC2B2AE35) & 0xFFFFFFFF


def hash_service_indices(xp, salt, r, n_patches, n_samples):
    """Counter-based service-sample indices: a splitmix-style uint32 hash of
    (salt, request, patch), evaluated in-kernel.

    Presampling (``sample_service_indices``) materializes per-layer (N, ppi)
    int64 tensors — tens of GB at fleet scale (10^6 requests x ~1.5k patches)
    — so the streaming replay derives each request's indices on the fly
    instead.  Pure uint32 array arithmetic (multiply/xor/shift wrap
    identically under numpy and jit), so every engine sees the same indices:
    ``r`` may be a traced scalar (one request inside the scan) or an (N,)
    vector (``FabricSim``'s vectorized draw); the result broadcasts to
    ``r.shape + (n_patches,)``.  The final modulo is bias-free whenever
    ``n_samples`` is a power of two (the profiler's sample counts are) and
    biased by < n_samples/2^32 otherwise.
    """
    u = xp.uint32
    r32 = xp.asarray(r).astype(u)[..., None]
    p = xp.arange(n_patches, dtype=u)
    h = (p + u(1)) * u(0x9E3779B9)
    h = h + (r32 + u(1)) * u(0x85EBCA6B) + u(salt)
    h = h ^ (h >> 16)
    h = h * u(0x7FEB352D)
    h = h ^ (h >> 15)
    h = h * u(0x846CA68B)
    h = h ^ (h >> 16)
    return (h % u(n_samples)).astype(xp.int32)


@dataclass(frozen=True)
class _GroupPack:
    """One homogeneous (dataflow, zskip) sub-batch of allocations."""

    rows: np.ndarray  # (C,) indices into the caller's allocation list
    layerwise: bool
    zskip: bool
    stages: tuple  # per layer (cycles (S, B) float64, b_mask (B,) bool)
    frees: tuple  # per layer (C, B, D) float64 initial free-times
    xfer: np.ndarray | None = None  # (C, L) per-stage entry transfers


def _pack_group(
    spec: NetworkSpec, cyc, layerwise: bool, allocs, lane_quantum: int = 1
) -> tuple:
    """Dense per-layer (cycles, b_mask) + per-config (C, B, D) free tensors.

    ``lane_quantum`` rounds each layer's lane count D up to a multiple, so
    callers that re-pack slowly-growing allocations (the oracle refinement
    loop) keep stable shapes and reuse compiled kernels."""
    stages, frees = [], []
    for i, layer in enumerate(spec.layers):
        if layerwise:
            cycles = cyc[i].max(axis=1, keepdims=True)  # (S, 1) barrier
            b_mask = np.ones(1, dtype=bool)
            dups = np.asarray(
                [int(a.layer_dups[i]) for a in allocs], dtype=np.int64
            )[:, None]  # (C, 1)
        else:
            cycles = cyc[i]  # (S, B)
            b_mask = np.ones(layer.n_blocks, dtype=bool)
            dups = np.stack(
                [np.asarray(a.block_dups[i], dtype=np.int64) for a in allocs]
            )  # (C, B)
        q = max(1, int(lane_quantum))
        D = -(-int(dups.max()) // q) * q
        free = np.where(
            np.arange(D) < dups[:, :, None], 0.0, np.inf
        )  # (C, B, D)
        stages.append((np.ascontiguousarray(cycles, dtype=np.float64), b_mask))
        frees.append(free)
    return tuple(stages), tuple(frees)


def _split_by_padded_cost(spec, allocs, rows, layerwise) -> list[list[int]]:
    """Partition same-shape configs so lane padding stays bounded.

    The dense (C, B, D) free tensors pad every config to the sub-batch max
    lanes per layer, so one heavily-replicated allocation (a low-load
    latency-aware reshape, say) would inflate the scan cost of the whole
    batch.  Greedily chain configs in order of their own padded cost and cut
    a new sub-group when a config is more than 1.5x the sub-group's first —
    bounding the padding waste at ~1.5x for a few extra jit calls.
    """

    def padded_cost(a):
        # per-job scan work: patches (scan steps) x lanes touched per step
        if layerwise:
            return float(
                sum(
                    l.patches_per_image * int(a.layer_dups[i])
                    for i, l in enumerate(spec.layers)
                )
            )
        return float(
            sum(
                l.patches_per_image * l.n_blocks * int(np.max(a.block_dups[i]))
                for i, l in enumerate(spec.layers)
            )
        )

    costs = {j: padded_cost(allocs[j]) for j in rows}
    order = sorted(rows, key=lambda j: costs[j])
    subs: list[list[int]] = []
    for j in order:
        if subs and costs[j] <= 1.5 * max(costs[subs[-1][0]], 1.0):
            subs[-1].append(j)
        else:
            subs.append([j])
    return subs


# ----------------------------------------------------------------- results
@dataclass(frozen=True)
class VTResult:
    """Structure-of-arrays fabric outcome for C (allocation, trace) pairs."""

    arrivals: np.ndarray  # (C, N) cycles
    completions: np.ndarray  # (C, N) cycles
    percentiles: np.ndarray  # (C, P) latency percentiles, cycles
    percentile_qs: tuple  # the P percentile levels
    clock_hz: float = CLOCK_HZ
    # telemetry (run_batch(collect_stats=True) only): per-layer service and
    # queue-wait job-cycles accumulated inside the kernel's scan carry —
    # reconcile with FabricSim(stats=True)'s PoolStats at rtol 1e-9
    layer_busy: np.ndarray | None = None  # (C, L)
    layer_wait: np.ndarray | None = None  # (C, L)

    def __len__(self) -> int:
        return self.completions.shape[0]

    @property
    def latencies(self) -> np.ndarray:  # (C, N)
        return self.completions - self.arrivals

    def percentile(self, q: float) -> np.ndarray:  # (C,)
        return self.percentiles[:, self.percentile_qs.index(q)]

    @property
    def p99(self) -> np.ndarray:
        return self.percentile(99.0)

    def latency(self, i: int) -> LatencyStats:
        return latency_stats(self.latencies[i])

    def latency_ms(self, i: int) -> LatencyStats:
        return self.latency(i).scaled(1e3 / self.clock_hz)

    @property
    def images_per_sec(self) -> np.ndarray:  # (C,)
        return np.asarray(
            [steady_throughput(c, clock_hz=self.clock_hz) for c in self.completions]
        )


class VirtualTimeFabric:
    """Batched fabric evaluation: one jit call per homogeneous sub-batch
    evaluates per-request completion times and latency percentiles for a
    whole batch of (allocation, arrival-trace) pairs.

    Allocations may mix dataflows/policies; they are grouped internally by
    (layerwise, zero-skipping) since those change the packed tensor shapes.
    ``engine="numpy"`` runs the identical kernel functions with ``xp=numpy``
    (the scalar reference path used by the equivalence suite).
    """

    def __init__(
        self,
        spec: NetworkSpec,
        prof: NetworkProfile,
        *,
        live_prof: NetworkProfile | None = None,
        clock_hz: float = CLOCK_HZ,
        lane_quantum: int = 1,
    ):
        self.spec = spec
        self.prof = prof
        self.live_prof = live_prof
        self.clock_hz = clock_hz
        self.lane_quantum = int(lane_quantum)
        self._cyc = {
            z: _layer_patch_cycles(live_prof or prof, z) for z in (False, True)
        }
        self._compiled: dict[tuple, object] = {}

    # ------------------------------------------------------------- internals
    def _groups(self, allocs, placements=None) -> list[_GroupPack]:
        keys: dict[tuple, list[int]] = {}
        for j, a in enumerate(allocs):
            keys.setdefault((a.layer_dups is not None, a.policy != "baseline"), []).append(j)
        out = []
        for (layerwise, zskip), rows in keys.items():
            for sub in _split_by_padded_cost(self.spec, allocs, rows, layerwise):
                stages, frees = _pack_group(
                    self.spec, self._cyc[zskip], layerwise,
                    [allocs[j] for j in sub],
                    lane_quantum=self.lane_quantum,
                )
                xfer = (
                    None
                    if placements is None
                    else np.ascontiguousarray(
                        np.stack(
                            [
                                np.asarray(
                                    placements[j].stage_transfer, dtype=np.float64
                                )
                                for j in sub
                            ]
                        )
                    )
                )
                out.append(
                    _GroupPack(np.asarray(sub), layerwise, zskip, stages, frees, xfer)
                )
        return out

    def _jax_runner(
        self, g: _GroupPack, concurrency, n, percentiles, collect=False,
        window=1, return_state=False,
    ):
        """Cached jit(vmap) of the shared kernel for one group structure."""
        has_xfer = g.xfer is not None
        key = (
            g.layerwise,
            g.zskip,
            concurrency,
            n,
            percentiles,
            tuple(f.shape[1:] for f in g.frees),
            has_xfer,
            collect,  # stats-on kernels compile separately (extra outputs)
            window,
            return_state,
        )
        if key not in self._compiled:
            import functools

            import jax
            import jax.numpy as jnp

            np_stages = g.stages
            job_scan = functools.partial(jax.lax.scan, unroll=1)

            def one(frees, xfer, arrivals, idx):
                # convert the cycle constants INSIDE the traced function:
                # tracing happens under enable_x64(), so the float64 values
                # survive (a module-level jnp.asarray would downcast to f32
                # and quietly break bit-identity for non-f32-exact cycles)
                stages = tuple(
                    (jnp.asarray(c), jnp.asarray(m)) for c, m in np_stages
                )
                return run_fabric_kernel(
                    jnp, jax.lax.scan, stages, frees, arrivals, idx,
                    concurrency, percentiles, job_scan=job_scan, xfer=xfer,
                    collect_stats=collect, window=window,
                    return_state=return_state,
                )

            self._compiled[key] = jax.jit(
                jax.vmap(one, in_axes=(0, 0 if has_xfer else None, 0, None))
            )
        return self._compiled[key]

    # ------------------------------------------------------------------ run
    def run_batch(
        self,
        allocs,
        proc: ArrivalProcess | list,
        *,
        seed: int = 0,
        engine: str = "jax",
        percentiles: tuple = (50.0, 95.0, 99.0),
        placements: list | None = None,
        collect_stats: bool = False,
        window: int = 1,
    ) -> VTResult:
        """Evaluate C allocations against one shared arrival process (or a
        per-allocation list of same-kind processes).  Service times are
        sampled once with ``default_rng(seed)`` — the same draws every
        ``FabricSim(spec, prof, alloc, seed=seed)`` would consume.

        ``placements`` (one ``core.cim.topology.Placement`` per allocation,
        or None for the flat fabric) adds each config's per-stage entry
        transfer delays to the kernel — the multi-chip path, bit-identical
        to ``FabricSim(placement=...)``.

        ``collect_stats=True`` additionally populates ``VTResult.layer_busy``
        / ``layer_wait`` (C, L) from in-kernel accumulators; completion times
        and percentiles are bit-identical with the flag on or off.

        ``window`` blocks the request scan W-at-a-time (bit-identical for
        every W; auto-clamped to the closed-loop concurrency) — the
        fleet-replay scan-length lever, safe to raise on long traces."""
        if engine not in ("jax", "numpy"):
            raise ValueError(f"engine must be 'jax' or 'numpy', got {engine!r}")
        allocs = list(allocs)
        if not allocs:
            raise ValueError("need at least one allocation")
        if placements is not None and len(placements) != len(allocs):
            raise ValueError(
                f"{len(placements)} placements for {len(allocs)} allocations"
            )
        procs = proc if isinstance(proc, list) else [proc] * len(allocs)
        if len(procs) != len(allocs):
            raise ValueError(f"{len(procs)} arrival processes for {len(allocs)} allocations")
        closed = isinstance(procs[0], ClosedLoop)
        if any(isinstance(p, ClosedLoop) != closed for p in procs):
            raise ValueError("cannot mix closed- and open-loop processes in one batch")
        if closed:
            concurrency = procs[0].concurrency
            if any(p.concurrency != concurrency or p.n_requests != procs[0].n_requests for p in procs):
                raise ValueError("closed-loop batch needs identical (n_requests, concurrency)")
            n = procs[0].n_requests
            times = np.zeros((len(allocs), n))
        else:
            concurrency = None
            tlist = [arrival_times(p) for p in procs]
            n = tlist[0].size
            if any(t.size != n for t in tlist):
                raise ValueError("all arrival traces in a batch need the same length")
            times = np.stack(tlist).astype(np.float64)

        # one draw shared by every group: sampling dims depend only on the
        # profile (S_l, ppi_l), not on dataflow or zero-skipping
        dims = [
            (self._cyc[True][i].shape[0], l.patches_per_image)
            for i, l in enumerate(self.spec.layers)
        ]
        idx = sample_service_indices(np.random.default_rng(seed), dims, n)

        C = len(allocs)
        L = len(self.spec.layers)
        arrivals = np.zeros((C, n))
        completions = np.zeros((C, n))
        pcts = np.zeros((C, len(percentiles)))
        busy = np.zeros((C, L)) if collect_stats else None
        wait = np.zeros((C, L)) if collect_stats else None
        if n == 0:
            return VTResult(
                arrivals, completions, pcts, tuple(percentiles), self.clock_hz,
                layer_busy=busy, layer_wait=wait,
            )
        for g in self._groups(allocs, placements):
            if engine == "jax":
                from jax.experimental import enable_x64

                fn = self._jax_runner(
                    g, concurrency, n, tuple(percentiles),
                    collect=collect_stats, window=window,
                )
                with enable_x64():
                    out = fn(g.frees, g.xfer, times[g.rows], tuple(idx))
                t_arr, comp, pct = (np.asarray(o) for o in out[:3])
                if collect_stats:
                    busy[g.rows] = np.asarray(out[3])
                    wait[g.rows] = np.asarray(out[4])
            else:
                t_arr = np.zeros((len(g.rows), n))
                comp = np.zeros((len(g.rows), n))
                pct = np.zeros((len(g.rows), len(percentiles)))
                for k, row in enumerate(g.rows):
                    frees = tuple(f[k].copy() for f in g.frees)
                    out = run_fabric_kernel(
                        np, _np_scan, g.stages, frees, times[row],
                        tuple(idx), concurrency, tuple(percentiles),
                        xfer=None if g.xfer is None else g.xfer[k],
                        collect_stats=collect_stats, window=window,
                    )
                    t_arr[k], comp[k], pct[k] = out[:3]
                    if collect_stats:
                        busy[row] = np.asarray(out[3])
                        wait[row] = np.asarray(out[4])
            arrivals[g.rows] = t_arr
            completions[g.rows] = comp
            pcts[g.rows] = pct
        return VTResult(
            arrivals, completions, pcts, tuple(percentiles), self.clock_hz,
            layer_busy=busy, layer_wait=wait,
        )


# ------------------------------------------------- fabric-oracle refinement
def provision_latency_aware(
    spec: NetworkSpec,
    prof: NetworkProfile,
    n_pes: int,
    *,
    offered_ips: float | None = None,
    load_frac: float = 0.7,
    arrays_per_pe: int | None = None,
    proc: ArrivalProcess | list | None = None,
    calib_requests: int = 250,
    calib_seeds: tuple = (101, 211),
    margin: float = 0.02,
    grants: int = 8,
    seed: int = 0,
    percentile: float = 99.0,
    engine: str = "jax",
    vt: "VirtualTimeFabric | None" = None,
) -> Allocation:
    """Serving-oriented allocation: provision a fabric for traffic, not peak.

    The full latency-aware flow the analytic pieces plug into:

      1. build the paper's throughput allocation (``blockwise``) and the
         tail-weighted analytic allocation (``latency_aware`` =
         ``queueing_allocate``) at the same PE budget;
      2. measure both on a calibration workload with ONE batched
         virtual-time call per trace (``proc``, defaulting to open-loop
         Poisson traces at the offered load) and keep the measured-p99
         winner — the analytic model reshapes the fabric only where the
         measurement agrees it pays by more than ``margin`` (typically at
         low load, where bottleneck headroom the traffic does not need can
         buy a shorter request path; near saturation the paper's
         utilization-equalizing shape is already tail-near-optimal and
         wins the calibration);
      3. spend any arrays the winner's greedy left stranded with the
         fabric-oracle (``refine_latency_aware``).

    Returns a block-wise ``Allocation`` with policy ``latency_aware``.
    """
    from ..core.cim.simulate import ARRAYS_PER_PE, allocate, simulate

    app = ARRAYS_PER_PE if arrays_per_pe is None else arrays_per_pe
    bw = allocate(spec, prof, "blockwise", n_pes, app)
    if offered_ips is None:
        offered_ips = load_frac * simulate(spec, prof, bw).images_per_sec
    la = allocate(
        spec, prof, "latency_aware", n_pes, app, offered_ips=offered_ips
    )
    if proc is None:
        rate = float(offered_ips) / CLOCK_HZ
        procs = [
            PoissonOpen(int(calib_requests), rate, seed=s) for s in calib_seeds
        ]
    else:
        procs = proc if isinstance(proc, list) else [proc]
    if vt is None:
        vt = VirtualTimeFabric(spec, prof, lane_quantum=8)
    cands = [
        Allocation("latency_aware", None, bw.block_dups, bw.arrays_used, bw.arrays_total),
        la,
    ]
    p = np.zeros(len(cands))
    for k, pr in enumerate(procs):
        res = vt.run_batch(cands, pr, seed=seed + k, engine=engine, percentiles=(percentile,))
        p += res.percentiles[:, 0]
    # deviate from the throughput shape only on a decisive calibration win
    best = la if p[1] < p[0] * (1.0 - margin) else cands[0]
    if grants > 0 and best.arrays_total - best.arrays_used > 0:
        best = refine_latency_aware(
            spec, prof, best, procs, grants=grants, seed=seed,
            percentile=percentile, engine=engine, vt=vt,
        )
    return best


def refine_latency_aware(
    spec: NetworkSpec,
    prof: NetworkProfile,
    alloc: Allocation,
    proc: ArrivalProcess,
    *,
    grants: int = 16,
    candidates: int = 24,
    seed: int = 0,
    percentile: float = 99.0,
    engine: str = "jax",
    vt: "VirtualTimeFabric | None" = None,
) -> Allocation:
    """Greedy fabric-oracle refinement of a block-wise allocation.

    Each round evaluates, in ONE batched virtual-time call, the current
    allocation plus the ``candidates`` most promising affordable +1-replica
    moves (shortlisted by analytic marginal drain reduction per array), and
    grants the block with the best *measured* p``percentile`` reduction per
    array on the calibration workload ``proc``.  Stops after ``grants``
    rounds, when nothing is affordable, or when no candidate improves the
    tail.  This is the exact, expensive counterpart of the analytic
    queueing score inside the ``latency_aware`` allocator
    (``core.alloc.greedy.queueing_allocate``): the analytic path provisions
    the bulk, the oracle spends the last few replicas on the measured tail.
    """
    if alloc.block_dups is None:
        raise ValueError("fabric-oracle refinement requires a block-wise allocation")
    procs = proc if isinstance(proc, list) else [proc]
    # lane_quantum keeps packed shapes stable while replica counts creep up,
    # so the refinement loop reuses one compiled kernel per boundary; a
    # caller that already holds a warm VirtualTimeFabric passes it in
    if vt is None:
        vt = VirtualTimeFabric(spec, prof, lane_quantum=8)
    table = spec.block_table()  # (n_blocks, 3): layer, block-in-layer, width
    cost = table[:, 2].astype(np.int64)
    cyc = _layer_patch_cycles(prof, alloc.policy != "baseline")
    base_lat = np.concatenate(
        [c.mean(axis=0) * l.patches_per_image for c, l in zip(cyc, spec.layers)]
    )
    dups = [np.asarray(d, dtype=np.int64).copy() for d in alloc.block_dups]
    used, total = int(alloc.arrays_used), int(alloc.arrays_total)

    def mk(d, arrays_used):
        return Allocation(alloc.policy, None, [x.copy() for x in d], arrays_used, total)

    pq = (percentile,)
    for _ in range(int(grants)):
        budget = total - used
        flat = np.concatenate(dups).astype(np.float64)
        afford = np.flatnonzero(cost <= budget)
        if afford.size == 0:
            break
        # shortlist by analytic marginal drain reduction per array
        marg = (base_lat[afford] / flat[afford] - base_lat[afford] / (flat[afford] + 1)) / cost[afford]
        cand = afford[np.argsort(-marg, kind="stable")[: int(candidates)]]
        batch = [mk(dups, used)]
        for j in cand:
            li, bi = int(table[j, 0]), int(table[j, 1])
            d = [x.copy() for x in dups]
            d[li][bi] += 1
            batch.append(mk(d, used + int(cost[j])))
        # average the measured tail over the calibration traces (a list of
        # procs reduces single-trace overfit); one batched call per trace
        p = np.zeros(len(batch))
        for k, pr in enumerate(procs):
            res = vt.run_batch(batch, pr, seed=seed + k, engine=engine, percentiles=pq)
            p += res.percentiles[:, 0]
        p /= len(procs)
        gain = (p[0] - p[1:]) / cost[cand]
        best = int(np.argmax(gain))
        if gain[best] <= 0:
            break
        j = cand[best]
        li, bi = int(table[j, 0]), int(table[j, 1])
        dups[li][bi] += 1
        used += int(cost[j])
    return mk(dups, used)
