"""Seeded fabric failure injection + SLO-defending graceful degradation.

The paper's fixed eNVM crossbars make failures expensive: a dead array takes
its replica's weights with it, and re-placing the lost capacity costs real
reprogramming stalls.  This module makes the failure axis first-class for
both fabric engines:

  * ``FailureTrace`` / ``generate_failure_trace`` — a seeded failure model:
    every replica lane carries an independent Weibull renewal hazard
    (``weibull_shape=1`` is the exponential special case, scale =
    ``1 / (rate_per_array * lane_width)``), chips fail together via a
    per-chip Poisson burst process whose blast radius is the lanes homed on
    that chip (``FabricTopology.arrays_per_chip`` defines the failure
    domain), and an optional deterministic ``repair_cycles`` MTTR brings a
    dead lane back.  Events are totally ordered and reproducible from
    ``seed`` alone.
  * ``degrade_plan`` — compiles a trace into the SHARED artifact both
    engines consume: a segment trajectory of block-wise allocations cut at
    every failure/repair time.  A failure removes the lane with the largest
    next-free time (the multiset rule both engines implement identically: in
    the packed kernel the sorted positions ``[dups_new, dups_old)`` — the
    largest finite free-times — are set to ``+inf``, the existing
    absent-server convention; in the event engine ``ServerPool.kill`` pops
    the largest ``avail``).  Survivor re-placement draws like-for-like
    capacity from a hot-spare pool via warm-started
    ``greedy_allocate(initial_replicas=...)``; repairs and replacements are
    net growth and charge ``DriftConfig.stall`` reprogramming freezes
    exactly as segmented replay boundaries do.  ``FabricSim(failures=plan)``
    and ``fleet.run_trace_segments(plan.allocs, ..., plan.boundaries)`` are
    bit-identical under the same plan (the correctness spine, pinned in
    tests/test_failures.py on VGG11 and ResNet18).
  * ``RetryPolicy`` — event-engine-only serving policy on top of the shared
    semantics: requests reaching a zero-survivor block stall until its next
    repair/re-place and are shed (NaN completion) when the wait exceeds
    ``timeout_cycles`` or the request has already stalled ``max_retries``
    times.  The bit-identity contract deliberately excludes this path (the
    packed kernel reports ``+inf`` for dead blocks); pinned traces keep at
    least one survivor per block.

Jobs dispatched before a failure DRAIN: both engines fix a job's completion
at dispatch time (work-conserving FIFO, no preemption), so a lane that dies
busy still finishes its queue — ``ServerPool.kill`` reports how many lanes
died busy and the dispatcher counts them as retried-on-survivor work.

``failure_step_schedule`` exports the same seeded schedule to the training
runner (``runtime.fault.FaultInjector.from_trace``), so training-side and
fabric-side fault tests draw from one generator.
"""

from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field

import numpy as np

from ..core.alloc.greedy import greedy_allocate
from ..core.cim.network import NetworkSpec
from ..core.cim.profile import NetworkProfile
from ..core.cim.simulate import (
    Allocation,
    _layer_patch_cycles,
    blockwise_units,
    split_block_dups,
)
from .drift import DriftConfig
from .telemetry import get_telemetry

__all__ = [
    "DegradePlan",
    "FailureEvent",
    "FailureTrace",
    "RetryPolicy",
    "degrade_plan",
    "degrade_plan_from_allocs",
    "failure_step_schedule",
    "generate_failure_events",
    "generate_failure_trace",
    "lane_chips",
]


@dataclass(frozen=True)
class FailureEvent:
    """One lane transition: flat block ``unit`` loses (``repair=False``) or
    regains (``repair=True``) replica lane ``lane`` at ``time`` cycles.
    ``chip`` is the failure domain the lane is homed on (burst attribution;
    0 for a single-chip fabric)."""

    time: float
    unit: int
    lane: int
    repair: bool = False
    chip: int = 0


@dataclass(frozen=True)
class FailureTrace:
    """A totally-ordered, seed-reproducible sequence of failure/repair
    events over ``[0, horizon)`` cycles, against the flat block units of one
    block-wise allocation (``n_units`` blocks)."""

    events: tuple[FailureEvent, ...]
    horizon: float
    seed: int = 0
    n_units: int = 0

    def __post_init__(self):
        times = [e.time for e in self.events]
        if any(b < a for a, b in zip(times, times[1:])):
            raise ValueError("failure events must be sorted by time")

    @property
    def n_failures(self) -> int:
        return sum(not e.repair for e in self.events)

    @property
    def n_repairs(self) -> int:
        return sum(e.repair for e in self.events)

    @property
    def seam_times(self) -> np.ndarray:
        """Sorted unique event times — the segment boundaries a degrade
        plan cuts the request stream at."""
        return np.unique(np.asarray([e.time for e in self.events]))

    def mttr(self) -> float:
        """Mean time-to-repair over repaired lanes (cycles); ``inf`` when
        failures were never repaired, ``nan`` with no failures at all."""
        pend: dict[tuple[int, int], float] = {}
        gaps = []
        for ev in self.events:
            key = (ev.unit, ev.lane)
            if ev.repair:
                t0 = pend.pop(key, None)
                if t0 is not None:
                    gaps.append(ev.time - t0)
            else:
                pend[key] = ev.time
        if gaps:
            return float(np.mean(gaps))
        return math.inf if pend else math.nan


def lane_chips(dups, widths, arrays_per_chip: int | None = None) -> list[np.ndarray]:
    """Home chip of every replica lane, packed in (unit, lane) order.

    Lanes occupy consecutive array ranges (``widths[j]`` arrays each) and a
    lane's chip is where its first array lands — the same linear packing
    ``FabricTopology`` tiles arrays with, so ``arrays_per_chip`` from a
    topology carves the lanes into its chip failure domains.  ``None``
    (single chip) homes everything on chip 0."""
    dups = np.asarray(dups, dtype=np.int64)
    widths = np.asarray(widths, dtype=np.int64)
    if dups.shape != widths.shape:
        raise ValueError(f"dups {dups.shape} vs widths {widths.shape}")
    if arrays_per_chip is None:
        arrays_per_chip = max(int((dups * widths).sum()), 1)
    if arrays_per_chip < 1:
        raise ValueError(f"arrays_per_chip must be positive, got {arrays_per_chip}")
    out = []
    off = 0
    for j in range(dups.size):
        w = int(widths[j])
        chips = np.empty(int(dups[j]), dtype=np.int64)
        for i in range(int(dups[j])):
            chips[i] = off // arrays_per_chip
            off += w
        out.append(chips)
    return out


_FAIL, _REPAIR, _BURST = 0, 1, 2


def generate_failure_events(
    dups,
    widths,
    *,
    horizon: float,
    seed: int = 0,
    rate_per_array: float = 0.0,
    weibull_shape: float = 1.0,
    repair_cycles: float | None = None,
    arrays_per_chip: int | None = None,
    chip_burst_rate: float = 0.0,
    burst_kill_frac: float = 0.5,
    min_survivors: int = 1,
) -> tuple[FailureEvent, ...]:
    """Seeded failure/repair schedule against flat block units.

    Per-lane hazards are Weibull renewals with scale ``1 / (rate_per_array *
    widths[j])`` — shape 1 is exponential, shape > 1 wear-out, shape < 1
    infant mortality.  The renewal clock runs in wall time: a hazard firing
    while its lane is already dead (burst casualty) is absorbed.  Chip
    bursts arrive Poisson per chip at ``chip_burst_rate`` and kill
    ``ceil(burst_kill_frac * alive-on-chip)`` lanes homed on that chip, in
    deterministic (unit, lane) order.  With ``repair_cycles`` every kill
    schedules its lane's repair a fixed MTTR later (dropped past the
    horizon: the lane stays dead).  ``min_survivors`` is a floor per unit:
    failures that would breach it are absorbed, so a degraded block always
    keeps that many replicas — 1 keeps both engines finite, 0 permits
    zero-survivor episodes (event-engine ``RetryPolicy`` territory).

    Deterministic in all arguments: the RNG is consumed only in a fixed
    pre-generation order, and the chronological walk breaks time ties by
    generation order."""
    dups = np.asarray(dups, dtype=np.int64)
    widths = np.asarray(widths, dtype=np.int64)
    if dups.shape != widths.shape or dups.ndim != 1:
        raise ValueError(f"dups {dups.shape} vs widths {widths.shape}")
    if np.any(dups < 1) or np.any(widths < 1):
        raise ValueError("every unit needs >= 1 replica of >= 1 array")
    if not horizon > 0:
        raise ValueError(f"horizon must be positive, got {horizon}")
    if rate_per_array < 0 or chip_burst_rate < 0:
        raise ValueError("failure rates must be nonnegative")
    if not weibull_shape > 0:
        raise ValueError(f"weibull_shape must be positive, got {weibull_shape}")
    if not 0.0 < burst_kill_frac <= 1.0:
        raise ValueError(f"burst_kill_frac must be in (0, 1], got {burst_kill_frac}")
    if repair_cycles is not None and not repair_cycles > 0:
        raise ValueError(f"repair_cycles must be positive, got {repair_cycles}")
    if min_survivors < 0:
        raise ValueError(f"min_survivors must be >= 0, got {min_survivors}")

    rng = np.random.default_rng(seed)
    chips = lane_chips(dups, widths, arrays_per_chip)
    n = int(dups.size)
    seq = itertools.count()
    heap: list[tuple[float, int, int, int, int, int]] = []

    # fixed draw order (unit-major, lane-minor, then chips) = determinism
    if rate_per_array > 0:
        for j in range(n):
            scale = 1.0 / (rate_per_array * float(widths[j]))
            for i in range(int(dups[j])):
                t = 0.0
                while True:
                    t += scale * float(rng.weibull(weibull_shape))
                    if t >= horizon:
                        break
                    heapq.heappush(heap, (t, next(seq), _FAIL, j, i, int(chips[j][i])))
    if chip_burst_rate > 0:
        n_chips = int(max(int(c.max()) for c in chips if c.size) + 1) if n else 1
        for c in range(n_chips):
            t = 0.0
            while True:
                t += float(rng.exponential(1.0 / chip_burst_rate))
                if t >= horizon:
                    break
                heapq.heappush(heap, (t, next(seq), _BURST, c, -1, c))

    alive = [set(range(int(d))) for d in dups]
    events: list[FailureEvent] = []

    def kill(t: float, j: int, i: int, chip: int) -> None:
        alive[j].discard(i)
        events.append(FailureEvent(t, j, i, False, chip))
        if repair_cycles is not None and t + repair_cycles < horizon:
            heapq.heappush(
                heap, (t + repair_cycles, next(seq), _REPAIR, j, i, chip)
            )

    while heap:
        t, _, kind, j, i, chip = heapq.heappop(heap)
        if kind == _REPAIR:
            alive[j].add(i)
            events.append(FailureEvent(t, j, i, True, chip))
        elif kind == _FAIL:
            if i in alive[j] and len(alive[j]) > min_survivors:
                kill(t, j, i, chip)
        else:  # chip burst: j is the chip id
            targets = [
                (jj, ii)
                for jj in range(n)
                for ii in sorted(alive[jj])
                if chips[jj][ii] == j
            ]
            quota = int(math.ceil(burst_kill_frac * len(targets)))
            killed = 0
            for jj, ii in targets:
                if killed >= quota:
                    break
                if len(alive[jj]) > min_survivors:
                    kill(t, jj, ii, j)
                    killed += 1
    return tuple(events)


def generate_failure_trace(
    spec: NetworkSpec,
    alloc: Allocation,
    *,
    horizon: float,
    seed: int = 0,
    rate_per_array: float = 0.0,
    weibull_shape: float = 1.0,
    repair_cycles: float | None = None,
    topology=None,
    chip_burst_rate: float = 0.0,
    burst_kill_frac: float = 0.5,
    min_survivors: int = 1,
) -> FailureTrace:
    """``generate_failure_events`` against a (spec, block-wise allocation)
    pair; ``topology`` (a ``core.cim.topology.FabricTopology``) supplies
    ``arrays_per_chip`` so chip bursts respect the real failure domains."""
    if alloc.block_dups is None:
        raise ValueError("failure injection requires a block-wise allocation")
    dups = np.concatenate(
        [np.asarray(d, dtype=np.int64) for d in alloc.block_dups]
    )
    widths = np.concatenate(
        [
            np.full(l.n_blocks, l.arrays_per_block, dtype=np.int64)
            for l in spec.layers
        ]
    )
    events = generate_failure_events(
        dups,
        widths,
        horizon=horizon,
        seed=seed,
        rate_per_array=rate_per_array,
        weibull_shape=weibull_shape,
        repair_cycles=repair_cycles,
        arrays_per_chip=None if topology is None else topology.arrays_per_chip,
        chip_burst_rate=chip_burst_rate,
        burst_kill_frac=burst_kill_frac,
        min_survivors=min_survivors,
    )
    tel = get_telemetry()
    tel.count("fabric.failures.generated", sum(not e.repair for e in events))
    tel.count("fabric.failures.repairs_generated", sum(e.repair for e in events))
    return FailureTrace(events, float(horizon), int(seed), int(dups.size))


@dataclass(frozen=True)
class RetryPolicy:
    """Event-engine serving policy for zero-survivor blocks (outside the
    bit-identity contract): a request hitting a dead block waits for its
    next repair/re-place; it is shed (NaN completion) when that wait
    exceeds ``timeout_cycles``, when the block will never revive, or after
    the request has already stalled ``max_retries`` times."""

    timeout_cycles: float = math.inf
    max_retries: int = 8

    def __post_init__(self):
        if not self.timeout_cycles >= 0:
            raise ValueError(f"timeout_cycles must be >= 0, got {self.timeout_cycles}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")


@dataclass(frozen=True)
class DegradePlan:
    """Segmented degradation trajectory — the ONE artifact both fabric
    engines consume (``FabricSim(failures=plan)`` /
    ``fleet.run_trace_segments(plan.allocs, ..., plan.boundaries)``), which
    is what makes their results bit-identical under a failure trace.

    ``allocs[s]`` holds during ``[boundaries[s-1], boundaries[s])``;
    ``arrays_added[s]`` / ``stall_cycles[s]`` are the reprogrammed arrays
    (positive dup diffs only — survivors keep their weights) and the
    resulting fabric-wide freeze charged entering segment ``s``;
    ``arrays_online[s]`` is the live replica capacity, the availability
    integrand."""

    allocs: tuple[Allocation, ...]
    boundaries: np.ndarray  # (S-1,) cycles, nondecreasing
    arrays_added: np.ndarray  # (S,) int; [0] == 0
    stall_cycles: np.ndarray  # (S,)
    arrays_online: np.ndarray  # (S,) arrays holding live replicas
    drift: DriftConfig
    trace: FailureTrace
    spare_arrays: float = 0.0
    spare_left: float = 0.0
    n_killed: int = 0
    n_repaired: int = 0
    replaced_arrays: float = 0.0
    dropped_failures: int = field(default=0)  # kills absorbed by the floor

    @property
    def n_segments(self) -> int:
        return len(self.allocs)

    def flat_dups(self, s: int) -> np.ndarray:
        """Flat per-block replica counts of segment ``s``."""
        return np.concatenate(
            [np.asarray(d, dtype=np.int64) for d in self.allocs[s].block_dups]
        )

    @property
    def total_stall_cycles(self) -> float:
        return float(np.sum(self.stall_cycles))

    def availability(self, horizon: float | None = None) -> float:
        """Capacity availability over ``[0, horizon]``: live-array-cycles
        actually serviceable (reprogramming freezes subtracted) over the
        healthy fabric's array-cycles.  1.0 = no capacity lost; deterministic
        from the plan alone, so spare-fraction sweeps never need the event
        engine."""
        h = float(self.trace.horizon if horizon is None else horizon)
        if not h > 0:
            raise ValueError(f"horizon must be positive, got {h}")
        base = float(self.arrays_online[0])
        if base <= 0:
            return 0.0
        starts = np.concatenate([[0.0], self.boundaries])
        ends = np.concatenate([self.boundaries, [h]])
        length = np.maximum(np.minimum(ends, h) - np.minimum(starts, h), 0.0)
        eff = np.maximum(length - self.stall_cycles, 0.0)
        return float(min(1.0, float(self.arrays_online @ eff) / (base * h)))


def _plan_capacity(cur: np.ndarray, cost: np.ndarray) -> int:
    return int(round(float(cur @ cost)))


def degrade_plan(
    spec: NetworkSpec,
    prof: NetworkProfile,
    alloc: Allocation,
    trace: FailureTrace,
    *,
    spare_arrays: float = 0.0,
    drift: DriftConfig = DriftConfig(),
    zskip: bool | None = None,
    min_survivors: int = 1,
) -> DegradePlan:
    """Compile a failure trace into the shared segment trajectory.

    Every distinct event time becomes a seam.  Kills decrement the unit's
    replica count (clamped at ``min_survivors`` — the generator enforces the
    floor on original lanes, but spare re-placement can shift which unit is
    thinnest, so the clamp re-checks); repairs increment it.  When capacity
    was lost and hot spares remain, ``greedy_allocate(initial_replicas=
    survivors)`` re-places up to the arrays just killed — like-for-like
    budget, so spares restore the highest-latency blocks first, which is the
    paper's allocation rule applied to the degraded fabric.  Repairs and
    re-placements are net growth at the seam and charge
    ``drift.stall(arrays_added)`` exactly as ``run_trace_segments`` computes
    it from the dup diffs — the two books must agree for the engines to
    stay bit-identical.  Corollary: a seam whose kills are fully re-placed
    onto the SAME units leaves the replica counts unchanged and is dropped
    (no cut, no stall) — like-for-like hot-spare swap is modeled as
    seamless, a deliberate simplification both engines share."""
    if alloc.block_dups is None:
        raise ValueError("degrade_plan requires a block-wise allocation")
    if spare_arrays < 0:
        raise ValueError(f"spare_arrays must be >= 0, got {spare_arrays}")
    if min_survivors < 0:
        raise ValueError(f"min_survivors must be >= 0, got {min_survivors}")
    if zskip is None:
        zskip = alloc.policy != "baseline"
    cyc = _layer_patch_cycles(prof, zskip)
    base_lat, cost = blockwise_units(spec, [c.mean(axis=0) for c in cyc])
    cur = np.concatenate(
        [np.asarray(d, dtype=np.int64) for d in alloc.block_dups]
    )
    if trace.n_units and trace.n_units != cur.size:
        raise ValueError(
            f"trace covers {trace.n_units} units, allocation has {cur.size}"
        )
    total = int(alloc.arrays_total)

    allocs = [alloc]
    bounds: list[float] = []
    added = [0]
    stalls = [0.0]
    online = [_plan_capacity(cur, cost)]
    spare_left = float(spare_arrays)
    n_killed = n_repaired = dropped = 0
    replaced = 0.0

    for t, group in itertools.groupby(trace.events, key=lambda e: e.time):
        prev = cur.copy()
        lost = 0.0
        for ev in group:
            j = int(ev.unit)
            if not 0 <= j < cur.size:
                raise ValueError(f"event unit {j} outside [0, {cur.size})")
            if ev.repair:
                cur[j] += 1
                n_repaired += 1
            elif cur[j] > min_survivors:
                cur[j] -= 1
                n_killed += 1
                lost += float(cost[j])
            else:
                dropped += 1
        if lost > 0.0 and spare_left > 0.0:
            res = greedy_allocate(
                base_lat, cost, min(spare_left, lost), initial_replicas=cur
            )
            spare_left -= res.spent
            replaced += res.spent
            cur = res.replicas
        if np.array_equal(cur, prev):
            continue  # fully-absorbed seam: no allocation change, no cut
        diff = cur - prev
        add = int(round(float(np.maximum(diff, 0) @ cost)))
        used = _plan_capacity(cur, cost)
        bounds.append(float(t))
        added.append(add)
        stalls.append(drift.stall(add) if add > 0 else 0.0)
        online.append(used)
        allocs.append(
            Allocation(
                alloc.policy,
                None,
                split_block_dups(spec, cur.copy()),
                used,
                max(total, used),
            )
        )

    plan = DegradePlan(
        allocs=tuple(allocs),
        boundaries=np.asarray(bounds, dtype=np.float64),
        arrays_added=np.asarray(added, dtype=np.int64),
        stall_cycles=np.asarray(stalls, dtype=np.float64),
        arrays_online=np.asarray(online, dtype=np.int64),
        drift=drift,
        trace=trace,
        spare_arrays=float(spare_arrays),
        spare_left=spare_left,
        n_killed=n_killed,
        n_repaired=n_repaired,
        replaced_arrays=replaced,
        dropped_failures=dropped,
    )
    tel = get_telemetry()
    tel.gauge("fabric.failures.availability", plan.availability())
    mttr = trace.mttr()
    if math.isfinite(mttr):
        tel.observe("fabric.failures.mttr_cycles", mttr)
    return plan


def degrade_plan_from_allocs(
    spec: NetworkSpec,
    allocs,
    boundaries,
    *,
    drift: DriftConfig = DriftConfig(),
    horizon: float | None = None,
) -> DegradePlan:
    """Wrap a hand-built allocation trajectory (e.g. an explicit shrink) in
    a ``DegradePlan`` so the event engine can replay it via
    ``FabricSim(failures=...)`` — the seam bookkeeping (positive-diff
    reprogram arrays, stalls, online capacity) is derived exactly as
    ``degrade_plan`` and ``run_trace_segments`` derive it."""
    allocs = list(allocs)
    if not allocs:
        raise ValueError("need at least one allocation")
    bounds = np.asarray(boundaries, dtype=np.float64)
    if bounds.size != len(allocs) - 1:
        raise ValueError(
            f"{len(allocs)} allocations need {len(allocs) - 1} boundaries, "
            f"got {bounds.size}"
        )
    if np.any(np.diff(bounds) < 0):
        raise ValueError("boundaries must be nondecreasing")
    widths = np.concatenate(
        [
            np.full(l.n_blocks, l.arrays_per_block, dtype=np.int64)
            for l in spec.layers
        ]
    )
    flats = []
    for a in allocs:
        if a.block_dups is None:
            raise ValueError("degrade plans require block-wise allocations")
        flats.append(
            np.concatenate([np.asarray(d, dtype=np.int64) for d in a.block_dups])
        )
    added = [0]
    stalls = [0.0]
    online = [_plan_capacity(flats[0], widths.astype(np.float64))]
    for s in range(1, len(flats)):
        diff = flats[s] - flats[s - 1]
        add = int(np.maximum(diff, 0) @ widths)
        added.append(add)
        stalls.append(drift.stall(add) if add > 0 else 0.0)
        online.append(_plan_capacity(flats[s], widths.astype(np.float64)))
    h = float(horizon) if horizon is not None else float(bounds[-1]) if bounds.size else 0.0
    return DegradePlan(
        allocs=tuple(allocs),
        boundaries=bounds,
        arrays_added=np.asarray(added, dtype=np.int64),
        stall_cycles=np.asarray(stalls, dtype=np.float64),
        arrays_online=np.asarray(online, dtype=np.int64),
        drift=drift,
        trace=FailureTrace((), max(h, 1.0), 0, int(widths.size)),
    )


def failure_step_schedule(trace: FailureTrace, cycles_per_step: float) -> dict[int, int]:
    """Map a fabric failure trace onto training steps: step
    ``floor(time / cycles_per_step)`` absorbs each fail event.  The shared
    schedule type ``runtime.fault.FaultInjector.from_trace`` consumes, so
    training-side and fabric-side fault tests draw from one seeded
    generator."""
    if not cycles_per_step > 0:
        raise ValueError(f"cycles_per_step must be positive, got {cycles_per_step}")
    out: dict[int, int] = {}
    for ev in trace.events:
        if not ev.repair:
            s = int(ev.time // cycles_per_step)
            out[s] = out.get(s, 0) + 1
    return out
