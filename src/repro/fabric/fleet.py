"""Fleet-scale trace replay: streaming sketches + segmented re-allocation.

``vtime.py`` proves the fabric collapses to a scan over requests; this
module makes that scan usable as a *what-if oracle over millions of
requests* — the ROADMAP's online-serving control plane needs to replay a
day of traffic against a batch of candidate allocations in seconds, not
keep a (configs, requests) latency matrix alive to do it.

Three pieces, composable and individually pinned:

  * ``run_stream``: the virtual-time kernel with O(lanes + sketch) carry —
    service indices come from an in-kernel counter hash
    (``hash_service_indices``; presampling is tens of GB at 10^6 requests),
    per-request latencies fold into a ``fabric.metrics`` log-bucket sketch
    plus exact min/max and Welford moments, and the request scan is blocked
    ``window`` at a time.  Bucket counts, min/max and makespan are pinned
    bit-identical against ``FabricSim(service_sampling="hash")`` and
    against the numpy replay of the same kernel.
  * ``run_trace_segments``: splits a long trace at control-interval
    boundaries, carries free-lane state across segments, and applies a
    per-segment allocation (growth or shrink), charging the event engine's
    reprogramming semantics at each boundary: every lane of a reshaped
    config freezes until ``boundary + DriftConfig.stall(arrays_added)``
    (net-new replicas only) and the new lanes come online then — exactly
    ``FabricSim.apply_growth``; shrunk lanes go to ``+inf`` (absent), which
    is how seeded failure traces replay on this engine.  With no allocation
    change and zero stall the segmented replay is bit-identical to the
    unsegmented run (pinned in tests).
  * ``segment_growth_plan``: builds such a trajectory from per-boundary
    array budgets (negative = degraded capacity, via ``greedy_release``)
    through ``greedy_allocate(initial_replicas=...)`` — the warm-start hook
    the autoscaling controller drives.
  * ``run_trace_failures``: the fault-tolerance entry — compiles a seeded
    ``fabric.failures.FailureTrace`` into a ``DegradePlan`` and replays it
    here, bit-identical to ``FabricSim(failures=plan)`` (the cross-engine
    contract pinned in tests/test_failures.py).

``CoarsenConfig`` (from ``vtime``) optionally trades ~0.3-2% pessimistic
tail bias for the 2.7-3.2x macro-job speedup on top; every default is the
exact kernel.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import numpy as np

from ..core.cim.network import NetworkSpec
from ..core.cim.profile import NetworkProfile
from ..core.cim.simulate import (
    Allocation,
    CLOCK_HZ,
    _layer_patch_cycles,
    blockwise_units,
    split_block_dups,
)
from .arrivals import ArrivalProcess, ClosedLoop, arrival_times
from .drift import DriftConfig
from .metrics import (
    LatencySketch,
    LatencyStats,
    SketchConfig,
    sketch_init,
    sketch_update,
)
from .vtime import (
    CoarsenConfig,
    VirtualTimeFabric,
    _GroupPack,
    _chunk_services,
    _hash_salt,
    _np_scan,
    _pack_group,
    _scan_windowed,
    chunk_plan,
    hash_service_indices,
    pool_dispatch_stream,
    run_fabric_kernel,
    sample_service_indices,
)

__all__ = [
    "FleetResult",
    "SegmentReport",
    "SegmentedReplayResult",
    "run_stream",
    "run_trace_failures",
    "run_trace_segments",
    "segment_growth_plan",
]


# ------------------------------------------------------------ stream kernel
def _tree_where(xp, pred, new, old):
    """Select whole carry trees by a scalar predicate — how padded requests
    (``i >= n_valid``) leave the fabric state untouched bit-for-bit."""
    if isinstance(new, tuple):
        return tuple(_tree_where(xp, pred, a, b) for a, b in zip(new, old))
    return xp.where(pred, new, old)


def _stream_request_step(
    xp, job_scan, stages, xfer, concurrency, salts, dims, plans, cfg,
    r0, n_valid, emit, carry, inp,
):
    """``vtime._request_step`` with O(1)-per-request carry: hash-derived
    service indices, carry-max stage completions, in-carry sketch + horizon
    instead of per-request ys.  ``r0`` offsets the local scan index to the
    global request id (segment continuation + hash identity); requests at
    ``i >= n_valid`` are padding and leave the carry unchanged.  ``emit``
    additionally materializes per-request ``(t_arrival, t_done)`` — the
    O(N)-memory baseline the sketch replaces (kept for validation and the
    fleet bench's exact-percentile reference)."""
    frees, ring, sk, horizon = carry
    i, t_arr = inp
    r = r0 + i
    if concurrency is None:
        t = t_arr
    else:
        pos = r % concurrency
        t = ring[pos]
    t0 = t
    new_frees = []
    for li, ((cycles, b_mask), free) in enumerate(zip(stages, frees)):
        if xfer is not None:
            t = t + xfer[li]
        n_samples, ppi = dims[li]
        ix = hash_service_indices(xp, salts[li], r, ppi, n_samples)
        svc = _chunk_services(xp, cycles[ix], plans[li])
        free, t = pool_dispatch_stream(xp, job_scan, free, t, svc, b_mask)
        new_frees.append(free)
    if concurrency is not None:
        ring = xp.where(xp.arange(ring.shape[0]) == pos, t, ring)
    new = (
        tuple(new_frees),
        ring,
        sketch_update(xp, sk, t - t0, cfg),
        xp.maximum(horizon, t),
    )
    return _tree_where(xp, i < n_valid, new, carry), ((t0, t) if emit else None)


def _run_stream_kernel(
    xp, scan, stages, frees, arrivals, concurrency, cfg, salts, dims, plans,
    sk0, hor0, ring0, job_scan=None, xfer=None, window=1, r0=0, n_valid=None,
    emit=False,
):
    """One config/segment of the streaming replay; returns the final carry
    (frees, ring, sketch state, horizon) and — only with ``emit`` — the
    per-request ``(arrivals, completions)`` ys."""
    n = arrivals.shape[0]
    body = partial(
        _stream_request_step, xp, job_scan or scan, stages, xfer, concurrency,
        salts, dims, plans, cfg, r0, n_valid, emit,
    )
    if concurrency is not None:
        window = min(int(window), int(concurrency))
    carry0 = (frees, ring0, sk0, hor0)
    carry, ys = _scan_windowed(
        xp, scan, body, carry0, (xp.arange(n), arrivals), n, window
    )
    return (carry, ys) if emit else carry


def _stream_dims_salts(vt: VirtualTimeFabric, seed: int):
    dims = tuple(
        (int(vt._cyc[True][i].shape[0]), int(l.patches_per_image))
        for i, l in enumerate(vt.spec.layers)
    )
    salts = tuple(_hash_salt(seed, li) for li in range(len(dims)))
    return dims, salts


def _stream_runner(
    vt, g: _GroupPack, concurrency, n_pad, window, cfg, plans, dims, salts,
    seed, has_xfer, emit=False,
):
    """Cached jit(vmap) of the streaming kernel for one group structure.
    Lane state / ring / sketch state / r0 / n_valid are traced arguments, so
    segmented replay reuses ONE compiled kernel for every same-length
    (padded) segment."""
    key = (
        "fleet", g.layerwise, g.zskip, concurrency, n_pad, window, cfg, plans,
        tuple(f.shape[1:] for f in g.frees), seed, has_xfer, emit,
    )
    if key not in vt._compiled:
        import functools

        import jax
        import jax.numpy as jnp

        np_stages = g.stages
        job_scan = functools.partial(jax.lax.scan, unroll=1)

        def one(frees, xfer, arrivals, ring, sk, hor, r0, n_valid):
            # cycle constants converted INSIDE the trace: x64 survival,
            # same rationale as VirtualTimeFabric._jax_runner
            stages = tuple((jnp.asarray(c), jnp.asarray(m)) for c, m in np_stages)
            return _run_stream_kernel(
                jnp, jax.lax.scan, stages, frees, arrivals, concurrency, cfg,
                salts, dims, plans, sk, hor, ring0=ring, job_scan=job_scan,
                xfer=xfer, window=window, r0=r0, n_valid=n_valid, emit=emit,
            )

        vt._compiled[key] = jax.jit(
            jax.vmap(one, in_axes=(0, 0 if has_xfer else None, 0, 0, 0, 0, None, None))
        )
    return vt._compiled[key]


def _init_stream_state(g: _GroupPack, concurrency, cfg: SketchConfig):
    c = len(g.rows)
    ring = np.zeros((c, concurrency if concurrency is not None else 1))
    sk = tuple(
        np.zeros((c,) + np.shape(a), dtype=np.float64) + np.asarray(a)
        for a in sketch_init(np, cfg)
    )
    return (tuple(np.array(f) for f in g.frees), ring, sk, np.zeros(c))


def _stream_group_call(
    vt, g: _GroupPack, times, concurrency, seed, window, cfg, coarsen, engine,
    pad_to, state, r0, emit=False,
):
    """Advance one group's streaming state over ``times`` ((C, n) arrivals).
    Pads the segment to a multiple of ``pad_to`` with carry-masked requests
    so varying segment lengths share compiled kernels.  With ``emit`` also
    returns the materialized (C, n) completions (padding sliced off)."""
    c, n = times.shape
    if state is None:
        state = _init_stream_state(g, concurrency, cfg)
    if n == 0:
        return (state, (np.zeros((c, 0)), np.zeros((c, 0)))) if emit else state
    dims, salts = _stream_dims_salts(vt, seed)
    plans = tuple(
        chunk_plan(dims[li][1], g.frees[li].shape[-1], coarsen)
        for li in range(len(dims))
    )
    q = max(1, int(pad_to))
    n_pad = -(-n // q) * q
    if n_pad > n:
        times = np.concatenate(
            [times, np.broadcast_to(times[:, -1:], (c, n_pad - n))], axis=1
        )
    frees, ring, sk, hor = state
    if engine == "jax":
        from jax.experimental import enable_x64

        fn = _stream_runner(
            vt, g, concurrency, n_pad, window, cfg, plans, dims, salts, seed,
            g.xfer is not None, emit,
        )
        with enable_x64():
            out = fn(frees, g.xfer, times, ring, sk, hor, r0, n)
        if emit:
            out, ys = out
            comp = (np.asarray(ys[0])[:, :n], np.asarray(ys[1])[:, :n])
        frees = tuple(np.asarray(f) for f in out[0])
        ring = np.asarray(out[1])
        sk = tuple(np.asarray(a) for a in out[2])
        hor = np.asarray(out[3])
        state = (frees, ring, sk, hor)
        return (state, comp) if emit else state
    new_frees = [np.empty_like(f) for f in frees]
    ring = ring.copy()
    sk = tuple(a.copy() for a in sk)
    hor = hor.copy()
    comp = (np.zeros((c, n)), np.zeros((c, n))) if emit else None
    for k in range(c):
        carry = _run_stream_kernel(
            np, _np_scan, g.stages, tuple(f[k] for f in frees), times[k],
            concurrency, cfg, salts, dims, plans,
            tuple(a[k] for a in sk), hor[k], ring0=ring[k],
            xfer=None if g.xfer is None else g.xfer[k],
            window=window, r0=r0, n_valid=n, emit=emit,
        )
        if emit:
            carry, ys = carry
            comp[0][k] = np.asarray(ys[0])[:n]
            comp[1][k] = np.asarray(ys[1])[:n]
        for li, f in enumerate(carry[0]):
            new_frees[li][k] = f
        ring[k] = carry[1]
        for a, v in zip(sk, carry[2]):
            a[k] = v
        hor[k] = carry[3]
    state = (tuple(new_frees), ring, sk, hor)
    return (state, comp) if emit else state


# ----------------------------------------------------------------- results
@dataclass(frozen=True)
class FleetResult:
    """Streaming replay outcome: per-config sketches instead of (C, N)
    latency matrices — memory O(C x buckets) at any trace length."""

    sketches: tuple  # (C,) LatencySketch
    percentile_qs: tuple
    makespan: np.ndarray  # (C,) cycles (max completion)
    n_requests: int
    clock_hz: float = CLOCK_HZ
    window: int = 1
    arrivals: np.ndarray | None = None  # (C, N) materialize=True only
    completions: np.ndarray | None = None  # (C, N) materialize=True only

    def __len__(self) -> int:
        return len(self.sketches)

    @property
    def percentiles(self) -> np.ndarray:  # (C, Q) sketch-estimated, cycles
        return np.stack(
            [s.percentiles(self.percentile_qs) for s in self.sketches]
        )

    def percentile(self, q: float) -> np.ndarray:  # (C,)
        return self.percentiles[:, self.percentile_qs.index(q)]

    @property
    def p99(self) -> np.ndarray:
        return self.percentile(99.0)

    def latency(self, i: int) -> LatencyStats:
        return self.sketches[i].stats

    @property
    def exact_percentiles(self) -> np.ndarray:  # (C, Q), materialize=True only
        """Exact ``np.percentile`` over materialized latencies — the
        reference the sketch percentiles are pinned against."""
        if self.completions is None:
            raise ValueError("exact percentiles need run_stream(materialize=True)")
        lat = self.completions - self.arrivals
        return np.percentile(lat, self.percentile_qs, axis=1).T

    @property
    def requests_per_sec(self) -> np.ndarray:  # (C,) simulated service rate
        span = np.maximum(self.makespan, 1e-300)
        return np.where(
            self.makespan > 0, self.n_requests / span * self.clock_hz, 0.0
        )


@dataclass(frozen=True)
class SegmentReport:
    """One control interval: the re-allocation charged on entry + volume."""

    start: float  # cycles (0.0 for the first segment)
    n_requests: int
    arrays_added: np.ndarray  # (C,) eNVM arrays reprogrammed at entry
    stall_cycles: np.ndarray  # (C,) fabric freeze charged at entry


@dataclass(frozen=True)
class SegmentedReplayResult:
    """Whole-trace outcome of ``run_trace_segments``.

    ``sketches`` accumulate IN-KERNEL across segments (the sketch state is
    scan carry, handed from segment to segment), so they equal the
    unsegmented streaming sketches bit-for-bit when no allocation changes.
    Materializing mode (``stream=False``) also fills ``arrivals`` /
    ``completions`` for exact-percentile validation at test scale."""

    sketches: tuple  # (C,) LatencySketch over the whole trace
    percentile_qs: tuple
    segments: tuple  # (S,) SegmentReport
    makespan: np.ndarray  # (C,)
    n_requests: int
    clock_hz: float = CLOCK_HZ
    arrivals: np.ndarray | None = None  # (C, N) stream=False only
    completions: np.ndarray | None = None  # (C, N) stream=False only

    @property
    def percentiles(self) -> np.ndarray:  # (C, Q)
        return np.stack(
            [s.percentiles(self.percentile_qs) for s in self.sketches]
        )

    def percentile(self, q: float) -> np.ndarray:
        return self.percentiles[:, self.percentile_qs.index(q)]

    @property
    def p99(self) -> np.ndarray:
        return self.percentile(99.0)

    def latency(self, i: int) -> LatencyStats:
        return self.sketches[i].stats

    @property
    def total_stall_cycles(self) -> np.ndarray:  # (C,)
        return np.sum([s.stall_cycles for s in self.segments], axis=0)


# -------------------------------------------------------------- run_stream
def run_stream(
    vt: VirtualTimeFabric,
    allocs,
    proc: ArrivalProcess | list,
    *,
    seed: int = 0,
    engine: str = "jax",
    window: int = 8,
    percentiles: tuple = (50.0, 95.0, 99.0),
    sketch: SketchConfig = SketchConfig(),
    coarsen: CoarsenConfig | None = None,
    placements: list | None = None,
    pad_to: int = 1,
    materialize: bool = False,
) -> FleetResult:
    """Streaming batched replay: ``VirtualTimeFabric.run_batch`` semantics
    with O(lanes + sketch) memory per config and hash-derived service times.

    Service indices come from ``hash_service_indices(seed, layer, request,
    patch)`` rather than the presampled tensors, so results are a different
    (equally valid) draw than ``run_batch(seed=...)`` — the cross-engine pin
    is ``FabricSim(service_sampling="hash")``, which consumes the identical
    hash.  ``window`` blocks the request scan (bit-identical per the vtime
    proof); ``coarsen`` opts into macro-job chunking (documented pessimistic
    bias); percentiles come from the sketch within ``sketch.rel_error``.

    ``materialize`` additionally keeps the full (C, N) arrival/completion
    matrices — the exact-percentile baseline path (O(C x N) memory, what
    the sketch exists to avoid at fleet scale; same hashed service draws).
    """
    if engine not in ("jax", "numpy"):
        raise ValueError(f"engine must be 'jax' or 'numpy', got {engine!r}")
    allocs = list(allocs)
    if not allocs:
        raise ValueError("need at least one allocation")
    if placements is not None and len(placements) != len(allocs):
        raise ValueError(f"{len(placements)} placements for {len(allocs)} allocations")
    procs = proc if isinstance(proc, list) else [proc] * len(allocs)
    if len(procs) != len(allocs):
        raise ValueError(f"{len(procs)} arrival processes for {len(allocs)} allocations")
    closed = isinstance(procs[0], ClosedLoop)
    if any(isinstance(p, ClosedLoop) != closed for p in procs):
        raise ValueError("cannot mix closed- and open-loop processes in one batch")
    if closed:
        concurrency = procs[0].concurrency
        if any(
            p.concurrency != concurrency or p.n_requests != procs[0].n_requests
            for p in procs
        ):
            raise ValueError("closed-loop batch needs identical (n_requests, concurrency)")
        n = procs[0].n_requests
        times = np.zeros((len(allocs), n))
    else:
        concurrency = None
        tlist = [arrival_times(p) for p in procs]
        n = tlist[0].size
        if any(t.size != n for t in tlist):
            raise ValueError("all arrival traces in a batch need the same length")
        times = np.stack(tlist).astype(np.float64) if n else np.zeros((len(allocs), 0))

    c_total = len(allocs)
    sketches: list = [LatencySketch.from_latencies([], sketch)] * c_total
    makespan = np.zeros(c_total)
    arr = comp = None
    if materialize:
        arr, comp = np.zeros((c_total, n)), np.zeros((c_total, n))
    if n:
        for g in vt._groups(allocs, placements):
            state = _stream_group_call(
                vt, g, times[g.rows], concurrency, seed, window, sketch,
                coarsen, engine, pad_to, state=None, r0=0, emit=materialize,
            )
            if materialize:
                state, (t0s, ts) = state
                arr[g.rows], comp[g.rows] = t0s, ts
            _, _, sk, hor = state
            for k, row in enumerate(g.rows):
                sketches[row] = LatencySketch.from_state(
                    sketch, tuple(a[k] for a in sk)
                )
                makespan[row] = hor[k]
    return FleetResult(
        tuple(sketches), tuple(percentiles), makespan, int(n), vt.clock_hz,
        int(window), arrivals=arr, completions=comp,
    )


# ------------------------------------------------------- segmented replay
def segment_growth_plan(
    spec: NetworkSpec,
    prof: NetworkProfile,
    alloc: Allocation,
    budgets,
    *,
    zskip: bool | None = None,
) -> list[Allocation]:
    """Allocation trajectory for ``run_trace_segments``: at each control
    boundary grant ``budgets[s]`` additional arrays to the blocks with the
    highest expected drain time, warm-started from the previous segment's
    replicas via ``greedy_allocate(initial_replicas=...)`` — the controller
    hook named in the ROADMAP.  A NEGATIVE budget shrinks instead (degraded
    capacity after a failure): ``greedy_release`` frees at least ``-b``
    arrays from the blocks whose latency suffers least, the exact inverse
    of the grant rule.  Returns ``len(budgets) + 1`` allocations (the input
    first)."""
    from ..core.alloc.greedy import greedy_allocate, greedy_release

    if alloc.block_dups is None:
        raise ValueError("segment_growth_plan requires a block-wise allocation")
    if zskip is None:
        zskip = alloc.policy != "baseline"
    cyc = _layer_patch_cycles(prof, zskip)
    base_lat, cost = blockwise_units(spec, [c.mean(axis=0) for c in cyc])
    cur = np.concatenate(
        [np.asarray(d, dtype=np.int64) for d in alloc.block_dups]
    )
    used, total = int(alloc.arrays_used), int(alloc.arrays_total)
    out = [alloc]
    for b in budgets:
        if float(b) < 0:
            res = greedy_release(base_lat, cost, -float(b), replicas=cur)
        else:
            res = greedy_allocate(base_lat, cost, float(b), initial_replicas=cur)
        cur = res.replicas
        used += int(round(res.spent))
        out.append(
            Allocation(
                alloc.policy, None, split_block_dups(spec, cur), used,
                max(total, used),
            )
        )
    return out


def _segment_pack(vt: VirtualTimeFabric, segs):
    """One group for ALL segments: stages from the profile, lane count per
    layer = max over segments (lane_quantum-rounded) so every segment shares
    one compiled kernel shape.  Returns (group for segment 0, per-segment
    per-layer (C, B) dup arrays)."""
    zskip = segs[0][0].policy != "baseline"
    stages, _ = _pack_group(
        vt.spec, vt._cyc[zskip], False, segs[0], lane_quantum=vt.lane_quantum
    )
    n_layers = len(vt.spec.layers)
    dups = [
        [
            np.stack([np.asarray(a.block_dups[li], dtype=np.int64) for a in seg])
            for li in range(n_layers)
        ]
        for seg in segs
    ]  # (S)(L)(C, B)
    q = max(1, int(vt.lane_quantum))
    frees0 = []
    for li in range(n_layers):
        d_max = max(int(d[li].max()) for d in dups)
        d_lanes = -(-d_max // q) * q
        frees0.append(
            np.where(np.arange(d_lanes) < dups[0][li][:, :, None], 0.0, np.inf)
        )
    g = _GroupPack(
        np.arange(len(segs[0])), False, zskip, stages, tuple(frees0), None
    )
    return g, dups


def _apply_boundary(frees, dups_old, dups_new, arrays_added, t_free):
    """Event-engine seam semantics on packed lanes: for configs that
    reprogram (``arrays_added > 0``, positive dup diffs only) every existing
    lane freezes until ``t_free`` (= boundary + stall) and the grown lanes
    come online at ``t_free`` — exactly ``FabricSim.apply_growth``.  Blocks
    that SHRINK (failures: survivors < previous replicas) lose their
    latest-free lanes — sorted positions ``[dups_new, dups_old)`` hold the
    largest finite free-times, and setting them to ``+inf`` is the existing
    absent-server convention; ``ServerPool.kill`` removes the same multiset
    on the event side.  Unchanged configs pass through untouched (a
    zero-change boundary is a no-op)."""
    hit = arrays_added > 0
    out = []
    for li, f in enumerate(frees):
        lanes = np.array(f)  # (C, B, D) sorted ascending, inf = absent
        clamp = hit[:, None, None] & np.isfinite(lanes)
        lanes = np.where(clamp, np.maximum(lanes, t_free[:, None, None]), lanes)
        d = np.arange(lanes.shape[-1])
        grow = (d >= dups_old[li][:, :, None]) & (d < dups_new[li][:, :, None])
        lanes = np.where(grow, t_free[:, None, None], lanes)
        dead = (d >= dups_new[li][:, :, None]) & (d < dups_old[li][:, :, None])
        lanes = np.where(dead, np.inf, lanes)
        out.append(np.sort(lanes, axis=-1))
    return tuple(out)


def run_trace_segments(
    vt: VirtualTimeFabric,
    allocs_by_segment,
    proc: ArrivalProcess | np.ndarray,
    boundaries,
    *,
    drift: DriftConfig = DriftConfig(),
    seed: int = 0,
    engine: str = "jax",
    window: int = 8,
    percentiles: tuple = (50.0, 95.0, 99.0),
    sketch: SketchConfig = SketchConfig(),
    coarsen: CoarsenConfig | None = None,
    stream: bool = True,
    pad_to: int = 4096,
) -> SegmentedReplayResult:
    """Segmented warm-start replay of one long open-loop trace.

    The trace is split at ``boundaries`` (cycles, nondecreasing); segment
    ``s`` runs under ``allocs_by_segment[s]`` (one ``Allocation`` or a
    C-list per segment), with free-lane state carried across boundaries and
    each config's reprogramming stall — ``drift.stall(arrays_added)``, from
    net-NEW replicas only — charged to every lane at entry.  Allocations may
    grow or shrink at a seam: shrinking a block kills its latest-free lanes
    (``+inf``, the absent-server convention), which is how seeded failure
    traces replay here (``fabric.failures.degrade_plan`` /
    ``run_trace_failures``); a shrink-to-identical plan stays bit-identical
    to the unsegmented replay.

    ``stream=True`` (default) keeps sketch + lane state in-carry and pads
    segments to ``pad_to`` requests so all segments share compiled kernels;
    with identical allocations and zero stalls it is bit-identical to the
    unsegmented ``run_stream``.  ``stream=False`` materializes per-request
    completions (presampled service draws, exactly ``run_batch``'s) for
    validation at test scale — identical allocations reproduce
    ``run_batch`` completions bit-for-bit.
    """
    if engine not in ("jax", "numpy"):
        raise ValueError(f"engine must be 'jax' or 'numpy', got {engine!r}")
    if isinstance(proc, ClosedLoop):
        raise ValueError("segmented replay is open-loop only (trace/Poisson arrivals)")
    times = (
        np.asarray(proc, dtype=np.float64)
        if isinstance(proc, np.ndarray)
        else arrival_times(proc)
    )
    bounds = np.asarray(boundaries, dtype=np.float64)
    if bounds.ndim != 1:
        raise ValueError("boundaries must be a 1-D sequence of cycle times")
    if bounds.size and np.any(np.diff(bounds) < 0):
        raise ValueError("boundaries must be nondecreasing")
    segs = [
        list(seg) if isinstance(seg, (list, tuple)) else [seg]
        for seg in allocs_by_segment
    ]
    n_seg = len(segs)
    if n_seg != bounds.size + 1:
        raise ValueError(
            f"{n_seg} segment allocations need {n_seg - 1} boundaries, got {bounds.size}"
        )
    c_total = len(segs[0])
    if any(len(seg) != c_total for seg in segs):
        raise ValueError("every segment needs the same number of allocations")
    zskip = segs[0][0].policy != "baseline"
    for seg in segs:
        for a in seg:
            if a.block_dups is None:
                raise ValueError("segmented replay requires block-wise allocations")
            if (a.policy != "baseline") != zskip:
                raise ValueError("all segment allocations must share zero-skipping")

    g, dups = _segment_pack(vt, segs)
    n_layers = len(vt.spec.layers)
    widths = np.asarray(
        [vt.spec.layers[li].arrays_per_block for li in range(n_layers)],
        dtype=np.int64,
    )
    added = np.zeros((n_seg, c_total), dtype=np.int64)
    for s in range(1, n_seg):
        for li in range(n_layers):
            diff = dups[s][li] - dups[s - 1][li]  # (C, B)
            # positive diffs only: shrunk lanes (failures) lose their
            # replica without reprogramming anything, so only net-new
            # replicas charge the drift stall
            added[s] += np.maximum(diff, 0).sum(axis=1) * widths[li]
    stalls = np.zeros((n_seg, c_total))
    for s in range(1, n_seg):
        stalls[s] = [
            drift.stall(int(a)) if a > 0 else 0.0 for a in added[s]
        ]

    n = times.size
    cuts = np.searchsorted(times, bounds, side="left")
    starts = np.concatenate([[0], cuts]).astype(np.int64)
    ends = np.concatenate([cuts, [n]]).astype(np.int64)
    reports = tuple(
        SegmentReport(
            0.0 if s == 0 else float(bounds[s - 1]),
            int(ends[s] - starts[s]),
            added[s].astype(np.float64),
            stalls[s].copy(),
        )
        for s in range(n_seg)
    )

    if stream:
        state = _init_stream_state(g, None, sketch)
        for s in range(n_seg):
            if s:
                frees = _apply_boundary(
                    state[0], dups[s - 1], dups[s], added[s],
                    bounds[s - 1] + stalls[s],
                )
                state = (frees,) + state[1:]
            lo, hi = int(starts[s]), int(ends[s])
            if hi > lo:
                seg_times = np.broadcast_to(times[lo:hi], (c_total, hi - lo))
                state = _stream_group_call(
                    vt, g, seg_times, None, seed, window, sketch, coarsen,
                    engine, pad_to, state=state, r0=lo,
                )
        _, _, sk, hor = state
        sketches = tuple(
            LatencySketch.from_state(sketch, tuple(a[k] for a in sk))
            for k in range(c_total)
        )
        return SegmentedReplayResult(
            sketches, tuple(percentiles), reports, np.asarray(hor), int(n),
            vt.clock_hz,
        )

    # materializing mode: presampled draws (= run_batch's), exact outputs
    dims = [
        (vt._cyc[True][i].shape[0], l.patches_per_image)
        for i, l in enumerate(vt.spec.layers)
    ]
    idx = sample_service_indices(np.random.default_rng(seed), dims, n)
    frees = tuple(np.array(f) for f in g.frees)
    completions = np.zeros((c_total, n))
    for s in range(n_seg):
        if s:
            frees = _apply_boundary(
                frees, dups[s - 1], dups[s], added[s], bounds[s - 1] + stalls[s]
            )
        lo, hi = int(starts[s]), int(ends[s])
        if hi == lo:
            continue
        idx_s = tuple(ix[lo:hi] for ix in idx)
        times_s = times[lo:hi]
        if engine == "jax":
            from jax.experimental import enable_x64

            fn = vt._jax_runner(
                g, None, hi - lo, tuple(percentiles), window=window,
                return_state=True,
            )
            with enable_x64():
                out = fn(
                    frees, None, np.broadcast_to(times_s, (c_total, hi - lo)),
                    idx_s,
                )
            completions[:, lo:hi] = np.asarray(out[1])
            frees = tuple(np.asarray(f) for f in out[3])
        else:
            new_frees = [np.empty_like(f) for f in frees]
            for k in range(c_total):
                out = run_fabric_kernel(
                    np, _np_scan, g.stages, tuple(f[k] for f in frees),
                    times_s, idx_s, None, tuple(percentiles), window=window,
                    return_state=True,
                )
                completions[k, lo:hi] = out[1]
                for li, f in enumerate(out[3]):
                    new_frees[li][k] = f
            frees = tuple(new_frees)
    arrivals = np.broadcast_to(times, (c_total, n)).copy()
    sketches = tuple(
        LatencySketch.from_latencies(completions[k] - times, sketch)
        for k in range(c_total)
    )
    makespan = completions.max(axis=1) if n else np.zeros(c_total)
    return SegmentedReplayResult(
        sketches, tuple(percentiles), reports, makespan, int(n), vt.clock_hz,
        arrivals=arrivals, completions=completions,
    )


def run_trace_failures(
    vt: VirtualTimeFabric,
    prof: NetworkProfile,
    alloc: Allocation,
    proc: ArrivalProcess | np.ndarray,
    failures,
    *,
    spare_arrays: float = 0.0,
    drift: DriftConfig = DriftConfig(),
    min_survivors: int = 1,
    **kwargs,
) -> SegmentedReplayResult:
    """Replay one trace under a seeded failure trace on the vtime engine.

    ``failures`` is a ``fabric.failures.FailureTrace`` (compiled to a
    ``DegradePlan`` here) or an already-built ``DegradePlan``.  Thin sugar
    over ``degrade_plan`` + ``run_trace_segments``: every failure/repair
    time becomes a segment seam, survivors are re-placed from the
    ``spare_arrays`` hot pool via warm-started greedy, and reprogramming
    stalls are charged in-kernel.  ``FabricSim(failures=plan)`` replays the
    same plan bit-identically (the cross-engine contract)."""
    from .failures import FailureTrace, degrade_plan

    if isinstance(failures, FailureTrace):
        plan = degrade_plan(
            vt.spec, prof, alloc, failures,
            spare_arrays=spare_arrays, drift=drift, min_survivors=min_survivors,
        )
    else:
        plan = failures
    return run_trace_segments(
        vt, list(plan.allocs), proc, plan.boundaries, drift=plan.drift, **kwargs
    )
