"""FabricSim: execute a (NetworkSpec, NetworkProfile, Allocation) triple on
the discrete-event core.

Mapping onto pools follows the dataflow of the allocation:

  * layer-wise (``layer_dups``): one pool per layer; a server is a full
    duplicate of the layer's block grid; a job is a patch whose service time
    is the gather/accumulate barrier ``max_b cycles[p, b]``.
  * block-wise (``block_dups``): one pool per block; a server is one block
    replica; a patch becomes one independent job per block.

A request (image) traverses layers in sequence: all of its patch jobs for
layer ``l`` are enqueued when it enters the stage, and it enters ``l+1``
when the last of them completes.  Layers occupy disjoint arrays, so
consecutive requests pipeline across stages exactly as in the paper; the
steady-state throughput of a saturated closed loop converges to the analytic
``simulate()`` bottleneck (tests assert agreement within 10%).

Per-patch service times are drawn (with replacement) from the profiled
per-(patch, block) cycle sample — or, for drift studies, from a second
"live" profile that the dispatcher samples while the monitor still expects
the original one.  Draws are presampled request-major at the start of a run
(``vtime.sample_service_indices``), so the virtual-time engines consume
identical randomness and reproduce this engine bit for bit.

Multi-chip fabrics add one term: a ``Placement`` (``core.cim.topology``)
carries a per-stage entry transfer delay — the cycles a request's
activations spend crossing inter-chip links to reach the stage's farthest
replica — and the dispatcher simply dispatches stage ``s`` at ``t +
stage_transfer[s]``.  The virtual-time kernel adds the identical IEEE
operation at the identical point, so the engines stay bit-identical with
transfer delays enabled; a single-chip placement has all-zero transfers and
reproduces the flat engine exactly.

Failure injection (``failures=``, a ``fabric.failures.DegradePlan``) replays
a seeded failure trace on this engine: each failure/repair seam cuts the
request stream by ARRIVAL index (``searchsorted(times, boundary)`` — the
identical cut segmented replay makes) and is applied to a stage's pools
lazily, right before the first post-seam request dispatches there (valid
because pools are non-overtaking FIFO per stage).  A shrink kills the
latest-free lanes (``ServerPool.kill`` — the multiset the packed kernel
sends to ``+inf``); growth/repair freezes the stage until ``boundary +
DriftConfig.stall`` and brings lanes online then, exactly ``apply_growth``.
Jobs already dispatched to a killed lane drain (completion fixed at
dispatch, both engines).  Under the same plan this engine and
``fleet.run_trace_segments`` are bit-identical (pinned in tests).  On top —
outside the bit-identity contract — a ``RetryPolicy`` governs zero-survivor
blocks: requests stall until the block's next repair/re-place and are shed
(NaN completion) past ``timeout_cycles`` or ``max_retries`` stalls.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from ..core.cim.network import NetworkSpec
from ..core.cim.profile import NetworkProfile
from ..core.cim.simulate import Allocation, CLOCK_HZ, _layer_patch_cycles
from .arrivals import ArrivalProcess, ClosedLoop, arrival_times
from .events import EventCalendar, ServerPool
from .failures import DegradePlan, RetryPolicy
from .metrics import FabricResult, FabricStats
from .telemetry import get_telemetry
from .vtime import _hash_salt, hash_service_indices, sample_service_indices

__all__ = ["FabricSim"]


@dataclass
class _Stage:
    blockwise: bool
    pools: list[ServerPool]
    services: np.ndarray  # (S,) barrier times or (S, B) per-block samples
    ppi: int
    # layer-wise only: true busy array-cycles per patch (sum over blocks x
    # block width).  The pool's own accounting charges the barrier max to
    # every array, which would hide exactly the intra-layer waste the
    # analytic model's utilization (paper Fig 9) measures.
    busy_sample: np.ndarray | None = None
    busy: float = 0.0


class FabricSim:
    def __init__(
        self,
        spec: NetworkSpec,
        prof: NetworkProfile,
        alloc: Allocation,
        *,
        seed: int = 0,
        live_prof: NetworkProfile | None = None,
        reallocator=None,
        clock_hz: float = CLOCK_HZ,
        record_timeline: bool = False,
        placement=None,
        stats: bool = False,
        service_sampling: str = "presample",
        failures: DegradePlan | None = None,
        retry: RetryPolicy | None = None,
    ):
        if service_sampling not in ("presample", "hash"):
            raise ValueError(
                f"service_sampling must be 'presample' or 'hash', got {service_sampling!r}"
            )
        self.spec = spec
        self.alloc = alloc
        self.clock_hz = clock_hz
        self.reallocator = reallocator
        self.collect_stats = bool(stats)
        # "presample" draws (N, ppi) index tensors through
        # sample_service_indices (the seed-for-seed contract with
        # VirtualTimeFabric.run_batch); "hash" derives the same indices the
        # streaming fleet kernel hashes in-kernel (fleet.run_stream), so the
        # event engine stays the bit-identity reference at fleet seeds too
        self.service_sampling = service_sampling
        self._seed = int(seed)
        # per-stage request entry transfer (core.cim.topology.Placement);
        # None = flat single-chip fabric, zero added work on the hot path
        self._xfer = (
            None
            if placement is None
            else np.asarray(placement.stage_transfer, dtype=np.float64)
        )
        if self._xfer is not None and self._xfer.shape != (len(spec.layers),):
            raise ValueError(
                f"placement covers {self._xfer.shape[0]} stages, "
                f"spec has {len(spec.layers)} layers"
            )
        self.rng = np.random.default_rng(seed)
        zskip = alloc.policy != "baseline"
        cyc = _layer_patch_cycles(live_prof or prof, zskip)
        self.stages: list[_Stage] = []
        for i, layer in enumerate(spec.layers):
            if alloc.layer_dups is not None:
                pools = [
                    ServerPool(
                        int(alloc.layer_dups[i]),
                        width=layer.n_arrays,
                        record_starts=record_timeline,
                        stats=stats,
                    )
                ]
                services = cyc[i].max(axis=1)  # per-patch barrier
                busy_sample = cyc[i].sum(axis=1) * layer.arrays_per_block
                self.stages.append(
                    _Stage(False, pools, services, layer.patches_per_image, busy_sample)
                )
            else:
                dups = alloc.block_dups[i]
                pools = [
                    ServerPool(
                        int(dups[b]),
                        width=layer.arrays_per_block,
                        record_starts=record_timeline,
                        stats=stats,
                    )
                    for b in range(layer.n_blocks)
                ]
                self.stages.append(_Stage(True, pools, cyc[i], layer.patches_per_image))
        if reallocator is not None:
            if alloc.block_dups is None:
                raise ValueError("online re-allocation requires a block-wise allocation")
            reallocator.bind(self)
        self.failures = failures
        self.retry = retry if retry is not None else RetryPolicy()
        self._fail_bounds: np.ndarray | None = None
        if failures is not None:
            if alloc.block_dups is None:
                raise ValueError("failure injection requires a block-wise allocation")
            if reallocator is not None:
                raise ValueError(
                    "failure injection and online re-allocation both rewrite "
                    "pool shapes — use one or the other"
                )
            first = np.concatenate(
                [np.asarray(d) for d in failures.allocs[0].block_dups]
            )
            cur = np.concatenate([np.asarray(d) for d in alloc.block_dups])
            if not np.array_equal(first, cur):
                raise ValueError(
                    "the degrade plan's first segment must match the running "
                    "allocation"
                )
            self._fail_bounds = np.asarray(failures.boundaries, dtype=np.float64)
            self._fail_tfree = self._fail_bounds + np.asarray(
                failures.stall_cycles[1:], dtype=np.float64
            )
            self._fail_added = np.asarray(failures.arrays_added[1:], dtype=np.int64)
            self._seg_dups = [a.block_dups for a in failures.allocs]
            self._phantom: set[tuple[int, int]] = set()
            self._n_retried_busy = 0
            self._n_shed = 0

    # ------------------------------------------------------------- internals
    def _next_revival(self, stage_idx: int, b: int, seam: int) -> float:
        """When a zero-survivor block next regains a replica: the ``t_free``
        of the first seam after ``seam`` whose plan gives it lanes again
        (repair or spare re-place), ``inf`` if it never revives."""
        for s in range(seam + 1, len(self._fail_bounds)):
            if int(self._seg_dups[s + 1][stage_idx][b]) > 0:
                return float(self._fail_tfree[s])
        return math.inf

    def _apply_seam(self, stage_idx: int, seam: int) -> None:
        """Apply failure seam ``seam`` to one stage's pools: freeze-if-grown
        first, then per-block net kill/grow — the same order (and therefore
        the same free-time multisets) as ``fleet._apply_boundary``'s
        clamp-then-shrink on the packed lanes."""
        st = self.stages[stage_idx]
        boundary = float(self._fail_bounds[seam])
        t_free = float(self._fail_tfree[seam])
        if self._fail_added[seam] > 0:
            # reprogramming freezes word lines fabric-wide; each stage
            # applies its share lazily, before its first post-seam dispatch
            for p in st.pools:
                p.freeze_until(t_free)
        if not st.blockwise:
            return
        old = self._seg_dups[seam][stage_idx]
        new = self._seg_dups[seam + 1][stage_idx]
        for b, pool in enumerate(st.pools):
            diff = int(new[b]) - int(old[b])
            if (stage_idx, b) in self._phantom:
                if int(new[b]) > 0:
                    # the phantom placeholder becomes the first revived lane
                    if diff - 1 > 0:
                        pool.grow(diff - 1, t_free)
                    self._phantom.discard((stage_idx, b))
                continue
            if diff > 0:
                pool.grow(diff, t_free)
            elif diff < 0:
                self._n_retried_busy += pool.kill(-diff, boundary)
                if int(new[b]) == 0:
                    # park a placeholder lane at the block's next revival so
                    # FIFO queueing across the dead window falls out naturally
                    pool.grow(1, self._next_revival(stage_idx, b, seam))
                    self._phantom.add((stage_idx, b))

    def _dispatch_stage(self, stage_idx: int, t: float, req: int) -> float:
        if self._fail_bounds is not None:
            nxt = self._seam_next[stage_idx]
            while nxt < self._fail_cuts.size and req >= self._fail_cuts[nxt]:
                self._apply_seam(stage_idx, nxt)
                nxt += 1
            self._seam_next[stage_idx] = nxt
            if self._phantom:
                for b in range(len(self.stages[stage_idx].pools)):
                    if (stage_idx, b) not in self._phantom:
                        continue
                    pool = self.stages[stage_idx].pools[b]
                    start = min(pool.avail)
                    wait = (start if start > t else t) - t
                    if (
                        wait > self.retry.timeout_cycles
                        or self._stall_count[req] >= self.retry.max_retries
                    ):
                        self._n_shed += 1
                        return math.nan
                    self._stall_count[req] += 1
                    break  # one stall charge per stage entry
        if self._xfer is not None:
            # the request's activations cross the NoC/links before any of the
            # stage's jobs can start — same op, same place as vtime's kernel
            t = t + self._xfer[stage_idx]
        st = self.stages[stage_idx]
        idx = self._svc_idx[stage_idx][req]
        svc = st.services[idx]
        if not st.blockwise:
            st.busy += float(st.busy_sample[idx].sum())
            return st.pools[0].dispatch(t, svc)
        done = t
        for b, pool in enumerate(st.pools):
            c = pool.dispatch(t, svc[:, b])
            if c > done:
                done = c
        if self.reallocator is not None:
            self.reallocator.observe(stage_idx, svc.mean(axis=0), t)
        return done

    def current_block_dups(self) -> np.ndarray:
        """Flattened replica counts per block (block-wise stages only)."""
        return np.asarray(
            [p.n_servers for st in self.stages for p in st.pools if st.blockwise],
            dtype=np.int64,
        )

    def apply_growth(self, added: np.ndarray, t_free: float) -> None:
        """Bring ``added[j]`` extra replicas of flat block ``j`` online at
        ``t_free``; every pool stalls until then (array reprogramming freezes
        word lines fabric-wide).  Jobs already enqueued drain on the old
        configuration — re-programming overlaps with the drain."""
        k = 0
        for st in self.stages:
            for p in st.pools:
                p.freeze_until(t_free)
                if st.blockwise:
                    if added[k]:
                        p.grow(int(added[k]), t_free)
                    k += 1

    # ------------------------------------------------------------------ run
    def run(self, proc: ArrivalProcess) -> FabricResult:
        L = len(self.stages)
        cal = EventCalendar()
        times = arrival_times(proc)
        n = proc.n_requests if times is None else times.size
        if self._fail_bounds is not None:
            if times is None:
                raise ValueError(
                    "failure injection is open-loop only (trace/Poisson "
                    "arrivals), matching segmented replay"
                )
            # seams cut the request stream by ARRIVAL index — the identical
            # cut run_trace_segments makes, so the engines stay in lock-step
            self._fail_cuts = np.searchsorted(times, self._fail_bounds, side="left")
            self._seam_next = [0] * L
            self._stall_count = np.zeros(n, dtype=np.int64)
            self._phantom.clear()
        # request-major presampling (layer-major draw order): the same
        # helper, seed and order the virtual-time paths use, so per-request
        # service times are identical across engines regardless of the
        # calendar's interleaving; "hash" evaluates the fleet kernel's
        # counter hash instead (vectorized over requests — same bits the
        # streaming scan derives one request at a time)
        if self.service_sampling == "hash":
            self._svc_idx = [
                hash_service_indices(
                    np, _hash_salt(self._seed, li), np.arange(n),
                    st.ppi, st.services.shape[0],
                ).astype(np.int64)
                for li, st in enumerate(self.stages)
            ]
        else:
            self._svc_idx = sample_service_indices(
                self.rng, [(st.services.shape[0], st.ppi) for st in self.stages], n
            )
        arrivals = np.zeros(n)
        completions = np.zeros(n)
        if self.collect_stats:
            stage_entry = np.zeros((n, L))
            stage_exit = np.zeros((n, L))
        next_admit = 0
        if times is None:
            assert isinstance(proc, ClosedLoop)
            k = min(proc.concurrency, n)
            for r in range(k):
                cal.push(0.0, r, 0)
            next_admit = k
        else:
            for r in range(n):
                arrivals[r] = times[r]
                cal.push(times[r], r, 0)
        # Under a failure plan the contract is the request-ordered scan: a
        # seam that grows capacity can let a later request physically reach a
        # downstream stage first, but the plan semantics (and the vtime
        # kernel) assign lanes strictly by arrival index.  So with failures
        # active each stage buffers early arrivals and dispatches in request
        # order (head-of-line FIFO); without failures the calendar order IS
        # the index order (non-overtaking) and the buffer is bypassed.
        ordered = self._fail_bounds is not None
        if ordered:
            pend: list[dict[int, float]] = [{} for _ in range(L)]
            nxt_r = [0] * L
            is_shed = np.zeros(n, dtype=bool)

            def _drain(s: int) -> None:
                while True:
                    j = nxt_r[s]
                    if j < n and is_shed[j]:
                        nxt_r[s] += 1
                        continue
                    if j not in pend[s]:
                        return
                    tj = pend[s].pop(j)
                    dj = self._dispatch_stage(s, tj, j)
                    if self.collect_stats:
                        stage_entry[j, s] = tj
                        stage_exit[j, s] = dj
                    if dj != dj:  # shed on a dead block: NaN, no push
                        completions[j] = math.nan
                        is_shed[j] = True
                    else:
                        cal.push(dj, j, s + 1)
                    nxt_r[s] += 1

        while len(cal):
            t, r, s = cal.pop()
            if s == L:
                completions[r] = t
                if times is None and next_admit < n:
                    arrivals[next_admit] = t
                    cal.push(t, next_admit, 0)
                    next_admit += 1
                continue
            if ordered:
                pend[s][r] = t
                # a dispatch here can unblock any downstream stage (and a
                # shed must advance every later stage past the dead index)
                for s2 in range(s, L):
                    _drain(s2)
                continue
            done = self._dispatch_stage(s, t, r)
            if self.collect_stats:
                # entry = when the request became ready for the stage, BEFORE
                # the inter-chip transfer — residence = xfer + wait + service
                stage_entry[r, s] = t
                stage_exit[r, s] = done
            if done != done:  # shed on a dead block: NaN completion, no push
                completions[r] = math.nan
                continue
            cal.push(done, r, s + 1)

        layer_busy = np.array(
            [
                sum(p.busy for p in st.pools) if st.blockwise else st.busy
                for st in self.stages
            ]
        )
        layer_arrays = np.array(
            [sum(p.n_servers * p.width for p in st.pools) for st in self.stages],
            dtype=np.float64,
        )
        if self._fail_bounds is not None and completions.size:
            # shed requests leave NaN completions; the horizon is the last
            # SERVED completion (all-NaN degenerates to 0)
            served = completions[completions == completions]
            horizon = float(served.max()) if served.size else 0.0
        else:
            horizon = float(completions.max()) if completions.size else 0.0
        layer_capacity = np.array(
            [sum(p.capacity_cycles(horizon) for p in st.pools) for st in self.stages]
        )
        if self._fail_bounds is not None:
            tel = get_telemetry()
            tel.gauge("fabric.failures.availability", self.failures.availability())
            tel.count("fabric.failures.killed", self.failures.n_killed)
            tel.count("fabric.failures.repaired", self.failures.n_repaired)
            tel.count("fabric.failures.retried_busy_lanes", self._n_retried_busy)
            tel.count("fabric.failures.shed_requests", self._n_shed)
        stats = None
        if self.collect_stats:
            xfer = (
                np.zeros(L) if self._xfer is None else self._xfer * float(n)
            )  # every request crosses each stage's entry links exactly once
            stats = FabricStats(
                layer_service=np.array(
                    [sum(p.stats.svc_cycles for p in st.pools) for st in self.stages]
                ),
                layer_queue_wait=np.array(
                    [sum(p.stats.queue_wait for p in st.pools) for st in self.stages]
                ),
                layer_xfer=xfer,
                layer_reprogram=np.array(
                    [
                        sum(p.stats.frozen_cycles * p.width for p in st.pools)
                        for st in self.stages
                    ]
                ),
                layer_jobs=np.array(
                    [sum(p.stats.jobs for p in st.pools) for st in self.stages],
                    dtype=np.int64,
                ),
                replica_busy=tuple(
                    tuple(np.asarray(p.stats.server_busy) for p in st.pools)
                    for st in self.stages
                ),
                stage_entry=stage_entry,
                stage_exit=stage_exit,
                layer_occupied=np.array(
                    [sum(p.busy for p in st.pools) for st in self.stages]
                ),
            )
        return FabricResult(
            policy=self.alloc.policy,
            clock_hz=self.clock_hz,
            arrivals=arrivals,
            completions=completions,
            layer_busy=layer_busy,
            layer_arrays=layer_arrays,
            layer_capacity=layer_capacity,
            reallocations=(
                list(self.reallocator.events) if self.reallocator is not None else []
            ),
            stats=stats,
        )
