"""Per-cell abstract inputs + shardings for the dry-run and launchers.

``build_cell(arch, shape, mesh)`` resolves one (architecture x input-shape)
cell into: the step function to jit, abstract args (ShapeDtypeStruct —
weak-type-correct, shardable, NO device allocation), in/out shardings, and
the cell's useful MODEL_FLOPS for the roofline.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..configs import SHAPE_SPECS, get_config
from ..distrib.sharding import (
    batch_axes,
    cache_specs,
    data_specs,
    named,
    opt_specs,
    param_specs,
)
from ..models import encdec, lm
from ..models.config import ModelConfig
from ..optim.adamw import AdamWConfig, adamw_init
from ..train.step import (
    make_decode_step,
    make_encdec_decode_step,
    make_encdec_prefill_step,
    make_encdec_train_step,
    make_prefill_step,
    make_train_step,
)

__all__ = ["Cell", "build_cell"]


@dataclass
class Cell:
    arch: str
    shape: str
    cfg: ModelConfig
    fn: Callable
    args: tuple  # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    model_flops: float
    kind: str


def _abstract(fn) -> Any:
    return jax.eval_shape(fn)


def _abstract_params(cfg: ModelConfig):
    if cfg.family == "encdec":
        return _abstract(lambda: encdec.init_encdec_params(cfg, jax.random.PRNGKey(0)))
    return _abstract(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))


def _tokens_struct(b: int, s: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def build_cell(
    arch: str,
    shape: str,
    mesh: Mesh,
    opt: AdamWConfig | None = None,
    smoke: bool = False,
    overrides: dict | None = None,
) -> Cell:
    from ..distrib.context import set_mesh

    set_mesh(mesh)  # moe_fwd dispatch path selection
    cfg = get_config(arch, smoke=smoke)
    if overrides:
        cfg = cfg.with_(**overrides)
    spec = SHAPE_SPECS[shape]
    B, S, kind = spec["global_batch"], spec["seq_len"], spec["kind"]
    if smoke:
        B, S = 2, 32
    opt = opt or AdamWConfig()

    p_shape = _abstract_params(cfg)
    if spec["kind"] in ("prefill", "decode"):
        # serving runs on bf16 weights (fp32 masters are a training concern)
        p_shape = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.bfloat16)
            if a.dtype == jnp.float32
            else a,
            p_shape,
        )
    p_spec = param_specs(cfg, p_shape, mesh)
    p_shard = named(mesh, p_spec)
    dspec = data_specs(mesh, B)
    dshard = named(mesh, dspec)
    n_active = cfg.active_param_count()

    if kind == "train":
        o_shape = _abstract(lambda: adamw_init(p_shape))
        o_spec = opt_specs(cfg, o_shape, mesh)
        o_shard = named(mesh, o_spec)
        if cfg.family == "encdec":
            fn = make_encdec_train_step(cfg, opt)
            batch = {
                "frames": jax.ShapeDtypeStruct(
                    (B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype)
                ),
                "tokens": _tokens_struct(B, S),
                "targets": _tokens_struct(B, S),
            }
        else:
            fn = make_train_step(cfg, opt)
            batch = {"tokens": _tokens_struct(B, S), "targets": _tokens_struct(B, S)}
        b_shard = jax.tree.map(lambda _: dshard, batch)
        args = (p_shape, o_shape, batch)
        in_sh = (p_shard, o_shard, b_shard)
        out_sh = (p_shard, o_shard, None)
        model_flops = 6.0 * n_active * B * S
        return Cell(arch, shape, cfg, fn, args, in_sh, out_sh, model_flops, kind)

    if kind == "prefill":
        if cfg.family == "encdec":
            fn = make_encdec_prefill_step(cfg)
            frames = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype)
            )
            args = (p_shape, frames, _tokens_struct(B, S))
            in_sh = (p_shard, dshard, dshard)
        else:
            fn = make_prefill_step(cfg)
            args = (p_shape, _tokens_struct(B, S))
            in_sh = (p_shard, dshard)
        model_flops = 2.0 * n_active * B * S
        return Cell(arch, shape, cfg, fn, args, in_sh, None, model_flops, kind)

    # ---- decode: one new token with a cache of length S
    if cfg.family == "encdec":
        c_shape = _abstract(
            lambda: encdec.init_decoder_cache(cfg, B, S, jnp.dtype(cfg.dtype))
        )
        c_spec = cache_specs(cfg, c_shape, mesh)
        c_shard = named(mesh, c_spec)
        enc_out = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        fn = make_encdec_decode_step(cfg)
        args = (p_shape, c_shape, enc_out, _tokens_struct(B, 1))
        in_sh = (p_shard, c_shard, dshard, dshard)
        out_sh = (None, c_shard)
    else:
        c_shape = _abstract(lambda: lm.init_cache(cfg, B, S, jnp.dtype(cfg.dtype)))
        c_spec = cache_specs(cfg, c_shape, mesh)
        c_shard = named(mesh, c_spec)
        fn = make_decode_step(cfg)
        args = (p_shape, c_shape, _tokens_struct(B, 1))
        in_sh = (p_shard, c_shard, dshard)
        out_sh = (None, c_shard)
    model_flops = 2.0 * n_active * B * 1
    return Cell(arch, shape, cfg, fn, args, in_sh, out_sh, model_flops, "decode")
