"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS for 512 host devices
before first jax init; tests and benches see the single real CPU device.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_cpu_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; (2, 16, 16) = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh():
    """Degenerate 1x1 mesh over the single real CPU device (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
