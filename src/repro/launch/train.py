"""End-to-end trainer.

On real hardware this runs under the production mesh; on this container it
runs the smoke config of any architecture on the 1x1 CPU mesh — the same
code path (jit + shardings + fault-tolerant runner + checkpoints).

  PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --steps 20 \
      --smoke --ckpt /tmp/ckpt
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..data.pipeline import DataConfig, SyntheticLM
from ..distrib.context import set_mesh
from ..distrib.sharding import data_specs, named, opt_specs, param_specs
from ..models import lm
from ..optim.adamw import AdamWConfig, adamw_init
from ..runtime.fault import RunnerConfig, TrainRunner
from ..train.step import make_train_step
from .mesh import make_cpu_mesh, make_production_mesh


def fingerprint(cfg) -> str:
    return f"{cfg.name}/L{cfg.n_layers}/d{cfg.d_model}/v{cfg.vocab}"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="glm4-9b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family == "encdec":
        raise SystemExit("use examples/whisper_train.py for the enc-dec arch")
    mesh = make_production_mesh() if args.production_mesh else make_cpu_mesh()
    set_mesh(mesh)
    opt = AdamWConfig(lr=args.lr, warmup_steps=5, total_steps=args.steps)

    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    opt_state = adamw_init(params)
    p_sh = named(mesh, param_specs(cfg, params, mesh))
    o_sh = named(mesh, opt_specs(cfg, opt_state, mesh))
    d_sh = named(mesh, data_specs(mesh, args.batch))

    with mesh:
        step_fn = jax.jit(
            make_train_step(cfg, opt),
            in_shardings=(p_sh, o_sh, {"tokens": d_sh, "targets": d_sh}),
            out_shardings=(p_sh, o_sh, None),
        )

        data = SyntheticLM(
            DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
        )
        runner = TrainRunner(
            RunnerConfig(ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every),
            step_fn,
            lambda s: data.batch(s),
            fingerprint=fingerprint(cfg),
        )
        start = 0
        if args.resume:
            restored_step, tree = runner._restore(params, opt_state)
            if tree is not None:
                params, opt_state = tree["params"], tree["opt"]
                start = restored_step
                print(f"resumed from step {start}")
        t0 = time.time()
        params, opt_state = runner.run(params, opt_state, args.steps, start)
        dt = time.time() - t0

    losses = [h.metrics.get("loss", float("nan")) for h in runner.history]
    print(
        json.dumps(
            {
                "arch": cfg.name,
                "steps": len(runner.history),
                "first_loss": losses[0] if losses else None,
                "last_loss": losses[-1] if losses else None,
                "wall_s": round(dt, 1),
                "restores": runner.restores,
            }
        )
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
