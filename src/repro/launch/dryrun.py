import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production mesh and extract roofline terms.

This proves the distribution config is coherent without real hardware:
sharding mismatches, compile-time OOM and unsupported collectives all fail
here.  Results (memory analysis, cost analysis, collective schedule) are
written as JSON for EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both
"""

import argparse
import json
import sys
import time
import traceback

import jax

from ..configs import ARCH_IDS, SHAPES, cell_is_defined
from ..core import roofline as rl
from .mesh import make_production_mesh
from .specs import build_cell


def run_cell(
    arch: str,
    shape: str,
    *,
    multi_pod: bool = False,
    verbose: bool = True,
    overrides: dict | None = None,
) -> dict:
    ok, reason = cell_is_defined(arch, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod, "status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, overrides=overrides)
    with mesh:
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
        )
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    roof = rl.analyze(compiled, chips=chips, model_flops=cell.model_flops)
    st = rl.collective_stats(compiled.as_text())
    rec = {
        "arch": arch,
        "shape": shape,
        "multi_pod": multi_pod,
        "chips": chips,
        "status": "ok",
        "kind": cell.kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
        "roofline": roof.as_dict(),
        "collectives": {"bytes": st.bytes_by_op, "count": st.count_by_op},
    }
    if verbose:
        bpd = (rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"])
        print(
            f"[{arch} x {shape} x {'multi' if multi_pod else 'single'}-pod] OK  "
            f"compile={t_compile:.0f}s  bytes/dev={bpd/1e9:.2f}GB  "
            f"flops={roof.flops:.3e}  coll={roof.collective_bytes:.3e}B  "
            f"bottleneck={roof.bottleneck}  roofline_frac={roof.roofline_fraction:.3f}",
            flush=True,
        )
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=SHAPES)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["on", "off", "both"], default="off")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    pods = {"on": [True], "off": [False], "both": [False, True]}[args.multi_pod]
    cells = (
        [(a, s) for a in ARCH_IDS for s in SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    records, failures = [], 0
    for arch, shape in cells:
        for mp in pods:
            try:
                rec = run_cell(arch, shape, multi_pod=mp)
            except Exception as e:  # noqa: BLE001 — report and continue
                traceback.print_exc()
                rec = {
                    "arch": arch, "shape": shape, "multi_pod": mp,
                    "status": "failed", "error": f"{type(e).__name__}: {e}",
                }
                failures += 1
                print(f"[{arch} x {shape} x mp={mp}] FAILED: {e}", flush=True)
            records.append(rec)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records -> {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
