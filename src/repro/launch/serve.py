"""Batched serving loop: prefill a batch of prompts, then decode with the
KV/SSM cache.  Same step functions the dry-run lowers at production shapes.

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, get_config
from ..distrib.context import set_mesh
from ..models import lm
from ..train.step import make_decode_step
from .mesh import make_cpu_mesh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="glm4-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family == "encdec":
        raise SystemExit("use examples/whisper_serve for the enc-dec arch")
    mesh = make_cpu_mesh()
    set_mesh(mesh)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    max_seq = args.prompt_len + args.gen
    cache = lm.init_cache(cfg, args.batch, max_seq)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)

    decode_step = jax.jit(make_decode_step(cfg))
    with mesh:
        # prefill token-by-token is wasteful but exercises the decode path;
        # production prefill lowers the full-prompt forward (see specs.py).
        t0 = time.time()
        logits, cache = lm.forward(params, cfg, prompts, cache=cache)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)
        prefill_s = time.time() - t0

        out = [tok]
        t0 = time.time()
        for _ in range(args.gen - 1):
            tok, cache = decode_step(params, cache, tok[:, None])
            out.append(tok)
        decode_s = time.time() - t0
    gen = jnp.stack(out, axis=1)
    print(
        json.dumps(
            {
                "arch": cfg.name,
                "batch": args.batch,
                "prefill_s": round(prefill_s, 3),
                "decode_tok_per_s": round(args.batch * (args.gen - 1) / decode_s, 1),
                "sample": gen[0, :8].tolist(),
            }
        )
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
