"""Slot-based decode engine with PER-SLOT cache positions.

This is the paper's block-wise dataflow at the request level: a decode slot
is a "generalized compute unit"; when a request finishes, the slot refills
from the queue immediately instead of waiting for the whole batch (static
batching = the paper's layer-wise gather barrier; continuous batching =
next-available-block dispatch).

Per-slot state means per-sample cache lengths: writes scatter at
``lens[b]`` and attention masks per sample — the engine implements that
attention variant here (GQA archs), leaving the homogeneous-batch paths in
``models/layers.py`` untouched.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from ..models.layers import apply_rope, mlp_fwd, rmsnorm

__all__ = ["init_slot_state", "slot_decode_step", "reset_slots", "prefill_slot"]


def init_slot_state(cfg: ModelConfig, n_slots: int, max_seq: int, dtype=None) -> dict:
    """Stacked per-layer KV (L, b, S, kv, hd) + per-SLOT lengths (b,)."""
    assert cfg.family == "dense" and cfg.attn.kind == "gqa", (
        "slot engine covers GQA dense archs; other families use launch/serve"
    )
    dtype = dtype or jnp.dtype(cfg.dtype)
    _, nkv, hd = cfg.attn_dims()
    L = cfg.n_layers
    return {
        "k": jnp.zeros((L, n_slots, max_seq, nkv, hd), dtype),
        "v": jnp.zeros((L, n_slots, max_seq, nkv, hd), dtype),
        "lens": jnp.zeros((n_slots,), jnp.int32),
    }


def _slot_attn(p, cfg: ModelConfig, x, k_cache, v_cache, lens):
    """One token per slot against per-slot cache lengths.

    x: (b, d);  k_cache/v_cache: (b, S, kv, hd);  lens: (b,) pre-write lens.
    Returns (out (b, d), new_k, new_v)."""
    a = cfg.attn
    nh, nkv, hd = cfg.attn_dims()
    b, d = x.shape
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if a.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, 1, nh, hd)
    k = k.reshape(b, 1, nkv, hd)
    v = v.reshape(b, 1, nkv, hd)
    pos = lens[:, None]  # (b, 1) — per-slot positions
    q = apply_rope(q, pos, a.rope_theta, a.mrope_sections)
    k = apply_rope(k, pos, a.rope_theta, a.mrope_sections)
    # per-slot scatter at lens[b]
    bi = jnp.arange(b)
    k_cache = k_cache.at[bi, lens].set(k[:, 0])
    v_cache = v_cache.at[bi, lens].set(v[:, 0])
    # per-sample masked attention over the full cache
    rep = nh // nkv
    qg = q.reshape(b, nkv, rep, hd)
    scores = jnp.einsum("bkrh,bskh->bkrs", qg, k_cache) / np.sqrt(hd)
    valid = jnp.arange(k_cache.shape[1])[None, :] <= lens[:, None]  # (b, S)
    scores = jnp.where(valid[:, None, None, :], scores, jnp.finfo(scores.dtype).min)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bkrs,bskh->bkrh", probs, v_cache)
    y = out.reshape(b, nh * hd) @ p["wo"].astype(x.dtype)
    return y, k_cache, v_cache


@partial(jax.jit, static_argnames=("cfg",))
def slot_decode_step(params, cfg: ModelConfig, state: dict, tokens: jax.Array):
    """tokens (b,) -> (logits (b, vocab), new state).  Each slot advances
    by one at its OWN position."""
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]  # (b, d)
    lens = state["lens"]

    def body(x, inp):
        p_l, kc, vc = inp
        h, kc, vc = _slot_attn(
            p_l["attn"], cfg, rmsnorm(p_l["attn_norm"], x, cfg.norm_eps), kc, vc, lens
        )
        x = x + h
        x = x + mlp_fwd(p_l["mlp"], rmsnorm(p_l["mlp_norm"], x, cfg.norm_eps), cfg.activation)
        return x, (kc, vc)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["layers"], state["k"], state["v"])
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"]).astype(x.dtype)
    logits = x @ head
    new_state = {"k": new_k, "v": new_v, "lens": lens + 1}
    return logits, new_state


def reset_slots(state: dict, slot_mask: jax.Array) -> dict:
    """Zero the lengths (and lazily the cache validity) of refilled slots.
    slot_mask: (b,) bool — True = slot is being handed to a new request."""
    lens = jnp.where(slot_mask, 0, state["lens"])
    # stale kv beyond lens is masked by the per-sample valid mask; no need to
    # zero the buffers (same trick as paged-attention slot reuse).
    return dict(state, lens=lens)


def prefill_slot(params, cfg: ModelConfig, state: dict, tokens, slot_mask):
    """Feed prompt tokens (b, P) one step at a time into masked slots.
    Slots where slot_mask is False keep their state (their lens don't move
    because we re-assert them after)."""
    keep_lens = state["lens"]
    last_logits = None
    for t in range(tokens.shape[1]):
        logits, state = slot_decode_step(params, cfg, state, tokens[:, t])
        last_logits = logits
    # restore untouched slots' lengths (their cache rows were overwritten at
    # their own positions; acceptable for the demo engine, a production
    # engine would gather/scatter only the masked slots)
    lens = jnp.where(slot_mask, state["lens"], keep_lens)
    return last_logits, dict(state, lens=lens)
