"""Serving: slot engine (per-slot cache positions) + continuous batching."""
from .engine import init_slot_state, prefill_slot, reset_slots, slot_decode_step
from .scheduler import (
    BatchingStats,
    WorkloadConfig,
    sample_lengths,
    simulate_continuous,
    simulate_static,
)
__all__ = [
    "init_slot_state", "prefill_slot", "reset_slots", "slot_decode_step",
    "BatchingStats", "WorkloadConfig", "sample_lengths",
    "simulate_continuous", "simulate_static",
]
