"""Static vs continuous batching — the paper's barrier analysis for serving.

Static batching: B requests start together; the batch completes when the
LONGEST generation finishes (the synchronization barrier; utilization =
mean(len)/max(len), the exact shape of the paper's Fig 6 block-skew loss).

Continuous batching: a finished slot refills from the queue on the next
step (the paper's "send work to the next available block").

`simulate_*` are analytic slot-step counters (the serving counterpart of
core/cim/simulate.py); `Scheduler` drives the real slot engine
(serve/engine.py) for the runnable demo.

``fabric_slot_plan`` closes the loop with the fabric runtime: the fleet
replay (``fabric.fleet``) reports per-allocation tail latency for a day of
traffic, and the slot plan scales each allocation's decode batch so the
fabric stays inside its latency SLO — slots above the plan sit dormant
(``reset_slots``) until a re-allocation earns them back.

``brownout_plan`` is the failure-mode counterpart (``fabric.failures``):
when arrays die and post-failure capacity cannot meet the p99 SLO at the
offered load, it computes the admission fraction that sheds just enough
load to keep the queues from diverging — a degraded-but-bounded brownout
instead of an unbounded blackout.  Shedding trades throughput for tail
latency by construction; the EXPERIMENTS.md fault section quantifies the
loss.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = [
    "WorkloadConfig",
    "brownout_plan",
    "fabric_slot_plan",
    "sample_lengths",
    "simulate_static",
    "simulate_continuous",
    "BatchingStats",
]


@dataclass(frozen=True)
class WorkloadConfig:
    n_requests: int = 256
    mean_len: float = 128.0
    dist: str = "lognormal"  # request generation-length distribution
    sigma: float = 0.8
    seed: int = 0


def sample_lengths(cfg: WorkloadConfig) -> np.ndarray:
    rng = np.random.default_rng(cfg.seed)
    if cfg.dist == "lognormal":
        mu = np.log(cfg.mean_len) - cfg.sigma**2 / 2
        out = rng.lognormal(mu, cfg.sigma, cfg.n_requests)
    elif cfg.dist == "uniform":
        out = rng.uniform(1, 2 * cfg.mean_len, cfg.n_requests)
    else:
        raise ValueError(cfg.dist)
    return np.maximum(out.astype(np.int64), 1)


def fabric_slot_plan(
    p99_cycles, slo_cycles: float, n_slots: int, min_slots: int = 1
) -> np.ndarray:
    """Per-allocation decode slot budget from replayed tail latency.

    First-order admission control: an allocation whose replayed p99 exceeds
    the SLO is oversubscribed, and shrinking its decode batch shrinks its
    offered load proportionally — so grant ``floor(n_slots * slo / p99)``
    slots (clipped to ``[min_slots, n_slots]``); allocations inside the SLO
    keep the full batch.  Configs with no traffic (p99 = 0) keep full slots.
    """
    if not slo_cycles > 0:
        raise ValueError(f"slo_cycles must be positive, got {slo_cycles}")
    if not 1 <= min_slots <= n_slots:
        raise ValueError(
            f"need 1 <= min_slots <= n_slots, got {min_slots}, {n_slots}"
        )
    p99 = np.asarray(p99_cycles, dtype=np.float64)
    frac = np.where(p99 > 0, np.minimum(slo_cycles / np.maximum(p99, 1e-300), 1.0), 1.0)
    return np.clip(np.floor(n_slots * frac), min_slots, n_slots).astype(np.int64)


def brownout_plan(
    offered_rps,
    capacity_rps,
    p99_cycles,
    slo_cycles: float,
    min_admit_frac: float = 0.05,
) -> np.ndarray:
    """Admission fraction under degraded capacity (graceful brownout).

    Two first-order pressure signals, take the tighter:

      * stability — admitting more than ``capacity_rps`` makes queues grow
        without bound, so cap admission at ``capacity / offered``;
      * tail SLO — replayed p99 scales roughly with admitted load near
        saturation, so scale admission by ``slo / p99`` when the measured
        p99 already exceeds the SLO.

    Vectorized over allocations like ``fabric_slot_plan``; no traffic
    (``offered_rps == 0``) or no latency signal (``p99 == 0``) admits 1.0.
    ``min_admit_frac`` keeps a trickle flowing even under extreme loss so
    recovery is observable (and no tenant is fully blacked out).  Returns
    the fraction of offered load to admit, in ``[min_admit_frac, 1]`` —
    shedding loses throughput by construction; it buys bounded queues and a
    defended p99.
    """
    if not slo_cycles > 0:
        raise ValueError(f"slo_cycles must be positive, got {slo_cycles}")
    if not 0.0 < min_admit_frac <= 1.0:
        raise ValueError(
            f"min_admit_frac must be in (0, 1], got {min_admit_frac}"
        )
    offered = np.asarray(offered_rps, dtype=np.float64)
    cap = np.asarray(capacity_rps, dtype=np.float64)
    p99 = np.asarray(p99_cycles, dtype=np.float64)
    if np.any(offered < 0) or np.any(cap < 0):
        raise ValueError("offered_rps and capacity_rps must be nonnegative")
    stab = np.where(offered > 0, cap / np.maximum(offered, 1e-300), np.inf)
    tail = np.where(p99 > 0, slo_cycles / np.maximum(p99, 1e-300), np.inf)
    frac = np.minimum(np.minimum(stab, tail), 1.0)
    return np.clip(frac, min_admit_frac, 1.0)


@dataclass(frozen=True)
class BatchingStats:
    total_steps: int
    slot_steps_used: int
    slot_steps_alloc: int
    mean_latency: float

    @property
    def utilization(self) -> float:
        return self.slot_steps_used / self.slot_steps_alloc

    @property
    def throughput(self) -> float:
        """completed tokens per slot-step."""
        return self.slot_steps_used / self.total_steps


def simulate_static(lengths: np.ndarray, n_slots: int) -> BatchingStats:
    total, used, lat = 0, 0, []
    for i in range(0, lengths.size, n_slots):
        batch = lengths[i : i + n_slots]
        steps = int(batch.max())
        total += steps
        used += int(batch.sum())
        lat.extend((total - steps + batch).tolist())  # finish times
    return BatchingStats(total, used, total * n_slots, float(np.mean(lat)))


def simulate_continuous(lengths: np.ndarray, n_slots: int) -> BatchingStats:
    """Event simulation: each step every busy slot decodes one token;
    empty slots refill from the queue immediately."""
    remaining = list(lengths[::-1])
    slots = np.zeros(n_slots, dtype=np.int64)  # tokens left per slot
    t, used, lat = 0, 0, []
    active = 0
    while remaining or active:
        for s in range(n_slots):
            if slots[s] == 0 and remaining:
                slots[s] = remaining.pop()
                active += 1
        busy = slots > 0
        if not busy.any():
            break
        slots[busy] -= 1
        used += int(busy.sum())
        t += 1
        done = busy & (slots == 0)
        for _ in range(int(done.sum())):
            lat.append(t)
            active -= 1
    return BatchingStats(t, used, t * n_slots, float(np.mean(lat)))
