"""Sharded checkpoint store: flat-key npz payloads + JSON manifest.

Design points that matter at scale (and are tested here at CPU scale):
  * atomic: write to a temp dir, fsync, rename — a crash mid-save never
    corrupts the latest checkpoint,
  * manifest records step, mesh shape and a config fingerprint so restore
    can re-lower for a DIFFERENT mesh (elastic re-mesh) while refusing
    incompatible configs,
  * keep_last garbage collection,
  * pytrees are flattened to path-keyed arrays; restore rebuilds through the
    abstract shape tree so dtype/shape drift fails loudly.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Any

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "list_steps"]

_MANIFEST = "manifest.json"
_PAYLOAD = "arrays.npz"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(
    root: str,
    step: int,
    tree: Any,
    *,
    mesh_shape: tuple | None = None,
    config_fingerprint: str = "",
    keep_last: int = 3,
) -> str:
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=root)
    try:
        arrays = _flatten(tree)
        np.savez(os.path.join(tmp, _PAYLOAD), **arrays)
        manifest = {
            "step": step,
            "time": time.time(),
            "mesh_shape": list(mesh_shape) if mesh_shape else None,
            "config_fingerprint": config_fingerprint,
            "n_arrays": len(arrays),
            "total_bytes": int(sum(a.nbytes for a in arrays.values())),
        }
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(root, keep_last)
    return final


def _gc(root: str, keep_last: int) -> None:
    steps = list_steps(root)
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(root, f"step_{s:08d}"), ignore_errors=True)


def list_steps(root: str) -> list[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for name in os.listdir(root):
        if name.startswith("step_"):
            try:
                out.append(int(name.split("_")[1]))
            except (IndexError, ValueError):
                continue
    return sorted(out)


def latest_step(root: str) -> int | None:
    steps = list_steps(root)
    return steps[-1] if steps else None


def restore_checkpoint(
    root: str,
    like: Any,
    step: int | None = None,
    *,
    config_fingerprint: str = "",
) -> tuple[Any, dict]:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  Mesh shape may differ from save time — resharding
    is the caller's re-jit concern (elastic re-mesh)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    path = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    if config_fingerprint and manifest["config_fingerprint"] and manifest["config_fingerprint"] != config_fingerprint:
        raise ValueError(
            f"checkpoint config fingerprint {manifest['config_fingerprint']!r} "
            f"!= requested {config_fingerprint!r}"
        )
    payload = np.load(os.path.join(path, _PAYLOAD))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        if key not in payload:
            raise KeyError(f"checkpoint missing {key}")
        arr = payload[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != expected {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves
    ), manifest
