"""Version-portable shard_map.

``jax.shard_map`` (axis_names= / check_vma=) landed after 0.4.x; older
releases only have ``jax.experimental.shard_map.shard_map`` with the
``auto=`` / ``check_rep=`` spelling.  Callers here always name the manual
axes explicitly, so the translation is mechanical: auto = mesh axes minus
the manual set.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check_vma=False):
    manual = frozenset(axis_names) if axis_names is not None else frozenset(mesh.axis_names)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            axis_names=manual,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    auto = frozenset(mesh.axis_names) - manual
    kwargs = {"auto": auto} if auto else {}
    return _shard_map(
        f,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=check_vma,
        **kwargs,
    )
