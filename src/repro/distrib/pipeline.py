"""Pipeline parallelism: GPipe-style microbatch executor over a 'pipe' mesh
axis.

The paper's layer-pipelining section maps here directly: stages are the
"array groups", microbatches are the images streaming through, and the
fill/drain bubble (P-1)/(M+P-1) is the pipeline's synchronization cost.
Stage boundaries come from `core/alloc/pipeline_stages.partition_stages`
(the paper's performance-based allocation): stages are balanced by PROFILED
per-layer cost, not layer count.

Mechanics (SPMD, shard_map over 'pipe'):
  * every stage holds its slice of the (cost-balanced) stacked layer params,
  * each tick: stage 0 injects the next microbatch, every stage applies its
    layers, activations `collective-permute` one hop right,
  * the last stage banks its result; outputs return via a masked psum.
  * backward: jax AD differentiates straight through the schedule —
    ppermute transposes to the reverse permute, giving the classic
    fill-drain backward pipeline for free.

`stage_fn` must be shape-preserving ((mb, s, d) -> (mb, s, d)); embedding
and head run outside the pipelined region (replicated over 'pipe').
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..core.alloc.pipeline_stages import partition_stages

__all__ = ["stack_stages", "make_pipeline_fn", "bubble_fraction"]


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """Idle fraction of the GPipe schedule (the pipelining barrier cost)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)


def stack_stages(layer_params, costs: np.ndarray, n_stages: int):
    """Slice a stacked layer tree (L, ...) into (n_stages, L/P, ...).

    Layers are SEQUENTIAL, so stages must be CONTIGUOUS ranges in original
    order; the SPMD executor additionally needs equal layers per stage, so
    the split is the equal contiguous one.  Cost awareness enters through
    `report_stage_plan` (the paper's performance-based partition): when the
    profiled per-layer costs make the equal split imbalanced, the remedy at
    fixed L/P is choosing a different n_stages or moving to a ragged
    (non-SPMD, per-stage-program) schedule — both reported, not silently
    "fixed" by an order-breaking permutation."""
    L = jax.tree.leaves(layer_params)[0].shape[0]
    if L % n_stages != 0:
        raise ValueError(f"L={L} must divide n_stages={n_stages} for SPMD PP")
    per = L // n_stages
    stages = jax.tree.map(
        lambda a: a.reshape((n_stages, per) + a.shape[1:]), layer_params
    )
    loads = np.asarray(costs, dtype=np.float64).reshape(n_stages, per).sum(axis=1)
    return stages, loads


def report_stage_plan(costs: np.ndarray, n_stages: int) -> dict:
    """Compare the SPMD equal split against the optimal contiguous
    (cost-balanced, possibly ragged) partition from the paper's algorithm."""
    costs = np.asarray(costs, dtype=np.float64)
    per = -(-costs.size // n_stages)
    equal = [(i * per, min((i + 1) * per, costs.size)) for i in range(n_stages)]
    ragged = partition_stages(costs, n_stages)

    def bn(st):
        return max(costs[a:b].sum() for a, b in st if b > a)

    return {
        "equal_bottleneck": bn(equal),
        "ragged_bottleneck": bn(ragged),
        "ragged_gain": bn(equal) / bn(ragged),
        "ragged_bounds": ragged,
    }


def make_pipeline_fn(
    stage_fn: Callable,  # (stage_params, x) -> x, shape-preserving
    mesh: Mesh,
    n_micro: int,
):
    """Returns pipelined(stages_params, xs) with xs (n_micro, mb, ...)."""
    n_stages = mesh.shape["pipe"]
    fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

    def local(stage_params, xs):
        # stage_params: (1, per, ...) local slice; xs: (n_micro, mb, ...)
        stage_params = jax.tree.map(lambda a: a[0], stage_params)
        stage = jax.lax.axis_index("pipe")
        n_t = n_micro + n_stages - 1
        pad = jnp.zeros_like(xs[:1])
        xs_padded = jnp.concatenate([xs, jnp.repeat(pad, n_stages - 1, 0)], 0)
        out0 = jnp.zeros_like(xs)

        def tick(carry, x_t):
            received, out_buf, t = carry
            x_in = jnp.where(stage == 0, x_t, received)
            y = stage_fn(stage_params, x_in)
            mb_idx = t - stage  # microbatch this stage works on
            active = (mb_idx >= 0) & (mb_idx < n_micro)
            y = jnp.where(active, y, 0.0)
            nxt = (
                jax.lax.ppermute(y, "pipe", fwd_perm)
                if n_stages > 1
                else jnp.zeros_like(y)
            )
            slot = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            bank = active & (stage == n_stages - 1)
            out_buf = jax.lax.dynamic_update_slice_in_dim(
                out_buf,
                jnp.where(bank, y, jax.lax.dynamic_slice_in_dim(out_buf, slot, 1, 0)[0])[None],
                slot,
                axis=0,
            )
            return (nxt, out_buf, t + 1), None

        (_, out_buf, _), _ = jax.lax.scan(
            tick, (jnp.zeros_like(xs[0]), out0, jnp.int32(0)), xs_padded
        )
        # only the last stage holds real outputs; spread via masked psum
        mine = jnp.where(stage == n_stages - 1, out_buf, 0.0)
        return jax.lax.psum(mine, "pipe")

    from .compat import shard_map

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P("pipe"), P()),
        out_specs=P(),
        axis_names=frozenset({"pipe"}),
        check_vma=False,
    )
