"""Sharding rules: parameter / optimizer / activation / cache PartitionSpecs.

Mesh axes: ``("data", "model")`` single-pod or ``("pod", "data", "model")``
multi-pod.  Batch shards over ``("pod", "data")`` (DP), weights over
``"model"`` (TP / EP).  Rules are path-based over the param pytree so that
every model family resolves through one table:

  * vocab dims        -> 'model'        (embed / lm_head)
  * attention q dims  -> 'model'        (head-sharded)
  * attention kv dims -> 'model' only when n_kv_heads divides the TP degree
                          (small GQA kv blocks are replicated instead of
                          padded — see DESIGN.md)
  * MLP ff dims       -> 'model' column-, then row-parallel
  * MoE expert dim    -> 'model' (EP) when n_experts % tp == 0, else the
                          expert FF dim is TP-sharded inside each expert
  * Mamba2 head dims  -> 'model' (per-head SSD recurrence is independent)
  * norms, biases of replicated dims, routers -> replicated
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig

__all__ = [
    "batch_axes",
    "param_specs",
    "cache_specs",
    "data_specs",
    "local_eval_mesh",
    "named",
    "shard_map_batch",
    "tp_size",
]


def local_eval_mesh(axis: str = "batch") -> Mesh:
    """1-D mesh over every local device — the data-parallel axis batched
    evaluation kernels (DSE allocate/simulate, virtual-time fabric) shard
    over.  On a 1-device host this is a degenerate mesh and sharded
    evaluation reduces to the plain path."""
    return Mesh(np.array(jax.devices()), (axis,))


def shard_map_batch(fn, *, mesh: Mesh | None = None, axis: str = "batch"):
    """Shard a batched-leading-axis kernel over the host's local devices.

    ``fn`` maps arrays with a shared leading batch dimension C to arrays
    (or a pytree of arrays) with the same leading dimension — exactly the
    shape of the vmapped DSE evaluators (``BatchSimulator``'s kernel).  The
    wrapper pads C up to a device multiple (repeating row 0 — evaluation is
    per-row independent, so padding rows are wasted work, never wrong
    answers), jits the shard_mapped ``fn`` so each device evaluates its C/D
    slice, and strips the padding from every output leaf.  Sweep throughput
    then scales with the host's accelerators instead of saturating one.

    Pass ``fn`` un-jitted (e.g. the bare ``vmap``ed kernel): the jit happens
    here, outside the pad/unpad (which stays in plain numpy so compilation
    caches key on the padded shape only).
    """
    from .compat import shard_map

    m = mesh if mesh is not None else local_eval_mesh(axis)
    n_dev = int(np.prod([m.shape[a] for a in m.axis_names]))
    from jax.sharding import PartitionSpec as _P

    spec = _P(axis)
    inner = jax.jit(shard_map(fn, mesh=m, in_specs=spec, out_specs=spec))

    def wrapped(*args):
        C = args[0].shape[0]
        pad = (-C) % n_dev
        if pad:
            args = tuple(
                np.concatenate([a, np.repeat(a[:1], pad, axis=0)], axis=0)
                for a in args
            )
        out = inner(*args)
        if pad:
            out = jax.tree.map(lambda o: o[:C], out)
        return out

    return wrapped


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def moe_ep_axes(cfg: ModelConfig, mesh: Mesh, seq_len: int = 0) -> tuple[str, ...]:
    """Mesh axes the physical expert slots shard over.

    Prefers the widest expert-parallel group the physical slot count
    divides: ('data', 'model') 2D EP, then 'model', then 'data'.  Expert
    REPLICATION (cfg.moe.replication — the paper's block-wise duplication)
    pads the slot count, so a 160-expert model replicated to 256 slots
    reaches full 2D EP.  Empty tuple -> fall back to TP-inside-expert.
    """
    m = cfg.moe
    if not m.n_experts:
        return ()
    repl = m.replication or tuple([1] * m.n_experts)
    n_phys = int(sum(repl))
    tp = mesh.shape["model"]
    dn = mesh.shape.get("data", 1)
    if n_phys % (dn * tp) == 0:
        return ("data", "model")
    if n_phys % tp == 0:
        return ("model",)
    if n_phys % dn == 0:
        return ("data",)
    return ()


def _stack_depth(path: tuple) -> int:
    """Leading stacked axes: 1 for scanned layer stacks ('layers', ...)."""
    head = str(_key(path[0])) if path else ""
    return 1 if head in ("layers", "enc_layers", "dec_layers", "shared_sites") else 0


def _key(entry) -> str:
    return getattr(entry, "key", getattr(entry, "name", str(entry)))


def _leaf_rule(parts: list[str], ndim: int, cfg: ModelConfig, mesh: Mesh) -> tuple:
    """PartitionSpec entries for the UNSTACKED trailing dims of a leaf."""
    tp = tp_size(mesh)
    name = parts[-1]
    parent = parts[-2] if len(parts) > 1 else ""
    nh, nkv, hd = cfg.attn_dims()
    kv_shardable = nkv and (nkv * hd) % tp == 0 and nkv % tp == 0
    ssm_heads = cfg.ssm.n_heads(cfg.d_model) if cfg.family in ("ssm", "hybrid") else 0
    ssm_shardable = ssm_heads and ssm_heads % tp == 0

    # ---- embeddings / head
    if name == "embed":
        return ("model", None)
    if name == "lm_head":
        return (None, "model")
    # ---- norms and 1-d leftovers
    if name == "scale" or ndim == 1 and name in ("conv_x_b", "gate_norm"):
        if name == "scale" and parent == "gate_norm" and ssm_shardable:
            return ("model",)
        return (None,)
    # ---- attention
    if parent in ("attn", "cross"):
        if name == "wq":
            return (None, "model")
        if name in ("wk", "wv"):
            return (None, "model") if kv_shardable else (None, None)
        if name == "wo":
            return ("model", None)
        if name == "bq":
            return ("model",)
        if name in ("bk", "bv"):
            return ("model",) if kv_shardable else (None,)
        # MLA
        if name in ("wuq", "wuk", "wuv"):
            return (None, "model")  # head-sharded up-projections
        if name in ("wdq", "wdkv", "wkr"):
            return (None, None)  # small compressed projections: replicate
    # ---- MoE
    if parent == "experts":
        ep = moe_ep_axes(cfg, mesh)
        if ep:
            return (ep if len(ep) > 1 else ep[0],) + (None,) * (ndim - 1)
        # TP inside each expert: shard the ff dim (2D for serve_ff_2d)
        ff = ("data", "model") if cfg.moe.serve_ff_2d and "data" in mesh.axis_names else "model"
        if name in ("w_up", "w_gate"):
            return (None, None, ff)
        return (None, ff, None)  # w_down
    if name == "router":
        return (None, None)
    # MoE shared expert: replicated — the EP dispatch path splits tokens over
    # 'model', so the shared expert must see full weights per shard.
    if "shared" in parts:
        return (None,) * ndim
    # ---- dense MLP
    if name in ("w_up", "w_gate"):
        return (None, "model")
    if name == "w_down":
        return ("model", None)
    # ---- Mamba2
    if name in ("wz", "wx"):
        return (None, "model") if ssm_shardable else (None, None)
    if name in ("wB", "wC", "wdt"):
        if name == "wdt" and ssm_shardable:
            return (None, "model")
        return (None, None)
    if name == "conv_x_w":
        return (None, "model") if ssm_shardable else (None, None)
    if name in ("conv_B_w", "conv_C_w"):
        return (None, None)
    if name in ("conv_x_b",):
        return ("model",) if ssm_shardable else (None,)
    if name in ("conv_B_b", "conv_C_b"):
        return (None,)
    if name in ("A_log", "D", "dt_bias"):
        return ("model",) if ssm_shardable else (None,)
    if name == "out_proj":
        return ("model", None) if ssm_shardable else (None, None)
    # ---- fallback: replicate
    return (None,) * ndim


def param_specs(cfg: ModelConfig, params_shape: Any, mesh: Mesh) -> Any:
    """Mirror a param (or optimizer-moment) pytree with PartitionSpecs."""
    tp = tp_size(mesh)

    def rule(path, leaf):
        parts = [_key(p) for p in path if not isinstance(p, jax.tree_util.SequenceKey)]
        depth = _stack_depth(path)
        ndim = len(leaf.shape) - depth
        if ndim < 0:
            return P()
        entries = _leaf_rule(parts, ndim, cfg, mesh)
        entries = tuple(entries)[:ndim]
        entries = entries + (None,) * (ndim - len(entries))
        full = (None,) * depth + entries
        # never shard a dim the size doesn't divide
        checked = tuple(
            a
            if (
                a is None
                or leaf.shape[i]
                % int(np.prod([mesh.shape[x] for x in ((a,) if isinstance(a, str) else a)]))
                == 0
            )
            else None
            for i, a in enumerate(full)
        )
        return P(*checked)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def opt_specs(cfg: ModelConfig, opt_shape: Any, mesh: Mesh) -> Any:
    """Optimizer state: m/v shard like params PLUS ZeRO-1 sharding of the
    first shardable dim over the 'data' axis (stacked layer stacks shard the
    layer axis).  The step counter is replicated.

    ZeRO-1 semantics: moments live fully sharded; the update computes new
    params on shards and GSPMD inserts the param all-gather — trading one
    param-sized all-gather per step for (2x params / dp) resident bytes."""

    def rule(path, leaf):
        parts = [_key(p) for p in path]
        if parts and parts[0] == "step":
            return P()
        sub_path = path[1:]  # drop 'm'/'v'
        depth = _stack_depth(sub_path)
        ndim = len(leaf.shape) - depth
        names = [_key(p) for p in sub_path if not isinstance(p, jax.tree_util.SequenceKey)]
        entries = tuple(_leaf_rule(names, ndim, cfg, mesh))[:ndim]
        entries = entries + (None,) * (ndim - len(entries))
        full = list((None,) * depth + entries)
        # ZeRO-1: put the DP axes on the first dim they divide and don't
        # already carry a model axis.
        dp = batch_axes(mesh)
        dp_n = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
        used = {
            ax
            for e in full
            if e is not None
            for ax in ((e,) if isinstance(e, str) else e)
        }
        if dp and not used.intersection(dp):
            for i in range(len(full)):
                if full[i] is None and leaf.shape[i] % dp_n == 0 and leaf.shape[i] >= dp_n:
                    full[i] = dp if len(dp) > 1 else dp[0]
                    break
        checked = tuple(
            a
            if (
                a is None
                or leaf.shape[i]
                % int(np.prod([mesh.shape[x] for x in ((a,) if isinstance(a, str) else a)]))
                == 0
            )
            else None
            for i, a in enumerate(full)
        )
        return P(*checked)

    return jax.tree_util.tree_map_with_path(rule, opt_shape)


def cache_specs(cfg: ModelConfig, cache_shape: Any, mesh: Mesh) -> Any:
    """Decode-state sharding: batch over DP axes, heads over model."""
    dp = batch_axes(mesh)
    tp = tp_size(mesh)
    nh, nkv, hd = cfg.attn_dims()
    kv_ok = nkv and nkv % tp == 0
    ssm_heads = cfg.ssm.n_heads(cfg.d_model) if cfg.family in ("ssm", "hybrid") else 0
    ssm_ok = ssm_heads and ssm_heads % tp == 0

    def rule(path, leaf):
        parts = [_key(p) for p in path]
        name = parts[-1]
        depth = _stack_depth(path)
        shape = leaf.shape[depth:]
        if name == "len":
            return P(*((None,) * depth))
        batch = shape[0] if shape else 1
        bspec = dp if (dp and batch % int(np.prod([mesh.shape[a] for a in dp])) == 0) else None
        if isinstance(bspec, tuple) and len(bspec) == 1:
            bspec = bspec[0]  # P('data') == P(('data',)) semantically; older
            # jax PartitionSpec __eq__ compares entries literally
        if name in ("k", "v"):
            # heads when they divide TP; otherwise shard the SEQUENCE dim
            # (sequence-parallel KV — keeps big caches resident)
            if kv_ok:
                full = (None,) * depth + (bspec, None, "model", None)
            else:
                full = (None,) * depth + (bspec, "model", None, None)
        elif name == "ckv":
            # compressed cache is tiny (kv_lora_rank): batch-sharded only
            full = (None,) * depth + (bspec, None, None)
        elif name == "k_rope":
            full = (None,) * depth + (bspec, None, None, None)
        elif name == "ssm":
            full = (None,) * depth + (bspec, "model" if ssm_ok else None, None, None)
        elif name == "conv_x":
            full = (None,) * depth + (bspec, None, "model" if ssm_ok else None)
        elif name in ("conv_B", "conv_C"):
            full = (None,) * depth + (bspec, None, None)
        else:
            full = (None,) * depth + (bspec,) + (None,) * (len(shape) - 1)
        checked = tuple(
            a
            if (
                a is None
                or leaf.shape[i]
                % int(np.prod([mesh.shape[x] for x in ((a,) if isinstance(a, str) else a)]))
                == 0
            )
            else None
            for i, a in enumerate(full)
        )
        return P(*checked)

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def data_specs(mesh: Mesh, batch: int) -> P:
    """Token batch: shard the leading batch dim over all DP axes that divide."""
    dp = batch_axes(mesh)
    if dp and batch % int(np.prod([mesh.shape[a] for a in dp])) == 0:
        return P(dp)
    return P()


def named(mesh: Mesh, tree_of_specs: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_of_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
