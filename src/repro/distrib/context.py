"""Current-mesh context: lets pure model code (moe_fwd) select the
distributed dispatch path without threading the mesh through every call.

``build_cell`` / the launchers set this; CPU smoke tests leave it unset and
get the purely local dispatch path.
"""

from __future__ import annotations

from contextlib import contextmanager

from jax.sharding import Mesh

_CURRENT: list[Mesh | None] = [None]


def set_mesh(mesh: Mesh | None) -> None:
    _CURRENT[0] = mesh


def get_mesh() -> Mesh | None:
    return _CURRENT[0]


@contextmanager
def use_mesh(mesh: Mesh):
    prev = _CURRENT[0]
    _CURRENT[0] = mesh
    try:
        yield mesh
    finally:
        _CURRENT[0] = prev
