from .pipeline import bubble_fraction, make_pipeline_fn, report_stage_plan, stack_stages
from .sharding import (
    batch_axes,
    cache_specs,
    data_specs,
    named,
    opt_specs,
    param_specs,
    tp_size,
)

__all__ = [
    "bubble_fraction",
    "make_pipeline_fn",
    "report_stage_plan",
    "stack_stages",
    "batch_axes",
    "cache_specs",
    "data_specs",
    "named",
    "opt_specs",
    "param_specs",
    "tp_size",
]
