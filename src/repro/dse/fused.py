"""One-jit fused DSE pipeline: profile-derive -> allocate -> evaluate.

The staged sweep (``run_sweep``) dispatches three separately-jitted stages
per (network, array) group — host-side ``derive_profile`` views per ADC
variant, the lock-step batched allocators, and the vmapped throughput
kernel — with host round-trips (and profile-cache traffic) between every
pair.  This module fuses them: ONE traced program per (network,
rows-geometry) group derives the per-ADC bit-plane cycle banks from the
shared ``capture_activations`` capture *inside the graph*
(``kernels.bitplane_profile.bitplane_cycle_bank``: shift-and-mask popcount
+ multi-ADC zero-skip re-costing), runs the traceable batched greedy
(``core.alloc.greedy.greedy_batch_kernel``), and feeds the vmapped
``_eval_kernel`` — so a whole (ADC x policy x PE-budget) config tensor
evaluates with no host round-trips between the stages.  Configs partition
by ALLOCATION FAMILY (proportional / layer-greedy / block-greedy, a static
``kind`` per compiled program) so the serial lock-step greedy only runs
over the configs that need it — the same partitions the staged
``allocate_batch`` forms, but fused end-to-end and spanning every ADC
variant per dispatch instead of one dispatch per (geometry, ADC, family).

Equivalence contract (pinned by tests/test_fused_dse.py): every DISCRETE
column — replica tensors, arrays used/total, chip crossings — is exactly
equal to the staged path, and every float-derived column (total cycles,
throughput, utilization, latency percentiles) agrees to <= 1e-12 relative,
with the observed wobble at the last ULP (~2e-16).  Why not full
bit-identity:

  * cycle samples are integer-valued float64, so any summation order gives
    the exact integer sum (all partials < 2^53), and each per-block mean is
    that exact sum divided once by the patch count — bit-equal to
    ``_pack_profile``'s.  The greedy allocators then run the very same
    kernel body on those bit-equal inputs, which is why the replica
    tensors are EXACTLY equal, not merely close;
  * but the staged and fused evaluators are *different XLA programs*, and
    op-fusion choices between two compilations can shift the last ULP of
    the rounded mean->multiply->divide chains (observed: 1 config in 24 on
    a ResNet18 grid, 1.9e-16 relative in total cycles).  ``busy_sum``
    additionally sums the rounded per-block means in whatever reduction
    order each backend picks.  Float columns are therefore compared at
    rtol 1e-12 — four orders looser than the ULP wobble, tight enough that
    any real formula drift fails;
  * the greedy allocators run the very same kernel body on bit-equal base
    latencies, so replica vectors are exactly equal;
  * the proportional policies read NO profile data (MACs only), so their
    replica vectors are precomputed host-side with the same
    largest-remainder routine the staged path uses (this also sidesteps
    argsort tie-order differences between numpy and XLA) and enter the
    graph as config constants;
  * ``latency_aware`` is load-coupled and scalar by construction — it stays
    on the staged path and is rejected here.

``FusedPipeline.fabric_percentiles`` extends the fusion to the serving
side: the per-ADC cycle banks feed the ``lax.scan`` virtual-time kernel
through per-config (ADC, zskip, dataflow) gathers, so one vmapped fabric
call spans sub-batches that the staged ``VirtualTimeFabric`` would split
per (network, array) group.  ``run_fused_multichip_sweep`` lifts
``run_multichip_sweep``'s per-placement Python loop into a batchable
placement x load axis over the same kernel.

Scale-out: ``shard=True`` routes the fused program through
``distrib.sharding.shard_map_batch`` — the config axis splits across the
host's local devices, results identical to the unsharded path.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from ..core.alloc.greedy import greedy_batch_kernel, proportional_allocate_batch
from ..core.cim.cost import ArrayConfig, DEFAULT_ARRAY, baseline_cycles
from ..core.cim.network import NetworkSpec
from ..core.cim.profile import ActivationCapture
from ..core.cim.simulate import (
    ARRAYS_PER_PE,
    CLOCK_HZ,
    _eval_kernel,
)
from ..core.cim.topology import allocate_placed, stage_transfer_matrix
from .sweep import (
    ChipSweepPoint,
    FabricEval,
    SweepPoint,
    SweepResult,
    _spec_for,
    get_captured,
    get_profiled,
)

__all__ = [
    "FusedPipeline",
    "FusedChipSweepResult",
    "get_fused_pipeline",
    "clear_fused_caches",
    "run_fused_sweep",
    "run_fused_multichip_sweep",
]

_PROPORTIONAL = ("baseline", "weight_based", "weight_blockflow")
_LAYERWISE_FLOW = ("baseline", "weight_based", "perf_layerwise")
_FUSED_POLICIES = _PROPORTIONAL + ("perf_layerwise", "blockwise")
_KIND = {p: 0 for p in _PROPORTIONAL}
_KIND["perf_layerwise"] = 1
_KIND["blockwise"] = 2

_PIPELINE_CACHE: dict[tuple, "FusedPipeline"] = {}


def _canonical(array: ArrayConfig) -> ArrayConfig:
    """The rows-geometry key: ADC precision is a config axis INSIDE a fused
    group (it never changes block shapes), so strip it for grouping."""
    return array.variant(adc_bits=DEFAULT_ARRAY.adc_bits)


class FusedPipeline:
    """Fused derive->allocate->eval for one (network, rows-geometry) group.

    ``adc_bits`` is the group's ADC axis: per-config ``a_idx`` selects a
    variant in-graph.  All other ``ArrayConfig`` fields come from
    ``base_array`` and are part of the group identity (they change block
    shapes)."""

    def __init__(
        self,
        network: str,
        base_array: ArrayConfig,
        adc_bits: tuple[int, ...],
        *,
        profile_images: int = 1,
        sample_patches: int = 128,
        seed: int = 0,
        arrays_per_pe: int = ARRAYS_PER_PE,
        shard: bool = False,
    ):
        self.network = network
        self.adc_bits = tuple(int(a) for a in adc_bits)
        if len(set(self.adc_bits)) != len(self.adc_bits):
            raise ValueError(f"duplicate adc_bits {adc_bits}")
        self.base_array = _canonical(base_array)
        self.variants = tuple(
            self.base_array.variant(adc_bits=a) for a in self.adc_bits
        )
        self.arrays_per_pe = int(arrays_per_pe)
        self.shard = bool(shard)
        self.spec: NetworkSpec = _spec_for(network, self.base_array)
        self.capture: ActivationCapture = get_captured(
            network,
            profile_images=profile_images,
            sample_patches=sample_patches,
            seed=seed,
        )
        self._prof_kw = dict(
            profile_images=profile_images,
            sample_patches=sample_patches,
            seed=seed,
        )
        self._build_static()
        self._compiled: dict[tuple, object] = {}
        self._fabric_compiled: dict[tuple, object] = {}

    # ------------------------------------------------------------ host prep
    def _build_static(self) -> None:
        spec, cap = self.spec, self.capture
        L = len(spec.layers)
        B = max(l.n_blocks for l in spec.layers)
        R = self.base_array.rows
        self.S_l = [c.sampled_q.shape[0] for c in cap.layers]
        S = max(self.S_l)
        self.L, self.B, self.S = L, B, S
        # zero-padded (L, B, S, R) uint8 block tensor: padded rows/blocks/
        # samples contribute no '1' bits and are masked out after costing
        Q = np.zeros((L, B, S, R), dtype=np.uint8)
        s_mask = np.zeros((L, S), dtype=bool)
        b_mask = np.zeros((L, B), dtype=bool)
        for li, (layer, c) in enumerate(zip(spec.layers, cap.layers)):
            s = c.sampled_q.shape[0]
            s_mask[li, :s] = True
            b_mask[li, : layer.n_blocks] = True
            for bi, sl in enumerate(layer.block_row_slices()):
                Q[li, bi, :s, : sl.stop - sl.start] = c.sampled_q[:, sl]
        self.Q = Q
        self.s_mask = s_mask
        self.b_mask = b_mask
        self.s_count = s_mask.sum(axis=1).astype(np.float64)
        self.ppi = np.array(
            [l.patches_per_image for l in spec.layers], dtype=np.float64
        )
        self.width = np.array(
            [l.arrays_per_block for l in spec.layers], dtype=np.float64
        )
        self.layer_arrays = np.array(
            [l.n_arrays for l in spec.layers], dtype=np.float64
        )
        self.macs = np.array(
            [l.macs_per_image for l in spec.layers], dtype=np.float64
        )
        self.base_arrays = spec.n_arrays
        table = spec.block_table()  # (N, 3): layer, block-in-layer, width
        self.l_idx = table[:, 0].copy()
        self.blk_idx = table[:, 1].copy()
        self.cost_blk = table[:, 2].astype(np.float64)
        self.N = table.shape[0]
        # baseline (zskip OFF) statistics are capture-independent geometry
        # constants; computed with the exact ops _pack_profile applies to
        # its variant-0 slice so they are bit-equal to the staged banks
        A = len(self.variants)
        cyc0 = np.zeros((A, L, S, B))
        self.baseline_lb = np.zeros((A, L, B))
        for ai, v in enumerate(self.variants):
            for li, layer in enumerate(spec.layers):
                sl = layer.block_row_slices()
                base = baseline_cycles(
                    np.asarray([s.stop - s.start for s in sl]), v
                ).astype(np.float64)
                self.baseline_lb[ai, li, : layer.n_blocks] = base
                cyc0[ai, li, : self.S_l[li], : layer.n_blocks] = base
        self.mean0 = cyc0.sum(axis=2) / self.s_count[None, :, None]
        self.max0 = cyc0.max(axis=2)
        pmax0 = np.where(b_mask[None, :, None, :], cyc0, -np.inf).max(axis=3)
        self.pm_mean0 = (
            np.where(s_mask, pmax0, 0.0).sum(axis=2) / self.s_count[None, :]
        )
        self.pm_max0 = np.where(s_mask, pmax0, -np.inf).max(axis=2)
        self.busy0 = np.where(b_mask[None], self.mean0, 0.0).sum(axis=2)

    # --------------------------------------------------------- traced program
    def _fn(self, kind: int, n_images: int, clock_hz: float, return_bank: bool):
        key = (kind, n_images, clock_hz, return_bank)
        if key in self._compiled:
            return self._compiled[key]
        import functools

        import jax
        import jax.numpy as jnp

        from ..kernels.bitplane_profile import bitplane_cycle_bank

        if return_bank and self.shard:
            raise ValueError(
                "return_bank is unavailable on the sharded pipeline (the "
                "bank's leading axis is the ADC variant, not the config "
                "batch) — use bank() or an unsharded pipeline"
            )
        rows_per_read = tuple(v.rows_per_read for v in self.variants)
        cpr = self.base_array.cycles_per_read
        Q, s_mask, b_mask = self.Q, self.s_mask, self.b_mask
        s_count, ppi = self.s_count, self.ppi
        width, layer_arrays = self.width, self.layer_arrays
        l_idx, blk_idx, cost_blk = self.l_idx, self.blk_idx, self.cost_blk
        mean0, max0 = self.mean0, self.max0
        pm_mean0, pm_max0, busy0 = self.pm_mean0, self.pm_max0, self.busy0
        base_arrays, L, B, N = self.base_arrays, self.L, self.B, self.N

        def fused(Q, budgets, a_idx, zskip, layerwise, dups0):
            C = budgets.shape[0]
            # ---- stage 1: in-graph per-ADC profile derivation -----------
            bank = bitplane_cycle_bank(
                jnp.asarray(Q), rows_per_read, cycles_per_read=cpr
            )  # (A, L, B, S) int32
            valid = s_mask[None, :, None, :] & b_mask[None, :, :, None]
            cyc = jnp.where(valid, bank, 0).astype(jnp.float64)
            cyc = jnp.swapaxes(cyc, 2, 3)  # (A, L, S, B), 0-padded
            mean_b1 = cyc.sum(axis=2) / s_count[None, :, None]  # (A, L, B)
            max_b1 = cyc.max(axis=2)
            pmax1 = jnp.where(b_mask[None, :, None, :], cyc, -jnp.inf).max(axis=3)
            pm_mean1 = (
                jnp.where(s_mask, pmax1, 0.0).sum(axis=2) / s_count[None, :]
            )
            pm_max1 = jnp.where(s_mask, pmax1, -jnp.inf).max(axis=2)
            busy1 = jnp.where(b_mask[None], mean_b1, 0.0).sum(axis=2)

            # ---- stage 2: in-graph allocation ---------------------------
            # `kind` is STATIC: each allocation family gets its own program,
            # so the serial lock-step greedy only ever runs over configs
            # that need it — mirroring the staged per-policy partitions
            # instead of paying every allocator for every config
            if kind == 1:  # perf_layerwise: greedy on expected layer latency
                exp_lat = pm_mean1 * ppi[None, :]  # (A, L)
                r_perf, _ = greedy_batch_kernel(
                    exp_lat[a_idx],
                    jnp.broadcast_to(jnp.asarray(layer_arrays), (C, L)),
                    budgets,
                    jnp.ones((C, L)),
                )
                dups_lb = jnp.broadcast_to(r_perf[:, :, None], (C, L, B))
                used_f = (r_perf - 1.0) @ layer_arrays
            elif kind == 2:  # blockwise: greedy on flat per-block units
                base_blk = (mean_b1 * ppi[None, :, None])[:, l_idx, blk_idx]
                r_blk, _ = greedy_batch_kernel(
                    base_blk[a_idx],  # (C, N)
                    jnp.broadcast_to(jnp.asarray(cost_blk), (C, N)),
                    budgets,
                    jnp.ones((C, N)),
                )
                dups_lb = jnp.ones((C, L, B)).at[:, l_idx, blk_idx].set(r_blk)
                used_f = ((r_blk - 1.0) * cost_blk).sum(axis=1)
            else:  # proportional: replicas are host-precomputed constants
                dups_lb = jnp.broadcast_to(dups0[:, :, None], (C, L, B))
                used_f = (dups0 - 1.0) @ layer_arrays
            used = base_arrays + used_f.astype(jnp.int64)

            # ---- stage 3: vmapped throughput/utilization kernel ---------
            zc = zskip[:, None, None]
            mean_c = jnp.where(zc, mean_b1[a_idx], jnp.asarray(mean0)[a_idx])
            max_c = jnp.where(zc, max_b1[a_idx], jnp.asarray(max0)[a_idx])
            zl = zskip[:, None]
            pmn_c = jnp.where(zl, pm_mean1[a_idx], jnp.asarray(pm_mean0)[a_idx])
            pmx_c = jnp.where(zl, pm_max1[a_idx], jnp.asarray(pm_max0)[a_idx])
            busy_c = jnp.where(zl, busy1[a_idx], jnp.asarray(busy0)[a_idx])

            eval_one = functools.partial(
                _eval_kernel,
                jnp,
                b_mask=jnp.asarray(b_mask),
                ppi=jnp.asarray(ppi),
                width=jnp.asarray(width),
                layer_arrays=jnp.asarray(layer_arrays),
                n_images=n_images,
                clock_hz=clock_hz,
            )
            T, ips, layer_T, util = jax.vmap(
                lambda m, x, pn, px, bs, d, lw: eval_one(
                    m, x, pn, px, bs, dups_lb=d, layerwise=lw
                )
            )(mean_c, max_c, pmn_c, pmx_c, busy_c, dups_lb, layerwise)
            out = (T, ips, layer_T, util, dups_lb, used)
            if return_bank:
                out = out + (cyc,)
            return out

        if self.shard:
            # shard_map_batch splits every positional arg along the config
            # axis, so Q rides along as a closed-over replicated constant
            # (XLA folds the popcount once per compilation)
            from ..distrib.sharding import shard_map_batch

            self._compiled[key] = shard_map_batch(
                functools.partial(fused, Q)
            )
        else:
            # unsharded: Q enters as a runtime operand — the popcount runs
            # in-graph instead of being constant-folded at compile time
            jitted = jax.jit(fused)
            Qd = jnp.asarray(Q)
            self._compiled[key] = lambda *a, _j=jitted, _q=Qd: _j(_q, *a)
        return self._compiled[key]

    def _validate(self, policies, n_pes):
        policies = np.atleast_1d(np.asarray(policies, dtype=object))
        n_pes = np.atleast_1d(np.asarray(n_pes, dtype=np.int64))
        policies, n_pes = np.broadcast_arrays(policies, n_pes)
        unknown = sorted({p for p in policies if p not in _FUSED_POLICIES})
        if unknown:
            raise ValueError(
                f"unsupported policies {unknown} for the fused pipeline; "
                f"choose from {_FUSED_POLICIES} ('latency_aware' is "
                f"load-coupled — use the staged run_sweep)"
            )
        total = n_pes * self.arrays_per_pe
        if np.any(total < self.base_arrays):
            raise ValueError(
                f"{int(total.min())} arrays < minimum {self.base_arrays} "
                f"for {self.spec.name}"
            )
        return policies, n_pes, total

    def __call__(
        self,
        a_idx,  # (C,) index into self.adc_bits
        policies,  # (C,) policy names
        n_pes,  # (C,) PE budgets
        *,
        n_images: int = 64,
        clock_hz: float = CLOCK_HZ,
        chunk: int = 32768,
        return_bank: bool = False,
    ):
        """Evaluate C packed configs in one fused dispatch per chunk.

        Returns a dict of numpy columns (total_cycles, images_per_sec,
        layer_cycles, layer_utilization, dups_lb, layerwise, zskip,
        arrays_used, arrays_total) plus ``bank`` (A, L, S, B) float64 when
        ``return_bank`` — element-wise identical to the staged
        ``allocate_batch`` + ``BatchSimulator`` outputs.
        """
        from jax.experimental import enable_x64

        policies, n_pes, total = self._validate(policies, n_pes)
        a_idx = np.broadcast_to(
            np.atleast_1d(np.asarray(a_idx, dtype=np.int32)), policies.shape
        ).copy()
        if a_idx.size and (a_idx.min() < 0 or a_idx.max() >= len(self.adc_bits)):
            raise ValueError(
                f"a_idx out of range for {len(self.adc_bits)} ADC variants"
            )
        C = policies.shape[0]
        budgets = (total - self.base_arrays).astype(np.float64)
        kind = np.array([_KIND[p] for p in policies], dtype=np.int32)
        zskip = policies != "baseline"
        layerwise = np.isin(policies, _LAYERWISE_FLOW)
        # proportional replicas are MACs-only config constants: precompute
        # host-side with the staged routine (exact; and numpy argsort
        # tie-order never has to match XLA's inside the graph)
        dups0 = np.ones((C, self.L))
        prop = kind == 0
        if prop.any():
            res = proportional_allocate_batch(
                self.macs, self.layer_arrays, budgets[prop]
            )
            dups0[prop] = res.replicas.astype(np.float64)

        outs = {
            "total_cycles": np.zeros(C),
            "images_per_sec": np.zeros(C),
            "layer_cycles": np.zeros((C, self.L)),
            "layer_utilization": np.zeros((C, self.L)),
            "dups_lb": np.zeros((C, self.L, self.B)),
            "arrays_used": np.zeros(C, dtype=np.int64),
        }
        bank = None
        with enable_x64():
            for k in (0, 1, 2):
                rows = np.nonzero(kind == k)[0]
                if rows.size == 0:
                    continue
                fn = self._fn(k, int(n_images), float(clock_hz), bool(return_bank))
                csize = min(int(chunk), rows.size)
                for j0 in range(0, rows.size, csize):
                    part = rows[j0 : j0 + csize]
                    pad = csize - part.size
                    take = (
                        part
                        if pad == 0
                        else np.concatenate([part, np.repeat(part[:1], pad)])
                    )  # pad repeating row 0: one compilation per partition
                    out = fn(
                        budgets[take],
                        a_idx[take],
                        zskip[take],
                        layerwise[take],
                        dups0[take],
                    )
                    T, ips, layer_T, util, dups, used = out[:6]
                    outs["total_cycles"][part] = np.asarray(T)[: part.size]
                    outs["images_per_sec"][part] = np.asarray(ips)[: part.size]
                    outs["layer_cycles"][part] = np.asarray(layer_T)[: part.size]
                    outs["layer_utilization"][part] = np.asarray(util)[: part.size]
                    outs["dups_lb"][part] = np.asarray(dups)[: part.size]
                    outs["arrays_used"][part] = np.asarray(used)[: part.size]
                    if return_bank and bank is None:
                        bank = np.asarray(out[6])
        outs["arrays_total"] = total
        outs["layerwise"] = layerwise
        outs["zskip"] = zskip
        if return_bank:
            outs["bank"] = bank
        return outs

    # ----------------------------------------------------- fused fabric stage
    def _fabric_fn(self, n, D_by_layer, percentiles, has_xfer):
        key = (n, tuple(D_by_layer), tuple(percentiles), has_xfer)
        if key in self._fabric_compiled:
            return self._fabric_compiled[key]
        import functools

        import jax
        import jax.numpy as jnp

        from ..fabric.vtime import run_fabric_kernel

        cyc_banks = self._cyc_banks  # per layer (A, S_l, B_l) float64
        base_banks = [
            self.baseline_lb[:, li, : layer.n_blocks]
            for li, layer in enumerate(self.spec.layers)
        ]  # per layer (A, B_l)
        job_scan = functools.partial(jax.lax.scan, unroll=1)

        def one(frees, xfer, arrivals, a, z, lw, idx):
            stages = []
            for li in range(self.L):
                c1 = jnp.asarray(cyc_banks[li])[a]  # (S_l, B_l)
                c0 = jnp.broadcast_to(
                    jnp.asarray(base_banks[li])[a][None, :], c1.shape
                )
                c = jnp.where(z, c1, c0)
                b = c.shape[1]
                onehot0 = jnp.arange(b) == 0
                # layer-wise dataflow: the barrier collapses each patch to
                # its slowest block, dispatched on pool 0 (identical to the
                # staged per-group (S, 1) packing — max commutes with the
                # service-index gather)
                c_lw = jnp.where(
                    onehot0[None, :], c.max(axis=1, keepdims=True), 0.0
                )
                stages.append(
                    (
                        jnp.where(lw, c_lw, c),
                        jnp.where(lw, onehot0, jnp.ones(b, dtype=bool)),
                    )
                )
            return run_fabric_kernel(
                jnp,
                jax.lax.scan,
                tuple(stages),
                frees,
                arrivals,
                idx,
                None,
                tuple(percentiles),
                job_scan=job_scan,
                xfer=xfer,
            )

        self._fabric_compiled[key] = jax.jit(
            jax.vmap(
                one,
                in_axes=(0, 0 if has_xfer else None, 0, 0, 0, 0, None),
            )
        )
        return self._fabric_compiled[key]

    @property
    def _cyc_banks(self):
        banks = getattr(self, "_cyc_banks_cache", None)
        if banks is None:
            import jax
            import jax.numpy as jnp
            from jax.experimental import enable_x64

            from ..kernels.bitplane_profile import bitplane_cycle_bank

            rows_per_read = tuple(v.rows_per_read for v in self.variants)
            s_mask, b_mask = self.s_mask, self.b_mask

            def derive(Q):
                bank = bitplane_cycle_bank(
                    Q, rows_per_read,
                    cycles_per_read=self.base_array.cycles_per_read,
                )
                valid = s_mask[None, :, None, :] & b_mask[None, :, :, None]
                cyc = jnp.where(valid, bank, 0).astype(jnp.float64)
                return jnp.swapaxes(cyc, 2, 3)  # (A, L, S, B)

            with enable_x64():
                full = np.asarray(jax.jit(derive)(self.Q))
            banks = [
                np.ascontiguousarray(
                    full[:, li, : self.S_l[li], : layer.n_blocks]
                )
                for li, layer in enumerate(self.spec.layers)
            ]
            self._cyc_banks_cache = banks
        return banks

    def fabric_percentiles(
        self,
        a_idx: np.ndarray,  # (C,)
        dups_lb: np.ndarray,  # (C, L, B) from the analytic stage
        layerwise: np.ndarray,  # (C,) bool
        zskip: np.ndarray,  # (C,) bool
        arrival_times: np.ndarray,  # (C, n) cycles
        *,
        seed: int = 0,
        qs: tuple = (50.0, 95.0, 99.0),
        xfer: np.ndarray | None = None,  # (C, L) stage entry transfers
        lane_quantum: int = 1,
    ) -> np.ndarray:
        """(C, len(qs)) latency percentiles through the fused virtual-time
        kernel: per-config (ADC, zskip, dataflow) gathers against the
        in-graph-derived cycle banks, one vmapped ``lax.scan`` call per
        lane-homogeneous sub-batch.  Bit-identical to routing each config
        through the staged ``VirtualTimeFabric``."""
        from jax.experimental import enable_x64

        from ..fabric.vtime import sample_service_indices

        C, n = arrival_times.shape
        a_idx = np.asarray(a_idx, dtype=np.int32)
        lw = np.asarray(layerwise, dtype=bool)
        z = np.asarray(zskip, dtype=bool)
        dims = [(self.S_l[li], l.patches_per_image) for li, l in enumerate(self.spec.layers)]
        idx = sample_service_indices(np.random.default_rng(seed), dims, n)
        # effective lanes per (config, layer, pool): layer-wise configs pool
        # everything on block 0
        d_eff = []
        for li, layer in enumerate(self.spec.layers):
            b = layer.n_blocks
            d = np.asarray(dups_lb[:, li, :b], dtype=np.int64)
            d = np.where(
                lw[:, None],
                np.where(np.arange(b) == 0, dups_lb[:, li, :1].astype(np.int64), 0),
                d,
            )
            d_eff.append(d)  # (C, B_l)
        # bound lane padding: chain configs by their own scan cost, cutting
        # when one exceeds 1.5x its sub-batch's first (the staged policy)
        cost = np.zeros(C)
        for li, layer in enumerate(self.spec.layers):
            cost += layer.patches_per_image * layer.n_blocks * d_eff[li].max(axis=1)
        order = np.argsort(cost, kind="stable")
        subs: list[list[int]] = []
        for j in order:
            if subs and cost[j] <= 1.5 * max(cost[subs[-1][0]], 1.0):
                subs[-1].append(int(j))
            else:
                subs.append([int(j)])
        q = max(1, int(lane_quantum))
        pcts = np.zeros((C, len(qs)))
        with enable_x64():
            for rows in subs:
                r = np.asarray(rows)
                frees = []
                for li in range(self.L):
                    d = d_eff[li][r]
                    D = -(-max(int(d.max()), 1) // q) * q
                    frees.append(
                        np.where(np.arange(D) < d[:, :, None], 0.0, np.inf)
                    )
                fn = self._fabric_fn(
                    n, [f.shape[2] for f in frees], qs, xfer is not None
                )
                out = fn(
                    tuple(frees),
                    None if xfer is None else xfer[r],
                    arrival_times[r],
                    a_idx[r],
                    z[r],
                    lw[r],
                    tuple(idx),
                )
                t_arr, comp = np.asarray(out[0]), np.asarray(out[1])
                # percentiles recomputed host-side from the bit-exact
                # latencies, matching the staged sweep columns exactly
                pcts[r] = np.percentile(comp - t_arr, qs, axis=1).T
        return pcts


def get_fused_pipeline(
    network: str,
    base_array: ArrayConfig,
    adc_bits: tuple[int, ...],
    *,
    profile_images: int = 1,
    sample_patches: int = 128,
    seed: int = 0,
    arrays_per_pe: int = ARRAYS_PER_PE,
    shard: bool = False,
) -> FusedPipeline:
    """Cached ``FusedPipeline`` — compiled programs survive across sweeps."""
    key = (
        network,
        _canonical(base_array),
        tuple(int(a) for a in adc_bits),
        profile_images,
        sample_patches,
        seed,
        arrays_per_pe,
        shard,
    )
    if key not in _PIPELINE_CACHE:
        _PIPELINE_CACHE[key] = FusedPipeline(
            network,
            base_array,
            adc_bits,
            profile_images=profile_images,
            sample_patches=sample_patches,
            seed=seed,
            arrays_per_pe=arrays_per_pe,
            shard=shard,
        )
    return _PIPELINE_CACHE[key]


def clear_fused_caches() -> None:
    _PIPELINE_CACHE.clear()


def run_fused_sweep(
    points: list[SweepPoint],
    *,
    n_images: int = 64,
    profile_images: int = 1,
    sample_patches: int = 128,
    seed: int = 0,
    arrays_per_pe: int = ARRAYS_PER_PE,
    fabric: FabricEval | None = None,
    shard_devices: bool = False,
    chunk: int = 32768,
) -> SweepResult:
    """Drop-in fused counterpart of ``run_sweep(engine="batch")``.

    Groups points by (network, rows-geometry); each group's whole
    (ADC x policy x PE-budget) config tensor runs through ONE fused jit
    dispatch per chunk (derive -> allocate -> eval, no host round-trips),
    optionally followed by the fused virtual-time stage for the latency
    columns.  Results are element-wise identical to the staged path
    (pinned by tests/test_fused_dse.py).  ``latency_aware`` points are
    rejected — that policy is load-coupled and stays staged."""
    C = len(points)
    out = {
        name: np.zeros(C)
        for name in ("total_cycles", "images_per_sec", "mean_utilization")
    }
    used = np.zeros(C, dtype=np.int64)
    total = np.zeros(C, dtype=np.int64)
    pcts = np.full((C, 3), np.nan) if fabric is not None else None

    groups: dict[tuple, list[int]] = {}
    for i, p in enumerate(points):
        groups.setdefault((p.network, _canonical(p.array)), []).append(i)

    elapsed = 0.0
    for (net, arr), rows in groups.items():
        adcs = tuple(sorted({points[i].array.adc_bits for i in rows}))
        pipe = get_fused_pipeline(
            net,
            arr,
            adcs,
            profile_images=profile_images,
            sample_patches=sample_patches,
            seed=seed,
            arrays_per_pe=arrays_per_pe,
            shard=shard_devices,
        )
        idx = np.asarray(rows)
        a_idx = np.array(
            [adcs.index(points[i].array.adc_bits) for i in rows], dtype=np.int32
        )
        pols = np.array([points[i].policy for i in rows], dtype=object)
        pes = np.array([points[i].n_pes for i in rows], dtype=np.int64)
        t0 = time.perf_counter()
        res = pipe(a_idx, pols, pes, n_images=n_images, chunk=chunk)
        out["total_cycles"][idx] = res["total_cycles"]
        out["images_per_sec"][idx] = res["images_per_sec"]
        out["mean_utilization"][idx] = res["layer_utilization"].mean(axis=1)
        used[idx] = res["arrays_used"]
        total[idx] = res["arrays_total"]
        if fabric is not None:
            gaps = np.random.default_rng(fabric.seed).exponential(
                1.0, size=fabric.n_requests
            )
            rates = fabric.load_frac * res["images_per_sec"] / CLOCK_HZ
            times = np.cumsum(gaps)[None, :] / rates[:, None]
            pcts[idx] = pipe.fabric_percentiles(
                a_idx,
                res["dups_lb"],
                res["layerwise"],
                res["zskip"],
                times,
                seed=fabric.seed,
            )
        elapsed += time.perf_counter() - t0

    return SweepResult(
        points=list(points),
        total_cycles=out["total_cycles"],
        images_per_sec=out["images_per_sec"],
        mean_utilization=out["mean_utilization"],
        arrays_used=used,
        arrays_total=total,
        elapsed_s=elapsed,
        engine="fused",
        p50_cycles=pcts[:, 0] if fabric is not None else None,
        p95_cycles=pcts[:, 1] if fabric is not None else None,
        p99_cycles=pcts[:, 2] if fabric is not None else None,
        fabric=fabric,
    )


# --------------------------------------------------- fused multi-chip sweep
@dataclass
class FusedChipSweepResult:
    """Multi-chip outcome with a batched LOAD axis: row i of ``pcts`` holds
    the (len(load_fracs), 3) p50/p95/p99 surface of ``points[i]`` —
    placement x load evaluated in one batched virtual-time call per group."""

    points: list[ChipSweepPoint]
    load_fracs: tuple
    images_per_sec: np.ndarray  # (C,)
    pcts: np.ndarray  # (C, K, 3) latency percentiles, cycles
    max_stage_transfer: np.ndarray
    n_crossings: np.ndarray
    arrays_used: np.ndarray
    arrays_total: np.ndarray
    elapsed_s: float

    def __len__(self) -> int:
        return len(self.points)

    @property
    def n_evaluations(self) -> int:
        return len(self.points) * len(self.load_fracs)

    def rows(self) -> list[dict]:
        out = []
        for i, p in enumerate(self.points):
            for k, lf in enumerate(self.load_fracs):
                out.append(
                    {
                        "network": p.network,
                        "policy": p.policy,
                        "n_chips": p.n_chips,
                        "link_gbps": p.link_gbps,
                        "load_frac": float(lf),
                        "images_per_sec": float(self.images_per_sec[i]),
                        "p50_ms": float(self.pcts[i, k, 0] / CLOCK_HZ * 1e3),
                        "p95_ms": float(self.pcts[i, k, 1] / CLOCK_HZ * 1e3),
                        "p99_ms": float(self.pcts[i, k, 2] / CLOCK_HZ * 1e3),
                        "max_stage_transfer_cycles": float(
                            self.max_stage_transfer[i]
                        ),
                        "n_crossings": int(self.n_crossings[i]),
                        "arrays_used": int(self.arrays_used[i]),
                        "arrays_total": int(self.arrays_total[i]),
                    }
                )
        return out


def run_fused_multichip_sweep(
    points: list[ChipSweepPoint],
    *,
    load_fracs: tuple = (0.7,),
    n_requests: int = 200,
    closed_requests: int = 80,
    concurrency: int = 32,
    seed: int = 0,
    profile_images: int = 1,
    sample_patches: int = 128,
    arrays_per_pe: int = ARRAYS_PER_PE,
    latency_load_frac: float = 0.7,
) -> FusedChipSweepResult:
    """``run_multichip_sweep`` with the placement loop lifted into a
    batchable placement x load axis.

    The staged sweep evaluates one load point per run and walks placements
    in Python; here every group's (unique placement) x (load_frac) cross
    product goes through ONE batched open-loop virtual-time call (the
    placements' per-stage transfer vectors packed by
    ``topology.stage_transfer_matrix``), after one batched closed-loop call
    for throughput.  At ``load_fracs=(0.7,)`` the outcome is element-wise
    identical to ``run_multichip_sweep`` (pinned by the equivalence suite).
    """
    from ..fabric.arrivals import ClosedLoop, TraceReplay
    from ..fabric.vtime import VirtualTimeFabric

    K = len(load_fracs)
    C = len(points)
    ips = np.zeros(C)
    pcts = np.zeros((C, K, 3))
    xfer_max = np.zeros(C)
    crossings = np.zeros(C, dtype=np.int64)
    used = np.zeros(C, dtype=np.int64)
    total = np.zeros(C, dtype=np.int64)

    groups: dict[tuple, list[int]] = {}
    for i, p in enumerate(points):
        groups.setdefault((p.network, p.array), []).append(i)
    prof_kw = dict(
        profile_images=profile_images, sample_patches=sample_patches, seed=seed
    )
    for net, arr in groups:
        get_profiled(net, arr, **prof_kw)

    elapsed = 0.0
    qs = (50.0, 95.0, 99.0)
    for (net, arr), rows in groups.items():
        spec, prof = get_profiled(net, arr, **prof_kw)
        alias: dict[int, int] = {}
        canon: dict[tuple, int] = {}
        uniq: list[int] = []
        for i in rows:
            p = points[i]
            key = (
                p.policy, p.n_pes_total, p.n_chips,
                p.link_gbps if p.n_chips > 1 else None,
            )
            if key not in canon:
                canon[key] = i
                uniq.append(i)
            alias[i] = canon[key]
        placed = []
        for i in uniq:
            p = points[i]
            pa = allocate_placed(
                spec, prof, p.policy, p.topology(arrays_per_pe),
                load_frac=latency_load_frac,
            )
            placed.append(pa)
            xfer_max[i] = pa.placement.max_stage_transfer
            crossings[i] = pa.placement.n_crossings
            used[i] = pa.allocation.arrays_used
            total[i] = pa.allocation.arrays_total
        allocs = [pa.allocation for pa in placed]
        places = [pa.placement for pa in placed]
        stage_transfer_matrix(places)  # validate the packable axis up front
        t0 = time.perf_counter()
        vt = VirtualTimeFabric(spec, prof, lane_quantum=8)
        cl = vt.run_batch(
            allocs, ClosedLoop(closed_requests, concurrency),
            seed=seed, percentiles=qs, placements=places,
        )
        ips[uniq] = cl.images_per_sec
        # the lifted axis: (placement x load) pairs share one normalized
        # gap sequence and evaluate in ONE batched open-loop call
        gaps = np.random.default_rng(seed).exponential(1.0, size=n_requests)
        cum = np.cumsum(gaps)
        U = len(uniq)
        allocs_x = [allocs[u] for u in range(U) for _ in range(K)]
        places_x = [places[u] for u in range(U) for _ in range(K)]
        procs = [
            TraceReplay(cum / (lf * ips[uniq[u]] / CLOCK_HZ))
            for u in range(U)
            for lf in load_fracs
        ]
        op = vt.run_batch(
            allocs_x, procs, seed=seed, percentiles=qs, placements=places_x
        )
        lat = op.latencies.reshape(U, K, -1)
        for k in range(K):
            pcts[np.asarray(uniq), k] = np.percentile(lat[:, k], qs, axis=1).T
        for i in rows:
            j = alias[i]
            if j != i:
                ips[i] = ips[j]
                pcts[i] = pcts[j]
                xfer_max[i] = xfer_max[j]
                crossings[i] = crossings[j]
                used[i] = used[j]
                total[i] = total[j]
        elapsed += time.perf_counter() - t0

    return FusedChipSweepResult(
        points=list(points),
        load_fracs=tuple(load_fracs),
        images_per_sec=ips,
        pcts=pcts,
        max_stage_transfer=xfer_max,
        n_crossings=crossings,
        arrays_used=used,
        arrays_total=total,
        elapsed_s=elapsed,
    )
