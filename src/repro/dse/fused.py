"""One-jit fused DSE pipeline: profile-derive -> allocate -> evaluate.

The staged sweep (``run_sweep``) dispatches three separately-jitted stages
per (network, array) group — host-side ``derive_profile`` views per ADC
variant, the lock-step batched allocators, and the vmapped throughput
kernel — with host round-trips (and profile-cache traffic) between every
pair.  This module collapses them around ONE derive per (network,
rows-geometry) group: the per-ADC bit-plane cycle banks come from the
shared ``capture_activations`` capture *in-graph*
(``kernels.bitplane_profile.bitplane_cycle_bank``: shift-and-mask popcount
+ multi-ADC zero-skip re-costing), stacked once and kept device-resident
across every chunk of every call.  Allocation exploits the same sharing:
each greedy family's base latencies are per-ADC-variant constants, so the
whole lock-step greedy is replayed from ONE sorted grant-event table per
variant (``core.alloc.greedy.greedy_event_schedule`` — exact, heap-order
tie-for-tie) at a ``searchsorted`` per config, instead of a bisection +
residual ``while_loop`` over (C, N) tensors per dispatch.  The per-chunk
traced program is then pure scatter + vmapped ``_eval_kernel``, with each
config gathering its variant's banks by one scalar ``sel`` INSIDE the
kernel — so nothing (C, L, B)-shaped exists besides the replica tensor
and a whole (ADC x policy x PE-budget) config tensor streams through with
no host round-trips between the stages.  Configs partition by replica
FAMILY (per-layer vectors: proportional + perf_layerwise; per-block-unit
vectors: blockwise) — one compiled program per family, spanning every ADC
variant per dispatch instead of one dispatch per (geometry, ADC, family).

Equivalence contract (pinned by tests/test_fused_dse.py): every DISCRETE
column — replica tensors, arrays used/total, chip crossings — is exactly
equal to the staged path, and every float-derived column (total cycles,
throughput, utilization, latency percentiles) agrees to <= 1e-12 relative,
with the observed wobble at the last ULP (~2e-16).  Why not full
bit-identity:

  * cycle samples are integer-valued float64, so any summation order gives
    the exact integer sum (all partials < 2^53), and each per-block mean is
    that exact sum divided once by the patch count — bit-equal to
    ``_pack_profile``'s.  The greedy allocators then run the very same
    kernel body on those bit-equal inputs, which is why the replica
    tensors are EXACTLY equal, not merely close;
  * but the staged and fused evaluators are *different XLA programs*, and
    op-fusion choices between two compilations can shift the last ULP of
    the rounded mean->multiply->divide chains (observed: 1 config in 24 on
    a ResNet18 grid, 1.9e-16 relative in total cycles).  ``busy_sum``
    additionally sums the rounded per-block means in whatever reduction
    order each backend picks.  Float columns are therefore compared at
    rtol 1e-12 — four orders looser than the ULP wobble, tight enough that
    any real formula drift fails;
  * the greedy allocators run the very same kernel body on bit-equal base
    latencies, so replica vectors are exactly equal;
  * the proportional policies read NO profile data (MACs only), so their
    replica vectors are precomputed host-side with the same
    largest-remainder routine the staged path uses (this also sidesteps
    argsort tie-order differences between numpy and XLA) and enter the
    graph as config constants;
  * ``latency_aware`` is load-coupled and scalar by construction — it stays
    on the staged path and is rejected here.

``FusedPipeline.fabric_percentiles`` extends the fusion to the serving
side: the per-ADC cycle banks feed the ``lax.scan`` virtual-time kernel
through per-config (ADC, zskip, dataflow) gathers, so one vmapped fabric
call spans sub-batches that the staged ``VirtualTimeFabric`` would split
per (network, array) group.  ``run_fused_multichip_sweep`` lifts
``run_multichip_sweep``'s per-placement Python loop into a batchable
placement x load axis over the same kernel.

Scale-out: ``shard=True`` routes the fused program through
``distrib.sharding.shard_map_batch`` — the config axis splits across the
host's local devices, results identical to the unsharded path.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from ..core.alloc.greedy import greedy_event_schedule, proportional_allocate_batch
from ..core.cim.cost import ArrayConfig, DEFAULT_ARRAY, baseline_cycles
from ..core.cim.network import NetworkSpec
from ..core.cim.profile import ActivationCapture
from ..core.cim.simulate import (
    ARRAYS_PER_PE,
    CLOCK_HZ,
    _eval_kernel,
)
from ..core.cim.topology import allocate_placed, stage_transfer_matrix
from .sweep import (
    ChipSweepPoint,
    FabricEval,
    SweepPoint,
    SweepResult,
    _spec_for,
    get_captured,
    get_profiled,
)

__all__ = [
    "FusedPipeline",
    "FusedChipSweepResult",
    "get_fused_pipeline",
    "clear_fused_caches",
    "run_fused_sweep",
    "run_fused_multichip_sweep",
]

_PROPORTIONAL = ("baseline", "weight_based", "weight_blockflow")
_LAYERWISE_FLOW = ("baseline", "weight_based", "perf_layerwise")
_FUSED_POLICIES = _PROPORTIONAL + ("perf_layerwise", "blockwise")
_KIND = {p: 0 for p in _PROPORTIONAL}
_KIND["perf_layerwise"] = 1
_KIND["blockwise"] = 2

_PIPELINE_CACHE: dict[tuple, "FusedPipeline"] = {}


def _canonical(array: ArrayConfig) -> ArrayConfig:
    """The rows-geometry key: ADC precision is a config axis INSIDE a fused
    group (it never changes block shapes), so strip it for grouping."""
    return array.variant(adc_bits=DEFAULT_ARRAY.adc_bits)


class FusedPipeline:
    """Fused derive->allocate->eval for one (network, rows-geometry) group.

    ``adc_bits`` is the group's ADC axis: per-config ``a_idx`` selects a
    variant in-graph.  All other ``ArrayConfig`` fields come from
    ``base_array`` and are part of the group identity (they change block
    shapes)."""

    def __init__(
        self,
        network: str,
        base_array: ArrayConfig,
        adc_bits: tuple[int, ...],
        *,
        profile_images: int = 1,
        sample_patches: int = 128,
        seed: int = 0,
        arrays_per_pe: int = ARRAYS_PER_PE,
        shard: bool = False,
    ):
        self.network = network
        self.adc_bits = tuple(int(a) for a in adc_bits)
        if len(set(self.adc_bits)) != len(self.adc_bits):
            raise ValueError(f"duplicate adc_bits {adc_bits}")
        self.base_array = _canonical(base_array)
        self.variants = tuple(
            self.base_array.variant(adc_bits=a) for a in self.adc_bits
        )
        self.arrays_per_pe = int(arrays_per_pe)
        self.shard = bool(shard)
        self.spec: NetworkSpec = _spec_for(network, self.base_array)
        self.capture: ActivationCapture = get_captured(
            network,
            profile_images=profile_images,
            sample_patches=sample_patches,
            seed=seed,
        )
        self._prof_kw = dict(
            profile_images=profile_images,
            sample_patches=sample_patches,
            seed=seed,
        )
        self._build_static()
        self._compiled: dict[tuple, object] = {}
        self._fabric_compiled: dict[tuple, object] = {}

    # ------------------------------------------------------------ host prep
    def _build_static(self) -> None:
        spec, cap = self.spec, self.capture
        L = len(spec.layers)
        B = max(l.n_blocks for l in spec.layers)
        R = self.base_array.rows
        self.S_l = [c.sampled_q.shape[0] for c in cap.layers]
        S = max(self.S_l)
        self.L, self.B, self.S = L, B, S
        # zero-padded (L, B, S, R) uint8 block tensor: padded rows/blocks/
        # samples contribute no '1' bits and are masked out after costing
        Q = np.zeros((L, B, S, R), dtype=np.uint8)
        s_mask = np.zeros((L, S), dtype=bool)
        b_mask = np.zeros((L, B), dtype=bool)
        for li, (layer, c) in enumerate(zip(spec.layers, cap.layers)):
            s = c.sampled_q.shape[0]
            s_mask[li, :s] = True
            b_mask[li, : layer.n_blocks] = True
            for bi, sl in enumerate(layer.block_row_slices()):
                Q[li, bi, :s, : sl.stop - sl.start] = c.sampled_q[:, sl]
        self.Q = Q
        self.s_mask = s_mask
        self.b_mask = b_mask
        self.s_count = s_mask.sum(axis=1).astype(np.float64)
        self.ppi = np.array(
            [l.patches_per_image for l in spec.layers], dtype=np.float64
        )
        self.width = np.array(
            [l.arrays_per_block for l in spec.layers], dtype=np.float64
        )
        self.layer_arrays = np.array(
            [l.n_arrays for l in spec.layers], dtype=np.float64
        )
        self.macs = np.array(
            [l.macs_per_image for l in spec.layers], dtype=np.float64
        )
        self.base_arrays = spec.n_arrays
        table = spec.block_table()  # (N, 3): layer, block-in-layer, width
        self.l_idx = table[:, 0].copy()
        self.blk_idx = table[:, 1].copy()
        self.cost_blk = table[:, 2].astype(np.float64)
        self.N = table.shape[0]
        # baseline (zskip OFF) statistics are capture-independent geometry
        # constants; computed with the exact ops _pack_profile applies to
        # its variant-0 slice so they are bit-equal to the staged banks
        A = len(self.variants)
        cyc0 = np.zeros((A, L, S, B))
        self.baseline_lb = np.zeros((A, L, B))
        for ai, v in enumerate(self.variants):
            for li, layer in enumerate(spec.layers):
                sl = layer.block_row_slices()
                base = baseline_cycles(
                    np.asarray([s.stop - s.start for s in sl]), v
                ).astype(np.float64)
                self.baseline_lb[ai, li, : layer.n_blocks] = base
                cyc0[ai, li, : self.S_l[li], : layer.n_blocks] = base
        self.mean0 = cyc0.sum(axis=2) / self.s_count[None, :, None]
        self.max0 = cyc0.max(axis=2)
        pmax0 = np.where(b_mask[None, :, None, :], cyc0, -np.inf).max(axis=3)
        self.pm_mean0 = (
            np.where(s_mask, pmax0, 0.0).sum(axis=2) / self.s_count[None, :]
        )
        self.pm_max0 = np.where(s_mask, pmax0, -np.inf).max(axis=2)
        self.busy0 = np.where(b_mask[None], self.mean0, 0.0).sum(axis=2)

    # --------------------------------------------- stage 1: shared bank stacks
    def _stats(self, return_bank: bool = False):
        """Per-group SHARED statistic stacks, derived in-graph ONCE and kept
        device-resident across every chunk of every call.

        Returns ``(mean_s, max_s (2A, L, B), pmn_s, pmx_s, busy_s (2A, L),
        exp_lat (A, L), base_blk (A, N))``: the baseline (zskip OFF)
        variants occupy stack slots [0, A) and the zero-skip derivations
        slots [A, 2A), so a per-config scalar ``sel = a_idx + A*zskip``
        picks a variant *inside* ``_eval_kernel`` — no per-config (L, B)
        bank is ever materialized.  Derivation (popcount + multi-ADC
        re-costing + reductions) is bit-equal to the staged
        ``_pack_profile`` statistics: integer-valued sums are exact in any
        order and each division happens once."""
        key = bool(return_bank)
        cached = getattr(self, "_stats_cache", {})
        if key in cached:
            return cached[key]
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        from ..kernels.bitplane_profile import bitplane_cycle_bank

        rows_per_read = tuple(v.rows_per_read for v in self.variants)
        cpr = self.base_array.cycles_per_read
        s_mask, b_mask, s_count, ppi = (
            self.s_mask, self.b_mask, self.s_count, self.ppi,
        )
        l_idx, blk_idx = self.l_idx, self.blk_idx

        def derive(Q):
            bank = bitplane_cycle_bank(
                Q, rows_per_read, cycles_per_read=cpr
            )  # (A, L, B, S) int32
            valid = s_mask[None, :, None, :] & b_mask[None, :, :, None]
            cyc = jnp.where(valid, bank, 0).astype(jnp.float64)
            cyc = jnp.swapaxes(cyc, 2, 3)  # (A, L, S, B), 0-padded
            mean_b1 = cyc.sum(axis=2) / s_count[None, :, None]  # (A, L, B)
            max_b1 = cyc.max(axis=2)
            pmax1 = jnp.where(b_mask[None, :, None, :], cyc, -jnp.inf).max(axis=3)
            pm_mean1 = (
                jnp.where(s_mask, pmax1, 0.0).sum(axis=2) / s_count[None, :]
            )
            pm_max1 = jnp.where(s_mask, pmax1, -jnp.inf).max(axis=2)
            busy1 = jnp.where(b_mask[None], mean_b1, 0.0).sum(axis=2)
            # baseline stacked under zskip: slot v, slot A+v per ADC index v
            stats = (
                jnp.concatenate([jnp.asarray(self.mean0), mean_b1]),
                jnp.concatenate([jnp.asarray(self.max0), max_b1]),
                jnp.concatenate([jnp.asarray(self.pm_mean0), pm_mean1]),
                jnp.concatenate([jnp.asarray(self.pm_max0), pm_max1]),
                jnp.concatenate([jnp.asarray(self.busy0), busy1]),
                pm_mean1 * ppi[None, :],  # per-ADC perf_layerwise bases
                (mean_b1 * ppi[None, :, None])[:, l_idx, blk_idx],  # blockwise
            )
            return stats + (cyc,) if return_bank else stats

        with enable_x64():
            out = jax.jit(derive)(jnp.asarray(self.Q))
        cached[key] = out
        self._stats_cache = cached
        return out

    # ------------------------------------------- stage 2: schedule lookups
    def _schedule(self, kind: int, a: int, max_budget: float):
        """Cached ``GreedyEventSchedule`` for one (family, ADC variant).

        The greedy families' base latencies are per-variant constants
        (derived once by ``_stats``), so the entire lock-step greedy
        collapses into ONE sorted grant-event table per variant that
        answers every PE budget with a ``searchsorted`` — exactly (the
        schedule replays the heap order, tie-for-tie; see
        ``core.alloc.greedy.GreedyEventSchedule``).  Rebuilt only when a
        call's budget range outgrows the cached coverage."""
        cache = getattr(self, "_sched_cache", None)
        if cache is None:
            cache = self._sched_cache = {}
        sched = cache.get((kind, a))
        if sched is not None and sched.max_budget >= max_budget:
            return sched
        stats = self._stats()
        if kind == 1:
            base = np.asarray(stats[5])[a]  # (L,) expected layer latency
            cost = self.layer_arrays
        else:
            base = np.asarray(stats[6])[a]  # (N,) per-block-unit latency
            cost = self.cost_blk
        sched = greedy_event_schedule(base, cost, max_budget)
        cache[(kind, a)] = sched
        return sched

    # --------------------------------------------------------- traced program
    def _fn(self, fam: str, n_images: int, clock_hz: float):
        """Per-chunk program for one replica FAMILY: ``"L"`` (per-layer
        replica vectors — the proportional and perf_layerwise kinds) or
        ``"B"`` (per-block-unit vectors — blockwise).  With allocation
        answered by the shared event schedules, the traced program is pure
        scatter + vmapped eval; the bank stacks ride in as unbatched
        closures and each config gathers its variant by one scalar ``sel``
        inside ``_eval_kernel``."""
        key = (fam, n_images, clock_hz)
        if key in self._compiled:
            return self._compiled[key]
        import functools

        import jax
        import jax.numpy as jnp

        b_mask, ppi = self.b_mask, self.ppi
        width, layer_arrays = self.width, self.layer_arrays
        l_idx, blk_idx = self.l_idx, self.blk_idx
        L, B = self.L, self.B

        def fused(stats, sel, layerwise, r):
            mean_s, max_s, pmn_s, pmx_s, busy_s = stats
            C = sel.shape[0]
            if fam == "B":
                dups_lb = jnp.ones((C, L, B)).at[:, l_idx, blk_idx].set(r)
            else:
                dups_lb = jnp.broadcast_to(r[:, :, None], (C, L, B))
            eval_one = functools.partial(
                _eval_kernel,
                jnp,
                b_mask=jnp.asarray(b_mask),
                ppi=jnp.asarray(ppi),
                width=jnp.asarray(width),
                layer_arrays=jnp.asarray(layer_arrays),
                n_images=n_images,
                clock_hz=clock_hz,
            )
            T, ips, layer_T, util = jax.vmap(
                lambda s, d, lw: eval_one(
                    mean_s, max_s, pmn_s, pmx_s, busy_s,
                    dups_lb=d, layerwise=lw, sel=s,
                )
            )(sel, dups_lb, layerwise)
            return T, ips, layer_T, util, dups_lb

        stats = self._stats()[:5]
        if self.shard:
            # shard_map_batch splits every positional arg along the config
            # axis, so the bank stacks ride along as closed-over replicated
            # constants
            from ..distrib.sharding import shard_map_batch

            self._compiled[key] = shard_map_batch(
                functools.partial(fused, stats)
            )
        else:
            # donate the (C, L) replica operand where an output of the same
            # shape exists (layer_T / util): the chunked driver streams
            # fresh chunks through one program, so XLA reuses the buffer
            # instead of growing the live set per dispatch
            donate = (3,) if fam == "L" else ()
            jitted = jax.jit(fused, donate_argnums=donate)
            self._compiled[key] = lambda *a, _j=jitted, _s=stats: _j(_s, *a)
        return self._compiled[key]

    def _validate(self, policies, n_pes):
        policies = np.atleast_1d(np.asarray(policies, dtype=object))
        n_pes = np.atleast_1d(np.asarray(n_pes, dtype=np.int64))
        policies, n_pes = np.broadcast_arrays(policies, n_pes)
        unknown = sorted({p for p in policies if p not in _FUSED_POLICIES})
        if unknown:
            raise ValueError(
                f"unsupported policies {unknown} for the fused pipeline; "
                f"choose from {_FUSED_POLICIES} ('latency_aware' is "
                f"load-coupled — use the staged run_sweep)"
            )
        total = n_pes * self.arrays_per_pe
        if np.any(total < self.base_arrays):
            raise ValueError(
                f"{int(total.min())} arrays < minimum {self.base_arrays} "
                f"for {self.spec.name}"
            )
        return policies, n_pes, total

    def __call__(
        self,
        a_idx,  # (C,) index into self.adc_bits
        policies,  # (C,) policy names
        n_pes,  # (C,) PE budgets
        *,
        n_images: int = 64,
        clock_hz: float = CLOCK_HZ,
        chunk: int = 32768,
        return_bank: bool = False,
        need_dups: bool = True,
        engine: str = "xla",
    ):
        """Evaluate C packed configs in one fused dispatch per chunk.

        Returns a dict of numpy columns (total_cycles, images_per_sec,
        layer_cycles, layer_utilization, dups_lb, layerwise, zskip,
        arrays_used, arrays_total) plus ``bank`` (A, L, S, B) float64 when
        ``return_bank`` — element-wise identical to the staged
        ``allocate_batch`` + ``BatchSimulator`` outputs.

        ``chunk`` tiles the config axis: each tile is one fused dispatch,
        so peak memory is bounded by the tile, not by C — the knob that
        lets a 10^6-config sweep stream through a fixed device footprint.
        ``need_dups=False`` drops the (C, L, B) replica tensor from the
        host outputs (the analytic columns never read it back): at 10^6
        configs that single column is gigabytes, and skipping its
        device->host fetch is what keeps the host side flat too.

        ``engine="pallas"`` routes every config through the fused
        allocate+eval Pallas kernel (``kernels.fused_alloc_eval``): the
        greedy runs IN-kernel against the per-variant bases (proportional
        configs ride along at budget 0 with their replicas as warm start)
        — the dense-grid TPU regime, interpret-mode fallback off-TPU.
        Results are element-wise identical on the discrete columns and
        within the rtol 1e-12 contract on floats (pinned by
        tests/test_fused_dse.py).
        """
        from jax.experimental import enable_x64

        from ..fabric.telemetry import get_telemetry

        policies, n_pes, total = self._validate(policies, n_pes)
        a_idx = np.broadcast_to(
            np.atleast_1d(np.asarray(a_idx, dtype=np.int32)), policies.shape
        ).copy()
        if a_idx.size and (a_idx.min() < 0 or a_idx.max() >= len(self.adc_bits)):
            raise ValueError(
                f"a_idx out of range for {len(self.adc_bits)} ADC variants"
            )
        C = policies.shape[0]
        budgets = (total - self.base_arrays).astype(np.float64)
        kind = np.array([_KIND[p] for p in policies], dtype=np.int32)
        zskip = policies != "baseline"
        layerwise = np.isin(policies, _LAYERWISE_FLOW)
        A = len(self.variants)
        sel = (a_idx + np.where(zskip, A, 0)).astype(np.int32)

        # ---- stage 2, host side: every replica vector from shared tables.
        # Proportional replicas are MACs-only config constants (the staged
        # largest-remainder routine, exact); the greedy families replay the
        # per-variant event schedules — element-wise identical to the
        # lock-step kernel, at a searchsorted per config instead of a
        # bisection + residual loop over (C, N) tensors per chunk.
        r_layer = np.ones((C, self.L))  # rows of family "L" only
        prop = kind == 0
        if prop.any():
            res = proportional_allocate_batch(
                self.macs, self.layer_arrays, budgets[prop]
            )
            r_layer[prop] = res.replicas.astype(np.float64)
        if engine == "pallas":
            return self._pallas_eval(
                sel, a_idx, kind, budgets, layerwise, zskip, r_layer, total,
                int(n_images), float(clock_hz), int(chunk), need_dups,
                return_bank,
            )
        if engine != "xla":
            raise ValueError(f"unknown engine {engine!r}; use 'xla' or 'pallas'")
        used_f = np.zeros(C)
        rows_B = np.nonzero(kind == 2)[0]
        r_blk = np.ones((rows_B.size, self.N))  # family "B", rows_B order
        for k, rows_k in ((1, np.nonzero(kind == 1)[0]), (2, rows_B)):
            if rows_k.size == 0:
                continue
            bmax = float(budgets[rows_k].max())
            for a in np.unique(a_idx[rows_k]):
                rk = a_idx[rows_k] == a
                got = self._schedule(k, int(a), bmax).replicas_at(
                    budgets[rows_k[rk]]
                )
                if k == 1:
                    r_layer[rows_k[rk]] = got.replicas.astype(np.float64)
                else:
                    r_blk[rk] = got.replicas.astype(np.float64)
        rows_L = np.nonzero(kind != 2)[0]
        used_f[rows_L] = (r_layer[rows_L] - 1.0) @ self.layer_arrays
        used_f[rows_B] = ((r_blk - 1.0) * self.cost_blk).sum(axis=1)

        outs = {
            "total_cycles": np.zeros(C),
            "images_per_sec": np.zeros(C),
            "layer_cycles": np.zeros((C, self.L)),
            "layer_utilization": np.zeros((C, self.L)),
        }
        if need_dups:
            outs["dups_lb"] = np.zeros((C, self.L, self.B))
        tel = get_telemetry()
        csize_max = n_chunks = 0
        with enable_x64():
            for fam, rows, r_fam in (("L", rows_L, r_layer), ("B", rows_B, r_blk)):
                if rows.size == 0:
                    continue
                fn = self._fn(fam, int(n_images), float(clock_hz))
                csize = min(int(chunk), rows.size)
                csize_max = max(csize_max, csize)
                for j0 in range(0, rows.size, csize):
                    part = rows[j0 : j0 + csize]
                    pad = csize - part.size
                    take = (
                        part
                        if pad == 0
                        else np.concatenate([part, np.repeat(part[:1], pad)])
                    )  # pad repeating row 0: one compilation per partition
                    # family "L" replicas index by global row; family "B" by
                    # position (r_blk rows are laid out in rows_B order)
                    if fam == "L":
                        r_take = r_fam[take]
                    else:
                        r_take = r_fam[j0 : j0 + csize]
                        if pad:
                            r_take = np.concatenate(
                                [r_take, np.repeat(r_take[:1], pad, axis=0)]
                            )
                    T, ips, layer_T, util, dups = fn(
                        sel[take], layerwise[take], r_take
                    )[:5]
                    outs["total_cycles"][part] = np.asarray(T)[: part.size]
                    outs["images_per_sec"][part] = np.asarray(ips)[: part.size]
                    outs["layer_cycles"][part] = np.asarray(layer_T)[: part.size]
                    outs["layer_utilization"][part] = np.asarray(util)[: part.size]
                    if need_dups:
                        outs["dups_lb"][part] = np.asarray(dups)[: part.size]
                    n_chunks += 1
        outs["arrays_used"] = self.base_arrays + used_f.astype(np.int64)
        # chunking telemetry: the live device set per dispatch is one tile —
        # the (csize, L, B) replica tensor dominates — never the full C
        # (the peak-memory smoke in tests/test_fused_dse.py reads these)
        tel.gauge("dse.fused.chunk_configs", csize_max)
        tel.gauge(
            "dse.fused.chunk_device_bytes",
            csize_max * (2 * self.L * self.B + self.N + 2 * self.L + 3) * 8,
        )
        tel.gauge(
            "dse.fused.host_out_bytes", sum(a.nbytes for a in outs.values())
        )
        tel.count("dse.fused.chunks", n_chunks)
        outs["arrays_total"] = total
        outs["layerwise"] = layerwise
        outs["zskip"] = zskip
        if return_bank:
            outs["bank"] = np.asarray(self._stats(return_bank=True)[-1])
        return outs

    def _pallas_eval(
        self, sel, a_idx, kind, budgets, layerwise, zskip, dups0, total,
        n_images, clock_hz, chunk, need_dups, return_bank,
    ):
        """``engine="pallas"`` body: both greedy families flattened onto the
        shared unit axis and pushed through ``kernels.fused_alloc_eval`` —
        greedy + scatter + eval in one grid step per config block.
        Proportional configs enter at budget 0 with their host-precomputed
        replicas as the warm start (the greedy is then a no-op), so one
        kernel serves every supported policy."""
        from jax.experimental import enable_x64

        from ..kernels.fused_alloc_eval import fused_alloc_eval
        from .engine import flat_unit_map

        stats = self._stats()
        banks = stats[:5]
        C = budgets.shape[0]
        outs = {
            "total_cycles": np.zeros(C),
            "images_per_sec": np.zeros(C),
            "layer_cycles": np.zeros((C, self.L)),
            "layer_utilization": np.zeros((C, self.L)),
        }
        if need_dups:
            outs["dups_lb"] = np.zeros((C, self.L, self.B))
        used_f = np.zeros(C)
        fams = (
            ("L", np.nonzero(kind != 2)[0], np.asarray(stats[5]),
             self.layer_arrays, flat_unit_map(self.L, self.B)),
            ("B", np.nonzero(kind == 2)[0], np.asarray(stats[6]),
             self.cost_blk, flat_unit_map(self.L, self.B, self.l_idx, self.blk_idx)),
        )
        with enable_x64():
            for fam, rows, base, cost, umap in fams:
                if rows.size == 0:
                    continue
                r0 = np.ones((rows.size, base.shape[1]))
                bud = budgets[rows].copy()
                if fam == "L":
                    isprop = kind[rows] == 0
                    r0[isprop] = dups0[rows[isprop]]
                    bud[isprop] = 0.0
                csize = min(int(chunk), rows.size)
                for j0 in range(0, rows.size, csize):
                    part = rows[j0 : j0 + csize]
                    sl = slice(j0, j0 + part.size)
                    T, ips, layer_T, util, r, _ = fused_alloc_eval(
                        base, cost, umap, banks, self.b_mask, self.ppi,
                        self.width, self.layer_arrays, bud[sl], a_idx[part],
                        sel[part], layerwise[part], r0[sl],
                        n_images=n_images, clock_hz=clock_hz,
                        block_configs=min(csize, 128),
                    )
                    outs["total_cycles"][part] = np.asarray(T)
                    outs["images_per_sec"][part] = np.asarray(ips)
                    outs["layer_cycles"][part] = np.asarray(layer_T)
                    outs["layer_utilization"][part] = np.asarray(util)
                    r = np.asarray(r)
                    if fam == "L":
                        used_f[part] = (r - 1.0) @ self.layer_arrays
                        if need_dups:
                            outs["dups_lb"][part] = np.broadcast_to(
                                r[:, :, None], (part.size, self.L, self.B)
                            )
                    else:
                        used_f[part] = ((r - 1.0) * cost).sum(axis=1)
                        if need_dups:
                            d = np.ones((part.size, self.L, self.B))
                            d[:, self.l_idx, self.blk_idx] = r
                            outs["dups_lb"][part] = d
        outs["arrays_used"] = self.base_arrays + used_f.astype(np.int64)
        outs["arrays_total"] = total
        outs["layerwise"] = layerwise
        outs["zskip"] = zskip
        if return_bank:
            outs["bank"] = np.asarray(self._stats(return_bank=True)[-1])
        return outs

    # ----------------------------------------------------- fused fabric stage
    def _fabric_fn(self, n, D_by_layer, percentiles, has_xfer, window):
        key = (n, tuple(D_by_layer), tuple(percentiles), has_xfer, window)
        if key in self._fabric_compiled:
            return self._fabric_compiled[key]
        import functools

        import jax
        import jax.numpy as jnp

        from ..fabric.vtime import run_fabric_kernel

        cyc_banks = self._cyc_banks  # per layer (A, S_l, B_l) float64
        base_banks = [
            self.baseline_lb[:, li, : layer.n_blocks]
            for li, layer in enumerate(self.spec.layers)
        ]  # per layer (A, B_l)
        job_scan = functools.partial(jax.lax.scan, unroll=1)

        def one(frees, xfer, arrivals, a, z, lw, idx):
            stages = []
            for li in range(self.L):
                c1 = jnp.asarray(cyc_banks[li])[a]  # (S_l, B_l)
                c0 = jnp.broadcast_to(
                    jnp.asarray(base_banks[li])[a][None, :], c1.shape
                )
                c = jnp.where(z, c1, c0)
                b = c.shape[1]
                onehot0 = jnp.arange(b) == 0
                # layer-wise dataflow: the barrier collapses each patch to
                # its slowest block, dispatched on pool 0 (identical to the
                # staged per-group (S, 1) packing — max commutes with the
                # service-index gather)
                c_lw = jnp.where(
                    onehot0[None, :], c.max(axis=1, keepdims=True), 0.0
                )
                stages.append(
                    (
                        jnp.where(lw, c_lw, c),
                        jnp.where(lw, onehot0, jnp.ones(b, dtype=bool)),
                    )
                )
            return run_fabric_kernel(
                jnp,
                jax.lax.scan,
                tuple(stages),
                frees,
                arrivals,
                idx,
                None,
                tuple(percentiles),
                job_scan=job_scan,
                xfer=xfer,
                window=window,
            )

        self._fabric_compiled[key] = jax.jit(
            jax.vmap(
                one,
                in_axes=(0, 0 if has_xfer else None, 0, 0, 0, 0, None),
            )
        )
        return self._fabric_compiled[key]

    @property
    def _cyc_banks(self):
        banks = getattr(self, "_cyc_banks_cache", None)
        if banks is None:
            # the shared derive already produced the full (A, L, S, B) bank
            full = np.asarray(self._stats(return_bank=True)[-1])
            banks = [
                np.ascontiguousarray(
                    full[:, li, : self.S_l[li], : layer.n_blocks]
                )
                for li, layer in enumerate(self.spec.layers)
            ]
            self._cyc_banks_cache = banks
        return banks

    def fabric_percentiles(
        self,
        a_idx: np.ndarray,  # (C,)
        dups_lb: np.ndarray,  # (C, L, B) from the analytic stage
        layerwise: np.ndarray,  # (C,) bool
        zskip: np.ndarray,  # (C,) bool
        arrival_times: np.ndarray,  # (C, n) cycles
        *,
        seed: int = 0,
        qs: tuple = (50.0, 95.0, 99.0),
        xfer: np.ndarray | None = None,  # (C, L) stage entry transfers
        lane_quantum: int = 1,
        window: int = 8,
    ) -> np.ndarray:
        """(C, len(qs)) latency percentiles through the fused virtual-time
        kernel: per-config (ADC, zskip, dataflow) gathers against the
        in-graph-derived cycle banks, one vmapped ``lax.scan`` call per
        lane-homogeneous sub-batch.  Bit-identical to routing each config
        through the staged ``VirtualTimeFabric``.

        ``window`` dispatches that many requests per ``lax.scan`` step (the
        blocked scan; non-overtaking makes any window bit-identical to
        ``window=1``, so this is purely a host-overhead knob)."""
        from jax.experimental import enable_x64

        from ..fabric.vtime import sample_service_indices

        C, n = arrival_times.shape
        a_idx = np.asarray(a_idx, dtype=np.int32)
        lw = np.asarray(layerwise, dtype=bool)
        z = np.asarray(zskip, dtype=bool)
        dims = [(self.S_l[li], l.patches_per_image) for li, l in enumerate(self.spec.layers)]
        idx = sample_service_indices(np.random.default_rng(seed), dims, n)
        # effective lanes per (config, layer, pool): layer-wise configs pool
        # everything on block 0
        d_eff = []
        for li, layer in enumerate(self.spec.layers):
            b = layer.n_blocks
            d = np.asarray(dups_lb[:, li, :b], dtype=np.int64)
            d = np.where(
                lw[:, None],
                np.where(np.arange(b) == 0, dups_lb[:, li, :1].astype(np.int64), 0),
                d,
            )
            d_eff.append(d)  # (C, B_l)
        # bound lane padding: chain configs by their own scan cost, cutting
        # when one exceeds 1.5x its sub-batch's first (the staged policy)
        cost = np.zeros(C)
        for li, layer in enumerate(self.spec.layers):
            cost += layer.patches_per_image * layer.n_blocks * d_eff[li].max(axis=1)
        order = np.argsort(cost, kind="stable")
        subs: list[list[int]] = []
        for j in order:
            if subs and cost[j] <= 1.5 * max(cost[subs[-1][0]], 1.0):
                subs[-1].append(int(j))
            else:
                subs.append([int(j)])
        q = max(1, int(lane_quantum))
        pcts = np.zeros((C, len(qs)))
        with enable_x64():
            for rows in subs:
                r = np.asarray(rows)
                frees = []
                for li in range(self.L):
                    d = d_eff[li][r]
                    D = -(-max(int(d.max()), 1) // q) * q
                    frees.append(
                        np.where(np.arange(D) < d[:, :, None], 0.0, np.inf)
                    )
                fn = self._fabric_fn(
                    n, [f.shape[2] for f in frees], qs, xfer is not None,
                    int(window),
                )
                out = fn(
                    tuple(frees),
                    None if xfer is None else xfer[r],
                    arrival_times[r],
                    a_idx[r],
                    z[r],
                    lw[r],
                    tuple(idx),
                )
                t_arr, comp = np.asarray(out[0]), np.asarray(out[1])
                # percentiles recomputed host-side from the bit-exact
                # latencies, matching the staged sweep columns exactly
                pcts[r] = np.percentile(comp - t_arr, qs, axis=1).T
        return pcts


def get_fused_pipeline(
    network: str,
    base_array: ArrayConfig,
    adc_bits: tuple[int, ...],
    *,
    profile_images: int = 1,
    sample_patches: int = 128,
    seed: int = 0,
    arrays_per_pe: int = ARRAYS_PER_PE,
    shard: bool = False,
) -> FusedPipeline:
    """Cached ``FusedPipeline`` — compiled programs survive across sweeps."""
    key = (
        network,
        _canonical(base_array),
        tuple(int(a) for a in adc_bits),
        profile_images,
        sample_patches,
        seed,
        arrays_per_pe,
        shard,
    )
    if key not in _PIPELINE_CACHE:
        _PIPELINE_CACHE[key] = FusedPipeline(
            network,
            base_array,
            adc_bits,
            profile_images=profile_images,
            sample_patches=sample_patches,
            seed=seed,
            arrays_per_pe=arrays_per_pe,
            shard=shard,
        )
    return _PIPELINE_CACHE[key]


def clear_fused_caches() -> None:
    _PIPELINE_CACHE.clear()


def run_fused_sweep(
    points: list[SweepPoint],
    *,
    n_images: int = 64,
    profile_images: int = 1,
    sample_patches: int = 128,
    seed: int = 0,
    arrays_per_pe: int = ARRAYS_PER_PE,
    fabric: FabricEval | None = None,
    shard_devices: bool = False,
    chunk: int = 32768,
    chunk_size: int | None = None,
    engine: str = "xla",
) -> SweepResult:
    """Drop-in fused counterpart of ``run_sweep(engine="batch")``.

    Groups points by (network, rows-geometry); each group derives its
    shared per-ADC bank stacks once, then streams the whole (ADC x policy
    x PE-budget) config tensor through ONE fused allocate+eval dispatch
    per chunk — no host round-trips, peak memory bounded by the chunk
    (``chunk_size``, alias of ``chunk``; tilings are element-wise
    identical, pinned by tests/test_fused_dse.py) — optionally followed
    by the fused virtual-time stage for the latency columns.  Without a
    fabric stage the per-config replica tensors are never fetched to the
    host (``need_dups=False`` inside), so a 10^6-config analytic sweep
    holds only (C,)/(C, L) columns.  Results are element-wise identical
    to the staged path.  ``latency_aware`` points are rejected — that
    policy is load-coupled and stays staged.  ``engine="pallas"`` routes
    the analytic stage through the fused allocate+eval Pallas kernel (see
    ``FusedPipeline.__call__``)."""
    if chunk_size is not None:
        chunk = int(chunk_size)
    C = len(points)
    out = {
        name: np.zeros(C)
        for name in ("total_cycles", "images_per_sec", "mean_utilization")
    }
    used = np.zeros(C, dtype=np.int64)
    total = np.zeros(C, dtype=np.int64)
    pcts = np.full((C, 3), np.nan) if fabric is not None else None

    groups: dict[tuple, list[int]] = {}
    for i, p in enumerate(points):
        groups.setdefault((p.network, _canonical(p.array)), []).append(i)

    elapsed = 0.0
    for (net, arr), rows in groups.items():
        adcs = tuple(sorted({points[i].array.adc_bits for i in rows}))
        pipe = get_fused_pipeline(
            net,
            arr,
            adcs,
            profile_images=profile_images,
            sample_patches=sample_patches,
            seed=seed,
            arrays_per_pe=arrays_per_pe,
            shard=shard_devices,
        )
        idx = np.asarray(rows)
        a_idx = np.array(
            [adcs.index(points[i].array.adc_bits) for i in rows], dtype=np.int32
        )
        pols = np.array([points[i].policy for i in rows], dtype=object)
        pes = np.array([points[i].n_pes for i in rows], dtype=np.int64)
        t0 = time.perf_counter()
        res = pipe(
            a_idx, pols, pes, n_images=n_images, chunk=chunk,
            need_dups=fabric is not None, engine=engine,
        )
        out["total_cycles"][idx] = res["total_cycles"]
        out["images_per_sec"][idx] = res["images_per_sec"]
        out["mean_utilization"][idx] = res["layer_utilization"].mean(axis=1)
        used[idx] = res["arrays_used"]
        total[idx] = res["arrays_total"]
        if fabric is not None:
            gaps = np.random.default_rng(fabric.seed).exponential(
                1.0, size=fabric.n_requests
            )
            rates = fabric.load_frac * res["images_per_sec"] / CLOCK_HZ
            times = np.cumsum(gaps)[None, :] / rates[:, None]
            pcts[idx] = pipe.fabric_percentiles(
                a_idx,
                res["dups_lb"],
                res["layerwise"],
                res["zskip"],
                times,
                seed=fabric.seed,
            )
        elapsed += time.perf_counter() - t0

    return SweepResult(
        points=list(points),
        total_cycles=out["total_cycles"],
        images_per_sec=out["images_per_sec"],
        mean_utilization=out["mean_utilization"],
        arrays_used=used,
        arrays_total=total,
        elapsed_s=elapsed,
        engine="fused",
        p50_cycles=pcts[:, 0] if fabric is not None else None,
        p95_cycles=pcts[:, 1] if fabric is not None else None,
        p99_cycles=pcts[:, 2] if fabric is not None else None,
        fabric=fabric,
    )


# --------------------------------------------------- fused multi-chip sweep
@dataclass
class FusedChipSweepResult:
    """Multi-chip outcome with a batched LOAD axis: row i of ``pcts`` holds
    the (len(load_fracs), 3) p50/p95/p99 surface of ``points[i]`` —
    placement x load evaluated in one batched virtual-time call per group."""

    points: list[ChipSweepPoint]
    load_fracs: tuple
    images_per_sec: np.ndarray  # (C,)
    pcts: np.ndarray  # (C, K, 3) latency percentiles, cycles
    max_stage_transfer: np.ndarray
    n_crossings: np.ndarray
    arrays_used: np.ndarray
    arrays_total: np.ndarray
    elapsed_s: float

    def __len__(self) -> int:
        return len(self.points)

    @property
    def n_evaluations(self) -> int:
        return len(self.points) * len(self.load_fracs)

    def rows(self) -> list[dict]:
        out = []
        for i, p in enumerate(self.points):
            for k, lf in enumerate(self.load_fracs):
                out.append(
                    {
                        "network": p.network,
                        "policy": p.policy,
                        "n_chips": p.n_chips,
                        "link_gbps": p.link_gbps,
                        "load_frac": float(lf),
                        "images_per_sec": float(self.images_per_sec[i]),
                        "p50_ms": float(self.pcts[i, k, 0] / CLOCK_HZ * 1e3),
                        "p95_ms": float(self.pcts[i, k, 1] / CLOCK_HZ * 1e3),
                        "p99_ms": float(self.pcts[i, k, 2] / CLOCK_HZ * 1e3),
                        "max_stage_transfer_cycles": float(
                            self.max_stage_transfer[i]
                        ),
                        "n_crossings": int(self.n_crossings[i]),
                        "arrays_used": int(self.arrays_used[i]),
                        "arrays_total": int(self.arrays_total[i]),
                    }
                )
        return out


def run_fused_multichip_sweep(
    points: list[ChipSweepPoint],
    *,
    load_fracs: tuple = (0.7,),
    n_requests: int = 200,
    closed_requests: int = 80,
    concurrency: int = 32,
    seed: int = 0,
    profile_images: int = 1,
    sample_patches: int = 128,
    arrays_per_pe: int = ARRAYS_PER_PE,
    latency_load_frac: float = 0.7,
) -> FusedChipSweepResult:
    """``run_multichip_sweep`` with the placement loop lifted into a
    batchable placement x load axis.

    The staged sweep evaluates one load point per run and walks placements
    in Python; here every group's (unique placement) x (load_frac) cross
    product goes through ONE batched open-loop virtual-time call (the
    placements' per-stage transfer vectors packed by
    ``topology.stage_transfer_matrix``), after one batched closed-loop call
    for throughput.  At ``load_fracs=(0.7,)`` the outcome is element-wise
    identical to ``run_multichip_sweep`` (pinned by the equivalence suite).
    """
    from ..fabric.arrivals import ClosedLoop, TraceReplay
    from ..fabric.vtime import VirtualTimeFabric

    K = len(load_fracs)
    C = len(points)
    ips = np.zeros(C)
    pcts = np.zeros((C, K, 3))
    xfer_max = np.zeros(C)
    crossings = np.zeros(C, dtype=np.int64)
    used = np.zeros(C, dtype=np.int64)
    total = np.zeros(C, dtype=np.int64)

    groups: dict[tuple, list[int]] = {}
    for i, p in enumerate(points):
        groups.setdefault((p.network, p.array), []).append(i)
    prof_kw = dict(
        profile_images=profile_images, sample_patches=sample_patches, seed=seed
    )
    for net, arr in groups:
        get_profiled(net, arr, **prof_kw)

    elapsed = 0.0
    qs = (50.0, 95.0, 99.0)
    for (net, arr), rows in groups.items():
        spec, prof = get_profiled(net, arr, **prof_kw)
        alias: dict[int, int] = {}
        canon: dict[tuple, int] = {}
        uniq: list[int] = []
        for i in rows:
            p = points[i]
            key = (
                p.policy, p.n_pes_total, p.n_chips,
                p.link_gbps if p.n_chips > 1 else None,
            )
            if key not in canon:
                canon[key] = i
                uniq.append(i)
            alias[i] = canon[key]
        placed = []
        for i in uniq:
            p = points[i]
            pa = allocate_placed(
                spec, prof, p.policy, p.topology(arrays_per_pe),
                load_frac=latency_load_frac,
            )
            placed.append(pa)
            xfer_max[i] = pa.placement.max_stage_transfer
            crossings[i] = pa.placement.n_crossings
            used[i] = pa.allocation.arrays_used
            total[i] = pa.allocation.arrays_total
        allocs = [pa.allocation for pa in placed]
        places = [pa.placement for pa in placed]
        stage_transfer_matrix(places)  # validate the packable axis up front
        t0 = time.perf_counter()
        vt = VirtualTimeFabric(spec, prof, lane_quantum=8)
        cl = vt.run_batch(
            allocs, ClosedLoop(closed_requests, concurrency),
            seed=seed, percentiles=qs, placements=places,
        )
        ips[uniq] = cl.images_per_sec
        # the lifted axis: (placement x load) pairs share one normalized
        # gap sequence and evaluate in ONE batched open-loop call
        gaps = np.random.default_rng(seed).exponential(1.0, size=n_requests)
        cum = np.cumsum(gaps)
        U = len(uniq)
        allocs_x = [allocs[u] for u in range(U) for _ in range(K)]
        places_x = [places[u] for u in range(U) for _ in range(K)]
        procs = [
            TraceReplay(cum / (lf * ips[uniq[u]] / CLOCK_HZ))
            for u in range(U)
            for lf in load_fracs
        ]
        op = vt.run_batch(
            allocs_x, procs, seed=seed, percentiles=qs, placements=places_x
        )
        lat = op.latencies.reshape(U, K, -1)
        for k in range(K):
            pcts[np.asarray(uniq), k] = np.percentile(lat[:, k], qs, axis=1).T
        for i in rows:
            j = alias[i]
            if j != i:
                ips[i] = ips[j]
                pcts[i] = pcts[j]
                xfer_max[i] = xfer_max[j]
                crossings[i] = crossings[j]
                used[i] = used[j]
                total[i] = total[j]
        elapsed += time.perf_counter() - t0

    return FusedChipSweepResult(
        points=list(points),
        load_fracs=tuple(load_fracs),
        images_per_sec=ips,
        pcts=pcts,
        max_stage_transfer=xfer_max,
        n_crossings=crossings,
        arrays_used=used,
        arrays_total=total,
        elapsed_s=elapsed,
    )
