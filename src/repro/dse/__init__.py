"""Vectorized design-space exploration over the analytic CIM simulator.

The paper reports one design point; this package sweeps thousands —
(array geometry, ADC precision, PE budget, allocation policy, network) —
through the batched float64 allocate/simulate kernels and extracts the
arrays-vs-throughput-vs-utilization Pareto frontier.  With a ``FabricEval``
attached, every swept design additionally runs the batched virtual-time
fabric at its own operating load, so frontiers can rank on
(throughput, p99 tail latency, utilization) instead of throughput alone
(``LATENCY_OBJECTIVES``).  ``run_fault_sweep`` adds the robustness axis:
spare fraction x failure rate replayed under seeded failure traces into an
(availability, p99-under-failure, arrays) frontier (``FAULT_OBJECTIVES``).
"""

from .engine import AllocationBatch, allocate_batch, run_batch, to_allocation
from .faults import FaultPoint, FaultSweepResult, fault_grid, run_fault_sweep
from .fused import (
    FusedChipSweepResult,
    FusedPipeline,
    clear_fused_caches,
    get_fused_pipeline,
    run_fused_multichip_sweep,
    run_fused_sweep,
)
from .pareto import (
    DEFAULT_OBJECTIVES,
    FAULT_OBJECTIVES,
    LATENCY_OBJECTIVES,
    MULTICHIP_OBJECTIVES,
    pareto_frontier,
    pareto_mask,
)
from .sweep import (
    ChipSweepPoint,
    ChipSweepResult,
    FabricEval,
    SweepPoint,
    SweepResult,
    chip_grid,
    clear_caches,
    design_grid,
    get_captured,
    get_profiled,
    run_multichip_sweep,
    run_sweep,
)

__all__ = [
    "AllocationBatch",
    "allocate_batch",
    "run_batch",
    "to_allocation",
    "FaultPoint",
    "FaultSweepResult",
    "fault_grid",
    "run_fault_sweep",
    "FusedChipSweepResult",
    "FusedPipeline",
    "clear_fused_caches",
    "get_fused_pipeline",
    "run_fused_multichip_sweep",
    "run_fused_sweep",
    "DEFAULT_OBJECTIVES",
    "FAULT_OBJECTIVES",
    "LATENCY_OBJECTIVES",
    "MULTICHIP_OBJECTIVES",
    "pareto_frontier",
    "pareto_mask",
    "ChipSweepPoint",
    "ChipSweepResult",
    "FabricEval",
    "SweepPoint",
    "SweepResult",
    "chip_grid",
    "clear_caches",
    "design_grid",
    "get_captured",
    "get_profiled",
    "run_multichip_sweep",
    "run_sweep",
]
