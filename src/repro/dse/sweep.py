"""Cartesian design-space sweeps (array geometry x ADC x PE count x policy
x network) with profile caching.

Profiling is the expensive, config-independent step (a quantized forward
pass per (network, ArrayConfig) pair — see profile.py), so profiles are
cached keyed on the array config + profile parameters and shared between the
batched and scalar engines.  ``run_sweep`` groups points by (network, array)
— every group shares one packed-profile ``BatchSimulator`` — and evaluates
each group with two jit calls; ``engine="scalar"`` runs the identical points
through the per-config ``allocate``/``simulate`` loop (the pre-refactor
path) for equivalence checks and speedup measurement.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from ..core.cim.cost import ArrayConfig, DEFAULT_ARRAY
from ..core.cim.network import NetworkSpec, resnet18_imagenet, vgg11_cifar10, with_array
from ..core.cim.profile import NetworkProfile, profile_network
from ..core.cim.simulate import (
    ARRAYS_PER_PE,
    POLICIES,
    BatchSimulator,
    allocate,
    simulate,
)
from .engine import run_batch

__all__ = [
    "SweepPoint",
    "SweepResult",
    "design_grid",
    "run_sweep",
    "get_profiled",
    "clear_caches",
]

_SPEC_FNS = {"resnet18": resnet18_imagenet, "vgg11": vgg11_cifar10}
_PROFILE_CACHE: dict[tuple, tuple[NetworkSpec, NetworkProfile]] = {}
_SIMULATOR_CACHE: dict[tuple, BatchSimulator] = {}


@dataclass(frozen=True)
class SweepPoint:
    """One design point: what to build (array, PEs) and how to run it."""

    network: str
    policy: str
    n_pes: int
    array: ArrayConfig = DEFAULT_ARRAY


@dataclass
class SweepResult:
    """Columnar sweep outcome; row i corresponds to ``points[i]``."""

    points: list[SweepPoint]
    total_cycles: np.ndarray
    images_per_sec: np.ndarray
    mean_utilization: np.ndarray
    arrays_used: np.ndarray
    arrays_total: np.ndarray
    elapsed_s: float
    engine: str

    def __len__(self) -> int:
        return len(self.points)

    def rows(self) -> list[dict]:
        return [
            {
                "network": p.network,
                "policy": p.policy,
                "n_pes": p.n_pes,
                "adc_bits": p.array.adc_bits,
                "array_rows": p.array.rows,
                "total_cycles": float(self.total_cycles[i]),
                "images_per_sec": float(self.images_per_sec[i]),
                "mean_utilization": float(self.mean_utilization[i]),
                "arrays_used": int(self.arrays_used[i]),
                "arrays_total": int(self.arrays_total[i]),
            }
            for i, p in enumerate(self.points)
        ]

    def objectives(self, names: tuple[str, ...]) -> np.ndarray:
        """(C, len(names)) matrix of the named columns (pareto input)."""
        return np.stack([np.asarray(getattr(self, n), dtype=np.float64) for n in names], axis=1)


def _spec_for(network: str, array: ArrayConfig) -> NetworkSpec:
    if network not in _SPEC_FNS:
        raise ValueError(f"unknown network {network!r}; choose from {sorted(_SPEC_FNS)}")
    return with_array(_SPEC_FNS[network](), array)


def get_profiled(
    network: str,
    array: ArrayConfig = DEFAULT_ARRAY,
    *,
    profile_images: int = 1,
    sample_patches: int = 128,
    seed: int = 0,
) -> tuple[NetworkSpec, NetworkProfile]:
    """Cached (spec, profile) for a (network, array-config) pair."""
    _spec_for(network, array)  # validate the name before the cache lookup
    key = (network, array, profile_images, sample_patches, seed)
    if key not in _PROFILE_CACHE:
        spec = _spec_for(network, array)
        prof = profile_network(
            spec, n_images=profile_images, sample_patches=sample_patches, seed=seed
        )
        _PROFILE_CACHE[key] = (spec, prof)
    return _PROFILE_CACHE[key]


def clear_caches() -> None:
    _PROFILE_CACHE.clear()
    _SIMULATOR_CACHE.clear()


def design_grid(
    networks=("resnet18",),
    policies=POLICIES,
    pe_multipliers=(1.0, 1.41, 2.0, 2.83, 4.0, 5.66),
    arrays=(DEFAULT_ARRAY,),
    arrays_per_pe: int = ARRAYS_PER_PE,
) -> list[SweepPoint]:
    """Cartesian grid; PE budgets scale each (network, array)'s minimum
    design size so every point is feasible."""
    points = []
    for net in networks:
        for arr in arrays:
            spec = _spec_for(net, arr)
            base = spec.min_pes(arrays_per_pe)
            for m in pe_multipliers:
                n_pes = max(base, int(np.ceil(base * m)))
                for pol in policies:
                    points.append(SweepPoint(net, pol, n_pes, arr))
    return points


def run_sweep(
    points: list[SweepPoint],
    *,
    n_images: int = 64,
    profile_images: int = 1,
    sample_patches: int = 128,
    seed: int = 0,
    arrays_per_pe: int = ARRAYS_PER_PE,
    engine: str = "batch",
) -> SweepResult:
    """Evaluate every point; profiles are cached and excluded from timing."""
    if engine not in ("batch", "scalar"):
        raise ValueError(f"engine must be 'batch' or 'scalar', got {engine!r}")
    C = len(points)
    out = {
        name: np.zeros(C)
        for name in ("total_cycles", "images_per_sec", "mean_utilization")
    }
    used = np.zeros(C, dtype=np.int64)
    total = np.zeros(C, dtype=np.int64)

    # group rows by (network, array) — one packed profile per group
    groups: dict[tuple, list[int]] = {}
    for i, p in enumerate(points):
        groups.setdefault((p.network, p.array), []).append(i)
    prof_kw = dict(
        profile_images=profile_images, sample_patches=sample_patches, seed=seed
    )
    for net, arr in groups:  # warm the cache outside the timed region
        get_profiled(net, arr, **prof_kw)

    elapsed = 0.0
    for (net, arr), rows in groups.items():
        spec, prof = get_profiled(net, arr, **prof_kw)
        idx = np.asarray(rows)
        pols = np.array([points[i].policy for i in rows], dtype=object)
        pes = np.array([points[i].n_pes for i in rows], dtype=np.int64)
        t0 = time.perf_counter()
        if engine == "batch":
            key = (net, arr, profile_images, sample_patches, seed)
            if key not in _SIMULATOR_CACHE:
                _SIMULATOR_CACHE[key] = BatchSimulator(spec, prof)
            alloc, res = run_batch(
                spec,
                prof,
                pols,
                pes,
                n_images=n_images,
                arrays_per_pe=arrays_per_pe,
                simulator=_SIMULATOR_CACHE[key],
            )
            out["total_cycles"][idx] = res.total_cycles
            out["images_per_sec"][idx] = res.images_per_sec
            out["mean_utilization"][idx] = res.mean_utilization
            used[idx] = alloc.arrays_used
            total[idx] = alloc.arrays_total
        else:
            for i in rows:
                p = points[i]
                a = allocate(spec, prof, p.policy, p.n_pes, arrays_per_pe)
                s = simulate(spec, prof, a, n_images=n_images)
                out["total_cycles"][i] = s.total_cycles
                out["images_per_sec"][i] = s.images_per_sec
                out["mean_utilization"][i] = s.mean_utilization
                used[i] = a.arrays_used
                total[i] = a.arrays_total
        elapsed += time.perf_counter() - t0

    return SweepResult(
        points=list(points),
        total_cycles=out["total_cycles"],
        images_per_sec=out["images_per_sec"],
        mean_utilization=out["mean_utilization"],
        arrays_used=used,
        arrays_total=total,
        elapsed_s=elapsed,
        engine=engine,
    )
