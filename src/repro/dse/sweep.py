"""Cartesian design-space sweeps (array geometry x ADC x PE count x policy
x network) with profile caching.

Profiling is the expensive, config-independent step (a quantized forward
pass per (network, ArrayConfig) pair — see profile.py), so profiles are
cached keyed on the array config + profile parameters and shared between the
batched and scalar engines.  ``run_sweep`` groups points by (network, array)
— every group shares one packed-profile ``BatchSimulator`` — and evaluates
each group with two jit calls; ``engine="scalar"`` runs the identical points
through the per-config ``allocate``/``simulate`` loop (the pre-refactor
path) for equivalence checks and speedup measurement.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from ..core.cim.cost import ArrayConfig, DEFAULT_ARRAY
from ..core.cim.network import NetworkSpec, resnet18_imagenet, vgg11_cifar10, with_array
from ..core.cim.profile import NetworkProfile, profile_network
from ..core.cim.simulate import (
    ARRAYS_PER_PE,
    CLOCK_HZ,
    POLICIES,
    BatchSimulator,
    allocate,
    simulate,
)
from .engine import run_batch, to_allocation

__all__ = [
    "FabricEval",
    "SweepPoint",
    "SweepResult",
    "design_grid",
    "run_sweep",
    "get_profiled",
    "clear_caches",
]

_SPEC_FNS = {"resnet18": resnet18_imagenet, "vgg11": vgg11_cifar10}
_PROFILE_CACHE: dict[tuple, tuple[NetworkSpec, NetworkProfile]] = {}
_SIMULATOR_CACHE: dict[tuple, BatchSimulator] = {}
_VT_CACHE: dict[tuple, object] = {}  # VirtualTimeFabric per profiled group


@dataclass(frozen=True)
class SweepPoint:
    """One design point: what to build (array, PEs) and how to run it."""

    network: str
    policy: str
    n_pes: int
    array: ArrayConfig = DEFAULT_ARRAY


@dataclass(frozen=True)
class FabricEval:
    """Optional serving-side evaluation attached to a sweep.

    Every design point additionally runs the batched virtual-time fabric
    under open-loop Poisson traffic at ``load_frac`` of its own analytic
    throughput, filling the sweep's latency-percentile columns so designs
    can be ranked / Pareto-filtered on (throughput, p99, utilization).
    Traces share one normalized gap sequence (common random numbers), so
    latency differences across designs are allocation effects, not trace
    noise.
    """

    load_frac: float = 0.7
    n_requests: int = 200
    seed: int = 0


@dataclass
class SweepResult:
    """Columnar sweep outcome; row i corresponds to ``points[i]``.

    The latency columns (``p50_cycles``/``p95_cycles``/``p99_cycles``) are
    NaN unless the sweep ran with a ``FabricEval``.
    """

    points: list[SweepPoint]
    total_cycles: np.ndarray
    images_per_sec: np.ndarray
    mean_utilization: np.ndarray
    arrays_used: np.ndarray
    arrays_total: np.ndarray
    elapsed_s: float
    engine: str
    p50_cycles: np.ndarray | None = None
    p95_cycles: np.ndarray | None = None
    p99_cycles: np.ndarray | None = None
    fabric: FabricEval | None = None

    def __len__(self) -> int:
        return len(self.points)

    def rows(self) -> list[dict]:
        out = []
        for i, p in enumerate(self.points):
            row = {
                "network": p.network,
                "policy": p.policy,
                "n_pes": p.n_pes,
                "adc_bits": p.array.adc_bits,
                "array_rows": p.array.rows,
                "total_cycles": float(self.total_cycles[i]),
                "images_per_sec": float(self.images_per_sec[i]),
                "mean_utilization": float(self.mean_utilization[i]),
                "arrays_used": int(self.arrays_used[i]),
                "arrays_total": int(self.arrays_total[i]),
            }
            if self.p99_cycles is not None:
                row["p50_ms"] = float(self.p50_cycles[i] / CLOCK_HZ * 1e3)
                row["p95_ms"] = float(self.p95_cycles[i] / CLOCK_HZ * 1e3)
                row["p99_ms"] = float(self.p99_cycles[i] / CLOCK_HZ * 1e3)
            out.append(row)
        return out

    def objectives(self, names: tuple[str, ...]) -> np.ndarray:
        """(C, len(names)) matrix of the named columns (pareto input)."""
        cols = []
        for n in names:
            v = getattr(self, n)
            if v is None:
                raise ValueError(
                    f"column {n!r} was not computed — run the sweep with a "
                    f"FabricEval to fill latency percentiles"
                )
            cols.append(np.asarray(v, dtype=np.float64))
        return np.stack(cols, axis=1)


def _spec_for(network: str, array: ArrayConfig) -> NetworkSpec:
    if network not in _SPEC_FNS:
        raise ValueError(f"unknown network {network!r}; choose from {sorted(_SPEC_FNS)}")
    return with_array(_SPEC_FNS[network](), array)


def get_profiled(
    network: str,
    array: ArrayConfig = DEFAULT_ARRAY,
    *,
    profile_images: int = 1,
    sample_patches: int = 128,
    seed: int = 0,
) -> tuple[NetworkSpec, NetworkProfile]:
    """Cached (spec, profile) for a (network, array-config) pair."""
    _spec_for(network, array)  # validate the name before the cache lookup
    key = (network, array, profile_images, sample_patches, seed)
    if key not in _PROFILE_CACHE:
        spec = _spec_for(network, array)
        prof = profile_network(
            spec, n_images=profile_images, sample_patches=sample_patches, seed=seed
        )
        _PROFILE_CACHE[key] = (spec, prof)
    return _PROFILE_CACHE[key]


def clear_caches() -> None:
    _PROFILE_CACHE.clear()
    _SIMULATOR_CACHE.clear()
    _VT_CACHE.clear()


def design_grid(
    networks=("resnet18",),
    policies=POLICIES,
    pe_multipliers=(1.0, 1.41, 2.0, 2.83, 4.0, 5.66),
    arrays=(DEFAULT_ARRAY,),
    arrays_per_pe: int = ARRAYS_PER_PE,
) -> list[SweepPoint]:
    """Cartesian grid; PE budgets scale each (network, array)'s minimum
    design size so every point is feasible."""
    points = []
    for net in networks:
        for arr in arrays:
            spec = _spec_for(net, arr)
            base = spec.min_pes(arrays_per_pe)
            for m in pe_multipliers:
                n_pes = max(base, int(np.ceil(base * m)))
                for pol in policies:
                    points.append(SweepPoint(net, pol, n_pes, arr))
    return points


def run_sweep(
    points: list[SweepPoint],
    *,
    n_images: int = 64,
    profile_images: int = 1,
    sample_patches: int = 128,
    seed: int = 0,
    arrays_per_pe: int = ARRAYS_PER_PE,
    engine: str = "batch",
    fabric: FabricEval | None = None,
    latency_load_frac: float | None = None,
) -> SweepResult:
    """Evaluate every point; profiles are cached and excluded from timing.

    With ``fabric=FabricEval(...)`` every point additionally runs the
    virtual-time fabric at ``load_frac`` of its own analytic throughput —
    one batched call per (network, array) group on the batch engine, one
    ``FabricSim`` event-engine run per point on the scalar engine (the
    equivalence reference) — filling the p50/p95/p99 columns.

    ``latency_load_frac`` is the offered load ``latency_aware`` design
    points are *provisioned* for; it defaults to the load they are
    *evaluated* at (``fabric.load_frac``, else 0.7) so the two knobs cannot
    silently disagree."""
    if engine not in ("batch", "scalar"):
        raise ValueError(f"engine must be 'batch' or 'scalar', got {engine!r}")
    if latency_load_frac is None:
        latency_load_frac = fabric.load_frac if fabric is not None else 0.7
    C = len(points)
    out = {
        name: np.zeros(C)
        for name in ("total_cycles", "images_per_sec", "mean_utilization")
    }
    used = np.zeros(C, dtype=np.int64)
    total = np.zeros(C, dtype=np.int64)
    pcts = np.full((C, 3), np.nan) if fabric is not None else None

    # group rows by (network, array) — one packed profile per group
    groups: dict[tuple, list[int]] = {}
    for i, p in enumerate(points):
        groups.setdefault((p.network, p.array), []).append(i)
    prof_kw = dict(
        profile_images=profile_images, sample_patches=sample_patches, seed=seed
    )
    for net, arr in groups:  # warm the cache outside the timed region
        get_profiled(net, arr, **prof_kw)

    elapsed = 0.0
    for (net, arr), rows in groups.items():
        spec, prof = get_profiled(net, arr, **prof_kw)
        idx = np.asarray(rows)
        pols = np.array([points[i].policy for i in rows], dtype=object)
        pes = np.array([points[i].n_pes for i in rows], dtype=np.int64)
        t0 = time.perf_counter()
        allocs = None
        if engine == "batch":
            key = (net, arr, profile_images, sample_patches, seed)
            if key not in _SIMULATOR_CACHE:
                _SIMULATOR_CACHE[key] = BatchSimulator(spec, prof)
            alloc, res = run_batch(
                spec,
                prof,
                pols,
                pes,
                n_images=n_images,
                arrays_per_pe=arrays_per_pe,
                simulator=_SIMULATOR_CACHE[key],
                latency_load_frac=latency_load_frac,
            )
            out["total_cycles"][idx] = res.total_cycles
            out["images_per_sec"][idx] = res.images_per_sec
            out["mean_utilization"][idx] = res.mean_utilization
            used[idx] = alloc.arrays_used
            total[idx] = alloc.arrays_total
            if fabric is not None:
                allocs = [to_allocation(alloc, k, spec) for k in range(len(rows))]
        else:
            allocs = []
            for i in rows:
                p = points[i]
                a = allocate(
                    spec, prof, p.policy, p.n_pes, arrays_per_pe,
                    load_frac=latency_load_frac,
                )
                s = simulate(spec, prof, a, n_images=n_images)
                out["total_cycles"][i] = s.total_cycles
                out["images_per_sec"][i] = s.images_per_sec
                out["mean_utilization"][i] = s.mean_utilization
                used[i] = a.arrays_used
                total[i] = a.arrays_total
                allocs.append(a)
        if fabric is not None:
            pcts[idx] = _fabric_eval(
                spec, prof, allocs, out["images_per_sec"][idx], fabric, engine,
                cache_key=(net, arr, profile_images, sample_patches, seed),
            )
        elapsed += time.perf_counter() - t0

    return SweepResult(
        points=list(points),
        total_cycles=out["total_cycles"],
        images_per_sec=out["images_per_sec"],
        mean_utilization=out["mean_utilization"],
        arrays_used=used,
        arrays_total=total,
        elapsed_s=elapsed,
        engine=engine,
        p50_cycles=pcts[:, 0] if fabric is not None else None,
        p95_cycles=pcts[:, 1] if fabric is not None else None,
        p99_cycles=pcts[:, 2] if fabric is not None else None,
        fabric=fabric,
    )


def _fabric_eval(
    spec, prof, allocs, ips, fabric: FabricEval, engine: str, cache_key=None
) -> np.ndarray:
    """(C, 3) p50/p95/p99 in cycles for one sweep group.

    Each design gets a Poisson trace at ``load_frac`` of its own analytic
    throughput, built from one shared normalized gap sequence; the batch
    engine evaluates the whole group per virtual-time call, the scalar
    engine runs the event-driven ``FabricSim`` per point (bit-identical by
    construction — the equivalence suite pins this).
    """
    from ..fabric.arrivals import TraceReplay
    from ..fabric.dispatch import FabricSim
    from ..fabric.vtime import VirtualTimeFabric

    rng = np.random.default_rng(fabric.seed)
    gaps = rng.exponential(1.0, size=fabric.n_requests)
    rates = fabric.load_frac * np.asarray(ips, dtype=np.float64) / CLOCK_HZ
    procs = [TraceReplay(np.cumsum(gaps) / r) for r in rates]
    qs = (50.0, 95.0, 99.0)
    if engine == "batch":
        # cached like _SIMULATOR_CACHE so repeated sweeps over the same
        # (network, array, profile) group reuse the compiled kernels
        if cache_key is not None and cache_key in _VT_CACHE:
            vt = _VT_CACHE[cache_key]
        else:
            vt = VirtualTimeFabric(spec, prof)
            if cache_key is not None:
                _VT_CACHE[cache_key] = vt
        res = vt.run_batch(allocs, procs, seed=fabric.seed, percentiles=qs)
        # percentiles recomputed in numpy from the bit-exact latencies so the
        # batch and scalar sweep columns agree to the last bit
        return np.percentile(res.latencies, qs, axis=1).T
    out = np.zeros((len(allocs), 3))
    for k, (a, pr) in enumerate(zip(allocs, procs)):
        r = FabricSim(spec, prof, a, seed=fabric.seed).run(pr)
        out[k] = np.percentile(r.latencies, qs)
    return out
