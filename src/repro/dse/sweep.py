"""Cartesian design-space sweeps (array geometry x ADC x PE count x policy
x network) with two-level profile caching.

Profiling splits into a geometry-INDEPENDENT capture (the jit quantized
forward — see profile.py) and a cheap per-geometry derivation, so the cache
is split the same way: ``get_captured`` caches activations keyed on
(network, profile_images, sample_patches, seed), and ``get_profiled``
derives per-``ArrayConfig`` ``LayerProfile`` views from that shared capture
— a geometry x ADC sweep runs the network forward exactly once.
``run_sweep`` groups points by (network, array) — every group shares one
packed-profile ``BatchSimulator`` — and evaluates each group with two jit
calls; ``engine="scalar"`` runs the identical points through the per-config
``allocate``/``simulate`` loop (the pre-refactor path) for equivalence
checks and speedup measurement.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from ..core.cim.cost import ArrayConfig, DEFAULT_ARRAY
from ..core.cim.network import NetworkSpec, resnet18_imagenet, vgg11_cifar10, with_array
from ..core.cim.profile import (
    ActivationCapture,
    NetworkProfile,
    capture_activations,
    derive_profile,
)
from ..core.cim.simulate import (
    ARRAYS_PER_PE,
    CLOCK_HZ,
    POLICIES,
    BatchSimulator,
    allocate,
    simulate,
)
from ..core.cim.topology import FabricTopology, allocate_placed
from ..fabric.telemetry import get_telemetry
from .engine import run_batch, to_allocation

__all__ = [
    "ChipSweepPoint",
    "ChipSweepResult",
    "FabricEval",
    "SweepPoint",
    "SweepResult",
    "chip_grid",
    "design_grid",
    "run_multichip_sweep",
    "run_sweep",
    "get_captured",
    "get_profiled",
    "clear_caches",
]

_SPEC_FNS = {"resnet18": resnet18_imagenet, "vgg11": vgg11_cifar10}
_CAPTURE_CACHE: dict[tuple, ActivationCapture] = {}
_PROFILE_CACHE: dict[tuple, tuple[NetworkSpec, NetworkProfile]] = {}
_SIMULATOR_CACHE: dict[tuple, BatchSimulator] = {}
_VT_CACHE: dict[tuple, object] = {}  # VirtualTimeFabric per profiled group


@dataclass(frozen=True)
class SweepPoint:
    """One design point: what to build (array, PEs) and how to run it."""

    network: str
    policy: str
    n_pes: int
    array: ArrayConfig = DEFAULT_ARRAY


@dataclass(frozen=True)
class FabricEval:
    """Optional serving-side evaluation attached to a sweep.

    Every design point additionally runs the batched virtual-time fabric
    under open-loop Poisson traffic at ``load_frac`` of its own analytic
    throughput, filling the sweep's latency-percentile columns so designs
    can be ranked / Pareto-filtered on (throughput, p99, utilization).
    Traces share one normalized gap sequence (common random numbers), so
    latency differences across designs are allocation effects, not trace
    noise.
    """

    load_frac: float = 0.7
    n_requests: int = 200
    seed: int = 0


@dataclass
class SweepResult:
    """Columnar sweep outcome; row i corresponds to ``points[i]``.

    The latency columns (``p50_cycles``/``p95_cycles``/``p99_cycles``) are
    NaN unless the sweep ran with a ``FabricEval``.
    """

    points: list[SweepPoint]
    total_cycles: np.ndarray
    images_per_sec: np.ndarray
    mean_utilization: np.ndarray
    arrays_used: np.ndarray
    arrays_total: np.ndarray
    elapsed_s: float
    engine: str
    p50_cycles: np.ndarray | None = None
    p95_cycles: np.ndarray | None = None
    p99_cycles: np.ndarray | None = None
    fabric: FabricEval | None = None

    def __len__(self) -> int:
        return len(self.points)

    def rows(self) -> list[dict]:
        out = []
        for i, p in enumerate(self.points):
            row = {
                "network": p.network,
                "policy": p.policy,
                "n_pes": p.n_pes,
                "adc_bits": p.array.adc_bits,
                "array_rows": p.array.rows,
                "total_cycles": float(self.total_cycles[i]),
                "images_per_sec": float(self.images_per_sec[i]),
                "mean_utilization": float(self.mean_utilization[i]),
                "arrays_used": int(self.arrays_used[i]),
                "arrays_total": int(self.arrays_total[i]),
            }
            if self.p99_cycles is not None:
                row["p50_ms"] = float(self.p50_cycles[i] / CLOCK_HZ * 1e3)
                row["p95_ms"] = float(self.p95_cycles[i] / CLOCK_HZ * 1e3)
                row["p99_ms"] = float(self.p99_cycles[i] / CLOCK_HZ * 1e3)
            out.append(row)
        return out

    def objectives(self, names: tuple[str, ...]) -> np.ndarray:
        """(C, len(names)) matrix of the named columns (pareto input)."""
        cols = []
        for n in names:
            v = getattr(self, n)
            if v is None:
                raise ValueError(
                    f"column {n!r} was not computed — run the sweep with a "
                    f"FabricEval to fill latency percentiles"
                )
            cols.append(np.asarray(v, dtype=np.float64))
        return np.stack(cols, axis=1)


def _spec_for(network: str, array: ArrayConfig) -> NetworkSpec:
    if network not in _SPEC_FNS:
        raise ValueError(f"unknown network {network!r}; choose from {sorted(_SPEC_FNS)}")
    return with_array(_SPEC_FNS[network](), array)


def get_captured(
    network: str,
    *,
    profile_images: int = 1,
    sample_patches: int = 128,
    seed: int = 0,
) -> ActivationCapture:
    """Cached geometry-independent activation capture — ONE quantized
    forward per (network, images, sample, seed), shared by every
    ``ArrayConfig`` variant a sweep derives profiles for."""
    if network not in _SPEC_FNS:
        raise ValueError(f"unknown network {network!r}; choose from {sorted(_SPEC_FNS)}")
    key = (network, profile_images, sample_patches, seed)
    if key not in _CAPTURE_CACHE:
        get_telemetry().count("dse.capture.miss")
        with get_telemetry().timed("dse.capture", network=network):
            _CAPTURE_CACHE[key] = capture_activations(
                _SPEC_FNS[network](),
                n_images=profile_images,
                sample_patches=sample_patches,
                seed=seed,
            )
    else:
        get_telemetry().count("dse.capture.hit")
    return _CAPTURE_CACHE[key]


def get_profiled(
    network: str,
    array: ArrayConfig = DEFAULT_ARRAY,
    *,
    profile_images: int = 1,
    sample_patches: int = 128,
    seed: int = 0,
) -> tuple[NetworkSpec, NetworkProfile]:
    """Cached (spec, profile) for a (network, array-config) pair — a cheap
    derived view over the shared ``get_captured`` activations, so geometry
    sweeps never re-run the forward pass."""
    _spec_for(network, array)  # validate the name before the cache lookup
    key = (network, array, profile_images, sample_patches, seed)
    if key not in _PROFILE_CACHE:
        get_telemetry().count("dse.profile.miss")
        cap = get_captured(
            network,
            profile_images=profile_images,
            sample_patches=sample_patches,
            seed=seed,
        )
        spec = _spec_for(network, array)
        with get_telemetry().timed("dse.profile", network=network):
            _PROFILE_CACHE[key] = (spec, derive_profile(cap, spec, array=array))
    else:
        get_telemetry().count("dse.profile.hit")
    return _PROFILE_CACHE[key]


def clear_caches() -> None:
    _CAPTURE_CACHE.clear()
    _PROFILE_CACHE.clear()
    _SIMULATOR_CACHE.clear()
    _VT_CACHE.clear()


def design_grid(
    networks=("resnet18",),
    policies=POLICIES,
    pe_multipliers=(1.0, 1.41, 2.0, 2.83, 4.0, 5.66),
    arrays=(DEFAULT_ARRAY,),
    arrays_per_pe: int = ARRAYS_PER_PE,
) -> list[SweepPoint]:
    """Cartesian grid; PE budgets scale each (network, array)'s minimum
    design size so every point is feasible."""
    points = []
    for net in networks:
        for arr in arrays:
            spec = _spec_for(net, arr)
            base = spec.min_pes(arrays_per_pe)
            for m in pe_multipliers:
                n_pes = max(base, int(np.ceil(base * m)))
                for pol in policies:
                    points.append(SweepPoint(net, pol, n_pes, arr))
    return points


def run_sweep(
    points: list[SweepPoint],
    *,
    n_images: int = 64,
    profile_images: int = 1,
    sample_patches: int = 128,
    seed: int = 0,
    arrays_per_pe: int = ARRAYS_PER_PE,
    engine: str = "batch",
    fabric: FabricEval | None = None,
    latency_load_frac: float | None = None,
    shard_devices: bool = False,
) -> SweepResult:
    """Evaluate every point; profiles are cached and excluded from timing.

    With ``fabric=FabricEval(...)`` every point additionally runs the
    virtual-time fabric at ``load_frac`` of its own analytic throughput —
    one batched call per (network, array) group on the batch engine, one
    ``FabricSim`` event-engine run per point on the scalar engine (the
    equivalence reference) — filling the p50/p95/p99 columns.

    ``latency_load_frac`` is the offered load ``latency_aware`` design
    points are *provisioned* for; it defaults to the load they are
    *evaluated* at (``fabric.load_frac``, else 0.7) so the two knobs cannot
    silently disagree.

    ``shard_devices=True`` shard_maps the batched analytic evaluation over
    the host's local devices (``distrib.sharding.shard_map_batch``) —
    identical results, throughput scaling with the accelerators present."""
    if engine not in ("batch", "scalar"):
        raise ValueError(f"engine must be 'batch' or 'scalar', got {engine!r}")
    if latency_load_frac is None:
        latency_load_frac = fabric.load_frac if fabric is not None else 0.7
    C = len(points)
    out = {
        name: np.zeros(C)
        for name in ("total_cycles", "images_per_sec", "mean_utilization")
    }
    used = np.zeros(C, dtype=np.int64)
    total = np.zeros(C, dtype=np.int64)
    pcts = np.full((C, 3), np.nan) if fabric is not None else None

    # group rows by (network, array) — one packed profile per group
    groups: dict[tuple, list[int]] = {}
    for i, p in enumerate(points):
        groups.setdefault((p.network, p.array), []).append(i)
    prof_kw = dict(
        profile_images=profile_images, sample_patches=sample_patches, seed=seed
    )
    for net, arr in groups:  # warm the cache outside the timed region
        get_profiled(net, arr, **prof_kw)

    elapsed = 0.0
    tel = get_telemetry()
    tel.gauge("dse.sweep.points", C)
    tel.gauge("dse.sweep.groups", len(groups))
    done = 0
    for (net, arr), rows in groups.items():
        spec, prof = get_profiled(net, arr, **prof_kw)
        idx = np.asarray(rows)
        pols = np.array([points[i].policy for i in rows], dtype=object)
        pes = np.array([points[i].n_pes for i in rows], dtype=np.int64)
        t0 = time.perf_counter()
        group_timer = tel.timed("dse.sweep.group", network=net, points=len(rows))
        group_timer.__enter__()
        allocs = None
        if engine == "batch":
            key = (net, arr, profile_images, sample_patches, seed, shard_devices)
            if key not in _SIMULATOR_CACHE:
                tel.count("dse.simulator.miss")
                _SIMULATOR_CACHE[key] = BatchSimulator(spec, prof, shard=shard_devices)
            else:
                tel.count("dse.simulator.hit")
            alloc, res = run_batch(
                spec,
                prof,
                pols,
                pes,
                n_images=n_images,
                arrays_per_pe=arrays_per_pe,
                simulator=_SIMULATOR_CACHE[key],
                latency_load_frac=latency_load_frac,
            )
            out["total_cycles"][idx] = res.total_cycles
            out["images_per_sec"][idx] = res.images_per_sec
            out["mean_utilization"][idx] = res.mean_utilization
            used[idx] = alloc.arrays_used
            total[idx] = alloc.arrays_total
            if fabric is not None:
                allocs = [to_allocation(alloc, k, spec) for k in range(len(rows))]
        else:
            allocs = []
            for i in rows:
                p = points[i]
                a = allocate(
                    spec, prof, p.policy, p.n_pes, arrays_per_pe,
                    load_frac=latency_load_frac,
                )
                s = simulate(spec, prof, a, n_images=n_images)
                out["total_cycles"][i] = s.total_cycles
                out["images_per_sec"][i] = s.images_per_sec
                out["mean_utilization"][i] = s.mean_utilization
                used[i] = a.arrays_used
                total[i] = a.arrays_total
                allocs.append(a)
        if fabric is not None:
            pcts[idx] = _fabric_eval(
                spec, prof, allocs, out["images_per_sec"][idx], fabric, engine,
                cache_key=(net, arr, profile_images, sample_patches, seed),
            )
        elapsed += time.perf_counter() - t0
        group_timer.__exit__(None, None, None)
        done += len(rows)
        tel.gauge("dse.sweep.points_done", done)

    return SweepResult(
        points=list(points),
        total_cycles=out["total_cycles"],
        images_per_sec=out["images_per_sec"],
        mean_utilization=out["mean_utilization"],
        arrays_used=used,
        arrays_total=total,
        elapsed_s=elapsed,
        engine=engine,
        p50_cycles=pcts[:, 0] if fabric is not None else None,
        p95_cycles=pcts[:, 1] if fabric is not None else None,
        p99_cycles=pcts[:, 2] if fabric is not None else None,
        fabric=fabric,
    )


# ------------------------------------------------------- multi-chip sweep
@dataclass(frozen=True)
class ChipSweepPoint:
    """One multi-chip design point: the SAME total silicon (``n_pes_total``
    PEs) tiled over ``n_chips`` chips strung on ``link_gbps`` links."""

    network: str
    n_chips: int
    link_gbps: float
    n_pes_total: int
    policy: str = "blockwise"
    array: ArrayConfig = DEFAULT_ARRAY

    def topology(self, arrays_per_pe: int = ARRAYS_PER_PE) -> FabricTopology:
        return FabricTopology.split(
            self.n_chips, self.n_pes_total,
            arrays_per_pe=arrays_per_pe, link_gbps=self.link_gbps,
            array=self.array,
        )


@dataclass
class ChipSweepResult:
    """Columnar multi-chip sweep outcome; row i <-> ``points[i]``.

    ``objectives``-compatible with ``pareto_frontier`` — the
    (throughput, p99, chips) frontier is ``MULTICHIP_OBJECTIVES``.
    """

    points: list[ChipSweepPoint]
    images_per_sec: np.ndarray  # (C,) closed-loop steady rate WITH transfers
    p50_cycles: np.ndarray
    p95_cycles: np.ndarray
    p99_cycles: np.ndarray
    max_stage_transfer: np.ndarray  # (C,) worst per-request entry delay
    n_crossings: np.ndarray  # (C,) replicas parked off their source chip
    arrays_used: np.ndarray
    arrays_total: np.ndarray
    elapsed_s: float

    def __len__(self) -> int:
        return len(self.points)

    def objectives(self, names: tuple[str, ...]) -> np.ndarray:
        cols = {
            "n_chips": np.asarray([p.n_chips for p in self.points], dtype=np.float64),
            "link_gbps": np.asarray([p.link_gbps for p in self.points]),
        }
        out = []
        for n in names:
            v = cols.get(n)
            if v is None:
                v = np.asarray(getattr(self, n), dtype=np.float64)
            out.append(v)
        return np.stack(out, axis=1)

    def rows(self) -> list[dict]:
        out = []
        for i, p in enumerate(self.points):
            out.append(
                {
                    "network": p.network,
                    "policy": p.policy,
                    "n_chips": p.n_chips,
                    "link_gbps": p.link_gbps,
                    "n_pes_total": p.n_pes_total,
                    "images_per_sec": float(self.images_per_sec[i]),
                    "p50_ms": float(self.p50_cycles[i] / CLOCK_HZ * 1e3),
                    "p95_ms": float(self.p95_cycles[i] / CLOCK_HZ * 1e3),
                    "p99_ms": float(self.p99_cycles[i] / CLOCK_HZ * 1e3),
                    "max_stage_transfer_cycles": float(self.max_stage_transfer[i]),
                    "n_crossings": int(self.n_crossings[i]),
                    "arrays_used": int(self.arrays_used[i]),
                    "arrays_total": int(self.arrays_total[i]),
                }
            )
        return out


def chip_grid(
    networks=("vgg11",),
    chips=(1, 2, 4, 8),
    link_gbps=(16.0, 64.0),
    policy: str = "blockwise",
    pe_multiplier: float = 2.0,
    arrays_per_pe: int = ARRAYS_PER_PE,
    arrays=(DEFAULT_ARRAY,),
) -> list[ChipSweepPoint]:
    """chips x link-bandwidth grid at a FIXED total array budget per
    network: ``pe_multiplier`` times the minimum design, rounded up so every
    chip count divides it — the equal-silicon scaling comparison."""
    import math

    points = []
    div = math.lcm(*(int(c) for c in chips))
    for net in networks:
        for arr in arrays:
            spec = _spec_for(net, arr)
            base = spec.min_pes(arrays_per_pe)
            total = int(np.ceil(base * pe_multiplier))
            total = -(-total // div) * div
            for c in chips:
                for g in link_gbps:
                    points.append(
                        ChipSweepPoint(net, int(c), float(g), total, policy, arr)
                    )
    return points


def run_multichip_sweep(
    points: list[ChipSweepPoint],
    *,
    load_frac: float = 0.7,
    n_requests: int = 200,
    closed_requests: int = 80,
    concurrency: int = 32,
    seed: int = 0,
    profile_images: int = 1,
    sample_patches: int = 128,
    arrays_per_pe: int = ARRAYS_PER_PE,
    engine: str = "jax",
    latency_load_frac: float = 0.7,
) -> ChipSweepResult:
    """Evaluate a chips x link-bandwidth grid on the placed fabric.

    Per (network, array) group: every point's placed allocation
    (``allocate_placed`` on its ``FabricTopology``) runs through TWO batched
    virtual-time calls — a closed loop for steady throughput (transfer
    delays included) and an open-loop Poisson trace at ``load_frac`` of the
    point's own measured throughput for tail percentiles.  Traces share one
    normalized gap sequence (common random numbers), so differences across
    points are placement/topology effects, not noise.  ``engine="numpy"``
    runs the identical kernels scalar (the equivalence reference).
    """
    from ..fabric.arrivals import ClosedLoop, TraceReplay
    from ..fabric.vtime import VirtualTimeFabric

    C = len(points)
    ips = np.zeros(C)
    pcts = np.zeros((C, 3))
    xfer_max = np.zeros(C)
    crossings = np.zeros(C, dtype=np.int64)
    used = np.zeros(C, dtype=np.int64)
    total = np.zeros(C, dtype=np.int64)

    groups: dict[tuple, list[int]] = {}
    for i, p in enumerate(points):
        groups.setdefault((p.network, p.array), []).append(i)
    prof_kw = dict(
        profile_images=profile_images, sample_patches=sample_patches, seed=seed
    )
    for net, arr in groups:
        get_profiled(net, arr, **prof_kw)

    elapsed = 0.0
    qs = (50.0, 95.0, 99.0)
    for (net, arr), rows in groups.items():
        spec, prof = get_profiled(net, arr, **prof_kw)
        # dedupe physically identical points: on one chip the link is
        # unused, so every link_gbps value names the same design — evaluate
        # each unique topology once and alias the rest onto it
        alias: dict[int, int] = {}
        canon: dict[tuple, int] = {}
        uniq: list[int] = []
        for i in rows:
            p = points[i]
            key = (
                p.policy, p.n_pes_total, p.n_chips,
                p.link_gbps if p.n_chips > 1 else None,
            )
            if key not in canon:
                canon[key] = i
                uniq.append(i)
            alias[i] = canon[key]
        placed = []
        for i in uniq:
            p = points[i]
            pa = allocate_placed(
                spec, prof, p.policy, p.topology(arrays_per_pe),
                load_frac=latency_load_frac,
            )
            placed.append(pa)
            xfer_max[i] = pa.placement.max_stage_transfer
            crossings[i] = pa.placement.n_crossings
            used[i] = pa.allocation.arrays_used
            total[i] = pa.allocation.arrays_total
        allocs = [pa.allocation for pa in placed]
        places = [pa.placement for pa in placed]
        t0 = time.perf_counter()
        vt = VirtualTimeFabric(spec, prof, lane_quantum=8)
        # throughput: saturated closed loop, transfer delays included
        cl = vt.run_batch(
            allocs, ClosedLoop(closed_requests, concurrency),
            seed=seed, engine=engine, percentiles=qs, placements=places,
        )
        ips[uniq] = cl.images_per_sec
        # tail: Poisson at load_frac of each point's own throughput, one
        # shared normalized gap sequence (common random numbers)
        gaps = np.random.default_rng(seed).exponential(1.0, size=n_requests)
        rates = load_frac * ips[uniq] / CLOCK_HZ
        procs = [TraceReplay(np.cumsum(gaps) / r) for r in rates]
        op = vt.run_batch(
            allocs, procs, seed=seed, engine=engine, percentiles=qs,
            placements=places,
        )
        pcts[uniq] = np.percentile(op.latencies, qs, axis=1).T
        for i in rows:
            j = alias[i]
            if j != i:
                ips[i] = ips[j]
                pcts[i] = pcts[j]
                xfer_max[i] = xfer_max[j]
                crossings[i] = crossings[j]
                used[i] = used[j]
                total[i] = total[j]
        elapsed += time.perf_counter() - t0

    return ChipSweepResult(
        points=list(points),
        images_per_sec=ips,
        p50_cycles=pcts[:, 0],
        p95_cycles=pcts[:, 1],
        p99_cycles=pcts[:, 2],
        max_stage_transfer=xfer_max,
        n_crossings=crossings,
        arrays_used=used,
        arrays_total=total,
        elapsed_s=elapsed,
    )


def _fabric_eval(
    spec, prof, allocs, ips, fabric: FabricEval, engine: str, cache_key=None
) -> np.ndarray:
    """(C, 3) p50/p95/p99 in cycles for one sweep group.

    Each design gets a Poisson trace at ``load_frac`` of its own analytic
    throughput, built from one shared normalized gap sequence; the batch
    engine evaluates the whole group per virtual-time call, the scalar
    engine runs the event-driven ``FabricSim`` per point (bit-identical by
    construction — the equivalence suite pins this).
    """
    from ..fabric.arrivals import TraceReplay
    from ..fabric.dispatch import FabricSim
    from ..fabric.vtime import VirtualTimeFabric

    rng = np.random.default_rng(fabric.seed)
    gaps = rng.exponential(1.0, size=fabric.n_requests)
    rates = fabric.load_frac * np.asarray(ips, dtype=np.float64) / CLOCK_HZ
    procs = [TraceReplay(np.cumsum(gaps) / r) for r in rates]
    qs = (50.0, 95.0, 99.0)
    if engine == "batch":
        # cached like _SIMULATOR_CACHE so repeated sweeps over the same
        # (network, array, profile) group reuse the compiled kernels
        if cache_key is not None and cache_key in _VT_CACHE:
            get_telemetry().count("dse.vt.hit")
            vt = _VT_CACHE[cache_key]
        else:
            get_telemetry().count("dse.vt.miss")
            vt = VirtualTimeFabric(spec, prof)
            if cache_key is not None:
                _VT_CACHE[cache_key] = vt
        res = vt.run_batch(allocs, procs, seed=fabric.seed, percentiles=qs)
        # percentiles recomputed in numpy from the bit-exact latencies so the
        # batch and scalar sweep columns agree to the last bit
        return np.percentile(res.latencies, qs, axis=1).T
    out = np.zeros((len(allocs), 3))
    for k, (a, pr) in enumerate(zip(allocs, procs)):
        r = FabricSim(spec, prof, a, seed=fabric.seed).run(pr)
        out[k] = np.percentile(r.latencies, qs)
    return out
