"""Pareto-frontier extraction over swept design points.

The co-design question the sweep answers is three-way: how many arrays you
must build (cost), the throughput you get, and how busy the arrays stay
(paper Figs 8 + 9).  A design point is on the frontier iff no other point is
at least as good on every objective and strictly better on one.
"""

from __future__ import annotations

import numpy as np

from .sweep import SweepResult

__all__ = [
    "pareto_mask",
    "pareto_frontier",
    "DEFAULT_OBJECTIVES",
    "FAULT_OBJECTIVES",
    "LATENCY_OBJECTIVES",
    "MULTICHIP_OBJECTIVES",
]

# (column, maximize?) — fewer arrays is better, more img/s and util are better
DEFAULT_OBJECTIVES = (
    ("arrays_total", False),
    ("images_per_sec", True),
    ("mean_utilization", True),
)

# serving-oriented frontier: what you serve (throughput), what users feel
# (tail latency at the design's operating load — requires a sweep run with
# ``FabricEval``), and how busy the arrays you built stay
LATENCY_OBJECTIVES = (
    ("images_per_sec", True),
    ("p99_cycles", False),
    ("mean_utilization", True),
)

# scale-out frontier over ``run_multichip_sweep`` results: what you serve,
# what users feel with inter-chip transfers on the critical path, and how
# many chips you must package/interconnect (fewer is cheaper)
MULTICHIP_OBJECTIVES = (
    ("images_per_sec", True),
    ("p99_cycles", False),
    ("n_chips", False),
)

# fault-tolerance frontier over ``run_fault_sweep`` results: capacity that
# stays serviceable through failures (spares buy it), the tail users feel
# while degraded, and the arrays you must build (spares cost them) — the
# spare-fraction x failure-rate trade of the robustness PR
FAULT_OBJECTIVES = (
    ("availability", True),
    ("p99_cycles", False),
    ("arrays_total", False),
)


def pareto_mask(values: np.ndarray, maximize) -> np.ndarray:
    """(n, k) objective matrix -> (n,) bool mask of non-dominated points.

    ``maximize`` is a length-k sequence of bools; minimized objectives are
    sign-flipped.  Duplicate points are all kept (neither strictly
    dominates).  O(n^2 k) via broadcasting — fine for sweep-sized n.
    """
    v = np.asarray(values, dtype=np.float64)
    if v.ndim != 2:
        raise ValueError(f"expected (n, k) objectives, got shape {v.shape}")
    maximize = np.asarray(maximize, dtype=bool)
    if maximize.shape != (v.shape[1],):
        raise ValueError(f"maximize has {maximize.shape}, objectives k={v.shape[1]}")
    v = np.where(maximize[None, :], v, -v)
    # q dominates p: q >= p everywhere, q > p somewhere
    ge = (v[None, :, :] >= v[:, None, :]).all(axis=2)  # [p, q]
    gt = (v[None, :, :] > v[:, None, :]).any(axis=2)
    dominated = (ge & gt).any(axis=1)
    return ~dominated


def pareto_frontier(
    result: SweepResult, objectives=DEFAULT_OBJECTIVES
) -> np.ndarray:
    """Indices of frontier points, sorted by the first objective.

    Duck-typed on ``result.objectives(names)`` — works for ``SweepResult``
    and ``ChipSweepResult`` alike (pass ``MULTICHIP_OBJECTIVES`` for the
    latter's throughput/p99/chips frontier)."""
    names = tuple(n for n, _ in objectives)
    maximize = [m for _, m in objectives]
    vals = result.objectives(names)
    idx = np.flatnonzero(pareto_mask(vals, maximize))
    first = vals[idx, 0]
    order = np.argsort(-first if objectives[0][1] else first, kind="stable")
    return idx[order]
