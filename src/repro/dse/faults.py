"""Fault-tolerance design sweep: spare fraction x failure rate.

The robustness counterpart of ``dse.sweep``: every point provisions a
design with part of its free arrays held back as hot spares
(``allocate(free_budget=free - reserve)`` — the spares never serve healthy
traffic), replays one seeded failure trace against it on the segmented
vtime engine (``fabric.failures.degrade_plan`` → ``fleet.run_trace_
segments``), and reports the three objectives the ``FAULT_OBJECTIVES``
frontier ranks: availability (capacity that stayed serviceable), p99 under
failure, and total arrays built.  More spares cost throughput up front and
buy availability when arrays die — the sweep makes the exchange rate a
measured curve instead of a guess.

Traces share one normalized arrival-gap sequence across points (common
random numbers, as in ``dse.sweep._fabric_eval``), and failure traces share
the sweep seed, so differences across points are spare/rate effects.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from ..core.cim.cost import ArrayConfig, DEFAULT_ARRAY
from ..core.cim.simulate import ARRAYS_PER_PE, CLOCK_HZ, allocate, simulate
from ..fabric.drift import DriftConfig
from ..fabric.failures import degrade_plan, generate_failure_trace
from ..fabric.fleet import run_trace_segments
from ..fabric.telemetry import get_telemetry
from .sweep import _spec_for, get_profiled

__all__ = ["FaultPoint", "FaultSweepResult", "fault_grid", "run_fault_sweep"]


@dataclass(frozen=True)
class FaultPoint:
    """One fault-tolerance design point: how many arrays to hold back as
    spares (``spare_fraction`` of the free budget) against a per-array
    hazard of ``rate_per_array`` failures per cycle."""

    network: str
    spare_fraction: float
    rate_per_array: float
    n_pes: int
    policy: str = "blockwise"
    repair_cycles: float | None = None
    array: ArrayConfig = DEFAULT_ARRAY


@dataclass
class FaultSweepResult:
    """Columnar fault-sweep outcome; row i <-> ``points[i]``.

    ``objectives``-compatible with ``pareto_frontier`` — pass
    ``FAULT_OBJECTIVES`` for the (availability, p99, arrays) frontier.
    """

    points: list[FaultPoint]
    availability: np.ndarray  # (C,) in [0, 1]
    p50_cycles: np.ndarray
    p99_cycles: np.ndarray
    arrays_used: np.ndarray
    arrays_total: np.ndarray
    spare_arrays: np.ndarray  # (C,) reserve held back per point
    n_killed: np.ndarray
    n_repaired: np.ndarray
    total_stall_cycles: np.ndarray
    elapsed_s: float

    def __len__(self) -> int:
        return len(self.points)

    def objectives(self, names: tuple[str, ...]) -> np.ndarray:
        cols = {
            "spare_fraction": np.asarray(
                [p.spare_fraction for p in self.points], dtype=np.float64
            ),
            "rate_per_array": np.asarray(
                [p.rate_per_array for p in self.points], dtype=np.float64
            ),
        }
        out = []
        for n in names:
            v = cols.get(n)
            if v is None:
                v = np.asarray(getattr(self, n), dtype=np.float64)
            out.append(v)
        return np.stack(out, axis=1)

    def rows(self) -> list[dict]:
        out = []
        for i, p in enumerate(self.points):
            out.append(
                {
                    "network": p.network,
                    "policy": p.policy,
                    "n_pes": p.n_pes,
                    "spare_fraction": float(p.spare_fraction),
                    "rate_per_array": float(p.rate_per_array),
                    "repair_cycles": p.repair_cycles,
                    "availability": float(self.availability[i]),
                    "p50_ms": float(self.p50_cycles[i] / CLOCK_HZ * 1e3),
                    "p99_ms": float(self.p99_cycles[i] / CLOCK_HZ * 1e3),
                    "arrays_used": int(self.arrays_used[i]),
                    "arrays_total": int(self.arrays_total[i]),
                    "spare_arrays": int(self.spare_arrays[i]),
                    "n_killed": int(self.n_killed[i]),
                    "n_repaired": int(self.n_repaired[i]),
                    "total_stall_cycles": float(self.total_stall_cycles[i]),
                }
            )
        return out


def fault_grid(
    networks=("vgg11",),
    spare_fractions=(0.0, 0.1, 0.25),
    rates=(1e-9, 1e-8),
    policy: str = "blockwise",
    pe_multiplier: float = 2.0,
    repair_cycles: float | None = None,
    arrays_per_pe: int = ARRAYS_PER_PE,
    arrays=(DEFAULT_ARRAY,),
) -> list[FaultPoint]:
    """spare-fraction x failure-rate grid at a fixed silicon budget per
    network (``pe_multiplier`` times the minimum design)."""
    points = []
    for net in networks:
        for arr in arrays:
            spec = _spec_for(net, arr)
            n_pes = max(
                spec.min_pes(arrays_per_pe),
                int(np.ceil(spec.min_pes(arrays_per_pe) * pe_multiplier)),
            )
            for sf in spare_fractions:
                for rate in rates:
                    points.append(
                        FaultPoint(
                            net, float(sf), float(rate), n_pes, policy,
                            repair_cycles, arr,
                        )
                    )
    return points


def run_fault_sweep(
    points: list[FaultPoint],
    *,
    n_requests: int = 200,
    load_frac: float = 0.6,
    seed: int = 0,
    drift: DriftConfig = DriftConfig(),
    weibull_shape: float = 1.0,
    chip_burst_rate: float = 0.0,
    burst_kill_frac: float = 0.5,
    topology=None,
    min_survivors: int = 1,
    profile_images: int = 1,
    sample_patches: int = 128,
    arrays_per_pe: int = ARRAYS_PER_PE,
    engine: str = "jax",
) -> FaultSweepResult:
    """Replay one seeded failure trace against every design point.

    Per point: hold back ``floor(free * spare_fraction)`` arrays from the
    allocator (they idle as hot spares), offer Poisson traffic at
    ``load_frac`` of the degraded design's analytic throughput over a
    horizon set by the trace itself, generate the point's failure trace over
    that horizon, compile it to a ``DegradePlan`` (spares re-place lost
    replicas, reprogramming charges ``drift`` stalls), and replay on the
    streaming segmented vtime engine.  Availability comes from the plan
    (deterministic — it needs no simulation), the percentiles from the
    replayed sketches.
    """
    from ..fabric.vtime import VirtualTimeFabric

    C = len(points)
    avail = np.zeros(C)
    pcts = np.zeros((C, 2))
    used = np.zeros(C, dtype=np.int64)
    total = np.zeros(C, dtype=np.int64)
    spares = np.zeros(C, dtype=np.int64)
    killed = np.zeros(C, dtype=np.int64)
    repaired = np.zeros(C, dtype=np.int64)
    stalls = np.zeros(C)

    prof_kw = dict(
        profile_images=profile_images, sample_patches=sample_patches, seed=seed
    )
    gaps = np.random.default_rng(seed).exponential(1.0, size=n_requests)
    tel = get_telemetry()
    tel.gauge("dse.faults.points", C)
    elapsed = 0.0
    vts: dict[tuple, VirtualTimeFabric] = {}
    for i, p in enumerate(points):
        spec, prof = get_profiled(p.network, p.array, **prof_kw)
        free = p.n_pes * arrays_per_pe - spec.n_arrays
        if free < 0:
            raise ValueError(
                f"point {i}: {p.n_pes} PEs cannot hold {p.network}"
            )
        reserve = int(free * p.spare_fraction)
        alloc = allocate(
            spec, prof, p.policy, p.n_pes, arrays_per_pe,
            free_budget=free - reserve,
        )
        cap = simulate(spec, prof, alloc).images_per_sec
        rate = load_frac * cap / CLOCK_HZ
        times = np.cumsum(gaps) / rate
        horizon = float(times[-1])
        t0 = time.perf_counter()
        trace = generate_failure_trace(
            spec, alloc,
            horizon=horizon, seed=seed,
            rate_per_array=p.rate_per_array,
            weibull_shape=weibull_shape,
            repair_cycles=p.repair_cycles,
            topology=topology,
            chip_burst_rate=chip_burst_rate,
            burst_kill_frac=burst_kill_frac,
            min_survivors=min_survivors,
        )
        plan = degrade_plan(
            spec, prof, alloc, trace,
            spare_arrays=reserve, drift=drift, min_survivors=min_survivors,
        )
        key = (p.network, p.array)
        if key not in vts:
            vts[key] = VirtualTimeFabric(spec, prof)
        res = run_trace_segments(
            vts[key], list(plan.allocs), times, plan.boundaries,
            drift=drift, seed=seed, engine=engine, stream=True,
            percentiles=(50.0, 99.0),
        )
        elapsed += time.perf_counter() - t0
        avail[i] = plan.availability()
        pcts[i] = res.percentiles[0]
        used[i] = alloc.arrays_used
        total[i] = alloc.arrays_total
        spares[i] = reserve
        killed[i] = plan.n_killed
        repaired[i] = plan.n_repaired
        stalls[i] = plan.total_stall_cycles
        tel.gauge("dse.faults.points_done", i + 1)

    return FaultSweepResult(
        points=list(points),
        availability=avail,
        p50_cycles=pcts[:, 0],
        p99_cycles=pcts[:, 1],
        arrays_used=used,
        arrays_total=total,
        spare_arrays=spares,
        n_killed=killed,
        n_repaired=repaired,
        total_stall_cycles=stalls,
        elapsed_s=elapsed,
    )
