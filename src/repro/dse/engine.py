"""Batched (allocate, simulate) evaluation — the DSE inner loop.

``allocate_batch`` mirrors ``core.cim.simulate.allocate`` policy-for-policy
but runs every config of a sweep at once: the proportional policies reuse the
scalar largest-remainder routine (cheap, exact), while the greedy policies —
the paper's actual algorithm and the sweep hot path — go through the
lock-step ``greedy_allocate_batch``.  Replica vectors are element-wise
identical to the scalar allocator; the golden-equivalence suite pins this.

``run_batch`` chains it into ``BatchSimulator`` (vmapped float64 kernel) so a
(policy, PE-count) sweep over one profiled network is two jit calls.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.alloc.greedy import greedy_allocate_batch, proportional_allocate_batch
from ..core.cim.network import NetworkSpec
from ..core.cim.profile import NetworkProfile
from ..core.cim.simulate import (
    ALL_POLICIES,
    ARRAYS_PER_PE,
    CLOCK_HZ,
    Allocation,
    BatchSimResult,
    BatchSimulator,
    _layer_patch_cycles,
    allocate,
    blockwise_units,
)

__all__ = [
    "AllocationBatch",
    "allocate_batch",
    "flat_unit_map",
    "run_batch",
    "to_allocation",
]

_PROPORTIONAL = ("baseline", "weight_based", "weight_blockflow")
_LAYERWISE_FLOW = ("baseline", "weight_based", "perf_layerwise")


def flat_unit_map(
    L: int,
    B: int,
    l_idx: np.ndarray | None = None,
    blk_idx: np.ndarray | None = None,
) -> np.ndarray:
    """One-hot (N, L, B) map from a flat allocation-unit axis to the dense
    replica tensor — the shared representation of BOTH greedy families.

    ``l_idx is None`` builds the per-LAYER family (perf_layerwise and the
    proportional policies): N = L units, each broadcasting its replicas
    across every block column of its layer.  With ``l_idx``/``blk_idx``
    (from ``NetworkSpec.block_table``) it builds the per-BLOCK family
    (blockwise): each unit owns exactly its (layer, block) cell.  Replica
    scatters become the exact matmul ``dups = 1 + (r - 1) @ map`` (one
    nonzero * 1.0 per cell), which is how the Pallas fused allocate+eval
    kernel (``kernels.fused_alloc_eval``) keeps both families in one
    kernel body.
    """
    if l_idx is None:
        u = np.zeros((L, L, B))
        u[np.arange(L), np.arange(L), :] = 1.0
        return u
    l_idx = np.asarray(l_idx, dtype=np.int64)
    blk_idx = np.asarray(blk_idx, dtype=np.int64)
    u = np.zeros((l_idx.size, L, B))
    u[np.arange(l_idx.size), l_idx, blk_idx] = 1.0
    return u


@dataclass(frozen=True)
class AllocationBatch:
    """Structure-of-arrays ``Allocation`` for C configs on one network."""

    policies: np.ndarray  # (C,) str
    n_pes: np.ndarray  # (C,)
    dups_lb: np.ndarray  # (C, L, Bmax) float replicas (padded blocks = 1)
    layerwise: np.ndarray  # (C,) bool — barrier dataflow
    zskip: np.ndarray  # (C,) bool
    arrays_used: np.ndarray  # (C,) int64
    arrays_total: np.ndarray  # (C,) int64

    def __len__(self) -> int:
        return self.policies.shape[0]


def allocate_batch(
    spec: NetworkSpec,
    prof: NetworkProfile,
    policies,
    n_pes,
    arrays_per_pe: int = ARRAYS_PER_PE,
    latency_load_frac: float = 0.7,
) -> AllocationBatch:
    """Batched ``allocate``: one call for a whole (policy, PE-count) sweep.

    ``latency_aware`` points are supported but allocate through the scalar
    path per config (the queueing greedy is load-dependent and not
    lock-steppable); their offered load is ``latency_load_frac`` times the
    scalar blockwise throughput at the same budget, matching the scalar
    ``allocate`` default."""
    policies = np.atleast_1d(np.asarray(policies, dtype=object))
    n_pes = np.atleast_1d(np.asarray(n_pes, dtype=np.int64))
    policies, n_pes = np.broadcast_arrays(policies, n_pes)
    unknown = sorted({p for p in policies if p not in ALL_POLICIES})
    if unknown:
        raise ValueError(f"unknown policies {unknown}; choose from {ALL_POLICIES}")
    C = policies.shape[0]
    total = n_pes * arrays_per_pe
    base_arrays = spec.n_arrays
    if np.any(total < base_arrays):
        worst = int(total.min())
        raise ValueError(f"{worst} arrays < minimum {base_arrays} for {spec.name}")
    free = (total - base_arrays).astype(np.float64)

    L = len(spec.layers)
    B = max(l.n_blocks for l in spec.layers)
    layer_arrays = np.array([l.n_arrays for l in spec.layers], dtype=np.float64)
    ppi = np.array([l.patches_per_image for l in spec.layers], dtype=np.float64)
    cyc = _layer_patch_cycles(prof, True)

    dups_lb = np.ones((C, L, B))
    used = np.zeros(C, dtype=np.int64)

    prop = np.isin(policies, _PROPORTIONAL)
    if prop.any():
        macs = np.array([l.macs_per_image for l in spec.layers], dtype=np.float64)
        res = proportional_allocate_batch(macs, layer_arrays, free[prop])
        dups_lb[prop] = res.replicas[:, :, None].astype(np.float64)
        used[prop] = base_arrays + ((res.replicas - 1) @ layer_arrays).astype(np.int64)

    perf = policies == "perf_layerwise"
    if perf.any():
        exp_lat = np.array([cyc[i].max(axis=1).mean() * ppi[i] for i in range(L)])
        res = greedy_allocate_batch(exp_lat, layer_arrays, free[perf])
        dups_lb[perf] = res.replicas[:, :, None].astype(np.float64)
        used[perf] = base_arrays + ((res.replicas - 1) @ layer_arrays).astype(np.int64)

    block = policies == "blockwise"
    if block.any():
        base_lat, cost = blockwise_units(spec, [cyc[i].mean(axis=0) for i in range(L)])
        res = greedy_allocate_batch(base_lat, cost, free[block])
        table = spec.block_table()  # (n_blocks, 3): layer, block-in-layer, width
        rows = np.flatnonzero(block)
        dups_lb[rows[:, None], table[None, :, 0], table[None, :, 1]] = res.replicas
        used[block] = base_arrays + ((res.replicas - 1) * cost).sum(axis=1).astype(
            np.int64
        )

    for i in np.flatnonzero(policies == "latency_aware"):
        a = allocate(
            spec, prof, "latency_aware", int(n_pes[i]), arrays_per_pe,
            load_frac=latency_load_frac,
        )
        for li, d in enumerate(a.block_dups):
            dups_lb[i, li, : d.size] = d.astype(np.float64)
        used[i] = a.arrays_used

    return AllocationBatch(
        policies=policies.astype(str),
        n_pes=n_pes.copy(),
        dups_lb=dups_lb,
        layerwise=np.isin(policies, _LAYERWISE_FLOW),
        zskip=policies != "baseline",
        arrays_used=used,
        arrays_total=total,
    )


def to_allocation(batch: AllocationBatch, i: int, spec: NetworkSpec) -> Allocation:
    """Extract config ``i`` as a scalar ``Allocation`` (fabric-runtime handoff)."""
    policy = str(batch.policies[i])
    used = int(batch.arrays_used[i])
    total = int(batch.arrays_total[i])
    if policy in _LAYERWISE_FLOW:
        dups = batch.dups_lb[i, :, 0].astype(np.int64)
        return Allocation(policy, dups, None, used, total)
    block_dups = [
        batch.dups_lb[i, li, : l.n_blocks].astype(np.int64)
        for li, l in enumerate(spec.layers)
    ]
    return Allocation(policy, None, block_dups, used, total)


def run_batch(
    spec: NetworkSpec,
    prof: NetworkProfile,
    policies,
    n_pes,
    *,
    n_images: int = 64,
    clock_hz: float = CLOCK_HZ,
    arrays_per_pe: int = ARRAYS_PER_PE,
    simulator: BatchSimulator | None = None,
    latency_load_frac: float = 0.7,
) -> tuple[AllocationBatch, BatchSimResult]:
    """allocate_batch + BatchSimulator in one call."""
    alloc = allocate_batch(
        spec, prof, policies, n_pes, arrays_per_pe, latency_load_frac
    )
    sim = simulator if simulator is not None else BatchSimulator(spec, prof)
    res = sim(alloc.dups_lb, alloc.layerwise, alloc.zskip, n_images, clock_hz)
    return alloc, res
