"""Fault-tolerant training runner.

Production behaviours, exercised at CPU scale by the tests:
  * checkpoint every `ckpt_every` steps; on ANY step failure, restore the
    latest checkpoint and replay (the data pipeline is deterministic in
    step, so replay is bit-exact),
  * bounded retries per step, then re-raise (a real launcher would reschedule
    the job on fresh hosts),
  * straggler detection: per-step wall times feed an EWMA; steps slower than
    `straggler_factor` x the EWMA fire a callback (at scale: trigger
    re-sharding away from the slow host / enable backup executors — here:
    recorded so tests and EXPERIMENTS can assert on it).  This is the
    paper's core observation applied to the training loop: synchronized SPMD
    steps run at the speed of the slowest participant, so the scheduler must
    watch for and route around slow units,
  * elastic re-mesh: `restore into a different mesh` is just restore +
    re-jit; covered in tests/test_fault_tolerance.py.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

from ..checkpoint.store import latest_step, restore_checkpoint, save_checkpoint

__all__ = ["RunnerConfig", "StepStats", "TrainRunner", "FaultInjector"]


@dataclass
class RunnerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep_last: int = 3
    max_retries_per_step: int = 3
    straggler_factor: float = 2.5
    ewma_alpha: float = 0.2


@dataclass
class StepStats:
    step: int
    seconds: float
    retried: int
    straggler: bool
    metrics: dict = field(default_factory=dict)


class FaultInjector:
    """Deterministic failure schedule for tests: raises on listed steps
    (once each)."""

    def __init__(
        self,
        fail_at: dict[int, int] | None = None,
        slow_at: dict[int, float] | None = None,
        sleep: Callable[[float], None] | None = None,
    ):
        self.fail_budget = dict(fail_at or {})
        self.slow_at = dict(slow_at or {})
        self._sleep = sleep

    def __call__(self, step: int) -> None:
        if self.slow_at.get(step):
            # default late-bound so tests may monkeypatch time.sleep; a fake
            # clock's `advance` can be injected instead for determinism
            (self._sleep or time.sleep)(self.slow_at[step])
        if self.fail_budget.get(step, 0) > 0:
            self.fail_budget[step] -= 1
            raise RuntimeError(f"injected failure at step {step}")

    @classmethod
    def from_trace(
        cls,
        trace,
        cycles_per_step: float,
        *,
        slow_at: dict[int, float] | None = None,
        sleep: Callable[[float], None] | None = None,
    ) -> "FaultInjector":
        """Drive the training-side injector from a fabric failure trace.

        ``trace`` is a ``fabric.failures.FailureTrace``; each array failure
        lands on training step ``floor(time / cycles_per_step)``, so the
        training runner and the fabric engines exercise one seeded failure
        schedule (the shared-generator contract of the fault-tolerance PR).
        """
        # local import: runtime stays importable without the fabric package
        from ..fabric.failures import failure_step_schedule

        return cls(
            fail_at=failure_step_schedule(trace, cycles_per_step),
            slow_at=slow_at,
            sleep=sleep,
        )


class TrainRunner:
    def __init__(
        self,
        cfg: RunnerConfig,
        step_fn: Callable[[Any, Any, dict], tuple[Any, Any, dict]],
        batch_fn: Callable[[int], dict],
        *,
        fingerprint: str = "",
        on_straggler: Callable[[StepStats], None] | None = None,
        fault_hook: Callable[[int], None] | None = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.cfg = cfg
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.fingerprint = fingerprint
        self.on_straggler = on_straggler
        self.fault_hook = fault_hook
        self.clock = clock
        self.history: list[StepStats] = []
        self.restores = 0
        self._ewma: float | None = None
        self._settled = 0  # steps already folded into the EWMA

    # ------------------------------------------------------------- lifecycle
    def _save(self, step, params, opt_state):
        save_checkpoint(
            self.cfg.ckpt_dir,
            step,
            {"params": params, "opt": opt_state},
            config_fingerprint=self.fingerprint,
            keep_last=self.cfg.keep_last,
        )

    def _restore(self, params_like, opt_like):
        step = latest_step(self.cfg.ckpt_dir)
        if step is None:
            return 0, None
        tree, _ = restore_checkpoint(
            self.cfg.ckpt_dir,
            {"params": params_like, "opt": opt_like},
            config_fingerprint=self.fingerprint,
        )
        return step, tree

    # ------------------------------------------------------------------ run
    def run(self, params, opt_state, n_steps: int, start_step: int = 0):
        """Run to `n_steps`, surviving injected/real step failures."""
        step = start_step
        while step < n_steps:
            retries = 0
            while True:
                t0 = self.clock()
                try:
                    if self.fault_hook:
                        self.fault_hook(step)
                    batch = self.batch_fn(step)
                    params, opt_state, metrics = self.step_fn(params, opt_state, batch)
                    metrics = {
                        k: float(v) for k, v in metrics.items()
                    }
                    break
                except Exception:
                    retries += 1
                    if retries > self.cfg.max_retries_per_step:
                        raise
                    # restore-and-replay from last checkpoint
                    restored_step, tree = self._restore(params, opt_state)
                    self.restores += 1
                    if tree is not None:
                        params, opt_state = tree["params"], tree["opt"]
                        step = restored_step
            dt = self.clock() - t0
            # warm-up guard: the EWMA is meaningless until at least two steps
            # have settled into it, so no straggler verdicts before then
            straggler = (
                self._settled >= 2
                and self._ewma is not None
                and dt > self.cfg.straggler_factor * self._ewma
            )
            self._ewma = (
                dt
                if self._ewma is None
                else (1 - self.cfg.ewma_alpha) * self._ewma + self.cfg.ewma_alpha * dt
            )
            self._settled += 1
            stats = StepStats(step, dt, retries, straggler, metrics)
            self.history.append(stats)
            if straggler and self.on_straggler:
                self.on_straggler(stats)
            step += 1
            if step % self.cfg.ckpt_every == 0 or step == n_steps:
                self._save(step, params, opt_state)
        return params, opt_state
