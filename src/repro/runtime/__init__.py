from .fault import FaultInjector, RunnerConfig, StepStats, TrainRunner
__all__ = ["FaultInjector", "RunnerConfig", "StepStats", "TrainRunner"]
