"""Grok-1 314B [hf:xai-org/grok-1]: MoE, 8 experts top-2, GQA(kv=8)."""

from ..models.config import AttnConfig, ModelConfig, MoEConfig

FULL = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    d_ff=32768,
    vocab=131_072,
    attn=AttnConfig(kind="gqa", n_heads=48, n_kv_heads=8, head_dim=128),
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=0, d_ff_expert=32768),
    activation="gelu_glu",
)

SMOKE = ModelConfig(
    name="grok-1-314b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    d_ff=128,
    vocab=512,
    attn=AttnConfig(kind="gqa", n_heads=4, n_kv_heads=2, head_dim=16),
    moe=MoEConfig(n_experts=4, top_k=2, n_shared=0, d_ff_expert=64),
    activation="gelu_glu",
    remat="none",
)
