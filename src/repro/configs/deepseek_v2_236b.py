"""DeepSeek-V2 236B [arXiv:2405.04434]: MLA (kv_lora=512) + MoE 160e top-6,
2 shared experts.  Primary showcase for the paper's block-wise (expert)
replication technique."""

from ..models.config import AttnConfig, ModelConfig, MoEConfig

FULL = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    d_ff=12288,  # dense-equivalent (unused: all layers MoE here)
    vocab=102_400,
    attn=AttnConfig(
        kind="mla",
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_rope_dim=64,
        qk_nope_dim=128,
        v_head_dim=128,
    ),
    moe=MoEConfig(n_experts=160, top_k=6, n_shared=2, d_ff_expert=1536),
    activation="silu_glu",
)

SMOKE = ModelConfig(
    name="deepseek-v2-236b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    d_ff=128,
    vocab=512,
    attn=AttnConfig(
        kind="mla",
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        kv_lora_rank=32,
        q_lora_rank=48,
        qk_rope_dim=8,
        qk_nope_dim=16,
        v_head_dim=16,
    ),
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_ff_expert=32),
    activation="silu_glu",
    remat="none",
)
