"""Mamba2 370M [arXiv:2405.21060]: attention-free SSD state-space model."""

from ..models.config import AttnConfig, ModelConfig, SSMConfig

FULL = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    d_ff=0,
    vocab=50_280,
    attn=AttnConfig(kind="none"),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=128),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-370m-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    d_ff=0,
    vocab=512,
    attn=AttnConfig(kind="none"),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=32),
    tie_embeddings=True,
    remat="none",
)
