"""Architecture registry: ``--arch <id>`` resolves here.

Each module defines ``FULL`` (the exact published config) and ``SMOKE`` (a
reduced same-family config for CPU tests).  The CIM workloads of the paper
itself (ResNet18 / VGG11) live in ``cim_resnet18.py`` / ``cim_vgg11.py``.
"""

from __future__ import annotations

import importlib

from ..models.config import ModelConfig

ARCH_IDS = (
    "nemotron-4-15b",
    "glm4-9b",
    "qwen1.5-110b",
    "qwen2.5-32b",
    "mamba2-370m",
    "deepseek-v2-236b",
    "grok-1-314b",
    "qwen2-vl-2b",
    "whisper-medium",
    "zamba2-1.2b",
)

SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

SHAPE_SPECS = {
    "train_4k": dict(seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524_288, global_batch=1, kind="decode"),
}


def _module(arch: str):
    return importlib.import_module(f".{arch.replace('-', '_').replace('.', '_')}", __package__)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCH_IDS}")
    mod = _module(arch)
    return mod.SMOKE if smoke else mod.FULL


def cell_is_defined(arch: str, shape: str) -> tuple[bool, str]:
    """Whether a (arch, shape) dry-run cell runs, and the skip reason if not."""
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "full quadratic attention at 524k tokens — skipped per brief (sub-quadratic archs only)"
    return True, ""
