"""Nemotron-4 15B [arXiv:2402.16819]: dense, GQA(kv=8), squared-ReLU MLP."""

from ..models.config import AttnConfig, ModelConfig

FULL = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    d_ff=24576,
    vocab=256_000,
    attn=AttnConfig(kind="gqa", n_heads=48, n_kv_heads=8, head_dim=128, rope_theta=10_000.0),
    activation="sq_relu",
)

SMOKE = ModelConfig(
    name="nemotron-4-15b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    d_ff=256,
    vocab=512,
    attn=AttnConfig(kind="gqa", n_heads=4, n_kv_heads=2, head_dim=16),
    activation="sq_relu",
    remat="none",
)
