"""Qwen2.5 32B [hf:Qwen family]: dense, GQA(kv=8), QKV bias."""

from ..models.config import AttnConfig, ModelConfig

FULL = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    d_ff=27648,
    vocab=152_064,
    attn=AttnConfig(
        kind="gqa", n_heads=40, n_kv_heads=8, head_dim=128, qkv_bias=True,
        rope_theta=1_000_000.0,
    ),
    activation="silu_glu",
)

SMOKE = ModelConfig(
    name="qwen2.5-32b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    d_ff=160,
    vocab=512,
    attn=AttnConfig(kind="gqa", n_heads=4, n_kv_heads=2, head_dim=16, qkv_bias=True),
    activation="silu_glu",
    remat="none",
)
