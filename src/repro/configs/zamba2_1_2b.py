"""Zamba2 1.2B [arXiv:2411.15242]: Mamba2 backbone + shared attention block.

One transformer block (attention + MLP) with SHARED weights is applied after
every `shared_every` Mamba2 layers — the paper's block duplication idea in
reverse: one weight block serving many layer positions (each application
site keeps its own KV cache)."""

from ..models.config import AttnConfig, ModelConfig, SSMConfig

FULL = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    d_ff=8192,
    vocab=32_000,
    attn=AttnConfig(kind="gqa", n_heads=32, n_kv_heads=32, head_dim=64),
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
    shared_every=6,
    activation="gelu_glu",
)

SMOKE = ModelConfig(
    name="zamba2-1.2b-smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    d_ff=128,
    vocab=512,
    attn=AttnConfig(kind="gqa", n_heads=4, n_kv_heads=4, head_dim=16),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16),
    shared_every=2,
    activation="gelu_glu",
    remat="none",
)
