"""Whisper medium [arXiv:2212.04356]: enc-dec transformer backbone.

The mel-spectrogram conv frontend is a STUB per the assignment:
`input_specs` provides precomputed frame embeddings (b, 1500, d_model)."""

from ..models.config import AttnConfig, ModelConfig

FULL = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,  # decoder layers
    n_encoder_layers=24,
    encoder_seq=1500,
    d_model=1024,
    d_ff=4096,
    vocab=51_865,
    attn=AttnConfig(kind="gqa", n_heads=16, n_kv_heads=16, head_dim=64),
    activation="gelu",
    frontend="audio_stub",
)

SMOKE = ModelConfig(
    name="whisper-medium-smoke",
    family="encdec",
    n_layers=2,
    n_encoder_layers=2,
    encoder_seq=32,
    d_model=64,
    d_ff=128,
    vocab=512,
    attn=AttnConfig(kind="gqa", n_heads=4, n_kv_heads=4, head_dim=16),
    activation="gelu",
    frontend="audio_stub",
    remat="none",
)
