"""Qwen2-VL 2B [arXiv:2409.12191]: dense VLM backbone with M-RoPE.

The vision frontend (dynamic-resolution patch embed) is a STUB per the
assignment: the backbone consumes token ids; `input_specs` can also provide
precomputed patch embeddings."""

from ..models.config import AttnConfig, ModelConfig

FULL = ModelConfig(
    name="qwen2-vl-2b",
    family="dense",
    n_layers=28,
    d_model=1536,
    d_ff=8960,
    vocab=151_936,
    attn=AttnConfig(
        kind="gqa",
        n_heads=12,
        n_kv_heads=2,
        head_dim=128,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),  # (t, h, w) frequency bands; sums to hd/2
    ),
    activation="silu_glu",
    frontend="vision_stub",
)

SMOKE = ModelConfig(
    name="qwen2-vl-2b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    d_ff=128,
    vocab=512,
    attn=AttnConfig(
        kind="gqa", n_heads=4, n_kv_heads=2, head_dim=16, qkv_bias=True,
        mrope_sections=(2, 3, 3),
    ),
    activation="silu_glu",
    frontend="vision_stub",
    remat="none",
)
