"""GLM-4 9B [hf:THUDM/glm-4-9b]: dense, RoPE, GQA(kv=2)."""

from ..models.config import AttnConfig, ModelConfig

FULL = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    d_ff=13696,
    vocab=151_552,
    attn=AttnConfig(kind="gqa", n_heads=32, n_kv_heads=2, head_dim=128, rope_theta=10_000.0),
    activation="silu_glu",
)

SMOKE = ModelConfig(
    name="glm4-9b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    d_ff=128,
    vocab=512,
    attn=AttnConfig(kind="gqa", n_heads=4, n_kv_heads=2, head_dim=16),
    activation="silu_glu",
    remat="none",
)
