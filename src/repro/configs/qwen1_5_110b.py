"""Qwen1.5 110B [hf:Qwen family]: dense, GQA(kv=8), QKV bias."""

from ..models.config import AttnConfig, ModelConfig

FULL = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    d_ff=49152,
    vocab=152_064,
    attn=AttnConfig(
        kind="gqa", n_heads=64, n_kv_heads=8, head_dim=128, qkv_bias=True,
        rope_theta=1_000_000.0,
    ),
    activation="silu_glu",
)

SMOKE = ModelConfig(
    name="qwen1.5-110b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    d_ff=192,
    vocab=512,
    attn=AttnConfig(kind="gqa", n_heads=4, n_kv_heads=2, head_dim=16, qkv_bias=True),
    activation="silu_glu",
    remat="none",
)
