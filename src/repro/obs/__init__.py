"""Observability exporters for the fabric telemetry layer.

``repro.fabric.telemetry`` records; this package *renders*: Chrome/Perfetto
``trace_event`` timelines from an instrumented event-engine run
(``trace``), the paper's Fig-9-style utilization analysis as a standard
table (``report``), and the allocator's decision log (``audit``).  Nothing
here touches the simulation hot paths — exporters consume the ``stats`` /
``record_starts`` artifacts after the run finished.
"""

from .audit import AllocationAudit, AuditEntry
from .report import UtilizationReport, utilization_report
from .trace import build_trace, validate_trace, write_trace

__all__ = [
    "AllocationAudit",
    "AuditEntry",
    "UtilizationReport",
    "utilization_report",
    "build_trace",
    "validate_trace",
    "write_trace",
]
