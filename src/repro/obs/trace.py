"""Chrome/Perfetto ``trace_event`` export of an instrumented fabric run.

``build_trace`` turns a ``FabricSim(record_timeline=True, stats=True)`` run
into the JSON object format (``{"traceEvents": [...]}``) that
https://ui.perfetto.dev and ``chrome://tracing`` open directly:

  * one track (pid, tid) per replica lane, grouped into one process per
    chip when a ``Placement`` is given (chip -> PE/layer -> array replica —
    the resource tree the allocator placed onto), a single ``fabric``
    process otherwise;
  * a ``requests`` process with one track per request showing its per-stage
    residence spans (entry -> exit, from ``FabricStats``);
  * matched ``B``/``E`` duration events with microsecond timestamps
    (``cycles / clock_hz * 1e6``), plus ``M`` metadata naming every track.

Jobs on one replica lane are sequential (FIFO, dispatched in nondecreasing
time), so spans on a track never nest and abutting jobs can be coalesced
(``merge_gap``) to keep traces small at CIM job counts (~1e5 per image).

``validate_trace`` is the schema smoke used by tests and CI: per-track
monotonic timestamps and strictly matched B/E pairs.
"""

from __future__ import annotations

import json

import numpy as np

__all__ = ["build_trace", "validate_trace", "write_trace"]

_REQUEST_PID = 1_000_000  # process id for the per-request residence tracks


def _lane_chip(placement, layerwise: bool, s: int, b: int, lane: int) -> int:
    """Chip of replica ``lane`` of (stage s, pool b) under ``placement``.

    Lanes grown online (drift) are not in ``replica_chips``; they are
    clipped to the last planned replica's chip (growth draws from the same
    reserve pool, and the trace is a visualization, not an accounting)."""
    rc = placement.replica_chips[s]
    chips = rc if layerwise else rc[b]
    return int(chips[min(lane, len(chips) - 1)])


def _merge_spans(starts: np.ndarray, ends: np.ndarray, gap: float):
    """Coalesce time-sorted [start, end) spans closer than ``gap``."""
    out_s, out_e = [float(starts[0])], [float(ends[0])]
    for a, b in zip(starts[1:], ends[1:]):
        if a - out_e[-1] <= gap:
            if b > out_e[-1]:
                out_e[-1] = float(b)
        else:
            out_s.append(float(a))
            out_e.append(float(b))
    return out_s, out_e


def build_trace(
    sim,
    result,
    *,
    placement=None,
    merge_gap: float = 0.0,
    max_requests: int | None = None,
) -> dict:
    """Build a ``trace_event`` JSON object from an instrumented run.

    ``sim`` must have been constructed with ``record_timeline=True`` for the
    per-array tracks; request tracks additionally need ``stats=True``
    (``result.stats``).  ``merge_gap`` (cycles) coalesces abutting jobs on a
    lane into one span — 0.0 merges only back-to-back jobs, which already
    collapses saturated lanes.  ``max_requests`` caps the request tracks.
    """
    scale = 1e6 / result.clock_hz  # cycles -> microseconds
    meta: list[dict] = []
    events: list[dict] = []
    layerwise = getattr(sim.alloc, "layer_dups", None) is not None

    pids: dict[int, str] = {}

    def ensure_pid(pid: int, name: str):
        if pid not in pids:
            pids[pid] = name
            meta.append(
                {"ph": "M", "name": "process_name", "pid": pid,
                 "args": {"name": name}}
            )

    tid = 0
    for s, st in enumerate(sim.stages):
        for b, pool in enumerate(st.pools):
            if not pool.starts:
                continue
            starts = np.concatenate(pool.starts)
            durs = np.concatenate(pool.durations)
            lanes = np.concatenate(pool.servers)
            ends = starts + durs
            for lane in range(pool.n_servers):
                m = lanes == lane
                if not m.any():
                    continue
                order = np.argsort(starts[m], kind="stable")
                ls, le = _merge_spans(starts[m][order], ends[m][order], merge_gap)
                pid = (
                    0
                    if placement is None
                    else _lane_chip(placement, layerwise, s, b, lane)
                )
                ensure_pid(pid, "fabric" if placement is None else f"chip{pid}")
                tid += 1
                label = f"L{s}/r{lane}" if layerwise else f"L{s}/B{b}/r{lane}"
                meta.append(
                    {"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
                     "args": {"name": label}}
                )
                name = f"L{s}" if layerwise else f"L{s}B{b}"
                for a, e in zip(ls, le):
                    events.append(
                        {"ph": "B", "name": name, "pid": pid, "tid": tid,
                         "ts": a * scale}
                    )
                    events.append(
                        {"ph": "E", "name": name, "pid": pid, "tid": tid,
                         "ts": e * scale}
                    )

    stats = getattr(result, "stats", None)
    if stats is not None:
        n = stats.stage_entry.shape[0]
        if max_requests is not None:
            n = min(n, int(max_requests))
        if n:
            ensure_pid(_REQUEST_PID, "requests")
        for r in range(n):
            rt = _REQUEST_PID + 1 + r
            meta.append(
                {"ph": "M", "name": "thread_name", "pid": _REQUEST_PID,
                 "tid": rt, "args": {"name": f"req{r}"}}
            )
            for s in range(stats.stage_entry.shape[1]):
                events.append(
                    {"ph": "B", "name": f"L{s}", "pid": _REQUEST_PID,
                     "tid": rt, "ts": float(stats.stage_entry[r, s]) * scale}
                )
                events.append(
                    {"ph": "E", "name": f"L{s}", "pid": _REQUEST_PID,
                     "tid": rt, "ts": float(stats.stage_exit[r, s]) * scale}
                )

    # sorted timestamps; at equal ts an E precedes the next B so spans on a
    # track close before the next one opens (they never nest by construction)
    events.sort(key=lambda e: (e["ts"], 0 if e["ph"] == "E" else 1))
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def validate_trace(trace: dict) -> int:
    """Schema smoke for exported traces; returns the number of B/E pairs.

    Checks: top-level object format; every B/E event carries pid/tid/ts;
    per-track timestamps are monotonic (nondecreasing); every E matches the
    innermost open B of its track by name; nothing left open at the end.
    Raises ``ValueError`` on the first violation.
    """
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace must be an object with a 'traceEvents' list")
    evs = trace["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError("'traceEvents' must be a list")
    stacks: dict[tuple, list] = {}
    last_ts: dict[tuple, float] = {}
    pairs = 0
    for k, e in enumerate(evs):
        ph = e.get("ph")
        if ph not in ("B", "E"):
            continue  # metadata/counter events carry no duration pairing
        for key in ("pid", "tid", "ts"):
            if key not in e:
                raise ValueError(f"event {k}: {ph} event missing '{key}'")
        track = (e["pid"], e["tid"])
        ts = float(e["ts"])
        if ts < last_ts.get(track, -np.inf):
            raise ValueError(
                f"event {k}: timestamp {ts} goes backwards on track {track}"
            )
        last_ts[track] = ts
        stack = stacks.setdefault(track, [])
        if ph == "B":
            if "name" not in e:
                raise ValueError(f"event {k}: B event missing 'name'")
            stack.append((e["name"], ts))
        else:
            if not stack:
                raise ValueError(f"event {k}: E with no open B on track {track}")
            name, t0 = stack.pop()
            if e.get("name", name) != name:
                raise ValueError(
                    f"event {k}: E '{e.get('name')}' closes B '{name}'"
                )
            if ts < t0:
                raise ValueError(f"event {k}: span ends ({ts}) before it starts ({t0})")
            pairs += 1
    for track, stack in stacks.items():
        if stack:
            raise ValueError(f"track {track}: {len(stack)} B events never closed")
    return pairs


def write_trace(trace: dict, path) -> None:
    """Validate and write a trace to ``path`` (open in ui.perfetto.dev)."""
    validate_trace(trace)
    with open(path, "w") as f:
        json.dump(trace, f)
