"""Utilization report: the paper's Fig-9-style analysis as a standard table.

The paper's argument is that synchronization barriers strand array cycles;
this report shows exactly where each layer's capacity went on a real
(simulated) serving run, from an instrumented ``FabricSim(stats=True)``
result:

  * ``duty_cycle`` — true compute array-cycles / capacity (the paper's
    utilization);
  * ``barrier_frac`` — capacity occupied but wasted inside the layer's
    gather/accumulate barrier (arrays holding their result while the
    slowest block of the same duplicate finishes; layer-wise dataflow only
    — block-wise dataflow decouples the blocks, which is the paper's fix);
  * ``reprogram_frac`` — capacity frozen while drift re-allocation rewrites
    conductances (``drift.py`` stalls);
  * ``starved_frac`` — capacity idle with no job available: waiting on
    upstream stages, pipeline warmup/drain, or replica over-provisioning.

The four fractions plus duty cycle account for all capacity:
``duty + barrier + reprogram + starved = 1`` (pools are work-conserving).
Queue wait (jobs waiting for a free replica) is reported per job — it costs
requests latency, not arrays capacity.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["UtilizationReport", "utilization_report"]


@dataclass(frozen=True)
class UtilizationReport:
    policy: str
    clock_hz: float
    n_requests: int
    makespan_cycles: float
    arrays: np.ndarray  # (L,) arrays allocated per layer
    duty_cycle: np.ndarray  # (L,) true busy / capacity
    barrier_frac: np.ndarray  # (L,) intra-layer barrier waste / capacity
    reprogram_frac: np.ndarray  # (L,) reprogramming freeze / capacity
    starved_frac: np.ndarray  # (L,) idle (upstream wait, warmup/drain)
    imbalance: np.ndarray  # (L,) max/mean busy over replica lanes
    queue_wait_per_job: np.ndarray  # (L,) cycles a job waits for a replica
    jobs: np.ndarray  # (L,) jobs dispatched
    residence_mean: np.ndarray  # (L,) mean request residence in the stage

    @property
    def mean_duty_cycle(self) -> float:
        return float(self.duty_cycle.mean()) if self.duty_cycle.size else 0.0

    def to_json(self) -> dict:
        return {
            "policy": self.policy,
            "clock_hz": self.clock_hz,
            "n_requests": self.n_requests,
            "makespan_cycles": self.makespan_cycles,
            "mean_duty_cycle": self.mean_duty_cycle,
            "layers": [
                {
                    "layer": int(i),
                    "arrays": float(self.arrays[i]),
                    "duty_cycle": float(self.duty_cycle[i]),
                    "barrier_frac": float(self.barrier_frac[i]),
                    "reprogram_frac": float(self.reprogram_frac[i]),
                    "starved_frac": float(self.starved_frac[i]),
                    "imbalance": float(self.imbalance[i]),
                    "queue_wait_per_job": float(self.queue_wait_per_job[i]),
                    "jobs": int(self.jobs[i]),
                    "residence_mean": float(self.residence_mean[i]),
                }
                for i in range(self.duty_cycle.size)
            ],
        }

    def format(self) -> str:
        """Fixed-width text table (one row per layer + a mean row)."""
        hdr = (
            f"{'layer':>5} {'arrays':>7} {'duty%':>7} {'barrier%':>9} "
            f"{'reprog%':>8} {'starved%':>9} {'imbal':>6} {'wait/job':>10} "
            f"{'jobs':>9}"
        )
        lines = [f"policy={self.policy}  requests={self.n_requests}  "
                 f"makespan={self.makespan_cycles:.3e} cycles", hdr]
        for i in range(self.duty_cycle.size):
            lines.append(
                f"{i:>5} {self.arrays[i]:>7.0f} {100*self.duty_cycle[i]:>7.2f} "
                f"{100*self.barrier_frac[i]:>9.2f} "
                f"{100*self.reprogram_frac[i]:>8.2f} "
                f"{100*self.starved_frac[i]:>9.2f} {self.imbalance[i]:>6.3f} "
                f"{self.queue_wait_per_job[i]:>10.1f} {self.jobs[i]:>9d}"
            )
        lines.append(f"{'mean':>5} {'':>7} {100*self.mean_duty_cycle:>7.2f}")
        return "\n".join(lines)


def utilization_report(result) -> UtilizationReport:
    """Build the report from a ``FabricSim(stats=True)`` ``FabricResult``."""
    st = result.stats
    if st is None:
        raise ValueError(
            "utilization_report needs FabricResult.stats — run the fabric "
            "with FabricSim(..., stats=True)"
        )
    span = result.makespan
    cap = (
        result.layer_capacity
        if result.layer_capacity is not None
        else result.layer_arrays * span
    )
    cap = np.maximum(np.asarray(cap, dtype=np.float64), 1e-300)
    occupied = (
        st.layer_occupied
        if st.layer_occupied is not None
        else result.layer_busy
    )
    duty = result.layer_busy / cap
    barrier = np.maximum((occupied - result.layer_busy) / cap, 0.0)
    reprog = st.layer_reprogram / cap
    starved = np.maximum(1.0 - occupied / cap - reprog, 0.0)
    jobs = st.layer_jobs.astype(np.int64)
    wait_per_job = st.layer_queue_wait / np.maximum(jobs, 1)
    residence = (st.stage_exit - st.stage_entry).mean(axis=0)
    return UtilizationReport(
        policy=result.policy,
        clock_hz=result.clock_hz,
        n_requests=int(result.completions.size),
        makespan_cycles=span,
        arrays=np.asarray(result.layer_arrays, dtype=np.float64),
        duty_cycle=duty,
        barrier_frac=barrier,
        reprogram_frac=reprog,
        starved_frac=starved,
        imbalance=st.replica_imbalance(),
        queue_wait_per_job=wait_per_job,
        jobs=jobs,
        residence_mean=residence,
    )
