"""Allocation audit log: why each replica was granted.

The greedy allocators (``core.alloc.greedy.greedy_allocate`` /
``greedy_allocate_placed``) take an optional ``audit=AllocationAudit()``
and append one entry per grant — the unit chosen, what its expected latency
was before and after, what the grant cost, what remained — plus a final
entry for the paper's stopping rule when it fires.  The log is the
explanation artifact: "replica 37 went to block 12 because it was the
slowest affordable unit at 1.9e5 cycles".  ``audit=None`` (the default)
leaves the allocators' loops untouched.
"""

from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["AuditEntry", "AllocationAudit"]


@dataclass(frozen=True)
class AuditEntry:
    step: int  # grant index (0-based); stop entries reuse the next index
    kind: str  # "grant" | "stop"
    unit: int  # unit granted (grant) or the unaffordable slowest unit (stop)
    cost: float  # arrays consumed by this grant / needed by the blocked unit
    remaining: float  # budget left AFTER the grant (stop: at the stop)
    latency_before: float = 0.0  # unit's expected latency driving the choice
    latency_after: float = 0.0  # after the grant (base / new replica count)
    chip: int | None = None  # placed greedy: chip the replica landed on
    reason: str = ""  # stop entries: "budget" | "capacity"


class AllocationAudit:
    """Accumulates ``AuditEntry`` records from one allocator call."""

    def __init__(self):
        self.entries: list[AuditEntry] = []

    def grant(
        self,
        unit: int,
        cost: float,
        latency_before: float,
        latency_after: float,
        remaining: float,
        chip: int | None = None,
    ) -> None:
        self.entries.append(
            AuditEntry(
                step=len(self.entries),
                kind="grant",
                unit=int(unit),
                cost=float(cost),
                remaining=float(remaining),
                latency_before=float(latency_before),
                latency_after=float(latency_after),
                chip=None if chip is None else int(chip),
            )
        )

    def stop(self, reason: str, unit: int, cost: float, remaining: float) -> None:
        self.entries.append(
            AuditEntry(
                step=len(self.entries),
                kind="stop",
                unit=int(unit),
                cost=float(cost),
                remaining=float(remaining),
                reason=reason,
            )
        )

    # --------------------------------------------------------------- reading
    @property
    def grants(self) -> list[AuditEntry]:
        return [e for e in self.entries if e.kind == "grant"]

    @property
    def stop_reason(self) -> str | None:
        for e in reversed(self.entries):
            if e.kind == "stop":
                return e.reason
        return None

    def summary(self) -> dict:
        g = self.grants
        spent = sum(e.cost for e in g)
        per_unit: dict[int, int] = {}
        for e in g:
            per_unit[e.unit] = per_unit.get(e.unit, 0) + 1
        return {
            "grants": len(g),
            "spent": spent,
            "stop_reason": self.stop_reason,
            "grants_per_unit": per_unit,
        }

    def to_json(self) -> list[dict]:
        out = []
        for e in self.entries:
            d = {
                "step": e.step,
                "kind": e.kind,
                "unit": e.unit,
                "cost": e.cost,
                "remaining": e.remaining,
            }
            if e.kind == "grant":
                d["latency_before"] = e.latency_before
                d["latency_after"] = e.latency_after
                if e.chip is not None:
                    d["chip"] = e.chip
            else:
                d["reason"] = e.reason
            out.append(d)
        return out

    def __len__(self) -> int:
        return len(self.entries)
