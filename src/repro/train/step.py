"""Training / serving step factories — the functions the launcher jits.

``make_train_step(cfg, opt)``     (params, opt_state, batch) -> (params, opt_state, metrics)
``make_prefill_step(cfg)``        (params, tokens)           -> (last_logits, cache-ready kv)
``make_decode_step(cfg)``         (params, cache, tokens)    -> (logits, new_cache)

All are pure; distribution comes from jit in/out shardings (launch/dryrun.py,
launch/train.py)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..models import encdec, lm
from ..models.config import ModelConfig
from ..optim.adamw import AdamWConfig, adamw_update

__all__ = [
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "make_encdec_train_step",
    "make_encdec_decode_step",
    "make_compressed_train_step",
]


def make_train_step(cfg: ModelConfig, opt: AdamWConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lm.loss_fn)(
            params, cfg, batch["tokens"], batch["targets"]
        )
        params, opt_state, metrics = adamw_update(opt, grads, params, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_compressed_train_step(cfg: ModelConfig, opt: AdamWConfig, mesh):
    """Hierarchical reduction with int8+error-feedback on the POD axis.

    The pod axis is shard_map-manual; 'data'/'model' stay automatic (GSPMD
    keeps the intra-pod sharding).  Gradients reduce in full precision
    within a pod (autodiff's psum over 'data'), then cross-pod as an int8
    ring (optim/compress.py) — 4x less traffic on the slowest links.
    Signature gains an error-feedback pytree:
      (params, opt_state, ef, batch) -> (params, opt_state, ef, metrics)
    """
    from jax.sharding import PartitionSpec as P

    from ..optim.compress import apply_error_feedback, compressed_psum

    n_pods = mesh.shape["pod"]

    def local_step(params, opt_state, ef, batch):
        # inside the pod-manual region the context-mesh integrations
        # (_constrain_heads, MoE shard_map) must not name the 'pod' axis;
        # disable them — data/model sharding still propagates from the
        # param shardings via the auto axes.
        from ..distrib.context import use_mesh

        with use_mesh(None):
            loss, grads = jax.value_and_grad(lm.loss_fn)(
                params, cfg, batch["tokens"], batch["targets"]
            )
        carried = apply_error_feedback(grads, ef)
        reduced, errs = [], []
        flat, treedef = jax.tree.flatten(carried)
        for leaf in flat:
            r, e = compressed_psum(leaf, "pod", n_pods)
            reduced.append(r)
            errs.append(e)
        grads = treedef.unflatten(reduced)
        new_ef = treedef.unflatten(errs)
        params, opt_state, metrics = adamw_update(opt, grads, params, opt_state)
        metrics["loss"] = jax.lax.pmean(loss, "pod")
        return params, opt_state, new_ef, metrics

    from ..distrib.compat import shard_map

    return shard_map(
        local_step,
        mesh=mesh,
        in_specs=(P(), P(), P(), P("pod")),
        out_specs=(P(), P(), P(), P()),
        axis_names=frozenset({"pod"}),  # 'data'/'model' stay automatic
        check_vma=False,
    )


def make_prefill_step(cfg: ModelConfig):
    """Prefill: run the full prompt, return last-position logits.

    (The KV cache write path is exercised by decode; prefill lowering
    benchmarks the prompt-processing throughput the shape asks for.)"""

    def prefill_step(params, tokens):
        logits, _ = lm.forward(params, cfg, tokens)
        return logits[:, -1, :]

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    """One new token against a preallocated KV/SSM cache."""

    def decode_step(params, cache, tokens):
        logits, new_cache = lm.forward(params, cfg, tokens, cache=cache)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1)
        return next_tok, new_cache

    return decode_step


def make_encdec_train_step(cfg: ModelConfig, opt: AdamWConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(encdec.encdec_loss_fn)(
            params, cfg, batch["frames"], batch["tokens"], batch["targets"]
        )
        params, opt_state, metrics = adamw_update(opt, grads, params, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_encdec_prefill_step(cfg: ModelConfig):
    def prefill_step(params, frames, tokens):
        enc = encdec.encode(params, cfg, frames)
        logits, _ = encdec.decode(params, cfg, tokens, enc)
        return logits[:, -1, :]

    return prefill_step


def make_encdec_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, enc_out, tokens):
        logits, new_cache = encdec.decode(params, cfg, tokens, enc_out, cache=cache)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1)
        return next_tok, new_cache

    return decode_step
