"""Zero-skipping matmul — the paper's circuit trick, adapted to the TPU.

The paper's CIM arrays skip word-line reads for '0' input bits (bit-level
zero-skipping).  The MXU is a dense 128x128 systolic array with no per-row
gating, so the TPU-idiomatic equivalent is BLOCK-level skipping: a tiled
matmul that skips the MXU pass (and the B-tile VMEM load arithmetic) for
activation tiles that are entirely zero.  Post-ReLU / squared-ReLU
activations (Nemotron-4) are exactly the inputs the paper profiles.

Grid: (M/bm, N/bn, K/bk), K innermost.  A block mask (M/bm, K/bk) int32 —
computed once per activation tensor on the host side (ops.py) — gates the
accumulation with @pl.when.  The skipped fraction is the same statistic the
paper profiles as "percentage of '1's" (Fig 4), at tile granularity.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["zskip_matmul_kernel", "zskip_matmul"]


def zskip_matmul_kernel(mask_ref, a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    """One (bm, bn) output tile; iterate K on the innermost grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # mask_ref is a (1, 1) block of the (M/bm, K/bk) block-nonzero map
    @pl.when(mask_ref[0, 0] != 0)
    def _mac():
        acc_ref[...] += jnp.dot(
            a_ref[...], b_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "out_dtype")
)
def zskip_matmul(
    a: jax.Array,  # (M, K) activations (sparse after ReLU)
    b: jax.Array,  # (K, N) weights
    block_mask: jax.Array,  # (M/bm, K/bk) int32, 0 = skip
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    out_dtype = out_dtype or a.dtype
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(zskip_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (i, k)),  # block mask
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),  # A tile
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),  # B tile
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(block_mask, a, b)
