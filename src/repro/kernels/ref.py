"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["zskip_matmul_ref", "block_mask_ref", "flash_attention_ref", "ssd_chunk_ref"]


def block_mask_ref(a: jax.Array, bm: int, bk: int) -> jax.Array:
    """(M/bm, K/bk) int32 map: 1 where the A tile has any nonzero."""
    M, K = a.shape
    tiles = a.reshape(M // bm, bm, K // bk, bk)
    return (jnp.abs(tiles).sum(axis=(1, 3)) > 0).astype(jnp.int32)


def zskip_matmul_ref(a: jax.Array, b: jax.Array, block_mask: jax.Array, bm: int, bk: int) -> jax.Array:
    """Matmul with zeroed-out skipped A tiles (== exact matmul when the mask
    marks exactly the all-zero tiles)."""
    M, K = a.shape
    mask_full = jnp.repeat(jnp.repeat(block_mask, bm, axis=0), bk, axis=1)
    a_eff = a * mask_full.astype(a.dtype)
    return (a_eff.astype(jnp.float32) @ b.astype(jnp.float32)).astype(a.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True) -> jax.Array:
    """(bh, s, hd) dense softmax attention in fp32."""
    bh, sq, hd = q.shape
    sk = k.shape[1]
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / np.sqrt(hd)
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_chunk_ref(cum, xdt, B, C):
    """Oracle for kernels.ssd_scan.ssd_chunk (see models/ssm.ssd_chunked)."""
    cum = cum.astype(jnp.float32)
    Q = cum.shape[1]
    diff = cum[:, :, None, :] - cum[:, None, :, :]  # (nc, Q, Q, H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, :, :, None]
    L = jnp.exp(jnp.where(tri, diff, -jnp.inf))
    scores = jnp.einsum("cqn,ckn->cqk", C.astype(jnp.float32), B.astype(jnp.float32))
    y = jnp.einsum("cqk,cqkh,ckhp->cqhp", scores, L, xdt.astype(jnp.float32))
    decay_end = jnp.exp(cum[:, -1:, :] - cum)  # (nc, Q, H)
    S = jnp.einsum("ckh,ckn,ckhp->chnp", decay_end, B.astype(jnp.float32), xdt.astype(jnp.float32))
    return y.astype(xdt.dtype), S
