"""Flash attention (forward) Pallas kernel for TPU.

The q-chunked pure-JAX path (models/layers._sdpa) bounds LIVE memory but
still writes O(s^2) probability blocks to HBM.  This kernel keeps the
running softmax state (m, l, acc) in VMEM across the kv-block grid axis so
HBM traffic is O(s*d): q, k, v read once, o written once — the roofline
§Perf iterations substitute this kernel's analytic traffic for the lowered
pure-JAX attention.

Grid: (batch*heads, q_blocks, kv_blocks), kv innermost.  Causal masking per
(q_block, kv_block) tile; fully-masked kv tiles are predicated off with
@pl.when — the same "skip work that is provably zero" trick the paper plays
at the word-line level (its Fig 2), applied at tile granularity.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

_NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, n_kv: int, bq: int, bk: int, causal: bool, scale: float):
    kb = pl.program_id(2)
    qb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: kv block strictly after the q block is all-masked -> skip
    run = (not causal) or (kb * bk <= qb * bq + bq - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0]  # (bq, hd)
        k = k_ref[0]  # (bk, hd)
        v = v_ref[0]  # (bk, hd)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(kb == n_kv - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "interpret")
)
def flash_attention(
    q: jax.Array,  # (bh, sq, hd)  — batch*heads flattened
    k: jax.Array,  # (bh, sk, hd)
    v: jax.Array,  # (bh, sk, hd)
    *,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, hd = q.shape
    sk = k.shape[1]
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    n_kv = sk // bk
    grid = (bh, sq // bq, n_kv)
    scale = 1.0 / np.sqrt(hd)
    return pl.pallas_call(
        functools.partial(
            _fa_kernel, n_kv=n_kv, bq=bq, bk=bk, causal=causal, scale=scale
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),  # running max
            pltpu.VMEM((bq, 1), jnp.float32),  # running denom
            pltpu.VMEM((bq, hd), jnp.float32),  # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
