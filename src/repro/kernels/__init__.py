"""Pallas TPU kernels (validated on CPU via interpret=True)."""
from . import ops, ref
from .bitplane_profile import bitplane_block_profile, bitplane_profile
from .flash_attention import flash_attention
from .fused_alloc_eval import fused_alloc_eval
from .ssd_scan import ssd_chunk
from .zskip_matmul import zskip_matmul
__all__ = [
    "ops",
    "ref",
    "bitplane_block_profile",
    "bitplane_profile",
    "flash_attention",
    "fused_alloc_eval",
    "ssd_chunk",
    "zskip_matmul",
]
