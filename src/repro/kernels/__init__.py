"""Pallas TPU kernels (validated on CPU via interpret=True)."""
from . import ops, ref
from .flash_attention import flash_attention
from .ssd_scan import ssd_chunk
from .zskip_matmul import zskip_matmul
__all__ = ["ops", "ref", "flash_attention", "ssd_chunk", "zskip_matmul"]
