"""SSD (Mamba2) per-chunk Pallas kernel.

Computes, for one (batch x chunk, head-block) grid cell, the fused
intra-chunk output and the chunk summary state:

    y_intra[q, h, p] = sum_{k<=q} (C_q . B_k) * exp(cum_q,h - cum_k,h) * xdt[k, h, p]
    S_chunk[h, n, p] = sum_k exp(cum_last,h - cum_k,h) * B[k, n] * xdt[k, h, p]

The (Q, Q) score matrix C @ B^T hits the MXU once per cell and is reused for
every head in the block — the decay mask L is the only per-head term.  The
inter-chunk recurrence (a length-n_chunks scan) stays in JAX: it is O(s/Q)
sequential and tiny.

VMEM working set per cell: Q*N (B, C) + Q*Q scores + HB*(Q*P + Q) ~ well
under 1 MiB at Q=128, N=128, HB=4, P=64; all matmul dims are multiples of
the 128 MXU tile except P=64 (padded by Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_chunk"]


def _ssd_chunk_kernel(cum_ref, xdt_ref, b_ref, c_ref, y_ref, s_ref):
    # block shapes: cum (1, Q, HB), xdt (1, Q, HB, P), b/c (1, Q, N)
    cum = cum_ref[0].astype(jnp.float32)  # (Q, HB)
    B = b_ref[0]  # (Q, N)
    C = c_ref[0]  # (Q, N)
    Q = cum.shape[0]
    scores = jnp.dot(C, B.T, preferred_element_type=jnp.float32)  # (Q, Q)
    iq = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ik = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tri = iq >= ik

    hb = xdt_ref.shape[2]
    for h in range(hb):  # head block is small + static: unrolled
        diff = cum[:, None, h] - cum[None, :, h]  # (Q, Q)
        L = jnp.where(tri, jnp.exp(diff), 0.0)
        xdt_h = xdt_ref[0, :, h, :]  # (Q, P)
        y_ref[0, :, h, :] = jnp.dot(
            scores * L, xdt_h.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        ).astype(y_ref.dtype)
        decay_end = jnp.exp(cum[-1, h] - cum[:, h])  # (Q,)
        bw = B * decay_end[:, None].astype(B.dtype)  # (Q, N)
        s_ref[0, h, :, :] = jnp.dot(
            bw.T, xdt_h, preferred_element_type=jnp.float32
        ).astype(s_ref.dtype)


@functools.partial(jax.jit, static_argnames=("head_block", "interpret"))
def ssd_chunk(
    cum: jax.Array,  # (nc, Q, H)  cumulative log-decay per chunk
    xdt: jax.Array,  # (nc, Q, H, P)  dt-weighted inputs
    B: jax.Array,  # (nc, Q, N)
    C: jax.Array,  # (nc, Q, N)
    *,
    head_block: int = 4,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y_intra (nc, Q, H, P), S_chunk (nc, H, N, P))."""
    nc, Q, H = cum.shape
    P = xdt.shape[-1]
    N = B.shape[-1]
    assert H % head_block == 0, (H, head_block)
    grid = (nc, H // head_block)
    hb = head_block
    y, s = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, hb), lambda c, h: (c, 0, h)),
            pl.BlockSpec((1, Q, hb, P), lambda c, h: (c, 0, h, 0)),
            pl.BlockSpec((1, Q, N), lambda c, h: (c, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda c, h: (c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, hb, P), lambda c, h: (c, 0, h, 0)),
            pl.BlockSpec((1, hb, N, P), lambda c, h: (c, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nc, Q, H, P), xdt.dtype),
            jax.ShapeDtypeStruct((nc, H, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(cum, xdt, B, C)
    return y, s
