"""Jit'd public wrappers around the Pallas kernels.

On this container (CPU) the kernels run with interpret=True; on a real TPU
set ``REPRO_PALLAS_INTERPRET=0`` (or rely on the default platform check).
"""

from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention as _flash
from .ssd_scan import ssd_chunk as _ssd_chunk
from .zskip_matmul import zskip_matmul as _zskip
from .ref import block_mask_ref

__all__ = ["interpret_mode", "zskip_matmul_op", "flash_attention_op", "ssd_chunk_op"]


def interpret_mode() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false")
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def zskip_matmul_op(a, b, *, bm=128, bn=128, bk=128):
    """Zero-skipping matmul: builds the activation block mask then runs the
    kernel.  The mask build is one cheap reduction over A."""
    mask = block_mask_ref(a, bm, bk)
    return _zskip(a, b, mask, bm=bm, bn=bn, bk=bk, interpret=interpret_mode())


@partial(jax.jit, static_argnames=("causal",))
def flash_attention_op(q, k, v, *, causal=True):
    """q/k/v: (b, s, h, hd) -> (b, s, h, hd); h folded into the grid."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, hd)
    bq = min(128, sq)
    bk = min(128, sk)
    o = _flash(qf, kf, vf, causal=causal, bq=bq, bk=bk, interpret=interpret_mode())
    return o.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("head_block",))
def ssd_chunk_op(cum, xdt, B, C, *, head_block=4):
    return _ssd_chunk(cum, xdt, B, C, head_block=head_block, interpret=interpret_mode())
