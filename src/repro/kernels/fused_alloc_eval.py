"""Fused greedy allocate + throughput eval — one Pallas grid step per
config block.

The fused DSE pipeline's dense-grid regime evaluates millions of (ADC,
policy, PE-budget) configs against bank statistics that are shared per
variant.  This kernel fuses the whole per-config pipeline — lock-step
greedy water-fill + residual loop, replica scatter, and the throughput/
utilization eval — into a single ``pallas_call``: the grid walks blocks of
configs while the (V, L, B) statistic stacks, the per-variant allocation
bases, and the one-hot unit map stay resident in VMEM across the block
(their ``BlockSpec`` index maps pin them to slot 0), so a block's entire
allocate->eval chain runs without touching HBM between the stages.

Exactness: the allocation phase CALLS ``core.alloc.greedy.
greedy_batch_kernel`` inside the kernel body — plain ``jax.lax`` control
flow, legal in Pallas — so replica counts are bit-identical to the batched
greedy by construction, not by re-derivation (the interpret-mode property
suite pins this against ``greedy_allocate_batch``, warm starts and ties
included).  The eval phase applies the same formulas as
``core.cim.simulate._eval_kernel`` batched over the block; float outputs
agree with the staged path at the fused pipeline's rtol 1e-12 contract.

Both greedy FAMILIES flatten onto one unit axis: perf_layerwise passes
units = layers (the unit map broadcasts a layer's replicas across its
blocks), blockwise passes units = per-block flat units (the map scatters
each unit to its (layer, block) cell); proportional configs ride along
with ``budget = 0`` and their host-precomputed replicas as the warm start
— budget 0 makes the greedy a no-op, so one kernel serves every family.

Off-TPU the kernel runs ``interpret=True`` (float64, CI exercises exactly
that path); on TPU the natural dtype is float32 — callers that need the
1e-12 contract should stay on the XLA path there.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.alloc.greedy import greedy_batch_kernel

__all__ = ["fused_alloc_eval", "fused_alloc_eval_kernel"]


def fused_alloc_eval_kernel(
    base_ref,  # (A, N)  per-ADC-variant unit base latencies
    cost_ref,  # (1, N)  cost per extra replica of each unit
    umap_ref,  # (N, L*B) one-hot unit -> (layer, block) replica map
    mean_ref,  # (V, L, B) bank stacks (V = baseline + zskip slots)
    max_ref,  # (V, L, B)
    pmn_ref,  # (V, L)
    pmx_ref,  # (V, L)
    busy_ref,  # (V, L)
    bmask_ref,  # (L, B) bool
    ppi_ref,  # (1, L)
    width_ref,  # (1, L)
    larr_ref,  # (1, L)
    budget_ref,  # (Cb,)  per-config replica budget (0 = warm start is final)
    aidx_ref,  # (Cb,) int32 — variant for the ALLOCATION bases
    sel_ref,  # (Cb,) int32 — bank stack slot for the EVAL
    lw_ref,  # (Cb,) bool — layer-wise barrier dataflow
    r0_ref,  # (Cb, N) warm-start replicas
    t_ref,  # out (Cb,) total cycles
    ips_ref,  # out (Cb,) images/sec
    layer_t_ref,  # out (Cb, L)
    util_ref,  # out (Cb, L)
    r_ref,  # out (Cb, N) replicas
    rem_ref,  # out (Cb,) leftover budget
    *,
    n_images: int,
    clock_hz: float,
):
    base = base_ref[...]
    cost = cost_ref[0]
    r0 = r0_ref[...]
    budget = budget_ref[...]
    cb, n = r0.shape

    # ---- allocate: the batched greedy, verbatim (bit-identical replicas)
    r, rem = greedy_batch_kernel(
        base[aidx_ref[...]], jnp.broadcast_to(cost, (cb, n)), budget, r0
    )

    # ---- scatter: one-hot matmul is exact (one nonzero * 1.0 per cell)
    l, b = bmask_ref.shape
    dups = (1.0 + (r - 1.0) @ umap_ref[...]).reshape(cb, l, b)

    # ---- eval: _eval_kernel's formulas, batched over the config block
    sel = sel_ref[...]
    mean_b = mean_ref[...][sel]
    max_b = max_ref[...][sel]
    pmn = pmn_ref[...][sel]
    pmx = pmx_ref[...][sel]
    busy = busy_ref[...][sel]
    bmask = bmask_ref[...]
    lw = lw_ref[...]
    p = ppi_ref[0] * n_images
    width = width_ref[0]
    larr = larr_ref[0]
    d_layer = dups[:, :, 0]
    t_lw = jnp.maximum(pmn * p[None, :] / d_layer, pmx)
    per_block = jnp.maximum(mean_b * p[None, :, None] / dups, max_b)
    t_bw = jnp.where(bmask[None], per_block, -jnp.inf).max(axis=-1)
    layer_t = jnp.where(lw[:, None], t_lw, t_bw)
    alive = jnp.where(
        lw[:, None],
        larr[None, :] * d_layer,
        jnp.where(bmask[None], dups * width[None, :, None], 0.0).sum(axis=-1),
    )
    busy_c = busy * p[None, :] * width[None, :]
    t = layer_t.max(axis=-1)
    t_ref[...] = t
    ips_ref[...] = n_images / (t / clock_hz)
    layer_t_ref[...] = layer_t
    util_ref[...] = busy_c / (alive * t[:, None])
    r_ref[...] = r
    rem_ref[...] = rem


def fused_alloc_eval(
    base: jax.Array,  # (A, N)
    cost: jax.Array,  # (N,)
    unit_map: jax.Array,  # (N, L, B) one-hot
    banks: tuple,  # (mean (V,L,B), max (V,L,B), pm_mean (V,L), pm_max (V,L), busy (V,L))
    b_mask: jax.Array,  # (L, B) bool
    ppi: jax.Array,  # (L,)
    width: jax.Array,  # (L,)
    layer_arrays: jax.Array,  # (L,)
    budgets: jax.Array,  # (C,)
    a_idx: jax.Array,  # (C,) int32
    sel: jax.Array,  # (C,) int32
    layerwise: jax.Array,  # (C,) bool
    r0: jax.Array,  # (C, N)
    *,
    n_images: int = 64,
    clock_hz: float = 1e9,
    block_configs: int = 128,
    interpret: bool | None = None,
):
    """Run C configs through the fused allocate+eval kernel.

    Returns ``(T, ips, layer_T, util, r, rem)`` with shapes ``(C,)/(C,)/
    (C, L)/(C, L)/(C, N)/(C,)``.  The config axis is padded to a multiple
    of ``block_configs`` by repeating config 0 (one compiled program per
    shape) and truncated on return.  ``interpret=None`` auto-selects
    interpret mode off-TPU.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    mean_b, max_b, pm_mean, pm_max, busy = (jnp.asarray(x) for x in banks)
    base = jnp.asarray(base)
    cost = jnp.atleast_2d(jnp.asarray(cost))  # (1, N)
    v, l, b = mean_b.shape
    a, n = base.shape
    umap = jnp.asarray(unit_map).reshape(n, l * b)
    budgets = jnp.atleast_1d(jnp.asarray(budgets))
    c = budgets.shape[0]
    cb = min(int(block_configs), c)
    pad = (-c) % cb
    fullc = c + pad

    def padded(x):
        x = jnp.atleast_1d(jnp.asarray(x))
        if pad == 0:
            return x
        return jnp.concatenate([x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])])

    budgets_p = padded(budgets)
    aidx_p = padded(a_idx).astype(jnp.int32)
    sel_p = padded(sel).astype(jnp.int32)
    lw_p = padded(layerwise).astype(bool)
    r0_p = padded(jnp.broadcast_to(jnp.asarray(r0), (c, n)))
    f = budgets_p.dtype
    ppi2 = jnp.asarray(ppi, f).reshape(1, l)
    width2 = jnp.asarray(width, f).reshape(1, l)
    larr2 = jnp.asarray(layer_arrays, f).reshape(1, l)

    fixed = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    kernel = functools.partial(
        fused_alloc_eval_kernel, n_images=int(n_images), clock_hz=float(clock_hz)
    )
    outs = pl.pallas_call(
        kernel,
        grid=(fullc // cb,),
        in_specs=[
            fixed((a, n)),
            fixed((1, n)),
            fixed((n, l * b)),
            fixed((v, l, b)),
            fixed((v, l, b)),
            fixed((v, l)),
            fixed((v, l)),
            fixed((v, l)),
            fixed((l, b)),
            fixed((1, l)),
            fixed((1, l)),
            fixed((1, l)),
            pl.BlockSpec((cb,), lambda i: (i,)),
            pl.BlockSpec((cb,), lambda i: (i,)),
            pl.BlockSpec((cb,), lambda i: (i,)),
            pl.BlockSpec((cb,), lambda i: (i,)),
            pl.BlockSpec((cb, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((cb,), lambda i: (i,)),
            pl.BlockSpec((cb,), lambda i: (i,)),
            pl.BlockSpec((cb, l), lambda i: (i, 0)),
            pl.BlockSpec((cb, l), lambda i: (i, 0)),
            pl.BlockSpec((cb, n), lambda i: (i, 0)),
            pl.BlockSpec((cb,), lambda i: (i,)),
        ],
        out_shape=(
            jax.ShapeDtypeStruct((fullc,), f),
            jax.ShapeDtypeStruct((fullc,), f),
            jax.ShapeDtypeStruct((fullc, l), f),
            jax.ShapeDtypeStruct((fullc, l), f),
            jax.ShapeDtypeStruct((fullc, n), f),
            jax.ShapeDtypeStruct((fullc,), f),
        ),
        interpret=interpret,
    )(
        base.astype(f),
        cost.astype(f),
        umap.astype(f),
        mean_b.astype(f),
        max_b.astype(f),
        pm_mean.astype(f),
        pm_max.astype(f),
        busy.astype(f),
        jnp.asarray(b_mask, bool),
        ppi2,
        width2,
        larr2,
        budgets_p,
        aidx_p,
        sel_p,
        lw_p,
        r0_p,
    )
    return tuple(o[:c] for o in outs)
