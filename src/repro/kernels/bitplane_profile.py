"""Bit-plane popcount + zero-skip block costing — the profiler's hot loop
as a Pallas kernel.

The CIM profiler (core/cim/profile.py) needs, for every sampled patch and
every crossbar block (a contiguous row slice of the lowered matrix), the
number of '1' bits per input bit-plane and the resulting zero-skip cycle
count ``cycles_per_read * sum_p max(1, ceil(ones_p / rows_per_read))``.
One grid step handles one block: it extracts the 8 bit-planes of a
(S, block_rows) int32 tile with shift-and-mask, reduces each plane over the
row axis (VPU-friendly: the reduced axis is the 128-wide lane dimension for
the default 128-row block), and folds the ceil-div read count on the fly.

Outputs are laid out block-major — ``ones`` as (B, planes, S) and ``cycles``
as (B, S), last dimension S — so writes stay lane-contiguous; the host-side
wrapper transposes back to the profiler's (S, B) convention.  Like
``zskip_matmul``, the kernel runs under ``interpret=True`` off-TPU (CI
exercises exactly that path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = [
    "bitplane_profile_kernel",
    "bitplane_block_profile",
    "bitplane_profile",
    "bitplane_cycle_bank",
]


def bitplane_profile_kernel(
    q_ref, ones_ref, cyc_ref, *, input_bits: int, rows_per_read: int, cycles_per_read: int
):
    """One block: (1, S, r) int32 quantized patches -> per-plane popcounts
    (1, planes, S) and zskip cycles (1, S)."""
    q = q_ref[0]  # (S, r)
    total = jnp.zeros((q.shape[0],), jnp.int32)
    for p in range(input_bits):
        # plane 0 = MSB, matching np.unpackbits
        ones = jnp.sum((q >> (input_bits - 1 - p)) & 1, axis=1, dtype=jnp.int32)
        ones_ref[0, p, :] = ones
        total += jnp.maximum(1, (ones + rows_per_read - 1) // rows_per_read)
    cyc_ref[0, :] = cycles_per_read * total


@functools.partial(
    jax.jit,
    static_argnames=("input_bits", "rows_per_read", "cycles_per_read", "interpret"),
)
def bitplane_block_profile(
    q_blocks: jax.Array,  # (B, S, r) integer quantized patch rows, one block per slot
    *,
    input_bits: int = 8,
    rows_per_read: int = 8,
    cycles_per_read: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Raw kernel entry: returns (ones (B, planes, S) int32, cycles (B, S)
    int32).  Rows beyond a block's true extent must be zero-padded — zero
    rows contribute no '1' bits, exactly like the profiler's short last
    block."""
    assert q_blocks.ndim == 3, q_blocks.shape
    b, s, r = q_blocks.shape
    q_blocks = q_blocks.astype(jnp.int32)
    kernel = functools.partial(
        bitplane_profile_kernel,
        input_bits=input_bits,
        rows_per_read=rows_per_read,
        cycles_per_read=cycles_per_read,
    )
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, s, r), lambda i: (i, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, input_bits, s), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
        ],
        out_shape=(
            jax.ShapeDtypeStruct((b, input_bits, s), jnp.int32),
            jax.ShapeDtypeStruct((b, s), jnp.int32),
        ),
        interpret=interpret,
    )(q_blocks)


def bitplane_cycle_bank(
    q_blocks: jax.Array,  # (..., S, r) uint8/int blocks, zero-padded rows
    rows_per_read: tuple[int, ...],
    *,
    input_bits: int = 8,
    cycles_per_read: int = 8,
    use_pallas: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """TRACEABLE multi-ADC zero-skip costing: one popcount, A re-costings.

    The fused DSE pipeline's in-graph derivation step: counts '1' bits per
    bit-plane ONCE (shift-and-mask, the same integers as ``np.unpackbits``
    or the Pallas kernel) and re-costs them for every ADC precision in
    ``rows_per_read`` — the whole ADC axis of a sweep from a single shared
    capture, with no host round-trip.  Returns float64-able int32 cycles
    shaped ``(A, ..., S)``; padded (all-zero) blocks cost the 1-read floor
    per plane and must be masked by the caller, exactly like the profiler's
    short last block.

    ``use_pallas=True`` routes the popcount through ``bitplane_block_profile``
    (TPU path; ``interpret=True`` off-TPU) — ones are bit-identical either
    way, so the jnp path is the default inside large fused programs where a
    grid launch per layer buys nothing on CPU.
    """
    if use_pallas:
        if q_blocks.ndim != 3:
            raise ValueError(f"pallas path needs (B, S, r), got {q_blocks.shape}")
        ones, _ = bitplane_block_profile(
            q_blocks.astype(jnp.int32),
            input_bits=input_bits,
            rows_per_read=int(rows_per_read[0]),
            cycles_per_read=cycles_per_read,
            interpret=interpret,
        )
        ones = jnp.moveaxis(ones, 1, -1)  # (B, S, planes)
    else:
        q = q_blocks.astype(jnp.int32)
        ones = jnp.stack(
            [
                ((q >> (input_bits - 1 - p)) & 1).sum(axis=-1, dtype=jnp.int32)
                for p in range(input_bits)
            ],
            axis=-1,
        )  # (..., S, planes), plane 0 = MSB
    banks = [
        cycles_per_read
        * jnp.maximum(1, (ones + rpr - 1) // rpr).sum(axis=-1, dtype=jnp.int32)
        for rpr in rows_per_read
    ]
    return jnp.stack(banks, axis=0)  # (A, ..., S)


def bitplane_profile(
    patches_u8: np.ndarray,  # (S, rows) uint8 quantized word-line inputs
    *,
    block_rows: int,
    rows_per_read: int = 8,
    cycles_per_read: int = 8,
    interpret: bool = False,
) -> tuple[np.ndarray, np.ndarray]:
    """Profiler-facing wrapper: slice a (S, rows) patch matrix into
    ``ceil(rows / block_rows)`` word-line blocks (zero-padding the last) and
    run the kernel.  Returns (ones (S, B, planes) int64, cycles (S, B)
    int64) — bit-identical to ``np.unpackbits`` + ``zskip_cycles`` per row
    slice."""
    patches_u8 = np.asarray(patches_u8)
    if patches_u8.dtype != np.uint8:
        raise TypeError(f"expected uint8, got {patches_u8.dtype}")
    if patches_u8.ndim != 2:
        raise ValueError(f"expected (S, rows), got shape {patches_u8.shape}")
    s, rows = patches_u8.shape
    n_blocks = -(-rows // block_rows)
    padded = np.zeros((s, n_blocks * block_rows), np.uint8)
    padded[:, :rows] = patches_u8
    blocks = np.ascontiguousarray(
        padded.reshape(s, n_blocks, block_rows).transpose(1, 0, 2)
    )
    ones, cyc = bitplane_block_profile(
        jnp.asarray(blocks.astype(np.int32)),
        rows_per_read=rows_per_read,
        cycles_per_read=cycles_per_read,
        interpret=interpret,
    )
    ones = np.asarray(ones).transpose(2, 0, 1).astype(np.int64)  # (S, B, planes)
    cyc = np.asarray(cyc).T.astype(np.int64)  # (S, B)
    return ones, cyc
