"""Gradient compression for cross-pod reduction.

At 2+ pods the `pod` axis rides the slowest links (data-center network /
optical ICI), so the standard trick is hierarchical reduction with the
inter-pod hop compressed: reduce fp32/bf16 WITHIN a pod, then all-reduce
int8-quantized gradients ACROSS pods, with error feedback so quantization
error is carried to the next step instead of lost (Seide et al.'s 1-bit SGD
residual trick, at int8).

`compressed_psum(x, axis)` is used inside a shard_map over the pod axis;
`make_compressed_train_step` wires it into the training step with
`auto=` for the other mesh axes (GSPMD keeps handling data/model).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "quantize_int8",
    "dequantize_int8",
    "compressed_psum",
    "init_error_feedback",
    "apply_error_feedback",
]


def quantize_int8(x: jax.Array, key: jax.Array | None = None):
    """Per-tensor symmetric int8 with optional stochastic rounding.

    Returns (q int8, scale f32)."""
    scale = jnp.max(jnp.abs(x)).astype(jnp.float32) / 127.0 + 1e-30
    y = x.astype(jnp.float32) / scale
    if key is not None:
        y = jnp.floor(y + jax.random.uniform(key, y.shape))
    else:
        y = jnp.round(y)
    return jnp.clip(y, -127, 127).astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(
    x: jax.Array, axis: str, axis_size: int, key: jax.Array | None = None
):
    """int8 mean-reduce over `axis` with the int8 payload ON THE WIRE.

    A naive ``psum(q.astype(s32))`` would put s32 on the links (zero
    savings); instead we ring-rotate the int8 tensor (axis_size - 1
    collective-permutes of s8 + one f32 scalar each) and accumulate locally
    in s32 — 4x less inter-pod traffic than an fp32 all-reduce, visible as
    ``collective-permute(s8[...])`` in the dry-run HLO.

    Returns (mean-reduced value, local quantization error for feedback)."""
    q, scale = quantize_int8(x, key)
    err = x.astype(jnp.float32) - dequantize_int8(q, scale)
    idx = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % axis_size) for i in range(axis_size)]
    total = dequantize_int8(q, scale)
    rq, rs = q, scale
    for _ in range(axis_size - 1):
        rq = jax.lax.ppermute(rq, axis, perm)
        rs = jax.lax.ppermute(rs, axis, perm)
        total = total + dequantize_int8(rq, rs)
    del idx
    return (total / axis_size).astype(x.dtype), err


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def apply_error_feedback(grads, residual):
    """Add last step's quantization error before compressing this step."""
    return jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, grads, residual)
