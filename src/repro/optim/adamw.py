"""AdamW with decoupled weight decay, global-norm clipping and a
linear-warmup cosine schedule.  Pure pytree implementation (no optax
dependency); optimizer state shards exactly like the parameters."""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def adamw_init(params) -> dict:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def adamw_update(
    cfg: AdamWConfig, grads, params, state
) -> tuple[dict, dict, dict[str, jax.Array]]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state["step"] + 1
    lr = _schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, p, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        upd = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        p_new = p.astype(jnp.float32) - lr * (upd + cfg.weight_decay * p.astype(jnp.float32))
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, p, m, v) for g, p, m, v in zip(flat_g, flat_p, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return (
        new_p,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
