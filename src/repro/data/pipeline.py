"""Deterministic synthetic token pipeline.

Production shape: shard-aware (each data-parallel group reads its own slice),
deterministically seeded by (seed, step) so that resume-from-checkpoint
replays the exact stream without storing cursor state — the skip-ahead is
O(1), which is what makes checkpoint/restart cheap at scale.

The token distribution is Zipfian with a repeating n-gram structure so that
losses actually decrease during the example runs (pure uniform noise has no
learnable signal).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "batch_for_step"]


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    motif_len: int = 16
    motif_count: int = 64


class SyntheticLM:
    """Zipfian tokens with injected repeating motifs (learnable structure)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # frozen motif table: short phrases the model can memorize
        self.motifs = rng.integers(
            0, cfg.vocab, size=(cfg.motif_count, cfg.motif_len), dtype=np.int32
        )
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self.probs = probs / probs.sum()

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        """One (batch_local, seq+1) batch for `step`, deterministic in
        (seed, step, shard).  Resume = just call with the resumed step."""
        cfg = self.cfg
        assert cfg.global_batch % n_shards == 0
        b_local = cfg.global_batch // n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, shard])
        )
        toks = rng.choice(
            cfg.vocab, size=(b_local, cfg.seq_len + 1), p=self.probs
        ).astype(np.int32)
        # overwrite random spans with motifs (predictable continuations)
        n_spans = cfg.seq_len // (cfg.motif_len * 4)
        for i in range(b_local):
            for _ in range(max(n_spans, 1)):
                m = rng.integers(0, cfg.motif_count)
                pos = rng.integers(0, cfg.seq_len + 1 - cfg.motif_len)
                toks[i, pos : pos + cfg.motif_len] = self.motifs[m]
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "targets": jnp.asarray(toks[:, 1:]),
        }

    def stream(self, start_step: int = 0, shard: int = 0, n_shards: int = 1) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch(step, shard, n_shards)
            step += 1


def batch_for_step(cfg: DataConfig, step: int) -> dict:
    """Convenience single-host accessor (examples / tests)."""
    return SyntheticLM(cfg).batch(step)
