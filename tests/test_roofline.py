"""HLO static analyzer + roofline math on hand-written HLO and real lowered
programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo_analysis import analyze_hlo
from repro.core.roofline import HW, Roofline, collective_stats

HLO_SAMPLE = """
HloModule test

%body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %w = f32[8,8] constant({...})
  %dot.1 = f32[8,8] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%dot.1), replica_groups={}, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
}

%cond.1 (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %lim = s32[] constant(12)
  ROOT %cmp = pred[] compare(%i, %lim), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %a)
  %w2 = (s32[], f32[8,8]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[8,8] get-tuple-element(%w2), index=1
}
"""


def test_while_trip_count_multiplies():
    cost = analyze_hlo(HLO_SAMPLE)
    assert cost.n_while == 1
    assert cost.trip_counts == (12,)
    # dot: 2*8*8*8 = 1024 flops, x12 loop iterations
    assert cost.flops == 1024 * 12
    # all-reduce operand: 8*8*4 bytes, x12
    assert cost.collective_bytes == 256 * 12
    assert cost.coll_count == {"all-reduce": 12}


def test_analyzer_vs_real_lowering():
    """Scan of L matmuls must report ~L x the single-matmul flops."""
    L, D = 7, 64
    w = jnp.zeros((L, D, D))

    def f(x, w):
        def body(x, wl):
            return x @ wl, None

        y, _ = jax.lax.scan(body, x, w)
        return y

    compiled = jax.jit(f).lower(jnp.zeros((D, D)), w).compile()
    cost = analyze_hlo(compiled.as_text())
    expect = 2 * D * D * D * L
    assert expect * 0.9 <= cost.flops <= expect * 1.2


def test_roofline_terms():
    r = Roofline(
        flops=197e12 * 256,          # exactly 1 s of compute on 256 chips
        bytes_accessed=819e9 * 128,  # 0.5 s of HBM
        collective_bytes=50e9 * 64,  # 0.25 s of ICI
        chips=256,
        model_flops=197e12 * 128,    # half the issued flops are useful
    )
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.5)
    assert r.collective_s == pytest.approx(0.25)
    assert r.bottleneck == "compute"
    assert r.useful_flop_fraction == pytest.approx(0.5)
    assert r.roofline_fraction == pytest.approx(0.5)


def test_collective_stats_parser():
    st = collective_stats(
        "%ag = bf16[4,8]{1,0} all-gather(bf16[2,8]{1,0} %x), dimensions={0}\n"
        "%ar = f32[16]{0} all-reduce(%y), to_apply=%add\n"
    )
    # all-gather counts its (inline-shaped) operand: 2*8*2 bytes
    assert st.bytes_by_op["all-gather"] == 32
    assert st.bytes_by_op["all-reduce"] == 64
    assert st.total_count == 2
