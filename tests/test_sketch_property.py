"""Property suite pinning the streaming latency sketch to the exact
reductions: for arbitrary positive latency populations the sketch quantile
must stay within ``SketchConfig.rel_error`` of ``percentile_kernel`` /
``np.percentile``, extremes and moments must be exact, and the sequential
fold must equal the vectorized reference count-for-count — the streaming
mirror of ``test_percentile_property.py``.

Standalone module: the tier-1 minimal CI image has no hypothesis, so the
whole file skips at import."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

from repro.fabric.metrics import (
    LatencySketch,
    SketchConfig,
    percentile_kernel,
    sketch_init,
    sketch_update,
)

CFG = SketchConfig()

# in-range positive latencies: [2^min_exp, 2^(min_exp + n_octaves)) is the
# sketch's documented accuracy domain (cycles are >= 1 in practice)
_lat = st.floats(min_value=1.0, max_value=1e12, allow_nan=False, allow_infinity=False)
_arrays = hnp.arrays(
    dtype=np.float64, shape=st.integers(min_value=1, max_value=300), elements=_lat
)


@settings(max_examples=200, deadline=None)
@given(
    lat=_arrays,
    qs=st.lists(
        st.floats(min_value=0.0, max_value=100.0, allow_nan=False),
        min_size=1,
        max_size=6,
    ),
)
def test_quantiles_within_relative_bucket_error(lat, qs):
    sk = LatencySketch.from_latencies(lat, CFG)
    got = sk.percentiles(tuple(qs))
    want = percentile_kernel(np, lat, tuple(qs))
    np.testing.assert_array_equal(want, np.percentile(lat, qs))
    rel = np.abs(got - want) / np.maximum(np.abs(want), 1e-300)
    assert rel.max() <= CFG.rel_error


@settings(max_examples=200, deadline=None)
@given(lat=_arrays)
def test_extremes_and_mean_exact(lat):
    sk = LatencySketch.from_latencies(lat, CFG)
    assert sk.min == lat.min() and sk.max == lat.max()
    assert sk.percentiles((0.0, 100.0))[0] == lat.min()
    assert sk.percentiles((0.0, 100.0))[1] == lat.max()
    np.testing.assert_allclose(sk.mean, lat.mean(), rtol=1e-9)


@settings(max_examples=100, deadline=None)
@given(lat=_arrays)
def test_sequential_fold_equals_vectorized(lat):
    state = sketch_init(np, CFG)
    for v in lat:
        state = sketch_update(np, state, v, CFG)
    seq = LatencySketch.from_state(CFG, state)
    ref = LatencySketch.from_latencies(lat, CFG)
    np.testing.assert_array_equal(seq.counts, ref.counts)
    assert seq.n == ref.n and seq.min == ref.min and seq.max == ref.max


@settings(max_examples=100, deadline=None)
@given(value=_lat, n=st.integers(min_value=1, max_value=50))
def test_all_ties_stay_within_one_bucket(value, n):
    lat = np.full(n, value)
    got = LatencySketch.from_latencies(lat, CFG).percentiles((0.0, 50.0, 99.9, 100.0))
    assert got[0] == value and got[3] == value  # extremes exact
    rel = np.abs(got - value) / value
    assert rel.max() <= CFG.rel_error


@settings(max_examples=100, deadline=None)
@given(
    a=_arrays,
    b=_arrays,
    q=st.floats(min_value=0.0, max_value=100.0, allow_nan=False),
)
def test_merge_quantiles_match_pooled_population(a, b, q):
    merged = LatencySketch.from_latencies(a, CFG).merge(
        LatencySketch.from_latencies(b, CFG)
    )
    pooled = np.concatenate([a, b])
    got = merged.percentiles((q,))[0]
    want = np.percentile(pooled, q)
    assert abs(got - want) / max(abs(want), 1e-300) <= CFG.rel_error


def test_jit_fold_matches_numpy_on_representative_population():
    """Cross-``xp`` half of the pin (hypothesis drives numpy; the jit scan
    fold is pinned bit-identical on one representative draw)."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    lat = rng.lognormal(10, 1.5, 513)
    state = sketch_init(np, CFG)
    for v in lat:
        state = sketch_update(np, state, v, CFG)

    def step(s, v):
        return sketch_update(jnp, s, v, CFG), None

    with jax.experimental.enable_x64():
        out, _ = jax.jit(lambda s, x: jax.lax.scan(step, s, x))(
            tuple(jnp.asarray(a) for a in sketch_init(jnp, CFG)), jnp.asarray(lat)
        )
    for a, b in zip(state, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
