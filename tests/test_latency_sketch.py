"""``fabric.metrics`` streaming latency sketch: the fixed-size log-bucket
summary that replaces the (configs, requests) latency matrix at fleet scale.

Contracts:

  * quantile estimates land within the documented relative-bucket error
    (``SketchConfig.rel_error = 1 / bins_per_octave``) of the exact
    ``percentile_kernel`` / ``np.percentile`` values for in-range data;
  * min / max / mean / variance are EXACT (tracked outside the buckets:
    p0 and p100 return the true extremes even for out-of-range data);
  * the sequential in-carry update (numpy fold and jit ``lax.scan`` fold)
    is bit-identical to the vectorized ``from_latencies`` reference;
  * merging sketches is exact on counts and moments.
"""

import numpy as np
import pytest

from repro.fabric.metrics import (
    LatencySketch,
    SketchConfig,
    percentile_kernel,
    sketch_bucket,
    sketch_init,
    sketch_update,
)

CFG = SketchConfig()
QS = (0.0, 50.0, 95.0, 99.0, 100.0)


def _seq_sketch(lat, cfg=CFG):
    state = sketch_init(np, cfg)
    for v in lat:
        state = sketch_update(np, state, v, cfg)
    return LatencySketch.from_state(cfg, state)


@pytest.mark.parametrize(
    "name,lat",
    [
        ("lognormal", np.random.default_rng(0).lognormal(10, 1.5, 4000)),
        ("heavy_tail", np.random.default_rng(1).pareto(1.5, 4000) * 1e4 + 1.0),
        ("ties", np.repeat([3.0, 17.0, 1e6], 500)),
        ("single", np.array([12345.6])),
        ("two", np.array([2.0, 9.0])),
    ],
)
def test_quantiles_within_documented_error(name, lat):
    sk = LatencySketch.from_latencies(lat, CFG)
    ref = np.percentile(lat, QS)
    got = sk.percentiles(QS)
    rel = np.abs(got - ref) / np.maximum(np.abs(ref), 1e-300)
    assert rel.max() <= CFG.rel_error, (name, rel.max())


def test_extremes_and_moments_exact():
    rng = np.random.default_rng(2)
    lat = rng.gamma(2.0, 3e4, 2000)
    sk = LatencySketch.from_latencies(lat, CFG)
    assert sk.min == lat.min() and sk.max == lat.max()
    assert sk.percentiles((0.0,))[0] == lat.min()
    assert sk.percentiles((100.0,))[0] == lat.max()
    np.testing.assert_allclose(sk.mean, lat.mean(), rtol=1e-12)
    np.testing.assert_allclose(sk.variance, lat.var(), rtol=1e-9)


def test_out_of_range_values_keep_exact_extremes():
    """Values below 2^min_exp clamp into bucket 0, but p0/p100 still report
    the tracked true extremes, never a bucket midpoint."""
    lat = np.array([1e-6, 0.25, 3.0, 9.0])
    sk = LatencySketch.from_latencies(lat, CFG)
    assert sk.min == 1e-6 and sk.percentiles((0.0,))[0] == 1e-6
    assert sk.max == 9.0 and sk.percentiles((100.0,))[0] == 9.0


def test_sequential_update_equals_vectorized_reference():
    rng = np.random.default_rng(3)
    lat = rng.lognormal(8, 2.0, 300)
    seq = _seq_sketch(lat)
    ref = LatencySketch.from_latencies(lat, CFG)
    np.testing.assert_array_equal(seq.counts, ref.counts)
    assert seq.n == ref.n and seq.min == ref.min and seq.max == ref.max
    np.testing.assert_allclose(seq.mean, ref.mean, rtol=1e-12)
    np.testing.assert_allclose(seq.m2, ref.m2, rtol=1e-9)


def test_jit_scan_fold_bit_identical_to_numpy():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    rng = np.random.default_rng(4)
    lat = rng.lognormal(9, 1.2, 257)
    with jax.experimental.enable_x64():
        bnp = sketch_bucket(np, lat, CFG)
        bjx = np.asarray(sketch_bucket(jnp, jnp.asarray(lat), CFG))
        np.testing.assert_array_equal(bnp, bjx)

        def step(state, v):
            return sketch_update(jnp, state, v, CFG), None

        state0 = tuple(jnp.asarray(a) for a in sketch_init(jnp, CFG))
        out, _ = jax.jit(lambda s, x: jax.lax.scan(step, s, x))(
            state0, jnp.asarray(lat)
        )
    ref = _seq_sketch(lat)
    got = LatencySketch.from_state(CFG, tuple(np.asarray(a) for a in out))
    np.testing.assert_array_equal(got.counts, ref.counts)
    assert (got.n, got.min, got.max) == (ref.n, ref.min, ref.max)
    assert got.mean == ref.mean and got.m2 == ref.m2  # bit-identical Welford


def test_merge_is_exact():
    rng = np.random.default_rng(5)
    a, b = rng.lognormal(8, 1.0, 400), rng.lognormal(10, 0.5, 300)
    merged = LatencySketch.from_latencies(a, CFG).merge(
        LatencySketch.from_latencies(b, CFG)
    )
    both = LatencySketch.from_latencies(np.concatenate([a, b]), CFG)
    np.testing.assert_array_equal(merged.counts, both.counts)
    assert merged.min == both.min and merged.max == both.max
    np.testing.assert_allclose(merged.mean, both.mean, rtol=1e-12)
    np.testing.assert_allclose(merged.m2, both.m2, rtol=1e-9)


def test_empty_sketch_is_defined():
    sk = LatencySketch.from_latencies([], CFG)
    assert sk.n == 0
    assert np.all(sk.counts == 0)


def test_config_validation():
    with pytest.raises(ValueError):
        SketchConfig(bins_per_octave=12)  # not a power of two
    assert SketchConfig(bins_per_octave=64).rel_error == 1.0 / 64


def test_stats_view_matches_percentile_kernel_within_bound():
    """The LatencyStats adapter (p50/p95/p99 via the sketch) stays within
    rel_error of the exact shared reduction."""
    rng = np.random.default_rng(6)
    lat = rng.lognormal(11, 1.0, 3000)
    st = LatencySketch.from_latencies(lat, CFG).stats
    ref = percentile_kernel(np, lat, (50.0, 95.0, 99.0))
    for got, want in zip((st.p50, st.p95, st.p99), ref):
        assert abs(got - want) / want <= CFG.rel_error
