"""Unit + property tests for the crossbar cost model."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: pip install .[dev]
from hypothesis import given, settings, strategies as st

from repro.core.cim.cost import (
    ArrayConfig,
    DEFAULT_ARRAY,
    baseline_cycles,
    bitplane_ones,
    expected_cycles_from_density,
    zskip_cycles,
)


def test_cycle_range_matches_paper():
    """Paper: 'each array takes anywhere from 64 to 1024 cycles'."""
    assert DEFAULT_ARRAY.min_cycles() == 64
    assert DEFAULT_ARRAY.max_cycles() == 1024
    assert DEFAULT_ARRAY.logical_cols == 16  # 128x16 dot product per array


def test_zero_input_hits_min():
    x = np.zeros(128, dtype=np.uint8)
    assert zskip_cycles(x) == 64


def test_all_ones_hits_max():
    x = np.full(128, 255, dtype=np.uint8)
    assert zskip_cycles(x) == 1024


def test_baseline_is_worst_case():
    assert baseline_cycles(128) == 1024
    assert baseline_cycles(64) == 512


def test_bitplane_ones_simple():
    # 0b10000001 = 129: MSB and LSB planes set.
    x = np.array([129, 129], dtype=np.uint8)
    ones = bitplane_ones(x)
    assert ones.tolist() == [2, 0, 0, 0, 0, 0, 0, 2]


@given(
    st.integers(1, 128).flatmap(
        lambda r: st.lists(st.integers(0, 255), min_size=r, max_size=r)
    )
)
@settings(max_examples=200, deadline=None)
def test_zskip_never_exceeds_baseline(vals):
    """Property: zero-skipping only ever helps (paper Section III)."""
    x = np.asarray(vals, dtype=np.uint8)
    z = int(zskip_cycles(x))
    b = int(baseline_cycles(len(vals)))
    assert DEFAULT_ARRAY.min_cycles() <= z <= b


@given(st.lists(st.integers(0, 255), min_size=16, max_size=128))
@settings(max_examples=100, deadline=None)
def test_monotone_in_bits(vals):
    """Adding '1' bits can only increase (or keep) cycle count."""
    x = np.asarray(vals, dtype=np.uint8)
    denser = x | np.asarray(
        np.random.default_rng(0).integers(0, 256, size=x.shape), dtype=np.uint8
    )
    assert int(zskip_cycles(denser)) >= int(zskip_cycles(x))


def test_expected_cycles_linear_in_density():
    """Paper Fig 4: linear relationship between density and cycles."""
    d = np.linspace(0.1, 0.9, 9)
    e = expected_cycles_from_density(d, 128)
    diffs = np.diff(e)
    assert np.allclose(diffs, diffs[0])  # exactly linear above the floor
    assert e[0] < e[-1]


def test_expected_matches_monte_carlo():
    rng = np.random.default_rng(1)
    p = 0.3
    # uint8 values with iid bit density p
    bits = (rng.random((4096, 128, 8)) < p).astype(np.uint8)
    vals = np.packbits(bits, axis=-1)[..., 0]
    mc = zskip_cycles(vals).mean()
    analytic = float(expected_cycles_from_density(p, 128))
    assert abs(mc - analytic) / analytic < 0.08
