"""Per-architecture smoke tests: reduced config, one forward + one train-grad
step + one decode step on CPU; output shapes and finiteness asserted.

The FULL configs are exercised only through the dry-run (ShapeDtypeStruct,
no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (
    decode,
    encode,
    encdec_loss_fn,
    forward,
    init_cache,
    init_decoder_cache,
    init_encdec_params,
    init_params,
    loss_fn,
)

B, S = 2, 16


def _tokens(key, cfg, s=S):
    return jax.random.randint(key, (B, s), 0, cfg.vocab)


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if a != "whisper-medium"])
def test_lm_forward_and_grad(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    toks = _tokens(key, cfg)
    logits, _ = forward(params, cfg, toks)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    loss, grads = jax.value_and_grad(loss_fn)(params, cfg, toks[:, :-1], toks[:, 1:])
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves and all(bool(jnp.isfinite(g).all()) for g in leaves)
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves)


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if a != "whisper-medium"])
def test_lm_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    cache = init_cache(cfg, B, max_seq=32)
    tok = _tokens(key, cfg, s=1)
    logits, cache = forward(params, cfg, tok, cache=cache)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # second step must also work (cache advanced)
    logits2, cache = forward(params, cfg, tok, cache=cache)
    assert bool(jnp.isfinite(logits2.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if a != "whisper-medium"])
def test_decode_matches_forward(arch):
    """Property: token-by-token decode == full forward (teacher forcing)."""
    import dataclasses

    cfg = get_config(arch, smoke=True).with_(dtype="float32")
    if cfg.family == "moe":
        # capacity dropping is shape-dependent (N tokens vs 1); disable drops
        # so the equivalence is exact.
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    toks = _tokens(key, cfg, s=8)
    full_logits, _ = forward(params, cfg, toks)

    cache = init_cache(cfg, B, max_seq=16, dtype=jnp.float32)
    outs = []
    for t in range(8):
        lg, cache = forward(params, cfg, toks[:, t : t + 1], cache=cache)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full_logits), np.asarray(dec_logits), rtol=2e-2, atol=2e-2
    )


def test_whisper_smoke():
    cfg = get_config("whisper-medium", smoke=True)
    key = jax.random.PRNGKey(3)
    params = init_encdec_params(cfg, key)
    frames = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model))
    toks = _tokens(key, cfg)
    enc = encode(params, cfg, frames)
    assert enc.shape == (B, cfg.encoder_seq, cfg.d_model)
    logits, _ = decode(params, cfg, toks, enc)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    loss, grads = jax.value_and_grad(encdec_loss_fn)(
        params, cfg, frames, toks[:, :-1], toks[:, 1:]
    )
    assert np.isfinite(float(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))


def test_whisper_decode_cache_matches():
    cfg = get_config("whisper-medium", smoke=True).with_(dtype="float32")
    key = jax.random.PRNGKey(4)
    params = init_encdec_params(cfg, key)
    frames = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model))
    toks = _tokens(key, cfg, s=6)
    enc = encode(params, cfg, frames)
    full, _ = decode(params, cfg, toks, enc)
    cache = init_decoder_cache(cfg, B, max_seq=8, dtype=jnp.float32)
    outs = []
    for t in range(6):
        lg, cache = decode(params, cfg, toks[:, t : t + 1], enc, cache=cache)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(
        np.asarray(full), np.asarray(jnp.stack(outs, 1)), rtol=2e-2, atol=2e-2
    )


def test_param_counts_match_published_sizes():
    """FULL configs should land within ~15% of the published param counts."""
    expected = {
        "nemotron-4-15b": 15e9,
        "glm4-9b": 9e9,
        "qwen1.5-110b": 110e9,
        "qwen2.5-32b": 32e9,
        "mamba2-370m": 0.37e9,
        "deepseek-v2-236b": 236e9,
        "grok-1-314b": 314e9,
        "qwen2-vl-2b": 2e9,
        "zamba2-1.2b": 1.2e9,
    }
    for arch, target in expected.items():
        got = get_config(arch).param_count()
        assert 0.7 * target < got < 1.45 * target, (arch, got, target)
