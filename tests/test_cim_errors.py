"""Error-path coverage for the CIM stack: allocate(free_budget=...)
validation, profile_network's unknown-network guard, and the batched-engine
input validation."""

import numpy as np
import pytest

from repro.core.cim import (
    LayerSpec,
    NetworkSpec,
    allocate,
    profile_network,
    vgg11_cifar10,
)
from repro.core.cim.simulate import ARRAYS_PER_PE, BatchSimulator
from repro.dse import allocate_batch, get_profiled


@pytest.fixture(scope="module")
def vgg(profiled):
    return profiled("vgg11", n_images=1, sample_patches=32)


# ------------------------------------------------------ allocate(free_budget=)
def test_free_budget_negative_raises(vgg):
    spec, prof = vgg
    with pytest.raises(ValueError, match="free_budget"):
        allocate(spec, prof, "blockwise", spec.min_pes() * 2, free_budget=-1.0)


def test_free_budget_above_free_raises(vgg):
    spec, prof = vgg
    n_pes = spec.min_pes() * 2
    free = n_pes * ARRAYS_PER_PE - spec.n_arrays
    with pytest.raises(ValueError, match="outside"):
        allocate(spec, prof, "blockwise", n_pes, free_budget=free + 1)


@pytest.mark.parametrize("policy", ["blockwise", "perf_layerwise", "weight_based"])
def test_free_budget_zero_means_no_duplicates(vgg, policy):
    spec, prof = vgg
    a = allocate(spec, prof, policy, spec.min_pes() * 2, free_budget=0.0)
    assert a.arrays_used == spec.n_arrays
    dups = a.layer_dups if a.layer_dups is not None else np.concatenate(a.block_dups)
    assert (np.asarray(dups) == 1).all()


def test_free_budget_caps_spend(vgg):
    spec, prof = vgg
    n_pes = spec.min_pes() * 2
    cap = 100.0
    a = allocate(spec, prof, "blockwise", n_pes, free_budget=cap)
    assert a.arrays_used <= spec.n_arrays + cap


def test_allocate_below_minimum_raises(vgg):
    spec, prof = vgg
    with pytest.raises(ValueError, match="minimum"):
        allocate(spec, prof, "blockwise", n_pes=1)


def test_allocate_unknown_policy_raises(vgg):
    spec, prof = vgg
    with pytest.raises(ValueError):
        allocate(spec, prof, "optimal", spec.min_pes() * 2)


# -------------------------------------------------------------- profile_network
def test_profile_unknown_network_raises():
    spec = NetworkSpec("mystery", (LayerSpec("l0", 3, 3, 8, 8),))
    with pytest.raises(ValueError, match="no forward plan"):
        profile_network(spec, n_images=1, sample_patches=8)


def test_profile_mixed_array_configs_raises():
    layers = vgg11_cifar10().layers
    mixed = NetworkSpec(
        "vgg11",
        (layers[0], *(LayerSpec(l.name, l.kernel, l.cin, l.cout, l.out_hw,
                                l.stride, l.array.variant(adc_bits=5))
                      for l in layers[1:])),
    )
    with pytest.raises(ValueError, match="array configs"):
        profile_network(mixed, n_images=1, sample_patches=8)


def test_get_profiled_unknown_network_raises():
    with pytest.raises(ValueError, match="unknown network"):
        get_profiled("alexnet")


# ------------------------------------------------------------------ batched dse
def test_allocate_batch_unknown_policy_raises(vgg):
    spec, prof = vgg
    with pytest.raises(ValueError, match="unknown policies"):
        allocate_batch(spec, prof, ["blockwise", "optimal"], spec.min_pes() * 2)


def test_allocate_batch_below_minimum_raises(vgg):
    spec, prof = vgg
    with pytest.raises(ValueError, match="minimum"):
        allocate_batch(spec, prof, "blockwise", [spec.min_pes() * 2, 1])


def test_batch_simulator_rejects_bad_shape(vgg):
    spec, prof = vgg
    sim = BatchSimulator(spec, prof)
    with pytest.raises(ValueError, match="dups_lb"):
        sim(np.ones((2, 3, 4)), np.ones(2, bool), np.ones(2, bool))
