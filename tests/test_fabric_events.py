"""Unit tests for the discrete-event core: pool scheduling must be exactly
FIFO-c-server, arrivals must have the advertised statistics."""

import heapq

import numpy as np
import pytest

from repro.fabric import (
    ClosedLoop,
    EventCalendar,
    PoissonOpen,
    ServerPool,
    TraceReplay,
    arrival_times,
    latency_stats,
    steady_throughput,
)


def _brute_force_fifo(n_servers, batches):
    """One-event-per-job reference: (t_ready, services) batches in time order."""
    avail = [0.0] * n_servers
    ends = []
    for t, services in batches:
        for s in services:
            heapq.heapify(avail)
            a = max(heapq.heappop(avail), t)
            heapq.heappush(avail, a + s)
            ends.append(a + s)
    return ends


@pytest.mark.parametrize("n_servers", [1, 2, 3, 7])
def test_pool_matches_brute_force(n_servers):
    rng = np.random.default_rng(0)
    pool = ServerPool(n_servers)
    batches = []
    t = 0.0
    for _ in range(20):
        t += rng.exponential(5.0)
        s = rng.exponential(3.0, size=rng.integers(1, 12))
        batches.append((t, s))
    got = [pool.dispatch(t, s) for t, s in batches]
    ref_ends = _brute_force_fifo(n_servers, batches)
    # batch completion = max end among the batch's jobs
    k, ref = 0, []
    for _, s in batches:
        ref.append(max(ref_ends[k : k + len(s)]))
        k += len(s)
    np.testing.assert_allclose(got, ref, rtol=1e-12)
    assert pool.jobs == sum(len(s) for _, s in batches)
    assert pool.busy == pytest.approx(sum(s.sum() for _, s in batches))


def test_pool_more_servers_never_slower():
    rng = np.random.default_rng(1)
    s = rng.exponential(2.0, size=200)
    ends = []
    for d in (1, 2, 4, 8):
        pool = ServerPool(d)
        ends.append(pool.dispatch(0.0, s))
    assert all(a >= b - 1e-9 for a, b in zip(ends, ends[1:]))
    # lower bounds: work conservation and the longest job
    assert ends[-1] >= s.sum() / 8 - 1e-9
    assert ends[-1] >= s.max() - 1e-9


def test_pool_grow_and_freeze():
    pool = ServerPool(1)
    end = pool.dispatch(0.0, np.array([10.0, 10.0]))
    assert end == pytest.approx(20.0)
    pool.freeze_until(100.0)
    assert pool.dispatch(0.0, np.array([1.0])) == pytest.approx(101.0)
    pool.grow(1, t_free=200.0)
    # old server free at 101: job1 runs 150->155 there; job2 FIFO-picks the
    # earliest-free server, which is the old one again (155) not the new (200)
    end = pool.dispatch(150.0, np.array([5.0, 5.0]))
    assert end == pytest.approx(160.0)
    assert pool.n_servers == 2
    # a long batch spills onto the new server once it is online:
    # old(160): 160->210, new(200): 200->250, old again: 210->260
    end = pool.dispatch(160.0, np.array([50.0, 50.0, 50.0]))
    assert end == pytest.approx(260.0)


def test_pool_timeline_accounts_all_busy_cycles():
    rng = np.random.default_rng(2)
    pool = ServerPool(3, width=4, record_starts=True)
    s = rng.exponential(2.0, size=50)
    end = pool.dispatch(0.0, s)
    tl = pool.timeline(bucket=1.0, horizon=end)
    assert tl.sum() == pytest.approx(s.sum() * 4)


def test_pool_tie_break_is_lowest_index():
    """Replicas freeing at the same cycle must be chosen lowest-index-first
    (deterministic, matching the vtime kernel) — observable via the stored
    per-server free times."""
    pool = ServerPool(3)
    pool.dispatch(0.0, np.array([2.0]))
    assert pool.avail == [2.0, 0.0, 0.0]  # server 0, not an arbitrary heap pick
    pool.dispatch(0.0, np.array([1.0]))
    assert pool.avail == [2.0, 1.0, 0.0]
    # grown server ties with an old one at t_free: the old (lower) index wins
    pool = ServerPool(1)
    pool.freeze_until(5.0)
    pool.grow(1, t_free=5.0)
    pool.dispatch(0.0, np.array([3.0]))
    assert pool.avail == [8.0, 5.0]


def test_event_calendar_orders_ties_by_insertion():
    cal = EventCalendar()
    cal.push(5.0, 1, 0)
    cal.push(1.0, 2, 0)
    cal.push(5.0, 3, 0)
    assert [cal.pop()[1] for _ in range(3)] == [2, 1, 3]
    assert len(cal) == 0


def test_poisson_rate_and_trace_validation():
    proc = PoissonOpen(n_requests=4000, rate_per_cycle=1 / 50.0, seed=0)
    t = arrival_times(proc)
    assert t.size == 4000
    mean_gap = t[-1] / t.size
    assert mean_gap == pytest.approx(50.0, rel=0.1)
    assert arrival_times(ClosedLoop(10, 2)) is None
    with pytest.raises(ValueError):
        arrival_times(TraceReplay(np.array([3.0, 1.0])))


def test_latency_stats_and_steady_throughput():
    lat = np.arange(1, 101, dtype=np.float64)
    st = latency_stats(lat)
    assert st.n == 100 and st.max == 100.0
    assert st.p50 == pytest.approx(50.5)
    assert st.p99 >= st.p95 >= st.p50
    # constant completion rate: 1 per 10 cycles regardless of warmup trim
    comp = np.arange(0, 1000, 10.0)
    assert steady_throughput(comp) == pytest.approx(0.1)
    assert steady_throughput(comp, clock_hz=100.0) == pytest.approx(10.0)
    assert steady_throughput(np.array([5.0])) == 0.0
