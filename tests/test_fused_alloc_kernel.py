"""Property suites for the two allocation fast paths the fused DSE
pipeline leans on:

  * ``greedy_event_schedule`` — the static grant-event table must answer
    EVERY budget with replica vectors element-wise identical to the scalar
    heap greedy (``greedy_allocate``) and the lock-step batch kernel
    (``greedy_allocate_batch``), warm starts and ties included.  The
    schedule's exactness argument (priorities are the heap's own float64
    quotients; integer costs make prefix sums exact; ``searchsorted`` IS
    the stopping rule) lives in ``core/alloc/greedy.py`` — these
    properties are its enforcement.
  * ``kernels.fused_alloc_eval`` — the in-kernel greedy must return the
    same replicas as ``greedy_allocate_batch`` on random profiles (it
    calls the same kernel body; interpret mode, float64).

Hypothesis draws integer-valued bases from a SMALL pool so priority ties
across units are common — the regime where heap tie-order (lowest unit
index first) is actually observable.  The no-hypothesis (minimal-env)
deterministic counterparts live in ``test_alloc_warmstart.py`` and
``test_kernels.py``.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep: pip install .[dev]
from hypothesis import given, settings, strategies as st

from repro.core.alloc.greedy import (
    greedy_allocate,
    greedy_allocate_batch,
    greedy_event_schedule,
)


@st.composite
def _problem(draw, max_units=8):
    n = draw(st.integers(1, max_units))
    # small integer pools force cross-unit priority ties
    base = np.array(
        draw(st.lists(st.integers(1, 12), min_size=n, max_size=n)), dtype=np.float64
    )
    cost = np.array(
        draw(st.lists(st.integers(1, 4), min_size=n, max_size=n)), dtype=np.float64
    )
    warm = draw(st.booleans())
    r0 = (
        np.array(
            draw(st.lists(st.integers(1, 3), min_size=n, max_size=n)),
            dtype=np.int64,
        )
        if warm
        else None
    )
    budgets = np.array(
        draw(st.lists(st.integers(0, 40), min_size=1, max_size=6)),
        dtype=np.float64,
    )
    return base, cost, r0, budgets


# --------------------------------------------------- event schedule == heap
@given(_problem())
@settings(max_examples=60, deadline=None)
def test_event_schedule_matches_scalar_heap(problem):
    base, cost, r0, budgets = problem
    sched = greedy_event_schedule(
        base, cost, float(budgets.max()), initial_replicas=r0
    )
    got = sched.replicas_at(budgets)
    for i, b in enumerate(budgets):
        want = greedy_allocate(base, cost, float(b), initial_replicas=r0)
        np.testing.assert_array_equal(
            got.replicas[i], want.replicas, err_msg=f"budget {b}"
        )
        assert got.spent[i] == want.spent
        assert got.leftover[i] == want.leftover


@given(_problem())
@settings(max_examples=30, deadline=None)
def test_event_schedule_matches_batch_kernel(problem):
    base, cost, r0, budgets = problem
    sched = greedy_event_schedule(
        base, cost, float(budgets.max()), initial_replicas=r0
    )
    got = sched.replicas_at(budgets)
    want = greedy_allocate_batch(base, cost, budgets, initial_replicas=r0)
    np.testing.assert_array_equal(got.replicas, want.replicas)
    np.testing.assert_array_equal(got.leftover, want.leftover)


# ------------------------------------------- in-kernel greedy == batch kernel
@given(_problem(max_units=5), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_fused_kernel_greedy_matches_batch(problem, seed):
    from jax.experimental import enable_x64

    from repro.kernels.fused_alloc_eval import fused_alloc_eval

    base, cost, r0, budgets = problem
    n = base.size
    c = budgets.size
    rng = np.random.default_rng(seed)
    a = rng.integers(1, 3)
    l, b = rng.integers(1, 4), rng.integers(1, 4)
    bases = np.broadcast_to(base, (a, n)).copy()
    owner = rng.integers(0, n, size=(l, b))
    umap = np.zeros((n, l, b))
    umap[owner, np.arange(l)[:, None], np.arange(b)[None, :]] = 1.0
    v = 2 * a
    banks = (
        rng.integers(1, 50, size=(v, l, b)).astype(np.float64),
        rng.integers(50, 99, size=(v, l, b)).astype(np.float64),
        rng.integers(1, 50, size=(v, l)).astype(np.float64),
        rng.integers(50, 99, size=(v, l)).astype(np.float64),
        rng.integers(1, 50, size=(v, l)).astype(np.float64),
    )
    a_idx = rng.integers(0, a, size=c).astype(np.int32)
    r0_b = np.ones((c, n)) if r0 is None else np.broadcast_to(r0, (c, n)).copy()
    with enable_x64():
        *_, r, rem = fused_alloc_eval(
            bases, cost, umap, banks, np.ones((l, b), bool),
            np.ones(l), np.ones(l), np.ones(l),
            budgets, a_idx, a_idx.copy(),
            rng.integers(0, 2, size=c).astype(bool), r0_b,
            block_configs=max(1, c // 2), interpret=True,
        )
    want = greedy_allocate_batch(base, cost, budgets, initial_replicas=r0_b)
    np.testing.assert_array_equal(np.asarray(r), want.replicas)
    np.testing.assert_array_equal(np.asarray(rem), want.leftover)
