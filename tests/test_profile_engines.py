"""The batched profiling engine is provably behavior-preserving.

Two invariants, deliberately held to different strengths:

  * **Cross-engine bit-identity** (the real contract): reference,
    vectorized and Pallas (interpret) derivations from ONE shared
    activation capture must agree bit for bit — densities, cycle samples,
    digests.  Any divergence is an engine bug, never environment noise.
  * **Engine vs committed golden** (environment-gated): the pinned
    tests/golden/<net>_profile.json fixtures carry the generating
    container's ``env`` stamp (jax/jaxlib/numpy/python/platform/backend).
    When the running environment MATCHES the stamp, the comparison is
    bit-exact — float lists, sample sums, sha256 cycle digests — because
    no legitimate source of drift exists there.  When it differs,
    XLA-version-sensitive matmul ulps through the deep resnet18 BN stacks
    shift a handful of quantized bit counts (observed density drift
    <= 1.2e-4 across containers), so the comparison holds structure
    exactly (names, shapes, baseline cycles) but numerics to a documented
    tolerance: density atol 1e-2, cycle statistics rtol 2e-2.

A geometry VIEW derived from the capture must also equal a from-scratch
``profile_network`` at the same geometry.
"""

import json
import pathlib

import numpy as np
import pytest

from repro.core.cim import (
    DEFAULT_ARRAY,
    PROFILE_ENGINES,
    capture_activations,
    derive_profile,
    profile_network,
    resnet18_imagenet,
    vgg11_cifar10,
    with_array,
)

GOLDEN = pathlib.Path(__file__).parent / "golden"
_SPEC_FNS = {"resnet18": resnet18_imagenet, "vgg11": vgg11_cifar10}


def _digest(cycles_sample: np.ndarray) -> str:
    import hashlib

    return hashlib.sha256(
        np.ascontiguousarray(cycles_sample.astype("<i8")).tobytes()
    ).hexdigest()


@pytest.fixture(scope="module", params=["vgg11", "resnet18"])
def pinned_capture(request):
    g = json.loads((GOLDEN / f"{request.param}_profile.json").read_text())
    spec = _SPEC_FNS[request.param]()
    cap = capture_activations(
        spec,
        n_images=g["profile_params"]["n_images"],
        sample_patches=g["profile_params"]["sample_patches"],
    )
    return spec, cap, g


def test_engines_bit_identical_from_shared_capture(pinned_capture):
    """reference == vectorized == pallas, BIT for bit, from one capture.

    This is the contract the golden fixtures used to carry; it lives
    in-session now so environment ulp drift cannot mask an engine bug."""
    spec, cap, _ = pinned_capture
    ref = derive_profile(cap, spec, engine="reference")
    for engine in ("vectorized", "pallas"):
        prof = derive_profile(cap, spec, engine=engine)
        for a, b in zip(ref.layers, prof.layers):
            assert a.name == b.name
            np.testing.assert_array_equal(a.block_density, b.block_density)
            np.testing.assert_array_equal(a.mean_cycles, b.mean_cycles)
            np.testing.assert_array_equal(a.cycles_sample, b.cycles_sample)
            np.testing.assert_array_equal(
                a.baseline_block_cycles, b.baseline_block_cycles
            )
            assert _digest(a.cycles_sample) == _digest(b.cycles_sample)


def _env_matches_fixture(g) -> bool:
    """True iff the running environment equals the fixture's generating
    container stamp — the gate between bit-exact and tolerant compare."""
    import sys

    sys.path.insert(0, str(GOLDEN))
    try:
        from regen import environment_stamp
    finally:
        sys.path.remove(str(GOLDEN))
    return g.get("env") == environment_stamp()


@pytest.mark.parametrize("engine", PROFILE_ENGINES)
def test_engines_match_profile_golden(pinned_capture, engine):
    """Engine vs committed fixture: bit-exact when the running environment
    matches the fixture's ``env`` stamp, structure-exact + documented
    numeric tolerance otherwise (see module docstring)."""
    spec, cap, g = pinned_capture
    exact = _env_matches_fixture(g)
    prof = derive_profile(cap, spec, engine=engine)
    assert len(prof.layers) == len(g["layers"])
    for lp, rec in zip(prof.layers, g["layers"]):
        assert lp.name == rec["name"]
        assert lp.patches_per_image == rec["patches_per_image"]
        # structure and geometry-derived integers are environment-free
        assert (
            lp.baseline_block_cycles.tolist() == rec["baseline_block_cycles"]
        ), (engine, lp.name)
        assert list(lp.cycles_sample.shape) == rec["cycles_sample_shape"]
        if exact:
            # same container as the fixture: any divergence is a real bug,
            # so hold the full bit-exact contract including the digest
            assert lp.block_density.tolist() == rec["block_density"], (
                engine, lp.name, "block_density",
            )
            assert lp.mean_cycles.tolist() == rec["mean_cycles"], (
                engine, lp.name, "mean_cycles",
            )
            assert int(lp.cycles_sample.sum()) == rec["cycles_sample_sum"]
            assert _digest(lp.cycles_sample) == rec["cycles_sample_sha256"], (
                engine, lp.name, "cycles_sample_sha256",
            )
            continue
        # numerics: XLA matmul ulps through deep BN stacks perturb a few
        # quantized bit counts per container — compare distributionally
        np.testing.assert_allclose(
            lp.block_density, rec["block_density"], atol=1e-2, rtol=0,
            err_msg=f"{engine}/{lp.name} block_density",
        )
        np.testing.assert_allclose(
            lp.mean_cycles, rec["mean_cycles"], rtol=2e-2,
            err_msg=f"{engine}/{lp.name} mean_cycles",
        )
        np.testing.assert_allclose(
            float(lp.cycles_sample.sum()), float(rec["cycles_sample_sum"]),
            rtol=2e-2, err_msg=f"{engine}/{lp.name} cycles_sample_sum",
        )


def test_profile_network_is_capture_plus_derive(pinned_capture):
    """The one-shot API equals the two-phase API bit for bit."""
    spec, cap, g = pinned_capture
    one_shot = profile_network(spec, **g["profile_params"])
    derived = derive_profile(cap, spec)
    for a, b in zip(one_shot.layers, derived.layers):
        np.testing.assert_array_equal(a.block_density, b.block_density)
        np.testing.assert_array_equal(a.cycles_sample, b.cycles_sample)
        np.testing.assert_array_equal(a.mean_cycles, b.mean_cycles)
        np.testing.assert_array_equal(a.baseline_block_cycles, b.baseline_block_cycles)


@pytest.fixture(scope="module")
def vgg_capture():
    return capture_activations(vgg11_cifar10(), n_images=1, sample_patches=64)


@pytest.mark.parametrize(
    "variant",
    [dict(rows=256, cols=256), dict(adc_bits=2), dict(adc_bits=5, rows=64, cols=64)],
)
def test_geometry_view_equals_fresh_profile(vgg_capture, variant):
    """A derived view for a swept geometry == re-profiling from scratch at
    that geometry — the forward really is geometry-independent."""
    array = DEFAULT_ARRAY.variant(**variant)
    spec = vgg11_cifar10()
    cap = vgg_capture
    spec_g = with_array(spec, array)
    view = derive_profile(cap, spec_g, array=array)
    fresh = profile_network(spec_g, n_images=1, sample_patches=64)
    for a, b, layer in zip(view.layers, fresh.layers, spec_g.layers):
        assert a.cycles_sample.shape[1] == layer.n_blocks
        np.testing.assert_array_equal(a.block_density, b.block_density)
        np.testing.assert_array_equal(a.cycles_sample, b.cycles_sample)
        np.testing.assert_array_equal(a.baseline_block_cycles, b.baseline_block_cycles)


def test_adc_view_recosts_without_changing_block_shapes(vgg_capture):
    """Same row slicing, different ADC: densities identical, cycles differ."""
    spec = vgg11_cifar10()
    cap = vgg_capture
    base = derive_profile(cap, spec)
    lowadc = derive_profile(cap, spec, array=DEFAULT_ARRAY.variant(adc_bits=2))
    for a, b in zip(base.layers, lowadc.layers):
        np.testing.assert_array_equal(a.block_density, b.block_density)
        assert a.cycles_sample.shape == b.cycles_sample.shape
        # 2-bit ADC reads 4 rows per cycle group instead of 8: never cheaper
        assert (b.cycles_sample >= a.cycles_sample).all()


def test_streaming_batches_cover_every_sample():
    """Streamed capture (batch_images < n_images) fills the full sample and
    accumulates rowbits over all patches — checked for CONTENT against an
    independent reassembly that gathers EVERY quantized patch of each batch
    from the same jit forward and applies the sample selection on the host,
    so an ownership-mask or rowbits-accumulation bug cannot hide."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core.cim import profile as P

    spec = vgg11_cifar10()
    n, spp, batch = 4, 48, 2
    cap = capture_activations(spec, n_images=n, sample_patches=spp, batch_images=batch)

    key = jax.random.PRNGKey(0)
    kimg, kw = jax.random.split(key)
    keys = jax.random.split(kw, len(spec.layers))
    weights = tuple(
        P._kaiming(keys[i], l.rows, l.cout) for i, l in enumerate(spec.layers)
    )
    x = P.synthetic_images(n, 32, kimg)
    rng = np.random.default_rng(0)
    sel = [
        rng.choice(n * l.patches_per_image, size=min(spp, n * l.patches_per_image), replace=False)
        for l in spec.layers
    ]
    rowbits = [np.zeros(l.rows, np.int64) for l in spec.layers]
    sampled = [np.zeros((len(s), l.rows), np.uint8) for s, l in zip(sel, spec.layers)]
    for i0 in range(0, n, batch):
        sel_full = tuple(
            jnp.arange(batch * l.patches_per_image, dtype=jnp.int32)
            for l in spec.layers
        )
        with enable_x64():
            rb, q_full = P._capture_jit(spec, weights, sel_full, x[i0 : i0 + batch])
        for li, layer in enumerate(spec.layers):
            rowbits[li] += np.asarray(rb[li])
            loc = sel[li] - i0 * layer.patches_per_image
            m = (loc >= 0) & (loc < batch * layer.patches_per_image)
            sampled[li][m] = np.asarray(q_full[li])[loc[m]]
    for lc, rb, qs, layer in zip(cap.layers, rowbits, sampled, spec.layers):
        assert lc.n_patches == n * layer.patches_per_image
        np.testing.assert_array_equal(lc.rowbits, rb)
        np.testing.assert_array_equal(lc.sampled_q, qs)


def test_derive_validates_engine_and_network():
    spec = vgg11_cifar10()
    cap = capture_activations(spec, n_images=1, sample_patches=8)
    with pytest.raises(ValueError, match="engine"):
        derive_profile(cap, spec, engine="gpu")
    with pytest.raises(ValueError, match="capture is for"):
        derive_profile(cap, resnet18_imagenet())


def test_capture_cache_split_shares_forward_across_geometries():
    """dse.get_profiled derives geometry views from ONE cached capture."""
    from repro.dse import clear_caches, get_captured, get_profiled
    from repro.dse.sweep import _CAPTURE_CACHE

    clear_caches()
    kw = dict(profile_images=1, sample_patches=32, seed=0)
    arrays = (DEFAULT_ARRAY, DEFAULT_ARRAY.variant(adc_bits=2),
              DEFAULT_ARRAY.variant(rows=256, cols=256))
    profs = [get_profiled("vgg11", a, **kw) for a in arrays]
    assert len(_CAPTURE_CACHE) == 1  # one forward for three geometries
    cap = get_captured("vgg11", **kw)
    for (spec, prof), arr in zip(profs, arrays):
        ref = derive_profile(cap, spec, array=arr)
        for a, b in zip(prof.layers, ref.layers):
            np.testing.assert_array_equal(a.cycles_sample, b.cycles_sample)
    with pytest.raises(ValueError, match="unknown network"):
        get_captured("alexnet")
    clear_caches()
