"""Perfetto trace export: structure of the generated JSON, the schema
validator used by CI, and the round-trip through ``write_trace``."""

import json

import numpy as np
import pytest

from repro.core.cim import FabricTopology, allocate, allocate_placed
from repro.core.cim.simulate import CLOCK_HZ
from repro.fabric import FabricSim, PoissonOpen
from repro.obs import build_trace, validate_trace, write_trace


@pytest.fixture(scope="module")
def traced_run(profiled):
    spec, prof = profiled("vgg11", n_images=1, sample_patches=64)
    alloc = allocate(spec, prof, "weight_based", spec.min_pes() * 2)
    proc = PoissonOpen(n_requests=12, rate_per_cycle=2000.0 / CLOCK_HZ, seed=5)
    sim = FabricSim(spec, prof, alloc, seed=3, record_timeline=True, stats=True)
    return spec, sim, sim.run(proc)


def test_build_trace_structure(traced_run):
    spec, sim, res = traced_run
    trace = build_trace(sim, res)
    assert trace["displayTimeUnit"] == "ms"
    evs = trace["traceEvents"]
    phases = {e["ph"] for e in evs}
    assert phases == {"M", "B", "E"}
    # every track got a name, the lone process is "fabric" + "requests"
    pnames = {
        e["args"]["name"] for e in evs if e["ph"] == "M" and e["name"] == "process_name"
    }
    assert pnames == {"fabric", "requests"}
    b = [e for e in evs if e["ph"] == "B"]
    e_ = [e for e in evs if e["ph"] == "E"]
    assert len(b) == len(e_) > 0
    # request tracks cover every (request, stage) residence span
    req = [x for x in b if x["pid"] == 1_000_000]
    assert len(req) == res.stats.stage_entry.size
    ts = [x["ts"] for x in evs if x["ph"] in "BE"]
    assert ts == sorted(ts)  # globally time-ordered
    assert validate_trace(trace) == len(b)


def test_build_trace_chip_processes(profiled):
    """With a placement, lanes group into one Perfetto process per chip."""
    spec, prof = profiled("vgg11", n_images=1, sample_patches=64)
    pes = spec.min_pes() * 2
    topo = FabricTopology.split(4, pes + (-pes) % 4, link_gbps=16.0)
    placed = allocate_placed(spec, prof, "blockwise", topo)
    proc = PoissonOpen(n_requests=8, rate_per_cycle=2000.0 / CLOCK_HZ, seed=5)
    sim = FabricSim(
        spec, prof, placed.allocation, seed=3,
        record_timeline=True, stats=True, placement=placed.placement,
    )
    res = sim.run(proc)
    trace = build_trace(sim, res, placement=placed.placement)
    pnames = {
        e["args"]["name"]
        for e in trace["traceEvents"]
        if e["ph"] == "M" and e["name"] == "process_name"
    }
    assert "requests" in pnames
    assert len(pnames - {"requests"}) > 1  # lanes spread over >1 chip
    assert all(n.startswith("chip") for n in sorted(pnames - {"requests"}))
    validate_trace(trace)


def test_merge_gap_coalesces_spans(traced_run):
    spec, sim, res = traced_run
    dense = build_trace(sim, res)
    merged = build_trace(sim, res, merge_gap=float("inf"))
    n_dense = sum(1 for e in dense["traceEvents"] if e["ph"] == "B")
    n_merged = sum(1 for e in merged["traceEvents"] if e["ph"] == "B")
    assert n_merged < n_dense  # lanes collapse to one span per lane
    validate_trace(merged)


def test_max_requests_caps_request_tracks(traced_run):
    spec, sim, res = traced_run
    trace = build_trace(sim, res, max_requests=3)
    req_tids = {
        e["tid"]
        for e in trace["traceEvents"]
        if e["ph"] == "B" and e["pid"] == 1_000_000
    }
    assert len(req_tids) == 3


def test_write_trace_round_trip(tmp_path, traced_run):
    spec, sim, res = traced_run
    p = tmp_path / "trace.json"
    write_trace(build_trace(sim, res), p)
    loaded = json.loads(p.read_text())
    assert validate_trace(loaded) > 0


# ----------------------------------------------------- validator negatives
def _pair(ts0, ts1, pid=1, tid=1, name="x"):
    return [
        {"ph": "B", "name": name, "pid": pid, "tid": tid, "ts": ts0},
        {"ph": "E", "name": name, "pid": pid, "tid": tid, "ts": ts1},
    ]


def test_validate_rejects_non_object():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_trace([])
    with pytest.raises(ValueError, match="list"):
        validate_trace({"traceEvents": "nope"})


def test_validate_rejects_backwards_timestamps():
    evs = _pair(0.0, 5.0) + _pair(3.0, 4.0)  # second B jumps back in time
    with pytest.raises(ValueError, match="backwards"):
        validate_trace({"traceEvents": evs})


def test_validate_rejects_unmatched_events():
    open_b = {"ph": "B", "name": "x", "pid": 1, "tid": 1, "ts": 0.0}
    with pytest.raises(ValueError, match="never closed"):
        validate_trace({"traceEvents": [open_b]})
    stray_e = {"ph": "E", "name": "x", "pid": 1, "tid": 1, "ts": 0.0}
    with pytest.raises(ValueError, match="no open B"):
        validate_trace({"traceEvents": [stray_e]})
    wrong_name = [
        {"ph": "B", "name": "a", "pid": 1, "tid": 1, "ts": 0.0},
        {"ph": "E", "name": "b", "pid": 1, "tid": 1, "ts": 1.0},
    ]
    with pytest.raises(ValueError, match="closes"):
        validate_trace({"traceEvents": wrong_name})


def test_validate_rejects_missing_fields():
    with pytest.raises(ValueError, match="missing"):
        validate_trace({"traceEvents": [{"ph": "B", "name": "x", "ts": 0.0}]})


def test_validate_skips_metadata_and_counters():
    evs = [
        {"ph": "M", "name": "process_name", "pid": 1, "args": {"name": "p"}},
        {"ph": "C", "name": "occupancy", "pid": 1, "ts": 0.0, "args": {"v": 1}},
    ] + _pair(0.0, 1.0)
    assert validate_trace({"traceEvents": evs}) == 1
