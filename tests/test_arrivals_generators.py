"""Non-stationary arrival generators (``fabric.arrivals``): the diurnal
sinusoidal-rate Poisson and the 2-state MMPP burst model that feed the
fleet replay bench.  Seeded, nondecreasing by construction, with empirical
rates matching the requested envelopes; plus the existing trace contract
(backwards time rejected with position)."""

import numpy as np
import pytest

from repro.fabric import (
    MMPP2,
    SinusoidalPoisson,
    TraceReplay,
    arrival_times,
)


def test_sinusoidal_monotone_seeded_and_sized():
    p = SinusoidalPoisson(n_requests=5000, base_rate=1e-3, period=2e6, seed=7)
    t = arrival_times(p)
    assert t.shape == (5000,)
    assert np.all(np.diff(t) >= 0)
    np.testing.assert_array_equal(t, arrival_times(p))  # same seed
    assert not np.array_equal(
        t, arrival_times(SinusoidalPoisson(5000, 1e-3, 2e6, seed=8))
    )


def test_sinusoidal_rate_envelope():
    """Empirical arrival counts track base_rate * (1 + A sin(...)) — peak
    phase bins must be busier than trough bins, and the overall mean rate
    lands near base_rate (thinning is exact, not approximate)."""
    base, period, amp = 2e-3, 1e6, 0.8
    t = arrival_times(
        SinusoidalPoisson(60000, base_rate=base, period=period, amplitude=amp, seed=0)
    )
    mean_rate = t.size / t[-1]
    assert abs(mean_rate - base) / base < 0.05
    phase = (t % period) / period
    peak = np.sum((phase > 0.15) & (phase < 0.35))  # sin ~ +1 around 0.25
    trough = np.sum((phase > 0.65) & (phase < 0.85))  # sin ~ -1 around 0.75
    expect = (1 + amp) / (1 - amp)
    ratio = peak / max(trough, 1)
    assert 0.6 * expect < ratio < 1.4 * expect


def test_sinusoidal_flat_amplitude_is_poisson_rate():
    t = arrival_times(SinusoidalPoisson(40000, base_rate=5e-3, period=1e5, amplitude=0.0))
    rate = t.size / t[-1]
    assert abs(rate - 5e-3) / 5e-3 < 0.05


def test_sinusoidal_validation():
    with pytest.raises(ValueError, match="base_rate"):
        arrival_times(SinusoidalPoisson(10, base_rate=0.0, period=1e5))
    with pytest.raises(ValueError, match="amplitude"):
        arrival_times(SinusoidalPoisson(10, base_rate=1e-3, period=1e5, amplitude=1.5))
    with pytest.raises(ValueError, match="period"):
        arrival_times(SinusoidalPoisson(10, base_rate=1e-3, period=0.0))


def test_mmpp2_monotone_seeded_and_sized():
    p = MMPP2(3000, rate0=1e-4, rate1=5e-3, mean_sojourn0=1e6, mean_sojourn1=2e5, seed=3)
    t = arrival_times(p)
    assert t.shape == (3000,)
    assert np.all(np.diff(t) >= 0)
    np.testing.assert_array_equal(t, arrival_times(p))


def test_mmpp2_burstier_than_poisson():
    """The MMPP's inter-arrival coefficient of variation must exceed the
    exponential's (CV = 1): that's the point of the burst state."""
    t = arrival_times(
        MMPP2(30000, rate0=1e-4, rate1=1e-2, mean_sojourn0=5e5, mean_sojourn1=5e4, seed=0)
    )
    gaps = np.diff(t)
    cv = gaps.std() / gaps.mean()
    assert cv > 1.3


def test_mmpp2_mean_rate_matches_state_mix():
    """Long-run rate = (r0 s0 + r1 s1) / (s0 + s1)."""
    r0, r1, s0, s1 = 5e-4, 5e-3, 3e5, 1e5
    t = arrival_times(MMPP2(80000, r0, r1, s0, s1, seed=1))
    want = (r0 * s0 + r1 * s1) / (s0 + s1)
    got = t.size / t[-1]
    assert abs(got - want) / want < 0.10


def test_mmpp2_validation():
    with pytest.raises(ValueError, match="rates"):
        arrival_times(MMPP2(10, 0.0, 0.0, 1e5, 1e5))
    with pytest.raises(ValueError, match="sojourn"):
        arrival_times(MMPP2(10, 1e-3, 1e-2, 0.0, 1e5))


def test_trace_backwards_time_still_rejected_with_position():
    with pytest.raises(ValueError, match="nondecreasing.*index 2"):
        arrival_times(TraceReplay(np.array([0.0, 5.0, 3.0, 9.0])))
