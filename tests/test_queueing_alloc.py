"""Queueing-aware allocation primitives (`core.alloc.greedy`):
Erlang-C / Allen-Cunneen waits and the tail-weighted `queueing_allocate`
greedy behind the `latency_aware` policy."""

import numpy as np
import pytest

from repro.core.alloc.greedy import (
    erlang_c,
    greedy_allocate,
    queueing_allocate,
    queueing_delay,
)


# ---------------------------------------------------------------- erlang_c
def test_erlang_c_known_values():
    # M/M/1: P(wait) = rho;  M/M/2 at a=1: C = 1/3 (textbook value)
    np.testing.assert_allclose(
        erlang_c(np.array([1]), np.array([0.5])), [0.5], rtol=1e-12
    )
    np.testing.assert_allclose(
        erlang_c(np.array([2]), np.array([1.0])), [1 / 3], rtol=1e-12
    )


def test_erlang_c_limits_and_saturation():
    c = np.array([1, 4, 8])
    assert np.all(erlang_c(c, np.zeros(3)) == 0.0)  # empty system never waits
    # at/beyond saturation the wait probability pins to 1
    np.testing.assert_array_equal(erlang_c(np.array([2]), np.array([2.5])), [1.0])
    with pytest.raises(ValueError):
        erlang_c(np.array([0]), np.array([0.5]))


def test_erlang_c_more_servers_wait_less():
    a = np.full(5, 3.5)
    c = np.array([4, 5, 6, 8, 12])
    pw = erlang_c(c, a)
    assert np.all(np.diff(pw) < 0)


# ----------------------------------------------------------- queueing_delay
def test_queueing_delay_monotone_and_saturating():
    lam = np.full(4, 0.8)
    s = np.ones(4)
    scv = np.zeros(4)
    wq = queueing_delay(np.array([1, 2, 3, 4]), lam, s, scv)
    assert np.all(np.diff(wq) < 0)  # replicas reduce waiting
    assert np.isinf(queueing_delay(np.array([1]), np.array([1.5]), s[:1], scv[:1]))[0]
    # M/D/1 is half the M/M/1 wait
    mm1 = queueing_delay(np.array([1]), lam[:1], s[:1], np.ones(1))
    md1 = queueing_delay(np.array([1]), lam[:1], s[:1], np.zeros(1))
    np.testing.assert_allclose(md1, mm1 / 2, rtol=1e-12)


# --------------------------------------------------------- queueing_allocate
def _units(n=6, seed=0):
    rng = np.random.default_rng(seed)
    s = rng.uniform(10, 120, n)
    lam = rng.uniform(0.2, 0.9, n) / s * 2.5  # some units start saturated
    scv = rng.uniform(0.0, 1.0, n)
    cost = rng.integers(1, 5, n).astype(np.float64)
    return lam, s, scv, cost


def test_budget_and_floor_respected():
    lam, s, scv, cost = _units()
    res = queueing_allocate(lam, s, scv, cost, budget=40.0)
    assert np.all(res.replicas >= 1)
    assert res.spent <= 40.0 + 1e-9
    assert res.spent + res.leftover == pytest.approx(40.0)
    spent = ((res.replicas - 1) * cost).sum()
    assert spent == pytest.approx(res.spent)


def test_stabilization_buys_out_saturation_first():
    # one unit needs 3 replicas just to be stable; tiny budget goes there
    lam = np.array([2.5 / 10, 0.1 / 10])
    s = np.array([10.0, 10.0])
    scv = np.zeros(2)
    cost = np.ones(2)
    res = queueing_allocate(lam, s, scv, cost, budget=2.0)
    assert res.replicas[0] == 3  # rho = 2.5/3 < 1
    assert np.all(np.isfinite(res.latency))


def test_matches_drain_greedy_quality_at_negligible_load():
    """As load -> 0 the queueing term vanishes; run as ONE group (the
    paper's objective: minimize the max unit drain) the wavefront greedy
    must match greedy_allocate's makespan — the grant ORDER may differ on
    near-ties, the achieved bottleneck drain may not."""
    rng = np.random.default_rng(3)
    base = rng.uniform(100, 1000, 8)
    cost = np.ones(8)
    batch = np.full(8, 64.0)
    s = base / batch
    res_q = queueing_allocate(
        np.full(8, 1e-12), s, np.zeros(8), cost, 40.0,
        batch_size=batch, group=np.zeros(8, dtype=np.int64),
    )
    res_g = greedy_allocate(base, cost, 40.0)
    drain_q = (base / res_q.replicas).max()
    assert drain_q <= res_g.makespan * 1.05
    assert ((res_q.replicas - 1) * cost).sum() <= 40.0


def test_group_wavefront_lifts_wide_groups():
    """A wide group of near-tied units gets whole-wave grants: with a group
    label the allocator must not starve it against a single-unit group."""
    n_wide = 6
    s = np.concatenate([[50.0], np.full(n_wide, 49.0)])
    lam = np.full(n_wide + 1, 1e-9)
    scv = np.zeros(n_wide + 1)
    cost = np.ones(n_wide + 1)
    batch = np.full(n_wide + 1, 32.0)
    group = np.concatenate([[0], np.ones(n_wide, dtype=np.int64)])
    res = queueing_allocate(
        lam, s * 0 + s, scv, cost, budget=float(n_wide) * 3, batch_size=batch, group=group
    )
    # the wide group's units move together (within one replica of each other)
    wide = res.replicas[1:]
    assert wide.max() - wide.min() <= 1
    assert wide.min() >= 2  # it actually received waves


def test_input_validation():
    with pytest.raises(ValueError, match="shape mismatch"):
        queueing_allocate(np.ones(2), np.ones(3), np.ones(3), np.ones(3), 1.0)
    with pytest.raises(ValueError, match="strictly positive"):
        queueing_allocate(np.ones(2), np.ones(2), np.ones(2), np.zeros(2), 1.0)
    with pytest.raises(ValueError, match="group"):
        queueing_allocate(
            np.ones(2), np.ones(2), np.ones(2), np.ones(2), 1.0, group=np.ones(3)
        )
    with pytest.raises(ValueError, match="at least one replica"):
        queueing_allocate(
            np.ones(2), np.ones(2), np.ones(2), np.ones(2), 1.0,
            initial_replicas=np.array([0, 1]),
        )
    res = queueing_allocate(np.ones(0), np.ones(0), np.ones(0), np.ones(0), 5.0)
    assert res.replicas.size == 0 and res.leftover == 5.0
