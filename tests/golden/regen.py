"""Regenerate the pinned scalar-simulator fixtures in tests/golden/.

The fixtures pin ``allocate()``/``simulate()`` outputs (float64, all 5
policies, 2 design sizes per network) so refactors of the simulator core are
provably behavior-preserving (tests/test_golden_equivalence.py).  Only
re-run this after an INTENTIONAL behavior change, and say so in the commit:

  PYTHONPATH=src python tests/golden/regen.py
"""

from __future__ import annotations

import json
import pathlib

from repro.core.cim import (
    POLICIES,
    allocate,
    profile_network,
    resnet18_imagenet,
    simulate,
    vgg11_cifar10,
)

HERE = pathlib.Path(__file__).parent
SIM_IMAGES = 64
CONFIGS = {
    "resnet18": (resnet18_imagenet, {"n_images": 1, "sample_patches": 128}),
    "vgg11": (vgg11_cifar10, {"n_images": 2, "sample_patches": 128}),
}


def main() -> None:
    for name, (spec_fn, prof_kw) in CONFIGS.items():
        spec = spec_fn()
        prof = profile_network(spec, **prof_kw)
        results = []
        for n_pes in (spec.min_pes() * 2, spec.min_pes() * 4):
            for policy in POLICIES:
                a = allocate(spec, prof, policy, n_pes)
                s = simulate(spec, prof, a, n_images=SIM_IMAGES)
                results.append(
                    {
                        "policy": policy,
                        "n_pes": n_pes,
                        "arrays_used": a.arrays_used,
                        "arrays_total": a.arrays_total,
                        "layer_dups": None
                        if a.layer_dups is None
                        else a.layer_dups.tolist(),
                        "block_dups": None
                        if a.block_dups is None
                        else [d.tolist() for d in a.block_dups],
                        "total_cycles": s.total_cycles,
                        "images_per_sec": s.images_per_sec,
                        "layer_cycles": s.layer_cycles.tolist(),
                        "layer_utilization": s.layer_utilization.tolist(),
                    }
                )
        out = HERE / f"{name}_scalar.json"
        out.write_text(
            json.dumps(
                {"network": name, "profile_params": prof_kw, "results": results},
                indent=1,
            )
            + "\n"
        )
        print(f"wrote {out} ({len(results)} pinned configs)")


if __name__ == "__main__":
    main()
