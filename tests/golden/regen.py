"""Regenerate the pinned fixtures in tests/golden/.

Three fixture families:

  * ``<net>_scalar.json`` — ``allocate()``/``simulate()`` outputs (float64,
    all 5 policies, 2 design sizes per network), pinned by
    tests/test_golden_equivalence.py.
  * ``<net>_fabric_scalar.json`` — ``FabricSim`` per-request percentiles and
    completion-time digests for ``blockwise`` + ``latency_aware`` under a
    fixed Poisson trace, pinned by tests/test_topology.py: the single-chip
    placed path must reproduce them BIT-IDENTICALLY.  The vgg11 fixture
    still dates from the pre-placement commit (the jit profiling forward
    left vgg11 profiles bit-identical); the resnet18 fixture was re-pinned
    at the profiling-engine commit, where resnet18 profile numerics shifted.
  * ``<net>_profile.json`` — the scalar ``"reference"`` profiling engine's
    ``LayerProfile`` statistics (float densities + a sha256 digest of the
    integer cycle samples) with an ``env`` stamp recording the generating
    container (jax/jaxlib/numpy versions, platform).  Pinned by
    tests/test_profile_engines.py to a documented TOLERANCE (XLA-version
    matmul ulps through deep BN stacks shift quantized bit counts across
    containers); the bit-exact contract is cross-engine and lives
    in-session there instead.

Only re-run this after an INTENTIONAL behavior change, and say so in the
commit:

  PYTHONPATH=src python tests/golden/regen.py
"""

from __future__ import annotations

import hashlib
import json
import pathlib

import numpy as np

from repro.core.cim import (
    POLICIES,
    allocate,
    capture_activations,
    derive_profile,
    profile_network,
    resnet18_imagenet,
    simulate,
    vgg11_cifar10,
)
from repro.core.cim.simulate import CLOCK_HZ
from repro.fabric import FabricSim, PoissonOpen

HERE = pathlib.Path(__file__).parent
SIM_IMAGES = 64
FABRIC_REQUESTS = 120
FABRIC_ARRIVAL_SEED = 7
FABRIC_SERVICE_SEED = 3
CONFIGS = {
    "resnet18": (resnet18_imagenet, {"n_images": 1, "sample_patches": 128}),
    "vgg11": (vgg11_cifar10, {"n_images": 2, "sample_patches": 128}),
}


def regen_fabric(name, spec, prof, prof_kw) -> None:
    pes = spec.min_pes() * 2
    bw = allocate(spec, prof, "blockwise", pes)
    cap = simulate(spec, prof, bw, n_images=SIM_IMAGES).images_per_sec
    la = allocate(spec, prof, "latency_aware", pes, offered_ips=0.6 * cap)
    results = []
    for pol, a in (("blockwise", bw), ("latency_aware", la)):
        proc = PoissonOpen(
            FABRIC_REQUESTS, 0.6 * cap / CLOCK_HZ, seed=FABRIC_ARRIVAL_SEED
        )
        r = FabricSim(spec, prof, a, seed=FABRIC_SERVICE_SEED).run(proc)
        pct = np.percentile(r.latencies, [50.0, 95.0, 99.0])
        results.append(
            {
                "policy": pol,
                "n_pes": pes,
                "arrays_used": a.arrays_used,
                "block_dups": [d.tolist() for d in a.block_dups],
                "offered_ips": 0.6 * cap,
                "percentiles": pct.tolist(),
                "completions_head": r.completions[:5].tolist(),
                "completions_tail": r.completions[-5:].tolist(),
                "completions_sum": float(r.completions.sum()),
            }
        )
    out = HERE / f"{name}_fabric_scalar.json"
    out.write_text(
        json.dumps(
            {
                "network": name,
                "profile_params": prof_kw,
                "n_requests": FABRIC_REQUESTS,
                "arrival_seed": FABRIC_ARRIVAL_SEED,
                "service_seed": FABRIC_SERVICE_SEED,
                "results": results,
            },
            indent=1,
        )
        + "\n"
    )
    print(f"wrote {out} ({len(results)} pinned fabric configs)")


def cycles_digest(cycles_sample: np.ndarray) -> str:
    """Platform-independent digest of the integer (S, B) cycle sample."""
    return hashlib.sha256(
        np.ascontiguousarray(cycles_sample.astype("<i8")).tobytes()
    ).hexdigest()


def environment_stamp() -> dict:
    """Provenance of the generating container — recorded in the profile
    fixtures so cross-container drift is attributable, never mysterious."""
    import platform

    import jax
    import jaxlib

    return {
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "numpy": np.__version__,
        "python": platform.python_version(),
        "platform": platform.platform(),
        "default_backend": jax.default_backend(),
    }


def regen_profile(name, spec, prof_kw) -> None:
    cap = capture_activations(
        spec, n_images=prof_kw["n_images"], sample_patches=prof_kw["sample_patches"]
    )
    prof = derive_profile(cap, spec, engine="reference")
    layers = [
        {
            "name": lp.name,
            "patches_per_image": lp.patches_per_image,
            # json round-trips python floats via repr: exact float64
            "block_density": lp.block_density.tolist(),
            "mean_cycles": lp.mean_cycles.tolist(),
            "baseline_block_cycles": lp.baseline_block_cycles.tolist(),
            "cycles_sample_shape": list(lp.cycles_sample.shape),
            "cycles_sample_sum": int(lp.cycles_sample.sum()),
            "cycles_sample_sha256": cycles_digest(lp.cycles_sample),
        }
        for lp in prof.layers
    ]
    out = HERE / f"{name}_profile.json"
    out.write_text(
        json.dumps(
            {
                "network": name,
                "profile_params": prof_kw,
                "engine": "reference",
                "env": environment_stamp(),
                "layers": layers,
            },
            indent=1,
        )
        + "\n"
    )
    print(f"wrote {out} ({len(layers)} pinned layer profiles)")


def main() -> None:
    for name, (spec_fn, prof_kw) in CONFIGS.items():
        spec = spec_fn()
        prof = profile_network(spec, **prof_kw)
        results = []
        for n_pes in (spec.min_pes() * 2, spec.min_pes() * 4):
            for policy in POLICIES:
                a = allocate(spec, prof, policy, n_pes)
                s = simulate(spec, prof, a, n_images=SIM_IMAGES)
                results.append(
                    {
                        "policy": policy,
                        "n_pes": n_pes,
                        "arrays_used": a.arrays_used,
                        "arrays_total": a.arrays_total,
                        "layer_dups": None
                        if a.layer_dups is None
                        else a.layer_dups.tolist(),
                        "block_dups": None
                        if a.block_dups is None
                        else [d.tolist() for d in a.block_dups],
                        "total_cycles": s.total_cycles,
                        "images_per_sec": s.images_per_sec,
                        "layer_cycles": s.layer_cycles.tolist(),
                        "layer_utilization": s.layer_utilization.tolist(),
                    }
                )
        out = HERE / f"{name}_scalar.json"
        out.write_text(
            json.dumps(
                {"network": name, "profile_params": prof_kw, "results": results},
                indent=1,
            )
            + "\n"
        )
        print(f"wrote {out} ({len(results)} pinned configs)")
        regen_fabric(name, spec, prof, prof_kw)
        regen_profile(name, spec, prof_kw)


if __name__ == "__main__":
    main()
