"""The packed virtual-time kernel is the event engine, bit for bit.

Three implementations of the fabric exist after the refactor — the
event-calendar ``FabricSim`` (scalar production path), the numpy run of the
shared virtual-time kernel, and the jit+vmap batched run — and they must
produce IDENTICAL per-request arrival/completion times (not merely close:
the kernel performs the same IEEE operations in the same order).  Plus the
serving-side allocation flow built on top: ``queueing_allocate`` /
``provision_latency_aware`` must beat the paper's throughput allocation on
tail latency at a low-load operating point (the acceptance experiment,
reproduced in EXPERIMENTS.md).
"""

import numpy as np
import pytest

from repro.core.cim import allocate, simulate
from repro.core.cim.simulate import CLOCK_HZ
from repro.fabric import (
    ClosedLoop,
    FabricSim,
    PoissonOpen,
    TraceReplay,
    VirtualTimeFabric,
    provision_latency_aware,
    refine_latency_aware,
)
from repro.fabric.vtime import dispatch_step


@pytest.fixture(scope="module")
def vgg(profiled):
    return profiled("vgg11", n_images=1, sample_patches=64)


@pytest.fixture(scope="module")
def vgg_allocs(vgg):
    spec, prof = vgg
    pes = spec.min_pes() * 2
    wb = allocate(spec, prof, "weight_based", pes)
    bw = allocate(spec, prof, "blockwise", pes)
    cap = simulate(spec, prof, bw, n_images=64).images_per_sec
    la = allocate(spec, prof, "latency_aware", pes, offered_ips=0.5 * cap)
    return {"weight_based": wb, "blockwise": bw, "latency_aware": la, "cap": cap}


# ------------------------------------------------------------- kernel unit
def test_dispatch_step_is_fifo_earliest_free():
    """Sorted-insert lanes == a brute-force earliest-free heap (multiset)."""
    rng = np.random.default_rng(0)
    for d in (1, 2, 5):
        lanes = np.sort(rng.uniform(0, 10, d))
        ref = list(lanes)
        free = lanes.copy()
        for s in rng.exponential(2.0, size=40):
            free, end = dispatch_step(np, free, s)
            i = min(range(d), key=ref.__getitem__)
            assert end == ref[i] + s
            ref[i] += s
            np.testing.assert_array_equal(free, np.sort(ref))
            assert np.all(np.diff(free) >= 0)  # stays sorted


def test_dispatch_step_inf_lanes_never_selected():
    free = np.array([3.0, np.inf, np.inf])
    free, end = dispatch_step(np, free, 2.0)
    assert end == 5.0
    np.testing.assert_array_equal(free, [5.0, np.inf, np.inf])


# -------------------------------------------------------- exact equivalence
@pytest.mark.parametrize("policy", ["weight_based", "blockwise", "latency_aware"])
def test_poisson_bit_identical_to_event_engine(vgg, vgg_allocs, policy):
    spec, prof = vgg
    alloc = vgg_allocs[policy]
    proc = PoissonOpen(
        n_requests=40, rate_per_cycle=0.6 * vgg_allocs["cap"] / CLOCK_HZ, seed=5
    )
    ref = FabricSim(spec, prof, alloc, seed=3).run(proc)
    vt = VirtualTimeFabric(spec, prof)
    for engine in ("jax", "numpy"):
        res = vt.run_batch([alloc], proc, seed=3, engine=engine)
        np.testing.assert_array_equal(res.completions[0], ref.completions)
        np.testing.assert_array_equal(res.arrivals[0], ref.arrivals)


def test_closed_loop_bit_identical_to_event_engine(vgg, vgg_allocs):
    spec, prof = vgg
    alloc = vgg_allocs["blockwise"]
    proc = ClosedLoop(n_requests=30, concurrency=8)
    ref = FabricSim(spec, prof, alloc, seed=1).run(proc)
    vt = VirtualTimeFabric(spec, prof)
    for engine in ("jax", "numpy"):
        res = vt.run_batch([alloc], proc, seed=1, engine=engine)
        np.testing.assert_array_equal(res.completions[0], ref.completions)
        np.testing.assert_array_equal(res.arrivals[0], ref.arrivals)


def test_mixed_batch_matches_per_config_runs(vgg, vgg_allocs):
    """One call, mixed dataflows and per-config traces -> every config
    bit-identical to its own FabricSim run."""
    spec, prof = vgg
    cap = vgg_allocs["cap"]
    allocs = [vgg_allocs["weight_based"], vgg_allocs["blockwise"], vgg_allocs["latency_aware"]]
    procs = [
        PoissonOpen(n_requests=25, rate_per_cycle=f * cap / CLOCK_HZ, seed=5)
        for f in (0.3, 0.5, 0.6)
    ]
    vt = VirtualTimeFabric(spec, prof)
    res = vt.run_batch(allocs, procs, seed=3)
    for i, (a, p) in enumerate(zip(allocs, procs)):
        ref = FabricSim(spec, prof, a, seed=3).run(p)
        np.testing.assert_array_equal(res.completions[i], ref.completions)


def test_bit_identical_with_fractional_cycles(vgg, vgg_allocs):
    """Profiled cycle counts happen to be small integers (exact in float32);
    a drift-shifted live profile has FRACTIONAL cycles, so this catches any
    silent float32 downcast in the jax path (the constants must stay f64)."""
    from repro.fabric import shift_profile

    spec, prof = vgg
    live = shift_profile(prof, {2: 1.3, 3: 1.7})
    alloc = vgg_allocs["blockwise"]
    assert any(  # the premise: the shifted cycles really are non-integral
        np.any(c.cycles_sample != np.rint(c.cycles_sample)) for c in live.layers
    )
    proc = ClosedLoop(n_requests=20, concurrency=6)
    ref = FabricSim(spec, prof, alloc, seed=4, live_prof=live).run(proc)
    vt = VirtualTimeFabric(spec, prof, live_prof=live)
    res = vt.run_batch([alloc], proc, seed=4)
    np.testing.assert_array_equal(res.completions[0], ref.completions)


def test_percentiles_match_numpy(vgg, vgg_allocs):
    spec, prof = vgg
    proc = PoissonOpen(
        n_requests=40, rate_per_cycle=0.5 * vgg_allocs["cap"] / CLOCK_HZ, seed=2
    )
    vt = VirtualTimeFabric(spec, prof)
    res = vt.run_batch([vgg_allocs["blockwise"]], proc, seed=3)
    lat = res.latencies[0]
    np.testing.assert_allclose(
        res.percentiles[0], np.percentile(lat, [50, 95, 99]), rtol=1e-12
    )
    assert res.p99[0] == res.percentiles[0][2]
    assert res.latency(0).n == 40


def test_run_batch_validation(vgg, vgg_allocs):
    spec, prof = vgg
    vt = VirtualTimeFabric(spec, prof)
    bw = vgg_allocs["blockwise"]
    with pytest.raises(ValueError, match="at least one"):
        vt.run_batch([], ClosedLoop(4, 2))
    with pytest.raises(ValueError, match="engine"):
        vt.run_batch([bw], ClosedLoop(4, 2), engine="torch")
    with pytest.raises(ValueError, match="arrival processes"):
        vt.run_batch([bw, bw], [ClosedLoop(4, 2)])
    with pytest.raises(ValueError, match="mix closed"):
        vt.run_batch([bw, bw], [ClosedLoop(4, 2), TraceReplay(np.arange(4.0))])


# --------------------------------------------------------- arrivals edges
def test_empty_trace_runs_and_returns_empty(vgg, vgg_allocs):
    spec, prof = vgg
    alloc = vgg_allocs["blockwise"]
    proc = TraceReplay(np.array([], dtype=np.float64))
    ref = FabricSim(spec, prof, alloc, seed=0).run(proc)
    assert ref.completions.size == 0 and ref.makespan == 0.0
    assert ref.latency.n == 0
    res = VirtualTimeFabric(spec, prof).run_batch([alloc], proc, seed=0)
    assert res.completions.shape == (1, 0)


def test_simultaneous_arrivals_processed_in_order(vgg, vgg_allocs):
    """Duplicate timestamps are legal; ties dispatch in request order, so
    completions are nondecreasing and identical across engines."""
    spec, prof = vgg
    alloc = vgg_allocs["blockwise"]
    t = np.repeat([0.0, 5e4], 4)  # two 4-request bursts at the same instant
    ref = FabricSim(spec, prof, alloc, seed=2).run(TraceReplay(t))
    assert np.all(np.diff(ref.completions) >= 0)
    res = VirtualTimeFabric(spec, prof).run_batch([alloc], TraceReplay(t), seed=2)
    np.testing.assert_array_equal(res.completions[0], ref.completions)


def test_non_monotone_trace_rejected_with_position():
    from repro.fabric import arrival_times

    with pytest.raises(ValueError, match="nondecreasing.*index 2"):
        arrival_times(TraceReplay(np.array([1.0, 4.0, 2.0])))


# ------------------------------------------------------ latency-aware flow
def test_latency_aware_beats_blockwise_p99_at_low_load(vgg, vgg_allocs):
    """Acceptance: at a low-load operating point the latency-aware
    provisioning improves measured p99 over the paper's throughput-greedy
    at the SAME PE budget (reproduced in EXPERIMENTS.md)."""
    spec, prof = vgg
    pes = spec.min_pes() * 2
    bw = vgg_allocs["blockwise"]
    offered = 0.3 * vgg_allocs["cap"]
    la = provision_latency_aware(
        spec, prof, pes, offered_ips=offered, calib_requests=200, grants=0
    )
    assert la.arrays_total == bw.arrays_total  # equal PE budget
    ev = PoissonOpen(n_requests=300, rate_per_cycle=offered / CLOCK_HZ, seed=5)
    res = VirtualTimeFabric(spec, prof).run_batch([bw, la], ev, seed=3)
    assert res.p99[1] < res.p99[0]


def test_provision_never_worse_than_blockwise_shape(vgg, vgg_allocs):
    """Near saturation the measured selection keeps the throughput shape —
    the policy can only deviate on a decisive calibration win."""
    spec, prof = vgg
    pes = spec.min_pes() * 2
    offered = 0.85 * vgg_allocs["cap"]
    la = provision_latency_aware(
        spec, prof, pes, offered_ips=offered, calib_requests=120, grants=0
    )
    bw = vgg_allocs["blockwise"]
    assert [d.tolist() for d in la.block_dups] == [d.tolist() for d in bw.block_dups]
    assert la.policy == "latency_aware"


def test_refine_spends_leftover_budget(vgg, vgg_allocs):
    spec, prof = vgg
    pes = spec.min_pes() * 2
    free = bwfree = vgg_allocs["blockwise"].arrays_total - spec.n_arrays
    base = allocate(
        spec, prof, "latency_aware", pes,
        free_budget=free - 64, offered_ips=0.5 * vgg_allocs["cap"],
    )
    calib = PoissonOpen(
        n_requests=60, rate_per_cycle=0.5 * vgg_allocs["cap"] / CLOCK_HZ, seed=11
    )
    ref = refine_latency_aware(spec, prof, base, calib, grants=3, candidates=6)
    assert ref.arrays_used >= base.arrays_used
    assert ref.arrays_used <= ref.arrays_total
    before = np.concatenate(base.block_dups)
    after = np.concatenate(ref.block_dups)
    assert np.all(after >= before)  # refinement only grants
