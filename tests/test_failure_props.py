"""Property tests for the seeded failure-trace generator.

Hypothesis drives random fabric shapes and hazard parameters through
``generate_failure_events`` and checks the structural invariants every
consumer (degrade_plan, FabricSim seams, FaultInjector bridge) relies on:
chronological order, per-lane fail/repair alternation with repairs strictly
after their failures, the ``min_survivors`` floor, chip-burst domain
containment, and bit-exact seeded determinism.

The dev extra installs hypothesis; the tier1-minimal CI env does not, so
the whole module skips there.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.fabric import generate_failure_events, lane_chips  # noqa: E402


def _shapes():
    n = st.integers(min_value=1, max_value=5)
    return n.flatmap(
        lambda k: st.tuples(
            st.lists(st.integers(1, 6), min_size=k, max_size=k),
            st.lists(st.sampled_from([1, 2, 4, 8]), min_size=k, max_size=k),
        )
    )


_PARAMS = dict(
    shape=_shapes(),
    seed=st.integers(0, 2**32 - 1),
    rate=st.floats(1e-7, 1e-4),
    repair=st.one_of(st.none(), st.floats(1e3, 1e5)),
    burst=st.floats(0.0, 1e-5),
)


@settings(max_examples=30, deadline=None)
@given(**_PARAMS)
def test_trace_invariants(shape, seed, rate, repair, burst):
    dups, widths = np.asarray(shape[0]), np.asarray(shape[1])
    horizon = 1e6
    events = generate_failure_events(
        dups, widths, horizon=horizon, seed=seed, rate_per_array=rate,
        repair_cycles=repair, arrays_per_chip=16, chip_burst_rate=burst,
    )

    # chronological, inside the horizon
    times = [e.time for e in events]
    assert times == sorted(times)
    assert all(0.0 < t < horizon for t in times)

    # per-(unit, lane): strictly increasing times, alternation starting with
    # a failure, repairs strictly after (never coincident with) the failure
    per_lane: dict = {}
    for e in events:
        key = (e.unit, e.lane)
        hist = per_lane.setdefault(key, [])
        if hist:
            assert e.time > hist[-1][0]
        hist.append((e.time, e.repair))
    for hist in per_lane.values():
        for i, (_, is_repair) in enumerate(hist):
            assert is_repair == (i % 2 == 1)

    # the min_survivors floor holds at every instant
    alive = dups.astype(np.int64).copy()
    for e in events:
        alive[e.unit] += 1 if e.repair else -1
        assert alive[e.unit] >= 1

    # chip homes are consistent with linear array packing
    chips = lane_chips(dups, widths, arrays_per_chip=16)
    for e in events:
        if e.lane < dups[e.unit]:  # repaired lanes may exceed original dups
            assert e.chip == int(chips[e.unit][e.lane])


@settings(max_examples=15, deadline=None)
@given(**_PARAMS)
def test_seeded_determinism(shape, seed, rate, repair, burst):
    dups, widths = np.asarray(shape[0]), np.asarray(shape[1])
    kw = dict(
        horizon=1e6, seed=seed, rate_per_array=rate, repair_cycles=repair,
        arrays_per_chip=16, chip_burst_rate=burst,
    )
    assert generate_failure_events(dups, widths, **kw) == generate_failure_events(
        dups, widths, **kw
    )


@settings(max_examples=20, deadline=None)
@given(
    shape=_shapes(),
    seed=st.integers(0, 2**32 - 1),
    burst=st.floats(1e-6, 1e-4),
    frac=st.floats(0.1, 1.0),
)
def test_chip_burst_domain_containment(shape, seed, burst, frac):
    """Every lane a burst kills at one timestamp lives on the bursting chip
    — correlated failures stay inside their failure domain."""
    dups, widths = np.asarray(shape[0]), np.asarray(shape[1])
    events = generate_failure_events(
        dups, widths, horizon=1e6, seed=seed, rate_per_array=0.0,
        arrays_per_chip=8, chip_burst_rate=burst, burst_kill_frac=frac,
    )
    chips = lane_chips(dups, widths, arrays_per_chip=8)
    by_time: dict = {}
    for e in events:
        assert not e.repair
        by_time.setdefault(e.time, []).append(e)
    for group in by_time.values():
        domain = {e.chip for e in group}
        assert len(domain) == 1  # one burst = one chip
        for e in group:
            assert int(chips[e.unit][e.lane]) == e.chip


@settings(max_examples=15, deadline=None)
@given(shape=_shapes(), seed=st.integers(0, 2**32 - 1))
def test_zero_rates_empty_trace(shape, seed):
    dups, widths = np.asarray(shape[0]), np.asarray(shape[1])
    assert (
        generate_failure_events(
            dups, widths, horizon=1e6, seed=seed, rate_per_array=0.0
        )
        == ()
    )
