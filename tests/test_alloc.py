"""Expert replication + pipeline stage partitioning (the paper's allocation
algorithms at the distributed-runtime level)."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: pip install .[dev]
from hypothesis import given, settings, strategies as st

from repro.core.alloc.expert import (
    drop_rate,
    expected_max_load,
    plan_replication,
    profile_expert_histogram,
)
from repro.core.alloc.pipeline_stages import bottleneck, partition_stages, stage_costs


# ------------------------------------------------------------------- experts
def _skewed_hist(e=16, alpha=1.2, seed=0):
    rng = np.random.default_rng(seed)
    h = rng.pareto(alpha, size=e) + 0.05
    return h / h.sum()


def test_replication_reduces_max_load():
    hist = _skewed_hist()
    base = expected_max_load(hist, n_tokens=4096, top_k=2)
    plan = plan_replication(hist, slot_budget=32)
    repl = expected_max_load(plan, n_tokens=4096, top_k=2)
    assert repl < base * 0.75  # barrier relief


def test_replication_reduces_drop_rate():
    hist = _skewed_hist(seed=1)
    base = drop_rate(hist, n_tokens=4096, top_k=2, capacity_factor=1.25)
    plan = plan_replication(hist, slot_budget=32)
    repl = drop_rate(plan, n_tokens=4096, top_k=2, capacity_factor=1.25)
    assert repl < base


def test_replication_grants_follow_load():
    hist = np.array([0.5, 0.3, 0.1, 0.1])
    plan = plan_replication(hist, slot_budget=8)
    r = np.asarray(plan.replication)
    assert r[0] >= r[1] >= r[2]
    assert plan.n_physical == 8


def test_pad_to_mesh_divisible():
    """DeepSeek-V2 on (16, 16): 160 experts padded to 256 slots -> 2D EP."""
    hist = _skewed_hist(e=160, seed=2)
    plan = plan_replication(hist, slot_budget=256, pad_to=256)
    assert plan.n_physical == 256
    assert plan.balance > 0.3  # hot experts split toward the mean


def test_histogram_profiling():
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(1000, 8))
    logits[:, 0] += 2.0  # expert 0 is hot
    hist = profile_expert_histogram(logits, top_k=2)
    assert hist.argmax() == 0
    assert np.isclose(hist.sum(), 1.0)


@given(st.integers(4, 32).flatmap(lambda e: st.tuples(
    st.lists(st.floats(0.01, 10), min_size=e, max_size=e),
    st.integers(0, 64),
)))
@settings(max_examples=50, deadline=None)
def test_plan_properties(args):
    raw, extra = args
    hist = np.asarray(raw) / np.sum(raw)
    plan = plan_replication(hist, slot_budget=hist.size + extra)
    assert plan.n_physical == hist.size + extra
    assert min(plan.replication) >= 1
    # slot loads sum back to 1
    assert np.isclose(plan.slot_load.sum(), 1.0)
    # replication never increases the max slot load
    assert plan.max_slot_load <= hist.max() + 1e-12


# -------------------------------------------------------------------- stages
def test_equal_count_vs_cost_based():
    """The paper's perf-based allocation beats count-based on skewed costs."""
    costs = np.array([1, 1, 1, 1, 10, 10, 1, 1], dtype=float)
    P = 4
    naive = [(i * 2, i * 2 + 2) for i in range(P)]  # equal layer counts
    smart = partition_stages(costs, P)
    assert bottleneck(costs, smart) <= bottleneck(costs, naive)
    assert bottleneck(costs, smart) == 10  # optimal: [1111][10][10][11]


def test_partition_covers_all_layers():
    costs = np.arange(1, 13, dtype=float)
    stages = partition_stages(costs, 5)
    assert stages[0][0] == 0 and stages[-1][1] == 12
    for (a, b), (c, d) in zip(stages, stages[1:]):
        assert b == c


@given(st.integers(2, 24).flatmap(lambda L: st.tuples(
    st.lists(st.floats(0.1, 100), min_size=L, max_size=L),
    st.integers(2, 8),
)))
@settings(max_examples=50, deadline=None)
def test_partition_optimality_lower_bound(args):
    raw, P = args
    costs = np.asarray(raw)
    stages = partition_stages(costs, min(P, costs.size))
    got = bottleneck(costs, stages)
    # can't beat max single layer or the perfect-split average
    assert got >= max(costs.max(), costs.sum() / min(P, costs.size)) - 1e-9
    # and must be no worse than one-stage-per... the equal-count heuristic
    L, Pn = costs.size, min(P, costs.size)
    step = -(-L // Pn)
    naive = [(min(i * step, L), min((i + 1) * step, L)) for i in range(Pn)]
    assert got <= bottleneck(costs, naive) + 1e-9

def test_profile_plan_redeploy_loop():
    """The paper's workflow end-to-end: capture REAL routing from an MoE,
    plan replication, verify relief (condensed from
    examples/expert_replication_flow.py)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.distrib.context import set_mesh
    from repro.models import init_params
    from repro.models.layers import capture_routing
    from repro.models.lm import _block_fwd

    set_mesh(None)
    cfg = get_config("deepseek-v2-236b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 32), 0, cfg.vocab)
    with capture_routing() as records:
        x = params["embed"].astype(jnp.dtype(cfg.dtype))[toks]
        pos = jnp.broadcast_to(jnp.arange(32)[None], (2, 32))
        for i in range(cfg.n_layers):
            p_l = jax.tree.map(lambda a, i=i: a[i], params["layers"])
            x, _ = _block_fwd(p_l, cfg, x, pos, None)
    assert len(records) == cfg.n_layers
    eids = np.concatenate([r.reshape(-1) for r in records])
    assert eids.min() >= 0 and eids.max() < cfg.moe.n_experts
    hist = np.bincount(eids, minlength=cfg.moe.n_experts).astype(float)
    hist /= hist.sum()
    plan = plan_replication(hist, slot_budget=cfg.moe.n_experts + 4)
    assert plan.max_slot_load <= hist.max()
