"""greedy_allocate warm-start (initial_replicas=) invariants +
proportional_allocate edge cases — the online re-allocation path — plus
the ``greedy_event_schedule`` exactness contract (the static grant-event
table the fused DSE pipeline replays instead of re-running the greedy).

No hypothesis dependency: these must run in the minimal environment."""

import numpy as np
import pytest

from repro.core.alloc.greedy import (
    greedy_allocate,
    greedy_event_schedule,
    proportional_allocate,
)


def _units(seed=0, n=24):
    rng = np.random.default_rng(seed)
    lat = rng.exponential(100.0, size=n) + 1.0
    cost = rng.integers(1, 9, size=n).astype(np.float64)
    return lat, cost


# ------------------------------------------------------------- warm start
def test_warm_start_never_decreases_replicas():
    lat, cost = _units()
    init = np.ones(lat.size, dtype=np.int64)
    init[::3] = 4
    res = greedy_allocate(lat, cost, budget=60.0, initial_replicas=init)
    assert np.all(res.replicas >= init)
    assert res.spent <= 60.0 + 1e-9
    assert res.spent + res.leftover == pytest.approx(60.0)


def test_warm_start_equals_cold_start_from_ones():
    lat, cost = _units(1)
    cold = greedy_allocate(lat, cost, budget=100.0)
    warm = greedy_allocate(
        lat, cost, budget=100.0, initial_replicas=np.ones(lat.size, dtype=np.int64)
    )
    np.testing.assert_array_equal(cold.replicas, warm.replicas)


def test_warm_start_same_stopping_rule():
    """The loop must stop exactly when the *current slowest* unit cannot be
    afforded — not skip to a cheaper faster unit."""
    lat, cost = _units(2)
    init = 1 + (np.arange(lat.size) % 3).astype(np.int64)
    res = greedy_allocate(lat, cost, budget=35.0, initial_replicas=init)
    slowest = int(np.argmax(res.latency))
    assert cost[slowest] > res.leftover


def test_warm_start_zero_budget_is_identity():
    lat, cost = _units(3)
    init = np.full(lat.size, 2, dtype=np.int64)
    res = greedy_allocate(lat, cost, budget=0.0, initial_replicas=init)
    np.testing.assert_array_equal(res.replicas, init)
    assert res.spent == 0.0
    np.testing.assert_allclose(res.latency, lat / init)


def test_warm_start_reduces_makespan_when_affordable():
    lat, cost = _units(4)
    init = np.ones(lat.size, dtype=np.int64)
    before = (lat / init).max()
    res = greedy_allocate(lat, cost, budget=200.0, initial_replicas=init)
    assert res.makespan < before


def test_warm_start_rejects_invalid_initials():
    lat, cost = _units(5)
    bad = np.ones(lat.size, dtype=np.int64)
    bad[0] = 0
    with pytest.raises(ValueError, match="at least one replica"):
        greedy_allocate(lat, cost, budget=10.0, initial_replicas=bad)


def test_incremental_warm_start_tracks_cold_total():
    """Spending a budget in two warm-started installments can't beat the
    greedy one-shot makespan, and lands within one replica-step of it."""
    lat, cost = _units(6)
    one_shot = greedy_allocate(lat, cost, budget=120.0)
    first = greedy_allocate(lat, cost, budget=60.0)
    second = greedy_allocate(
        lat, cost, budget=60.0 + first.leftover, initial_replicas=first.replicas
    )
    assert second.makespan >= one_shot.makespan - 1e-9
    assert np.all(second.replicas >= first.replicas)


# ------------------------------------------------------- event schedule
def test_event_schedule_matches_heap_randomized():
    """The schedule replays the scalar heap greedy exactly — replicas,
    spent, leftover — across random integer problems, warm starts and
    budget-0 edges included (the hypothesis suite widens this when the
    dev deps are installed)."""
    rng = np.random.default_rng(11)
    for trial in range(40):
        n = int(rng.integers(1, 10))
        base = rng.integers(1, 12, size=n).astype(np.float64)
        cost = rng.integers(1, 4, size=n).astype(np.float64)
        r0 = (
            rng.integers(1, 3, size=n).astype(np.int64)
            if trial % 2
            else None
        )
        budgets = rng.integers(0, 40, size=5).astype(np.float64)
        sched = greedy_event_schedule(
            base, cost, float(budgets.max()), initial_replicas=r0
        )
        got = sched.replicas_at(budgets)
        for i, b in enumerate(budgets):
            want = greedy_allocate(base, cost, float(b), initial_replicas=r0)
            np.testing.assert_array_equal(
                got.replicas[i], want.replicas, err_msg=f"trial {trial} b {b}"
            )
            assert got.spent[i] == want.spent
            assert got.leftover[i] == want.leftover


def test_event_schedule_tie_order_matches_heap():
    """Equal priorities must grant the LOWEST unit index first — heapq
    tuple order — observable when the budget cuts inside a tie run."""
    base = np.array([6.0, 6.0, 6.0])
    cost = np.array([2.0, 2.0, 2.0])
    for b in (2.0, 4.0):  # budget affords 1 (then 2) of the 3 tied grants
        want = greedy_allocate(base, cost, b)
        got = greedy_event_schedule(base, cost, b).replicas_at([b])
        np.testing.assert_array_equal(got.replicas[0], want.replicas)


def test_event_schedule_rejects_uncovered_budget():
    sched = greedy_event_schedule(np.array([5.0, 3.0]), np.array([1.0, 1.0]), 10.0)
    with pytest.raises(ValueError, match="coverage"):
        sched.replicas_at(np.array([11.0]))


def test_event_schedule_rejects_fractional_inputs():
    with pytest.raises(ValueError, match="integral"):
        greedy_event_schedule(np.array([5.0]), np.array([1.5]), 10.0)
    sched = greedy_event_schedule(np.array([5.0]), np.array([1.0]), 10.0)
    with pytest.raises(ValueError, match="integral"):
        sched.replicas_at(np.array([2.5]))


def test_event_schedule_zero_and_tiny_budgets():
    base = np.array([9.0, 4.0])
    cost = np.array([3.0, 5.0])
    sched = greedy_event_schedule(base, cost, 2.0)  # < min cost: empty table
    assert len(sched) == 0
    got = sched.replicas_at(np.array([0.0, 2.0]))
    np.testing.assert_array_equal(got.replicas, np.ones((2, 2), dtype=np.int64))
    np.testing.assert_array_equal(got.spent, [0.0, 0.0])
    np.testing.assert_array_equal(got.leftover, [0.0, 2.0])


# ------------------------------------------------------- proportional edges
def test_proportional_zero_budget():
    w = np.array([5.0, 1.0, 3.0])
    c = np.array([2.0, 2.0, 2.0])
    res = proportional_allocate(w, c, budget=0.0)
    np.testing.assert_array_equal(res.replicas, [1, 1, 1])
    assert res.spent == 0.0 and res.leftover == 0.0


def test_proportional_negative_budget_clamps_to_ones():
    w = np.array([5.0, 1.0])
    res = proportional_allocate(w, np.array([1.0, 1.0]), budget=-7.0)
    np.testing.assert_array_equal(res.replicas, [1, 1])


def test_proportional_single_unit():
    res = proportional_allocate(np.array([10.0]), np.array([3.0]), budget=10.0)
    # floor(10/3) = 3 extra, remainder 1 < 3 -> no top-up
    np.testing.assert_array_equal(res.replicas, [4])
    assert res.spent == pytest.approx(9.0)
    assert res.leftover == pytest.approx(1.0)


def test_proportional_empty():
    res = proportional_allocate(np.array([]), np.array([]), budget=5.0)
    assert res.replicas.size == 0
    assert res.makespan == 0.0


def test_proportional_never_overspends():
    rng = np.random.default_rng(7)
    for _ in range(20):
        n = rng.integers(1, 12)
        w = rng.exponential(1.0, n) + 1e-3
        c = rng.integers(1, 6, n).astype(np.float64)
        b = float(rng.integers(0, 40))
        res = proportional_allocate(w, c, b)
        assert res.spent <= b + 1e-9
        assert np.all(res.replicas >= 1)
        assert res.spent + res.leftover == pytest.approx(b)
