"""Multi-tenant allocation: weighted-fair greedy across networks sharing one
array budget, with per-tenant accounting."""

import numpy as np
import pytest

from repro.core.cim import resnet18_imagenet
from repro.fabric import ClosedLoop, Tenant, allocate_shared, fairness_report, run_tenants


@pytest.fixture(scope="module")
def vgg(profiled):
    return profiled("vgg11", n_images=1, sample_patches=128)


def _pes_for(*specs, mult=2):
    base = sum(s.n_arrays for s in specs)
    return -(-base // 64) * mult


def test_weighted_tenant_gets_more(vgg):
    """Identical networks, 3:1 weights -> the heavy tenant must get more
    arrays, more throughput, and a better tail."""
    spec, prof = vgg
    tenants = [
        Tenant("heavy", spec, prof, weight=3.0),
        Tenant("light", spec, prof, weight=1.0),
    ]
    shared = allocate_shared(tenants, n_pes=_pes_for(spec, spec, mult=2))
    a_heavy, a_light = shared.allocations
    assert a_heavy.arrays_used > a_light.arrays_used
    assert all(np.all(d >= 1) for d in a_heavy.block_dups + a_light.block_dups)
    assert shared.arrays_used <= shared.arrays_total

    results = run_tenants(
        shared, [ClosedLoop(40, 12), ClosedLoop(40, 12)], seed=0
    )
    heavy, light = results
    assert heavy.tenant == "heavy" and light.tenant == "light"
    assert heavy.images_per_sec > light.images_per_sec
    assert heavy.latency.p95 < light.latency.p95

    rep = fairness_report(shared, results)
    assert set(rep["tenants"]) == {"heavy", "light"}
    assert 0 < rep["weighted_rate_balance"] <= 1.0
    # identical specs: weighted rates should be roughly proportional
    assert rep["weighted_rate_balance"] > 0.5


def test_mixed_networks_fit_and_serve(vgg):
    """ResNet18 + VGG11 share a fabric (allocation-level check: the event
    run at ResNet18 scale lives in benchmarks)."""
    vspec, vprof = vgg
    rspec = resnet18_imagenet()
    # a flat synthetic profile is enough for allocation geometry checks —
    # the shared allocator only reads per-block mean cycles
    from repro.core.cim.profile import LayerProfile, NetworkProfile

    layers = []
    for l in rspec.layers:
        base = np.full(l.n_blocks, 512.0)
        layers.append(
            LayerProfile(
                name=l.name,
                block_density=np.full(l.n_blocks, 0.5),
                mean_cycles=base,
                cycles_sample=np.broadcast_to(base, (8, l.n_blocks)).copy(),
                baseline_block_cycles=np.full(l.n_blocks, 1024, dtype=np.int64),
                patches_per_image=l.patches_per_image,
            )
        )
    rprof = NetworkProfile("resnet18", tuple(layers))

    tenants = [Tenant("resnet", rspec, rprof), Tenant("vgg", vspec, vprof)]
    shared = allocate_shared(tenants, n_pes=_pes_for(rspec, vspec, mult=2))
    assert shared.arrays_used <= shared.arrays_total
    assert shared.leftover >= 0
    r_alloc, v_alloc = shared.allocations
    assert sum(d.size for d in r_alloc.block_dups) == rspec.n_blocks
    assert sum(d.size for d in v_alloc.block_dups) == vspec.n_blocks
    # both tenants got replicas beyond the mandatory copy
    assert r_alloc.arrays_used > rspec.n_arrays
    assert v_alloc.arrays_used > vspec.n_arrays


def test_budget_too_small_raises(vgg):
    spec, prof = vgg
    tenants = [Tenant("a", spec, prof), Tenant("b", spec, prof)]
    with pytest.raises(ValueError, match="mandatory"):
        allocate_shared(tenants, n_pes=spec.min_pes())  # fits one, not two
    with pytest.raises(ValueError, match="positive"):
        allocate_shared([Tenant("a", spec, prof, weight=0.0)], n_pes=spec.min_pes() * 2)
