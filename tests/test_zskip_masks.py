"""zskip_matmul vs the dense reference under ARBITRARY block masks.

test_kernels.py exercises masks derived from the activations (the op
wrapper's path, where skipping is exact).  Here the mask is an independent
input: the kernel's contract is "compute A@B with masked-off A tiles treated
as zero", which must hold for random masks, the all-zero / all-ones edge
cases, and non-square grids."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.zskip_matmul import zskip_matmul


def _rand(key, m, n, dtype=jnp.float32):
    return jax.random.normal(key, (m, n), dtype)


@pytest.mark.parametrize(
    "M,K,N,bm,bn,bk",
    [
        (128, 256, 128, 64, 64, 64),  # non-square 2x4 mask grid
        (192, 64, 128, 64, 64, 64),  # tall 3x1 grid
        (64, 320, 192, 64, 64, 64),  # wide 1x5 grid
        (128, 128, 128, 128, 128, 128),  # single-tile-per-axis MXU shape
    ],
)
@pytest.mark.parametrize("density", [0.0, 0.3, 0.7, 1.0])
def test_zskip_matmul_random_masks(M, K, N, bm, bn, bk, density):
    key = jax.random.PRNGKey(int(M + K + N + density * 100))
    ka, kb, km = jax.random.split(key, 3)
    a = _rand(ka, M, K)
    b = _rand(kb, K, N)
    mask = jax.random.bernoulli(km, density, (M // bm, K // bk)).astype(jnp.int32)
    got = zskip_matmul(a, b, mask, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.zskip_matmul_ref(a, b, mask, bm, bk)
    # full-range gaussian inputs cancel, so small outputs carry the f32
    # accumulation-order noise — tolerance is absolute-dominated
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_zskip_all_zero_mask_is_exact_zero():
    """Every tile skipped -> the accumulator never fires -> exact zeros."""
    key = jax.random.PRNGKey(0)
    a = _rand(key, 128, 256)
    b = _rand(jax.random.fold_in(key, 1), 256, 128)
    mask = jnp.zeros((2, 4), jnp.int32)  # (M/bm, K/bk) for bm=bk=64
    got = zskip_matmul(a, b, mask, bm=64, bn=64, bk=64, interpret=True)
    assert got.shape == (128, 128)
    np.testing.assert_array_equal(np.asarray(got), np.zeros((128, 128), np.float32))


def test_zskip_all_ones_mask_is_dense_matmul():
    """No tile skipped -> bit-for-bit the dense tiled matmul."""
    key = jax.random.PRNGKey(2)
    a = _rand(key, 128, 192)
    b = _rand(jax.random.fold_in(key, 3), 192, 64)
    mask = jnp.ones((2, 3), jnp.int32)
    got = zskip_matmul(a, b, mask, bm=64, bn=64, bk=64, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(a @ b), rtol=1e-5, atol=1e-5
    )


def test_zskip_mask_zeroes_live_tiles():
    """A mask may also DROP nonzero tiles — the reference semantics are
    'masked tile == zero tile', not 'mask == nonzero map'."""
    a = jnp.ones((128, 128), jnp.float32)
    b = jnp.ones((128, 64), jnp.float32)
    mask = jnp.array([[1, 0], [0, 1]], jnp.int32)  # bm=bk=64: checkerboard
    got = zskip_matmul(a, b, mask, bm=64, bn=64, bk=64, interpret=True)
    # each output row sums exactly one surviving 64-wide K tile of ones
    np.testing.assert_array_equal(np.asarray(got), np.full((128, 64), 64.0, np.float32))


def test_zskip_forward_matches_dense_matmul_on_masked_input():
    """End-to-end interpret-mode smoke: when the mask is DERIVED from an
    activation whose masked tiles are genuinely all-zero (the op wrapper's
    contract), the kernel must reproduce the plain dense matmul ``a @ b`` —
    skipping changes nothing because the skipped tiles contribute nothing."""
    from repro.kernels.ref import block_mask_ref

    key = jax.random.PRNGKey(7)
    ka, kb = jax.random.split(key)
    a = jax.nn.relu(jax.random.normal(ka, (128, 256)))
    # zero out a structured half of the tiles (post-ReLU sparsity pattern)
    keep = jnp.kron(jnp.array([[1, 0, 0, 1], [0, 1, 1, 0]], jnp.float32), jnp.ones((64, 64)))
    a = a * keep
    b = jax.random.normal(kb, (256, 128))
    mask = block_mask_ref(a, 64, 64)
    assert int(mask.sum()) == 4  # half the 2x4 grid really is skipped
    got = zskip_matmul(a, b, mask, bm=64, bn=64, bk=64, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(a @ b), rtol=1e-4, atol=1e-4
    )


def test_zskip_rejects_unaligned_shapes():
    a = jnp.zeros((100, 128))
    b = jnp.zeros((128, 128))
    mask = jnp.ones((1, 1), jnp.int32)
    with pytest.raises(AssertionError):
        zskip_matmul(a, b, mask, interpret=True)
