"""Property tests for the DSE building blocks: the blockwise flatten /
unflatten round-trip and the batched greedy allocator vs the scalar heap."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep: pip install .[dev]
from hypothesis import given, settings, strategies as st

from repro.core.alloc.greedy import (
    greedy_allocate,
    greedy_allocate_batch,
    proportional_allocate,
    proportional_allocate_batch,
)
from repro.core.cim import LayerSpec, NetworkSpec
from repro.core.cim.simulate import blockwise_units, split_block_dups

# fixed (C, N) so every hypothesis example reuses one compiled jnp kernel
N_UNITS = 16
N_CONFIGS = 4


# ------------------------------------------------- flatten/unflatten round-trip
layer_st = st.tuples(
    st.sampled_from([1, 3, 5]),  # kernel
    st.integers(1, 64),  # cin
    st.integers(1, 300),  # cout
    st.integers(1, 32),  # out_hw
)
spec_st = st.lists(layer_st, min_size=1, max_size=6).map(
    lambda ls: NetworkSpec(
        "prop",
        tuple(
            LayerSpec(f"l{i}", k, cin, cout, hw) for i, (k, cin, cout, hw) in enumerate(ls)
        ),
    )
)


@given(spec_st, st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_blockwise_units_split_round_trip(spec, seed):
    rng = np.random.default_rng(seed)
    means = [rng.uniform(8, 1024, l.n_blocks) for l in spec.layers]
    base_lat, cost = blockwise_units(spec, means)
    assert base_lat.shape == cost.shape == (spec.n_blocks,)
    # flat order is layers-then-blocks with the documented contents
    k = 0
    for i, layer in enumerate(spec.layers):
        for b in range(layer.n_blocks):
            assert base_lat[k] == means[i][b] * layer.patches_per_image
            assert cost[k] == layer.arrays_per_block
            k += 1
    # split is the exact inverse of the flattening
    flat = rng.integers(1, 50, spec.n_blocks)
    per_layer = split_block_dups(spec, flat)
    assert [d.size for d in per_layer] == [l.n_blocks for l in spec.layers]
    np.testing.assert_array_equal(np.concatenate(per_layer), flat)
    # and the split views are copies, not aliases into the flat vector
    per_layer[0][0] += 1
    assert flat[0] == per_layer[0][0] - 1


# ---------------------------------------------------- batched greedy == scalar
def _units(draw_ints, draw_floats):
    return st.tuples(
        st.lists(draw_floats, min_size=N_UNITS, max_size=N_UNITS),
        st.lists(draw_ints, min_size=N_UNITS, max_size=N_UNITS),
        st.lists(st.integers(0, 400), min_size=N_CONFIGS, max_size=N_CONFIGS),
    )


@given(_units(st.integers(1, 8), st.floats(1, 1e4)))
@settings(max_examples=60, deadline=None)
def test_greedy_batch_matches_scalar_loop(args):
    lats, costs, budgets = args
    base = np.asarray(lats)
    cost = np.asarray(costs, dtype=np.float64)
    budgets = np.asarray(budgets, dtype=np.float64)
    batch = greedy_allocate_batch(base, cost, budgets)
    for c, budget in enumerate(budgets):
        ref = greedy_allocate(base, cost, budget)
        np.testing.assert_array_equal(batch.replicas[c], ref.replicas)
        np.testing.assert_allclose(batch.spent[c], ref.spent, rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(batch.leftover[c], ref.leftover, rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(batch.latency[c], ref.latency, rtol=1e-12)


@given(
    _units(st.integers(1, 8), st.floats(1, 1e4)),
    st.lists(st.integers(1, 5), min_size=N_UNITS, max_size=N_UNITS),
)
@settings(max_examples=40, deadline=None)
def test_greedy_batch_warm_start_matches_scalar(args, r0):
    lats, costs, budgets = args
    base = np.asarray(lats)
    cost = np.asarray(costs, dtype=np.float64)
    r0 = np.asarray(r0, dtype=np.int64)
    batch = greedy_allocate_batch(
        base, cost, np.asarray(budgets, dtype=np.float64), initial_replicas=r0
    )
    for c, budget in enumerate(budgets):
        ref = greedy_allocate(base, cost, float(budget), initial_replicas=r0)
        np.testing.assert_array_equal(batch.replicas[c], ref.replicas)
        # warm start invariant: replicas never drop below the starting point
        assert (batch.replicas[c] >= r0).all()


@given(
    st.integers(1, 20).flatmap(
        lambda n: st.tuples(
            st.lists(st.floats(0.1, 1e6), min_size=n, max_size=n),
            st.lists(st.integers(1, 8), min_size=n, max_size=n),
            st.lists(st.integers(-5, 300), min_size=1, max_size=6),
        )
    )
)
@settings(max_examples=60, deadline=None)
def test_proportional_batch_matches_scalar_loop(args):
    """Vectorized shares + lock-step top-up == scalar per-config routine,
    including argsort tie order and the budget<=0 early return."""
    weights, costs, budgets = args
    w = np.asarray(weights)
    cost = np.asarray(costs, dtype=np.float64)
    batch = proportional_allocate_batch(w, cost, np.asarray(budgets, dtype=np.float64))
    for c, budget in enumerate(budgets):
        ref = proportional_allocate(w, cost, float(budget))
        np.testing.assert_array_equal(batch.replicas[c], ref.replicas)
        np.testing.assert_allclose(batch.spent[c], ref.spent, rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(batch.leftover[c], ref.leftover, rtol=1e-12, atol=1e-12)


def test_greedy_batch_tie_breaking_matches_heap():
    """Equal latencies and power-of-two ratios — the adversarial tie cases
    for the bisection bulk phase — still match the scalar heap exactly."""
    base = np.array([4.0, 2.0, 2.0, 1.0, 1.0, 8.0])
    cost = np.array([1.0, 2.0, 1.0, 1.0, 3.0, 2.0])
    for budget in range(0, 30):
        batch = greedy_allocate_batch(base, cost, np.array([float(budget)]))
        ref = greedy_allocate(base, cost, float(budget))
        np.testing.assert_array_equal(batch.replicas[0], ref.replicas)


def test_greedy_batch_validation():
    with pytest.raises(ValueError, match="strictly positive"):
        greedy_allocate_batch([1.0, 2.0], [1.0, 0.0], [5.0])
    with pytest.raises(ValueError, match="base_latency"):
        greedy_allocate_batch([1.0, 2.0], [1.0, 1.0, 1.0], [5.0])
    with pytest.raises(ValueError, match="at least one replica"):
        greedy_allocate_batch([1.0, 2.0], [1.0, 1.0], [5.0], initial_replicas=[0, 1])


def test_greedy_batch_empty_units():
    res = greedy_allocate_batch(np.zeros(0), np.zeros(0), [7.0, 0.0])
    assert res.replicas.shape == (2, 0)
    np.testing.assert_array_equal(res.leftover, [7.0, 0.0])
    np.testing.assert_array_equal(res.makespan, [0.0, 0.0])  # (C,) like scalar
