"""Fused-vs-staged DSE equivalence: the one-jit pipeline (in-graph profile
derivation -> allocation -> evaluation, ``dse/fused.py``) against the
staged path on pinned ResNet18 + VGG11 grids.

The contract (documented in ``dse/fused.py``): DISCRETE columns — replica
tensors, arrays used/total, chip crossings — are EXACTLY equal (the
allocators run the same kernel body on bit-equal integer-cycle inputs).
Float-derived columns — total cycles, throughput, utilization, latency
percentiles — are compared at rtol 1e-12: the staged and fused evaluators
are different XLA programs, and cross-compilation op-fusion can wobble the
last ULP of the rounded mean->multiply->divide chains (observed: 1 config
in 24, ~2e-16 relative; ``busy_sum`` additionally sums rounded means in
backend-chosen order).  1e-12 is four orders looser than that wobble and
tight enough that any real formula drift fails.

Also pinned here: sharded (``shard_map_batch``) vs plain fused identity,
and the fused pipeline's declared limits (latency_aware rejected,
infeasible budgets rejected).
"""

import numpy as np
import pytest

from repro.core.cim.cost import DEFAULT_ARRAY
from repro.dse import (
    FabricEval,
    allocate_batch,
    chip_grid,
    design_grid,
    get_fused_pipeline,
    run_fused_multichip_sweep,
    run_fused_sweep,
    run_sweep,
)
from repro.dse.sweep import get_profiled, run_multichip_sweep

ARRAYS = (DEFAULT_ARRAY, DEFAULT_ARRAY.variant(adc_bits=5))
POLS = ("baseline", "weight_based", "perf_layerwise", "blockwise")
EXACT_COLS = ("arrays_used", "arrays_total")
FLOAT_COLS = ("total_cycles", "images_per_sec", "mean_utilization")
ULP_RTOL = 1e-12


def _assert_equiv(a, b, exact_cols, float_cols, msg=""):
    for col in exact_cols:
        np.testing.assert_array_equal(
            getattr(a, col), getattr(b, col), err_msg=f"{msg}{col}"
        )
    for col in float_cols:
        np.testing.assert_allclose(
            getattr(a, col), getattr(b, col), rtol=ULP_RTOL, atol=0,
            err_msg=f"{msg}{col}",
        )


def _grid(net):
    return design_grid(
        networks=(net,), policies=POLS, pe_multipliers=(1.0, 2.0, 3.5), arrays=ARRAYS
    )


@pytest.fixture(
    scope="module",
    params=["vgg11", pytest.param("resnet18", marks=pytest.mark.slow)],
)
def pair(request):
    """(staged, fused) SweepResult pair on the pinned grid, fabric attached.

    VGG11 runs in the fast tier on every PR; the ResNet18 grid (the one
    that exposed the cross-compilation ULP wobble) rides the nightly slow
    tier with the multichip surface and the sharded-identity check."""
    pts = _grid(request.param)
    fab = FabricEval(load_frac=0.7, n_requests=30, seed=0)
    staged = run_sweep(pts, engine="batch", fabric=fab)
    fused = run_fused_sweep(pts, fabric=fab)
    return staged, fused


def test_analytic_columns_equivalent(pair):
    staged, fused = pair
    _assert_equiv(staged, fused, EXACT_COLS, FLOAT_COLS)


def test_latency_percentiles_equivalent(pair):
    """The fused fabric stage (per-config ADC/zskip/dataflow gathers over
    the in-graph cycle banks) reproduces the staged VirtualTimeFabric's
    percentile columns — same service draws, same arrivals, same scan
    recurrence (ULP tolerance only, see module docstring)."""
    staged, fused = pair
    _assert_equiv(
        staged, fused, (), ("p50_cycles", "p95_cycles", "p99_cycles")
    )


def test_replica_tensors_bit_equal():
    """dups_lb out of the in-graph allocators == allocate_batch's, for every
    policy family (proportional constants, layer greedy, block greedy)."""
    net = "vgg11"
    pts = _grid(net)
    by_arr = {}
    for i, p in enumerate(pts):
        by_arr.setdefault(p.array, []).append(i)
    adcs = tuple(sorted({p.array.adc_bits for p in pts}))
    pipe = get_fused_pipeline(net, DEFAULT_ARRAY, adcs)
    res = pipe(
        np.array([adcs.index(p.array.adc_bits) for p in pts], dtype=np.int32),
        [p.policy for p in pts],
        [p.n_pes for p in pts],
    )
    for arr, rows in by_arr.items():
        spec, prof = get_profiled(net, arr)
        batch = allocate_batch(
            spec, prof, [pts[i].policy for i in rows], [pts[i].n_pes for i in rows]
        )
        fused_dups = res["dups_lb"][rows][:, :, : batch.dups_lb.shape[2]]
        np.testing.assert_array_equal(fused_dups, batch.dups_lb)
        np.testing.assert_array_equal(res["arrays_used"][rows], batch.arrays_used)


@pytest.mark.slow
def test_multichip_load_surface_matches_staged():
    """run_fused_multichip_sweep at K loads matches K staged sweeps column
    for column — the lifted placement x load axis changes the batching,
    not the numbers (discrete columns exact, float columns at ULP rtol)."""
    pts = chip_grid(networks=("vgg11",), chips=(1, 2), link_gbps=(16.0, 64.0))
    loads = (0.5, 0.7)
    kw = dict(n_requests=30, closed_requests=20, concurrency=8, seed=0)
    fused = run_fused_multichip_sweep(pts, load_fracs=loads, **kw)
    assert fused.pcts.shape == (len(pts), len(loads), 3)
    assert fused.n_evaluations == len(pts) * len(loads)
    for k, lf in enumerate(loads):
        staged = run_multichip_sweep(pts, load_frac=lf, **kw)
        np.testing.assert_allclose(
            staged.images_per_sec, fused.images_per_sec, rtol=ULP_RTOL, atol=0
        )
        np.testing.assert_allclose(
            np.stack(
                [staged.p50_cycles, staged.p95_cycles, staged.p99_cycles], axis=1
            ),
            fused.pcts[:, k, :],
            rtol=ULP_RTOL,
            atol=0,
        )
        np.testing.assert_array_equal(staged.n_crossings, fused.n_crossings)
        np.testing.assert_array_equal(
            staged.max_stage_transfer, fused.max_stage_transfer
        )
    rows = fused.rows()
    assert len(rows) == fused.n_evaluations
    assert {r["load_frac"] for r in rows} == set(loads)


@pytest.mark.slow
def test_sharded_fused_identical_to_plain():
    """shard_map_batch routing (padded config axis over local devices) must
    match the unsharded fused pipeline under the same contract."""
    pts = _grid("vgg11")[:11]  # odd count exercises the pad-to-devices path
    plain = run_fused_sweep(pts)
    shard = run_fused_sweep(pts, shard_devices=True)
    _assert_equiv(plain, shard, EXACT_COLS, FLOAT_COLS)


def _packed_grid(net="vgg11", pols=POLS, pes=(300, 557, 800)):
    """(a_idx, policies, n_pes) columns spanning both ADC variants."""
    P, A, N = [], [], []
    for p in pols:
        for a in (0, 1):
            for n in pes:
                P.append(p)
                A.append(a)
                N.append(n)
    return (
        np.array(A, dtype=np.int32),
        np.array(P, dtype=object),
        np.array(N, dtype=np.int64),
    )


def test_pallas_engine_matches_xla():
    """engine="pallas" (the fused allocate+eval kernel, interpret mode
    off-TPU) against the XLA path: discrete columns — replica tensors,
    arrays used — exactly equal, floats within the rtol 1e-12 contract."""
    pipe = get_fused_pipeline("vgg11", DEFAULT_ARRAY, (6, 8))
    a_idx, pols, pes = _packed_grid(
        pols=POLS + ("weight_blockflow",), pes=(300, 557, 800)
    )
    ref = pipe(a_idx, pols, pes, need_dups=True)
    got = pipe(a_idx, pols, pes, need_dups=True, engine="pallas")
    for k in ("arrays_used", "arrays_total", "layerwise", "zskip", "dups_lb"):
        np.testing.assert_array_equal(ref[k], got[k], err_msg=k)
    for k in (
        "total_cycles", "images_per_sec", "layer_cycles", "layer_utilization"
    ):
        np.testing.assert_allclose(
            ref[k], got[k], rtol=ULP_RTOL, atol=0, err_msg=k
        )


def test_unknown_engine_is_rejected():
    pipe = get_fused_pipeline("vgg11", DEFAULT_ARRAY, (6,))
    with pytest.raises(ValueError, match="engine"):
        pipe(np.zeros(1, np.int32), ["blockwise"], [600], engine="cuda")


@pytest.mark.parametrize("chunk", [1, 5, 10**6])
def test_chunk_tilings_identical(chunk):
    """chunk=1 (one dispatch per config), a non-divisor tile (pad-repeat
    path), and chunk >= C (single dispatch) must all be element-wise
    IDENTICAL: chunking changes dispatch boundaries, never values."""
    pipe = get_fused_pipeline("vgg11", DEFAULT_ARRAY, (6, 8))
    a_idx, pols, pes = _packed_grid()
    ref = pipe(a_idx, pols, pes, need_dups=True)
    got = pipe(a_idx, pols, pes, need_dups=True, chunk=chunk)
    assert set(ref) == set(got)
    for k in ref:
        np.testing.assert_array_equal(ref[k], got[k], err_msg=f"chunk={chunk} {k}")


def test_chunking_bounds_device_footprint():
    """The peak-memory contract of the streamed sweep: the per-dispatch
    device footprint scales with the TILE, not with C — read back from the
    pipeline's telemetry gauges."""
    from repro.fabric.telemetry import telemetry_session

    pipe = get_fused_pipeline("vgg11", DEFAULT_ARRAY, (6, 8))
    a_idx, pols, pes = _packed_grid()
    C = len(pols)
    n_L = int(np.sum(pols != "blockwise"))
    n_B = C - n_L
    per_config = (2 * pipe.L * pipe.B + pipe.N + 2 * pipe.L + 3) * 8
    with telemetry_session() as tel:
        pipe(a_idx, pols, pes, chunk=4, need_dups=False)
        snap = tel.snapshot()
    assert snap["gauges"]["dse.fused.chunk_configs"] == 4
    assert snap["gauges"]["dse.fused.chunk_device_bytes"] == 4 * per_config
    assert snap["counters"]["dse.fused.chunks"] == -(-n_L // 4) - (-n_B // 4)
    assert snap["gauges"]["dse.fused.host_out_bytes"] > 0
    with telemetry_session() as tel:
        pipe(a_idx, pols, pes, need_dups=False)  # chunk >= C: one tile/family
        snap_full = tel.snapshot()
    assert snap_full["gauges"]["dse.fused.chunk_configs"] == max(n_L, n_B)
    assert (
        snap_full["gauges"]["dse.fused.chunk_device_bytes"]
        == max(n_L, n_B) * per_config
    )
    assert snap_full["counters"]["dse.fused.chunks"] == 2  # one per family


def test_latency_aware_is_rejected():
    pts = design_grid(
        networks=("vgg11",), policies=("latency_aware",), pe_multipliers=(2.0,)
    )
    with pytest.raises(ValueError, match="latency_aware"):
        run_fused_sweep(pts)


def test_infeasible_budget_is_rejected():
    pipe = get_fused_pipeline("vgg11", DEFAULT_ARRAY, (3,))
    with pytest.raises(ValueError, match="arrays"):
        pipe(np.zeros(1, np.int32), ["blockwise"], [1])


def test_bad_adc_index_is_rejected():
    pipe = get_fused_pipeline("vgg11", DEFAULT_ARRAY, (3,))
    pes = pipe.spec.min_pes()
    with pytest.raises(ValueError, match="a_idx"):
        pipe(np.array([1], np.int32), ["blockwise"], [pes * 2])
