"""Hierarchical chip->PE->array topology: placement, transfer delays, and
the golden single-chip equivalence.

The refactor from "replica counts in a flat pool" to "placement on a
resource tree" must be provably behavior-preserving in the degenerate case:
a 1-chip topology has zero transfer cost everywhere, so every placed policy
must reproduce the flat allocator replica-for-replica and the fabric
engines must reproduce the flat (placement-free) per-request timings bit
for bit, pinned by tests/golden/*_fabric_scalar.json.  The vgg11 fixture
still dates from the pre-placement commit; the resnet18 fixture was
re-pinned when the profiling forward moved into XLA (see regen.py), so for
resnet18 the fixture proves placed == flat at the current profile, not
continuity with the pre-placement commit.  Multi-chip runs must keep the
three fabric engines (event
calendar, numpy virtual-time, jit+vmap virtual-time) bit-identical WITH
transfer delays enabled.
"""

import json
import pathlib

import numpy as np
import pytest

from repro.core.cim import (
    FabricTopology,
    allocate,
    allocate_placed,
    place_allocation,
    resnet18_imagenet,
    vgg11_cifar10,
)
from repro.core.cim.simulate import ALL_POLICIES, CLOCK_HZ
from repro.fabric import FabricSim, PoissonOpen, VirtualTimeFabric

GOLDEN = pathlib.Path(__file__).parent / "golden"
_SPEC_FNS = {"resnet18": resnet18_imagenet, "vgg11": vgg11_cifar10}


@pytest.fixture(scope="module")
def vgg(profiled):
    return profiled("vgg11", n_images=1, sample_patches=64)


@pytest.fixture(scope="module")
def vgg_golden(profiled):
    g = json.loads((GOLDEN / "vgg11_fabric_scalar.json").read_text())
    spec, prof = profiled("vgg11", **g["profile_params"])
    return spec, prof, g


# ------------------------------------------------------------- cost model
def test_single_chip_transfers_are_zero():
    topo = FabricTopology.single_chip(64)
    assert topo.transfer_cycles(0, 0, 1e9) == 0.0
    assert topo.total_arrays == 64 * 64


def test_transfer_scales_with_hops_and_bytes():
    topo = FabricTopology.split(4, 64, link_gbps=32.0)
    one = topo.transfer_cycles(0, 1, 1000.0)
    assert topo.transfer_cycles(0, 3, 1000.0) == pytest.approx(3 * one)
    assert topo.transfer_cycles(3, 0, 1000.0) == one * 3  # symmetric chain
    more = topo.transfer_cycles(0, 1, 2000.0)
    assert more > one
    fast = topo.variant(link_gbps=64.0)
    assert fast.transfer_cycles(0, 1, 1000.0) < one


def test_topology_validation():
    with pytest.raises(ValueError):
        FabricTopology(pes_per_chip=0)
    with pytest.raises(ValueError):
        FabricTopology(pes_per_chip=4, link_gbps=0.0)
    with pytest.raises(ValueError):
        FabricTopology.split(3, 64)  # 64 PEs don't split over 3 chips


# ------------------------------------------- single-chip golden equivalence
def test_single_chip_reproduces_flat_allocator(vgg):
    """Every policy on a 1-chip tree == the flat allocator, replica for
    replica, with all-zero stage transfers."""
    spec, prof = vgg
    pes = spec.min_pes() * 2
    topo = FabricTopology.single_chip(pes)
    for pol in ALL_POLICIES:
        kw = {"offered_ips": 5000.0} if pol == "latency_aware" else {}
        flat = allocate(spec, prof, pol, pes, **kw)
        placed = allocate_placed(spec, prof, pol, topo, **kw)
        assert placed.allocation.arrays_used == flat.arrays_used, pol
        if flat.layer_dups is not None:
            np.testing.assert_array_equal(
                placed.allocation.layer_dups, flat.layer_dups, err_msg=pol
            )
        else:
            for a, b in zip(placed.allocation.block_dups, flat.block_dups):
                np.testing.assert_array_equal(a, b, err_msg=pol)
        assert np.all(placed.placement.stage_transfer == 0.0), pol
        assert placed.placement.n_crossings == 0, pol


def test_single_chip_fabric_matches_prerefactor_golden(vgg_golden):
    """FabricSim WITH a single-chip placement reproduces the pre-refactor
    percentiles and completion times bit for bit (vgg11 fixture)."""
    spec, prof, g = vgg_golden
    topo = FabricTopology.single_chip(g["results"][0]["n_pes"])
    for rec in g["results"]:
        kw = (
            {"offered_ips": rec["offered_ips"]}
            if rec["policy"] == "latency_aware"
            else {}
        )
        placed = allocate_placed(spec, prof, rec["policy"], topo, **kw)
        assert [
            d.tolist() for d in placed.allocation.block_dups
        ] == rec["block_dups"], rec["policy"]
        proc = PoissonOpen(
            g["n_requests"], rec["offered_ips"] / CLOCK_HZ, seed=g["arrival_seed"]
        )
        r = FabricSim(
            spec, prof, placed.allocation, seed=g["service_seed"],
            placement=placed.placement,
        ).run(proc)
        pct = np.percentile(r.latencies, [50.0, 95.0, 99.0])
        assert pct.tolist() == rec["percentiles"], rec["policy"]
        assert float(r.completions.sum()) == rec["completions_sum"]
        assert r.completions[:5].tolist() == rec["completions_head"]
        assert r.completions[-5:].tolist() == rec["completions_tail"]


@pytest.mark.slow
def test_single_chip_fabric_matches_prerefactor_golden_resnet18(profiled):
    g = json.loads((GOLDEN / "resnet18_fabric_scalar.json").read_text())
    spec, prof = profiled("resnet18", **g["profile_params"])
    topo = FabricTopology.single_chip(g["results"][0]["n_pes"])
    for rec in g["results"]:
        kw = (
            {"offered_ips": rec["offered_ips"]}
            if rec["policy"] == "latency_aware"
            else {}
        )
        placed = allocate_placed(spec, prof, rec["policy"], topo, **kw)
        proc = PoissonOpen(
            g["n_requests"], rec["offered_ips"] / CLOCK_HZ, seed=g["arrival_seed"]
        )
        r = FabricSim(
            spec, prof, placed.allocation, seed=g["service_seed"],
            placement=placed.placement,
        ).run(proc)
        pct = np.percentile(r.latencies, [50.0, 95.0, 99.0])
        assert pct.tolist() == rec["percentiles"], rec["policy"]
        assert float(r.completions.sum()) == rec["completions_sum"]


# ------------------------------------------------- multi-chip bit-identity
@pytest.fixture(scope="module")
def multichip(vgg):
    spec, prof = vgg
    pes = spec.min_pes() * 2
    topo = FabricTopology.split(4, pes + (-pes) % 4, link_gbps=16.0)
    pa = allocate_placed(spec, prof, "blockwise", topo)
    pb = allocate_placed(spec, prof, "latency_aware", topo, offered_ips=4000.0)
    return spec, prof, topo, [pa, pb]


def test_multichip_engines_bit_identical(multichip):
    """Event calendar == numpy virtual time == jit virtual time, per-request
    bit for bit, WITH transfer delays enabled."""
    spec, prof, topo, placed = multichip
    allocs = [p.allocation for p in placed]
    places = [p.placement for p in placed]
    assert any(p.stage_transfer.max() > 0 for p in places)  # delays real
    proc = PoissonOpen(50, 4000.0 / CLOCK_HZ, seed=11)
    scalar = [
        FabricSim(spec, prof, a, seed=3, placement=p).run(proc)
        for a, p in zip(allocs, places)
    ]
    vt = VirtualTimeFabric(spec, prof)
    rn = vt.run_batch(allocs, proc, seed=3, engine="numpy", placements=places)
    rj = vt.run_batch(allocs, proc, seed=3, engine="jax", placements=places)
    for i, r in enumerate(scalar):
        np.testing.assert_array_equal(rn.completions[i], r.completions)
        np.testing.assert_array_equal(rj.completions[i], r.completions)
        np.testing.assert_array_equal(rn.arrivals[i], r.arrivals)
        np.testing.assert_array_equal(rj.arrivals[i], r.arrivals)


def test_transfer_delays_shift_latency(multichip):
    """The SAME allocation is strictly slower with transfer delays than
    without (transfers are on the request path)."""
    spec, prof, topo, placed = multichip
    a, p = placed[0].allocation, placed[0].placement
    proc = PoissonOpen(40, 3000.0 / CLOCK_HZ, seed=5)
    vt = VirtualTimeFabric(spec, prof)
    with_x = vt.run_batch([a], proc, seed=3, engine="numpy", placements=[p])
    without = vt.run_batch([a], proc, seed=3, engine="numpy")
    assert np.all(with_x.latencies >= without.latencies)
    assert with_x.latencies.mean() > without.latencies.mean()


# ------------------------------------------------------------- placement
def test_placement_respects_chip_capacity(multichip):
    spec, prof, topo, placed = multichip
    for p in placed:
        assert p.placement.chip_arrays.sum() == p.allocation.arrays_used
        assert np.all(p.placement.chip_arrays <= topo.arrays_per_chip)


def test_locality_beats_striping(multichip):
    """Comm-aware placement never moves MORE data than blind striping of
    the same replica counts (worst-stage transfer and total transfer).
    Counts are built with placement slack: a fully-spent flat budget can be
    UNPLACEABLE under striping (fragmentation), which is its own finding."""
    spec, prof, topo, placed = multichip
    free = topo.total_arrays - spec.n_arrays
    flat = allocate(
        spec, prof, "blockwise", topo.total_pes, free_budget=int(free * 0.7)
    )
    loc = place_allocation(spec, flat, topo, strategy="locality")
    stripe = place_allocation(spec, flat, topo, strategy="stripe")
    assert loc.stage_transfer.sum() <= stripe.stage_transfer.sum()
    assert loc.max_stage_transfer <= stripe.max_stage_transfer
    with pytest.raises(ValueError):
        place_allocation(spec, flat, topo, strategy="nope")


def test_faster_links_reduce_transfer(vgg):
    spec, prof = vgg
    pes = spec.min_pes() * 2
    total = pes + (-pes) % 4
    slow = allocate_placed(
        spec, prof, "blockwise", FabricTopology.split(4, total, link_gbps=8.0)
    )
    fast = allocate_placed(
        spec, prof, "blockwise", FabricTopology.split(4, total, link_gbps=256.0)
    )
    assert fast.placement.stage_transfer.sum() < slow.placement.stage_transfer.sum()


def test_repack_falls_back_to_greedy_chips():
    """On a near-full fabric the dataflow-order re-pack can fail to place
    counts the greedy already certified (different first-fit order); the
    placement must fall back to the greedy's own chips, never crash."""
    from repro.core.alloc.greedy import greedy_allocate_placed, place_extras
    from repro.core.cim.topology import _repack_or_keep

    base = np.array([9.0, 10.0])
    cost = np.array([4.0, 8.0])
    home = np.array([0, 1])
    free = np.array([8.0, 4.0])
    pen = np.zeros((2, 2))
    res = greedy_allocate_placed(
        base, cost, 12.0, home_chip=home, unit_penalty=pen, chip_free=free
    )
    np.testing.assert_array_equal(res.replicas, [2, 2])  # greedy placed both
    with pytest.raises(ValueError):
        place_extras(
            res.replicas, cost, home_chip=home, unit_penalty=pen, chip_free=free
        )
    out = _repack_or_keep(res, cost, home=home, pen=pen, chip_free=free)
    assert [c.tolist() for c in out] == [c.tolist() for c in res.replica_chips]


def test_topology_too_small_rejected(vgg):
    spec, prof = vgg
    tiny = FabricTopology.split(2, 2)  # 2 chips x 1 PE x 64 arrays
    with pytest.raises(ValueError):
        allocate_placed(spec, prof, "blockwise", tiny)


def test_layerwise_placement_accounting(vgg):
    """Layer-wise placements account the mandatory grid at its TRUE
    per-block chips (first-fit may split a grid across chips): per-chip
    load must respect capacity and sum to arrays_used, and the stage
    transfer must see mandatory blocks stranded off the majority chip."""
    spec, prof = vgg
    pes = spec.min_pes() * 2
    total = pes + (-pes) % 4
    topo = FabricTopology.split(4, total, link_gbps=32.0)
    pa = allocate_placed(spec, prof, "perf_layerwise", topo)
    pl = pa.placement
    assert pl.chip_arrays.sum() == pa.allocation.arrays_used
    assert np.all(pl.chip_arrays <= topo.arrays_per_chip)
    # a mandatory grid split across chips must show up in the entry delay:
    # every layer whose mandatory blocks span chips off the source pays > 0
    for i, (man, src) in enumerate(zip(pl.mandatory_chips, pl.layer_src)):
        if (man != src).any():
            assert pl.stage_transfer[i] > 0.0, i


def test_partition_stages_comm_aware():
    """Cut pricing: edge_cost=None is the classic partition (bit-identical);
    a fat activation edge moves the cut; and when every cut costs more than
    the imbalance it relieves, FEWER nonempty stages win (the DP must not
    force degenerate cuts)."""
    from repro.core.alloc.pipeline_stages import bottleneck, partition_stages

    costs = np.exp(np.random.default_rng(1).normal(0, 0.8, size=16))
    assert partition_stages(costs, 4) == partition_stages(costs, 4, edge_cost=None)
    s0 = partition_stages(costs, 4)
    edge = np.zeros(16)
    edge[s0[1][0]] = 100.0  # make the chosen cut very fat
    s1 = partition_stages(costs, 4, edge_cost=edge)
    assert s1[1][0] != s0[1][0]
    # review-found case: both cuts dominated by the edge -> merge instead
    out = partition_stages(
        np.array([10.0, 10.0]), 2, edge_cost=np.array([0.0, 100.0])
    )
    assert out == [(0, 2), (2, 2)]
    assert bottleneck(np.array([10.0, 10.0]), out) == 20.0
