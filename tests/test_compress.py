"""Gradient compression: quantization error bounds + error-feedback
convergence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: pip install .[dev]
from hypothesis import given, settings, strategies as st

from repro.optim.compress import (
    apply_error_feedback,
    dequantize_int8,
    init_error_feedback,
    quantize_int8,
)


@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=4, max_size=64))
@settings(max_examples=100, deadline=None)
def test_quantization_error_bound(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    # deterministic rounding: error <= scale/2 elementwise
    assert err.max() <= float(scale) / 2 + 1e-6


def test_stochastic_rounding_unbiased():
    x = jnp.full((20000,), 0.3) * 127.0 / 127.0
    key = jax.random.PRNGKey(0)
    q, scale = quantize_int8(x, key)
    mean = float(dequantize_int8(q, scale).mean())
    assert abs(mean - 0.3) < 0.01


def test_error_feedback_recovers_signal():
    """A gradient component smaller than one quantization step must still
    accumulate through the residual and eventually transmit (the classic
    error-feedback guarantee)."""
    big, small = 127.0, 0.2  # small < 0.5 * step (step = 1.0)
    g = jnp.asarray([big, small])
    residual = jnp.zeros((2,), jnp.float32)
    sent = np.zeros(2)
    for _ in range(20):
        carried = apply_error_feedback(g, residual)
        q, scale = quantize_int8(carried)
        approx = dequantize_int8(q, scale)
        residual = carried - approx
        sent += np.asarray(approx)
    # over 20 steps the small component must transmit ~20*0.2 total
    assert sent[1] == pytest.approx(20 * small, rel=0.15)
    assert sent[0] == pytest.approx(20 * big, rel=0.01)


def test_sgd_with_compression_converges():
    """Quadratic toy problem: int8+EF SGD reaches the optimum like fp32."""
    target = jnp.asarray([1.5, -2.0, 0.5, 3.0])

    def loss(w):
        return jnp.sum((w - target) ** 2)

    for compressed in (False, True):
        w = jnp.zeros(4)
        residual = jnp.zeros(4)
        for i in range(200):
            g = jax.grad(loss)(w)
            if compressed:
                carried = apply_error_feedback(g, residual)
                q, scale = quantize_int8(carried)
                g_used = dequantize_int8(q, scale)
                residual = carried - g_used
            else:
                g_used = g
            w = w - 0.05 * g_used
        assert float(loss(w)) < 1e-3, ("compressed" if compressed else "exact")


def test_init_error_feedback_shapes():
    tree = {"a": jnp.zeros((3, 4), jnp.bfloat16), "b": jnp.ones((2,), jnp.float32)}
    r = init_error_feedback(tree)
    assert r["a"].shape == (3, 4) and r["a"].dtype == jnp.float32


def test_compressed_train_step_runs_on_cpu_mesh():
    """End-to-end: the pod-compressed step runs (degenerate 1-pod mesh) and
    trains: loss decreases, error-feedback state is produced."""
    import jax
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.distrib.context import set_mesh
    from repro.models import init_params
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.optim.compress import init_error_feedback
    from repro.train.step import make_compressed_train_step

    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    set_mesh(None)
    cfg = get_config("glm4-9b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    ef = init_error_feedback(params)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
    step = make_compressed_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50), mesh)
    with mesh:
        jitted = jax.jit(step)
        losses = []
        for s in range(10):
            params, opt_state, ef, metrics = jitted(params, opt_state, ef, data.batch(s))
            losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    # int8-noisy steps: compare trailing vs leading means
    assert np.mean(losses[-3:]) < np.mean(losses[:3])
    # error feedback is actually carrying quantization residue
    assert any(float(jnp.abs(e).max()) > 0 for e in jax.tree.leaves(ef))
