"""`benchmarks/run.py --json` writes one BENCH_<mode>.json per mode at the
repo root — the machine-readable perf trajectory CI uploads nightly."""

import json
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent


def test_bench_json_schema(tmp_path):
    out = REPO / "BENCH_stage_balance.json"
    existing = out.read_text() if out.exists() else None
    try:
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--json", "stage_balance"],
            cwd=REPO,
            env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin:/usr/local/bin"},
            capture_output=True,
            text=True,
            timeout=300,
        )
        assert r.returncode == 0, r.stderr
        assert out.exists()
        doc = json.loads(out.read_text())
        assert doc["mode"] == "stage_balance"
        assert doc["wall_clock_s"] >= 0
        assert {"python", "numpy", "jax", "platform", "argv"} <= set(doc["config"])
        assert doc["rows"] and doc["rows"][0]["name"].startswith("stage_balance")
        assert "us_per_call" in doc["rows"][0] and "derived" in doc["rows"][0]
    finally:
        if existing is not None:
            out.write_text(existing)
        elif out.exists():
            out.unlink()


def test_bench_rejects_unknown_mode():
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "no_such_bench"],
        cwd=REPO,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin:/usr/local/bin"},
        capture_output=True,
        text=True,
        timeout=120,
    )
    assert r.returncode != 0
    assert "no_such_bench" in r.stderr
