"""Multi-chip DSE sweep: chips x link-bandwidth grid, Pareto frontier over
(throughput, p99, chips), sharded batched evaluation, and topology-aware
tenancy placement."""

import numpy as np
import pytest

from repro.core.cim import FabricTopology
from repro.dse import (
    MULTICHIP_OBJECTIVES,
    chip_grid,
    clear_caches,
    pareto_frontier,
    run_multichip_sweep,
    run_sweep,
    design_grid,
)
from repro.fabric import ClosedLoop, Tenant, allocate_shared, fairness_report, run_tenants


@pytest.fixture(scope="module")
def small_sweep():
    pts = chip_grid(
        networks=("vgg11",), chips=(1, 2, 4), link_gbps=(16.0, 256.0),
        pe_multiplier=2.0,
    )
    res = run_multichip_sweep(
        pts, n_requests=40, closed_requests=30, concurrency=12,
        sample_patches=64, engine="numpy",
    )
    return pts, res


def test_chip_grid_fixes_total_silicon():
    pts = chip_grid(networks=("vgg11",), chips=(1, 2, 4), link_gbps=(16.0,))
    totals = {p.n_pes_total for p in pts}
    assert len(totals) == 1  # equal-silicon comparison
    (total,) = totals
    for p in pts:
        assert total % p.n_chips == 0


def test_multichip_sweep_columns(small_sweep):
    pts, res = small_sweep
    assert len(res) == len(pts)
    assert np.all(np.isfinite(res.images_per_sec))
    assert np.all(res.images_per_sec > 0)
    assert np.all(res.p99_cycles >= res.p50_cycles)
    rows = {(p.n_chips, p.link_gbps): i for i, p in enumerate(res.points)}
    # single chip: no transfers, identical across link bandwidths
    for g in (16.0, 256.0):
        i = rows[(1, g)]
        assert res.max_stage_transfer[i] == 0.0
        assert res.n_crossings[i] == 0
    assert res.p99_cycles[rows[(1, 16.0)]] == res.p99_cycles[rows[(1, 256.0)]]
    # more chips at the same link never reduces the worst transfer
    assert (
        res.max_stage_transfer[rows[(4, 16.0)]]
        >= res.max_stage_transfer[rows[(2, 16.0)]]
    )
    # faster links strictly shrink the transfer at fixed chips
    assert (
        res.max_stage_transfer[rows[(4, 256.0)]]
        < res.max_stage_transfer[rows[(4, 16.0)]]
    )


def test_multichip_pareto_frontier(small_sweep):
    pts, res = small_sweep
    idx = pareto_frontier(res, MULTICHIP_OBJECTIVES)
    assert len(idx) >= 1
    # the single-chip point dominates on p99 and chips at equal silicon, so
    # the frontier must include a 1-chip design
    assert any(res.points[i].n_chips == 1 for i in idx)
    # rows() serializes every point
    rows = res.rows()
    assert len(rows) == len(pts)
    assert {"n_chips", "link_gbps", "images_per_sec", "p99_ms"} <= set(rows[0])


def test_sharded_sweep_identical_to_plain():
    """shard_devices=True routes the batched evaluation through
    distrib.sharding.shard_map_batch — identical numbers."""
    clear_caches()
    pts = design_grid(networks=("vgg11",), pe_multipliers=(1.0, 1.7, 2.0))
    a = run_sweep(pts, sample_patches=48)
    b = run_sweep(pts, sample_patches=48, shard_devices=True)
    np.testing.assert_array_equal(a.images_per_sec, b.images_per_sec)
    np.testing.assert_array_equal(a.total_cycles, b.total_cycles)
    np.testing.assert_array_equal(a.arrays_used, b.arrays_used)


def test_shard_map_batch_pads_odd_batches():
    import jax
    import jax.numpy as jnp

    from repro.distrib.sharding import shard_map_batch

    fn = shard_map_batch(jax.vmap(lambda x: (x * 2.0, x.sum())))
    x = np.arange(15.0).reshape(5, 3)  # 5 rows: not a multiple of anything even
    y, s = fn(x)
    np.testing.assert_allclose(np.asarray(y), x * 2.0)
    np.testing.assert_allclose(np.asarray(s), x.sum(axis=1))


# ------------------------------------------------------- tenancy placement
def test_tenancy_topology_placement(profiled):
    spec, prof = profiled("vgg11", n_images=1, sample_patches=64)
    tenants = [
        Tenant("prio", spec, prof, weight=2.0),
        Tenant("batch", spec, prof, weight=1.0),
    ]
    n_pes = -(-2 * spec.n_arrays // 64) * 2
    n_pes += (-n_pes) % 2
    flat = allocate_shared(tenants, n_pes=n_pes)
    topo = FabricTopology.split(2, n_pes, link_gbps=32.0)
    shared = allocate_shared(tenants, n_pes=n_pes, topology=topo)
    # counts are the flat weighted-fair greedy's, topology or not
    for a, b in zip(flat.allocations, shared.allocations):
        for x, y in zip(a.block_dups, b.block_dups):
            np.testing.assert_array_equal(x, y)
    assert shared.placements is not None and len(shared.placements) == 2
    # tenants share the tree without oversubscribing any chip
    load = sum(p.chip_arrays for p in shared.placements)
    assert np.all(load <= topo.arrays_per_chip)
    # placements flow into the simulations + report
    results = run_tenants(shared, [ClosedLoop(20, 8), ClosedLoop(20, 8)], seed=0)
    rep = fairness_report(shared, results)
    for d in rep["tenants"].values():
        assert "max_stage_transfer_cycles" in d and "chips" in d
    # budget mismatch is rejected
    with pytest.raises(ValueError):
        allocate_shared(tenants, n_pes=n_pes, topology=FabricTopology.split(2, n_pes + 2))
