"""Acceptance: event-driven closed-loop throughput agrees with the analytic
``simulate()`` within 10% for all five policies on ResNet18.

A 20-stage pipeline needs ~2x that many in-flight requests before the
bottleneck saturates (blockwise equalizes per-stage times, so the
sum/max ratio approaches the layer count); the closed loop below holds 40.
"""

import pytest

from repro.core.cim import allocate, simulate
from repro.fabric import ClosedLoop, FabricSim

POLICIES = ("baseline", "weight_based", "perf_layerwise", "weight_blockflow", "blockwise")


@pytest.fixture(scope="module")
def resnet(profiled):
    return profiled("resnet18", n_images=1, sample_patches=64)


@pytest.mark.parametrize("policy", POLICIES)
def test_closed_loop_matches_analytic_resnet18(resnet, policy):
    spec, prof = resnet
    alloc = allocate(spec, prof, policy, spec.min_pes() * 2)
    ana = simulate(spec, prof, alloc, n_images=64)
    res = FabricSim(spec, prof, alloc, seed=1).run(
        ClosedLoop(n_requests=120, concurrency=40)
    )
    assert res.images_per_sec == pytest.approx(ana.images_per_sec, rel=0.10)


def test_vtime_bit_identical_resnet18(resnet):
    """The batched virtual-time kernel reproduces the event engine's
    per-request times exactly on the ResNet18 closed-loop workload (the
    VGG11 equivalences live in test_fabric_vtime.py)."""
    import numpy as np

    from repro.fabric import VirtualTimeFabric

    spec, prof = resnet
    alloc = allocate(spec, prof, "blockwise", spec.min_pes() * 2)
    proc = ClosedLoop(n_requests=30, concurrency=12)
    ref = FabricSim(spec, prof, alloc, seed=1).run(proc)
    res = VirtualTimeFabric(spec, prof).run_batch([alloc], proc, seed=1)
    np.testing.assert_array_equal(res.completions[0], ref.completions)
    np.testing.assert_array_equal(res.arrivals[0], ref.arrivals)
