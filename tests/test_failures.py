"""Fault-tolerant fabric: trace generation, degrade plans, both engines.

The correctness spine is the cross-engine contract: one seeded
``FailureTrace`` compiled to a ``DegradePlan`` replays BIT-identically on
the event calendar (``FabricSim(failures=plan)``) and the segmented vtime
kernel (``run_trace_segments`` / ``run_trace_failures``) — pinned here on
VGG11 and ResNet18 with numpy and jax loop shapes.  Around it: generator
determinism and floors, spare-pool re-placement accounting, zero-survivor
retry/shedding (event engine only, outside the identity contract),
brownout admission, the allocator's spare holdback/release, and the
spare-fraction x failure-rate DSE sweep feeding ``FAULT_OBJECTIVES``.
"""

import math

import numpy as np
import pytest

from repro.core.cim import allocate, simulate
from repro.core.cim.simulate import CLOCK_HZ, split_block_dups
from repro.fabric import (
    DriftConfig,
    FabricSim,
    FailureTrace,
    RetryPolicy,
    TraceReplay,
    VirtualTimeFabric,
    degrade_plan,
    degrade_plan_from_allocs,
    failure_step_schedule,
    generate_failure_events,
    generate_failure_trace,
    lane_chips,
    run_trace_failures,
    run_trace_segments,
)
from repro.fabric.dispatch import Allocation


@pytest.fixture(scope="module")
def vgg(profiled):
    return profiled("vgg11", n_images=1, sample_patches=64)


@pytest.fixture(scope="module")
def setup(vgg):
    spec, prof = vgg
    bw = allocate(spec, prof, "blockwise", spec.min_pes() * 2)
    cap = simulate(spec, prof, bw, n_images=64).images_per_sec
    vt = VirtualTimeFabric(spec, prof)
    return spec, prof, bw, cap, vt


def _times(cap, n=60, frac=0.6, seed=7):
    gaps = np.random.default_rng(seed).exponential(1.0, size=n)
    return np.cumsum(gaps) / (frac * cap / CLOCK_HZ)


# ------------------------------------------------------------- generator
def test_generator_deterministic_and_sorted():
    dups = np.array([3, 2, 4])
    widths = np.array([2, 8, 1])
    kw = dict(
        horizon=1e6, seed=11, rate_per_array=2e-5, repair_cycles=2e5,
        arrays_per_chip=8, chip_burst_rate=1e-6,
    )
    a = generate_failure_events(dups, widths, **kw)
    b = generate_failure_events(dups, widths, **kw)
    assert a == b
    c = generate_failure_events(dups, widths, **{**kw, "seed": 12})
    assert a != c
    times = [e.time for e in a]
    assert times == sorted(times)
    assert all(0.0 < e.time < 1e6 for e in a)


def test_generator_min_survivors_floor():
    dups = np.array([2, 3])
    widths = np.array([4, 4])
    ev = generate_failure_events(
        dups, widths, horizon=1e7, seed=0, rate_per_array=1e-4
    )
    alive = dups.astype(np.int64).copy()
    for e in ev:
        alive[e.unit] += 1 if e.repair else -1
        assert alive[e.unit] >= 1  # the default floor
    # a zero floor may drain units completely
    ev0 = generate_failure_events(
        dups, widths, horizon=1e7, seed=0, rate_per_array=1e-4, min_survivors=0
    )
    assert sum(not e.repair for e in ev0) >= sum(not e.repair for e in ev)


def test_lane_chips_linear_packing():
    chips = lane_chips(np.array([2, 3, 1]), np.array([4, 2, 8]), arrays_per_chip=8)
    assert [c.tolist() for c in chips] == [[0, 0], [1, 1, 1], [1]]


def _ev(time, unit, lane, repair=False, chip=0):
    from repro.fabric import FailureEvent

    return FailureEvent(time, unit, lane, repair, chip)


def test_trace_mttr_and_step_schedule():
    t = FailureTrace(
        (
            _ev(100.0, 0, 0), _ev(300.0, 0, 0, repair=True),
            _ev(500.0, 1, 1), _ev(900.0, 1, 1, repair=True),
        ),
        horizon=1000.0, seed=0, n_units=2,
    )
    assert t.mttr() == 300.0
    assert t.n_failures == 2 and t.n_repairs == 2
    sched = failure_step_schedule(t, cycles_per_step=250.0)
    assert sched == {0: 1, 2: 1}


# ----------------------------------------------------------- degrade plan
def test_degrade_plan_accounting(setup):
    spec, prof, bw, cap, vt = setup
    horizon = 2e6
    trace = generate_failure_trace(
        spec, bw, horizon=horizon, seed=5, rate_per_array=2e-8,
        repair_cycles=horizon / 4,
    )
    assert trace.n_failures > 0
    plan = degrade_plan(spec, prof, bw, trace, spare_arrays=64.0)
    assert plan.n_segments == len(plan.boundaries) + 1
    assert plan.arrays_added[0] == 0 and plan.stall_cycles[0] == 0.0
    assert 0.0 < plan.availability() <= 1.0
    assert plan.spare_left >= 0.0
    assert plan.replaced_arrays == pytest.approx(64.0 - plan.spare_left)
    # stalls follow the drift book exactly: stall(added) where added > 0
    for a, s in zip(plan.arrays_added, plan.stall_cycles):
        assert s == (plan.drift.stall(int(a)) if a > 0 else 0.0)
    # spares defend capacity: same trace without spares sits strictly lower
    bare = degrade_plan(spec, prof, bw, trace)
    assert bare.availability() < plan.availability()


def test_degrade_plan_empty_trace_is_identity(setup):
    spec, prof, bw, cap, vt = setup
    trace = FailureTrace((), 1e6, 0, 0)
    plan = degrade_plan(spec, prof, bw, trace)
    assert plan.n_segments == 1 and plan.availability() == 1.0
    np.testing.assert_array_equal(plan.flat_dups(0),
                                  np.concatenate(bw.block_dups))


# --------------------------------------------- cross-engine bit-identity
@pytest.mark.parametrize("network", ["vgg11", "resnet18"])
@pytest.mark.parametrize("engine", ["numpy", "jax"])
def test_failure_replay_bit_identical_across_engines(profiled, network, engine):
    """THE acceptance pin: one seeded failure trace (kills, repairs, spare
    re-placement, reprogram stalls) replayed by the event calendar and the
    segmented vtime kernel produces byte-equal completion times."""
    if engine == "jax":
        pytest.importorskip("jax")
    spec, prof = profiled(network, n_images=1, sample_patches=64)
    bw = allocate(spec, prof, "blockwise", spec.min_pes() * 2)
    cap = simulate(spec, prof, bw, n_images=64).images_per_sec
    times = _times(cap, n=60)
    horizon = float(times[-1])
    trace = generate_failure_trace(
        spec, bw, horizon=horizon, seed=5, rate_per_array=2e-9,
        repair_cycles=horizon / 4,
    )
    assert trace.n_failures > 0, "trace must actually exercise failures"
    plan = degrade_plan(spec, prof, bw, trace, spare_arrays=32.0)
    assert plan.n_segments > 1
    ev = FabricSim(spec, prof, bw, seed=3, failures=plan).run(TraceReplay(times))
    vt = VirtualTimeFabric(spec, prof)
    res = run_trace_segments(
        vt, list(plan.allocs), times, plan.boundaries, drift=plan.drift,
        stream=False, seed=3, engine=engine,
    )
    np.testing.assert_array_equal(ev.completions, res.completions[0])


def test_run_trace_failures_wrapper(setup):
    """The one-call vtime entry point compiles the trace itself and equals
    the hand-compiled plan replay."""
    spec, prof, bw, cap, vt = setup
    times = _times(cap, n=50)
    horizon = float(times[-1])
    trace = generate_failure_trace(
        spec, bw, horizon=horizon, seed=5, rate_per_array=2e-9,
    )
    plan = degrade_plan(spec, prof, bw, trace)
    a = run_trace_failures(
        vt, prof, bw, TraceReplay(times), trace, stream=False, seed=3,
        engine="numpy",
    )
    b = run_trace_segments(
        vt, list(plan.allocs), times, plan.boundaries, drift=plan.drift,
        stream=False, seed=3, engine="numpy",
    )
    np.testing.assert_array_equal(a.completions, b.completions)


def test_failure_free_run_unchanged(setup):
    """An empty failure trace is a no-op on the event engine: bit-identical
    to a plain run (the failure machinery may not perturb healthy serving)."""
    spec, prof, bw, cap, vt = setup
    times = _times(cap, n=40)
    plan = degrade_plan(
        spec, prof, bw, FailureTrace((), float(times[-1]), 0, 0)
    )
    with_hooks = FabricSim(spec, prof, bw, seed=3, failures=plan).run(
        TraceReplay(times)
    )
    plain = FabricSim(spec, prof, bw, seed=3).run(TraceReplay(times))
    np.testing.assert_array_equal(with_hooks.completions, plain.completions)


def test_failure_injection_requires_open_loop_and_blockwise(setup, profiled):
    from repro.fabric import ClosedLoop

    spec, prof, bw, cap, vt = setup
    plan = degrade_plan(spec, prof, bw, FailureTrace((), 1e6, 0, 0))
    with pytest.raises(ValueError, match="open-loop"):
        FabricSim(spec, prof, bw, seed=0, failures=plan).run(ClosedLoop(10, 4))
    wb = allocate(spec, prof, "weight_based", spec.min_pes() * 2)
    with pytest.raises(ValueError, match="block-wise"):
        FabricSim(spec, prof, wb, seed=0, failures=plan)


# ------------------------------------------------- zero-survivor serving
@pytest.fixture(scope="module")
def outage(setup):
    """Manual trajectory: the first block loses ALL replicas for the middle
    third of the trace, then revives."""
    spec, prof, bw, cap, vt = setup
    times = _times(cap, n=60)
    flat = np.concatenate(bw.block_dups)
    dead = flat.copy()
    dead[0] = 0
    dead_alloc = Allocation(
        bw.policy, None, split_block_dups(spec, dead),
        bw.arrays_used, bw.arrays_total,
    )
    bounds = [float(times[20]) + 0.5, float(times[40]) + 0.5]
    plan = degrade_plan_from_allocs(
        spec, [bw, dead_alloc, bw], bounds, horizon=float(times[-1])
    )
    return spec, prof, bw, times, bounds, plan


def test_zero_survivor_stall_until_revival(outage):
    """Infinite patience: every request is served, but requests arriving
    into the outage wait for the revival seam — their completions land at
    or after it."""
    spec, prof, bw, times, bounds, plan = outage
    out = FabricSim(spec, prof, bw, seed=0, failures=plan).run(TraceReplay(times))
    comp = np.asarray(out.completions)
    assert not np.isnan(comp).any()
    mid = (times > bounds[0]) & (times <= bounds[1])
    assert comp[mid].min() >= bounds[1]
    # post-revival requests complete; ordering within the stream is intact
    assert comp[-1] > bounds[1]


def test_zero_survivor_timeout_sheds(outage):
    """Finite patience: outage-window requests exceed the timeout and are
    shed (NaN completions, never forwarded); healthy-window requests are
    untouched."""
    spec, prof, bw, times, bounds, plan = outage
    policy = RetryPolicy(timeout_cycles=(bounds[1] - bounds[0]) / 10)
    out = FabricSim(
        spec, prof, bw, seed=0, failures=plan, retry=policy
    ).run(TraceReplay(times))
    comp = np.asarray(out.completions)
    shed = np.isnan(comp)
    assert shed.any()
    # every outage-window request facing a wait beyond the timeout is shed;
    # one arriving within `timeout` of the revival seam rides it out
    deep = (times > bounds[0]) & (times < bounds[1] - policy.timeout_cycles)
    assert shed[deep].all()
    assert not shed[times <= bounds[0]].any()
    ref = FabricSim(spec, prof, bw, seed=0).run(TraceReplay(times))
    pre = times <= bounds[0]
    np.testing.assert_array_equal(comp[pre], ref.completions[pre])


def test_retry_policy_validation():
    with pytest.raises(ValueError, match="timeout_cycles"):
        RetryPolicy(timeout_cycles=-1.0)
    with pytest.raises(ValueError, match="max_retries"):
        RetryPolicy(max_retries=-1)


# ------------------------------------------------------ allocator spares
def test_greedy_allocate_spare_fraction(vgg):
    from repro.core.cim.simulate import _layer_patch_cycles, blockwise_units
    from repro.core.alloc.greedy import greedy_allocate

    spec, prof = vgg
    cyc = _layer_patch_cycles(prof, True)
    base_lat, cost = blockwise_units(spec, [c.mean(axis=0) for c in cyc])
    full = greedy_allocate(base_lat, cost, 256.0)
    held = greedy_allocate(base_lat, cost, 256.0, spare_fraction=0.25)
    # default 0.0 is bit-identical to the pre-PR allocator
    again = greedy_allocate(base_lat, cost, 256.0, spare_fraction=0.0)
    np.testing.assert_array_equal(full.replicas, again.replicas)
    assert held.spent <= 256.0 * 0.75
    assert held.leftover >= 256.0 * 0.25  # the reserve comes back untouched
    assert held.spent + held.leftover == pytest.approx(256.0)
    with pytest.raises(ValueError, match="spare_fraction"):
        greedy_allocate(base_lat, cost, 256.0, spare_fraction=1.5)


def test_greedy_release_frees_cheapest_latency(vgg):
    from repro.core.cim.simulate import _layer_patch_cycles, blockwise_units
    from repro.core.alloc.greedy import greedy_allocate, greedy_release

    spec, prof = vgg
    cyc = _layer_patch_cycles(prof, True)
    base_lat, cost = blockwise_units(spec, [c.mean(axis=0) for c in cyc])
    grown = greedy_allocate(base_lat, cost, 512.0)
    rel = greedy_release(base_lat, cost, 128.0, replicas=grown.replicas)
    freed = float((grown.replicas - rel.replicas) @ cost)
    assert freed >= 128.0 and rel.spent == -freed
    assert np.all(rel.replicas >= 1)
    # release everything releasable: lands on exactly one copy per unit
    total = float((grown.replicas - 1) @ cost)
    floor = greedy_release(base_lat, cost, total * 2, replicas=grown.replicas)
    np.testing.assert_array_equal(floor.replicas, np.ones_like(grown.replicas))


def test_spares_per_chip():
    from repro.core.cim.topology import FabricTopology

    topo = FabricTopology(pes_per_chip=32, n_chips=4, arrays_per_pe=8)
    assert topo.arrays_per_chip == 256
    assert topo.spares_per_chip(0.1) == 25
    assert topo.spares_per_chip(0.0) == 0
    with pytest.raises(ValueError, match="spare_fraction"):
        topo.spares_per_chip(-0.1)


# ------------------------------------------------------------- brownout
def test_brownout_plan():
    from repro.serve.scheduler import brownout_plan

    frac = brownout_plan(
        offered_rps=np.array([10.0, 100.0, 100.0, 0.0]),
        capacity_rps=np.array([50.0, 50.0, 200.0, 50.0]),
        p99_cycles=np.array([1e3, 1e3, 4e3, 1e3]),
        slo_cycles=2e3,
    )
    assert frac[0] == 1.0          # healthy: fully admitted
    assert frac[1] == pytest.approx(0.5)   # over capacity: shed to stability
    assert frac[2] == pytest.approx(0.5)   # SLO-violating tail: shed to SLO
    assert frac[3] == 1.0          # no traffic: no shedding
    lo = brownout_plan(
        offered_rps=np.array([1e9]), capacity_rps=np.array([1.0]),
        p99_cycles=np.array([1.0]), slo_cycles=1e3,
    )
    assert lo[0] == pytest.approx(0.05)  # floor: never a full blackout
    with pytest.raises(ValueError, match="slo_cycles"):
        brownout_plan(np.array([1.0]), np.array([1.0]), np.array([1.0]), 0.0)


# ------------------------------------------------------------ DSE sweep
def test_fault_objectives_wiring():
    """FAULT_OBJECTIVES resolve against FaultSweepResult columns (plus the
    virtual spare_fraction/rate columns) without running a sweep."""
    from repro.dse import FAULT_OBJECTIVES, pareto_mask
    from repro.dse.faults import FaultPoint, FaultSweepResult

    pts = [
        FaultPoint("vgg11", 0.0, 1e-8, 8),
        FaultPoint("vgg11", 0.2, 1e-8, 8),
    ]
    res = FaultSweepResult(
        points=pts,
        availability=np.array([0.9, 1.0]),
        p50_cycles=np.array([10.0, 8.0]),
        p99_cycles=np.array([30.0, 20.0]),
        arrays_used=np.array([100, 90]),
        arrays_total=np.array([128, 128]),
        spare_arrays=np.array([0, 25]),
        n_killed=np.array([5, 5]),
        n_repaired=np.array([0, 0]),
        total_stall_cycles=np.array([0.0, 2048.0]),
        elapsed_s=0.0,
    )
    names = tuple(n for n, _ in FAULT_OBJECTIVES)
    vals = res.objectives(names)
    assert vals.shape == (2, 3)
    np.testing.assert_array_equal(vals[:, 0], res.availability)
    mask = pareto_mask(vals, [m for _, m in FAULT_OBJECTIVES])
    assert mask[1] and not mask[0]  # point 1 dominates on all three
    extra = res.objectives(("spare_fraction", "rate_per_array"))
    np.testing.assert_allclose(extra[:, 0], [0.0, 0.2])
    assert res.rows()[1]["spare_arrays"] == 25


@pytest.mark.slow
def test_fault_sweep_and_frontier(profiled):
    from repro.dse import FAULT_OBJECTIVES, fault_grid, pareto_frontier, run_fault_sweep

    pts = fault_grid(networks=("vgg11",), spare_fractions=(0.0, 0.2), rates=(5e-9,))
    assert len(pts) == 2
    res = run_fault_sweep(
        pts, n_requests=40, profile_images=1, sample_patches=64, engine="numpy"
    )
    assert np.all((res.availability >= 0.0) & (res.availability <= 1.0))
    # spares buy availability at equal silicon
    assert res.availability[1] >= res.availability[0]
    assert res.spare_arrays[1] > 0 and res.spare_arrays[0] == 0
    np.testing.assert_array_equal(res.arrays_total[0], res.arrays_total[1])
    idx = pareto_frontier(res, FAULT_OBJECTIVES)
    assert len(idx) >= 1
    rows = res.rows()
    assert rows[0]["availability"] == pytest.approx(float(res.availability[0]))


# --------------------------------------------------- training-side bridge
def test_fault_injector_from_trace():
    from repro.runtime.fault import FaultInjector

    t = FailureTrace(
        (_ev(100.0, 0, 0), _ev(260.0, 1, 0), _ev(300.0, 0, 0, repair=True)),
        horizon=1000.0, seed=0, n_units=2,
    )
    inj = FaultInjector.from_trace(t, cycles_per_step=250.0)
    assert inj.fail_budget == {0: 1, 1: 1}  # repairs do not raise
    with pytest.raises(RuntimeError, match="injected failure at step 0"):
        inj(0)
    inj(0)  # budget exhausted: second pass is clean
